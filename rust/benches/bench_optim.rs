//! Figure-1 timing basis: per-iteration cost of each optimizer on a
//! binarized dataset. The paper's wall-clock claim reduces to the ratio
//! between one surrogate CD sweep and one (quasi/prox/exact) Newton
//! iteration; this bench regenerates those per-iteration costs.

use fastsurvival::cox::CoxProblem;
use fastsurvival::data::binarize::{binarize, BinarizeConfig};
use fastsurvival::data::datasets;
use fastsurvival::optim::{self, FitConfig, Objective, Optimizer};
use fastsurvival::util::bench::Bencher;
use std::hint::black_box;

fn main() {
    let mut b = Bencher::from_env();
    let mut spec = datasets::spec("flchain");
    spec.n = 1000;
    let raw = datasets::generate_stand_in(&spec, 1);
    let ds = binarize(&raw, &BinarizeConfig { max_quantiles: 15, ..Default::default() });
    let pr = CoxProblem::new(&ds);
    println!("== per-iteration optimizer cost (flchain stand-in, n={} p={}) ==", ds.n(), ds.p());

    for (l1, l2, tag) in [(0.0, 1.0, "l2=1"), (1.0, 5.0, "l1=1,l2=5")] {
        for m in ["quadratic", "cubic", "newton", "quasi-newton", "prox-newton", "gd"] {
            if m == "newton" && l1 > 0.0 {
                continue; // exact Newton has no ℓ1 mode (paper)
            }
            let opt = optim::by_name(m).unwrap();
            let cfg = FitConfig {
                objective: Objective { l1, l2 },
                max_iters: 1, // one outer iteration
                tol: 0.0,
                record_trace: false,
                ..Default::default()
            };
            b.bench(&format!("{:<18} 1 iter  ({tag})", opt.name()), || {
                black_box(opt.fit(&pr, &cfg).unwrap());
            });
        }
    }

    println!("\n== end-to-end to tolerance 1e-8 (the Figure-1 wall-clock race) ==");
    for m in ["quadratic", "cubic", "quasi-newton", "prox-newton"] {
        let opt = optim::by_name(m).unwrap();
        let cfg = FitConfig {
            objective: Objective { l1: 1.0, l2: 5.0 },
            max_iters: 500,
            tol: 1e-8,
            record_trace: false,
            ..Default::default()
        };
        b.bench(&format!("{:<18} to 1e-8 (l1=1,l2=5)", opt.name()), || {
            black_box(opt.fit(&pr, &cfg).unwrap());
        });
    }

    b.summary("bench_optim (Figure 1 / Figs 5-20 timing basis)");
}
