//! Figure-3/4 timing basis: fit + predict cost of every model class
//! compared in the Dialysis / EmployeeAttrition experiments.

use fastsurvival::baselines::forest::{ForestConfig, RandomSurvivalForest};
use fastsurvival::baselines::gbst::{GbstConfig, GradientBoostedCox};
use fastsurvival::baselines::svm::{FastSurvivalSvm, NaiveSurvivalSvm, SvmConfig};
use fastsurvival::baselines::tree::{SurvivalTree, TreeConfig};
use fastsurvival::baselines::SurvivalModel;
use fastsurvival::cox::CoxProblem;
use fastsurvival::data::datasets;
use fastsurvival::optim::{CubicSurrogate, FitConfig, Objective, Optimizer};
use fastsurvival::util::bench::Bencher;
use std::hint::black_box;

fn main() {
    let mut b = Bencher::from_env();
    let mut spec = datasets::spec("dialysis");
    spec.n = 800;
    let ds = datasets::generate_stand_in(&spec, 3);
    println!("== model-class fit cost (dialysis stand-in, n={} p={}) ==", ds.n(), ds.p());

    let pr = CoxProblem::new(&ds);
    b.bench("cox cubic-surrogate (ours)      fit", || {
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: 0.1 },
            max_iters: 50,
            tol: 1e-8,
            record_trace: false,
            ..Default::default()
        };
        black_box(CubicSurrogate.fit(&pr, &cfg).unwrap());
    });
    b.bench("survival-tree  (depth 4)        fit", || {
        black_box(SurvivalTree::fit(&ds, &TreeConfig::default()));
    });
    b.bench("rsf            (20 trees)       fit", || {
        black_box(RandomSurvivalForest::fit(
            &ds,
            &ForestConfig { n_trees: 20, ..Default::default() },
        ));
    });
    b.bench("gbst           (30 stages)      fit", || {
        black_box(GradientBoostedCox::fit(
            &ds,
            &GbstConfig { n_stages: 30, ..Default::default() },
        ));
    });
    b.bench("fast-svm       (adjacent pairs) fit", || {
        black_box(FastSurvivalSvm::fit(&ds, &SvmConfig { max_iters: 100, ..Default::default() }));
    });
    b.bench("naive-svm      (all pairs)      fit", || {
        black_box(NaiveSurvivalSvm::fit(&ds, &SvmConfig { max_iters: 20, ..Default::default() }));
    });

    println!("\n== prediction cost ==");
    let rf = RandomSurvivalForest::fit(&ds, &ForestConfig { n_trees: 20, ..Default::default() });
    b.bench("rsf predict_risk (n=800)", || {
        black_box(rf.predict_risk(&ds.x));
    });

    b.summary("bench_model_classes (Figures 3/4 timing basis)");
}
