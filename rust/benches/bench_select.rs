//! Figure-2 timing basis: variable-selection cost. Benchmarks the beam
//! search's two inner operations (batched screening, exact candidate
//! evaluation) and whole-path runs for each selector.

use fastsurvival::cox::{CoxProblem, CoxState};
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::select::beam::screen_gains;
use fastsurvival::select::{Abess, AdaptiveLasso, BeamSearch, CoxnetPath, VariableSelector};
use fastsurvival::util::bench::Bencher;
use std::hint::black_box;

fn main() {
    let mut b = Bencher::from_env();
    let ds = generate(&SyntheticConfig { n: 400, p: 400, rho: 0.9, k: 10, s: 0.1, seed: 0 });
    let pr = CoxProblem::new(&ds);
    println!("== selection primitives (synthetic rho=0.9, n=p=400) ==");

    let st = CoxState::zeros(&pr);
    b.bench("screen_gains (all p surrogate gains)", || {
        black_box(screen_gains(&pr, &st));
    });

    println!("\n== full selection paths to k=5 ==");
    let selectors: Vec<(&str, Box<dyn VariableSelector>)> = vec![
        (
            "beam(width=5,screen=10)",
            Box::new(BeamSearch { width: 5, screen: 10, ..Default::default() }),
        ),
        ("abess", Box::new(Abess::default())),
        ("coxnet-path", Box::new(CoxnetPath { n_lambdas: 20, ..Default::default() })),
        (
            "adaptive-lasso(3 alphas)",
            Box::new(AdaptiveLasso { alphas: vec![0.1, 1.0, 10.0], ..Default::default() }),
        ),
    ];
    let ks: Vec<usize> = (1..=5).collect();
    for (name, sel) in &selectors {
        b.bench(&format!("{name:<28} ks=1..5"), || {
            black_box(sel.select(&pr, &ks));
        });
    }

    println!("\n== ablation: beam swap-polish (DESIGN.md design choice) ==");
    for (name, rounds) in [("polish off", 0usize), ("polish 2 rounds", 2)] {
        let bs = BeamSearch { width: 5, screen: 10, polish_rounds: rounds, ..Default::default() };
        b.bench(&format!("beam k=5 {name}"), || {
            black_box(bs.select(&pr, &ks));
        });
    }

    b.summary("bench_select (Figure 2 timing basis)");
}
