//! L1 hot-path microbenchmarks: the O(n) derivative passes (Corollary
//! 3.3) that make the surrogate methods cheap — native vs AOT-XLA.
//!
//! Run with `cargo bench` (set FASTSURVIVAL_BENCH_QUICK=1 for CI).

use fastsurvival::cox::derivatives::{
    all_coord_d1_d2, all_coord_d1_d2_seq, all_coord_d1_d2_with_threads, coord_d1, coord_d1_d2,
    coord_derivs, Workspace,
};
use fastsurvival::cox::lipschitz::coord_lipschitz;
use fastsurvival::cox::{CoxProblem, CoxState};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::linalg::Matrix;
use fastsurvival::runtime::engine::{CoxEngine, XlaEngine};
use fastsurvival::util::bench::Bencher;
use fastsurvival::util::rng::Rng;
use std::hint::black_box;

fn problem(n: usize, p: usize, seed: u64) -> CoxProblem {
    let mut rng = Rng::new(seed);
    let cols: Vec<Vec<f64>> = (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
    let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
    CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "b"))
}

fn main() {
    let mut b = Bencher::from_env();
    println!("== L1 hot path: exact O(n) coordinate derivatives ==");

    for &n in &[1024usize, 4096, 16384] {
        let pr = problem(n, 4, 42);
        let st = CoxState::from_beta(&pr, &[0.2, -0.1, 0.3, 0.0]);
        b.bench(&format!("coord_d1            n={n}"), || {
            black_box(coord_d1(&pr, &st, 0));
        });
        b.bench(&format!("coord_d1_d2         n={n}"), || {
            black_box(coord_d1_d2(&pr, &st, 0));
        });
        b.bench(&format!("coord_derivs(d1-d3) n={n}"), || {
            black_box(coord_derivs(&pr, &st, 0));
        });
        b.bench(&format!("lipschitz           n={n}"), || {
            black_box(coord_lipschitz(&pr, 0));
        });
    }

    println!("\n== batched screening pass (beam-search hot path) ==");
    for &(n, p) in &[(1024usize, 128usize), (4096, 256)] {
        let pr = problem(n, p, 7);
        let st = CoxState::zeros(&pr);
        b.bench(&format!("all_coord_seq       n={n} p={p}"), || {
            black_box(all_coord_d1_d2_seq(&pr, &st));
        });
        let mut ws = Workspace::default();
        b.bench(&format!("all_coord_blocked   n={n} p={p}"), || {
            black_box(all_coord_d1_d2(&pr, &st, &mut ws));
        });
        for t in [1usize, 2, 4] {
            let mut ws = Workspace::default();
            b.bench(&format!("all_coord_blocked_t{t} n={n} p={p}"), || {
                black_box(all_coord_d1_d2_with_threads(&pr, &st, &mut ws, t));
            });
        }
    }

    // Native vs AOT-XLA comparison (three-layer composition cost).
    if let Ok(xe) = XlaEngine::new(std::path::Path::new("artifacts")) {
        println!("\n== native vs AOT-XLA engine (n=1024) ==");
        let pr = problem(1000, 4, 9);
        let st = CoxState::from_beta(&pr, &[0.1, 0.2, -0.1, 0.0]);
        b.bench("xla coord_derivs     n=1024(pad)", || {
            black_box(xe.coord_derivs(&pr, &st, 0).unwrap());
        });
        b.bench("xla cox_loss         n=1024(pad)", || {
            black_box(xe.loss(&pr, &st).unwrap());
        });
    } else {
        println!("(artifacts missing; skipping XLA benches — run `make artifacts`)");
    }

    b.summary("bench_derivatives");
}
