//! Edge cases and failure modes: degenerate datasets, extreme ties,
//! constant features, single samples, all-censored data.

use fastsurvival::cox::derivatives::{coord_derivs, Workspace, all_coord_d1_d2};
use fastsurvival::cox::lipschitz::coord_lipschitz;
use fastsurvival::cox::loss::loss;
use fastsurvival::cox::{CoxProblem, CoxState};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::linalg::Matrix;
use fastsurvival::metrics::{concordance_index, KaplanMeier};
use fastsurvival::optim::{CubicSurrogate, FitConfig, Objective, Optimizer, QuadraticSurrogate};
use fastsurvival::select::{BeamSearch, VariableSelector};

fn ds(x_cols: &[Vec<f64>], time: Vec<f64>, event: Vec<bool>) -> SurvivalDataset {
    SurvivalDataset::new(Matrix::from_columns(x_cols), time, event, "edge")
}

#[test]
fn all_censored_fit_is_noop() {
    let d = ds(&[vec![1.0, -1.0, 0.5, 0.0]], vec![4.0, 3.0, 2.0, 1.0], vec![false; 4]);
    let pr = CoxProblem::new(&d);
    let st = CoxState::zeros(&pr);
    assert_eq!(loss(&pr, &st), 0.0);
    let res = CubicSurrogate.fit(&pr, &FitConfig::default()).unwrap();
    assert!(res.beta.iter().all(|&b| b == 0.0), "no events → nothing to fit");
}

#[test]
fn single_sample_problem() {
    let d = ds(&[vec![1.5]], vec![1.0], vec![true]);
    let pr = CoxProblem::new(&d);
    let st = CoxState::zeros(&pr);
    // One sample: its risk set is itself → loss = log(1) = 0, derivs 0.
    assert_eq!(loss(&pr, &st), 0.0);
    let der = coord_derivs(&pr, &st, 0);
    assert_eq!(der.d1, 0.0);
    assert_eq!(der.d2, 0.0);
    let res = QuadraticSurrogate.fit(&pr, &FitConfig::default()).unwrap();
    assert!(res.beta[0].abs() < 1e-12);
}

#[test]
fn all_times_tied() {
    // Every sample in one tie group: every risk set is everything.
    let d = ds(
        &[vec![1.0, 2.0, 3.0, 4.0]],
        vec![5.0; 4],
        vec![true, true, false, true],
    );
    let pr = CoxProblem::new(&d);
    assert_eq!(pr.groups.len(), 1);
    let st = CoxState::zeros(&pr);
    let l = loss(&pr, &st);
    assert!((l - 3.0 * (4.0_f64).ln()).abs() < 1e-12);
    // Fit stays finite and monotone.
    let res = CubicSurrogate
        .fit(
            &pr,
            &FitConfig { objective: Objective { l1: 0.0, l2: 0.1 }, ..Default::default() },
        )
        .unwrap();
    assert!(res.trace.monotone(1e-10));
    assert!(res.beta[0].is_finite());
}

#[test]
fn constant_feature_is_ignored() {
    let d = ds(
        &[vec![2.0; 6], vec![1.0, -1.0, 0.5, -0.5, 0.2, -0.2]],
        vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0],
        vec![true; 6],
    );
    let pr = CoxProblem::new(&d);
    assert_eq!(coord_lipschitz(&pr, 0).l2, 0.0);
    let res = CubicSurrogate.fit(&pr, &FitConfig::default()).unwrap();
    assert_eq!(res.beta[0], 0.0, "constant column gets no weight");
    assert!(res.beta[1].abs() > 0.0);
}

#[test]
fn perfectly_separated_feature_stays_finite() {
    // Feature that exactly orders failures: unregularized MLE → ∞, but
    // the surrogate steps remain finite and the loss decreases.
    let d = ds(
        &[vec![3.0, 2.0, 1.0, 0.0, -1.0, -2.0]],
        vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        vec![true; 6],
    );
    let pr = CoxProblem::new(&d);
    let res = QuadraticSurrogate
        .fit(&pr, &FitConfig { max_iters: 200, ..Default::default() })
        .unwrap();
    assert!(res.beta[0].is_finite());
    assert!(res.trace.monotone(1e-10));
    assert!(res.beta[0] > 1.0, "separation should drive a large coefficient");
}

#[test]
fn huge_feature_scale_is_stable() {
    let d = ds(
        &[vec![1e6, -1e6, 5e5, -5e5]],
        vec![4.0, 3.0, 2.0, 1.0],
        vec![true; 4],
    );
    let pr = CoxProblem::new(&d);
    let res = CubicSurrogate
        .fit(
            &pr,
            &FitConfig { objective: Objective { l1: 0.0, l2: 1.0 }, ..Default::default() },
        )
        .unwrap();
    assert!(res.beta[0].is_finite());
    assert!(res.trace.monotone(1e-8));
}

#[test]
fn batched_derivs_on_empty_events_are_constant_term_only() {
    let d = ds(
        &[vec![1.0, 2.0], vec![0.5, -0.5]],
        vec![2.0, 1.0],
        vec![false, false],
    );
    let pr = CoxProblem::new(&d);
    let st = CoxState::zeros(&pr);
    let mut ws = Workspace::default();
    let (d1, d2) = all_coord_d1_d2(&pr, &st, &mut ws);
    assert!(d1.iter().all(|&v| v == 0.0));
    assert!(d2.iter().all(|&v| v == 0.0));
}

#[test]
fn beam_search_with_k_exceeding_p() {
    let d = ds(
        &[vec![1.0, -1.0, 0.5, -0.5, 0.7], vec![0.3, 0.1, -0.4, 0.9, -0.2]],
        vec![5.0, 4.0, 3.0, 2.0, 1.0],
        vec![true; 5],
    );
    let pr = CoxProblem::new(&d);
    let bs = BeamSearch { width: 2, screen: 4, ..Default::default() };
    let path = bs.run(&pr, 10); // k > p: clipped to p
    assert!(path.iter().all(|s| s.k <= 2));
}

#[test]
fn kaplan_meier_single_observation() {
    let km = KaplanMeier::fit(&[1.0], &[true]);
    assert_eq!(km.at(0.5), 1.0);
    assert_eq!(km.at(1.0), 0.0);
    let g = KaplanMeier::fit_censoring(&[1.0], &[true]);
    assert_eq!(g.at(2.0), 1.0, "no censoring events");
}

#[test]
fn cindex_degenerate_inputs() {
    // All censored → no comparable pairs → 0.5 by convention.
    assert_eq!(concordance_index(&[1.0, 2.0], &[false, false], &[1.0, 0.0]), 0.5);
    // Identical times → not comparable.
    assert_eq!(concordance_index(&[1.0, 1.0], &[true, true], &[1.0, 0.0]), 0.5);
}

#[test]
fn zero_iteration_budget() {
    let d = ds(&[vec![1.0, -1.0, 0.5]], vec![3.0, 2.0, 1.0], vec![true; 3]);
    let pr = CoxProblem::new(&d);
    let res = QuadraticSurrogate
        .fit(&pr, &FitConfig { max_iters: 0, ..Default::default() })
        .unwrap();
    assert!(res.beta.iter().all(|&b| b == 0.0));
    assert_eq!(res.iterations, 0);
}

#[test]
fn negative_and_zero_times_are_valid() {
    // Observation times only enter through their ordering.
    let d = ds(
        &[vec![1.0, -1.0, 0.5, -0.5]],
        vec![0.0, -1.0, 2.0, -3.0],
        vec![true, true, false, true],
    );
    let pr = CoxProblem::new(&d);
    assert_eq!(pr.time, vec![2.0, 0.0, -1.0, -3.0]);
    let res = CubicSurrogate.fit(&pr, &FitConfig::default()).unwrap();
    assert!(res.trace.monotone(1e-10));
}
