//! End-to-end sharded big-n training properties:
//!
//! 1. The sharded parallel fit is **bitwise identical** to the
//!    single-store fit across shard counts {1, 2, 4} × thread /
//!    shard-worker counts {1, 2, 4} — the merge-tile prefix carries
//!    make the distributed risk-set scan partition-invariant.
//! 2. Heavy ties quantized onto shard boundaries don't move a bit:
//!    the shard cutter keeps every tie group whole.
//! 3. A crash-interrupted shard rewrite (stray next-generation shard
//!    files, temp leftovers) leaves the previously published manifest
//!    view openable and its fit unchanged.
//! 4. Tampered manifests (overlapping time ranges) surface as typed
//!    `FastSurvivalError::Store`; `inspect` cross-checks every shard
//!    against the manifest and flags missing files.

use fastsurvival::coordinator::inspect::inspect_shards;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::error::FastSurvivalError;
use fastsurvival::optim::{Objective, SurrogateKind};
use fastsurvival::store::shard::shard_file_path;
use fastsurvival::store::{
    shard_manifest_path, write_sharded_store, write_store, ChunkedDataset, DatasetRows,
    ShardManifest, ShardedDataset, StreamingFit, StreamingFitResult,
};
use fastsurvival::util::compute::{Compute, Precision};
use std::path::{Path, PathBuf};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fs_shard_integration_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fitter(threads: usize) -> StreamingFit {
    StreamingFit {
        objective: Objective { l1: 0.0, l2: 1.0 },
        surrogate: SurrogateKind::Quadratic,
        max_sweeps: 4000,
        tol: 0.0,
        stop_kkt: 1e-8,
        compute: Compute::default().threads(threads),
        ..Default::default()
    }
}

fn assert_bitwise(a: &StreamingFitResult, b: &StreamingFitResult, tag: &str) {
    assert_eq!(a.sweeps, b.sweeps, "{tag}: sweep counts diverged");
    assert_eq!(
        a.objective_value.to_bits(),
        b.objective_value.to_bits(),
        "{tag}: objective diverged ({} vs {})",
        a.objective_value,
        b.objective_value
    );
    for (l, (x, y)) in a.beta.iter().zip(b.beta.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: beta[{l}] {x} vs {y}");
    }
    for (k, (x, y)) in a.eta.iter().zip(b.eta.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: eta[{k}] {x} vs {y}");
    }
}

/// Write both views of `ds`, fit the single store once, then demand the
/// sharded fit reproduce it bit for bit at every (shards × workers)
/// combination. Thread counts are pinned through `Compute` (never the
/// env — libtest runs tests concurrently).
fn check_parity(
    ds: &SurvivalDataset,
    dir: &Path,
    chunk_rows: usize,
    shard_counts: &[usize],
    worker_counts: &[usize],
) {
    let single_path = dir.join("single.fsds");
    let mut rows = DatasetRows::new(ds);
    write_store(&mut rows, &single_path, chunk_rows, "single").unwrap();
    let mut single = ChunkedDataset::open(&single_path).unwrap();
    let reference = fitter(1).fit(&mut single).unwrap();

    for &shards in shard_counts {
        let out = dir.join(format!("sharded{shards}.fsds"));
        let mut rows = DatasetRows::new(ds);
        let summary =
            write_sharded_store(&mut rows, &out, chunk_rows, "sharded", Precision::F64, shards)
                .unwrap();
        assert!(summary.n_shards >= 1 && summary.n_shards <= shards);
        for &workers in worker_counts {
            let mut sharded = ShardedDataset::open(&out).unwrap();
            let got = fitter(workers).fit_sharded(&mut sharded, workers).unwrap();
            assert_bitwise(&reference, &got, &format!("shards={shards} workers={workers}"));
        }
    }
}

#[test]
fn sharded_fit_is_bitwise_identical_across_shards_and_workers() {
    let dir = temp_dir("parity");
    let ds = generate(&SyntheticConfig { n: 900, p: 6, rho: 0.3, k: 3, s: 0.1, seed: 71 });
    check_parity(&ds, &dir, 128, &[1, 2, 4], &[1, 2, 4]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn heavy_ties_at_shard_boundaries_stay_bitwise() {
    let dir = temp_dir("ties");
    let mut ds =
        generate(&SyntheticConfig { n: 480, p: 5, rho: 0.2, k: 2, s: 0.1, seed: 83 });
    // Quantize times onto a coarse grid: long runs of exact ties that
    // the shard cutter must keep whole wherever the boundaries land.
    for t in ds.time.iter_mut() {
        *t = (*t * 3.0).ceil().max(1.0) / 3.0;
    }
    check_parity(&ds, &dir, 64, &[2, 4], &[1, 2]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn interrupted_rewrite_leaves_published_generation_readable() {
    let dir = temp_dir("crash");
    let ds = generate(&SyntheticConfig { n: 300, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 97 });
    let out = dir.join("crash.fsds");
    let mut rows = DatasetRows::new(&ds);
    write_sharded_store(&mut rows, &out, 64, "crash", Precision::F64, 3).unwrap();
    let before = {
        let mut sharded = ShardedDataset::open(&out).unwrap();
        fitter(1).fit_sharded(&mut sharded, 2).unwrap()
    };

    // A rewrite that died mid-flight: next-generation shard files (one
    // complete-looking, one partial temp) exist, but the manifest was
    // never republished. Readers must keep seeing the old generation.
    let generation = ShardManifest::load(&shard_manifest_path(&out)).unwrap().unwrap().generation;
    std::fs::write(shard_file_path(&out, generation + 1, 0), b"half-written junk").unwrap();
    std::fs::write(
        format!("{}.partial.tmp", shard_file_path(&out, generation + 1, 1).display()),
        b"junk",
    )
    .unwrap();

    let report = inspect_shards(&out).unwrap();
    assert!(report.healthy(), "published generation must stay healthy: {report:?}");
    let mut sharded = ShardedDataset::open(&out).unwrap();
    let after = fitter(1).fit_sharded(&mut sharded, 2).unwrap();
    assert_bitwise(&before, &after, "pre/post interrupted rewrite");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tampered_manifests_and_missing_shards_are_caught() {
    let dir = temp_dir("tamper");
    let ds = generate(&SyntheticConfig { n: 300, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 101 });
    let out = dir.join("tamper.fsds");
    let mut rows = DatasetRows::new(&ds);
    write_sharded_store(&mut rows, &out, 64, "tamper", Precision::F64, 3).unwrap();
    let mpath = shard_manifest_path(&out);
    let good = ShardManifest::load(&mpath).unwrap().unwrap();

    // Overlapping time ranges (shard 0 claims to reach past shard 1's
    // start) break the risk-set prefix structure: typed Store error at
    // open, before any fit can run.
    let mut bad = good.clone();
    bad.shards[0].t_last = bad.shards[1].t_first - 1e-9;
    bad.save(&mpath).unwrap();
    assert!(matches!(ShardedDataset::open(&out), Err(FastSurvivalError::Store(_))));
    assert!(matches!(inspect_shards(&out), Err(FastSurvivalError::Store(_))));

    // Restore, then delete a shard file: inspect names the hole and the
    // verdict goes unhealthy; the assembled open fails too.
    good.save(&mpath).unwrap();
    std::fs::remove_file(dir.join(&good.shards[1].file)).unwrap();
    let report = inspect_shards(&out).unwrap();
    assert!(!report.healthy());
    assert!(!report.shards[1].ok);
    assert!(!report.assembled_ok);
    assert!(matches!(
        ShardedDataset::open(&out),
        Err(FastSurvivalError::Store(_) | FastSurvivalError::Io { .. })
    ));
    let _ = std::fs::remove_dir_all(&dir);
}
