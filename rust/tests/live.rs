//! End-to-end online-learning loop properties:
//!
//! 1. Crash-interrupted appends (killed between segment write and
//!    manifest commit, leftover `.partial.tmp` workspace, stale
//!    manifest after a base rewrite) always leave a store that opens
//!    cleanly, and the next append sweeps the debris.
//! 2. Append → warm refit is bitwise identical across
//!    FASTSURVIVAL_THREADS ∈ {1, 2, 4} and matches a cold fit of the
//!    merged view to ≤1e-8 per coefficient (KKT certificate on both).
//! 3. A refit that fails holdout validation leaves the served model
//!    untouched — scored through the registry before and after, bitwise.
//! 4. `/healthz` names the served models and carries a registry
//!    generation counter that bumps on every successful reload.

use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::live::manifest::{manifest_path, segment_path, Manifest};
use fastsurvival::live::{append_rows, fingerprint, IncrementalRefit, LiveDataset, Watcher};
use fastsurvival::optim::{Objective, SurrogateKind};
use fastsurvival::serve::scorer::BatchConfig;
use fastsurvival::serve::{serve, HttpClient, ModelRegistry, ServeConfig};
use fastsurvival::store::{write_store, ChunkedDataset, CoxData, DatasetRows, StreamingFit};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fs_live_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn gen(n: usize, seed: u64) -> SurvivalDataset {
    generate(&SyntheticConfig { n, p: 5, rho: 0.3, k: 3, s: 0.1, seed })
}

fn seed_store(dir: &Path, n: usize, seed: u64) -> PathBuf {
    let base = dir.join("events.fsds");
    let ds = gen(n, seed);
    let mut rows = DatasetRows::new(&ds);
    write_store(&mut rows, &base, 48, "events").unwrap();
    base
}

#[test]
fn crash_interrupted_appends_leave_an_openable_store() {
    let dir = temp_dir("crash");
    let base = seed_store(&dir, 90, 1);
    let extra = gen(11, 2);
    let mut rows = DatasetRows::new(&extra);
    append_rows(&base, &mut rows, 0).unwrap();

    // Crash point 1: a segment fully written but never committed (kill
    // between segment write and manifest update). Readers must serve
    // exactly the committed view.
    let orphan = gen(7, 3);
    let mut rows = DatasetRows::new(&orphan);
    write_store(&mut rows, &segment_path(&base, 2), 48, "events.seg000002").unwrap();
    // Crash point 2: leftover writer workspace.
    let tmp = PathBuf::from(format!("{}.partial.tmp", base.display()));
    std::fs::write(&tmp, b"half-written junk").unwrap();

    let mut live = LiveDataset::open(&base).unwrap();
    assert_eq!(live.meta().n, 90 + 11, "orphan rows must not be served");
    let mut buf = Vec::new();
    let rows0 = live.load_chunk(0, &mut buf).unwrap();
    assert!(rows0 > 0, "the merged view must actually read");

    // The next append sweeps both leftovers and commits cleanly.
    let more = gen(5, 4);
    let mut rows = DatasetRows::new(&more);
    let s = append_rows(&base, &mut rows, 0).unwrap();
    assert_eq!(s.seq, 2, "the orphan's sequence number is reclaimed");
    assert_eq!(s.total_rows, 90 + 11 + 5);
    assert!(!tmp.exists(), ".partial.tmp must be cleaned");
    let m = Manifest::load_valid(&base).unwrap().unwrap();
    assert_eq!(m.segments.len(), 2);
    assert_eq!(m.segments[1].n, 5, "the commit holds the new rows, not the orphan's");

    // Crash point 3: compaction renamed a new base into place but died
    // before retiring the manifest — simulate by rewriting the base.
    let rebuilt = gen(40, 5);
    let mut rows = DatasetRows::new(&rebuilt);
    write_store(&mut rows, &base, 48, "events").unwrap();
    assert!(manifest_path(&base).exists(), "stale manifest still on disk");
    let live = LiveDataset::open(&base).unwrap();
    assert_eq!(live.meta().n, 40, "stale manifest ignored; base alone is served");
    let fp = fingerprint(&base).unwrap();
    assert!(fp.segments.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The thread-parity satellite. All FASTSURVIVAL_THREADS mutation for
/// this test binary lives in this one test (libtest runs tests
/// concurrently; results everywhere are thread-count independent by
/// design, but the env writes themselves must not race each other).
#[test]
fn append_then_warm_refit_parity_across_thread_counts() {
    let dir = temp_dir("parity");
    let base = seed_store(&dir, 240, 6);
    let obj = Objective { l1: 0.0, l2: 1.0 };

    // The "served" β: a cold KKT-certified fit of the base alone.
    let fitter = StreamingFit {
        objective: obj,
        surrogate: SurrogateKind::Quadratic,
        max_sweeps: 10_000,
        tol: 0.0,
        stop_kkt: 1e-9,
        ..Default::default()
    };
    let mut base_store = ChunkedDataset::open(&base).unwrap();
    let served = fitter.fit(&mut base_store).unwrap();

    // ~5% append.
    let extra = gen(13, 7);
    let mut rows = DatasetRows::new(&extra);
    append_rows(&base, &mut rows, 0).unwrap();

    let refit = IncrementalRefit { objective: obj, stop_kkt: 1e-9, ..Default::default() };
    let saved = std::env::var("FASTSURVIVAL_THREADS").ok();
    let mut snapshots: Vec<Vec<f64>> = Vec::new();
    let mut warm_sweeps = 0usize;
    for threads in ["1", "2", "4"] {
        std::env::set_var("FASTSURVIVAL_THREADS", threads);
        let mut live = LiveDataset::open(&base).unwrap();
        let warm = refit.refit(&mut live, &served.beta).unwrap();
        assert!(warm.trace.converged, "threads={threads}: warm refit must KKT-converge");
        warm_sweeps = warm.sweeps;
        snapshots.push(warm.beta);
    }
    match saved {
        Some(v) => std::env::set_var("FASTSURVIVAL_THREADS", v),
        None => std::env::remove_var("FASTSURVIVAL_THREADS"),
    }
    for snap in &snapshots[1..] {
        for (a, b) in snapshots[0].iter().zip(snap.iter()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "warm refit changed with FASTSURVIVAL_THREADS"
            );
        }
    }

    // Warm vs cold on the same merged view: ≤1e-8 per coefficient (both
    // certified to KKT residual 1e-9 of the same strongly-convex
    // objective) and no more exact-phase work than the cold run.
    let mut live = LiveDataset::open(&base).unwrap();
    let cold = fitter.fit(&mut live).unwrap();
    assert!(cold.trace.converged);
    for (a, b) in snapshots[0].iter().zip(cold.beta.iter()) {
        assert!(
            (a - b).abs() <= 1e-8,
            "warm {a} vs cold {b}: outside the KKT parity certificate"
        );
    }
    assert!(
        warm_sweeps <= cold.sweeps,
        "warm refit must not sweep more than a cold fit ({warm_sweeps} vs {})",
        cold.sweeps
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rejected_publish_leaves_the_served_model_untouched() {
    let dir = temp_dir("reject");
    let base = seed_store(&dir, 260, 8);
    let artifacts = dir.join("models");
    let watcher = Watcher::new(&base, &artifacts, "events");

    // Cycle 1: no incumbent → v1 publishes.
    let first = watcher.run_cycle().unwrap();
    assert_eq!(first.published, Some(1), "{}", first.reason);

    // Score a probe row through the registry, exactly as the server
    // would.
    let registry = ModelRegistry::open(&artifacts).unwrap();
    let model_before = registry.resolve("events@1").unwrap();
    let probe: Vec<f64> = (0..model_before.p()).map(|j| 0.1 * (j as f64 + 1.0)).collect();
    let eta_before = model_before.eta_row(&probe);
    let bytes_before = std::fs::read(artifacts.join("events@1.json")).unwrap();

    // Cycle 2 on unchanged data: the deterministic refit ties the
    // incumbent on both holdout metrics → the gate must reject.
    let second = watcher.run_cycle().unwrap();
    assert_eq!(second.published, None, "{}", second.reason);

    // The served model is untouched: same artifact bytes, same version
    // list after a reload, bitwise-identical scores.
    registry.reload().unwrap();
    let state = registry.snapshot();
    assert_eq!(state.latest_version("events"), Some(1));
    let model_after = registry.resolve("events@1").unwrap();
    assert_eq!(
        model_after.eta_row(&probe).to_bits(),
        eta_before.to_bits(),
        "a rejected publish must not change served scores"
    );
    assert_eq!(
        std::fs::read(artifacts.join("events@1.json")).unwrap(),
        bytes_before,
        "a rejected publish must leave the artifact byte-identical"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn healthz_names_models_and_generation_bumps_on_reload() {
    let dir = temp_dir("healthz");
    let base = seed_store(&dir, 220, 9);
    let artifacts = dir.join("models");
    let watcher = Watcher::new(&base, &artifacts, "events");
    watcher.run_cycle().unwrap();

    let registry = Arc::new(ModelRegistry::open(&artifacts).unwrap());
    assert_eq!(registry.generation(), 1);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_body_bytes: 1 << 20,
        batch: BatchConfig::default(),
    };
    let handle = serve(Arc::clone(&registry), &cfg).unwrap();
    let addr = handle.local_addr();
    let mut client = HttpClient::connect(addr).unwrap();

    let healthz = client.get("/healthz").unwrap();
    assert_eq!(healthz.status, 200);
    assert!(healthz.body.contains("\"events\""), "healthz must name the model: {}", healthz.body);
    assert!(healthz.body.contains("\"version\": 1") || healthz.body.contains("\"version\":1"));
    assert!(healthz.body.contains("\"generation\": 1") || healthz.body.contains("\"generation\":1"));

    // Grow the store so the next cycle publishes v2, then hot-reload.
    let extra = gen(30, 10);
    let mut rows = DatasetRows::new(&extra);
    append_rows(&base, &mut rows, 0).unwrap();
    let report = watcher.run_cycle().unwrap();
    let reload = client.post("/v1/reload", "{}").unwrap();
    assert_eq!(reload.status, 200);
    let healthz2 = client.get("/healthz").unwrap();
    assert!(
        healthz2.body.contains("\"generation\": 2") || healthz2.body.contains("\"generation\":2"),
        "generation must bump on reload (published={:?}): {}",
        report.published,
        healthz2.body
    );
    // /metrics carries the drift block the watcher's sidecars feed.
    let metrics = client.get("/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    assert!(metrics.body.contains("\"drift\""), "{}", metrics.body);

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
