//! Telemetry contract, end to end: enabling span tracing and engine
//! counters must never perturb the numerics. A traced fit lands on
//! bitwise-identical coefficients to an untraced fit at every worker
//! count, the traced model carries a populated `FitReport`, and the
//! untraced model carries none. The same holds for the λ-path solver,
//! whose traced run additionally records screening phases and workspace
//! cache traffic.
//!
//! The obs sink is process-global, so everything lives in one `#[test]`
//! — libtest would otherwise interleave enable/disable flips across
//! test threads inside this binary.

use fastsurvival::api::CoxFit;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::obs;
use fastsurvival::util::compute::Compute;

#[test]
fn tracing_never_perturbs_the_fit_and_reports_ride_the_artifacts() {
    let ds = generate(&SyntheticConfig { n: 400, p: 16, rho: 0.4, k: 4, s: 0.1, seed: 901 });

    // --- Single fit: bitwise parity at every worker count. ------------
    for threads in [1usize, 2, 4] {
        let fit = || {
            CoxFit::new()
                .l1(0.1)
                .l2(0.5)
                .compute(Compute::default().threads(threads))
                .fit(&ds)
                .unwrap()
        };

        // Untraced reference: telemetry disabled (the default).
        assert!(!obs::enabled(), "telemetry must start disabled");
        let plain = fit();
        assert!(
            plain.diagnostics().report.is_none(),
            "threads={threads}: untraced fit must not attach a report"
        );

        // Traced run of the exact same problem and config.
        obs::set_enabled(true);
        obs::reset();
        let traced = fit();
        obs::set_enabled(false);
        obs::reset();

        assert_eq!(plain.beta().len(), traced.beta().len());
        for (j, (a, b)) in plain.beta().iter().zip(traced.beta()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "threads={threads}, coord {j}: tracing changed β ({a} vs {b})"
            );
        }

        let report = traced
            .diagnostics()
            .report
            .as_ref()
            .unwrap_or_else(|| panic!("threads={threads}: traced fit must attach a report"));
        assert!(!report.is_empty(), "threads={threads}: report must not be empty");
        let sweep = report
            .phases
            .iter()
            .find(|p| p.phase == "cd_sweep")
            .unwrap_or_else(|| panic!("threads={threads}: cd_sweep phase missing"));
        assert!(sweep.count > 0, "threads={threads}: cd_sweep never fired");
        assert!(
            sweep.self_ns <= sweep.total_ns,
            "threads={threads}: cd_sweep self-time exceeds its total"
        );
    }

    // --- λ-path: same contract through the screening solver. ----------
    let builder = CoxFit::new().n_lambdas(8);
    let plain_path = builder.clone().l1_path(&ds).unwrap();
    assert!(plain_path.report().is_none(), "untraced path must not attach a report");

    obs::set_enabled(true);
    obs::reset();
    let traced_path = builder.clone().l1_path(&ds).unwrap();
    obs::set_enabled(false);
    obs::reset();

    assert_eq!(plain_path.len(), traced_path.len());
    for (a, b) in plain_path.points().iter().zip(traced_path.points().iter()) {
        for (x, y) in a.beta.iter().zip(b.beta.iter()) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "λ={:?}: tracing changed the path solution",
                a.lambda
            );
        }
    }

    let report = traced_path.report().expect("traced path must attach a report");
    assert!(
        report.phases.iter().any(|p| p.phase == "path_screen" && p.count > 0),
        "screening phase missing from the path report"
    );
    assert!(
        report.phases.iter().any(|p| p.phase == "cd_sweep" && p.count > 0),
        "inner CD sweeps missing from the path report"
    );
    let c = &report.counters;
    assert!(
        c.workspace_hits + c.workspace_misses > 0,
        "workspace cache traffic must be counted along the path"
    );
}
