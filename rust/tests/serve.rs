//! Black-box tests for the model-serving subsystem: registry hot-reload
//! atomicity under concurrent scoring, HTTP request-framing edge cases
//! (pipelining, oversized bodies, malformed JSON), bitwise parity
//! between HTTP-scored and in-process-scored results under a concurrent
//! burst with mid-burst reloads, request-level observability (request
//! IDs, `/debug/trace`, the JSONL access log), and offline CSV
//! round-trip parity.
//!
//! Tests that need request-obs recording turn the process-wide obs flag
//! on and deliberately never turn it off — tests run concurrently, and
//! a disable would race another test's recording window. The flag being
//! on is harmless to the non-obs tests.

use fastsurvival::api::json;
use fastsurvival::api::{CoxFit, CoxModel};
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::linalg::Matrix;
use fastsurvival::obs::parse_request_records;
use fastsurvival::serve::http::{serve, HttpClient, ServeConfig};
use fastsurvival::serve::registry::ModelRegistry;
use fastsurvival::serve::scorer::{score_csv, BatchConfig, CompiledModel};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn dataset(seed: u64) -> SurvivalDataset {
    generate(&SyntheticConfig { n: 180, p: 9, rho: 0.5, k: 3, s: 0.1, seed })
}

fn train(ds: &SurvivalDataset, l2: f64) -> CoxModel {
    CoxFit::new().l2(l2).max_iters(80).tol(1e-9).fit(ds).unwrap()
}

fn unique_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fs_serve_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn row_major(x: &Matrix, rows: &[usize]) -> Vec<f64> {
    let mut out = Vec::with_capacity(rows.len() * x.cols);
    for &r in rows {
        for c in 0..x.cols {
            out.push(x.get(r, c));
        }
    }
    out
}

fn rows_json(x: &Matrix, rows: &[usize]) -> String {
    let mut out = String::from("[");
    for (i, &r) in rows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let row: Vec<f64> = (0..x.cols).map(|c| x.get(r, c)).collect();
        json::write_f64_array(&mut out, &row);
    }
    out.push(']');
    out
}

// ------------------------------------------------------------- registry

#[test]
fn hot_reload_is_atomic_under_concurrent_scoring() {
    let ds = dataset(21);
    let m1 = train(&ds, 0.5);
    let m2 = train(&ds, 5.0);
    let dir = unique_dir("atomic");
    let sub = dir.join("m");
    std::fs::create_dir_all(&sub).unwrap();
    m1.save(&sub.join("1.json")).unwrap();
    let registry = Arc::new(ModelRegistry::open(&dir).unwrap());

    let probe = row_major(&ds.x, &[0]);
    let e1 = m1.predict_risk(&ds.x).unwrap()[0];
    let e2 = m2.predict_risk(&ds.x).unwrap()[0];
    assert_ne!(e1.to_bits(), e2.to_bits(), "the two versions must differ");

    std::thread::scope(|scope| {
        // Scorers hammer the latest version while the main thread flips
        // v2 in and out with atomic renames + reloads. Every observed
        // score must be exactly one of the two valid models' outputs —
        // never a torn or partially-loaded state.
        for _ in 0..4 {
            let registry = &registry;
            let probe = &probe;
            scope.spawn(move || {
                for _ in 0..400 {
                    let model = registry.resolve("m").unwrap();
                    let out = model.score_rows(probe, 1, None).unwrap();
                    let bits = out.risk[0].to_bits();
                    assert!(
                        bits == e1.to_bits() || bits == e2.to_bits(),
                        "scored value must come from a fully-loaded model"
                    );
                }
            });
        }
        let v2 = sub.join("2.json");
        let tmp = dir.join("staging.tmp");
        for round in 0..30 {
            if round % 2 == 0 {
                // Atomic publish: write outside the scanned namespace
                // (no .json extension), then rename into place.
                std::fs::write(&tmp, m2.to_json()).unwrap();
                std::fs::rename(&tmp, &v2).unwrap();
            } else {
                std::fs::remove_file(&v2).unwrap();
            }
            registry.reload().unwrap();
        }
    });

    // Final state: v2 present and latest.
    std::fs::write(sub.join("2.json"), m2.to_json()).unwrap();
    registry.reload().unwrap();
    assert_eq!(registry.resolve("m").unwrap().version(), 2);
    assert_eq!(registry.resolve("m@1").unwrap().version(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- http framing

struct TestServer {
    handle: fastsurvival::serve::http::ServerHandle,
    dir: PathBuf,
    ds: SurvivalDataset,
    model: CoxModel,
}

fn start_server_cfg(
    tag: &str,
    cfg_fn: impl FnOnce(&std::path::Path, &mut ServeConfig),
) -> TestServer {
    let ds = dataset(33);
    let model = train(&ds, 1.0);
    let dir = unique_dir(tag);
    model.save(&dir.join("m@1.json")).unwrap();
    let registry = Arc::new(ModelRegistry::open(&dir).unwrap());
    let mut cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 4,
        max_body_bytes: 8 << 20,
        batch: BatchConfig::default(),
        ..ServeConfig::default()
    };
    cfg_fn(&dir, &mut cfg);
    let handle = serve(registry, &cfg).unwrap();
    TestServer { handle, dir, ds, model }
}

fn start_server(tag: &str, max_body: usize, workers: usize) -> TestServer {
    start_server_cfg(tag, |_, cfg| {
        cfg.max_body_bytes = max_body;
        cfg.workers = workers;
    })
}

#[test]
fn http_framing_edge_cases() {
    // Enough workers that every connection this test holds open gets
    // its own, so nothing serializes behind the keep-alive idle window.
    let server = start_server("framing", 4096, 8);
    let addr = server.handle.local_addr();

    // Pipelined requests: two GETs written in one burst, two framed
    // responses read back in order.
    let mut client = HttpClient::connect(addr).unwrap();
    client
        .send_raw(b"GET /healthz HTTP/1.1\r\n\r\nGET /v1/models HTTP/1.1\r\n\r\n")
        .unwrap();
    let r1 = client.read_response().unwrap();
    let r2 = client.read_response().unwrap();
    assert_eq!(r1.status, 200);
    assert_eq!(r2.status, 200);
    assert!(r1.body.contains("\"status\""));
    assert!(r2.body.contains("\"models\""));

    // A request with a body, pipelined with a follow-up: leftover bytes
    // after the body must frame the next request correctly.
    let score = format!(
        "{{\"model\": \"m@1\", \"rows\": {}}}",
        rows_json(&server.ds.x, &[0, 1])
    );
    let pipelined = format!(
        "POST /v1/score HTTP/1.1\r\nContent-Length: {}\r\n\r\n{score}GET /healthz HTTP/1.1\r\n\r\n",
        score.len()
    );
    client.send_raw(pipelined.as_bytes()).unwrap();
    let r3 = client.read_response().unwrap();
    let r4 = client.read_response().unwrap();
    assert_eq!(r3.status, 200);
    assert!(r3.body.contains("\"risk\""));
    assert_eq!(r4.status, 200);

    // Oversized body → 413 before the body is read, connection closed.
    let mut big = HttpClient::connect(addr).unwrap();
    big.send_raw(b"POST /v1/score HTTP/1.1\r\nContent-Length: 100000\r\n\r\n")
        .unwrap();
    let r = big.read_response().unwrap();
    assert_eq!(r.status, 413);

    // Malformed JSON → 400.
    let mut bad = HttpClient::connect(addr).unwrap();
    let r = bad.post("/v1/score", "this is not json").unwrap();
    assert_eq!(r.status, 400);

    // Wrong row width → 400 with a diagnostic.
    let mut narrow = HttpClient::connect(addr).unwrap();
    let r = narrow
        .post("/v1/score", "{\"model\": \"m@1\", \"rows\": [[1.0, 2.0]]}")
        .unwrap();
    assert_eq!(r.status, 400);
    assert!(r.body.contains("expects"));

    // Unknown model → 404; unknown path → 404; wrong method → 405;
    // missing rows → 400; chunked encoding → 400.
    let mut misc = HttpClient::connect(addr).unwrap();
    assert_eq!(misc.post("/v1/score", "{\"model\": \"nope\", \"rows\": []}").unwrap().status, 404);
    // Syntactically bad spec → 400 (client error), not 404.
    assert_eq!(misc.post("/v1/score", "{\"model\": \"m@x\", \"rows\": []}").unwrap().status, 400);
    // Non-finite row values (overflowing literal → inf) → 400, keeping
    // the response's risk array numeric.
    assert_eq!(misc.post("/v1/score", "{\"model\": \"m@1\", \"rows\": [[1e999]]}").unwrap().status, 400);
    assert_eq!(misc.get("/v1/nothing").unwrap().status, 404);
    assert_eq!(misc.post("/healthz", "{}").unwrap().status, 405);
    assert_eq!(misc.post("/v1/score", "{\"model\": \"m@1\"}").unwrap().status, 400);
    let mut chunked = HttpClient::connect(addr).unwrap();
    chunked
        .send_raw(b"POST /v1/score HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n")
        .unwrap();
    assert_eq!(chunked.read_response().unwrap().status, 400);

    let _ = std::fs::remove_dir_all(&server.dir);
}

// ------------------------------------- burst + mid-burst reload parity

#[test]
fn concurrent_burst_with_midburst_reload_keeps_bitwise_parity() {
    let server = start_server("burst", 8 << 20, 6);
    let addr = server.handle.local_addr();
    let rows: Vec<usize> = (0..16).collect();
    let body = format!(
        "{{\"model\": \"m@1\", \"horizons\": [0.5, 2.0], \"rows\": {}}}",
        rows_json(&server.ds.x, &rows)
    );
    let sub = server.ds.x.select_rows(&rows);
    let expect_risk = server.model.predict_risk(&sub).unwrap();
    let expect_curves = server.model.predict_survival_curve(&sub, &[0.5, 2.0]).unwrap();

    std::thread::scope(|scope| {
        for _ in 0..4 {
            let body = &body;
            let expect_risk = &expect_risk;
            let expect_curves = &expect_curves;
            scope.spawn(move || {
                let mut client = HttpClient::connect(addr).unwrap();
                for _ in 0..20 {
                    let resp = client.post("/v1/score", body).unwrap();
                    assert_eq!(resp.status, 200, "body: {}", resp.body);
                    let doc = json::parse(&resp.body).unwrap();
                    let risk = doc.require("risk").unwrap().as_f64_vec().unwrap();
                    assert_eq!(risk.len(), 16);
                    for (a, b) in risk.iter().zip(expect_risk) {
                        assert_eq!(a.to_bits(), b.to_bits(), "HTTP risk must be bitwise");
                    }
                    let survival = doc.require("survival").unwrap();
                    let curves = survival.as_array().unwrap();
                    for (i, curve) in curves.iter().enumerate() {
                        let vals = curve.as_f64_vec().unwrap();
                        for (j, v) in vals.iter().enumerate() {
                            assert_eq!(v.to_bits(), expect_curves[i][j].to_bits());
                        }
                    }
                }
            });
        }
        // Mid-burst hot reloads: same artifact directory, so parity
        // must hold across the swap and no in-flight request may drop.
        scope.spawn(move || {
            let mut client = HttpClient::connect(addr).unwrap();
            for _ in 0..5 {
                std::thread::sleep(Duration::from_millis(10));
                let resp = client.post("/v1/reload", "{}").unwrap();
                assert_eq!(resp.status, 200, "body: {}", resp.body);
            }
        });
    });

    // The metrics endpoint saw all of it.
    let mut client = HttpClient::connect(addr).unwrap();
    let metrics = client.get("/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    let doc = json::parse(&metrics.body).unwrap();
    let endpoints = doc.require("endpoints").unwrap();
    let score = endpoints.require("score").unwrap();
    assert_eq!(score.require("requests").unwrap().as_usize().unwrap(), 80);
    assert_eq!(score.require("errors").unwrap().as_usize().unwrap(), 0);
    assert_eq!(score.require("rows").unwrap().as_usize().unwrap(), 80 * 16);
    let reload = endpoints.require("reload").unwrap();
    assert_eq!(reload.require("requests").unwrap().as_usize().unwrap(), 5);
    drop(client); // close the last connection so shutdown joins immediately

    // Graceful shutdown completes (joins every thread) without hanging.
    let dir = server.dir.clone();
    server.handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------- metrics expositions

/// Value of a Prometheus sample line `name{labels} value` (or
/// `name value`) in an exposition body.
fn prom_value(body: &str, line_prefix: &str) -> f64 {
    body.lines()
        .find(|l| l.starts_with(line_prefix))
        .unwrap_or_else(|| panic!("no sample starting with {line_prefix:?} in:\n{body}"))
        .rsplit(' ')
        .next()
        .unwrap()
        .parse()
        .unwrap()
}

#[test]
fn metrics_json_and_prometheus_render_the_same_snapshot() {
    let server = start_server("prom", 8 << 20, 4);
    let addr = server.handle.local_addr();

    // Put known traffic on the score endpoint first.
    let mut client = HttpClient::connect(addr).unwrap();
    let body = format!("{{\"model\": \"m@1\", \"rows\": {}}}", rows_json(&server.ds.x, &[0, 1, 2]));
    for _ in 0..3 {
        assert_eq!(client.post("/v1/score", &body).unwrap().status, 200);
    }

    // JSON first, then Prometheus: the score counters sit still between
    // the two reads (only the metrics endpoint's own counter moves).
    let json_resp = client.get("/metrics").unwrap();
    assert_eq!(json_resp.status, 200);
    let doc = json::parse(&json_resp.body).unwrap();
    let score = doc.require("endpoints").unwrap().require("score").unwrap();
    let requests = score.require("requests").unwrap().as_usize().unwrap();
    let rows = score.require("rows").unwrap().as_usize().unwrap();
    assert_eq!(requests, 3);
    assert_eq!(rows, 9);
    let training = doc.require("training").unwrap();
    let publishes = training.require("publishes").unwrap().as_usize().unwrap();
    let rejects = training.require("rejects").unwrap().as_usize().unwrap();

    let prom_resp = client.get("/metrics?format=prometheus").unwrap();
    assert_eq!(prom_resp.status, 200);
    let prom = &prom_resp.body;
    assert!(prom.starts_with("# TYPE fastsurvival_uptime_seconds gauge"), "{prom}");
    assert_eq!(
        prom_value(prom, "fastsurvival_requests_total{endpoint=\"score\"}") as usize,
        requests,
        "prometheus and JSON disagree on score requests"
    );
    assert_eq!(
        prom_value(prom, "fastsurvival_rows_total{endpoint=\"score\"}") as usize,
        rows,
        "prometheus and JSON disagree on score rows"
    );
    assert_eq!(
        prom_value(prom, "fastsurvival_rows_scored_total ") as usize,
        rows,
        "prometheus and JSON disagree on total rows scored"
    );
    assert_eq!(
        prom_value(prom, "fastsurvival_errors_total{endpoint=\"score\"}") as usize,
        0
    );
    // Training gauges render in both expositions from the same
    // process-global snapshot.
    assert_eq!(prom_value(prom, "fastsurvival_publishes_total ") as usize, publishes);
    assert_eq!(prom_value(prom, "fastsurvival_rejects_total ") as usize, rejects);
    // The latency histogram's +Inf cumulative count equals the
    // endpoint's request count, as the exposition format requires.
    assert_eq!(
        prom_value(prom, "fastsurvival_request_latency_us_bucket{endpoint=\"score\",le=\"+Inf\"}")
            as usize,
        requests
    );

    // An unknown format is a client error, not a silent JSON fallback.
    assert_eq!(client.get("/metrics?format=xml").unwrap().status, 400);

    drop(client);
    let dir = server.dir.clone();
    server.handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------- request observability

#[test]
fn request_ids_round_trip_and_debug_trace_exposes_lifecycle() {
    fastsurvival::obs::set_enabled(true);
    let server = start_server_cfg("trace", |_, cfg| {
        cfg.recorder_capacity = 64;
    });
    let addr = server.handle.local_addr();
    let mut client = HttpClient::connect(addr).unwrap();
    let body = format!(
        "{{\"model\": \"m@1\", \"rows\": {}}}",
        rows_json(&server.ds.x, &[0, 1])
    );

    // A caller-supplied x-request-id echoes back on the response.
    let resp = client
        .request_with("POST", "/v1/score", Some(&body), &[("x-request-id", "it-trace-1")])
        .unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.request_id.as_deref(), Some("it-trace-1"));

    // Without the header the server mints an id of its own.
    let resp2 = client.post("/v1/score", &body).unwrap();
    assert_eq!(resp2.status, 200);
    let minted = resp2.request_id.expect("server-minted request id");
    assert!(minted.starts_with("fs-"), "unexpected id shape: {minted}");

    // The flight recorder committed both records before the same
    // connection's next request is read, so the dump is deterministic.
    let trace = client.get("/debug/trace?n=50").unwrap();
    assert_eq!(trace.status, 200);
    let doc = json::parse(&trace.body).unwrap();
    assert!(doc.require("capacity").unwrap().as_usize().unwrap() >= 64);
    assert!(doc.require("recorded").unwrap().as_usize().unwrap() >= 2);
    doc.require("slow_threshold_us").unwrap();
    doc.require("slow").unwrap();
    let records = parse_request_records(&trace.body).unwrap();
    let rec = records
        .iter()
        .find(|r| r.id == "it-trace-1")
        .expect("tagged request in flight-recorder dump");
    assert_eq!(rec.endpoint, "score");
    assert_eq!(rec.status, 200);
    assert_eq!(rec.rows, 2);
    assert!(rec.total_us > 0);
    // The six-stage breakdown accounts for the measured total: stage
    // boundaries are adjacent clock reads, so only µs-level glue between
    // them may go missing.
    let sum = rec.stage_sum_us();
    let tol = (rec.total_us / 20).max(25);
    assert!(
        sum.abs_diff(rec.total_us) <= tol,
        "stage sum {sum} vs total {} (tol {tol})",
        rec.total_us
    );
    assert!(records.iter().any(|r| r.id == minted));

    // Sliced metrics picked the traffic up under the score endpoint.
    let metrics = client.get("/metrics").unwrap();
    let mdoc = json::parse(&metrics.body).unwrap();
    let slices = mdoc.require("slices").unwrap().as_array().unwrap();
    assert!(
        slices
            .iter()
            .any(|s| s.get("endpoint").and_then(|e| e.as_str().ok()) == Some("score")),
        "no score slice in {}",
        metrics.body
    );

    // A malformed count is a client error, not a default.
    assert_eq!(client.get("/debug/trace?n=abc").unwrap().status, 400);

    drop(client);
    let dir = server.dir.clone();
    server.handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn access_log_writes_one_valid_line_per_request() {
    fastsurvival::obs::set_enabled(true);
    let server = start_server_cfg("alog", |dir, cfg| {
        cfg.access_log = Some(dir.join("access.jsonl").to_string_lossy().into_owned());
    });
    let addr = server.handle.local_addr();
    let mut client = HttpClient::connect(addr).unwrap();
    let body = format!(
        "{{\"model\": \"m@1\", \"rows\": {}}}",
        rows_json(&server.ds.x, &[0, 1, 2])
    );
    let mut ids = Vec::new();
    for i in 0..5 {
        let resp = client
            .request_with(
                "POST",
                "/v1/score",
                Some(&body),
                &[("x-request-id", &format!("it-alog-{i}"))],
            )
            .unwrap();
        assert_eq!(resp.status, 200);
        ids.push(resp.request_id.expect("echoed id"));
    }
    assert_eq!(client.get("/healthz").unwrap().status, 200);

    // shutdown joins every worker, and each worker appends its log line
    // before looping for the next request, so the file is complete here.
    drop(client);
    let dir = server.dir.clone();
    server.handle.shutdown();

    let text = std::fs::read_to_string(dir.join("access.jsonl")).unwrap();
    let records = parse_request_records(&text).unwrap();
    assert_eq!(records.len(), 6, "one line per request:\n{text}");
    let score: Vec<_> = records.iter().filter(|r| r.endpoint == "score").collect();
    assert_eq!(score.len(), 5);
    for (i, rec) in score.iter().enumerate() {
        assert_eq!(rec.id, ids[i], "ids round-trip in request order");
        assert_eq!(rec.status, 200);
        assert_eq!(rec.rows, 3);
        let tol = (rec.total_us / 20).max(25);
        assert!(rec.stage_sum_us().abs_diff(rec.total_us) <= tol);
    }
    assert!(records.iter().any(|r| r.endpoint == "healthz"));
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------- CSV round trip

#[test]
fn score_csv_round_trips_with_in_process_parity() {
    let ds = dataset(55);
    let model = CoxFit::new().l1(0.15).l2(0.05).max_iters(200).tol(1e-10).fit(&ds).unwrap();
    let compiled = CompiledModel::compile(&model, "m", 1);

    // Positional layout: time/event named, feature names unknown to the
    // model, so mapping falls back to column order.
    let mut csv = String::from("time,event");
    for j in 0..ds.p() {
        csv.push_str(&format!(",col{j}"));
    }
    csv.push('\n');
    for i in 0..ds.n() {
        csv.push_str(&format!("{},{}", ds.time[i], u8::from(ds.event[i])));
        for c in 0..ds.p() {
            csv.push_str(&format!(",{}", ds.x.get(i, c)));
        }
        csv.push('\n');
    }
    let horizons = [0.25, 1.0, 3.0];
    let mut out: Vec<u8> = Vec::new();
    let summary =
        score_csv(&compiled, &mut csv.as_bytes(), &mut out, &horizons, 32).unwrap();
    assert_eq!(summary.rows, ds.n());
    assert!(summary.chunks > 1, "must stream in multiple chunks");

    let expect_risk = model.predict_risk(&ds.x).unwrap();
    let expect_curves = model.predict_survival_curve(&ds.x, &horizons).unwrap();
    let text = String::from_utf8(out).unwrap();
    let mut lines = text.lines();
    let header = lines.next().unwrap();
    assert_eq!(header, "risk,surv@0.25,surv@1,surv@3");
    for i in 0..ds.n() {
        let cells: Vec<f64> = lines
            .next()
            .unwrap()
            .split(',')
            .map(|c| c.parse().unwrap())
            .collect();
        assert!(
            (cells[0] - expect_risk[i]).abs() <= 1e-12,
            "row {i}: {} vs {}",
            cells[0],
            expect_risk[i]
        );
        for j in 0..horizons.len() {
            assert!((cells[1 + j] - expect_curves[i][j]).abs() <= 1e-12);
        }
    }
    assert!(lines.next().is_none());
}
