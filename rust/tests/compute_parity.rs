//! Compute backend contract, end to end: scalar and SIMD kernels land on
//! the same fit across thread counts, f32 storage stays within 1e-6 of
//! the f64 pipeline (in-memory fit, λ-path, chunked store fit), and the
//! `.fsds` v2 encoding round-trips while v1 stores keep reading.

use fastsurvival::api::CoxFit;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::error::FastSurvivalError;
use fastsurvival::optim::{Objective, SurrogateKind};
use fastsurvival::store::{
    write_store, write_store_with, ChunkedDataset, CoxData, DatasetRows, MemoryCoxData,
    StreamingFit,
};
use fastsurvival::util::compute::{Backend, Compute, Precision};
use std::path::PathBuf;

fn max_abs_gap(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn quantized(ds: &SurvivalDataset) -> SurvivalDataset {
    let mut q = ds.clone();
    q.x.quantize_f32();
    q
}

fn temp_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fs_compute_parity_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.fsds"))
}

/// A KKT-stopped streaming fitter: the certificate pins both runs within
/// ~3e-9 of the unique λ₂=1 optimum, so cross-run gaps measure the
/// pipeline, not the stopping rule.
fn kkt_fitter(compute: Compute) -> StreamingFit {
    StreamingFit {
        objective: Objective { l1: 0.0, l2: 1.0 },
        surrogate: SurrogateKind::Quadratic,
        max_sweeps: 10_000,
        tol: 0.0,
        stop_kkt: 1e-9,
        compute,
        ..Default::default()
    }
}

/// Tentpole parity property: the scalar reference and the SIMD lane
/// kernels drive the full in-memory fit to the same coefficients at
/// every worker count, and each backend is bitwise deterministic across
/// worker counts (threads split work by column, never inside a
/// reduction). Thread counts are pinned through `Compute`, not the env,
/// so this runs race-free under libtest's concurrency.
#[test]
fn scalar_and_simd_fits_agree_across_thread_counts() {
    let ds = generate(&SyntheticConfig { n: 300, p: 12, rho: 0.4, k: 3, s: 0.1, seed: 301 });
    let mut per_backend: Vec<Vec<Vec<f64>>> = vec![Vec::new(), Vec::new()];
    for threads in [1usize, 2, 4] {
        let mut betas = Vec::new();
        for (slot, backend) in [Backend::Scalar, Backend::Simd].into_iter().enumerate() {
            let model = CoxFit::new()
                .l2(0.5)
                .compute(Compute::default().backend(backend).threads(threads))
                .fit(&ds)
                .unwrap();
            per_backend[slot].push(model.beta().to_vec());
            betas.push(model.beta().to_vec());
        }
        let gap = max_abs_gap(&betas[0], &betas[1]);
        assert!(gap <= 1e-8, "threads={threads}: scalar vs simd max|Δβ| = {gap:.3e}");
    }
    for snapshots in &per_backend {
        for later in &snapshots[1..] {
            for (a, b) in snapshots[0].iter().zip(later) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "fit β not bitwise identical across thread counts"
                );
            }
        }
    }
}

/// f32 storage keeps the in-memory fit within 1e-6 of f64, and an
/// explicit zero thread count is rejected as a typed config error when
/// the request is resolved — never a silent fallback.
#[test]
fn f32_storage_fit_within_1e6_and_bad_compute_is_typed() {
    let ds = generate(&SyntheticConfig { n: 250, p: 10, rho: 0.3, k: 3, s: 0.1, seed: 302 });
    let f64_fit = CoxFit::new().l2(0.5).fit(&ds).unwrap();
    let f32_fit = CoxFit::new()
        .l2(0.5)
        .compute(Compute::default().precision(Precision::F32Storage))
        .fit(&ds)
        .unwrap();
    let gap = max_abs_gap(f64_fit.beta(), f32_fit.beta());
    assert!(gap <= 1e-6, "f32 storage max|Δβ| = {gap:.3e}");

    let err = CoxFit::new().compute(Compute::default().threads(0)).fit(&ds).unwrap_err();
    assert!(matches!(err, FastSurvivalError::InvalidConfig(_)), "got {err}");
}

/// The λ-path under f32 storage tracks the f64 path: same grid, per-point
/// train losses within 1e-6 relative, and the dense (λ_min) endpoint's
/// coefficients within 1e-6. Backends must agree on the path too.
#[test]
fn l1_path_endpoints_match_across_precision_and_backends() {
    let ds = generate(&SyntheticConfig { n: 220, p: 10, rho: 0.2, k: 3, s: 0.1, seed: 303 });
    let base = CoxFit::new().n_lambdas(8);
    let p64 = base.clone().l1_path(&ds).unwrap();
    let p32 = base
        .clone()
        .compute(Compute::default().precision(Precision::F32Storage))
        .l1_path(&ds)
        .unwrap();
    assert_eq!(p64.len(), p32.len());
    // λ_max is data-derived, so the f32 grid may shift by the storage
    // rounding — but no further.
    for (a, b) in p64.lambdas().iter().zip(p32.lambdas().iter()) {
        assert!((a - b).abs() / (1.0 + b.abs()) <= 1e-6, "grid drifted: {a} vs {b}");
    }
    for (a, b) in p64.points().iter().zip(p32.points().iter()) {
        let gap = (a.train_loss - b.train_loss).abs() / (1.0 + b.train_loss.abs());
        assert!(gap <= 1e-6, "λ={:?}: f64 vs f32 loss gap {gap:.3e}", a.lambda);
    }
    let dense64 = &p64.points()[p64.len() - 1].beta;
    let dense32 = &p32.points()[p32.len() - 1].beta;
    let gap = max_abs_gap(dense64, dense32);
    assert!(gap <= 1e-6, "λ_min endpoint max|Δβ| = {gap:.3e}");

    // Backend parity on the same path: identical supports and train
    // losses within 1e-8 relative at every grid point (the convex
    // objective has one optimum per λ).
    let support = |beta: &[f64]| -> Vec<usize> {
        beta.iter().enumerate().filter(|(_, b)| b.abs() > 1e-10).map(|(i, _)| i).collect()
    };
    let scalar = base
        .clone()
        .compute(Compute::default().backend(Backend::Scalar))
        .l1_path(&ds)
        .unwrap();
    for (a, b) in p64.points().iter().zip(scalar.points().iter()) {
        assert_eq!(
            support(&a.beta),
            support(&b.beta),
            "λ={:?}: simd and scalar supports disagree",
            a.lambda
        );
        let gap = (a.train_loss - b.train_loss).abs() / (1.0 + b.train_loss.abs());
        assert!(gap <= 1e-8, "λ={:?}: simd vs scalar loss gap {gap:.3e}", a.lambda);
    }
}

/// Chunked store fits: a v2 (f32-cell) store written from pre-quantized
/// data is bitwise identical to the in-memory quantized source, and a v2
/// store written from raw f64 data stays within 1e-6 of the v1 fit.
#[test]
fn f32_store_fit_matches_memory_source_and_f64_store() {
    let ds = generate(&SyntheticConfig { n: 500, p: 8, rho: 0.3, k: 3, s: 0.1, seed: 304 });
    let chunk_rows = 128;

    // v1 (f64) reference fit.
    let v1_path = temp_path("parity_v1");
    let mut rows = DatasetRows::new(&ds);
    write_store(&mut rows, &v1_path, chunk_rows, "parity").unwrap();
    let mut v1 = ChunkedDataset::open(&v1_path).unwrap();
    let from_v1 = kkt_fitter(Compute::default()).fit(&mut v1).unwrap();

    // v2 from raw f64 data: the 1e-6 storage-precision contract.
    let v2_raw_path = temp_path("parity_v2_raw");
    let mut rows = DatasetRows::new(&ds);
    write_store_with(&mut rows, &v2_raw_path, chunk_rows, "parity", Precision::F32Storage)
        .unwrap();
    let mut v2_raw = ChunkedDataset::open(&v2_raw_path).unwrap();
    assert_eq!(v2_raw.header().precision, Precision::F32Storage);
    let from_v2 = kkt_fitter(Compute::default()).fit(&mut v2_raw).unwrap();
    let gap = max_abs_gap(&from_v1.beta, &from_v2.beta);
    assert!(gap <= 1e-6, "v2 store vs v1 store max|Δβ| = {gap:.3e}");

    // v2 from pre-quantized data vs the in-memory quantized source: both
    // execute the same instructions on the same bits.
    let qds = quantized(&ds);
    let v2_q_path = temp_path("parity_v2_quant");
    let mut rows = DatasetRows::new(&qds);
    write_store_with(&mut rows, &v2_q_path, chunk_rows, "parity", Precision::F32Storage)
        .unwrap();
    let mut v2_q = ChunkedDataset::open(&v2_q_path).unwrap();
    let from_store = kkt_fitter(Compute::default()).fit(&mut v2_q).unwrap();
    let mut mem =
        MemoryCoxData::from_dataset_with(&qds, chunk_rows, Precision::F32Storage).unwrap();
    let from_mem = kkt_fitter(Compute::default()).fit(&mut mem).unwrap();
    for (a, b) in from_store.beta.iter().zip(from_mem.beta.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "v2 store vs memory source must be bitwise");
    }

    // Backend parity holds through the chunked engine as well.
    let mut v1 = ChunkedDataset::open(&v1_path).unwrap();
    let scalar =
        kkt_fitter(Compute::default().backend(Backend::Scalar)).fit(&mut v1).unwrap();
    let gap = max_abs_gap(&from_v1.beta, &scalar.beta);
    assert!(gap <= 1e-8, "chunked simd vs scalar max|Δβ| = {gap:.3e}");

    for p in [&v1_path, &v2_raw_path, &v2_q_path] {
        let _ = std::fs::remove_file(p);
    }
}

/// `.fsds` v2 round-trip: geometry, survival columns, and meta survive
/// the f32 encoding; v1 stores written by the same build stay readable
/// with exact f64 cells (backward compatibility at the fit level is
/// covered above — here the raw columns are checked).
#[test]
fn fsds_v2_round_trips_and_v1_stays_readable() {
    let ds = generate(&SyntheticConfig { n: 90, p: 5, rho: 0.3, k: 2, s: 0.1, seed: 305 });
    let v1_path = temp_path("roundtrip_v1");
    let v2_path = temp_path("roundtrip_v2");
    let mut rows = DatasetRows::new(&ds);
    write_store(&mut rows, &v1_path, 32, "rt").unwrap();
    let mut rows = DatasetRows::new(&ds);
    write_store_with(&mut rows, &v2_path, 32, "rt", Precision::F32Storage).unwrap();

    let mut v1 = ChunkedDataset::open(&v1_path).unwrap();
    let mut v2 = ChunkedDataset::open(&v2_path).unwrap();
    assert_eq!(v1.header().precision, Precision::F64);
    assert_eq!(v2.header().precision, Precision::F32Storage);
    assert_eq!(v1.meta().n, v2.meta().n);
    assert_eq!(v1.meta().p, v2.meta().p);
    // Survival columns never change representation.
    assert_eq!(v1.meta().time, v2.meta().time);
    assert_eq!(v1.meta().event, v2.meta().event);

    let (mut c1, mut c2) = (Vec::new(), Vec::new());
    for j in 0..v1.meta().p {
        v1.load_col(j, &mut c1).unwrap();
        v2.load_col(j, &mut c2).unwrap();
        let quant: Vec<f64> = c1.iter().map(|&v| v as f32 as f64).collect();
        assert_eq!(c2, quant, "column {j}: v2 must decode as the f32 round-trip of v1");
    }
    let _ = std::fs::remove_file(&v1_path);
    let _ = std::fs::remove_file(&v2_path);
}
