//! Property-based tests over the paper's core mathematical claims,
//! using the in-repo randomized-property harness (util::proptest).

use fastsurvival::cox::derivatives::{
    all_coord_d1_d2, all_coord_d1_d2_seq, all_coord_d1_d2_with_threads, coord_d1,
    coord_d1_d2, coord_d1_d2_ws, coord_d1_ws, coord_derivs, Workspace,
};
use fastsurvival::cox::stratified::StratifiedCoxProblem;
use fastsurvival::cox::lipschitz::coord_lipschitz;
use fastsurvival::cox::loss::{loss, penalized_loss};
use fastsurvival::cox::{CoxProblem, CoxState};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::linalg::Matrix;
use fastsurvival::optim::cubic::cubic_coord_step;
use fastsurvival::optim::quadratic::quad_coord_step;
use fastsurvival::optim::Objective;
use fastsurvival::util::proptest::{check, gen};
use fastsurvival::util::rng::Rng;

fn random_problem(rng: &mut Rng, max_n: usize, p: usize) -> (CoxProblem, Vec<f64>) {
    let n = 8 + rng.below(max_n - 8);
    let cols: Vec<Vec<f64>> = (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let with_ties = rng.bernoulli(0.5);
    let time = gen::times(rng, n, with_ties);
    let event = gen::events(rng, n, 0.6);
    let ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "prop");
    let beta: Vec<f64> = (0..p).map(|_| rng.normal() * 0.7).collect();
    (CoxProblem::new(&ds), beta)
}

/// Theorem 3.4 as a property: for arbitrary data, ties, and β —
/// 0 ≤ d2 ≤ L2 and |d3| ≤ L3.
#[test]
fn prop_lipschitz_bounds() {
    check(
        "thm-3.4-bounds",
        101,
        80,
        |r| {
            let (pr, beta) = random_problem(r, 50, 3);
            (pr, beta)
        },
        |(pr, beta)| {
            let st = CoxState::from_beta(pr, beta);
            for l in 0..pr.p() {
                let d = coord_derivs(pr, &st, l);
                let lc = coord_lipschitz(pr, l);
                if d.d2 < -1e-9 {
                    return Err(format!("d2 negative: {}", d.d2));
                }
                if d.d2 > lc.l2 + 1e-9 {
                    return Err(format!("d2 {} > L2 {}", d.d2, lc.l2));
                }
                if d.d3.abs() > lc.l3 + 1e-9 {
                    return Err(format!("|d3| {} > L3 {}", d.d3.abs(), lc.l3));
                }
            }
            Ok(())
        },
    );
}

/// The quadratic surrogate step NEVER increases the penalized loss
/// (Eq. 15 majorization), for any data and any current β.
#[test]
fn prop_quadratic_step_monotone() {
    check(
        "quad-step-monotone",
        103,
        60,
        |r| {
            let (pr, beta) = random_problem(r, 40, 2);
            let l1 = if r.bernoulli(0.5) { r.uniform_range(0.0, 2.0) } else { 0.0 };
            let l2 = r.uniform_range(0.0, 2.0);
            let l = r.below(2);
            (pr, beta, l1, l2, l)
        },
        |(pr, beta, l1, l2, l)| {
            let obj = Objective { l1: *l1, l2: *l2 };
            let mut st = CoxState::from_beta(pr, beta);
            let before = penalized_loss(pr, &st, obj.l1, obj.l2);
            let lip = coord_lipschitz(pr, *l);
            quad_coord_step(pr, &mut st, *l, lip, obj);
            let after = penalized_loss(pr, &st, obj.l1, obj.l2);
            if after <= before + 1e-9 {
                Ok(())
            } else {
                Err(format!("loss increased: {before} -> {after}"))
            }
        },
    );
}

/// Same majorization property for the cubic surrogate step (Eq. 16).
#[test]
fn prop_cubic_step_monotone() {
    check(
        "cubic-step-monotone",
        107,
        60,
        |r| {
            let (pr, beta) = random_problem(r, 40, 2);
            let l1 = if r.bernoulli(0.5) { r.uniform_range(0.0, 2.0) } else { 0.0 };
            let l2 = r.uniform_range(0.0, 2.0);
            let l = r.below(2);
            (pr, beta, l1, l2, l)
        },
        |(pr, beta, l1, l2, l)| {
            let obj = Objective { l1: *l1, l2: *l2 };
            let mut st = CoxState::from_beta(pr, beta);
            let before = penalized_loss(pr, &st, obj.l1, obj.l2);
            let lip = coord_lipschitz(pr, *l);
            cubic_coord_step(pr, &mut st, *l, lip, obj);
            let after = penalized_loss(pr, &st, obj.l1, obj.l2);
            if after <= before + 1e-9 {
                Ok(())
            } else {
                Err(format!("loss increased: {before} -> {after}"))
            }
        },
    );
}

/// The cubic surrogate's predicted decrease is a valid lower bound on
/// the actual decrease (the surrogate upper-bounds the loss).
#[test]
fn prop_surrogate_upper_bounds_loss() {
    check(
        "surrogate-majorizes",
        109,
        60,
        |r| {
            let (pr, beta) = random_problem(r, 40, 1);
            let delta = r.uniform_range(-1.5, 1.5);
            (pr, beta, delta)
        },
        |(pr, beta, delta)| {
            let st = CoxState::from_beta(pr, beta);
            let f0 = loss(pr, &st);
            let (d1, d2) = coord_d1_d2(pr, &st, 0);
            let lip = coord_lipschitz(pr, 0);
            let surrogate = f0
                + d1 * delta
                + 0.5 * d2 * delta * delta
                + lip.l3 / 6.0 * delta.abs().powi(3);
            let mut moved = st.clone();
            moved.update_coord(pr, 0, *delta);
            let f1 = loss(pr, &moved);
            if f1 <= surrogate + 1e-7 * (f0.abs() + 1.0) {
                Ok(())
            } else {
                Err(format!("h(Δ)={surrogate} < f(x+Δ)={f1} at Δ={delta}"))
            }
        },
    );
}

/// Quadratic majorization too: f(x+Δ) ≤ f(x) + d1·Δ + L2/2·Δ².
#[test]
fn prop_quadratic_majorizes() {
    check(
        "quad-majorizes",
        113,
        60,
        |r| {
            let (pr, beta) = random_problem(r, 40, 1);
            let delta = r.uniform_range(-1.5, 1.5);
            (pr, beta, delta)
        },
        |(pr, beta, delta)| {
            let st = CoxState::from_beta(pr, beta);
            let f0 = loss(pr, &st);
            let (d1, _) = coord_d1_d2(pr, &st, 0);
            let lip = coord_lipschitz(pr, 0);
            let surrogate = f0 + d1 * delta + 0.5 * lip.l2 * delta * delta;
            let mut moved = st.clone();
            moved.update_coord(pr, 0, *delta);
            let f1 = loss(pr, &moved);
            if f1 <= surrogate + 1e-7 * (f0.abs() + 1.0) {
                Ok(())
            } else {
                Err(format!("g(Δ)={surrogate} < f(x+Δ)={f1} at Δ={delta}"))
            }
        },
    );
}

/// The parallel blocked batched pass matches the sequential
/// per-coordinate kernels within 1e-10 — for every worker count in
/// {1, 2, 4} (the counts `FASTSURVIVAL_THREADS` would set; pinned here
/// via the explicit-workers entry point because mutating the
/// environment from a parallel test harness races glibc's setenv),
/// for tied and untied inputs (ties are randomized inside
/// `random_problem`), and through the cached per-coordinate `_ws` paths.
#[test]
fn prop_blocked_parallel_matches_sequential_derivatives() {
    check(
        "blocked-parallel-parity",
        131,
        30,
        |r| {
            let p = 3 + r.below(18);
            let (pr, beta) = random_problem(r, 80, p);
            (pr, beta)
        },
        |(pr, beta)| {
            let st = CoxState::from_beta(pr, beta);
            let (r1, r2) = all_coord_d1_d2_seq(pr, &st);
            for &threads in &[1usize, 2, 4] {
                let mut ws = Workspace::default();
                let (d1, d2) = all_coord_d1_d2_with_threads(pr, &st, &mut ws, threads);
                for l in 0..pr.p() {
                    let (e1, e2) = coord_d1_d2(pr, &st, l);
                    if (d1[l] - e1).abs() > 1e-10 || (d1[l] - r1[l]).abs() > 1e-10 {
                        return Err(format!(
                            "threads={threads} l={l}: blocked d1 {} vs coord {} vs seq {}",
                            d1[l], e1, r1[l]
                        ));
                    }
                    if (d2[l] - e2).abs() > 1e-10 || (d2[l] - r2[l]).abs() > 1e-10 {
                        return Err(format!(
                            "threads={threads} l={l}: blocked d2 {} vs coord {} vs seq {}",
                            d2[l], e2, r2[l]
                        ));
                    }
                }
            }
            // Cached single-coordinate paths (evaluated twice at one η so
            // both the classic and the cache-hit branches run).
            let mut ws = Workspace::default();
            for _ in 0..2 {
                for l in 0..pr.p() {
                    let got = coord_d1_ws(pr, &st, &mut ws, l);
                    if (got - coord_d1(pr, &st, l)).abs() > 1e-10 {
                        return Err(format!("cached d1 mismatch at {l}"));
                    }
                    let (g1, g2) = coord_d1_d2_ws(pr, &st, &mut ws, l);
                    let (e1, e2) = coord_d1_d2(pr, &st, l);
                    if (g1 - e1).abs() > 1e-10 || (g2 - e2).abs() > 1e-10 {
                        return Err(format!("cached d1d2 mismatch at {l}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The public auto-threaded entry point (the one `FASTSURVIVAL_THREADS`
/// steers at runtime) agrees with the sequential reference for whatever
/// worker count this environment resolves to.
#[test]
fn prop_auto_threaded_batched_matches_sequential() {
    let mut rng = Rng::new(977);
    let (pr, beta) = random_problem(&mut rng, 60, 20);
    let st = CoxState::from_beta(&pr, &beta);
    let (r1, r2) = all_coord_d1_d2_seq(&pr, &st);
    let mut ws = Workspace::default();
    let (d1, d2) = all_coord_d1_d2(&pr, &st, &mut ws);
    for l in 0..pr.p() {
        assert!((d1[l] - r1[l]).abs() < 1e-10, "l={l}: {} vs {}", d1[l], r1[l]);
        assert!((d2[l] - r2[l]).abs() < 1e-10);
    }
}

/// Stratified inputs: the batched per-stratum blocked pass and the
/// cached per-coordinate path both match the sequential per-coordinate
/// sum within 1e-10.
#[test]
fn prop_stratified_blocked_matches_sequential() {
    check(
        "stratified-blocked-parity",
        139,
        20,
        |r| {
            let n = 30 + r.below(60);
            let p = 2 + r.below(4);
            let cols: Vec<Vec<f64>> =
                (0..p).map(|_| (0..n).map(|_| r.normal()).collect()).collect();
            let time = gen::times(r, n, r.bernoulli(0.5));
            let event = gen::events(r, n, 0.7);
            let labels: Vec<usize> = (0..n).map(|_| r.below(3)).collect();
            let beta: Vec<f64> = (0..p).map(|_| r.normal() * 0.5).collect();
            (cols, time, event, labels, beta)
        },
        |(cols, time, event, labels, beta)| {
            let ds = SurvivalDataset::new(
                Matrix::from_columns(cols),
                time.clone(),
                event.clone(),
                "strat-prop",
            );
            let sp = StratifiedCoxProblem::new(&ds, labels);
            let states: Vec<CoxState> = sp
                .strata
                .iter()
                .map(|pr| CoxState::from_beta(pr, beta))
                .collect();
            let mut wss = sp.workspaces();
            let (b1, b2) = sp.all_coord_d1_d2(&states, &mut wss);
            for l in 0..sp.p {
                let (d1, d2) = sp.coord_d1_d2(&states, l);
                if (b1[l] - d1).abs() > 1e-10 || (b2[l] - d2).abs() > 1e-10 {
                    return Err(format!(
                        "stratified batched mismatch at {l}: ({}, {}) vs ({d1}, {d2})",
                        b1[l], b2[l]
                    ));
                }
                let (c1, c2) = sp.coord_d1_d2_ws(&states, &mut wss, l);
                if (c1 - d1).abs() > 1e-10 || (c2 - d2).abs() > 1e-10 {
                    return Err(format!("stratified cached mismatch at {l}"));
                }
            }
            Ok(())
        },
    );
}

/// Loss invariance: permuting samples does not change the loss or the
/// coordinate derivatives (the problem is order-normalized internally).
#[test]
fn prop_permutation_invariance() {
    check(
        "permutation-invariant",
        127,
        40,
        |r| {
            let n = 10 + r.below(30);
            let col: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            let time = gen::times(r, n, true);
            let event = gen::events(r, n, 0.6);
            let perm = r.permutation(n);
            let beta = r.uniform_range(-1.0, 1.0);
            (col, time, event, perm, beta)
        },
        |(col, time, event, perm, beta)| {
            let ds1 = SurvivalDataset::new(
                Matrix::from_columns(&[col.clone()]),
                time.clone(),
                event.clone(),
                "a",
            );
            let ds2 = SurvivalDataset::new(
                Matrix::from_columns(&[perm.iter().map(|&i| col[i]).collect()]),
                perm.iter().map(|&i| time[i]).collect(),
                perm.iter().map(|&i| event[i]).collect(),
                "b",
            );
            let p1 = CoxProblem::new(&ds1);
            let p2 = CoxProblem::new(&ds2);
            let s1 = CoxState::from_beta(&p1, &[*beta]);
            let s2 = CoxState::from_beta(&p2, &[*beta]);
            let (l1v, l2v) = (loss(&p1, &s1), loss(&p2, &s2));
            if (l1v - l2v).abs() > 1e-8 {
                return Err(format!("loss differs under permutation: {l1v} vs {l2v}"));
            }
            let d1 = coord_derivs(&p1, &s1, 0);
            let d2 = coord_derivs(&p2, &s2, 0);
            if (d1.d1 - d2.d1).abs() > 1e-8 || (d1.d2 - d2.d2).abs() > 1e-8 {
                return Err("derivatives differ under permutation".into());
            }
            Ok(())
        },
    );
}
