//! Property-based tests over the paper's core mathematical claims,
//! using the in-repo randomized-property harness (util::proptest).

use fastsurvival::cox::derivatives::{coord_d1_d2, coord_derivs};
use fastsurvival::cox::lipschitz::coord_lipschitz;
use fastsurvival::cox::loss::{loss, penalized_loss};
use fastsurvival::cox::{CoxProblem, CoxState};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::linalg::Matrix;
use fastsurvival::optim::cubic::cubic_coord_step;
use fastsurvival::optim::quadratic::quad_coord_step;
use fastsurvival::optim::Objective;
use fastsurvival::util::proptest::{check, gen};
use fastsurvival::util::rng::Rng;

fn random_problem(rng: &mut Rng, max_n: usize, p: usize) -> (CoxProblem, Vec<f64>) {
    let n = 8 + rng.below(max_n - 8);
    let cols: Vec<Vec<f64>> = (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let with_ties = rng.bernoulli(0.5);
    let time = gen::times(rng, n, with_ties);
    let event = gen::events(rng, n, 0.6);
    let ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "prop");
    let beta: Vec<f64> = (0..p).map(|_| rng.normal() * 0.7).collect();
    (CoxProblem::new(&ds), beta)
}

/// Theorem 3.4 as a property: for arbitrary data, ties, and β —
/// 0 ≤ d2 ≤ L2 and |d3| ≤ L3.
#[test]
fn prop_lipschitz_bounds() {
    check(
        "thm-3.4-bounds",
        101,
        80,
        |r| {
            let (pr, beta) = random_problem(r, 50, 3);
            (pr, beta)
        },
        |(pr, beta)| {
            let st = CoxState::from_beta(pr, beta);
            for l in 0..pr.p() {
                let d = coord_derivs(pr, &st, l);
                let lc = coord_lipschitz(pr, l);
                if d.d2 < -1e-9 {
                    return Err(format!("d2 negative: {}", d.d2));
                }
                if d.d2 > lc.l2 + 1e-9 {
                    return Err(format!("d2 {} > L2 {}", d.d2, lc.l2));
                }
                if d.d3.abs() > lc.l3 + 1e-9 {
                    return Err(format!("|d3| {} > L3 {}", d.d3.abs(), lc.l3));
                }
            }
            Ok(())
        },
    );
}

/// The quadratic surrogate step NEVER increases the penalized loss
/// (Eq. 15 majorization), for any data and any current β.
#[test]
fn prop_quadratic_step_monotone() {
    check(
        "quad-step-monotone",
        103,
        60,
        |r| {
            let (pr, beta) = random_problem(r, 40, 2);
            let l1 = if r.bernoulli(0.5) { r.uniform_range(0.0, 2.0) } else { 0.0 };
            let l2 = r.uniform_range(0.0, 2.0);
            let l = r.below(2);
            (pr, beta, l1, l2, l)
        },
        |(pr, beta, l1, l2, l)| {
            let obj = Objective { l1: *l1, l2: *l2 };
            let mut st = CoxState::from_beta(pr, beta);
            let before = penalized_loss(pr, &st, obj.l1, obj.l2);
            let lip = coord_lipschitz(pr, *l);
            quad_coord_step(pr, &mut st, *l, lip, obj);
            let after = penalized_loss(pr, &st, obj.l1, obj.l2);
            if after <= before + 1e-9 {
                Ok(())
            } else {
                Err(format!("loss increased: {before} -> {after}"))
            }
        },
    );
}

/// Same majorization property for the cubic surrogate step (Eq. 16).
#[test]
fn prop_cubic_step_monotone() {
    check(
        "cubic-step-monotone",
        107,
        60,
        |r| {
            let (pr, beta) = random_problem(r, 40, 2);
            let l1 = if r.bernoulli(0.5) { r.uniform_range(0.0, 2.0) } else { 0.0 };
            let l2 = r.uniform_range(0.0, 2.0);
            let l = r.below(2);
            (pr, beta, l1, l2, l)
        },
        |(pr, beta, l1, l2, l)| {
            let obj = Objective { l1: *l1, l2: *l2 };
            let mut st = CoxState::from_beta(pr, beta);
            let before = penalized_loss(pr, &st, obj.l1, obj.l2);
            let lip = coord_lipschitz(pr, *l);
            cubic_coord_step(pr, &mut st, *l, lip, obj);
            let after = penalized_loss(pr, &st, obj.l1, obj.l2);
            if after <= before + 1e-9 {
                Ok(())
            } else {
                Err(format!("loss increased: {before} -> {after}"))
            }
        },
    );
}

/// The cubic surrogate's predicted decrease is a valid lower bound on
/// the actual decrease (the surrogate upper-bounds the loss).
#[test]
fn prop_surrogate_upper_bounds_loss() {
    check(
        "surrogate-majorizes",
        109,
        60,
        |r| {
            let (pr, beta) = random_problem(r, 40, 1);
            let delta = r.uniform_range(-1.5, 1.5);
            (pr, beta, delta)
        },
        |(pr, beta, delta)| {
            let st = CoxState::from_beta(pr, beta);
            let f0 = loss(pr, &st);
            let (d1, d2) = coord_d1_d2(pr, &st, 0);
            let lip = coord_lipschitz(pr, 0);
            let surrogate = f0
                + d1 * delta
                + 0.5 * d2 * delta * delta
                + lip.l3 / 6.0 * delta.abs().powi(3);
            let mut moved = st.clone();
            moved.update_coord(pr, 0, *delta);
            let f1 = loss(pr, &moved);
            if f1 <= surrogate + 1e-7 * (f0.abs() + 1.0) {
                Ok(())
            } else {
                Err(format!("h(Δ)={surrogate} < f(x+Δ)={f1} at Δ={delta}"))
            }
        },
    );
}

/// Quadratic majorization too: f(x+Δ) ≤ f(x) + d1·Δ + L2/2·Δ².
#[test]
fn prop_quadratic_majorizes() {
    check(
        "quad-majorizes",
        113,
        60,
        |r| {
            let (pr, beta) = random_problem(r, 40, 1);
            let delta = r.uniform_range(-1.5, 1.5);
            (pr, beta, delta)
        },
        |(pr, beta, delta)| {
            let st = CoxState::from_beta(pr, beta);
            let f0 = loss(pr, &st);
            let (d1, _) = coord_d1_d2(pr, &st, 0);
            let lip = coord_lipschitz(pr, 0);
            let surrogate = f0 + d1 * delta + 0.5 * lip.l2 * delta * delta;
            let mut moved = st.clone();
            moved.update_coord(pr, 0, *delta);
            let f1 = loss(pr, &moved);
            if f1 <= surrogate + 1e-7 * (f0.abs() + 1.0) {
                Ok(())
            } else {
                Err(format!("g(Δ)={surrogate} < f(x+Δ)={f1} at Δ={delta}"))
            }
        },
    );
}

/// Loss invariance: permuting samples does not change the loss or the
/// coordinate derivatives (the problem is order-normalized internally).
#[test]
fn prop_permutation_invariance() {
    check(
        "permutation-invariant",
        127,
        40,
        |r| {
            let n = 10 + r.below(30);
            let col: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            let time = gen::times(r, n, true);
            let event = gen::events(r, n, 0.6);
            let perm = r.permutation(n);
            let beta = r.uniform_range(-1.0, 1.0);
            (col, time, event, perm, beta)
        },
        |(col, time, event, perm, beta)| {
            let ds1 = SurvivalDataset::new(
                Matrix::from_columns(&[col.clone()]),
                time.clone(),
                event.clone(),
                "a",
            );
            let ds2 = SurvivalDataset::new(
                Matrix::from_columns(&[perm.iter().map(|&i| col[i]).collect()]),
                perm.iter().map(|&i| time[i]).collect(),
                perm.iter().map(|&i| event[i]).collect(),
                "b",
            );
            let p1 = CoxProblem::new(&ds1);
            let p2 = CoxProblem::new(&ds2);
            let s1 = CoxState::from_beta(&p1, &[*beta]);
            let s2 = CoxState::from_beta(&p2, &[*beta]);
            let (l1v, l2v) = (loss(&p1, &s1), loss(&p2, &s2));
            if (l1v - l2v).abs() > 1e-8 {
                return Err(format!("loss differs under permutation: {l1v} vs {l2v}"));
            }
            let d1 = coord_derivs(&p1, &s1, 0);
            let d2 = coord_derivs(&p2, &s2, 0);
            if (d1.d1 - d2.d1).abs() > 1e-8 || (d1.d2 - d2.d2).abs() > 1e-8 {
                return Err("derivatives differ under permutation".into());
            }
            Ok(())
        },
    );
}
