//! End-to-end integration tests: whole-pipeline fits, optimizer
//! agreement, runtime failure injection, CV reproducibility, and the
//! unified engine-threading fit path.

use fastsurvival::coordinator::cv::cv_selector;
use fastsurvival::cox::derivatives::CoordDerivs;
use fastsurvival::cox::lipschitz::LipschitzPair;
use fastsurvival::cox::{CoxProblem, CoxState};
use fastsurvival::data::binarize::{binarize, BinarizeConfig};
use fastsurvival::data::datasets;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::error::Result;
use fastsurvival::metrics::concordance_index;
use fastsurvival::optim::{
    self, CubicSurrogate, FitConfig, Objective, Optimizer, QuadraticSurrogate,
};
use fastsurvival::runtime::engine::{CoxEngine, NativeEngine, XlaEngine};
use fastsurvival::runtime::Manifest;
use fastsurvival::select::{BeamSearch, VariableSelector};
use std::path::Path;

/// All convergent optimizers agree on the strictly convex ℓ2 problem.
#[test]
fn all_optimizers_agree_on_l2_optimum() {
    let ds = generate(&SyntheticConfig { n: 250, p: 8, rho: 0.4, k: 3, s: 0.1, seed: 1 });
    let pr = CoxProblem::new(&ds);
    let reference = CubicSurrogate
        .fit(
            &pr,
            &FitConfig {
                objective: Objective { l1: 0.0, l2: 2.0 },
                max_iters: 3000,
                tol: 1e-13,
                ..Default::default()
            },
        )
        .unwrap();
    for name in ["quadratic", "quasi-newton", "prox-newton", "newton-ls"] {
        let opt = optim::by_name(name).unwrap();
        let res = opt
            .fit(
                &pr,
                &FitConfig {
                    objective: Objective { l1: 0.0, l2: 2.0 },
                    max_iters: 3000,
                    tol: 1e-13,
                    ..Default::default()
                },
            )
            .unwrap();
        assert!(
            (res.objective_value - reference.objective_value).abs() < 1e-4,
            "{name}: {} vs reference {}",
            res.objective_value,
            reference.objective_value
        );
    }
}

/// The full paper pipeline: generate → binarize → select → evaluate.
#[test]
fn binarized_selection_pipeline() {
    let mut spec = datasets::spec("dialysis");
    spec.n = 600;
    let raw = datasets::generate_stand_in(&spec, 7);
    let ds = binarize(&raw, &BinarizeConfig { max_quantiles: 12, ..Default::default() });
    assert!(ds.p() > raw.p());
    let pr = CoxProblem::new(&ds);
    let bs = BeamSearch { width: 3, screen: 8, ..Default::default() };
    let sols = bs.select(&pr, &[1, 3, 5]);
    assert_eq!(sols.len(), 3);
    // Larger support must not have larger training loss.
    assert!(sols[2].train_loss <= sols[0].train_loss + 1e-9);
    // The k=5 model must rank risk better than chance.
    let eta = ds.x.matvec(&sols[2].beta);
    let ci = concordance_index(&ds.time, &ds.event, &eta);
    assert!(ci > 0.55, "cindex {ci}");
}

/// CV with a fixed seed is bit-reproducible.
#[test]
fn cv_reproducible() {
    let ds = generate(&SyntheticConfig { n: 150, p: 10, rho: 0.3, k: 2, s: 0.1, seed: 3 });
    let bs = BeamSearch { width: 2, screen: 5, ..Default::default() };
    let a = cv_selector(&ds, &bs, &[1, 2], 3, 9);
    let b = cv_selector(&ds, &bs, &[1, 2], 3, 9);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.k, y.k);
        assert_eq!(x.fold, y.fold);
        assert_eq!(x.test_cindex, y.test_cindex);
        assert_eq!(x.train_loss, y.train_loss);
    }
}

/// Failure injection: missing artifact dir and corrupted HLO text both
/// surface as typed errors, never a crash — in every build flavor.
#[test]
fn runtime_failure_injection() {
    // Missing directory → helpful error.
    assert!(XlaEngine::new(Path::new("/definitely/not/here")).is_err());

    // Corrupted HLO: the manifest parses, and then either the stub build
    // reports the feature is off (typed error at construction) or the
    // real build surfaces the compile error at execution time.
    let dir = std::env::temp_dir().join("fs_bad_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.tsv"),
        "cox_loss_n64\tbad.hlo.txt\t64\t1\tfloat32:64\n",
    )
    .unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule garbage THIS IS NOT HLO").unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    assert_eq!(manifest.entries.len(), 1);
    match XlaEngine::new(&dir) {
        Err(e) => assert!(e.to_string().contains("xla"), "stub build: {e}"),
        Ok(eng) => {
            let ds = generate(&SyntheticConfig { n: 30, p: 2, rho: 0.1, k: 1, s: 0.1, seed: 4 });
            let pr = CoxProblem::new(&ds);
            let st = CoxState::zeros(&pr);
            assert!(eng.loss(&pr, &st).is_err(), "corrupted HLO must error cleanly");
        }
    }
}

/// A pass-through engine that serves every quantity from the native
/// kernels but reports `is_native() == false`, forcing the optimizers
/// down the engine-generic code path. Proves the unified `fit_from`
/// sweep is numerically identical to the fused native fast path without
/// needing the AOT artifacts.
struct ForwardingEngine(NativeEngine);

impl CoxEngine for ForwardingEngine {
    fn name(&self) -> &'static str {
        "forwarding"
    }

    fn loss(&self, problem: &CoxProblem, state: &CoxState) -> Result<f64> {
        self.0.loss(problem, state)
    }

    fn coord_derivs(
        &self,
        problem: &CoxProblem,
        state: &CoxState,
        l: usize,
    ) -> Result<CoordDerivs> {
        self.0.coord_derivs(problem, state, l)
    }

    fn all_d1_d2(&self, problem: &CoxProblem, state: &CoxState) -> Result<(Vec<f64>, Vec<f64>)> {
        self.0.all_d1_d2(problem, state)
    }

    fn lipschitz(&self, problem: &CoxProblem, l: usize) -> Result<LipschitzPair> {
        self.0.lipschitz(problem, l)
    }
}

#[test]
fn engine_generic_path_matches_native_fast_path() {
    let ds = generate(&SyntheticConfig { n: 120, p: 5, rho: 0.4, k: 2, s: 0.1, seed: 61 });
    let pr = CoxProblem::new(&ds);
    for (l1, l2) in [(0.0, 1.0), (0.5, 1.0)] {
        let cfg = FitConfig {
            objective: Objective { l1, l2 },
            max_iters: 300,
            tol: 1e-12,
            ..Default::default()
        };
        for opt in [&CubicSurrogate as &dyn Optimizer, &QuadraticSurrogate] {
            let native = opt.fit(&pr, &cfg).unwrap();
            let generic = opt
                .fit_from(&pr, CoxState::zeros(&pr), &cfg, &ForwardingEngine(NativeEngine))
                .unwrap();
            assert!(generic.trace.monotone(1e-9));
            for l in 0..pr.p() {
                assert!(
                    (native.beta[l] - generic.beta[l]).abs() < 1e-6,
                    "{} λ1={l1} coord {l}: {} vs {}",
                    opt.name(),
                    native.beta[l],
                    generic.beta[l]
                );
            }
        }
    }
}

/// Baselines that need native kernels reject non-native engines with a
/// typed error instead of silently falling back.
#[test]
fn native_only_optimizers_reject_foreign_engines() {
    let ds = generate(&SyntheticConfig { n: 60, p: 3, rho: 0.2, k: 1, s: 0.1, seed: 13 });
    let pr = CoxProblem::new(&ds);
    let cfg = FitConfig::default();
    for name in ["newton", "quasi-newton", "prox-newton", "gd"] {
        let opt = optim::by_name(name).unwrap();
        let err = opt
            .fit_from(&pr, CoxState::zeros(&pr), &cfg, &ForwardingEngine(NativeEngine))
            .unwrap_err();
        assert!(
            err.to_string().contains("native engine"),
            "{name}: unexpected error {err}"
        );
    }
}

/// Native vs XLA on *binarized* (binary-feature) data — the paper's
/// actual regime — through the unified `Optimizer::fit_from` path.
#[test]
fn engine_parity_on_binarized_data() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let Ok(xe) = XlaEngine::new(dir) else {
        eprintln!("skipping: xla feature not compiled in");
        return;
    };
    let mut spec = datasets::spec("dialysis");
    spec.n = 300;
    let raw = datasets::generate_stand_in(&spec, 11);
    let ds = binarize(&raw, &BinarizeConfig { max_quantiles: 6, ..Default::default() });
    let pr = CoxProblem::new(&ds);
    let cfg = FitConfig {
        objective: Objective { l1: 0.5, l2: 0.5 },
        max_iters: 20,
        tol: 1e-8,
        ..Default::default()
    };
    let bn = CubicSurrogate.fit(&pr, &cfg).unwrap().beta;
    let rx = CubicSurrogate.fit_from(&pr, CoxState::zeros(&pr), &cfg, &xe).unwrap();
    assert!(rx.trace.monotone(1e-4));
    for l in 0..pr.p() {
        assert!(
            (bn[l] - rx.beta[l]).abs() < 1e-2,
            "coord {l}: native {} vs xla {}",
            bn[l],
            rx.beta[l]
        );
    }
}

/// Warm-started fits resume without loss jumps.
#[test]
fn warm_start_continuity() {
    let ds = generate(&SyntheticConfig { n: 200, p: 6, rho: 0.5, k: 2, s: 0.1, seed: 5 });
    let pr = CoxProblem::new(&ds);
    let cfg = FitConfig {
        objective: Objective { l1: 0.0, l2: 1.0 },
        max_iters: 5,
        tol: 0.0,
        ..Default::default()
    };
    let first = QuadraticSurrogate.fit(&pr, &cfg).unwrap();
    let warm = CoxState::from_beta(&pr, &first.beta);
    let second = QuadraticSurrogate.fit_from(&pr, warm, &cfg, &NativeEngine).unwrap();
    let first_end = first.trace.final_loss();
    let second_start = second.trace.points.first().unwrap().loss;
    assert!(
        second_start <= first_end + 1e-9,
        "warm start must not regress: {second_start} vs {first_end}"
    );
}

/// The experiment harness writes every advertised file for a tiny run.
#[test]
fn experiment_harness_outputs() {
    use fastsurvival::coordinator::experiments::{run, ExperimentConfig};
    let out = std::env::temp_dir().join("fs_integration_results");
    let cfg = ExperimentConfig {
        scale: 0.03,
        quantiles: 5,
        folds: 2,
        ks: vec![1, 2],
        optim_iters: 3,
        seed: 0,
        out_dir: out.clone(),
    };
    run("table1", &cfg).unwrap();
    run("fig17", &cfg).unwrap(); // dialysis grid cell (λ1=0, λ2=1)
    assert!(out.join("table1.csv").exists());
    assert!(out.join("fig17_curves.csv").exists());
    assert!(out.join("fig17_summary.csv").exists());
}
