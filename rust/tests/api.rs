//! Black-box tests for the unified estimator API: builder round-trips,
//! survival-prediction semantics, and typed error paths.

use fastsurvival::api::{CoxFit, CoxModel, EngineKind, OptimizerKind};
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::error::FastSurvivalError;
use fastsurvival::linalg::Matrix;
use fastsurvival::metrics::BreslowBaseline;

fn train() -> SurvivalDataset {
    generate(&SyntheticConfig { n: 300, p: 12, rho: 0.5, k: 4, s: 0.1, seed: 42 })
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fs_api_{name}.json"))
}

#[test]
fn fit_save_load_round_trip_predicts_identically() {
    let ds = train();
    let model = CoxFit::new().l1(0.3).l2(0.2).max_iters(300).tol(1e-11).fit(&ds).unwrap();
    let path = tmp("round_trip");
    model.save(&path).unwrap();
    let loaded = CoxModel::load(&path).unwrap();

    assert_eq!(model.beta(), loaded.beta(), "coefficients must round-trip exactly");
    assert_eq!(model.feature_names(), loaded.feature_names());
    let risk_a = model.predict_risk(&ds.x).unwrap();
    let risk_b = loaded.predict_risk(&ds.x).unwrap();
    assert_eq!(risk_a, risk_b);
    for t in [0.1, 0.7, 2.0, 10.0] {
        let sa = model.predict_survival(&ds.x, t).unwrap();
        let sb = loaded.predict_survival(&ds.x, t).unwrap();
        assert_eq!(sa, sb, "survival at t={t} must round-trip exactly");
    }
    // Scalar diagnostics persist too.
    let (d, e) = (model.diagnostics(), loaded.diagnostics());
    assert_eq!(d.optimizer, e.optimizer);
    assert_eq!(d.iterations, e.iterations);
    assert_eq!(d.l1, e.l1);
    assert_eq!(d.objective_value, e.objective_value);
}

#[test]
fn predict_survival_is_monotone_and_matches_breslow_directly() {
    let ds = train();
    let model = CoxFit::new().l2(0.5).fit(&ds).unwrap();

    // Agreement with a BreslowBaseline fitted by hand on the same η.
    let eta = ds.x.matvec(model.beta());
    let direct = BreslowBaseline::fit(&ds.time, &ds.event, &eta);
    for t in [0.0, 0.3, 1.0, 5.0] {
        let s = model.predict_survival(&ds.x, t).unwrap();
        for i in (0..ds.n()).step_by(37) {
            let expect = direct.survival(t, eta[i]);
            assert!(
                (s[i] - expect).abs() < 1e-12,
                "t={t} i={i}: {} vs direct {expect}",
                s[i]
            );
        }
    }

    // Monotone non-increasing in t for every subject.
    let grid = [0.0, 0.2, 0.5, 1.0, 2.0, 4.0, 8.0];
    let mut prev = vec![1.0; ds.n()];
    for &t in &grid {
        let s = model.predict_survival(&ds.x, t).unwrap();
        for i in 0..ds.n() {
            assert!(
                s[i] <= prev[i] + 1e-12,
                "S(t|x_{i}) increased: {} -> {} at t={t}",
                prev[i],
                s[i]
            );
            assert!((0.0..=1.0).contains(&s[i]));
            prev[i] = s[i];
        }
    }
}

#[test]
fn nan_time_is_a_typed_error_not_a_panic() {
    let x = Matrix::from_columns(&[vec![1.0, -1.0, 0.5]]);
    let mut time = vec![3.0, 2.0, 1.0];
    time[1] = f64::NAN;
    let ds = SurvivalDataset::new(x, time, vec![true, true, false], "nan");
    let err = CoxFit::new().fit(&ds).unwrap_err();
    assert!(matches!(err, FastSurvivalError::InvalidData(_)), "got {err}");
    assert!(err.to_string().contains("sample 1"), "got {err}");
}

#[test]
fn empty_dataset_is_a_typed_error() {
    let ds = SurvivalDataset::new(Matrix::zeros(0, 2), vec![], vec![], "empty");
    let err = CoxFit::new().fit(&ds).unwrap_err();
    assert!(matches!(err, FastSurvivalError::InvalidData(_)), "got {err}");
}

#[test]
fn all_censored_is_a_typed_error() {
    let x = Matrix::from_columns(&[vec![0.1, 0.4, -0.3, 0.9]]);
    let ds = SurvivalDataset::new(x, vec![4.0, 3.0, 2.0, 1.0], vec![false; 4], "cens");
    let err = CoxFit::new().fit(&ds).unwrap_err();
    assert!(matches!(err, FastSurvivalError::InvalidData(_)), "got {err}");
    assert!(err.to_string().contains("censored"), "got {err}");
}

#[test]
fn xla_engine_unavailable_is_a_typed_error_or_matches_native() {
    let ds = train();
    let native = CoxFit::new().l2(1.0).max_iters(50).fit(&ds).unwrap();
    match CoxFit::new().l2(1.0).max_iters(50).engine(EngineKind::Xla).fit(&ds) {
        // No artifacts / no xla feature in this build: typed error.
        Err(FastSurvivalError::Engine(_)) | Err(FastSurvivalError::Unsupported(_)) => {}
        Err(other) => panic!("unexpected error kind: {other}"),
        // Accelerator image with artifacts: parity with the native fit.
        Ok(xla_model) => {
            for (a, b) in native.beta().iter().zip(xla_model.beta()) {
                assert!((a - b).abs() < 1e-2, "{a} vs {b}");
            }
        }
    }
}

#[test]
fn coefficients_replace_beta_to_original() {
    let ds = train();
    let model = CoxFit::new().l1(1.0).l2(0.1).fit(&ds).unwrap();
    let cs = model.coefficients();
    assert_eq!(cs.len(), ds.p());
    for (j, c) in cs.iter().enumerate() {
        assert_eq!(c.index, j, "coefficients are keyed by original feature index");
        assert_eq!(c.name, ds.feature_names[j]);
        assert_eq!(c.value, model.beta()[j]);
    }
    let nz = model.nonzero_coefficients(1e-10);
    assert!(nz.len() < ds.p(), "ℓ1 fit should be sparse");
    assert!(nz.windows(2).all(|w| w[0].value.abs() >= w[1].value.abs()));
}

#[test]
fn optimizer_name_strings_reach_the_builder() {
    // The CLI path: names → kinds → fits, all through one builder.
    let ds = train();
    for name in ["quadratic", "cubic", "quasi-newton"] {
        let kind = OptimizerKind::from_name(name).unwrap();
        let model = CoxFit::new().l2(1.0).optimizer(kind).max_iters(40).fit(&ds).unwrap();
        assert!(model.concordance(&ds).unwrap() > 0.5);
    }
}

#[test]
fn load_rejects_tampered_files() {
    let ds = train();
    let model = CoxFit::new().l2(0.5).fit(&ds).unwrap();
    let path = tmp("tampered");
    model.save(&path).unwrap();
    let good = std::fs::read_to_string(&path).unwrap();

    // Remove a required field.
    let bad = good.replace("\"beta\"", "\"beta_gone\"");
    std::fs::write(&path, &bad).unwrap();
    assert!(matches!(
        CoxModel::load(&path),
        Err(FastSurvivalError::Persist(_))
    ));

    // Corrupt the baseline ordering.
    let bad = good.replace("\"cumhaz\": [", "\"cumhaz\": [9999999,");
    std::fs::write(&path, &bad).unwrap();
    assert!(CoxModel::load(&path).is_err());

    // Missing file.
    assert!(matches!(
        CoxModel::load(std::path::Path::new("/no/such/model.json")),
        Err(FastSurvivalError::Io { .. })
    ));
}
