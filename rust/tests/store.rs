//! End-to-end out-of-core store properties:
//!
//! 1. CSV → `.fsds` → dataset equals the direct CSV load bitwise (in
//!    the engine's canonical sorted order).
//! 2. Truncated / corrupt store files surface as typed
//!    `FastSurvivalError::Store`; a missing path is a typed I/O error.
//! 3. The streamed fit agrees between the on-disk store and the
//!    in-memory reference source bit for bit, matches the classic
//!    in-memory surrogate CD optimum to ≤1e-8, and is bitwise identical
//!    across FASTSURVIVAL_THREADS ∈ {1, 2, 4}.

use fastsurvival::api::CoxFit;
use fastsurvival::cox::CoxProblem;
use fastsurvival::data::csv::load_survival_csv;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::error::FastSurvivalError;
use fastsurvival::optim::{Objective, OptimizerKind, SurrogateKind};
use fastsurvival::store::{
    convert_csv, reference_fit_kkt, write_store, ChunkedDataset, CoxData, DatasetRows,
    MemoryCoxData, StreamingFit,
};
use std::path::PathBuf;

fn temp_dir() -> PathBuf {
    let dir = std::env::temp_dir().join("fs_store_integration");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_ds(seed: u64) -> SurvivalDataset {
    generate(&SyntheticConfig { n: 260, p: 9, rho: 0.4, k: 3, s: 0.1, seed })
}

#[test]
fn csv_to_store_to_dataset_is_bitwise_round_trip() {
    // A CSV with awkward values: ties, negatives, long fractions.
    let mut csv = String::from("time,event,age,score\n");
    let rows = [
        (5.25, 1, 61.0, 0.123456789012345),
        (3.0, 0, 50.5, -2.75),
        (5.25, 1, 47.25, 1e-3),
        (0.5, 0, 39.0, 123456.789),
        (9.125, 1, 72.5, -0.0625),
    ];
    for (t, e, a, s) in rows {
        csv.push_str(&format!("{t},{e},{a},{s}\n"));
    }
    let dir = temp_dir();
    let csv_path = dir.join("roundtrip.csv");
    std::fs::write(&csv_path, &csv).unwrap();
    let store_path = dir.join("roundtrip.fsds");

    let direct = load_survival_csv(&csv_path, "roundtrip").unwrap();
    let summary = convert_csv(&csv_path, &store_path, 2, "roundtrip").unwrap();
    assert_eq!(summary.n, 5);
    assert_eq!(summary.p, 2);

    // The store is sorted; compare against the direct load run through
    // the same canonical sort (CoxProblem).
    let pr = CoxProblem::new(&direct);
    let mut store = ChunkedDataset::open(&store_path).unwrap();
    let back = store.to_dataset().unwrap();
    assert_eq!(back.x.data, pr.x.data, "feature bits must round-trip");
    assert_eq!(back.time, pr.time);
    let delta: Vec<f64> = back.event.iter().map(|&e| if e { 1.0 } else { 0.0 }).collect();
    assert_eq!(delta, pr.delta);
    assert_eq!(back.feature_names, direct.feature_names);
    // Derived per-column constants agree bitwise with the in-memory
    // problem's own.
    assert_eq!(store.meta().xt_delta, pr.xt_delta);
    assert_eq!(store.meta().col_binary, pr.col_binary);
}

#[test]
fn corrupt_store_files_yield_typed_errors() {
    let dir = temp_dir();
    let ds = small_ds(17);
    let store_path = dir.join("victim.fsds");
    let mut rows = DatasetRows::new(&ds);
    write_store(&mut rows, &store_path, 64, "victim").unwrap();
    let bytes = std::fs::read(&store_path).unwrap();

    // Truncation at several depths: header, meta, payload.
    for cut in [10, 40, bytes.len() / 2, bytes.len() - 3] {
        let path = dir.join(format!("cut{cut}.fsds"));
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = ChunkedDataset::open(&path).unwrap_err();
        assert!(
            matches!(err, FastSurvivalError::Store(_)),
            "cut at {cut}: expected Store error, got {err}"
        );
    }
    // Corrupt header field → checksum mismatch.
    let mut bad = bytes.clone();
    bad[17] ^= 0x02;
    let path = dir.join("badheader.fsds");
    std::fs::write(&path, &bad).unwrap();
    assert!(matches!(
        ChunkedDataset::open(&path),
        Err(FastSurvivalError::Store(_))
    ));
    // Missing file: typed Io error, message names the path.
    let missing = dir.join("no-such-store.fsds");
    let err = ChunkedDataset::open(&missing).unwrap_err();
    assert!(matches!(err, FastSurvivalError::Io { .. }));
    assert!(err.to_string().contains("no-such-store"));
    // fit --store's builder path reports the same typed error.
    let err = CoxFit::new().fit_store(&missing).unwrap_err();
    assert!(matches!(err, FastSurvivalError::Io { .. }));
}

/// The parity satellite. All FASTSURVIVAL_THREADS mutation for this test
/// binary lives in this one test (libtest runs tests concurrently;
/// results everywhere are thread-count independent by design, but the
/// env writes themselves must not race each other).
#[test]
fn chunked_vs_in_memory_fit_parity_across_thread_counts() {
    let dir = temp_dir();
    let ds = small_ds(29);
    let store_path = dir.join("parity.fsds");
    let chunk_rows = 48;
    let mut rows = DatasetRows::new(&ds);
    write_store(&mut rows, &store_path, chunk_rows, "parity").unwrap();

    let obj = Objective { l1: 0.0, l2: 1.0 };
    let fitter = StreamingFit {
        objective: obj,
        surrogate: SurrogateKind::Quadratic,
        max_sweeps: 10_000,
        tol: 0.0,
        stop_kkt: 1e-9,
        ..Default::default()
    };

    let saved = std::env::var("FASTSURVIVAL_THREADS").ok();
    let mut snapshots: Vec<Vec<f64>> = Vec::new();
    for threads in ["1", "2", "4"] {
        std::env::set_var("FASTSURVIVAL_THREADS", threads);
        let mut store = ChunkedDataset::open(&store_path).unwrap();
        let from_store = fitter.fit(&mut store).unwrap();
        let mut mem = MemoryCoxData::from_dataset(&ds, chunk_rows).unwrap();
        let from_mem = fitter.fit(&mut mem).unwrap();
        for (a, b) in from_store.beta.iter().zip(from_mem.beta.iter()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "threads={threads}: store-backed and memory-backed streamed fits \
                 must be bitwise identical ({a} vs {b})"
            );
        }
        snapshots.push(from_store.beta);
    }
    match saved {
        Some(v) => std::env::set_var("FASTSURVIVAL_THREADS", v),
        None => std::env::remove_var("FASTSURVIVAL_THREADS"),
    }
    for snap in &snapshots[1..] {
        for (a, b) in snapshots[0].iter().zip(snap.iter()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "streamed fit changed with FASTSURVIVAL_THREADS"
            );
        }
    }

    // And the streamed optimum matches the engine's classic in-memory
    // CD, driven to the same KKT residual, to ≤1e-8: both are within
    // √p·ε/μ ≈ 1.5e-9 of the λ₂=1 objective's unique optimum.
    let pr = CoxProblem::new(&ds);
    let classic = reference_fit_kkt(&pr, obj, SurrogateKind::Quadratic, 1e-9, 10_000);
    for (a, b) in snapshots[0].iter().zip(classic.iter()) {
        assert!(
            (a - b).abs() <= 1e-8,
            "streamed {a} vs classic {b} (|Δ| = {:.3e})",
            (a - b).abs()
        );
    }
}

#[test]
fn fit_store_through_the_builder_end_to_end() {
    let dir = temp_dir();
    let ds = small_ds(41);
    let store_path = dir.join("builder.fsds");
    let mut rows = DatasetRows::new(&ds);
    write_store(&mut rows, &store_path, 64, "builder").unwrap();

    let model = CoxFit::new()
        .l2(0.5)
        .optimizer(OptimizerKind::Quadratic)
        .max_iters(3000)
        .tol(1e-12)
        .fit_store(&store_path)
        .unwrap();
    let d = model.diagnostics();
    assert_eq!(d.engine, "chunked-store");
    assert_eq!(d.optimizer, "streaming-quadratic-surrogate");
    assert!(d.converged);
    assert_eq!(d.n_train, ds.n());
    assert_eq!(d.n_events, ds.n_events());

    // The builder is pure plumbing over StreamingFit: a hand-built
    // fitter with the mirrored configuration over the in-memory source
    // must reproduce the builder's coefficients bit for bit.
    let mirrored = StreamingFit {
        objective: Objective { l1: 0.0, l2: 0.5 },
        surrogate: SurrogateKind::Quadratic,
        max_sweeps: 3000,
        tol: 1e-12,
        ..Default::default()
    };
    let mut mem = MemoryCoxData::from_dataset(&ds, 64).unwrap();
    let manual = mirrored.fit(&mut mem).unwrap();
    for (a, b) in model.beta().iter().zip(manual.beta.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "builder plumbing changed the fit: {a} vs {b}");
    }
    // Sanity against the classic builder fit on the materialized data
    // (loss-tol stopping on both sides — coarse agreement only; the
    // ≤1e-8 gate lives with the KKT-stopped comparisons).
    let classic = CoxFit::new()
        .l2(0.5)
        .optimizer(OptimizerKind::Quadratic)
        .max_iters(3000)
        .tol(1e-12)
        .fit(&ds)
        .unwrap();
    for (a, b) in model.beta().iter().zip(classic.beta().iter()) {
        assert!((a - b).abs() <= 1e-3, "{a} vs {b}");
    }
    // The model predicts: informative concordance on the training data.
    let ci = model.concordance(&ds).unwrap();
    assert!(ci > 0.55, "cindex {ci}");

    // Arming the stop_kkt knob certifies ≤1e-8 against the KKT-stopped
    // classic in-memory CD (the loss-tol default only gives the coarse
    // agreement asserted above).
    let kkt_model = CoxFit::new()
        .l2(1.0)
        .optimizer(OptimizerKind::Quadratic)
        .max_iters(10_000)
        .tol(0.0)
        .stop_kkt(1e-9)
        .fit_store(&store_path)
        .unwrap();
    let pr = CoxProblem::new(&ds);
    let reference = reference_fit_kkt(
        &pr,
        Objective { l1: 0.0, l2: 1.0 },
        SurrogateKind::Quadratic,
        1e-9,
        10_000,
    );
    for (a, b) in kkt_model.beta().iter().zip(reference.iter()) {
        assert!((a - b).abs() <= 1e-8, "{a} vs {b}");
    }

    // Non-surrogate optimizers and non-native engines are rejected.
    assert!(matches!(
        CoxFit::new().optimizer(OptimizerKind::Newton).fit_store(&store_path),
        Err(FastSurvivalError::InvalidConfig(_))
    ));
    assert!(matches!(
        CoxFit::new()
            .engine(fastsurvival::api::EngineKind::Xla)
            .fit_store(&store_path),
        Err(FastSurvivalError::Unsupported(_))
    ));
}

#[test]
fn cubic_streamed_fit_matches_cubic_classic() {
    // The cubic surrogate streams too: KKT-stopped chunked fit over a
    // store vs the engine's KKT-stopped in-memory cubic CD, ≤1e-8.
    let dir = temp_dir();
    let ds = small_ds(53);
    let store_path = dir.join("cubic.fsds");
    let mut rows = DatasetRows::new(&ds);
    write_store(&mut rows, &store_path, 32, "cubic").unwrap();
    let obj = Objective { l1: 0.0, l2: 1.0 };
    let fitter = StreamingFit {
        objective: obj,
        surrogate: SurrogateKind::Cubic,
        max_sweeps: 10_000,
        tol: 0.0,
        stop_kkt: 1e-9,
        ..Default::default()
    };
    let mut store = ChunkedDataset::open(&store_path).unwrap();
    let streamed = fitter.fit(&mut store).unwrap();
    assert!(streamed.trace.converged);
    let pr = CoxProblem::new(&ds);
    let classic = reference_fit_kkt(&pr, obj, SurrogateKind::Cubic, 1e-9, 10_000);
    for (a, b) in streamed.beta.iter().zip(classic.iter()) {
        assert!((a - b).abs() <= 1e-8, "{a} vs {b}");
    }
}
