//! Path-solver correctness: warm-started endpoints match cold fits,
//! strong-rule screening + KKT repair never changes a solution, results
//! are bitwise identical across thread counts, and CV fold assignment is
//! deterministic.

use fastsurvival::api::{CoxFit, CoxPath, PathKind};
use fastsurvival::coordinator::cv::{cv_l1_path, SelectionCriterion};
use fastsurvival::cox::CoxProblem;
use fastsurvival::data::synthetic::{generate, SyntheticConfig};
use fastsurvival::data::SurvivalDataset;
use fastsurvival::linalg::Matrix;
use fastsurvival::path::{CardinalityPath, PathSolver};
use fastsurvival::select::Abess;
use fastsurvival::util::proptest::{check, gen};
use fastsurvival::util::rng::Rng;

fn random_problem(rng: &mut Rng, max_n: usize, p: usize) -> CoxProblem {
    let n = 30 + rng.below(max_n - 30);
    let cols: Vec<Vec<f64>> = (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let with_ties = rng.bernoulli(0.5);
    let time = gen::times(rng, n, with_ties);
    let event = gen::events(rng, n, 0.6);
    let ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "path-prop");
    CoxProblem::new(&ds)
}

/// Normalized loss gap used everywhere: |a − b| / (1 + |b|).
fn rel_gap(a: f64, b: f64) -> f64 {
    (a - b).abs() / (1.0 + b.abs())
}

/// Support at a small threshold — screened and unscreened solves sweep
/// coordinates in different orders, so a boundary coefficient may end as
/// an exact 0.0 in one and ~1e-14 in the other.
fn support_of(beta: &[f64]) -> Vec<usize> {
    beta.iter()
        .enumerate()
        .filter(|(_, b)| b.abs() > 1e-10)
        .map(|(i, _)| i)
        .collect()
}

#[test]
fn warm_path_endpoints_match_cold_fits_within_1e8() {
    let ds = generate(&SyntheticConfig { n: 200, p: 15, rho: 0.4, k: 3, s: 0.1, seed: 201 });
    let pr = CoxProblem::new(&ds);
    let warm = PathSolver { n_lambdas: 15, stop_rel: 1e-8, ..Default::default() };
    let grid = warm.lambda_grid(&pr).unwrap();
    let warm_path = warm.run_grid(&pr, &grid).unwrap();
    // Cold reference: every grid point solved independently from zeros
    // with no screening — the convex objective has one optimum, so the
    // losses must coincide.
    let cold = PathSolver { warm_start: false, screen: false, ..warm.clone() };
    let cold_path = cold.run_grid(&pr, &grid).unwrap();
    assert_eq!(warm_path.len(), cold_path.len());
    for (w, c) in warm_path.points.iter().zip(cold_path.points.iter()) {
        let gap = rel_gap(w.train_loss, c.train_loss);
        assert!(
            gap <= 1e-8,
            "λ={}: warm loss {} vs cold loss {} (gap {gap:.3e})",
            w.lambda,
            w.train_loss,
            c.train_loss
        );
        assert_eq!(
            support_of(&w.beta),
            support_of(&c.beta),
            "λ={}: warm and cold supports disagree",
            w.lambda
        );
    }
    // Warm starts + screening must actually save work on a 15-point
    // path: compare coordinate-visit counts (sweeps × candidate-set
    // size), the quantity the bench gate tracks as wall time.
    let work = |path: &fastsurvival::path::LambdaPath| -> usize {
        path.points.iter().map(|pt| pt.sweeps * pt.screened.max(1)).sum()
    };
    assert!(
        work(&warm_path) < work(&cold_path),
        "warm work {} vs cold {}",
        work(&warm_path),
        work(&cold_path)
    );
}

/// The satellite property: strong-rule screening plus the KKT check never
/// drops an active feature — screened and unscreened solves agree exactly
/// — across FASTSURVIVAL_THREADS ∈ {1, 2, 4}, with bitwise-identical
/// coefficients between thread counts. Fold-assignment determinism rides
/// in the same test because it is the only test that mutates the env var
/// (libtest runs tests concurrently; keeping all env writes here avoids
/// cross-test races).
#[test]
fn screening_kkt_and_fold_determinism_across_thread_counts() {
    let ds = generate(&SyntheticConfig { n: 120, p: 10, rho: 0.5, k: 3, s: 0.1, seed: 202 });
    let saved = std::env::var("FASTSURVIVAL_THREADS").ok();

    // Reference fold split and path betas, computed per thread count.
    let mut fold_snapshots: Vec<Vec<(Vec<usize>, Vec<usize>)>> = Vec::new();
    let mut beta_snapshots: Vec<Vec<Vec<f64>>> = Vec::new();
    for threads in ["1", "2", "4"] {
        std::env::set_var("FASTSURVIVAL_THREADS", threads);
        fold_snapshots.push(ds.kfold_seeded(4, 99));

        check(
            "strong-rule-kkt-never-drops-active",
            300 + threads.len() as u64,
            6,
            |r| r.next_u64(),
            |&seed| {
                let mut rng = Rng::new(seed);
                let pr = random_problem(&mut rng, 120, 8);
                let screened =
                    PathSolver { n_lambdas: 8, stop_rel: 1e-8, ..Default::default() };
                let grid = match screened.lambda_grid(&pr) {
                    Ok(g) => g,
                    // Degenerate draw (no usable signal): nothing to test.
                    Err(_) => return Ok(()),
                };
                let a = screened.run_grid(&pr, &grid).map_err(|e| e.to_string())?;
                let unscreened = PathSolver { screen: false, ..screened.clone() };
                let b = unscreened.run_grid(&pr, &grid).map_err(|e| e.to_string())?;
                for (pa, pb) in a.points.iter().zip(b.points.iter()) {
                    let (sa, sb) = (support_of(&pa.beta), support_of(&pb.beta));
                    if sa != sb {
                        return Err(format!(
                            "λ={}: screened support {sa:?} vs unscreened {sb:?}",
                            pa.lambda
                        ));
                    }
                    let gap = rel_gap(pa.train_loss, pb.train_loss);
                    if gap > 1e-8 {
                        return Err(format!(
                            "λ={}: screened loss {} vs unscreened {} (gap {gap:.3e})",
                            pa.lambda, pa.train_loss, pb.train_loss
                        ));
                    }
                }
                Ok(())
            },
        );

        // One fixed path whose coefficients must be bitwise identical for
        // every thread count.
        let pr = CoxProblem::new(&ds);
        let solver = PathSolver { n_lambdas: 10, ..Default::default() };
        let path = solver.run(&pr).unwrap();
        beta_snapshots.push(path.points.into_iter().map(|p| p.beta).collect());
    }
    match saved {
        Some(v) => std::env::set_var("FASTSURVIVAL_THREADS", v),
        None => std::env::remove_var("FASTSURVIVAL_THREADS"),
    }

    for snap in &fold_snapshots[1..] {
        assert_eq!(
            &fold_snapshots[0], snap,
            "fold assignment changed with FASTSURVIVAL_THREADS"
        );
    }
    for snap in &beta_snapshots[1..] {
        assert_eq!(beta_snapshots[0].len(), snap.len());
        for (a, b) in beta_snapshots[0].iter().zip(snap.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "path β not bitwise identical across thread counts"
                );
            }
        }
    }
}

#[test]
fn abess_warm_k_path_matches_cold_runs_on_easy_signal() {
    let ds = generate(&SyntheticConfig { n: 300, p: 20, rho: 0.2, k: 3, s: 0.1, seed: 203 });
    let pr = CoxProblem::new(&ds);
    let ab = Abess::default();
    let path = CardinalityPath::run_abess(&pr, 5, &ab);
    assert_eq!(path.len(), 5);
    // Up to the true signal size the warm-chained path and independent
    // cold solves must land on the same (planted) support, hence the
    // same restricted optimum.
    for k in 1..=3usize {
        let pt = path.point_for_k(k).expect("k-path point");
        let cold = ab.run_k(&pr, k);
        assert_eq!(
            pt.support, cold.support,
            "k={k}: warm-chained support diverged from cold"
        );
        assert!(
            rel_gap(pt.train_loss, cold.train_loss) <= 1e-6,
            "k={k}: warm loss {} vs cold {}",
            pt.train_loss,
            cold.train_loss
        );
    }
    // Past the signal size the extra features are noise and trajectories
    // may differ, but sizes are exact and the warm chain stays monotone.
    for (i, pt) in path.points.iter().enumerate() {
        assert_eq!(pt.k, i + 1);
    }
    for w in path.points.windows(2) {
        assert!(w[1].train_loss <= w[0].train_loss + 1e-6);
    }
}

#[test]
fn cox_path_json_round_trip_preserves_predictions() {
    let ds = generate(&SyntheticConfig { n: 150, p: 8, rho: 0.3, k: 2, s: 0.1, seed: 204 });
    let path = CoxFit::new().n_lambdas(8).l1_path(&ds).unwrap();
    assert_eq!(path.kind(), PathKind::L1);
    let file = std::env::temp_dir().join("fs_path_roundtrip_test.json");
    path.save(&file).unwrap();
    let loaded = CoxPath::load(&file).unwrap();
    assert_eq!(loaded.len(), path.len());
    for i in 0..path.len() {
        let a = path.model_at(i).unwrap();
        let b = loaded.model_at(i).unwrap();
        assert_eq!(a.beta(), b.beta(), "point {i} coefficients drifted");
        let ra = a.predict_risk(&ds.x).unwrap();
        let rb = b.predict_risk(&ds.x).unwrap();
        assert_eq!(ra, rb, "point {i} predictions drifted through JSON");
    }
}

#[test]
fn path_cv_prefers_an_informative_lambda() {
    let ds = generate(&SyntheticConfig { n: 240, p: 16, rho: 0.3, k: 4, s: 0.1, seed: 205 });
    let solver = PathSolver { n_lambdas: 12, ..Default::default() };
    let cv = cv_l1_path(&ds, &solver, 4, 3, SelectionCriterion::Deviance).unwrap();
    assert_eq!(cv.points.len(), 12);
    let best = cv.best();
    // The winner must beat both the null model and the λ_max endpoint.
    assert!(best.mean_test_deviance < 0.0, "best deviance {}", best.mean_test_deviance);
    assert!(
        best.mean_test_deviance <= cv.points[0].mean_test_deviance,
        "λ_max endpoint should not win CV on informative data"
    );
    assert!(best.mean_support > 0.0);
}

#[test]
fn cardinality_path_through_builder_queries_by_k() {
    let ds = generate(&SyntheticConfig { n: 200, p: 12, rho: 0.3, k: 3, s: 0.1, seed: 206 });
    let path = CoxFit::new().cardinality_path(&ds, 5).unwrap();
    assert_eq!(path.kind(), PathKind::Cardinality);
    let m3 = path.model_for_k(3).unwrap();
    assert_eq!(m3.beta().iter().filter(|b| b.abs() > 1e-10).count(), 3);
    assert!(m3.concordance(&ds).unwrap() > 0.55);
    // k-path points carry no λ.
    assert!(path.points().iter().all(|p| p.lambda.is_none()));
    assert!(path.lambdas().is_empty());
}
