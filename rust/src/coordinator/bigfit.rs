//! The `bigfit` CLI subcommand: the tracked out-of-core workload →
//! `BENCH_bigfit.json`, with three gates.
//!
//! The workload streams an n=1,000,000 × p=100 Appendix-C.2 synthetic
//! dataset into a `.fsds` store (never materializing the matrix), runs
//! the two-phase [`StreamingFit`], and records:
//!
//! - **memory gate** — the process peak RSS must stay below *half* the
//!   dataset's in-memory footprint (n·p·8 bytes). The store pipeline's
//!   resident state is O(n + chunk·p), so on the tracked shape it sits
//!   far below the bound; holding the matrix even once would trip it.
//! - **parity gate** — on small data, the same streamed algorithm run
//!   over the on-disk store and over the in-memory reference source must
//!   agree bit for bit, and the streamed optimum must match the classic
//!   in-memory surrogate CD fit to ≤1e-8.
//! - **shard gate** — the same workload written as a sharded store and
//!   fit by the parallel engine must be bitwise identical to the
//!   single-store fit (≤1e-8 under f32 storage) *and* at least 1.5×
//!   faster at `--shard-workers` (default 2) workers than the identical
//!   engine at 1 worker, timed in the same run on the same machine.
//!
//! `--quick` scales n down for the CI `bigfit-smoke` job; all gates are
//! enforced at every scale (nonzero exit on violation, JSON always
//! written first — it is the diagnostic).

use crate::api::json;
use crate::cox::CoxProblem;
use crate::data::synthetic::{generate, SyntheticConfig};
use crate::error::{FastSurvivalError, Result};
use crate::optim::{Objective, SurrogateKind};
use crate::store::{
    convert_synthetic_sharded, convert_synthetic_with, reference_fit_kkt, write_store_with,
    ChunkedDataset, CoxData, DatasetRows, MemoryCoxData, ShardedDataset, StreamingFit,
    DEFAULT_CHUNK_ROWS,
};
use crate::util::args::Args;
use crate::util::compute::{Compute, Precision};
use crate::util::mem::peak_rss_bytes;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Parity tolerance of the streamed optimum vs the classic in-memory fit
/// (the acceptance criterion's ≤1e-8).
const PARITY_TOL: f64 = 1e-8;
/// Cross-source (disk vs memory) tolerance. The two sources execute the
/// same instructions on the same bits, so the expected gap is exactly 0;
/// the gate leaves three orders of magnitude of headroom under the
/// classic-parity tolerance.
const CROSS_SOURCE_TOL: f64 = 1e-12;
/// Minimum sharded-engine speedup at the tracked worker count (the
/// timed fit at `--shard-workers`, default 2, vs the same engine at 1
/// worker — same run, same machine, mirroring the `simd_gate`
/// discipline).
const SHARD_SPEEDUP_MIN: f64 = 1.5;

/// The shard gate's evidence: exactness (sharded vs single-store fit)
/// and the parallel speedup, both measured in this run.
struct ShardReport {
    n_shards: usize,
    shard_workers: usize,
    fit_secs_workers_1: f64,
    fit_secs_workers_n: f64,
    speedup: f64,
    sharded_vs_single_max_abs: f64,
    bitwise_identical: bool,
    /// Under `--precision f32` the gate relaxes bitwise to ≤[`PARITY_TOL`].
    f32_storage: bool,
}

impl ShardReport {
    fn parity_ok(&self) -> bool {
        if self.f32_storage {
            self.sharded_vs_single_max_abs <= PARITY_TOL
        } else {
            self.bitwise_identical
        }
    }
    fn speedup_ok(&self) -> bool {
        self.speedup >= SHARD_SPEEDUP_MIN
    }
    fn ok(&self) -> bool {
        self.parity_ok() && self.speedup_ok()
    }
}

struct ParityReport {
    n: usize,
    p: usize,
    chunked_vs_memory_max_abs: f64,
    bitwise_identical: bool,
    vs_classic_max_abs: f64,
}

impl ParityReport {
    fn ok(&self) -> bool {
        self.chunked_vs_memory_max_abs <= CROSS_SOURCE_TOL
            && self.vs_classic_max_abs <= PARITY_TOL
    }
}

/// Small-data parity: the streamed fit over the on-disk store vs over
/// the in-memory reference source (bitwise expectation), and vs the
/// engine's classic in-memory CD — all three stopped on a KKT residual
/// of 1e-9, which pins each within √p·ε/μ ≈ 3e-9 of the unique optimum
/// of the λ₂=1 objective and so certifies the ≤1e-8 agreement (loss-
/// change stopping could not).
fn parity_gate(dir: &Path, compute: Compute) -> Result<ParityReport> {
    let (n, p, chunk_rows) = (2000, 40, 256);
    let obj = Objective { l1: 0.0, l2: 1.0 };
    let mut ds = generate(&SyntheticConfig { n, p, rho: 0.4, k: 5, s: 0.1, seed: 7 });
    // Under --precision f32 every source (store cells, memory source,
    // classic reference) must see the same f32-rounded values, so the
    // bitwise and 1e-8 gates keep measuring the pipeline, not the
    // quantization step.
    if compute.precision == Precision::F32Storage {
        ds.x.quantize_f32();
    }
    let store_path = dir.join("bigfit_parity.fsds");
    let mut rows = DatasetRows::new(&ds);
    write_store_with(&mut rows, &store_path, chunk_rows, "parity", compute.precision)?;

    let fitter = StreamingFit {
        objective: obj,
        surrogate: SurrogateKind::Quadratic,
        max_sweeps: 10_000,
        tol: 0.0,
        stop_kkt: 1e-9,
        compute,
        ..Default::default()
    };
    let mut chunked = ChunkedDataset::open(&store_path)?;
    let from_store = fitter.fit(&mut chunked)?;
    let mut mem = MemoryCoxData::from_dataset(&ds, chunk_rows)?;
    let from_mem = fitter.fit(&mut mem)?;

    let mut cross = 0.0_f64;
    let mut bitwise = true;
    for (a, b) in from_store.beta.iter().zip(from_mem.beta.iter()) {
        cross = cross.max((a - b).abs());
        if a.to_bits() != b.to_bits() {
            bitwise = false;
        }
    }

    let pr = CoxProblem::try_new(&ds)?;
    let classic = reference_fit_kkt(&pr, obj, SurrogateKind::Quadratic, 1e-9, 10_000);
    let mut vs_classic = 0.0_f64;
    for (a, b) in from_store.beta.iter().zip(classic.iter()) {
        vs_classic = vs_classic.max((a - b).abs());
    }

    let _ = std::fs::remove_file(&store_path);
    Ok(ParityReport {
        n,
        p,
        chunked_vs_memory_max_abs: cross,
        bitwise_identical: bitwise,
        vs_classic_max_abs: vs_classic,
    })
}

/// The shard gate: write the tracked workload as a sharded store, fit
/// it with the parallel engine at 1 worker and at `shard_workers`
/// workers, and compare both against the single-store fit of the same
/// configuration. All three fits skip the (serial, shared) warmup so
/// the timed phase is exactly the distributed exact CD the gate is
/// about; exactness is unaffected (all three start from β = 0).
#[allow(clippy::too_many_arguments)]
fn shard_gate(
    cfg: &SyntheticConfig,
    sharded_path: &Path,
    chunk_rows: usize,
    base: &StreamingFit,
    compute: Compute,
    single: &mut ChunkedDataset,
    shards: usize,
    shard_workers: usize,
    keep: bool,
) -> Result<ShardReport> {
    let fitter = StreamingFit { sgd_blocks: Some(0), ..base.clone() };
    let summary =
        convert_synthetic_sharded(cfg, sharded_path, chunk_rows, compute.precision, shards)?;
    println!(
        "bigfit: sharded store — {} shard(s), generation {}, {:.1} MB",
        summary.n_shards,
        summary.generation,
        summary.bytes as f64 / 1e6
    );
    let single_ref = fitter.fit(single)?;
    let mut sharded = ShardedDataset::open(sharded_path)?;
    let t = Instant::now();
    let r1 = fitter.fit_sharded(&mut sharded, 1)?;
    let fit_secs_workers_1 = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let rn = fitter.fit_sharded(&mut sharded, shard_workers)?;
    let fit_secs_workers_n = t.elapsed().as_secs_f64();

    let mut max_abs = 0.0_f64;
    let mut bitwise = true;
    for res in [&r1, &rn] {
        for (a, b) in res.beta.iter().zip(single_ref.beta.iter()) {
            max_abs = max_abs.max((a - b).abs());
            if a.to_bits() != b.to_bits() {
                bitwise = false;
            }
        }
    }

    if !keep {
        if let Some(parent) = summary.manifest_path.parent() {
            for e in &sharded.manifest().shards {
                let _ = std::fs::remove_file(parent.join(&e.file));
            }
        }
        let _ = std::fs::remove_file(&summary.manifest_path);
    } else {
        println!(
            "bigfit: kept sharded store at {}",
            summary.manifest_path.display()
        );
    }
    let speedup = if fit_secs_workers_n > 0.0 {
        fit_secs_workers_1 / fit_secs_workers_n
    } else {
        f64::INFINITY
    };
    Ok(ShardReport {
        n_shards: summary.n_shards,
        shard_workers,
        fit_secs_workers_1,
        fit_secs_workers_n,
        speedup,
        sharded_vs_single_max_abs: max_abs,
        bitwise_identical: bitwise,
        f32_storage: compute.precision == Precision::F32Storage,
    })
}

#[allow(clippy::too_many_arguments)]
fn render_json(
    quick: bool,
    cfg: &SyntheticConfig,
    chunk_rows: usize,
    store_bytes: u64,
    dataset_bytes: u64,
    rss_bound: u64,
    peak_rss: Option<u64>,
    rss_ok: bool,
    convert_secs: f64,
    fit_secs: f64,
    sweeps: usize,
    sgd_steps: usize,
    converged: bool,
    objective_value: f64,
    parity: &ParityReport,
    shard: &ShardReport,
    passed: bool,
) -> String {
    let mut out = String::with_capacity(2048);
    out.push_str("{\n");
    out.push_str("  \"schema_version\": 1,\n");
    out.push_str("  \"suite\": \"fastsurvival-bigfit\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!(
        "  \"workload\": {{\"n\": {}, \"p\": {}, \"chunk_rows\": {chunk_rows}, \
         \"rho\": {}, \"true_k\": {}, \"seed\": {}}},\n",
        cfg.n, cfg.p, cfg.rho, cfg.k, cfg.seed
    ));
    out.push_str(&format!("  \"dataset_bytes_in_memory\": {dataset_bytes},\n"));
    out.push_str(&format!("  \"store_bytes\": {store_bytes},\n"));
    out.push_str("  \"memory_gate\": {\n");
    out.push_str(&format!("    \"bound_bytes\": {rss_bound},\n"));
    match peak_rss {
        Some(b) => out.push_str(&format!("    \"peak_rss_bytes\": {b},\n")),
        None => out.push_str("    \"peak_rss_bytes\": null,\n"),
    }
    out.push_str(&format!("    \"measured\": {},\n", peak_rss.is_some()));
    out.push_str(&format!("    \"passed\": {rss_ok}\n  }},\n"));
    out.push_str("  \"timings\": {\"convert_secs\": ");
    json::write_f64(&mut out, convert_secs);
    out.push_str(", \"fit_secs\": ");
    json::write_f64(&mut out, fit_secs);
    out.push_str("},\n");
    out.push_str(&format!(
        "  \"fit\": {{\"sweeps\": {sweeps}, \"sgd_steps\": {sgd_steps}, \
         \"converged\": {converged}, \"objective_value\": "
    ));
    json::write_f64(&mut out, objective_value);
    out.push_str("},\n");
    out.push_str("  \"parity_gate\": {\n");
    out.push_str(&format!(
        "    \"n\": {}, \"p\": {},\n",
        parity.n, parity.p
    ));
    out.push_str("    \"chunked_vs_memory_max_abs\": ");
    json::write_f64(&mut out, parity.chunked_vs_memory_max_abs);
    out.push_str(&format!(
        ",\n    \"bitwise_identical\": {},\n",
        parity.bitwise_identical
    ));
    out.push_str("    \"cross_source_tol\": ");
    json::write_f64(&mut out, CROSS_SOURCE_TOL);
    out.push_str(",\n    \"vs_classic_max_abs\": ");
    json::write_f64(&mut out, parity.vs_classic_max_abs);
    out.push_str(",\n    \"tol\": ");
    json::write_f64(&mut out, PARITY_TOL);
    out.push_str(&format!(",\n    \"passed\": {}\n  }},\n", parity.ok()));
    out.push_str("  \"shard_gate\": {\n");
    out.push_str(&format!(
        "    \"n_shards\": {}, \"shard_workers\": {},\n",
        shard.n_shards, shard.shard_workers
    ));
    out.push_str("    \"fit_secs_workers_1\": ");
    json::write_f64(&mut out, shard.fit_secs_workers_1);
    out.push_str(",\n    \"fit_secs_workers_n\": ");
    json::write_f64(&mut out, shard.fit_secs_workers_n);
    out.push_str(",\n    \"speedup\": ");
    json::write_f64(&mut out, shard.speedup);
    out.push_str(",\n    \"min_speedup\": ");
    json::write_f64(&mut out, SHARD_SPEEDUP_MIN);
    out.push_str(",\n    \"sharded_vs_single_max_abs\": ");
    json::write_f64(&mut out, shard.sharded_vs_single_max_abs);
    out.push_str(&format!(
        ",\n    \"bitwise_identical\": {},\n    \"f32_storage\": {},\n",
        shard.bitwise_identical, shard.f32_storage
    ));
    out.push_str(&format!(
        "    \"parity_passed\": {}, \"speedup_passed\": {},\n",
        shard.parity_ok(),
        shard.speedup_ok()
    ));
    out.push_str(&format!("    \"passed\": {}\n  }},\n", shard.ok()));
    out.push_str(&format!("  \"passed\": {passed}\n}}\n"));
    out
}

/// Entry point for the `bigfit` subcommand.
pub fn run(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let n = args.get_or("n", if quick { 250_000 } else { 1_000_000 });
    let p = args.get_or("p", 100);
    // Smaller chunks at smoke scale: the gate budget (half the dataset)
    // shrinks with n while the chunk buffers would not.
    let chunk_rows =
        args.get_or("chunk-rows", if quick { 4096 } else { DEFAULT_CHUNK_ROWS });
    let out_path = args.str_or("out", "BENCH_bigfit.json");
    let keep = args.flag("keep");
    // One compute request (--backend/--threads/--precision/--block-rows)
    // shared by the parity gate and the tracked workload; resolved by
    // each StreamingFit exactly once.
    let compute = Compute::from_args(args)?;
    let dir = match args.get("dir") {
        Some(d) => PathBuf::from(d),
        None => std::env::temp_dir().join("fastsurvival_bigfit"),
    };
    std::fs::create_dir_all(&dir)
        .map_err(|e| FastSurvivalError::io(format!("creating {}", dir.display()), e))?;

    // Parity gate first: cheap, and a broken kernel should fail fast.
    println!("bigfit: parity gate (n=2000, p=40, chunked vs memory vs classic)...");
    let parity = parity_gate(&dir, compute)?;
    println!(
        "bigfit: parity chunked-vs-memory max|Δβ| = {:.3e} (bitwise: {}), \
         vs classic = {:.3e}",
        parity.chunked_vs_memory_max_abs, parity.bitwise_identical, parity.vs_classic_max_abs
    );

    // Streamed conversion: the matrix exists only as chunks on disk.
    let cfg = SyntheticConfig { n, p, rho: 0.2, k: 10.min(p), s: 0.1, seed: 42 };
    let store_path = dir.join(format!("bigfit_n{n}_p{p}.fsds"));
    let t0 = Instant::now();
    let summary = convert_synthetic_with(&cfg, &store_path, chunk_rows, compute.precision)?;
    let convert_secs = t0.elapsed().as_secs_f64();
    println!(
        "bigfit: streamed {}x{} store ({} chunks, {:.1} MB) in {:.1}s",
        summary.n,
        summary.p,
        summary.n_chunks,
        summary.bytes as f64 / 1e6,
        convert_secs
    );

    // Streamed fit.
    let mut store = ChunkedDataset::open(&store_path)?;
    let fitter = StreamingFit {
        objective: Objective { l1: 0.0, l2: args.get_or("l2", 1.0) },
        surrogate: SurrogateKind::Quadratic,
        max_sweeps: args.get_or("sweeps", 6),
        tol: args.get_or("tol", 1e-7),
        compute,
        ..Default::default()
    };
    let t1 = Instant::now();
    let res = fitter.fit(&mut store)?;
    let fit_secs = t1.elapsed().as_secs_f64();
    let dataset_bytes = store.meta().matrix_bytes();
    println!(
        "bigfit: fit in {:.1}s ({} warmup blocks, {} exact sweeps, objective {:.4}, \
         converged={})",
        fit_secs, res.sgd_steps, res.sweeps, res.objective_value, res.trace.converged
    );

    // Shard gate: same workload through the sharded parallel engine,
    // exactness vs the single-store fit plus the 1-vs-N-worker speedup.
    // Runs before the RSS read so the memory gate covers it too.
    let shards = args.get_or("shards", 2usize);
    let shard_workers = args.get_or("shard-workers", 2usize);
    println!(
        "bigfit: shard gate ({shards} shard(s), {shard_workers} vs 1 worker(s), \
         no-warmup exact fits)..."
    );
    let sharded_path = dir.join(format!("bigfit_sharded_n{n}_p{p}.fsds"));
    let shard = shard_gate(
        &cfg,
        &sharded_path,
        chunk_rows,
        &fitter,
        compute,
        &mut store,
        shards,
        shard_workers,
        keep,
    )?;
    println!(
        "bigfit: sharded fit {:.1}s at 1 worker -> {:.1}s at {} workers \
         ({:.2}x, need >={SHARD_SPEEDUP_MIN}x); vs single max|Δβ| = {:.3e} (bitwise: {})",
        shard.fit_secs_workers_1,
        shard.fit_secs_workers_n,
        shard.shard_workers,
        shard.speedup,
        shard.sharded_vs_single_max_abs,
        shard.bitwise_identical
    );

    // Memory gate.
    let rss_bound = dataset_bytes / 2;
    let peak_rss = peak_rss_bytes();
    let rss_ok = peak_rss.map_or(true, |b| b < rss_bound);
    match peak_rss {
        Some(b) => println!(
            "bigfit: peak RSS {:.1} MB vs bound {:.1} MB (dataset would be {:.1} MB in \
             memory) — {}",
            b as f64 / 1e6,
            rss_bound as f64 / 1e6,
            dataset_bytes as f64 / 1e6,
            if rss_ok { "OK" } else { "EXCEEDED" }
        ),
        None => println!("bigfit: peak RSS unavailable on this platform — memory gate skipped"),
    }

    let passed = rss_ok && parity.ok() && shard.ok();
    let doc = render_json(
        quick,
        &cfg,
        chunk_rows,
        summary.bytes,
        dataset_bytes,
        rss_bound,
        peak_rss,
        rss_ok,
        convert_secs,
        fit_secs,
        res.sweeps,
        res.sgd_steps,
        res.trace.converged,
        res.objective_value,
        &parity,
        &shard,
        passed,
    );
    std::fs::write(&out_path, &doc)
        .map_err(|e| FastSurvivalError::io(format!("writing {out_path}"), e))?;
    println!("bigfit: wrote {out_path}");

    if !keep {
        let _ = std::fs::remove_file(&store_path);
    } else {
        println!("bigfit: kept store at {}", store_path.display());
    }

    if !passed {
        let mut why = Vec::new();
        if !rss_ok {
            why.push(format!(
                "peak RSS {} exceeded bound {} (half the in-memory dataset)",
                peak_rss.unwrap_or(0),
                rss_bound
            ));
        }
        if parity.chunked_vs_memory_max_abs > CROSS_SOURCE_TOL {
            why.push(format!(
                "chunked vs in-memory streamed fits diverged: max|Δβ| = {:.3e}",
                parity.chunked_vs_memory_max_abs
            ));
        }
        if parity.vs_classic_max_abs > PARITY_TOL {
            why.push(format!(
                "streamed fit off the classic optimum: max|Δβ| = {:.3e} > {PARITY_TOL:.0e}",
                parity.vs_classic_max_abs
            ));
        }
        if !shard.parity_ok() {
            why.push(format!(
                "sharded fit diverged from the single-store fit: max|Δβ| = {:.3e} \
                 (bitwise: {})",
                shard.sharded_vs_single_max_abs, shard.bitwise_identical
            ));
        }
        if !shard.speedup_ok() {
            why.push(format!(
                "sharded speedup {:.2}x at {} workers below the {SHARD_SPEEDUP_MIN}x floor",
                shard.speedup, shard.shard_workers
            ));
        }
        return Err(FastSurvivalError::PerfRegression(format!(
            "bigfit gate failed: {}",
            why.join("; ")
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_shard_report() -> ShardReport {
        ShardReport {
            n_shards: 2,
            shard_workers: 2,
            fit_secs_workers_1: 4.0,
            fit_secs_workers_n: 2.0,
            speedup: 2.0,
            sharded_vs_single_max_abs: 0.0,
            bitwise_identical: true,
            f32_storage: false,
        }
    }

    #[test]
    fn json_document_parses_and_carries_gates() {
        let parity = ParityReport {
            n: 2000,
            p: 40,
            chunked_vs_memory_max_abs: 0.0,
            bitwise_identical: true,
            vs_classic_max_abs: 3.2e-10,
        };
        assert!(parity.ok());
        let shard = sample_shard_report();
        assert!(shard.ok());
        let cfg = SyntheticConfig { n: 1000, p: 10, rho: 0.2, k: 3, s: 0.1, seed: 42 };
        let doc = render_json(
            true, &cfg, 128, 80_000, 80_000, 40_000, Some(30_000), true, 1.5, 2.5, 6, 8,
            true, 123.4, &parity, &shard, true,
        );
        let parsed = json::parse(&doc).unwrap();
        assert!(parsed.get("passed").unwrap().as_bool().unwrap());
        let mem = parsed.get("memory_gate").unwrap();
        assert_eq!(mem.get("bound_bytes").unwrap().as_usize().unwrap(), 40_000);
        assert!(mem.get("passed").unwrap().as_bool().unwrap());
        let pg = parsed.get("parity_gate").unwrap();
        assert!(pg.get("bitwise_identical").unwrap().as_bool().unwrap());
        assert!(pg.get("passed").unwrap().as_bool().unwrap());
        let sg = parsed.get("shard_gate").unwrap();
        assert_eq!(sg.get("n_shards").unwrap().as_usize().unwrap(), 2);
        assert_eq!(sg.get("shard_workers").unwrap().as_usize().unwrap(), 2);
        assert!((sg.get("speedup").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
        assert!(
            (sg.get("min_speedup").unwrap().as_f64().unwrap() - SHARD_SPEEDUP_MIN).abs()
                < 1e-12
        );
        assert!(sg.get("bitwise_identical").unwrap().as_bool().unwrap());
        assert!(sg.get("passed").unwrap().as_bool().unwrap());
        // An exceeded bound flips both gate and top-level verdicts.
        let doc = render_json(
            true, &cfg, 128, 80_000, 80_000, 40_000, Some(50_000), false, 1.5, 2.5, 6, 8,
            true, 123.4, &parity, &shard, false,
        );
        let parsed = json::parse(&doc).unwrap();
        assert!(!parsed.get("passed").unwrap().as_bool().unwrap());
        assert!(!parsed.get("memory_gate").unwrap().get("passed").unwrap().as_bool().unwrap());
    }

    #[test]
    fn shard_report_gates_each_axis() {
        let mut s = sample_shard_report();
        assert!(s.ok());
        // A sub-floor speedup fails even with perfect parity.
        s.speedup = 1.2;
        assert!(!s.ok() && s.parity_ok());
        s.speedup = 2.0;
        // f64 storage demands bitwise identity, not just ≤1e-8.
        s.bitwise_identical = false;
        s.sharded_vs_single_max_abs = 1e-12;
        assert!(!s.parity_ok());
        // f32 storage relaxes the gate to the ≤1e-8 tolerance.
        s.f32_storage = true;
        assert!(s.parity_ok() && s.ok());
        s.sharded_vs_single_max_abs = 1e-6;
        assert!(!s.parity_ok());
    }

    #[test]
    fn parity_report_gates_each_axis() {
        let mut r = ParityReport {
            n: 1,
            p: 1,
            chunked_vs_memory_max_abs: 0.0,
            bitwise_identical: true,
            vs_classic_max_abs: 0.0,
        };
        assert!(r.ok());
        r.vs_classic_max_abs = 1e-6;
        assert!(!r.ok());
        r.vs_classic_max_abs = 0.0;
        r.chunked_vs_memory_max_abs = 1e-9;
        assert!(!r.ok());
    }
}
