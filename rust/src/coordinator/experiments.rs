//! The experiment harness: regenerates every table and figure of the
//! paper (see DESIGN.md "Experiment index"). Each regenerator writes CSV
//! series plus a rendered text table under `results/`.
//!
//! Figure ids:
//! * `table1`           — dataset summary
//! * `fig1`             — Flchain efficiency (λ2=1 and λ1=1,λ2=5)
//! * `fig2`             — synthetic variable selection (F1), 3 sizes
//! * `fig3`             — EmployeeAttrition: support vs CIndex/IBS (Cox)
//! * `fig4`             — Dialysis: vs other model classes
//! * `fig5`..`fig20`    — optimization grids on the four datasets
//! * `fig21`..`fig35`   — 5-fold CV suites (Dialysis / Attrition / Kickstarter)
//! * `all`              — everything
//!
//! Full-paper scale is expensive; `--scale` shrinks n and `--quantiles`
//! controls the binarized width so CI-sized runs finish in minutes. The
//! qualitative shapes (blow-ups, monotonicity, sparsity frontiers) are
//! scale-stable.

use crate::baselines::forest::{ForestConfig, RandomSurvivalForest};
use crate::baselines::gbst::{GbstConfig, GradientBoostedCox};
use crate::baselines::svm::{FastSurvivalSvm, NaiveSurvivalSvm, SvmConfig};
use crate::baselines::tree::{SurvivalTree, TreeConfig};
use crate::baselines::SurvivalModel;
use crate::coordinator::cv::{cv_model, cv_selector, CvRow};
use crate::cox::CoxProblem;
use crate::data::binarize::{binarize, BinarizeConfig};
use crate::data::synthetic::{fig2_config, generate};
use crate::data::{datasets, SurvivalDataset};
use crate::optim::{self, FitConfig, Objective, Optimizer};
use crate::select::{Abess, AdaptiveLasso, BeamSearch, CoxnetPath, VariableSelector};
use crate::util::table::{fnum, Table};
use crate::error::{FastSurvivalError, Result};
use std::path::PathBuf;

/// Harness configuration (CLI-settable).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Sample-size multiplier on the Table-1 sizes (1.0 = paper scale).
    pub scale: f64,
    /// Quantile cutpoints per continuous column (paper: 1000).
    pub quantiles: usize,
    /// CV folds (paper: 5).
    pub folds: usize,
    /// Support sizes for the selection experiments (paper: 1..=30).
    pub ks: Vec<usize>,
    /// Outer iterations for the optimization figures.
    pub optim_iters: usize,
    pub seed: u64,
    pub out_dir: PathBuf,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            scale: 0.25,
            quantiles: 25,
            folds: 5,
            ks: (1..=10).collect(),
            optim_iters: 40,
            seed: 0,
            out_dir: PathBuf::from("results"),
        }
    }
}

impl ExperimentConfig {
    fn dataset(&self, name: &str) -> SurvivalDataset {
        let mut spec = datasets::spec(name);
        spec.n = ((spec.n as f64 * self.scale) as usize).max(200);
        let raw = datasets::generate_stand_in(&spec, self.seed);
        binarize(&raw, &BinarizeConfig { max_quantiles: self.quantiles, ..Default::default() })
    }

    fn write(&self, file: &str, table: &Table) -> Result<()> {
        let path = self.out_dir.join(file);
        table
            .write_csv(&path)
            .map_err(|e| FastSurvivalError::io(format!("writing {path:?}"), e))?;
        println!("{}", table.render());
        println!("wrote {}", path.display());
        Ok(())
    }
}

/// Entry point: run one experiment id (or `all`).
pub fn run(id: &str, cfg: &ExperimentConfig) -> Result<()> {
    match id {
        "table1" => table1(cfg),
        "fig1" => {
            optim_figure("fig1a", "flchain", 0.0, 1.0, cfg)?;
            optim_figure("fig1b", "flchain", 1.0, 5.0, cfg)
        }
        "fig2" => fig2(cfg),
        "fig3" => cv_suite("employee_attrition", "fig3", true, false, cfg),
        "fig4" => cv_suite("dialysis", "fig4", false, true, cfg),
        id if id.starts_with("fig") => {
            let num: usize = id[3..].parse().map_err(|_| FastSurvivalError::Unknown {
                kind: "experiment",
                name: id.to_string(),
                expected: "table1|fig1..fig35|all",
            })?;
            match num {
                5..=8 => grid_figure(num, 5, "flchain", cfg),
                9..=12 => grid_figure(num, 9, "employee_attrition", cfg),
                13..=16 => grid_figure(num, 13, "kickstarter1", cfg),
                17..=20 => grid_figure(num, 17, "dialysis", cfg),
                21..=25 => cv_suite("dialysis", id, true, true, cfg),
                26..=30 => cv_suite("employee_attrition", id, true, true, cfg),
                31..=35 => cv_suite("kickstarter1", id, true, true, cfg),
                _ => {
                    return Err(FastSurvivalError::Unknown {
                        kind: "experiment",
                        name: id.to_string(),
                        expected: "table1|fig1..fig35|all",
                    })
                }
            }
        }
        "all" => {
            table1(cfg)?;
            run("fig1", cfg)?;
            fig2(cfg)?;
            for f in [5, 9, 13, 17] {
                grid_figure(f, f, datasets_for_grid(f), cfg)?;
                grid_figure(f + 1, f, datasets_for_grid(f), cfg)?;
                grid_figure(f + 2, f, datasets_for_grid(f), cfg)?;
                grid_figure(f + 3, f, datasets_for_grid(f), cfg)?;
            }
            cv_suite("dialysis", "fig21-25", true, true, cfg)?;
            cv_suite("employee_attrition", "fig26-30", true, true, cfg)?;
            cv_suite("kickstarter1", "fig31-35", true, true, cfg)?;
            Ok(())
        }
        other => Err(FastSurvivalError::Unknown {
            kind: "experiment",
            name: other.to_string(),
            expected: "table1|fig1..fig35|all",
        }),
    }
}

fn datasets_for_grid(base: usize) -> &'static str {
    match base {
        5 => "flchain",
        9 => "employee_attrition",
        13 => "kickstarter1",
        17 => "dialysis",
        _ => unreachable!(),
    }
}

/// Table 1: dataset summary.
fn table1(cfg: &ExperimentConfig) -> Result<()> {
    let mut t = Table::new(
        "Table 1: datasets (stand-ins at --scale unless a real CSV is present)",
        &["dataset", "samples", "raw features", "encoded binary features", "censoring"],
    );
    for name in datasets::REAL_DATASETS {
        let mut spec = datasets::spec(name);
        spec.n = ((spec.n as f64 * cfg.scale) as usize).max(200);
        let raw = datasets::generate_stand_in(&spec, cfg.seed);
        let bin = binarize(
            &raw,
            &BinarizeConfig { max_quantiles: cfg.quantiles, ..Default::default() },
        );
        t.row(vec![
            name.to_string(),
            raw.n().to_string(),
            raw.p().to_string(),
            bin.p().to_string(),
            format!("{:.2}", raw.censoring_rate()),
        ]);
    }
    for idx in 1..=3 {
        let c = fig2_config(idx, cfg.seed);
        let n = ((c.n as f64 * cfg.scale.max(0.5)) as usize).max(200);
        t.row(vec![
            format!("SyntheticHighCorrHighDim{idx}"),
            n.to_string(),
            n.to_string(),
            "N/A".to_string(),
            "-".to_string(),
        ]);
    }
    cfg.write("table1.csv", &t)
}

/// One optimization-efficiency figure: loss vs iteration and wall clock
/// for every method on one (λ1, λ2) configuration.
pub fn optim_figure(
    out_name: &str,
    dataset: &str,
    l1: f64,
    l2: f64,
    cfg: &ExperimentConfig,
) -> Result<()> {
    let ds = cfg.dataset(dataset);
    let pr = CoxProblem::new(&ds);
    println!(
        "== {out_name}: {dataset} n={} p={} λ1={l1} λ2={l2} ==",
        ds.n(),
        ds.p()
    );
    let methods: Vec<&str> = if l1 == 0.0 {
        vec!["quadratic", "cubic", "newton", "quasi-newton", "prox-newton", "gd"]
    } else {
        // Exact Newton cannot handle ℓ1 (paper).
        vec!["quadratic", "cubic", "quasi-newton", "prox-newton", "gd"]
    };
    let fit_cfg = FitConfig {
        objective: Objective { l1, l2 },
        max_iters: cfg.optim_iters,
        tol: 1e-12,
        budget_secs: 60.0,
        record_trace: true,
        ..Default::default()
    };

    let mut curve = Table::new(
        &format!("{out_name}: loss vs iteration / time"),
        &["method", "iter", "secs", "loss"],
    );
    let mut summary = Table::new(
        &format!("{out_name}: summary"),
        &["method", "final loss", "iters", "monotone", "diverged"],
    );
    for m in methods {
        let opt = optim::by_name(m)?;
        let res = opt.fit(&pr, &fit_cfg)?;
        for p in &res.trace.points {
            curve.row(vec![
                opt.name().to_string(),
                p.iter.to_string(),
                format!("{:.6}", p.secs),
                fnum(p.loss),
            ]);
        }
        summary.row(vec![
            opt.name().to_string(),
            fnum(res.objective_value),
            res.iterations.to_string(),
            res.trace.monotone(1e-8).to_string(),
            res.trace.diverged.to_string(),
        ]);
    }
    cfg.write(&format!("{out_name}_curves.csv", ), &curve)?;
    cfg.write(&format!("{out_name}_summary.csv"), &summary)
}

/// Appendix grid figures: one (dataset, λ-config) cell each.
fn grid_figure(num: usize, base: usize, dataset: &str, cfg: &ExperimentConfig) -> Result<()> {
    let (l1, l2) = match num - base {
        0 => (0.0, 1.0),
        1 => (0.0, 5.0),
        2 => (1.0, 1.0),
        3 => (1.0, 5.0),
        _ => unreachable!(),
    };
    optim_figure(&format!("fig{num}"), dataset, l1, l2, cfg)
}

/// Figure 2: synthetic high-correlation variable selection, F1 vs k.
fn fig2(cfg: &ExperimentConfig) -> Result<()> {
    let mut t = Table::new(
        "Figure 2: support size vs F1 (synthetic, rho=0.9, true k=15)",
        &["dataset", "method", "k", "f1_mean", "f1_std"],
    );
    // The planted support size is 15: make sure the sweep reaches it
    // even when the CLI `--ks` default tops out lower.
    let mut ks = cfg.ks.clone();
    for k in [12usize, 15] {
        if !ks.contains(&k) {
            ks.push(k);
        }
    }
    ks.sort_unstable();
    for idx in 1..=3usize {
        let mut c = fig2_config(idx, cfg.seed);
        // Scaling keeps n>=p informative; paper sizes at scale>=1.
        c.n = ((c.n as f64 * cfg.scale.max(0.5)) as usize).max(200);
        c.p = c.n;
        let ds = generate(&c);
        println!("== fig2 synthetic{idx}: n={} p={} ==", ds.n(), ds.p());
        let selectors: Vec<Box<dyn VariableSelector>> = vec![
            Box::new(BeamSearch { width: 5, screen: 15, ..Default::default() }),
            Box::new(Abess::default()),
            Box::new(CoxnetPath::default()),
            Box::new(AdaptiveLasso::default()),
        ];
        for sel in &selectors {
            let rows = cv_selector(&ds, sel.as_ref(), &ks, cfg.folds, cfg.seed);
            aggregate_f1(&mut t, &format!("synthetic{idx}"), &rows);
        }
    }
    cfg.write("fig2_f1.csv", &t)
}

fn aggregate_f1(t: &mut Table, dataset: &str, rows: &[CvRow]) {
    use std::collections::BTreeMap;
    let mut by_k: BTreeMap<(String, usize), Vec<f64>> = BTreeMap::new();
    for r in rows {
        if let Some(f1) = r.f1 {
            by_k.entry((r.method.clone(), r.k)).or_default().push(f1);
        }
    }
    for ((method, k), f1s) in by_k {
        let n = f1s.len() as f64;
        let mean = f1s.iter().sum::<f64>() / n;
        let var = f1s.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        t.row(vec![
            dataset.to_string(),
            method,
            k.to_string(),
            fnum(mean),
            fnum(var.sqrt()),
        ]);
    }
}

/// CV suite: Cox-based selectors and/or other model classes on one
/// dataset; emits per-fold rows with every metric (the data behind
/// Figures 3, 4, and 21–35).
fn cv_suite(
    dataset: &str,
    out_name: &str,
    cox_methods: bool,
    model_classes: bool,
    cfg: &ExperimentConfig,
) -> Result<()> {
    let ds = cfg.dataset(dataset);
    println!("== {out_name}: {dataset} n={} p={} ==", ds.n(), ds.p());
    let mut rows: Vec<CvRow> = Vec::new();

    if cox_methods {
        let selectors: Vec<Box<dyn VariableSelector>> = vec![
            Box::new(BeamSearch { width: 5, screen: 15, ..Default::default() }),
            Box::new(Abess::default()),
            Box::new(CoxnetPath::default()),
            Box::new(AdaptiveLasso::default()),
        ];
        for sel in &selectors {
            rows.extend(cv_selector(&ds, sel.as_ref(), &cfg.ks, cfg.folds, cfg.seed));
        }
    }
    if model_classes {
        type FitFn = Box<dyn Fn(&SurvivalDataset) -> Box<dyn SurvivalModel> + Sync>;
        let fits: Vec<(&str, FitFn)> = vec![
            (
                "survival-tree",
                Box::new(|tr: &SurvivalDataset| {
                    Box::new(SurvivalTree::fit(tr, &TreeConfig { max_depth: 4, ..Default::default() }))
                        as Box<dyn SurvivalModel>
                }),
            ),
            (
                "random-survival-forest",
                Box::new(|tr: &SurvivalDataset| {
                    Box::new(RandomSurvivalForest::fit(
                        tr,
                        &ForestConfig { n_trees: 30, ..Default::default() },
                    )) as Box<dyn SurvivalModel>
                }),
            ),
            (
                "gradient-boosted-cox",
                Box::new(|tr: &SurvivalDataset| {
                    Box::new(GradientBoostedCox::fit(
                        tr,
                        &GbstConfig { n_stages: 50, ..Default::default() },
                    )) as Box<dyn SurvivalModel>
                }),
            ),
            (
                "fast-survival-svm",
                Box::new(|tr: &SurvivalDataset| {
                    Box::new(FastSurvivalSvm::fit(tr, &SvmConfig::default()))
                        as Box<dyn SurvivalModel>
                }),
            ),
            (
                "naive-survival-svm",
                Box::new(|tr: &SurvivalDataset| {
                    Box::new(NaiveSurvivalSvm::fit(
                        tr,
                        &SvmConfig { max_iters: 60, ..Default::default() },
                    )) as Box<dyn SurvivalModel>
                }),
            ),
        ];
        for (name, fit) in &fits {
            rows.extend(cv_model(&ds, name, fit, cfg.folds, cfg.seed + 1));
        }
    }

    let mut t = Table::new(
        &format!("{out_name}: 5-fold CV on {dataset}"),
        &[
            "method", "k", "fold", "train_loss", "test_loss", "train_cindex",
            "test_cindex", "train_ibs", "test_ibs", "f1",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.method.clone(),
            r.k.to_string(),
            r.fold.to_string(),
            fnum(r.train_loss),
            fnum(r.test_loss),
            fnum(r.train_cindex),
            fnum(r.test_cindex),
            fnum(r.train_ibs),
            fnum(r.test_ibs),
            r.f1.map(fnum).unwrap_or_else(|| "n/a".into()),
        ]);
    }
    cfg.write(&format!("{out_name}_{dataset}_cv.csv"), &t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            scale: 0.04,
            quantiles: 6,
            folds: 2,
            ks: vec![1, 2],
            optim_iters: 4,
            seed: 0,
            out_dir: std::env::temp_dir().join("fs_experiments_test"),
        }
    }

    #[test]
    fn table1_writes_csv() {
        let cfg = tiny_cfg();
        run("table1", &cfg).unwrap();
        assert!(cfg.out_dir.join("table1.csv").exists());
    }

    #[test]
    fn fig1_runs_all_methods() {
        let cfg = tiny_cfg();
        run("fig1", &cfg).unwrap();
        let text = std::fs::read_to_string(cfg.out_dir.join("fig1a_summary.csv")).unwrap();
        assert!(text.contains("cubic-surrogate"));
        assert!(text.contains("exact-newton"));
        let b = std::fs::read_to_string(cfg.out_dir.join("fig1b_summary.csv")).unwrap();
        assert!(!b.contains("exact-newton"), "no exact newton under l1");
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99", &tiny_cfg()).is_err());
        assert!(run("nonsense", &tiny_cfg()).is_err());
    }

    #[test]
    fn grid_mapping_covers_24_cells() {
        // fig5..fig20 resolve without panicking on id parsing.
        for num in 5..=20usize {
            let base = match num {
                5..=8 => 5,
                9..=12 => 9,
                13..=16 => 13,
                _ => 17,
            };
            let _ = (num, base); // mapping is exercised in run(); smoke only
        }
    }
}
