//! `fastsurvival inspect` — dump and verify a `.fsds` store: header
//! fields, checksum, meta block, chunk geometry, the live-append
//! segment manifest, and any stray files a crash left behind. The
//! read-only companion to `convert`/`append`: it never modifies the
//! store, it only reports what a reader would (and would not) see.
//!
//! Sharded stores (written by `convert --shards N`) are inspected
//! through their manifest instead: pass the `{out}.shards.json` path
//! (or the `{out}` stem it sits next to) and every shard's header
//! checksum and row count is verified against the manifest, then the
//! assembled [`ShardedDataset`] view is opened with full validation.

use crate::error::{FastSurvivalError, Result};
use crate::live::manifest::{header_checksum, manifest_path, segment_path, Manifest};
use crate::store::{
    shard_manifest_path, ChunkedDataset, CoxData, ShardEntry, ShardManifest, ShardedDataset,
};
use crate::util::args::Args;
use std::path::{Path, PathBuf};

/// One segment's inspection row.
#[derive(Clone, Debug)]
pub struct SegmentReport {
    pub seq: u64,
    pub path: PathBuf,
    pub n: usize,
    pub n_events: usize,
    /// The segment file opened and validated cleanly.
    pub ok: bool,
    pub error: Option<String>,
}

/// Everything `inspect` establishes about a store.
#[derive(Clone, Debug)]
pub struct InspectReport {
    pub path: PathBuf,
    pub file_bytes: u64,
    pub n: usize,
    pub p: usize,
    pub chunk_rows: usize,
    pub n_chunks: usize,
    pub n_events: usize,
    pub name: String,
    pub feature_names: Vec<String>,
    pub checksum_stored: u64,
    pub checksum_computed: u64,
    /// Base store opened with full validation (sort order, tie groups,
    /// column stats).
    pub base_ok: bool,
    pub base_error: Option<String>,
    /// `None` = no manifest file; `Some(false)` = a manifest exists but
    /// its base signature no longer matches (stale — e.g. after a
    /// compaction crash window or a base rewrite).
    pub manifest_valid: Option<bool>,
    pub segments: Vec<SegmentReport>,
    /// Files next to the store that no reader will ever load: leftover
    /// temp files and segment files the manifest does not commit.
    pub stray_files: Vec<PathBuf>,
}

impl InspectReport {
    /// Total rows a merged reader serves (base + committed segments).
    pub fn total_rows(&self) -> usize {
        self.n + self.segments.iter().map(|s| s.n).sum::<usize>()
    }

    /// Everything verified: checksum, base, manifest, every segment.
    pub fn healthy(&self) -> bool {
        self.base_ok
            && self.checksum_stored == self.checksum_computed
            && self.manifest_valid != Some(false)
            && self.segments.iter().all(|s| s.ok)
    }
}

/// Inspect a store without modifying anything on disk.
pub fn inspect(store: &Path) -> Result<InspectReport> {
    let file_bytes = std::fs::metadata(store)
        .map_err(|e| FastSurvivalError::io(format!("stat {store:?}"), e))?
        .len();
    let (checksum_stored, checksum_computed) = header_checksum(store)?;

    // Full-validation open: worth its one O(n·p) pass — this is the
    // command you run when you *suspect* a store.
    let (base_ok, base_error, meta) = match ChunkedDataset::open(store) {
        Ok(ds) => (true, None, Some(ds.meta_arc())),
        Err(e) => (false, Some(e.to_string()), None),
    };

    // Header-level fallback so a corrupt payload still gets its header
    // dumped (that is the interesting part when the open failed).
    let header = crate::live::manifest::read_header(store)?;
    let (n, p, chunk_rows, n_chunks, n_events, name, feature_names) = match &meta {
        Some(m) => (
            m.n,
            m.p,
            m.chunk_rows,
            m.n_chunks,
            m.n_events,
            m.name.clone(),
            m.feature_names.clone(),
        ),
        None => {
            let (name, features) = crate::live::manifest::read_name_and_features(store)
                .unwrap_or_else(|_| (String::from("<unreadable meta>"), Vec::new()));
            (header.n, header.p, header.chunk_rows, header.n_chunks(), 0, name, features)
        }
    };

    let manifest = Manifest::load(store)?;
    let valid = match &manifest {
        None => None,
        Some(_) => Some(Manifest::load_valid(store)?.is_some()),
    };
    let committed: Vec<u64> = match (&manifest, valid) {
        (Some(m), Some(true)) => m.segments.iter().map(|s| s.seq).collect(),
        _ => Vec::new(),
    };
    let mut segments = Vec::new();
    if let (Some(m), Some(true)) = (&manifest, valid) {
        for entry in &m.segments {
            let sp = segment_path(store, entry.seq);
            let (ok, error) = match ChunkedDataset::open(&sp) {
                Ok(seg) => {
                    if seg.meta().n == entry.n && seg.meta().n_events == entry.n_events {
                        (true, None)
                    } else {
                        (
                            false,
                            Some(format!(
                                "manifest says n={} events={}, file holds n={} events={}",
                                entry.n,
                                entry.n_events,
                                seg.meta().n,
                                seg.meta().n_events
                            )),
                        )
                    }
                }
                Err(e) => (false, Some(e.to_string())),
            };
            segments.push(SegmentReport {
                seq: entry.seq,
                path: sp,
                n: entry.n,
                n_events: entry.n_events,
                ok,
                error,
            });
        }
    }

    let stray_files = find_stray_files(store, &committed)?;
    Ok(InspectReport {
        path: store.to_path_buf(),
        file_bytes,
        n,
        p,
        chunk_rows,
        n_chunks,
        n_events,
        name,
        feature_names,
        checksum_stored,
        checksum_computed,
        base_ok,
        base_error,
        manifest_valid: valid,
        segments,
        stray_files,
    })
}

/// List (without touching) files prefixed by the store's name that no
/// reader loads: temp leftovers and uncommitted segments.
fn find_stray_files(store: &Path, committed: &[u64]) -> Result<Vec<PathBuf>> {
    let parent = store.parent().unwrap_or_else(|| Path::new("."));
    let stem = store
        .file_name()
        .and_then(|s| s.to_str())
        .ok_or_else(|| FastSurvivalError::Store(format!("non-UTF-8 store path {store:?}")))?;
    let rd = std::fs::read_dir(parent)
        .map_err(|e| FastSurvivalError::io(format!("scanning {parent:?}"), e))?;
    let mut stray = Vec::new();
    for entry in rd {
        let entry =
            entry.map_err(|e| FastSurvivalError::io(format!("scanning {parent:?}"), e))?;
        let path = entry.path();
        let fname = match path.file_name().and_then(|s| s.to_str()) {
            Some(f) => f,
            None => continue,
        };
        if fname == stem || !fname.starts_with(stem) {
            continue;
        }
        let suffix = &fname[stem.len()..];
        let is_temp = suffix.ends_with(".partial.tmp")
            || suffix.ends_with(".rows.tmp")
            || suffix.ends_with(".compact.tmp");
        let is_orphan_segment = suffix.starts_with(".seg")
            && suffix.ends_with(".fsds")
            && !committed.iter().any(|&seq| fname
                == segment_path(store, seq)
                    .file_name()
                    .and_then(|s| s.to_str())
                    .unwrap_or_default());
        if is_temp || is_orphan_segment {
            stray.push(path);
        }
    }
    stray.sort();
    Ok(stray)
}

/// One shard file's inspection row.
#[derive(Clone, Debug)]
pub struct ShardFileReport {
    pub seq: usize,
    pub path: PathBuf,
    /// Rows the manifest claims for this shard.
    pub rows: usize,
    /// First sorted global row index the manifest claims.
    pub row0: usize,
    /// Header checksum verified (stored == computed == manifest entry),
    /// the file opened with full validation, and its row count matches
    /// the manifest.
    pub ok: bool,
    pub error: Option<String>,
}

/// Everything `inspect` establishes about a sharded store.
#[derive(Clone, Debug)]
pub struct ShardInspectReport {
    pub manifest_path: PathBuf,
    pub generation: u64,
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub chunk_rows: usize,
    pub precision: &'static str,
    pub shards: Vec<ShardFileReport>,
    /// The assembled [`ShardedDataset`] (all shards stitched back into
    /// the global chunk geometry) opened with full validation.
    pub assembled_ok: bool,
    pub assembled_error: Option<String>,
}

impl ShardInspectReport {
    /// Every shard verified and the assembled view opens cleanly.
    pub fn healthy(&self) -> bool {
        self.assembled_ok && self.shards.iter().all(|s| s.ok)
    }
}

/// Verify one shard file against its manifest entry: header checksum
/// (stored vs computed vs the manifest's copy), then a full-validation
/// open cross-checking the row count the manifest claims.
fn inspect_one_shard(path: &Path, entry: &ShardEntry) -> (bool, Option<String>) {
    let (stored, computed) = match header_checksum(path) {
        Ok(pair) => pair,
        Err(e) => return (false, Some(e.to_string())),
    };
    if stored != computed {
        return (
            false,
            Some(format!("header checksum stored {stored:#018x} != computed {computed:#018x}")),
        );
    }
    if computed != entry.checksum {
        return (
            false,
            Some(format!(
                "header checksum {computed:#018x} != manifest entry {:#018x}",
                entry.checksum
            )),
        );
    }
    match ChunkedDataset::open(path) {
        Ok(ds) => {
            let n = ds.meta().n;
            if n == entry.rows {
                (true, None)
            } else {
                (false, Some(format!("manifest says {} rows, file holds {n}", entry.rows)))
            }
        }
        Err(e) => (false, Some(e.to_string())),
    }
}

/// Inspect a sharded store (by its stem path, next to which the
/// `.shards.json` manifest lives) without modifying anything on disk.
pub fn inspect_shards(store: &Path) -> Result<ShardInspectReport> {
    let mpath = shard_manifest_path(store);
    let manifest = ShardManifest::load(&mpath)?.ok_or_else(|| {
        FastSurvivalError::Store(format!("no shard manifest at {}", mpath.display()))
    })?;
    let parent = mpath.parent().unwrap_or_else(|| Path::new("."));
    let shards: Vec<ShardFileReport> = manifest
        .shards
        .iter()
        .map(|entry| {
            let sp = parent.join(&entry.file);
            let (ok, error) = inspect_one_shard(&sp, entry);
            ShardFileReport {
                seq: entry.seq,
                path: sp,
                rows: entry.rows,
                row0: entry.row0,
                ok,
                error,
            }
        })
        .collect();
    // The assembled view pays the same O(n·p) stats pass a fit would,
    // so a HEALTHY verdict means `bigfit --shards` will actually run.
    let (assembled_ok, assembled_error) = match ShardedDataset::open(store) {
        Ok(_) => (true, None),
        Err(e) => (false, Some(e.to_string())),
    };
    Ok(ShardInspectReport {
        manifest_path: mpath,
        generation: manifest.generation,
        name: manifest.name,
        n: manifest.n,
        p: manifest.p,
        chunk_rows: manifest.chunk_rows,
        precision: manifest.precision.name(),
        shards,
        assembled_ok,
        assembled_error,
    })
}

/// Print + verdict for a sharded store; nonzero exit on any unhealthy
/// shard (or a broken assembled view).
fn run_sharded(store: &Path) -> Result<()> {
    let report = inspect_shards(store)?;
    println!(
        "sharded store: {} (generation {})",
        report.manifest_path.display(),
        report.generation
    );
    println!(
        "geometry: n={} p={} chunk_rows={} precision={} name={:?} shards={}",
        report.n,
        report.p,
        report.chunk_rows,
        report.precision,
        report.name,
        report.shards.len()
    );
    for s in &report.shards {
        match (&s.ok, &s.error) {
            (true, _) => println!(
                "  shard{:03}: rows {}..{} [OK] {}",
                s.seq,
                s.row0,
                s.row0 + s.rows,
                s.path.display()
            ),
            (false, e) => println!(
                "  shard{:03}: rows {}..{} [FAILED: {}]",
                s.seq,
                s.row0,
                s.row0 + s.rows,
                e.as_deref().unwrap_or("unknown")
            ),
        }
    }
    match (&report.assembled_ok, &report.assembled_error) {
        (true, _) => println!("assembled: opens cleanly ({} rows total)", report.n),
        (false, Some(e)) => println!("assembled: FAILED validation — {e}"),
        (false, None) => println!("assembled: FAILED validation"),
    }
    println!("verdict: {}", if report.healthy() { "HEALTHY" } else { "UNHEALTHY" });
    if !report.healthy() {
        return Err(FastSurvivalError::Store(format!(
            "sharded store {} failed inspection",
            report.manifest_path.display()
        )));
    }
    Ok(())
}

/// The `inspect` CLI subcommand.
pub fn run(args: &Args) -> Result<()> {
    let store = args.get("store").ok_or_else(|| {
        FastSurvivalError::InvalidConfig(
            "inspect requires --store <file.fsds | file.fsds.shards.json>".into(),
        )
    })?;
    // A sharded store is addressed by its manifest path or by the stem
    // the manifest sits next to (`convert --shards` writes no base file
    // at the stem, so an absent stem with a manifest present is the
    // sharded case, not a missing store).
    if let Some(stem) = store.strip_suffix(".shards.json") {
        return run_sharded(Path::new(stem));
    }
    let path = Path::new(store);
    if !path.exists() && shard_manifest_path(path).exists() {
        return run_sharded(path);
    }
    let report = inspect(Path::new(store))?;
    println!("store: {} ({:.1} MB)", report.path.display(), report.file_bytes as f64 / 1e6);
    println!(
        "header: n={} p={} chunk_rows={} ({} chunks) name={:?}",
        report.n, report.p, report.chunk_rows, report.n_chunks, report.name
    );
    let check =
        if report.checksum_stored == report.checksum_computed { "OK" } else { "MISMATCH" };
    println!(
        "checksum: stored {:#018x} computed {:#018x} [{check}]",
        report.checksum_stored, report.checksum_computed
    );
    match (&report.base_ok, &report.base_error) {
        (true, _) => println!("base: opens cleanly, {} events", report.n_events),
        (false, Some(e)) => println!("base: FAILED validation — {e}"),
        (false, None) => println!("base: FAILED validation"),
    }
    if report.p <= 12 {
        println!("features: {}", report.feature_names.join(", "));
    } else {
        println!(
            "features: {} … ({} total)",
            report.feature_names.iter().take(8).cloned().collect::<Vec<_>>().join(", "),
            report.p
        );
    }
    match report.manifest_valid {
        None => println!("manifest: none (no live appends)"),
        Some(false) => println!(
            "manifest: STALE — {} does not match the base header (readers ignore it)",
            manifest_path(&report.path).display()
        ),
        Some(true) => {
            println!("manifest: {} committed segment(s)", report.segments.len());
            for s in &report.segments {
                match (&s.ok, &s.error) {
                    (true, _) => println!(
                        "  seg{:06}: n={} events={} [OK] {}",
                        s.seq,
                        s.n,
                        s.n_events,
                        s.path.display()
                    ),
                    (false, e) => println!(
                        "  seg{:06}: n={} events={} [FAILED: {}]",
                        s.seq,
                        s.n,
                        s.n_events,
                        e.as_deref().unwrap_or("unknown")
                    ),
                }
            }
            println!("merged view: {} rows total", report.total_rows());
        }
    }
    if report.stray_files.is_empty() {
        println!("stray files: none");
    } else {
        println!(
            "stray files: {} (ignored by readers, cleaned at next append)",
            report.stray_files.len()
        );
        for f in &report.stray_files {
            println!("  {}", f.display());
        }
    }
    println!("verdict: {}", if report.healthy() { "HEALTHY" } else { "UNHEALTHY" });
    if !report.healthy() {
        return Err(FastSurvivalError::Store(format!(
            "store {} failed inspection",
            report.path.display()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::live::append::append_rows;
    use crate::store::writer::{write_store, DatasetRows};

    fn temp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fs_inspect_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn seed_store(dir: &Path) -> PathBuf {
        let base = dir.join("s.fsds");
        let ds = generate(&SyntheticConfig { n: 80, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 5 });
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &base, 32, "s").unwrap();
        base
    }

    #[test]
    fn healthy_store_with_segments_passes() {
        let dir = temp_dir("ok");
        let base = seed_store(&dir);
        let extra = generate(&SyntheticConfig { n: 9, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 6 });
        let mut rows = DatasetRows::new(&extra);
        append_rows(&base, &mut rows, 32).unwrap();
        let r = inspect(&base).unwrap();
        assert!(r.healthy(), "{r:?}");
        assert_eq!(r.checksum_stored, r.checksum_computed);
        assert_eq!(r.manifest_valid, Some(true));
        assert_eq!(r.segments.len(), 1);
        assert_eq!(r.total_rows(), 89);
        assert!(r.stray_files.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn orphan_segment_and_temp_files_are_reported_stray() {
        let dir = temp_dir("stray");
        let base = seed_store(&dir);
        // A crash between segment write and manifest commit: segment
        // file exists, manifest doesn't mention it.
        let extra = generate(&SyntheticConfig { n: 7, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 7 });
        let mut rows = DatasetRows::new(&extra);
        write_store(&mut rows, &segment_path(&base, 1), 32, "orphan").unwrap();
        std::fs::write(base.with_extension("fsds.partial.tmp"), b"junk").unwrap();
        let r = inspect(&base).unwrap();
        assert!(r.healthy(), "orphans don't make the store unhealthy: {r:?}");
        assert_eq!(r.manifest_valid, None);
        assert_eq!(r.stray_files.len(), 2, "{:?}", r.stray_files);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_header_checksum_is_unhealthy() {
        let dir = temp_dir("bad");
        let base = seed_store(&dir);
        let mut bytes = std::fs::read(&base).unwrap();
        bytes[9] ^= 0xFF; // flip a bit inside the checksummed header area
        std::fs::write(&base, &bytes).unwrap();
        match inspect(&base) {
            // Depending on how decode guards, either inspect() itself
            // errors or the report is unhealthy; both are correct.
            Ok(r) => assert!(!r.healthy()),
            Err(_) => {}
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn seed_sharded_store(dir: &Path, shards: usize) -> PathBuf {
        let out = dir.join("sh.fsds");
        let ds =
            generate(&SyntheticConfig { n: 120, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 11 });
        let mut rows = DatasetRows::new(&ds);
        crate::store::write_sharded_store(
            &mut rows,
            &out,
            32,
            "sh",
            crate::util::compute::Precision::F64,
            shards,
        )
        .unwrap();
        out
    }

    #[test]
    fn healthy_sharded_store_passes() {
        let dir = temp_dir("shards_ok");
        let out = seed_sharded_store(&dir, 3);
        let r = inspect_shards(&out).unwrap();
        assert!(r.healthy(), "{r:?}");
        assert_eq!(r.n, 120);
        assert_eq!(r.shards.len(), 3);
        assert_eq!(r.shards.iter().map(|s| s.rows).sum::<usize>(), 120);
        assert!(r.assembled_ok);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampered_shard_file_is_unhealthy() {
        let dir = temp_dir("shards_bad");
        let out = seed_sharded_store(&dir, 3);
        let manifest = ShardManifest::load(&shard_manifest_path(&out)).unwrap().unwrap();
        let victim = dir.join(&manifest.shards[1].file);
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes[9] ^= 0xFF; // inside the checksummed header area
        std::fs::write(&victim, &bytes).unwrap();
        let r = inspect_shards(&out).unwrap();
        assert!(!r.healthy());
        assert!(!r.shards[1].ok, "{:?}", r.shards[1]);
        assert!(r.shards[0].ok && r.shards[2].ok, "only the tampered shard fails");
        // A missing shard file is caught the same way.
        std::fs::remove_file(&victim).unwrap();
        let r = inspect_shards(&out).unwrap();
        assert!(!r.shards[1].ok && !r.assembled_ok);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_row_count_mismatch_vs_manifest_is_unhealthy() {
        let dir = temp_dir("shards_rows");
        let out = seed_sharded_store(&dir, 2);
        let mpath = shard_manifest_path(&out);
        // Shrink the last shard's claim (and n, keeping the manifest
        // structurally valid) so only the file-vs-manifest cross-check
        // can catch the drift.
        let mut manifest = ShardManifest::load(&mpath).unwrap().unwrap();
        manifest.shards.last_mut().unwrap().rows -= 1;
        manifest.n -= 1;
        manifest.save(&mpath).unwrap();
        let r = inspect_shards(&out).unwrap();
        assert!(!r.healthy());
        let last = r.shards.last().unwrap();
        assert!(!last.ok);
        assert!(
            last.error.as_deref().unwrap_or("").contains("rows"),
            "row-count mismatch should be named: {:?}",
            last.error
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
