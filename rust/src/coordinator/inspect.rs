//! `fastsurvival inspect` — dump and verify a `.fsds` store: header
//! fields, checksum, meta block, chunk geometry, the live-append
//! segment manifest, and any stray files a crash left behind. The
//! read-only companion to `convert`/`append`: it never modifies the
//! store, it only reports what a reader would (and would not) see.

use crate::error::{FastSurvivalError, Result};
use crate::live::manifest::{header_checksum, manifest_path, segment_path, Manifest};
use crate::store::{ChunkedDataset, CoxData};
use crate::util::args::Args;
use std::path::{Path, PathBuf};

/// One segment's inspection row.
#[derive(Clone, Debug)]
pub struct SegmentReport {
    pub seq: u64,
    pub path: PathBuf,
    pub n: usize,
    pub n_events: usize,
    /// The segment file opened and validated cleanly.
    pub ok: bool,
    pub error: Option<String>,
}

/// Everything `inspect` establishes about a store.
#[derive(Clone, Debug)]
pub struct InspectReport {
    pub path: PathBuf,
    pub file_bytes: u64,
    pub n: usize,
    pub p: usize,
    pub chunk_rows: usize,
    pub n_chunks: usize,
    pub n_events: usize,
    pub name: String,
    pub feature_names: Vec<String>,
    pub checksum_stored: u64,
    pub checksum_computed: u64,
    /// Base store opened with full validation (sort order, tie groups,
    /// column stats).
    pub base_ok: bool,
    pub base_error: Option<String>,
    /// `None` = no manifest file; `Some(false)` = a manifest exists but
    /// its base signature no longer matches (stale — e.g. after a
    /// compaction crash window or a base rewrite).
    pub manifest_valid: Option<bool>,
    pub segments: Vec<SegmentReport>,
    /// Files next to the store that no reader will ever load: leftover
    /// temp files and segment files the manifest does not commit.
    pub stray_files: Vec<PathBuf>,
}

impl InspectReport {
    /// Total rows a merged reader serves (base + committed segments).
    pub fn total_rows(&self) -> usize {
        self.n + self.segments.iter().map(|s| s.n).sum::<usize>()
    }

    /// Everything verified: checksum, base, manifest, every segment.
    pub fn healthy(&self) -> bool {
        self.base_ok
            && self.checksum_stored == self.checksum_computed
            && self.manifest_valid != Some(false)
            && self.segments.iter().all(|s| s.ok)
    }
}

/// Inspect a store without modifying anything on disk.
pub fn inspect(store: &Path) -> Result<InspectReport> {
    let file_bytes = std::fs::metadata(store)
        .map_err(|e| FastSurvivalError::io(format!("stat {store:?}"), e))?
        .len();
    let (checksum_stored, checksum_computed) = header_checksum(store)?;

    // Full-validation open: worth its one O(n·p) pass — this is the
    // command you run when you *suspect* a store.
    let (base_ok, base_error, meta) = match ChunkedDataset::open(store) {
        Ok(ds) => (true, None, Some(ds.meta_arc())),
        Err(e) => (false, Some(e.to_string()), None),
    };

    // Header-level fallback so a corrupt payload still gets its header
    // dumped (that is the interesting part when the open failed).
    let header = crate::live::manifest::read_header(store)?;
    let (n, p, chunk_rows, n_chunks, n_events, name, feature_names) = match &meta {
        Some(m) => (
            m.n,
            m.p,
            m.chunk_rows,
            m.n_chunks,
            m.n_events,
            m.name.clone(),
            m.feature_names.clone(),
        ),
        None => {
            let (name, features) = crate::live::manifest::read_name_and_features(store)
                .unwrap_or_else(|_| (String::from("<unreadable meta>"), Vec::new()));
            (header.n, header.p, header.chunk_rows, header.n_chunks(), 0, name, features)
        }
    };

    let manifest = Manifest::load(store)?;
    let valid = match &manifest {
        None => None,
        Some(_) => Some(Manifest::load_valid(store)?.is_some()),
    };
    let committed: Vec<u64> = match (&manifest, valid) {
        (Some(m), Some(true)) => m.segments.iter().map(|s| s.seq).collect(),
        _ => Vec::new(),
    };
    let mut segments = Vec::new();
    if let (Some(m), Some(true)) = (&manifest, valid) {
        for entry in &m.segments {
            let sp = segment_path(store, entry.seq);
            let (ok, error) = match ChunkedDataset::open(&sp) {
                Ok(seg) => {
                    if seg.meta().n == entry.n && seg.meta().n_events == entry.n_events {
                        (true, None)
                    } else {
                        (
                            false,
                            Some(format!(
                                "manifest says n={} events={}, file holds n={} events={}",
                                entry.n,
                                entry.n_events,
                                seg.meta().n,
                                seg.meta().n_events
                            )),
                        )
                    }
                }
                Err(e) => (false, Some(e.to_string())),
            };
            segments.push(SegmentReport {
                seq: entry.seq,
                path: sp,
                n: entry.n,
                n_events: entry.n_events,
                ok,
                error,
            });
        }
    }

    let stray_files = find_stray_files(store, &committed)?;
    Ok(InspectReport {
        path: store.to_path_buf(),
        file_bytes,
        n,
        p,
        chunk_rows,
        n_chunks,
        n_events,
        name,
        feature_names,
        checksum_stored,
        checksum_computed,
        base_ok,
        base_error,
        manifest_valid: valid,
        segments,
        stray_files,
    })
}

/// List (without touching) files prefixed by the store's name that no
/// reader loads: temp leftovers and uncommitted segments.
fn find_stray_files(store: &Path, committed: &[u64]) -> Result<Vec<PathBuf>> {
    let parent = store.parent().unwrap_or_else(|| Path::new("."));
    let stem = store
        .file_name()
        .and_then(|s| s.to_str())
        .ok_or_else(|| FastSurvivalError::Store(format!("non-UTF-8 store path {store:?}")))?;
    let rd = std::fs::read_dir(parent)
        .map_err(|e| FastSurvivalError::io(format!("scanning {parent:?}"), e))?;
    let mut stray = Vec::new();
    for entry in rd {
        let entry =
            entry.map_err(|e| FastSurvivalError::io(format!("scanning {parent:?}"), e))?;
        let path = entry.path();
        let fname = match path.file_name().and_then(|s| s.to_str()) {
            Some(f) => f,
            None => continue,
        };
        if fname == stem || !fname.starts_with(stem) {
            continue;
        }
        let suffix = &fname[stem.len()..];
        let is_temp = suffix.ends_with(".partial.tmp")
            || suffix.ends_with(".rows.tmp")
            || suffix.ends_with(".compact.tmp");
        let is_orphan_segment = suffix.starts_with(".seg")
            && suffix.ends_with(".fsds")
            && !committed.iter().any(|&seq| fname
                == segment_path(store, seq)
                    .file_name()
                    .and_then(|s| s.to_str())
                    .unwrap_or_default());
        if is_temp || is_orphan_segment {
            stray.push(path);
        }
    }
    stray.sort();
    Ok(stray)
}

/// The `inspect` CLI subcommand.
pub fn run(args: &Args) -> Result<()> {
    let store = args.get("store").ok_or_else(|| {
        FastSurvivalError::InvalidConfig("inspect requires --store <file.fsds>".into())
    })?;
    let report = inspect(Path::new(store))?;
    println!("store: {} ({:.1} MB)", report.path.display(), report.file_bytes as f64 / 1e6);
    println!(
        "header: n={} p={} chunk_rows={} ({} chunks) name={:?}",
        report.n, report.p, report.chunk_rows, report.n_chunks, report.name
    );
    let check =
        if report.checksum_stored == report.checksum_computed { "OK" } else { "MISMATCH" };
    println!(
        "checksum: stored {:#018x} computed {:#018x} [{check}]",
        report.checksum_stored, report.checksum_computed
    );
    match (&report.base_ok, &report.base_error) {
        (true, _) => println!("base: opens cleanly, {} events", report.n_events),
        (false, Some(e)) => println!("base: FAILED validation — {e}"),
        (false, None) => println!("base: FAILED validation"),
    }
    if report.p <= 12 {
        println!("features: {}", report.feature_names.join(", "));
    } else {
        println!(
            "features: {} … ({} total)",
            report.feature_names.iter().take(8).cloned().collect::<Vec<_>>().join(", "),
            report.p
        );
    }
    match report.manifest_valid {
        None => println!("manifest: none (no live appends)"),
        Some(false) => println!(
            "manifest: STALE — {} does not match the base header (readers ignore it)",
            manifest_path(&report.path).display()
        ),
        Some(true) => {
            println!("manifest: {} committed segment(s)", report.segments.len());
            for s in &report.segments {
                match (&s.ok, &s.error) {
                    (true, _) => println!(
                        "  seg{:06}: n={} events={} [OK] {}",
                        s.seq,
                        s.n,
                        s.n_events,
                        s.path.display()
                    ),
                    (false, e) => println!(
                        "  seg{:06}: n={} events={} [FAILED: {}]",
                        s.seq,
                        s.n,
                        s.n_events,
                        e.as_deref().unwrap_or("unknown")
                    ),
                }
            }
            println!("merged view: {} rows total", report.total_rows());
        }
    }
    if report.stray_files.is_empty() {
        println!("stray files: none");
    } else {
        println!(
            "stray files: {} (ignored by readers, cleaned at next append)",
            report.stray_files.len()
        );
        for f in &report.stray_files {
            println!("  {}", f.display());
        }
    }
    println!("verdict: {}", if report.healthy() { "HEALTHY" } else { "UNHEALTHY" });
    if !report.healthy() {
        return Err(FastSurvivalError::Store(format!(
            "store {} failed inspection",
            report.path.display()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::live::append::append_rows;
    use crate::store::writer::{write_store, DatasetRows};

    fn temp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fs_inspect_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn seed_store(dir: &Path) -> PathBuf {
        let base = dir.join("s.fsds");
        let ds = generate(&SyntheticConfig { n: 80, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 5 });
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &base, 32, "s").unwrap();
        base
    }

    #[test]
    fn healthy_store_with_segments_passes() {
        let dir = temp_dir("ok");
        let base = seed_store(&dir);
        let extra = generate(&SyntheticConfig { n: 9, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 6 });
        let mut rows = DatasetRows::new(&extra);
        append_rows(&base, &mut rows, 32).unwrap();
        let r = inspect(&base).unwrap();
        assert!(r.healthy(), "{r:?}");
        assert_eq!(r.checksum_stored, r.checksum_computed);
        assert_eq!(r.manifest_valid, Some(true));
        assert_eq!(r.segments.len(), 1);
        assert_eq!(r.total_rows(), 89);
        assert!(r.stray_files.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn orphan_segment_and_temp_files_are_reported_stray() {
        let dir = temp_dir("stray");
        let base = seed_store(&dir);
        // A crash between segment write and manifest commit: segment
        // file exists, manifest doesn't mention it.
        let extra = generate(&SyntheticConfig { n: 7, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 7 });
        let mut rows = DatasetRows::new(&extra);
        write_store(&mut rows, &segment_path(&base, 1), 32, "orphan").unwrap();
        std::fs::write(base.with_extension("fsds.partial.tmp"), b"junk").unwrap();
        let r = inspect(&base).unwrap();
        assert!(r.healthy(), "orphans don't make the store unhealthy: {r:?}");
        assert_eq!(r.manifest_valid, None);
        assert_eq!(r.stray_files.len(), 2, "{:?}", r.stray_files);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_header_checksum_is_unhealthy() {
        let dir = temp_dir("bad");
        let base = seed_store(&dir);
        let mut bytes = std::fs::read(&base).unwrap();
        bytes[9] ^= 0xFF; // flip a bit inside the checksummed header area
        std::fs::write(&base, &bytes).unwrap();
        match inspect(&base) {
            // Depending on how decode guards, either inspect() itself
            // errors or the report is unhealthy; both are correct.
            Ok(r) => assert!(!r.healthy()),
            Err(_) => {}
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
