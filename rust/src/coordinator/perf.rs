//! The `bench` CLI subcommand: reproducible hot-path benchmarks plus the
//! CI perf gate.
//!
//! Workloads are fixed-seed synthetic Cox problems (continuous and tied
//! times, multi-stratum, n up to 100k and p up to 1k under `--full`).
//! Results land in `BENCH_optim.json` — the file that starts the repo's
//! perf trajectory: the tracked kernel is the blocked parallel batched
//! derivative pass, whose speedup over the seed's sequential pass at
//! n=50k, p=500 with 4 worker threads is recorded in the `gate` object.
//!
//! `--check <baseline.json>` turns the run into a gate: it fails if any
//! `gate: true` kernel in the committed baseline is now >`tolerance_pct`
//! slower, or if the tracked parallel kernel falls clearly below its
//! sequential reference (speedup < [`INVARIANT_MIN_SPEEDUP`] — a
//! machine-independent invariant). A `bootstrap: true` baseline (no
//! trustworthy timings recorded yet) downgrades every failure to
//! advisory output.

use crate::api::json;
use crate::cox::derivatives::{
    all_coord_d1_d2_opts, all_coord_d1_d2_seq, all_coord_d1_d2_with_threads, Workspace,
};
use crate::cox::stratified::StratifiedCoxProblem;
use crate::cox::{CoxProblem, CoxState};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::linalg::Matrix;
use crate::path::PathSolver;
use crate::util::args::Args;
use crate::util::bench::{time_once, Bencher};
use crate::util::compute::{auto_block_rows, Backend, KernelBackend};
use crate::util::parallel::num_threads;
use crate::util::rng::Rng;
use std::hint::black_box;
use std::path::Path;

/// The speedup the blocked kernel is expected to hold over the seed
/// sequential pass on the tracked workload (acceptance criterion).
const REQUIRED_SPEEDUP: f64 = 2.0;

/// The speedup the warm-started screened λ-path must hold over the same
/// grid solved as independent cold fits (acceptance criterion). The
/// ratio compares two timings from one run on one machine, so the gate
/// is machine-independent.
const REQUIRED_PATH_SPEEDUP: f64 = 3.0;

/// Maximum normalized per-grid-point loss gap |warm − cold| / (1 + |cold|)
/// between the warm-started screened path and the cold reference.
const PATH_ENDPOINT_TOL: f64 = 1e-8;

/// The speedup the SIMD lane kernels must hold over the scalar backend
/// on the tracked batched workload at the same thread count. Like the
/// path gate, the ratio compares two timings from one run on one
/// machine, so it is machine-independent.
const REQUIRED_SIMD_SPEEDUP: f64 = 1.3;

/// Maximum slowdown the telemetry layer may impose on the tracked
/// batched workload when spans and counters are enabled, in percent.
/// Like the path and SIMD gates, the ratio compares two timings from
/// one run on one machine, so it is machine-independent.
const MAX_OBS_OVERHEAD_PCT: f64 = 1.0;

/// Default slow-down tolerance for `--check`, in percent.
const DEFAULT_TOLERANCE_PCT: f64 = 25.0;

/// Floor for the machine-independent invariant: the blocked parallel
/// kernel must stay within this factor of the sequential reference.
/// Below 1.0 to absorb scheduler noise on small smoke workloads and
/// oversubscribed CI runners; a genuine regression (parallel kernel
/// structurally slower) lands well under it.
const INVARIANT_MIN_SPEEDUP: f64 = 0.8;

/// One benchmark row of `BENCH_optim.json`.
struct Entry {
    name: String,
    kernel: &'static str,
    n: usize,
    p: usize,
    ties: bool,
    strata: usize,
    threads: usize,
    seed: u64,
    median_ns: f64,
    min_ns: f64,
    mean_ns: f64,
    mad_ns: f64,
    samples: usize,
    iters_per_sample: u64,
    speedup_vs_seq: Option<f64>,
    gate: bool,
}

/// Workload sizes; `quick` keeps the CI smoke job under a few seconds,
/// `full` stretches to the paper-scale extremes.
struct Sizes {
    n_main: usize,
    p_main: usize,
    n_ties: usize,
    p_ties: usize,
    n_strat: usize,
    p_strat: usize,
    strata: usize,
    n_state: usize,
    n_path: usize,
    p_path: usize,
    k_path: usize,
}

impl Sizes {
    fn pick(quick: bool) -> Sizes {
        if quick {
            Sizes {
                n_main: 4_000,
                p_main: 64,
                n_ties: 2_000,
                p_ties: 48,
                n_strat: 4_000,
                p_strat: 32,
                strata: 4,
                n_state: 10_000,
                // Same shape as the tracked full workload, n scaled down:
                // the p=200 screening profile is what the gate measures.
                n_path: 2_000,
                p_path: 200,
                k_path: 15,
            }
        } else {
            Sizes {
                n_main: 50_000,
                p_main: 500,
                n_ties: 20_000,
                p_ties: 200,
                n_strat: 40_000,
                p_strat: 100,
                strata: 4,
                n_state: 100_000,
                // The tracked path workload from the acceptance criterion.
                n_path: 10_000,
                p_path: 200,
                k_path: 15,
            }
        }
    }
}

/// λ grid length of the path workload (both modes — the grid is the
/// workload's identity, only n × p shrinks under `--quick`).
const PATH_N_LAMBDAS: usize = 50;

/// Fixed-seed synthetic problem (the dataset copy is dropped on return,
/// so the steady-state footprint is one column-major matrix).
fn synthetic_problem(n: usize, p: usize, seed: u64, ties: bool) -> CoxProblem {
    let mut rng = Rng::new(seed);
    let cols: Vec<Vec<f64>> = (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let time: Vec<f64> = (0..n)
        .map(|_| {
            let t = rng.uniform_range(0.5, 9.5);
            if ties {
                (t * 4.0).round() / 4.0
            } else {
                t
            }
        })
        .collect();
    let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
    CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "bench"))
}

/// Deterministic non-zero β so risk-set weights are nontrivial.
fn bench_state(problem: &CoxProblem, seed: u64) -> CoxState {
    let mut rng = Rng::new(seed);
    let beta: Vec<f64> = (0..problem.p()).map(|_| rng.normal() * 0.1).collect();
    CoxState::from_beta(problem, &beta)
}

#[allow(clippy::too_many_arguments)]
fn push_entry(
    entries: &mut Vec<Entry>,
    b: &Bencher,
    name: String,
    kernel: &'static str,
    n: usize,
    p: usize,
    ties: bool,
    strata: usize,
    threads: usize,
    seed: u64,
) {
    let s = b.results().last().expect("bench just ran");
    entries.push(Entry {
        name,
        kernel,
        n,
        p,
        ties,
        strata,
        threads,
        seed,
        median_ns: s.median_ns,
        min_ns: s.min_ns,
        mean_ns: s.mean_ns,
        mad_ns: s.mad_ns,
        samples: s.samples.len(),
        iters_per_sample: s.iters_per_sample,
        speedup_vs_seq: None,
        gate: false,
    });
}

/// Benchmark one (n, p, ties) workload: the seed sequential batched pass
/// against the blocked parallel pass at explicit worker counts. Returns
/// the entry indices of (sequential reference, t=4 blocked).
fn bench_batched_pair(
    entries: &mut Vec<Entry>,
    b: &mut Bencher,
    n: usize,
    p: usize,
    seed: u64,
    ties: bool,
    tag: &str,
) -> (usize, usize) {
    let pr = synthetic_problem(n, p, seed, ties);
    let st = bench_state(&pr, seed ^ 0x5eed);
    b.bench(&format!("batched_seq{tag}_n{n}_p{p}"), || {
        black_box(all_coord_d1_d2_seq(&pr, &st));
    });
    push_entry(
        entries,
        b,
        format!("batched_seq{tag}_n{n}_p{p}"),
        "all_coord_d1_d2_seq",
        n,
        p,
        ties,
        1,
        1,
        seed,
    );
    let seq_idx = entries.len() - 1;
    let seq_median = entries[seq_idx].median_ns;

    let mut t4_idx = entries.len();
    for &t in &[1usize, 2, 4] {
        let mut ws = Workspace::default();
        b.bench(&format!("batched_blocked{tag}_t{t}_n{n}_p{p}"), || {
            black_box(all_coord_d1_d2_with_threads(&pr, &st, &mut ws, t));
        });
        push_entry(
            entries,
            b,
            format!("batched_blocked{tag}_t{t}_n{n}_p{p}"),
            "all_coord_d1_d2_blocked",
            n,
            p,
            ties,
            1,
            t,
            seed,
        );
        let e = entries.last_mut().expect("just pushed");
        e.speedup_vs_seq = Some(seq_median / e.median_ns);
        if t == 4 {
            t4_idx = entries.len() - 1;
        }
    }
    (seq_idx, t4_idx)
}

/// Everything the SIMD-vs-scalar backend gate tracks for one run.
struct SimdGateInfo {
    tracked: String,
    reference: String,
    threads: usize,
    /// scalar median / simd median on the tracked workload.
    speedup: f64,
}

impl SimdGateInfo {
    fn passed(&self) -> bool {
        self.speedup >= REQUIRED_SIMD_SPEEDUP
    }
}

/// Benchmark the batched derivative pass per kernel backend on one
/// workload at a fixed worker count — the `--backend` sweep. Emits one
/// entry per backend; when both backends ran, returns the gate ratio
/// (scalar median / simd median, same run, same machine).
fn bench_backend_sweep(
    entries: &mut Vec<Entry>,
    b: &mut Bencher,
    n: usize,
    p: usize,
    seed: u64,
    threads: usize,
    backends: &[KernelBackend],
) -> Option<SimdGateInfo> {
    let pr = synthetic_problem(n, p, seed, false);
    let st = bench_state(&pr, seed ^ 0x5eed);
    let block_rows = auto_block_rows(n);
    let mut medians: Vec<(KernelBackend, String, f64)> = Vec::new();
    for &backend in backends {
        // One workspace per backend: the risk-set cache is backend-keyed,
        // so reuse inside the timing loop measures the hot path, not a
        // re-preparation per call.
        let mut ws = Workspace::default();
        let name = format!("batched_{}_t{threads}_n{n}_p{p}", backend.name());
        let kernel = match backend {
            KernelBackend::Scalar => "all_coord_d1_d2_scalar",
            KernelBackend::Simd => "all_coord_d1_d2_simd",
        };
        b.bench(&name, || {
            black_box(all_coord_d1_d2_opts(&pr, &st, &mut ws, threads, backend, block_rows));
        });
        push_entry(entries, b, name.clone(), kernel, n, p, false, 1, threads, seed);
        let median = entries.last().expect("just pushed").median_ns;
        medians.push((backend, name, median));
    }
    let scalar = medians.iter().find(|(bk, _, _)| *bk == KernelBackend::Scalar)?;
    let simd = medians.iter().find(|(bk, _, _)| *bk == KernelBackend::Simd)?;
    // Attribute the ratio to the SIMD row so BENCH readers see it inline.
    if let Some(e) = entries.iter_mut().find(|e| e.name == simd.1) {
        e.speedup_vs_seq = Some(scalar.2 / simd.2);
    }
    Some(SimdGateInfo {
        tracked: simd.1.clone(),
        reference: scalar.1.clone(),
        threads,
        speedup: scalar.2 / simd.2,
    })
}

/// Everything the telemetry-overhead gate tracks for one run.
struct ObsGateInfo {
    tracked: String,
    reference: String,
    threads: usize,
    /// (enabled min / disabled min − 1) × 100 on the tracked workload.
    overhead_pct: f64,
}

impl ObsGateInfo {
    fn passed(&self) -> bool {
        self.overhead_pct <= MAX_OBS_OVERHEAD_PCT
    }
}

/// Benchmark the batched derivative pass with telemetry disabled (the
/// default: every span and counter short-circuits on one relaxed atomic
/// load) and then enabled, same run, same workload. min_ns is compared
/// rather than the median: the gate asks whether instrumentation adds
/// work to the hot path, and the minimum is the cleanest estimate of
/// the undisturbed cost on a noisy runner.
fn bench_obs_gate(
    entries: &mut Vec<Entry>,
    b: &mut Bencher,
    n: usize,
    p: usize,
    seed: u64,
    threads: usize,
) -> ObsGateInfo {
    let pr = synthetic_problem(n, p, seed, false);
    let st = bench_state(&pr, seed ^ 0x5eed);
    let off_name = format!("batched_obs_off_t{threads}_n{n}_p{p}");
    let on_name = format!("batched_obs_on_t{threads}_n{n}_p{p}");
    let mut ws = Workspace::default();
    b.bench(&off_name, || {
        black_box(all_coord_d1_d2_with_threads(&pr, &st, &mut ws, threads));
    });
    push_entry(
        entries,
        b,
        off_name.clone(),
        "all_coord_d1_d2_blocked",
        n,
        p,
        false,
        1,
        threads,
        seed,
    );
    let off_min = entries.last().expect("just pushed").min_ns;
    crate::obs::set_enabled(true);
    crate::obs::reset();
    b.bench(&on_name, || {
        black_box(all_coord_d1_d2_with_threads(&pr, &st, &mut ws, threads));
    });
    crate::obs::set_enabled(false);
    crate::obs::reset();
    push_entry(
        entries,
        b,
        on_name.clone(),
        "all_coord_d1_d2_blocked_traced",
        n,
        p,
        false,
        1,
        threads,
        seed,
    );
    let on_min = entries.last().expect("just pushed").min_ns;
    ObsGateInfo {
        tracked: on_name,
        reference: off_name,
        threads,
        overhead_pct: (on_min / off_min - 1.0) * 100.0,
    }
}

/// Everything the path gate tracks for one run.
struct PathGateInfo {
    tracked: String,
    reference: String,
    speedup: f64,
    endpoint_max_gap: f64,
    n_lambdas: usize,
}

impl PathGateInfo {
    fn passed(&self) -> bool {
        self.speedup >= REQUIRED_PATH_SPEEDUP && self.endpoint_max_gap <= PATH_ENDPOINT_TOL
    }
}

/// Benchmark the warm-started screened λ-path against the same grid
/// solved as independent cold fits (no warm start, no screening). Both
/// are single-shot wall timings — a whole path is the unit of work, and
/// the KKT guarantee makes the two solves land on the same losses, which
/// the gate verifies alongside the speedup.
///
/// The workload is the paper's Appendix C.2 generator at its canonical
/// ρ = 0.9 correlation with a planted sparse signal — the regime path
/// solving is for: supports stay far below p along the grid (screening
/// pays) and cold fits converge slowly from zeros.
fn bench_path(entries: &mut Vec<Entry>, n: usize, p: usize, k: usize, seed: u64) -> PathGateInfo {
    let ds = crate::data::synthetic::generate(&crate::data::synthetic::SyntheticConfig {
        n,
        p,
        rho: 0.9,
        k,
        s: 0.1,
        seed,
    });
    let pr = CoxProblem::new(&ds);
    drop(ds);
    let warm_solver =
        PathSolver { n_lambdas: PATH_N_LAMBDAS, min_ratio: 0.1, ..Default::default() };
    let grid = warm_solver.lambda_grid(&pr).expect("bench problem has usable signal");
    let (warm, warm_dur) = time_once(|| {
        warm_solver.run_grid(&pr, &grid).expect("warm path solve on clean synthetic data")
    });
    let cold_solver = PathSolver { warm_start: false, screen: false, ..warm_solver.clone() };
    let (cold, cold_dur) = time_once(|| {
        cold_solver.run_grid(&pr, &grid).expect("cold path solve on clean synthetic data")
    });
    let mut endpoint_max_gap = 0.0_f64;
    for (a, b) in warm.points.iter().zip(cold.points.iter()) {
        let gap = (a.train_loss - b.train_loss).abs() / (1.0 + b.train_loss.abs());
        endpoint_max_gap = endpoint_max_gap.max(gap);
    }
    let warm_ns = warm_dur.as_nanos() as f64;
    let cold_ns = cold_dur.as_nanos() as f64;
    let warm_name = format!("path_warm_screened_n{n}_p{p}_l{PATH_N_LAMBDAS}");
    let cold_name = format!("path_cold_n{n}_p{p}_l{PATH_N_LAMBDAS}");
    entries.push(Entry {
        name: cold_name.clone(),
        kernel: "path_cold_fits",
        n,
        p,
        ties: false,
        strata: 1,
        threads: num_threads(),
        seed,
        median_ns: cold_ns,
        min_ns: cold_ns,
        mean_ns: cold_ns,
        mad_ns: 0.0,
        samples: 1,
        iters_per_sample: 1,
        speedup_vs_seq: None,
        gate: false,
    });
    entries.push(Entry {
        name: warm_name.clone(),
        kernel: "path_warm_screened",
        n,
        p,
        ties: false,
        strata: 1,
        threads: num_threads(),
        seed,
        median_ns: warm_ns,
        min_ns: warm_ns,
        mean_ns: warm_ns,
        mad_ns: 0.0,
        samples: 1,
        iters_per_sample: 1,
        speedup_vs_seq: Some(cold_ns / warm_ns),
        // Not median-gated: a single-shot wall timing would gate on
        // unaveraged noise under the 25% baseline comparison. The path
        // workload is tracked through the `path_gate` ratio instead,
        // which is noise-robust (both timings share the run).
        gate: false,
    });
    println!(
        "bench {warm_name:<52} {:.3} ms vs cold {:.3} ms — {:.2}x, max endpoint gap {:.2e} \
         (warm {} sweeps vs cold {})",
        warm_ns / 1e6,
        cold_ns / 1e6,
        cold_ns / warm_ns,
        endpoint_max_gap,
        warm.total_sweeps(),
        cold.total_sweeps(),
    );
    PathGateInfo {
        tracked: warm_name,
        reference: cold_name,
        speedup: cold_ns / warm_ns,
        endpoint_max_gap,
        n_lambdas: PATH_N_LAMBDAS,
    }
}

/// `fastsurvival bench [--quick] [--full] [--out F] [--check BASELINE]
/// [--backend scalar|simd|auto] [--threads N]`.
pub fn run(args: &Args) -> Result<()> {
    let quick = args.flag("quick")
        || std::env::var("FASTSURVIVAL_BENCH_QUICK").as_deref() == Ok("1");
    let full = args.flag("full");
    let out_path = args.str_or("out", "BENCH_optim.json");
    // The backend sweep: both backends by default (the simd gate needs
    // the scalar reference); `--backend scalar` profiles scalar alone
    // and skips the ratio gate.
    let sweep_backends: Vec<KernelBackend> = match args.get("backend") {
        None => vec![KernelBackend::Scalar, KernelBackend::Simd],
        Some(name) => match Backend::from_name(name)? {
            Backend::Scalar => vec![KernelBackend::Scalar],
            Backend::Simd | Backend::Auto => {
                vec![KernelBackend::Scalar, KernelBackend::Simd]
            }
        },
    };
    let sweep_threads = args.get_or("threads", 4usize).max(1);
    let sizes = Sizes::pick(quick);
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let mut entries: Vec<Entry> = Vec::new();

    println!(
        "== bench: blocked parallel derivative kernels (quick={quick}, full={full}, \
         {} threads available) ==",
        num_threads()
    );

    // --- The tracked workload: continuous times, n_main × p_main. -----
    let (ref_idx, gate_idx) = bench_batched_pair(
        &mut entries,
        &mut b,
        sizes.n_main,
        sizes.p_main,
        42,
        false,
        "",
    );
    entries[gate_idx].gate = true;
    let gate_speedup = entries[gate_idx].speedup_vs_seq.expect("blocked entry has speedup");
    let gate_tracked = entries[gate_idx].name.clone();
    let gate_reference = entries[ref_idx].name.clone();

    // --- Backend sweep on the tracked workload: scalar vs SIMD lanes
    // at the same worker count (the simd_gate ratio). ------------------
    let simd_gate = bench_backend_sweep(
        &mut entries,
        &mut b,
        sizes.n_main,
        sizes.p_main,
        42,
        sweep_threads,
        &sweep_backends,
    );

    // --- Telemetry overhead on the tracked workload: spans + counters
    // disabled vs enabled (the obs_gate ratio). ------------------------
    let obs_gate = bench_obs_gate(
        &mut entries,
        &mut b,
        sizes.n_main,
        sizes.p_main,
        42,
        sweep_threads,
    );

    // --- Tied times. --------------------------------------------------
    bench_batched_pair(&mut entries, &mut b, sizes.n_ties, sizes.p_ties, 43, true, "_ties");

    // --- Path workload: warm+screened λ-path vs independent cold fits. -
    let path_gate = bench_path(&mut entries, sizes.n_path, sizes.p_path, sizes.k_path, 49);

    // --- Paper-scale extremes (memory-heavy; opt-in). -----------------
    if full {
        bench_batched_pair(&mut entries, &mut b, 100_000, 500, 44, false, "");
        bench_batched_pair(&mut entries, &mut b, 50_000, 1_000, 45, false, "");
    }

    // --- Stratified: per-coordinate loop vs batched-per-stratum. ------
    {
        let n = sizes.n_strat;
        let p = sizes.p_strat;
        let nstrata = sizes.strata;
        let mut rng = Rng::new(46);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        let labels: Vec<usize> = (0..n).map(|i| i % nstrata).collect();
        let ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "bench-strat");
        let sp = StratifiedCoxProblem::new(&ds, &labels);
        drop(ds);
        let mut states = sp.zero_states();
        for (pr, st) in sp.strata.iter().zip(states.iter_mut()) {
            st.update_coord(pr, 0, 0.1);
        }
        b.bench(&format!("stratified_percoord_n{n}_p{p}_s{nstrata}"), || {
            for l in 0..p {
                black_box(sp.coord_d1_d2(&states, l));
            }
        });
        push_entry(
            &mut entries,
            &b,
            format!("stratified_percoord_n{n}_p{p}_s{nstrata}"),
            "stratified_coord_d1_d2_loop",
            n,
            p,
            false,
            nstrata,
            1,
            46,
        );
        let ref_median = entries.last().expect("just pushed").median_ns;
        let mut wss = sp.workspaces();
        b.bench(&format!("stratified_batched_n{n}_p{p}_s{nstrata}"), || {
            black_box(sp.all_coord_d1_d2(&states, &mut wss));
        });
        push_entry(
            &mut entries,
            &b,
            format!("stratified_batched_n{n}_p{p}_s{nstrata}"),
            "stratified_all_coord_d1_d2",
            n,
            p,
            false,
            nstrata,
            num_threads(),
            46,
        );
        let e = entries.last_mut().expect("just pushed");
        e.speedup_vs_seq = Some(ref_median / e.median_ns);
    }

    // --- Incremental state maintenance vs full re-exponentiation. -----
    {
        let n = sizes.n_state;
        let pr = synthetic_problem(n, 4, 47, false);
        let mut st = bench_state(&pr, 48);
        let mut sign = 1.0_f64;
        b.bench(&format!("state_update_coord_n{n}"), || {
            // Alternating ±Δ keeps η bounded across samples.
            st.update_coord(&pr, 0, sign * 1e-3);
            sign = -sign;
            black_box(st.w[0]);
        });
        push_entry(
            &mut entries,
            &b,
            format!("state_update_coord_n{n}"),
            "state_update_coord",
            n,
            4,
            false,
            1,
            1,
            47,
        );
        let inc_median = entries.last().expect("just pushed").median_ns;
        let beta = st.beta.clone();
        b.bench(&format!("state_set_beta_n{n}"), || {
            st.set_beta(&pr, &beta);
            black_box(st.w[0]);
        });
        push_entry(
            &mut entries,
            &b,
            format!("state_set_beta_n{n}"),
            "state_set_beta_full",
            n,
            4,
            false,
            1,
            1,
            47,
        );
        let full_median = entries.last().expect("just pushed").median_ns;
        // Attribute the speedup to the incremental entry.
        let idx = entries.len() - 2;
        entries[idx].speedup_vs_seq = Some(full_median / inc_median);
    }

    b.summary("bench");
    println!(
        "\ngate: {gate_tracked} vs {gate_reference}: speedup {:.2}x (required {:.1}x) — {}",
        gate_speedup,
        REQUIRED_SPEEDUP,
        if gate_speedup >= REQUIRED_SPEEDUP { "OK" } else { "BELOW TARGET" }
    );
    println!(
        "path gate: {} vs {}: speedup {:.2}x (required {:.1}x), endpoint gap {:.2e} \
         (tol {PATH_ENDPOINT_TOL:.0e}) — {}",
        path_gate.tracked,
        path_gate.reference,
        path_gate.speedup,
        REQUIRED_PATH_SPEEDUP,
        path_gate.endpoint_max_gap,
        if path_gate.passed() { "OK" } else { "BELOW TARGET" }
    );
    match &simd_gate {
        Some(sg) => println!(
            "simd gate: {} vs {}: speedup {:.2}x (required {:.1}x) — {}",
            sg.tracked,
            sg.reference,
            sg.speedup,
            REQUIRED_SIMD_SPEEDUP,
            if sg.passed() { "OK" } else { "BELOW TARGET" }
        ),
        None => println!("simd gate: skipped (--backend restricted the sweep to one backend)"),
    }
    println!(
        "obs gate: {} vs {}: overhead {:.2}% (max {MAX_OBS_OVERHEAD_PCT:.1}%) — {}",
        obs_gate.tracked,
        obs_gate.reference,
        obs_gate.overhead_pct,
        if obs_gate.passed() { "OK" } else { "ABOVE BUDGET" }
    );

    let doc = render_json(
        quick,
        full,
        &entries,
        &gate_tracked,
        &gate_reference,
        gate_speedup,
        &path_gate,
        simd_gate.as_ref(),
        &obs_gate,
    );
    std::fs::write(&out_path, &doc)
        .map_err(|e| FastSurvivalError::io(format!("writing {out_path}"), e))?;
    println!("wrote {out_path} ({} entries)", entries.len());

    if let Some(baseline) = args.get("check") {
        check_against_baseline(
            &entries,
            gate_speedup,
            &path_gate,
            simd_gate.as_ref(),
            &obs_gate,
            Path::new(baseline),
        )?;
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn render_json(
    quick: bool,
    full: bool,
    entries: &[Entry],
    gate_tracked: &str,
    gate_reference: &str,
    gate_speedup: f64,
    path_gate: &PathGateInfo,
    simd_gate: Option<&SimdGateInfo>,
    obs_gate: &ObsGateInfo,
) -> String {
    let mut out = String::with_capacity(4096);
    out.push_str("{\n");
    out.push_str("  \"schema_version\": 1,\n");
    out.push_str("  \"suite\": \"fastsurvival-bench\",\n");
    // Emitted so a run can be committed as ci/bench_baseline.json as-is:
    // flip `bootstrap` to arm/disarm absolute comparisons; `--check`
    // reads `tolerance_pct` from this top level.
    out.push_str("  \"bootstrap\": false,\n");
    out.push_str("  \"tolerance_pct\": ");
    json::write_f64(&mut out, DEFAULT_TOLERANCE_PCT);
    out.push_str(",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!("  \"full\": {full},\n"));
    out.push_str(&format!("  \"threads_available\": {},\n", num_threads()));
    out.push_str("  \"gate\": {\n");
    out.push_str("    \"tracked\": ");
    json::write_str(&mut out, gate_tracked);
    out.push_str(",\n    \"reference\": ");
    json::write_str(&mut out, gate_reference);
    out.push_str(",\n    \"speedup_vs_seq\": ");
    json::write_f64(&mut out, gate_speedup);
    out.push_str(",\n    \"required_speedup\": ");
    json::write_f64(&mut out, REQUIRED_SPEEDUP);
    out.push_str(",\n    \"tolerance_pct\": ");
    json::write_f64(&mut out, DEFAULT_TOLERANCE_PCT);
    out.push_str(&format!(",\n    \"passed\": {}\n  }},\n", gate_speedup >= REQUIRED_SPEEDUP));
    out.push_str("  \"path_gate\": {\n");
    out.push_str("    \"tracked\": ");
    json::write_str(&mut out, &path_gate.tracked);
    out.push_str(",\n    \"reference\": ");
    json::write_str(&mut out, &path_gate.reference);
    out.push_str(&format!(",\n    \"n_lambdas\": {}", path_gate.n_lambdas));
    out.push_str(",\n    \"speedup_warm_vs_cold\": ");
    json::write_f64(&mut out, path_gate.speedup);
    out.push_str(",\n    \"required_speedup\": ");
    json::write_f64(&mut out, REQUIRED_PATH_SPEEDUP);
    out.push_str(",\n    \"endpoint_max_gap\": ");
    json::write_f64(&mut out, path_gate.endpoint_max_gap);
    out.push_str(",\n    \"endpoint_tol\": ");
    json::write_f64(&mut out, PATH_ENDPOINT_TOL);
    out.push_str(&format!(",\n    \"passed\": {}\n  }},\n", path_gate.passed()));
    if let Some(sg) = simd_gate {
        out.push_str("  \"simd_gate\": {\n");
        out.push_str("    \"tracked\": ");
        json::write_str(&mut out, &sg.tracked);
        out.push_str(",\n    \"reference\": ");
        json::write_str(&mut out, &sg.reference);
        out.push_str(&format!(",\n    \"threads\": {}", sg.threads));
        out.push_str(",\n    \"speedup_simd_vs_scalar\": ");
        json::write_f64(&mut out, sg.speedup);
        out.push_str(",\n    \"required_speedup\": ");
        json::write_f64(&mut out, REQUIRED_SIMD_SPEEDUP);
        out.push_str(&format!(",\n    \"passed\": {}\n  }},\n", sg.passed()));
    }
    out.push_str("  \"obs_gate\": {\n");
    out.push_str("    \"tracked\": ");
    json::write_str(&mut out, &obs_gate.tracked);
    out.push_str(",\n    \"reference\": ");
    json::write_str(&mut out, &obs_gate.reference);
    out.push_str(&format!(",\n    \"threads\": {}", obs_gate.threads));
    out.push_str(",\n    \"overhead_pct\": ");
    json::write_f64(&mut out, obs_gate.overhead_pct);
    out.push_str(",\n    \"max_overhead_pct\": ");
    json::write_f64(&mut out, MAX_OBS_OVERHEAD_PCT);
    out.push_str(&format!(",\n    \"passed\": {}\n  }},\n", obs_gate.passed()));
    out.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        out.push_str("    {\"name\": ");
        json::write_str(&mut out, &e.name);
        out.push_str(", \"kernel\": ");
        json::write_str(&mut out, e.kernel);
        out.push_str(&format!(
            ", \"n\": {}, \"p\": {}, \"ties\": {}, \"strata\": {}, \"threads\": {}, \
             \"seed\": {}",
            e.n, e.p, e.ties, e.strata, e.threads, e.seed
        ));
        out.push_str(", \"median_ns\": ");
        json::write_f64(&mut out, e.median_ns);
        out.push_str(", \"min_ns\": ");
        json::write_f64(&mut out, e.min_ns);
        out.push_str(", \"mean_ns\": ");
        json::write_f64(&mut out, e.mean_ns);
        out.push_str(", \"mad_ns\": ");
        json::write_f64(&mut out, e.mad_ns);
        out.push_str(&format!(
            ", \"samples\": {}, \"iters_per_sample\": {}",
            e.samples, e.iters_per_sample
        ));
        out.push_str(", \"ns_per_cell\": ");
        json::write_f64(&mut out, e.median_ns / (e.n as f64 * e.p as f64));
        out.push_str(", \"speedup_vs_seq\": ");
        match e.speedup_vs_seq {
            Some(s) => json::write_f64(&mut out, s),
            None => out.push_str("null"),
        }
        out.push_str(&format!(", \"gate\": {}}}", e.gate));
        out.push_str(if i + 1 < entries.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// The CI perf gate: compare this run against a committed baseline.
fn check_against_baseline(
    entries: &[Entry],
    gate_speedup: f64,
    path_gate: &PathGateInfo,
    simd_gate: Option<&SimdGateInfo>,
    obs_gate: &ObsGateInfo,
    baseline_path: &Path,
) -> Result<()> {
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(_) => {
            println!(
                "perf gate: no baseline at {} — recording only (commit one with \
                 `bench --quick --out {}`)",
                baseline_path.display(),
                baseline_path.display()
            );
            return Ok(());
        }
    };
    let doc = json::parse(&text)?;
    let bootstrap = doc
        .get("bootstrap")
        .map(|b| b.as_bool().unwrap_or(false))
        .unwrap_or(false);
    let tol_pct = doc
        .get("tolerance_pct")
        .map(|t| t.as_f64().unwrap_or(DEFAULT_TOLERANCE_PCT))
        .unwrap_or(DEFAULT_TOLERANCE_PCT);
    // Machine-independent invariant: the tracked blocked kernel must
    // never clearly lose to the sequential reference it replaced (slack
    // absorbs scheduler noise on smoke-size workloads; a bootstrap
    // baseline downgrades the failure to advisory like everything else).
    if gate_speedup < INVARIANT_MIN_SPEEDUP {
        let msg = format!(
            "blocked parallel batched pass is slower than the sequential reference \
             (speedup {gate_speedup:.2}x < {INVARIANT_MIN_SPEEDUP}x)"
        );
        if bootstrap {
            println!("perf gate: bootstrap baseline; advisory only: {msg}");
        } else {
            return Err(FastSurvivalError::PerfRegression(msg));
        }
    } else if gate_speedup < 1.0 {
        println!(
            "perf gate: warning — blocked pass barely trails the sequential \
             reference ({gate_speedup:.2}x); within noise tolerance, not failing"
        );
    }
    // The warm-vs-cold path gate: both timings come from the same run on
    // the same machine, so (unlike absolute medians) the ratio is armed
    // independently of `bootstrap` whenever the baseline opts in with
    // `path_gate.enforce`.
    if let Some(pg) = doc.get("path_gate") {
        let enforce = pg.get("enforce").map(|b| b.as_bool().unwrap_or(false)).unwrap_or(false);
        let required = pg
            .get("required_speedup")
            .map(|v| v.as_f64().unwrap_or(REQUIRED_PATH_SPEEDUP))
            .unwrap_or(REQUIRED_PATH_SPEEDUP);
        let endpoint_tol = pg
            .get("endpoint_tol")
            .map(|v| v.as_f64().unwrap_or(PATH_ENDPOINT_TOL))
            .unwrap_or(PATH_ENDPOINT_TOL);
        let mut problems: Vec<String> = Vec::new();
        if path_gate.speedup < required {
            problems.push(format!(
                "warm-started screened path is only {:.2}x faster than cold fits \
                 (required {required:.1}x)",
                path_gate.speedup
            ));
        }
        if path_gate.endpoint_max_gap.is_nan() || path_gate.endpoint_max_gap > endpoint_tol {
            problems.push(format!(
                "warm path losses drift {:.2e} from cold fits (tol {endpoint_tol:.0e})",
                path_gate.endpoint_max_gap
            ));
        }
        if problems.is_empty() {
            println!(
                "perf gate: path warm-vs-cold {:.2}x (required {required:.1}x), endpoint \
                 gap {:.2e} — ok",
                path_gate.speedup, path_gate.endpoint_max_gap
            );
        } else if enforce {
            return Err(FastSurvivalError::PerfRegression(problems.join("; ")));
        } else {
            println!("perf gate: path gate advisory (enforce=false):\n  {}", problems.join("\n  "));
        }
    }
    // The SIMD-vs-scalar gate: same-machine same-run ratio, armed by the
    // baseline's `simd_gate.enforce` like the path gate above.
    if let Some(sg_base) = doc.get("simd_gate") {
        let enforce =
            sg_base.get("enforce").map(|b| b.as_bool().unwrap_or(false)).unwrap_or(false);
        let required = sg_base
            .get("required_speedup")
            .map(|v| v.as_f64().unwrap_or(REQUIRED_SIMD_SPEEDUP))
            .unwrap_or(REQUIRED_SIMD_SPEEDUP);
        match simd_gate {
            None => {
                let msg = "baseline enforces the simd gate but this run skipped the \
                           backend sweep (drop --backend to run both backends)"
                    .to_string();
                if enforce {
                    return Err(FastSurvivalError::PerfRegression(msg));
                }
                println!("perf gate: simd gate advisory (enforce=false): {msg}");
            }
            Some(sg) => {
                if sg.speedup.is_nan() || sg.speedup < required {
                    let msg = format!(
                        "SIMD lane kernels are only {:.2}x the scalar backend on the \
                         tracked workload (required {required:.1}x)",
                        sg.speedup
                    );
                    if enforce {
                        return Err(FastSurvivalError::PerfRegression(msg));
                    }
                    println!("perf gate: simd gate advisory (enforce=false): {msg}");
                } else {
                    println!(
                        "perf gate: simd-vs-scalar {:.2}x (required {required:.1}x) — ok",
                        sg.speedup
                    );
                }
            }
        }
    }
    // The telemetry-overhead gate: enabled-vs-disabled ratio from this
    // run, armed by the baseline's `obs_gate.enforce` like the gates
    // above. NaN (degenerate timings) fails rather than passing silently.
    if let Some(og_base) = doc.get("obs_gate") {
        let enforce =
            og_base.get("enforce").map(|b| b.as_bool().unwrap_or(false)).unwrap_or(false);
        let max_pct = og_base
            .get("max_overhead_pct")
            .map(|v| v.as_f64().unwrap_or(MAX_OBS_OVERHEAD_PCT))
            .unwrap_or(MAX_OBS_OVERHEAD_PCT);
        if obs_gate.overhead_pct.is_nan() || obs_gate.overhead_pct > max_pct {
            let msg = format!(
                "enabled telemetry slows the tracked batched pass by {:.2}% \
                 (budget {max_pct:.1}%)",
                obs_gate.overhead_pct
            );
            if enforce {
                return Err(FastSurvivalError::PerfRegression(msg));
            }
            println!("perf gate: obs gate advisory (enforce=false): {msg}");
        } else {
            println!(
                "perf gate: telemetry overhead {:.2}% (budget {max_pct:.1}%) — ok",
                obs_gate.overhead_pct
            );
        }
    }
    let baseline_entries = match doc.get("entries") {
        Some(arr) => arr.as_array()?.to_vec(),
        None => Vec::new(),
    };
    let mut failures: Vec<String> = Vec::new();
    for be in &baseline_entries {
        let gated = be.get("gate").map(|g| g.as_bool().unwrap_or(false)).unwrap_or(false);
        if !gated {
            continue;
        }
        let name = be.require("name")?.as_str()?.to_string();
        let base_median = be.require("median_ns")?.as_f64()?;
        let Some(cur) = entries.iter().find(|e| e.name == name) else {
            failures.push(format!("tracked kernel {name:?} missing from this run"));
            continue;
        };
        let ratio = cur.median_ns / base_median;
        let verdict = if ratio > 1.0 + tol_pct / 100.0 { "REGRESSED" } else { "ok" };
        println!(
            "perf gate: {name}: {:.3} ms vs baseline {:.3} ms ({:.0}% — {verdict})",
            cur.median_ns / 1e6,
            base_median / 1e6,
            ratio * 100.0
        );
        if ratio > 1.0 + tol_pct / 100.0 {
            failures.push(format!(
                "{name}: {ratio:.2}x the baseline median (tolerance {tol_pct:.0}%)"
            ));
        }
    }
    if !failures.is_empty() {
        if bootstrap {
            println!(
                "perf gate: baseline is marked bootstrap (timings not from gate \
                 hardware); advisory only:\n  {}",
                failures.join("\n  ")
            );
            return Ok(());
        }
        return Err(FastSurvivalError::PerfRegression(failures.join("; ")));
    }
    println!("perf gate: OK (speedup {gate_speedup:.2}x, {} gated kernels)", {
        baseline_entries
            .iter()
            .filter(|be| be.get("gate").map(|g| g.as_bool().unwrap_or(false)).unwrap_or(false))
            .count()
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pg(speedup: f64, gap: f64) -> PathGateInfo {
        PathGateInfo {
            tracked: "path_warm_screened_n100_p8_l50".into(),
            reference: "path_cold_n100_p8_l50".into(),
            speedup,
            endpoint_max_gap: gap,
            n_lambdas: 50,
        }
    }

    fn sg(speedup: f64) -> SimdGateInfo {
        SimdGateInfo {
            tracked: "batched_simd_t4_n2000_p24".into(),
            reference: "batched_scalar_t4_n2000_p24".into(),
            threads: 4,
            speedup,
        }
    }

    fn og(overhead_pct: f64) -> ObsGateInfo {
        ObsGateInfo {
            tracked: "batched_obs_on_t4_n2000_p24".into(),
            reference: "batched_obs_off_t4_n2000_p24".into(),
            threads: 4,
            overhead_pct,
        }
    }

    #[test]
    fn path_gate_enforced_only_when_baseline_opts_in() {
        let dir = std::env::temp_dir().join("fs_perf_path_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let armed = dir.join("armed.json");
        std::fs::write(
            &armed,
            "{\"bootstrap\": true, \"entries\": [], \
              \"path_gate\": {\"enforce\": true, \"required_speedup\": 3.0, \
              \"endpoint_tol\": 1e-8}}",
        )
        .unwrap();
        // Healthy run passes (bootstrap does not disarm the ratio gate).
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(0.2), &armed)
            .expect("healthy path gate");
        // Too-slow warm path fails.
        let err =
            check_against_baseline(&[], 2.0, &pg(1.5, 1e-12), Some(&sg(2.0)), &og(0.2), &armed)
                .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)), "got {err}");
        // Endpoint drift fails.
        let err = check_against_baseline(&[], 2.0, &pg(8.0, 1e-3), Some(&sg(2.0)), &og(0.2), &armed)
            .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)), "got {err}");
        // NaN drift (corrupt losses) fails rather than passing silently.
        let err =
            check_against_baseline(&[], 2.0, &pg(8.0, f64::NAN), Some(&sg(2.0)), &og(0.2), &armed)
                .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)), "got {err}");
        // Without enforce, the same shortfall is advisory.
        let advisory = dir.join("advisory.json");
        std::fs::write(
            &advisory,
            "{\"bootstrap\": true, \"entries\": [], \"path_gate\": {\"enforce\": false}}",
        )
        .unwrap();
        check_against_baseline(&[], 2.0, &pg(1.5, 1e-3), Some(&sg(2.0)), &og(0.2), &advisory)
            .expect("advisory path gate must not fail");
        // A baseline with no path_gate object skips the check entirely.
        let silent = dir.join("silent.json");
        std::fs::write(&silent, "{\"bootstrap\": true, \"entries\": []}").unwrap();
        check_against_baseline(&[], 2.0, &pg(0.5, 1.0), Some(&sg(2.0)), &og(0.2), &silent)
            .expect("no path gate");
    }

    #[test]
    fn simd_gate_enforced_only_when_baseline_opts_in() {
        let dir = std::env::temp_dir().join("fs_perf_simd_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let armed = dir.join("armed.json");
        std::fs::write(
            &armed,
            "{\"bootstrap\": true, \"entries\": [], \
              \"simd_gate\": {\"enforce\": true, \"required_speedup\": 1.3}}",
        )
        .unwrap();
        // Healthy SIMD speedup passes.
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(1.5)), &og(0.2), &armed)
            .expect("healthy simd gate");
        // Too-slow SIMD kernels fail.
        let err =
            check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(1.1)), &og(0.2), &armed)
                .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)), "got {err}");
        // NaN ratio (degenerate timings) fails rather than passing silently.
        let err =
            check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(f64::NAN)), &og(0.2), &armed)
                .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)), "got {err}");
        // A run that skipped the sweep (--backend restricted it) fails an armed gate.
        let err = check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), None, &og(0.2), &armed)
            .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)), "got {err}");
        // Without enforce, the same shortfall is advisory.
        let advisory = dir.join("advisory.json");
        std::fs::write(
            &advisory,
            "{\"bootstrap\": true, \"entries\": [], \"simd_gate\": {\"enforce\": false}}",
        )
        .unwrap();
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(1.1)), &og(0.2), &advisory)
            .expect("advisory simd gate must not fail");
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), None, &og(0.2), &advisory)
            .expect("advisory simd gate tolerates a skipped sweep");
        // A baseline with no simd_gate object skips the check entirely.
        let silent = dir.join("silent.json");
        std::fs::write(&silent, "{\"bootstrap\": true, \"entries\": []}").unwrap();
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(0.2)), &og(0.2), &silent)
            .expect("no simd gate");
    }

    #[test]
    fn obs_gate_enforced_only_when_baseline_opts_in() {
        let dir = std::env::temp_dir().join("fs_perf_obs_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let armed = dir.join("armed.json");
        std::fs::write(
            &armed,
            "{\"bootstrap\": true, \"entries\": [], \
              \"obs_gate\": {\"enforce\": true, \"max_overhead_pct\": 1.0}}",
        )
        .unwrap();
        // Overhead within budget passes (bootstrap does not disarm the ratio gate).
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(0.5), &armed)
            .expect("healthy obs gate");
        // Negative overhead (enabled run landed faster — pure noise) passes.
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(-0.3), &armed)
            .expect("negative overhead is within budget");
        // Over-budget overhead fails.
        let err =
            check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(4.0), &armed)
                .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)), "got {err}");
        // NaN overhead (degenerate timings) fails rather than passing silently.
        let err = check_against_baseline(
            &[],
            2.0,
            &pg(8.0, 1e-12),
            Some(&sg(2.0)),
            &og(f64::NAN),
            &armed,
        )
        .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)), "got {err}");
        // Without enforce, the same overrun is advisory.
        let advisory = dir.join("advisory.json");
        std::fs::write(
            &advisory,
            "{\"bootstrap\": true, \"entries\": [], \"obs_gate\": {\"enforce\": false}}",
        )
        .unwrap();
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(4.0), &advisory)
            .expect("advisory obs gate must not fail");
        // A baseline with no obs_gate object skips the check entirely.
        let silent = dir.join("silent.json");
        std::fs::write(&silent, "{\"bootstrap\": true, \"entries\": []}").unwrap();
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(50.0), &silent)
            .expect("no obs gate");
    }

    #[test]
    fn json_document_parses_and_round_trips_gate_fields() {
        let entries = vec![Entry {
            name: "batched_seq_n100_p8".into(),
            kernel: "all_coord_d1_d2_seq",
            n: 100,
            p: 8,
            ties: false,
            strata: 1,
            threads: 1,
            seed: 42,
            median_ns: 1234.5,
            min_ns: 1200.0,
            mean_ns: 1250.0,
            mad_ns: 10.0,
            samples: 5,
            iters_per_sample: 3,
            speedup_vs_seq: Some(2.5),
            gate: true,
        }];
        let doc = render_json(
            true,
            false,
            &entries,
            "tracked",
            "ref",
            2.5,
            &pg(6.5, 2e-12),
            Some(&sg(1.8)),
            &og(0.4),
        );
        let parsed = json::parse(&doc).expect("self-emitted JSON must parse");
        assert_eq!(parsed.require("schema_version").unwrap().as_usize().unwrap(), 1);
        let gate = parsed.require("gate").unwrap();
        assert_eq!(gate.require("tracked").unwrap().as_str().unwrap(), "tracked");
        assert!(gate.require("passed").unwrap().as_bool().unwrap());
        let pgate = parsed.require("path_gate").unwrap();
        assert!(
            (pgate.require("speedup_warm_vs_cold").unwrap().as_f64().unwrap() - 6.5).abs()
                < 1e-12
        );
        assert_eq!(pgate.require("n_lambdas").unwrap().as_usize().unwrap(), 50);
        assert!(pgate.require("passed").unwrap().as_bool().unwrap());
        let sgate = parsed.require("simd_gate").unwrap();
        assert!(
            (sgate.require("speedup_simd_vs_scalar").unwrap().as_f64().unwrap() - 1.8).abs()
                < 1e-12
        );
        assert_eq!(sgate.require("threads").unwrap().as_usize().unwrap(), 4);
        assert!(sgate.require("passed").unwrap().as_bool().unwrap());
        let ogate = parsed.require("obs_gate").unwrap();
        assert!((ogate.require("overhead_pct").unwrap().as_f64().unwrap() - 0.4).abs() < 1e-12);
        assert!(
            (ogate.require("max_overhead_pct").unwrap().as_f64().unwrap()
                - MAX_OBS_OVERHEAD_PCT)
                .abs()
                < 1e-12
        );
        assert!(ogate.require("passed").unwrap().as_bool().unwrap());
        let arr = parsed.require("entries").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].require("n").unwrap().as_usize().unwrap(), 100);
        assert!((arr[0].require("speedup_vs_seq").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
        assert!(arr[0].require("gate").unwrap().as_bool().unwrap());
    }

    #[test]
    fn gate_rejects_parallel_clearly_slower_than_sequential() {
        let dir = std::env::temp_dir().join("fs_perf_invariant_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("armed_baseline.json");
        std::fs::write(&path, "{\"bootstrap\": false, \"entries\": []}").unwrap();
        let err = check_against_baseline(&[], 0.5, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(0.2), &path)
            .unwrap_err();
        assert!(
            matches!(err, FastSurvivalError::PerfRegression(_)),
            "expected PerfRegression, got {err}"
        );
        // Marginal shortfalls stay within the noise floor and pass.
        check_against_baseline(&[], 0.9, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(0.2), &path)
            .expect("within INVARIANT_MIN_SPEEDUP slack");
        // A bootstrap baseline downgrades even a clear shortfall to advisory.
        let boot = dir.join("bootstrap_baseline.json");
        std::fs::write(&boot, "{\"bootstrap\": true, \"entries\": []}").unwrap();
        check_against_baseline(&[], 0.5, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(0.2), &boot)
            .expect("bootstrap invariant is advisory");
    }

    #[test]
    fn gate_passes_without_baseline_file() {
        // Recording-only mode: no baseline means nothing to compare, even
        // the invariant (there is no armed gate to protect yet).
        let missing = Path::new("/nonexistent/baseline.json");
        check_against_baseline(&[], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(0.2), missing)
            .expect("missing baseline must degrade to recording-only");
        check_against_baseline(&[], 0.5, &pg(0.5, 1.0), Some(&sg(0.8)), &og(0.8), missing)
            .expect("missing baseline skips the invariant too");
    }

    #[test]
    fn gate_compares_against_committed_baseline() {
        let dir = std::env::temp_dir().join("fs_perf_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("baseline.json");
        std::fs::write(
            &path,
            "{\"tolerance_pct\": 25, \"entries\": [\
              {\"name\": \"k\", \"median_ns\": 1000.0, \"gate\": true}]}",
        )
        .unwrap();
        let mk = |median_ns: f64| Entry {
            name: "k".into(),
            kernel: "all_coord_d1_d2_blocked",
            n: 10,
            p: 2,
            ties: false,
            strata: 1,
            threads: 4,
            seed: 1,
            median_ns,
            min_ns: median_ns,
            mean_ns: median_ns,
            mad_ns: 0.0,
            samples: 5,
            iters_per_sample: 1,
            speedup_vs_seq: Some(2.0),
            gate: true,
        };
        // Within tolerance: 20% slower passes.
        check_against_baseline(&[mk(1200.0)], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(0.2), &path)
            .expect("within tolerance");
        // Past tolerance: 50% slower fails.
        let err = check_against_baseline(
            &[mk(1500.0)],
            2.0,
            &pg(8.0, 1e-12),
            Some(&sg(2.0)),
            &og(0.2),
            &path,
        )
        .unwrap_err();
        assert!(matches!(err, FastSurvivalError::PerfRegression(_)));
        // A bootstrap baseline downgrades the same failure to advisory.
        std::fs::write(
            &path,
            "{\"bootstrap\": true, \"tolerance_pct\": 25, \"entries\": [\
              {\"name\": \"k\", \"median_ns\": 1000.0, \"gate\": true}]}",
        )
        .unwrap();
        check_against_baseline(&[mk(1500.0)], 2.0, &pg(8.0, 1e-12), Some(&sg(2.0)), &og(0.2), &path)
            .expect("bootstrap is advisory");
    }
}
