//! The Layer-3 coordinator: fit driver (engine-generic coordinate
//! descent), k-fold cross-validation, and the experiment harness that
//! regenerates every table and figure of the paper.

pub mod cv;
pub mod driver;
pub mod experiments;

pub use cv::{cv_selector, CvRow};
pub use driver::{fit_with_engine, EngineFitConfig};
