//! The Layer-3 coordinator: cross-validation (path-based since the
//! warm-started path refactor) and the experiment harness that
//! regenerates every table and figure of the paper.
//!
//! The old engine-specific fit driver is gone: engine selection now
//! threads through [`crate::optim::Optimizer::fit_from`] and the
//! [`crate::api::CoxFit`] builder, so there is exactly one fit path.

pub mod bigfit;
pub mod cv;
pub mod experiments;
pub mod inspect;
pub mod perf;
pub mod profile;

pub use cv::{
    cv_cardinality_path, cv_l1_path, cv_selector, CvRow, PathCvResult, SelectionCriterion,
};
