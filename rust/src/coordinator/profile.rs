//! The `profile` subcommand: read a telemetry file and print the right
//! report for what it holds.
//!
//! Two input kinds are auto-detected:
//!
//! * a **training trace** (`fit/path/bigfit/watch --trace-out` JSONL) —
//!   rendered as a self-time-sorted phase table, a wall-clock
//!   reconciliation, and the engine counters;
//! * **serve request records** (an access-log JSONL or a `/debug/trace`
//!   flight-recorder dump) — rendered as per-endpoint stage tables with
//!   exact p50/p99 per lifecycle stage and the queue-wait share of
//!   total request time.
//!
//! Self-time is what the training table ranks by: a phase's total minus
//! the time spent inside nested instrumented phases, so the column sums
//! to the run's wall clock instead of double-counting parents and
//! children. Parallel phases (the sharded worker legs) accumulate
//! across worker threads concurrently, so their self-time can
//! legitimately exceed the wall clock — they are reconciled and listed
//! separately.

use crate::api::json;
use crate::error::{FastSurvivalError, Result};
use crate::obs::hist::quantile_from_counts;
use crate::obs::recorder::{parse_request_records, ParsedRequest, Stage};
use crate::obs::{parse_trace_jsonl, TraceDoc};
use crate::util::args::Args;

/// Largest tolerated |serial self-sum − wall| / wall before the
/// reconciliation line flags the trace as incomplete.
const RECONCILE_TOL: f64 = 0.05;

/// One row of the rendered table, precomputed from a phase line.
struct Row {
    phase: String,
    parallel: bool,
    count: u64,
    total_ms: f64,
    self_ms: f64,
    p50_us: f64,
    p99_us: f64,
}

/// Render the profile report for a parsed trace document.
pub fn render(doc: &TraceDoc) -> String {
    let mut rows: Vec<Row> = doc
        .phases
        .iter()
        .map(|p| Row {
            phase: p.phase.clone(),
            parallel: p.parallel,
            count: p.count,
            total_ms: p.total_ns as f64 / 1e6,
            self_ms: p.self_ns as f64 / 1e6,
            p50_us: quantile_from_counts(&p.buckets_us_log2, 0.50),
            p99_us: quantile_from_counts(&p.buckets_us_log2, 0.99),
        })
        .collect();
    rows.sort_by(|a, b| {
        b.self_ms.partial_cmp(&a.self_ms).unwrap_or(std::cmp::Ordering::Equal)
    });

    let wall_ms = doc.wall_secs * 1e3;
    let serial_self_ms: f64 =
        rows.iter().filter(|r| !r.parallel).map(|r| r.self_ms).sum();
    let parallel_self_ms: f64 =
        rows.iter().filter(|r| r.parallel).map(|r| r.self_ms).sum();

    let mut out = String::with_capacity(2048);
    out.push_str(&format!(
        "profile: cmd={} wall={:.1} ms threads={}\n\n",
        doc.cmd, wall_ms, doc.threads
    ));
    out.push_str(&format!(
        "{:<20} {:>10} {:>12} {:>12} {:>7} {:>10} {:>10}\n",
        "phase", "count", "total ms", "self ms", "self %", "p50 us", "p99 us"
    ));
    for r in rows.iter().filter(|r| !r.parallel) {
        let pct = if wall_ms > 0.0 { 100.0 * r.self_ms / wall_ms } else { 0.0 };
        out.push_str(&format!(
            "{:<20} {:>10} {:>12.3} {:>12.3} {:>6.1}% {:>10.1} {:>10.1}\n",
            r.phase, r.count, r.total_ms, r.self_ms, pct, r.p50_us, r.p99_us
        ));
    }
    let par_rows: Vec<&Row> = rows.iter().filter(|r| r.parallel).collect();
    if !par_rows.is_empty() {
        out.push_str("\nparallel phases (summed across worker threads):\n");
        for r in &par_rows {
            out.push_str(&format!(
                "{:<20} {:>10} {:>12.3} {:>12.3} {:>7} {:>10.1} {:>10.1}\n",
                r.phase, r.count, r.total_ms, r.self_ms, "", r.p50_us, r.p99_us
            ));
        }
    }

    let gap = if wall_ms > 0.0 {
        (serial_self_ms - wall_ms).abs() / wall_ms
    } else {
        0.0
    };
    out.push_str(&format!(
        "\nreconciliation: serial self-time {:.1} ms vs wall {:.1} ms ({:.1}% gap{}{})\n",
        serial_self_ms,
        wall_ms,
        gap * 100.0,
        if parallel_self_ms > 0.0 {
            format!("; +{parallel_self_ms:.1} ms parallel worker time")
        } else {
            String::new()
        },
        if gap > RECONCILE_TOL { "; WARNING: trace looks incomplete" } else { "" }
    ));

    let c = &doc.counters;
    out.push_str("\ncounters:\n");
    for (name, value) in c.fields() {
        if value > 0 {
            out.push_str(&format!("  {name:<20} {value}\n"));
        }
    }
    out
}

/// Does this text hold serve request records (access-log JSONL or a
/// `/debug/trace` dump) rather than a training trace? Probes the first
/// non-empty line: request records carry `id` + `endpoint` per line, a
/// dump wraps them in a `records` array, and a training trace leads
/// with its `cmd` header.
fn looks_like_request_records(text: &str) -> bool {
    let first = text.lines().find(|l| !l.trim().is_empty()).unwrap_or("").trim();
    match json::parse(first) {
        Ok(j) => {
            j.get("records").is_some() || (j.get("endpoint").is_some() && j.get("id").is_some())
        }
        // A pretty-printed dump spans multiple lines; only the whole
        // text parses.
        Err(_) => json::parse(text).map(|j| j.get("records").is_some()).unwrap_or(false),
    }
}

/// Exact ceil-rank quantile of an ascending-sorted microsecond sample.
fn q_us(sorted: &[u64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[i - 1] as f64
}

/// Render the per-endpoint stage report for serve request records.
pub fn render_requests(records: &[ParsedRequest]) -> String {
    use std::collections::BTreeMap;
    let mut by_endpoint: BTreeMap<&str, Vec<&ParsedRequest>> = BTreeMap::new();
    for r in records {
        by_endpoint.entry(r.endpoint.as_str()).or_default().push(r);
    }
    let mut out = String::with_capacity(2048);
    out.push_str(&format!(
        "profile: {} request records across {} endpoint(s)\n",
        records.len(),
        by_endpoint.len()
    ));
    for (endpoint, rs) in &by_endpoint {
        let errors = rs.iter().filter(|r| r.status >= 400).count();
        let rows: u64 = rs.iter().map(|r| r.rows).sum();
        let total_sum_us: u64 = rs.iter().map(|r| r.total_us).sum();
        out.push_str(&format!(
            "\nendpoint {endpoint}: {} requests · {errors} errors · {rows} rows · \
             {:.1} ms total\n",
            rs.len(),
            total_sum_us as f64 / 1e3
        ));
        out.push_str(&format!(
            "  {:<12} {:>12} {:>8} {:>10} {:>10}\n",
            "stage", "total ms", "share %", "p50 us", "p99 us"
        ));
        for st in Stage::ALL {
            let mut vals: Vec<u64> = rs.iter().map(|r| r.stage_us[st.index()]).collect();
            vals.sort_unstable();
            let sum: u64 = vals.iter().sum();
            let share = if total_sum_us > 0 {
                100.0 * sum as f64 / total_sum_us as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "  {:<12} {:>12.3} {:>7.1}% {:>10.1} {:>10.1}\n",
                st.name(),
                sum as f64 / 1e3,
                share,
                q_us(&vals, 0.50),
                q_us(&vals, 0.99)
            ));
        }
        let mut totals: Vec<u64> = rs.iter().map(|r| r.total_us).collect();
        totals.sort_unstable();
        out.push_str(&format!(
            "  {:<12} {:>12.3} {:>7.1}% {:>10.1} {:>10.1}\n",
            "total",
            total_sum_us as f64 / 1e3,
            100.0,
            q_us(&totals, 0.50),
            q_us(&totals, 0.99)
        ));
    }
    let queue_us: u64 =
        records.iter().map(|r| r.stage_us[Stage::QueueWait.index()]).sum();
    let total_us: u64 = records.iter().map(|r| r.total_us).sum();
    out.push_str(&format!(
        "\nqueue wait: {:.1} ms — {:.1}% of total request time\n",
        queue_us as f64 / 1e3,
        if total_us > 0 { 100.0 * queue_us as f64 / total_us as f64 } else { 0.0 }
    ));
    out
}

/// `fastsurvival profile --trace <file>` (the file may also be passed
/// positionally): a training trace, an access log, or a flight-recorder
/// dump — the kind is detected from the content.
pub fn run(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .map(|s| s.to_string())
        .or_else(|| args.positional.get(1).cloned())
        .ok_or_else(|| {
            FastSurvivalError::InvalidConfig(
                "profile requires --trace <file> (a fit/path/bigfit/watch --trace-out \
                 trace, a serve access log, or a /debug/trace dump)"
                    .into(),
            )
        })?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| FastSurvivalError::io(format!("reading trace from {path}"), e))?;
    if looks_like_request_records(&text) {
        let records = parse_request_records(&text)?;
        print!("{}", render_requests(&records));
        return Ok(());
    }
    let doc = parse_trace_jsonl(&text)?;
    print!("{}", render(&doc));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{render_trace_jsonl, reset, set_enabled, Phase, SpanTimer};

    #[test]
    fn render_sorts_by_self_time_and_reconciles() {
        let _guard = crate::obs::span::test_support::obs_test_guard();
        set_enabled(true);
        reset();
        {
            let _fit = SpanTimer::start(Phase::Fit);
            for _ in 0..3 {
                let _sweep = SpanTimer::start(Phase::CdSweep);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        let jsonl = render_trace_jsonl("fit", 0.006, 1);
        set_enabled(false);
        reset();

        let doc = parse_trace_jsonl(&jsonl).unwrap();
        let report = render(&doc);
        // cd_sweep holds the sleeps, so it must outrank the fit root.
        let sweep_at = report.find("cd_sweep").unwrap();
        let fit_at = report.find("\nfit ").unwrap();
        assert!(sweep_at < fit_at, "self-time sort broken:\n{report}");
        assert!(report.contains("reconciliation:"), "{report}");
        // Root span covers the whole run, so the serial self-sum tracks
        // the wall we passed and no incompleteness warning fires.
        assert!(!report.contains("WARNING"), "{report}");
    }

    #[test]
    fn request_records_render_per_endpoint_stage_tables() {
        use crate::obs::recorder::{write_record_json, RequestRecord, N_STAGES};
        let mut jsonl = String::new();
        let mut push = |rec: &RequestRecord| {
            write_record_json(rec, &mut jsonl);
            jsonl.push('\n');
        };
        let base = RequestRecord {
            seq: 0,
            id: String::new(),
            endpoint: "score",
            model: "risk@1".into(),
            rows: 64,
            status: 200,
            stage_us: [5, 100, 300, 800, 50, 10],
            total_us: 1_265,
        };
        for (i, queue) in [300u64, 500, 100].iter().enumerate() {
            let mut r = base.clone();
            r.id = format!("s{i}");
            r.stage_us[2] = *queue;
            r.total_us = r.stage_us.iter().sum();
            push(&r);
        }
        let health = RequestRecord {
            seq: 3,
            id: "h0".into(),
            endpoint: "healthz",
            model: String::new(),
            rows: 0,
            status: 200,
            stage_us: [2, 0, 0, 0, 15, 3],
            total_us: 20,
        };
        push(&health);
        assert!(looks_like_request_records(&jsonl));
        let records = parse_request_records(&jsonl).unwrap();
        assert_eq!(records.len(), 4);
        assert_eq!(records[0].stage_us.len(), N_STAGES);
        let report = render_requests(&records);
        assert!(report.contains("endpoint score: 3 requests"), "{report}");
        assert!(report.contains("endpoint healthz: 1 requests"), "{report}");
        for stage in ["read", "parse", "queue_wait", "batch_score", "serialize", "write"]
        {
            assert!(report.contains(stage), "missing stage {stage}:\n{report}");
        }
        // Queue-wait share: 900 µs of queue over 3795 µs of score time
        // plus 20 µs of healthz → 900/3815 ≈ 23.6%.
        assert!(report.contains("queue wait: 0.9 ms"), "{report}");
        assert!(report.contains("23.6% of total request time"), "{report}");
    }

    #[test]
    fn input_kind_detection_routes_traces_and_records() {
        // A training trace leads with its cmd header — not request
        // records.
        let trace = "{\"schema_version\": 1, \"cmd\": \"fit\", \"wall_secs\": 0.1, \
                     \"threads\": 1}\n";
        assert!(!looks_like_request_records(trace));
        // A /debug/trace dump wraps records in one object.
        let dump = "{\"capacity\": 8, \"recorded\": 0, \"slow_threshold_us\": 0, \
                    \"records\": [], \"slow\": []}";
        assert!(looks_like_request_records(dump));
        // Garbage is neither.
        assert!(!looks_like_request_records("not json at all"));
    }

    #[test]
    fn parallel_phases_are_listed_separately() {
        let doc = parse_trace_jsonl(concat!(
            "{\"schema_version\": 1, \"cmd\": \"bigfit\", \"wall_secs\": 0.001, ",
            "\"threads\": 2}\n",
            "{\"event\": \"phase\", \"phase\": \"shard_scan\", \"parallel\": true, ",
            "\"count\": 4, \"total_ns\": 2000000, \"self_ns\": 2000000, ",
            "\"buckets_us_log2\": [0, 0, 0, 0, 0, 0, 0, 0, 0, 4]}\n",
            "{\"event\": \"phase\", \"phase\": \"fit\", \"parallel\": false, ",
            "\"count\": 1, \"total_ns\": 1000000, \"self_ns\": 1000000, ",
            "\"buckets_us_log2\": [0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1]}\n",
        ))
        .unwrap();
        let report = render(&doc);
        assert!(report.contains("parallel phases"), "{report}");
        // shard_scan's 2 ms across 2 workers exceeds the 1 ms wall, but
        // only the serial phase counts toward reconciliation.
        assert!(!report.contains("WARNING"), "{report}");
    }
}
