//! The `profile` subcommand: read a `--trace-out` JSONL file and print
//! a self-time-sorted phase table, a wall-clock reconciliation, and the
//! engine counters.
//!
//! Self-time is what the table ranks by: a phase's total minus the time
//! spent inside nested instrumented phases, so the column sums to the
//! run's wall clock instead of double-counting parents and children.
//! Parallel phases (the sharded worker legs) accumulate across worker
//! threads concurrently, so their self-time can legitimately exceed the
//! wall clock — they are reconciled and listed separately.

use crate::error::{FastSurvivalError, Result};
use crate::obs::hist::quantile_from_counts;
use crate::obs::{parse_trace_jsonl, TraceDoc};
use crate::util::args::Args;

/// Largest tolerated |serial self-sum − wall| / wall before the
/// reconciliation line flags the trace as incomplete.
const RECONCILE_TOL: f64 = 0.05;

/// One row of the rendered table, precomputed from a phase line.
struct Row {
    phase: String,
    parallel: bool,
    count: u64,
    total_ms: f64,
    self_ms: f64,
    p50_us: f64,
    p99_us: f64,
}

/// Render the profile report for a parsed trace document.
pub fn render(doc: &TraceDoc) -> String {
    let mut rows: Vec<Row> = doc
        .phases
        .iter()
        .map(|p| Row {
            phase: p.phase.clone(),
            parallel: p.parallel,
            count: p.count,
            total_ms: p.total_ns as f64 / 1e6,
            self_ms: p.self_ns as f64 / 1e6,
            p50_us: quantile_from_counts(&p.buckets_us_log2, 0.50),
            p99_us: quantile_from_counts(&p.buckets_us_log2, 0.99),
        })
        .collect();
    rows.sort_by(|a, b| {
        b.self_ms.partial_cmp(&a.self_ms).unwrap_or(std::cmp::Ordering::Equal)
    });

    let wall_ms = doc.wall_secs * 1e3;
    let serial_self_ms: f64 =
        rows.iter().filter(|r| !r.parallel).map(|r| r.self_ms).sum();
    let parallel_self_ms: f64 =
        rows.iter().filter(|r| r.parallel).map(|r| r.self_ms).sum();

    let mut out = String::with_capacity(2048);
    out.push_str(&format!(
        "profile: cmd={} wall={:.1} ms threads={}\n\n",
        doc.cmd, wall_ms, doc.threads
    ));
    out.push_str(&format!(
        "{:<20} {:>10} {:>12} {:>12} {:>7} {:>10} {:>10}\n",
        "phase", "count", "total ms", "self ms", "self %", "p50 us", "p99 us"
    ));
    for r in rows.iter().filter(|r| !r.parallel) {
        let pct = if wall_ms > 0.0 { 100.0 * r.self_ms / wall_ms } else { 0.0 };
        out.push_str(&format!(
            "{:<20} {:>10} {:>12.3} {:>12.3} {:>6.1}% {:>10.1} {:>10.1}\n",
            r.phase, r.count, r.total_ms, r.self_ms, pct, r.p50_us, r.p99_us
        ));
    }
    let par_rows: Vec<&Row> = rows.iter().filter(|r| r.parallel).collect();
    if !par_rows.is_empty() {
        out.push_str("\nparallel phases (summed across worker threads):\n");
        for r in &par_rows {
            out.push_str(&format!(
                "{:<20} {:>10} {:>12.3} {:>12.3} {:>7} {:>10.1} {:>10.1}\n",
                r.phase, r.count, r.total_ms, r.self_ms, "", r.p50_us, r.p99_us
            ));
        }
    }

    let gap = if wall_ms > 0.0 {
        (serial_self_ms - wall_ms).abs() / wall_ms
    } else {
        0.0
    };
    out.push_str(&format!(
        "\nreconciliation: serial self-time {:.1} ms vs wall {:.1} ms ({:.1}% gap{}{})\n",
        serial_self_ms,
        wall_ms,
        gap * 100.0,
        if parallel_self_ms > 0.0 {
            format!("; +{parallel_self_ms:.1} ms parallel worker time")
        } else {
            String::new()
        },
        if gap > RECONCILE_TOL { "; WARNING: trace looks incomplete" } else { "" }
    ));

    let c = &doc.counters;
    out.push_str("\ncounters:\n");
    for (name, value) in c.fields() {
        if value > 0 {
            out.push_str(&format!("  {name:<20} {value}\n"));
        }
    }
    out
}

/// `fastsurvival profile --trace trace.jsonl` (the file may also be
/// passed positionally).
pub fn run(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .map(|s| s.to_string())
        .or_else(|| args.positional.get(1).cloned())
        .ok_or_else(|| {
            FastSurvivalError::InvalidConfig(
                "profile requires --trace <trace.jsonl> (written by \
                 fit/path/bigfit/watch --trace-out)"
                    .into(),
            )
        })?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| FastSurvivalError::io(format!("reading trace from {path}"), e))?;
    let doc = parse_trace_jsonl(&text)?;
    print!("{}", render(&doc));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{render_trace_jsonl, reset, set_enabled, Phase, SpanTimer};

    #[test]
    fn render_sorts_by_self_time_and_reconciles() {
        let _guard = crate::obs::span::test_support::obs_test_guard();
        set_enabled(true);
        reset();
        {
            let _fit = SpanTimer::start(Phase::Fit);
            for _ in 0..3 {
                let _sweep = SpanTimer::start(Phase::CdSweep);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        let jsonl = render_trace_jsonl("fit", 0.006, 1);
        set_enabled(false);
        reset();

        let doc = parse_trace_jsonl(&jsonl).unwrap();
        let report = render(&doc);
        // cd_sweep holds the sleeps, so it must outrank the fit root.
        let sweep_at = report.find("cd_sweep").unwrap();
        let fit_at = report.find("\nfit ").unwrap();
        assert!(sweep_at < fit_at, "self-time sort broken:\n{report}");
        assert!(report.contains("reconciliation:"), "{report}");
        // Root span covers the whole run, so the serial self-sum tracks
        // the wall we passed and no incompleteness warning fires.
        assert!(!report.contains("WARNING"), "{report}");
    }

    #[test]
    fn parallel_phases_are_listed_separately() {
        let doc = parse_trace_jsonl(concat!(
            "{\"schema_version\": 1, \"cmd\": \"bigfit\", \"wall_secs\": 0.001, ",
            "\"threads\": 2}\n",
            "{\"event\": \"phase\", \"phase\": \"shard_scan\", \"parallel\": true, ",
            "\"count\": 4, \"total_ns\": 2000000, \"self_ns\": 2000000, ",
            "\"buckets_us_log2\": [0, 0, 0, 0, 0, 0, 0, 0, 0, 4]}\n",
            "{\"event\": \"phase\", \"phase\": \"fit\", \"parallel\": false, ",
            "\"count\": 1, \"total_ns\": 1000000, \"self_ns\": 1000000, ",
            "\"buckets_us_log2\": [0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1]}\n",
        ))
        .unwrap();
        let report = render(&doc);
        assert!(report.contains("parallel phases"), "{report}");
        // shard_scan's 2 ms across 2 workers exceeds the 1 ms wall, but
        // only the serial phase counts toward reconciliation.
        assert!(!report.contains("WARNING"), "{report}");
    }
}
