//! Engine-generic coordinate descent — the fit path the CLI exposes.
//!
//! The same cubic-surrogate sweep as `optim::cubic`, but every Cox
//! quantity is served through the [`CoxEngine`] abstraction, so the
//! identical driver runs on the native kernels or on the AOT-compiled
//! XLA artifacts (`--engine xla`), proving the three layers compose on a
//! real fit. Integration tests assert both engines reach the same β.

use crate::cox::{CoxProblem, CoxState};
use crate::optim::prox::{cubic_l1_step, cubic_step};
use crate::optim::{Objective, Trace};
use crate::runtime::engine::CoxEngine;
use anyhow::Result;
use std::time::Instant;

/// Configuration for [`fit_with_engine`].
#[derive(Clone, Debug)]
pub struct EngineFitConfig {
    pub objective: Objective,
    pub max_sweeps: usize,
    pub tol: f64,
}

impl Default for EngineFitConfig {
    fn default() -> Self {
        EngineFitConfig { objective: Objective::default(), max_sweeps: 100, tol: 1e-9 }
    }
}

/// Cubic-surrogate CD through an engine. Returns (β, trace).
pub fn fit_with_engine(
    engine: &dyn CoxEngine,
    problem: &CoxProblem,
    config: &EngineFitConfig,
) -> Result<(Vec<f64>, Trace)> {
    let p = problem.p();
    let obj = config.objective;
    let lip: Vec<_> = (0..p)
        .map(|l| engine.lipschitz(problem, l))
        .collect::<Result<_>>()?;
    let mut state = CoxState::zeros(problem);
    let mut trace = Trace::default();
    let start = Instant::now();
    let mut prev = f64::INFINITY;
    for sweep in 0..config.max_sweeps {
        for l in 0..p {
            let d = engine.coord_derivs(problem, &state, l)?;
            let a = d.d1 + 2.0 * obj.l2 * state.beta[l];
            let b = (d.d2 + 2.0 * obj.l2).max(0.0);
            if b <= 0.0 && lip[l].l3 <= 0.0 {
                continue;
            }
            let delta = if obj.l1 > 0.0 {
                cubic_l1_step(a, b, lip[l].l3, state.beta[l], obj.l1)
            } else {
                cubic_step(a, b, lip[l].l3)
            };
            state.update_coord(problem, l, delta);
        }
        let base = engine.loss(problem, &state)?;
        let pen = obj.l1 * state.beta.iter().map(|b| b.abs()).sum::<f64>()
            + obj.l2 * state.beta.iter().map(|b| b * b).sum::<f64>();
        let loss = base + pen;
        trace.push(sweep, start, loss);
        if !loss.is_finite() {
            trace.diverged = true;
            break;
        }
        if prev.is_finite() && (prev - loss).abs() < config.tol * (prev.abs() + 1.0) {
            trace.converged = true;
            break;
        }
        prev = loss;
    }
    Ok((state.beta, trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::runtime::engine::{NativeEngine, XlaEngine};
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.5, 9.5)).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    #[test]
    fn native_engine_matches_direct_cubic() {
        let pr = random_problem(80, 4, 61);
        let cfg = EngineFitConfig {
            objective: Objective { l1: 0.5, l2: 1.0 },
            max_sweeps: 300,
            tol: 1e-12,
        };
        let (beta_e, trace) = fit_with_engine(&NativeEngine, &pr, &cfg).unwrap();
        assert!(trace.monotone(1e-9));
        let direct = crate::optim::CubicSurrogate;
        use crate::optim::{FitConfig, Optimizer};
        let res = direct.fit(
            &pr,
            &FitConfig {
                objective: cfg.objective,
                max_iters: 300,
                tol: 1e-12,
                ..Default::default()
            },
        );
        for l in 0..4 {
            assert!(
                (beta_e[l] - res.beta[l]).abs() < 1e-6,
                "coord {l}: {} vs {}",
                beta_e[l],
                res.beta[l]
            );
        }
    }

    #[test]
    fn xla_engine_reaches_native_solution() {
        // End-to-end three-layer composition: the same CD driver on the
        // AOT artifacts must land on the same coefficients (f32 tolerance).
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.tsv").exists() {
            return;
        }
        let xe = XlaEngine::new(dir).unwrap();
        let pr = random_problem(120, 3, 62);
        let cfg = EngineFitConfig {
            objective: Objective { l1: 0.0, l2: 1.0 },
            max_sweeps: 30,
            tol: 1e-8,
        };
        let (beta_n, _) = fit_with_engine(&NativeEngine, &pr, &cfg).unwrap();
        let (beta_x, trace_x) = fit_with_engine(&xe, &pr, &cfg).unwrap();
        assert!(trace_x.monotone(1e-4), "xla CD must stay monotone");
        for l in 0..3 {
            assert!(
                (beta_n[l] - beta_x[l]).abs() < 5e-3,
                "coord {l}: native {} vs xla {}",
                beta_n[l],
                beta_x[l]
            );
        }
    }
}
