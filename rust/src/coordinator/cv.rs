//! 5-fold cross-validation driver (Appendix C.3).
//!
//! Runs a variable selector (or a non-Cox model class) on each train
//! fold, evaluates CPH loss / CIndex / IBS (and F1 when the ground truth
//! is known) on both train and test folds, and aggregates mean ± std per
//! support size — the data behind Figures 2–4 and 21–35.

use crate::baselines::SurvivalModel;
use crate::cox::{loss::loss_for_eta, CoxProblem};
use crate::data::SurvivalDataset;
use crate::metrics::brier::{default_grid, integrated_brier_score};
use crate::metrics::{concordance_index, support_f1, BreslowBaseline, KaplanMeier};
use crate::select::VariableSelector;
use crate::util::parallel::par_map;
use crate::util::rng::Rng;

/// One (method, support size, fold) evaluation record.
#[derive(Clone, Debug)]
pub struct CvRow {
    pub method: String,
    pub k: usize,
    pub fold: usize,
    pub train_loss: f64,
    pub test_loss: f64,
    pub train_cindex: f64,
    pub test_cindex: f64,
    pub train_ibs: f64,
    pub test_ibs: f64,
    /// Support-recovery F1 (synthetic data only).
    pub f1: Option<f64>,
}

/// Evaluate a fitted linear (Cox) solution on a split.
fn eval_linear(
    beta: &[f64],
    train: &SurvivalDataset,
    test: &SurvivalDataset,
) -> (f64, f64, f64, f64, f64, f64) {
    let eta_train = train.x.matvec(beta);
    let eta_test = test.x.matvec(beta);

    let pr_train = CoxProblem::new(train);
    let pr_test = CoxProblem::new(test);
    let eta_tr_sorted: Vec<f64> = pr_train.order.iter().map(|&i| eta_train[i]).collect();
    let eta_te_sorted: Vec<f64> = pr_test.order.iter().map(|&i| eta_test[i]).collect();
    let train_loss = loss_for_eta(&pr_train, &eta_tr_sorted);
    let test_loss = loss_for_eta(&pr_test, &eta_te_sorted);

    let train_ci = concordance_index(&train.time, &train.event, &eta_train);
    let test_ci = concordance_index(&test.time, &test.event, &eta_test);

    let baseline = BreslowBaseline::fit(&train.time, &train.event, &eta_train);
    let censor_km = KaplanMeier::fit_censoring(&train.time, &train.event);
    let grid = default_grid(&train.time, &train.event, 30);
    let surv_tr = |i: usize, t: f64| baseline.survival(t, eta_train[i]);
    let surv_te = |i: usize, t: f64| baseline.survival(t, eta_test[i]);
    let train_ibs =
        integrated_brier_score(&train.time, &train.event, &surv_tr, &censor_km, &grid);
    let test_ibs =
        integrated_brier_score(&test.time, &test.event, &surv_te, &censor_km, &grid);
    (train_loss, test_loss, train_ci, test_ci, train_ibs, test_ibs)
}

/// 5-fold CV of a variable selector at the given support sizes.
pub fn cv_selector(
    ds: &SurvivalDataset,
    selector: &dyn VariableSelector,
    ks: &[usize],
    folds: usize,
    seed: u64,
) -> Vec<CvRow> {
    let mut rng = Rng::new(seed);
    let splits = ds.kfold_indices(folds, &mut rng);
    let fold_inputs: Vec<(usize, Vec<usize>, Vec<usize>)> = splits
        .into_iter()
        .enumerate()
        .map(|(f, (tr, te))| (f, tr, te))
        .collect();

    let per_fold: Vec<Vec<CvRow>> = par_map(&fold_inputs, |(fold, tr_idx, te_idx)| {
        let train = ds.subset(tr_idx);
        let test = ds.subset(te_idx);
        let pr = CoxProblem::new(&train);
        let sols = selector.select(&pr, ks);
        sols.iter()
            .map(|sol| {
                let (train_loss, test_loss, train_ci, test_ci, train_ibs, test_ibs) =
                    eval_linear(&sol.beta, &train, &test);
                let f1 = ds
                    .true_beta
                    .as_ref()
                    .map(|tb| support_f1(tb, &sol.beta, 1e-10).f1);
                CvRow {
                    method: selector.name().to_string(),
                    k: sol.k,
                    fold: *fold,
                    train_loss,
                    test_loss,
                    train_cindex: train_ci,
                    test_cindex: test_ci,
                    train_ibs,
                    test_ibs,
                    f1,
                }
            })
            .collect()
    });
    per_fold.into_iter().flatten().collect()
}

/// 5-fold CV of a non-Cox model class (Figure 4 / 22 / 24).
pub fn cv_model<F>(
    ds: &SurvivalDataset,
    name: &str,
    fit: F,
    folds: usize,
    seed: u64,
) -> Vec<CvRow>
where
    F: Fn(&SurvivalDataset) -> Box<dyn SurvivalModel> + Sync,
{
    let mut rng = Rng::new(seed);
    let splits = ds.kfold_indices(folds, &mut rng);
    let fold_inputs: Vec<(usize, Vec<usize>, Vec<usize>)> = splits
        .into_iter()
        .enumerate()
        .map(|(f, (tr, te))| (f, tr, te))
        .collect();
    let rows: Vec<CvRow> = par_map(&fold_inputs, |(fold, tr_idx, te_idx)| {
        let train = ds.subset(tr_idx);
        let test = ds.subset(te_idx);
        let model = fit(&train);
        let ev = crate::baselines::evaluate_model(model.as_ref(), &train, &test);
        CvRow {
            method: name.to_string(),
            k: ev.complexity,
            fold: *fold,
            train_loss: f64::NAN,
            test_loss: f64::NAN,
            train_cindex: ev.train_cindex,
            test_cindex: ev.test_cindex,
            train_ibs: ev.train_ibs,
            test_ibs: ev.test_ibs,
            f1: None,
        }
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::select::BeamSearch;

    #[test]
    fn cv_produces_rows_per_fold_and_k() {
        let ds = generate(&SyntheticConfig { n: 150, p: 10, rho: 0.3, k: 2, s: 0.1, seed: 31 });
        let bs = BeamSearch { width: 2, screen: 5, ..Default::default() };
        let rows = cv_selector(&ds, &bs, &[1, 2], 3, 0);
        assert_eq!(rows.len(), 3 * 2);
        for r in &rows {
            assert!(r.test_cindex > 0.0 && r.test_cindex < 1.0 + 1e-12);
            assert!(r.train_ibs >= 0.0);
            assert!(r.f1.is_some(), "synthetic data has ground truth");
        }
    }

    #[test]
    fn informative_model_beats_chance_out_of_fold() {
        let ds = generate(&SyntheticConfig { n: 300, p: 8, rho: 0.2, k: 2, s: 0.1, seed: 32 });
        let bs = BeamSearch { width: 3, screen: 6, ..Default::default() };
        let rows = cv_selector(&ds, &bs, &[2], 3, 1);
        let mean_ci: f64 =
            rows.iter().map(|r| r.test_cindex).sum::<f64>() / rows.len() as f64;
        assert!(mean_ci > 0.6, "mean test cindex {mean_ci}");
    }

    #[test]
    fn cv_model_runs_tree() {
        use crate::baselines::tree::{SurvivalTree, TreeConfig};
        let ds = generate(&SyntheticConfig { n: 200, p: 6, rho: 0.2, k: 2, s: 0.1, seed: 33 });
        let rows = cv_model(
            &ds,
            "survival-tree",
            |train| {
                Box::new(SurvivalTree::fit(train, &TreeConfig::default()))
                    as Box<dyn SurvivalModel>
            },
            3,
            2,
        );
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| r.k >= 1));
    }
}
