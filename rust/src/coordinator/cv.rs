//! Cross-validation drivers (Appendix C.3), path-based since the
//! warm-started path refactor.
//!
//! The primary entry points fit **one whole path per training fold** —
//! [`cv_l1_path`] (λ grid shared across folds so scores align) and
//! [`cv_cardinality_path`] (k = 1..K warm-chained) — fan the folds across
//! threads via [`crate::util::parallel`], and pick λ/k by out-of-fold
//! partial-likelihood deviance or C-index. Fold assignment is
//! deterministic and thread-count-independent
//! ([`SurvivalDataset::kfold_seeded`]).
//!
//! The legacy per-selector / per-model-class drivers ([`cv_selector`],
//! [`cv_model`]) remain for the paper's figure harness.

use crate::baselines::SurvivalModel;
use crate::cox::{loss::loss_for_eta, CoxProblem};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::metrics::brier::{default_grid, integrated_brier_score};
use crate::metrics::{concordance_index, support_f1, BreslowBaseline, KaplanMeier};
use crate::path::{CardinalitySolver, PathSolver};
use crate::select::VariableSelector;
use crate::util::parallel::par_map;

/// One (method, support size, fold) evaluation record.
#[derive(Clone, Debug)]
pub struct CvRow {
    pub method: String,
    pub k: usize,
    pub fold: usize,
    pub train_loss: f64,
    pub test_loss: f64,
    pub train_cindex: f64,
    pub test_cindex: f64,
    pub train_ibs: f64,
    pub test_ibs: f64,
    /// Support-recovery F1 (synthetic data only).
    pub f1: Option<f64>,
}

/// Evaluate a fitted linear (Cox) solution on a split.
fn eval_linear(
    beta: &[f64],
    train: &SurvivalDataset,
    test: &SurvivalDataset,
) -> (f64, f64, f64, f64, f64, f64) {
    let eta_train = train.x.matvec(beta);
    let eta_test = test.x.matvec(beta);

    let pr_train = CoxProblem::new(train);
    let pr_test = CoxProblem::new(test);
    let eta_tr_sorted: Vec<f64> = pr_train.order.iter().map(|&i| eta_train[i]).collect();
    let eta_te_sorted: Vec<f64> = pr_test.order.iter().map(|&i| eta_test[i]).collect();
    let train_loss = loss_for_eta(&pr_train, &eta_tr_sorted);
    let test_loss = loss_for_eta(&pr_test, &eta_te_sorted);

    let train_ci = concordance_index(&train.time, &train.event, &eta_train);
    let test_ci = concordance_index(&test.time, &test.event, &eta_test);

    let baseline = BreslowBaseline::fit(&train.time, &train.event, &eta_train);
    let censor_km = KaplanMeier::fit_censoring(&train.time, &train.event);
    let grid = default_grid(&train.time, &train.event, 30);
    let surv_tr = |i: usize, t: f64| baseline.survival(t, eta_train[i]);
    let surv_te = |i: usize, t: f64| baseline.survival(t, eta_test[i]);
    let train_ibs =
        integrated_brier_score(&train.time, &train.event, &surv_tr, &censor_km, &grid);
    let test_ibs =
        integrated_brier_score(&test.time, &test.event, &surv_te, &censor_km, &grid);
    (train_loss, test_loss, train_ci, test_ci, train_ibs, test_ibs)
}

/// 5-fold CV of a variable selector at the given support sizes.
pub fn cv_selector(
    ds: &SurvivalDataset,
    selector: &dyn VariableSelector,
    ks: &[usize],
    folds: usize,
    seed: u64,
) -> Vec<CvRow> {
    let splits = ds.kfold_seeded(folds, seed);
    let fold_inputs: Vec<(usize, Vec<usize>, Vec<usize>)> = splits
        .into_iter()
        .enumerate()
        .map(|(f, (tr, te))| (f, tr, te))
        .collect();

    let per_fold: Vec<Vec<CvRow>> = par_map(&fold_inputs, |(fold, tr_idx, te_idx)| {
        let train = ds.subset(tr_idx);
        let test = ds.subset(te_idx);
        let pr = CoxProblem::new(&train);
        let sols = selector.select(&pr, ks);
        sols.iter()
            .map(|sol| {
                let (train_loss, test_loss, train_ci, test_ci, train_ibs, test_ibs) =
                    eval_linear(&sol.beta, &train, &test);
                let f1 = ds
                    .true_beta
                    .as_ref()
                    .map(|tb| support_f1(tb, &sol.beta, 1e-10).f1);
                CvRow {
                    method: selector.name().to_string(),
                    k: sol.k,
                    fold: *fold,
                    train_loss,
                    test_loss,
                    train_cindex: train_ci,
                    test_cindex: test_ci,
                    train_ibs,
                    test_ibs,
                    f1,
                }
            })
            .collect()
    });
    per_fold.into_iter().flatten().collect()
}

/// 5-fold CV of a non-Cox model class (Figure 4 / 22 / 24).
pub fn cv_model<F>(
    ds: &SurvivalDataset,
    name: &str,
    fit: F,
    folds: usize,
    seed: u64,
) -> Vec<CvRow>
where
    F: Fn(&SurvivalDataset) -> Box<dyn SurvivalModel> + Sync,
{
    let splits = ds.kfold_seeded(folds, seed);
    let fold_inputs: Vec<(usize, Vec<usize>, Vec<usize>)> = splits
        .into_iter()
        .enumerate()
        .map(|(f, (tr, te))| (f, tr, te))
        .collect();
    let rows: Vec<CvRow> = par_map(&fold_inputs, |(fold, tr_idx, te_idx)| {
        let train = ds.subset(tr_idx);
        let test = ds.subset(te_idx);
        let model = fit(&train);
        let ev = crate::baselines::evaluate_model(model.as_ref(), &train, &test);
        CvRow {
            method: name.to_string(),
            k: ev.complexity,
            fold: *fold,
            train_loss: f64::NAN,
            test_loss: f64::NAN,
            train_cindex: ev.train_cindex,
            test_cindex: ev.test_cindex,
            train_ibs: ev.train_ibs,
            test_ibs: ev.test_ibs,
            f1: None,
        }
    });
    rows
}

// ---------------------------------------------------------------------
// Path-based cross-validation: one path per fold, folds in parallel.

/// How path-based CV picks its winner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionCriterion {
    /// Minimize mean out-of-fold partial-likelihood deviance
    /// `2·(ℓ_test(β) − ℓ_test(0))` (negative = better than the null model).
    Deviance,
    /// Maximize mean out-of-fold concordance.
    CIndex,
}

impl SelectionCriterion {
    pub fn name(self) -> &'static str {
        match self {
            SelectionCriterion::Deviance => "deviance",
            SelectionCriterion::CIndex => "cindex",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "deviance" => Ok(SelectionCriterion::Deviance),
            "cindex" => Ok(SelectionCriterion::CIndex),
            other => Err(FastSurvivalError::Unknown {
                kind: "cv criterion",
                name: other.to_string(),
                expected: "deviance|cindex",
            }),
        }
    }
}

/// One grid point's aggregate over folds.
#[derive(Clone, Debug)]
pub struct PathCvPoint {
    /// Grid identity: λ for λ-paths, the support size k for k-paths.
    pub grid_value: f64,
    /// Mean support size of the per-fold solutions at this point.
    pub mean_support: f64,
    pub mean_test_deviance: f64,
    pub std_test_deviance: f64,
    pub mean_test_cindex: f64,
    pub std_test_cindex: f64,
}

/// Aggregated path CV: per-point scores plus the selected index.
#[derive(Clone, Debug)]
pub struct PathCvResult {
    pub points: Vec<PathCvPoint>,
    /// Index into `points` of the criterion winner.
    pub best_index: usize,
    pub criterion: SelectionCriterion,
    pub folds: usize,
    pub seed: u64,
}

impl PathCvResult {
    pub fn best(&self) -> &PathCvPoint {
        &self.points[self.best_index]
    }
}

/// (deviance, cindex, support) of one fitted β on one test fold.
fn fold_point_scores(
    beta: &[f64],
    test: &SurvivalDataset,
    pr_test: &CoxProblem,
    null_loss: f64,
) -> (f64, f64, usize) {
    let eta = test.x.matvec(beta);
    let eta_sorted: Vec<f64> = pr_test.order.iter().map(|&i| eta[i]).collect();
    let dev = 2.0 * (loss_for_eta(pr_test, &eta_sorted) - null_loss);
    let ci = concordance_index(&test.time, &test.event, &eta);
    let support = beta.iter().filter(|b| b.abs() > 1e-10).count();
    (dev, ci, support)
}

/// Aggregate per-fold per-point (deviance, cindex, support) rows into a
/// [`PathCvResult`]. Every fold must supply the same number of points.
fn aggregate_path_cv(
    grid: &[f64],
    per_fold: Vec<Vec<(f64, f64, usize)>>,
    criterion: SelectionCriterion,
    folds: usize,
    seed: u64,
) -> Result<PathCvResult> {
    let npoints = grid.len();
    if per_fold.iter().any(|f| f.len() != npoints) {
        return Err(FastSurvivalError::InvalidData(
            "path CV folds disagree on the grid".into(),
        ));
    }
    let nf = per_fold.len() as f64;
    let mut points = Vec::with_capacity(npoints);
    for (i, &grid_value) in grid.iter().enumerate() {
        let devs: Vec<f64> = per_fold.iter().map(|f| f[i].0).collect();
        let cis: Vec<f64> = per_fold.iter().map(|f| f[i].1).collect();
        let mean_support =
            per_fold.iter().map(|f| f[i].2 as f64).sum::<f64>() / nf;
        let mean_dev = devs.iter().sum::<f64>() / nf;
        let mean_ci = cis.iter().sum::<f64>() / nf;
        let var_dev =
            devs.iter().map(|d| (d - mean_dev) * (d - mean_dev)).sum::<f64>() / nf;
        let var_ci = cis.iter().map(|c| (c - mean_ci) * (c - mean_ci)).sum::<f64>() / nf;
        points.push(PathCvPoint {
            grid_value,
            mean_support,
            mean_test_deviance: mean_dev,
            std_test_deviance: var_dev.sqrt(),
            mean_test_cindex: mean_ci,
            std_test_cindex: var_ci.sqrt(),
        });
    }
    let best_index = match criterion {
        SelectionCriterion::Deviance => points
            .iter()
            .enumerate()
            .min_by(|a, b| {
                a.1.mean_test_deviance
                    .partial_cmp(&b.1.mean_test_deviance)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|(i, _)| i)
            .unwrap_or(0),
        SelectionCriterion::CIndex => points
            .iter()
            .enumerate()
            .max_by(|a, b| {
                a.1.mean_test_cindex
                    .partial_cmp(&b.1.mean_test_cindex)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|(i, _)| i)
            .unwrap_or(0),
    };
    Ok(PathCvResult { points, best_index, criterion, folds, seed })
}

/// Path-based λ cross-validation: derive one λ grid from the full data,
/// fit one warm-started screened path per training fold (folds in
/// parallel), score every grid point out of fold, and select λ by
/// `criterion`.
pub fn cv_l1_path(
    ds: &SurvivalDataset,
    solver: &PathSolver,
    folds: usize,
    seed: u64,
    criterion: SelectionCriterion,
) -> Result<PathCvResult> {
    let full = CoxProblem::try_new(ds)?;
    // One grid for every fold so per-point scores are comparable.
    let grid = solver.lambda_grid(&full)?;
    let splits = ds.kfold_seeded(folds, seed);
    let per_fold_results: Vec<Result<Vec<(f64, f64, usize)>>> =
        par_map(&splits, |(tr_idx, te_idx)| {
            let train = ds.subset(tr_idx);
            let test = ds.subset(te_idx);
            let pr_train = CoxProblem::try_new(&train)?;
            let pr_test = CoxProblem::try_new(&test)?;
            let null_loss = loss_for_eta(&pr_test, &vec![0.0; test.n()]);
            let path = solver.run_grid(&pr_train, &grid)?;
            Ok(path
                .points
                .iter()
                .map(|pt| fold_point_scores(&pt.beta, &test, &pr_test, null_loss))
                .collect())
        });
    let mut per_fold = Vec::with_capacity(per_fold_results.len());
    for r in per_fold_results {
        per_fold.push(r?);
    }
    aggregate_path_cv(&grid, per_fold, criterion, folds, seed)
}

/// Path-based k cross-validation: one warm-chained cardinality path per
/// training fold (folds in parallel), scored out of fold per k. Only
/// sizes every fold reached are aggregated (beam search can skip a size
/// on a degenerate fold).
pub fn cv_cardinality_path(
    ds: &SurvivalDataset,
    solver: &CardinalitySolver,
    max_k: usize,
    folds: usize,
    seed: u64,
    criterion: SelectionCriterion,
) -> Result<PathCvResult> {
    if max_k == 0 {
        return Err(FastSurvivalError::InvalidConfig(
            "cardinality CV needs max_k >= 1".into(),
        ));
    }
    let splits = ds.kfold_seeded(folds, seed);
    let per_fold_results: Vec<Result<Vec<Option<(f64, f64, usize)>>>> =
        par_map(&splits, |(tr_idx, te_idx)| {
            let train = ds.subset(tr_idx);
            let test = ds.subset(te_idx);
            let pr_train = CoxProblem::try_new(&train)?;
            let pr_test = CoxProblem::try_new(&test)?;
            let null_loss = loss_for_eta(&pr_test, &vec![0.0; test.n()]);
            let path = solver.run(&pr_train, max_k);
            Ok((1..=max_k)
                .map(|k| {
                    path.point_for_k(k).map(|pt| {
                        fold_point_scores(&pt.beta, &test, &pr_test, null_loss)
                    })
                })
                .collect())
        });
    let mut raw = Vec::with_capacity(per_fold_results.len());
    for r in per_fold_results {
        raw.push(r?);
    }
    // Keep only the sizes every fold reached.
    let mut grid = Vec::new();
    let mut per_fold: Vec<Vec<(f64, f64, usize)>> = vec![Vec::new(); raw.len()];
    for ki in 0..max_k {
        if raw.iter().all(|f| f[ki].is_some()) {
            grid.push((ki + 1) as f64);
            for (fi, f) in raw.iter().enumerate() {
                per_fold[fi].push(f[ki].expect("checked above"));
            }
        }
    }
    if grid.is_empty() {
        return Err(FastSurvivalError::InvalidData(
            "no support size was reached by every CV fold".into(),
        ));
    }
    aggregate_path_cv(&grid, per_fold, criterion, folds, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::select::BeamSearch;

    #[test]
    fn cv_produces_rows_per_fold_and_k() {
        let ds = generate(&SyntheticConfig { n: 150, p: 10, rho: 0.3, k: 2, s: 0.1, seed: 31 });
        let bs = BeamSearch { width: 2, screen: 5, ..Default::default() };
        let rows = cv_selector(&ds, &bs, &[1, 2], 3, 0);
        assert_eq!(rows.len(), 3 * 2);
        for r in &rows {
            assert!(r.test_cindex > 0.0 && r.test_cindex < 1.0 + 1e-12);
            assert!(r.train_ibs >= 0.0);
            assert!(r.f1.is_some(), "synthetic data has ground truth");
        }
    }

    #[test]
    fn informative_model_beats_chance_out_of_fold() {
        let ds = generate(&SyntheticConfig { n: 300, p: 8, rho: 0.2, k: 2, s: 0.1, seed: 32 });
        let bs = BeamSearch { width: 3, screen: 6, ..Default::default() };
        let rows = cv_selector(&ds, &bs, &[2], 3, 1);
        let mean_ci: f64 =
            rows.iter().map(|r| r.test_cindex).sum::<f64>() / rows.len() as f64;
        assert!(mean_ci > 0.6, "mean test cindex {mean_ci}");
    }

    #[test]
    fn l1_path_cv_selects_a_point_and_is_deterministic() {
        let ds = generate(&SyntheticConfig { n: 160, p: 12, rho: 0.3, k: 3, s: 0.1, seed: 34 });
        let solver = PathSolver { n_lambdas: 10, ..Default::default() };
        let a = cv_l1_path(&ds, &solver, 3, 7, SelectionCriterion::Deviance).unwrap();
        assert_eq!(a.points.len(), 10);
        assert!(a.best_index < a.points.len());
        // An informative λ beats the null model out of fold.
        assert!(
            a.best().mean_test_deviance < 0.0,
            "best deviance {}",
            a.best().mean_test_deviance
        );
        // Bitwise-deterministic: same seed, same result.
        let b = cv_l1_path(&ds, &solver, 3, 7, SelectionCriterion::Deviance).unwrap();
        assert_eq!(a.best_index, b.best_index);
        for (x, y) in a.points.iter().zip(b.points.iter()) {
            assert_eq!(x.mean_test_deviance, y.mean_test_deviance);
            assert_eq!(x.mean_test_cindex, y.mean_test_cindex);
        }
    }

    #[test]
    fn cardinality_path_cv_scores_every_reached_size() {
        let ds = generate(&SyntheticConfig { n: 150, p: 10, rho: 0.3, k: 2, s: 0.1, seed: 35 });
        let solver = CardinalitySolver::Beam(BeamSearch {
            width: 2,
            screen: 5,
            ..Default::default()
        });
        let r =
            cv_cardinality_path(&ds, &solver, 4, 3, 1, SelectionCriterion::CIndex).unwrap();
        assert!(!r.points.is_empty());
        assert!(r.best().mean_test_cindex > 0.5, "cindex {}", r.best().mean_test_cindex);
        for w in r.points.windows(2) {
            assert!(w[1].grid_value > w[0].grid_value, "k grid must ascend");
        }
    }

    #[test]
    fn criterion_names_round_trip() {
        for c in [SelectionCriterion::Deviance, SelectionCriterion::CIndex] {
            assert_eq!(SelectionCriterion::from_name(c.name()).unwrap(), c);
        }
        assert!(SelectionCriterion::from_name("aic").is_err());
    }

    #[test]
    fn cv_model_runs_tree() {
        use crate::baselines::tree::{SurvivalTree, TreeConfig};
        let ds = generate(&SyntheticConfig { n: 200, p: 6, rho: 0.2, k: 2, s: 0.1, seed: 33 });
        let rows = cv_model(
            &ds,
            "survival-tree",
            |train| {
                Box::new(SurvivalTree::fit(train, &TreeConfig::default()))
                    as Box<dyn SurvivalModel>
            },
            3,
            2,
        );
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| r.k >= 1));
    }
}
