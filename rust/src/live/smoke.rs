//! The `live-smoke` CLI subcommand: the online-learning loop end to
//! end, with the paper-claim gates CI holds it to.
//!
//! One run: synthesize a base store, publish a first model through a
//! watch cycle, append ~5% fresh rows as a live segment, then race the
//! two refits on the *same* merged view — a warm [`IncrementalRefit`]
//! from the served β against a cold [`StreamingFit`] from zeros — and
//! gate on both halves of the claim: the warm refit must be at least
//! `--min-speedup`× faster AND land within 1e-8 of the cold optimum
//! per coefficient (both runs carry the same KKT residual certificate,
//! so this is parity of certified optima, not of trajectories). A
//! second watch cycle exercises the validation gate on the grown store,
//! and a short-lived scoring server checks that `/healthz` reports the
//! published model + registry generation and `/metrics` exposes the
//! drift block. Numbers land in `BENCH_live.json` (written before any
//! gate failure exits, so CI always gets the artifact).

use super::append::append_rows;
use super::dataset::LiveDataset;
use super::refit::IncrementalRefit;
use super::watch::Watcher;
use crate::api::json;
use crate::data::synthetic::{generate, SyntheticConfig};
use crate::error::{FastSurvivalError, Result};
use crate::optim::cd::SurrogateKind;
use crate::optim::Objective;
use crate::serve::{serve, BatchConfig, HttpClient, ModelRegistry, ServeConfig};
use crate::store::writer::DatasetRows;
use crate::store::{write_store, StreamingFit};
use crate::util::args::Args;
use crate::util::parallel::num_threads;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_or("n", 12_000usize);
    let p = args.get_or("p", 40usize);
    let chunk_rows = args.get_or("chunk-rows", 1024usize);
    let append_frac = args.get_or("append-frac", 0.05f64);
    let l2 = args.get_or("l2", 1.0f64);
    let min_speedup = args.get_or("min-speedup", 3.0f64);
    let stop_kkt = args.get_or("stop-kkt", 1e-9f64);
    let seed = args.get_or("seed", 21u64);
    let out_path = args.str_or("out", "BENCH_live.json");
    let parity_tol = 1e-8f64;

    let dir = std::env::temp_dir().join(format!("fs_live_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)
        .map_err(|e| FastSurvivalError::io(format!("creating {dir:?}"), e))?;
    let store = dir.join("events.fsds");
    let artifacts = dir.join("models");
    let obj = Objective { l1: 0.0, l2 };

    // 1. Base store + first published model (watch cycle 1: no
    // incumbent, so the gate always publishes v1).
    let ds = generate(&SyntheticConfig { n, p, rho: 0.4, k: 8, s: 0.1, seed });
    let mut rows = DatasetRows::new(&ds);
    write_store(&mut rows, &store, chunk_rows, "events")?;
    let mut watcher = Watcher::new(&store, &artifacts, "events");
    watcher.objective = obj;
    watcher.stop_kkt = stop_kkt;
    let first = watcher.run_cycle()?;
    let published_version = first.published;
    println!("live-smoke: cycle 1 — {}", first.reason);

    // 2. Append ~append_frac·n fresh rows as a committed segment.
    let n_append = ((append_frac * n as f64).round() as usize).max(1);
    let extra =
        generate(&SyntheticConfig { n: n_append, p, rho: 0.4, k: 8, s: 0.1, seed: seed + 1 });
    let mut rows = DatasetRows::new(&extra);
    let appended = append_rows(&store, &mut rows, 0)?;
    println!(
        "live-smoke: appended {} rows ({} events) as segment {} — merged view {} rows",
        appended.n, appended.n_events, appended.seq, appended.total_rows
    );

    // 3. The race. Same merged view, same objective, same certificate.
    let served_beta = crate::api::model::CoxModel::load(&artifacts.join(format!(
        "events@{}.json",
        published_version.unwrap_or(1)
    )))?
    .beta()
    .to_vec();

    let mut live_warm = LiveDataset::open(&store)?;
    let t0 = Instant::now();
    let warm = IncrementalRefit { objective: obj, stop_kkt, ..Default::default() }
        .refit(&mut live_warm, &served_beta)?;
    let warm_secs = t0.elapsed().as_secs_f64();

    let mut live_cold = LiveDataset::open(&store)?;
    let t0 = Instant::now();
    let cold = StreamingFit {
        objective: obj,
        surrogate: SurrogateKind::Quadratic,
        max_sweeps: 10_000,
        tol: 0.0,
        stop_kkt,
        ..Default::default()
    }
    .fit(&mut live_cold)?;
    let cold_secs = t0.elapsed().as_secs_f64();

    let max_coef_delta = warm
        .beta
        .iter()
        .zip(cold.beta.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let speedup = if warm_secs > 0.0 { cold_secs / warm_secs } else { f64::INFINITY };
    println!(
        "live-smoke: warm {warm_secs:.3}s ({} sweeps, {} warmup blocks) vs cold \
         {cold_secs:.3}s ({} sweeps) — {speedup:.1}× · max |Δβ| = {max_coef_delta:.2e}",
        warm.sweeps, warm.warmup_blocks, cold.sweeps
    );

    // 4. Cycle 2: the validation gate decides on the grown store.
    let second = watcher.run_cycle()?;
    println!("live-smoke: cycle 2 — {}", second.reason);

    // 5. Serve the artifact dir briefly: /healthz must name the model
    // and carry the generation counter, /metrics must expose drift.
    let registry = Arc::new(ModelRegistry::open(&artifacts)?);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_body_bytes: 4 << 20,
        batch: BatchConfig::default(),
    };
    let handle = serve(Arc::clone(&registry), &cfg)?;
    let addr = handle.local_addr();
    let mut serve_ok = false;
    let mut healthz_generation = 0u64;
    if let Ok(mut client) = HttpClient::connect(addr) {
        let healthz = client.get("/healthz").map(|r| r.body).unwrap_or_default();
        let metrics = client.get("/metrics").map(|r| r.body).unwrap_or_default();
        if let Ok(doc) = json::parse(&healthz) {
            healthz_generation = doc
                .require("generation")
                .and_then(|g| g.as_usize())
                .unwrap_or(0) as u64;
            let names_ok = healthz.contains("\"events\"");
            serve_ok = names_ok && healthz_generation >= 1 && metrics.contains("\"drift\"");
        }
    }
    handle.shutdown();
    println!(
        "live-smoke: serve check {} (generation {healthz_generation})",
        if serve_ok { "OK" } else { "FAILED" }
    );

    let speedup_ok = speedup >= min_speedup;
    let parity_ok = max_coef_delta <= parity_tol && warm.trace.converged && cold.trace.converged;
    let publish_ok = published_version == Some(1);

    // 6. BENCH_live.json — written before any gate verdict exits.
    let mut out = String::with_capacity(1024);
    out.push_str("{\n  \"schema_version\": 1,\n  \"bench\": \"live\",\n  \"workload\": {");
    out.push_str(&format!(
        "\"n\": {n}, \"p\": {p}, \"chunk_rows\": {chunk_rows}, \"appended_rows\": {}, \
         \"l2\": {l2}, \"stop_kkt\": {stop_kkt}, \"seed\": {seed}, \"threads\": {}",
        appended.n,
        num_threads()
    ));
    out.push_str("},\n  \"results\": {\"cold_secs\": ");
    json::write_f64(&mut out, cold_secs);
    out.push_str(", \"warm_secs\": ");
    json::write_f64(&mut out, warm_secs);
    out.push_str(", \"speedup\": ");
    json::write_f64(&mut out, speedup);
    out.push_str(", \"max_coef_delta\": ");
    json::write_f64(&mut out, max_coef_delta);
    out.push_str(&format!(
        ", \"warm_sweeps\": {}, \"cold_sweeps\": {}, \"warmup_blocks\": {}, \
         \"published_version\": {}, \"cycle2_published\": {}, \"healthz_generation\": \
         {healthz_generation}",
        warm.sweeps,
        cold.sweeps,
        warm.warmup_blocks,
        published_version.map_or("null".into(), |v| v.to_string()),
        second.published.map_or("null".into(), |v| v.to_string()),
    ));
    out.push_str(", \"candidate_cindex\": ");
    json::write_f64(&mut out, second.candidate.cindex);
    out.push_str(", \"candidate_deviance\": ");
    json::write_f64(&mut out, second.candidate.deviance);
    out.push_str("},\n  \"gate\": {");
    out.push_str(&format!(
        "\"min_speedup\": {min_speedup}, \"speedup_ok\": {speedup_ok}, \
         \"parity_tol\": {parity_tol}, \"parity_ok\": {parity_ok}, \
         \"publish_ok\": {publish_ok}, \"serve_ok\": {serve_ok}"
    ));
    out.push_str("}\n}\n");
    std::fs::write(Path::new(&out_path), &out)
        .map_err(|e| FastSurvivalError::io(format!("writing {out_path}"), e))?;
    println!("live-smoke: wrote {out_path}");
    let _ = std::fs::remove_dir_all(&dir);

    if !(speedup_ok && parity_ok && publish_ok && serve_ok) {
        return Err(FastSurvivalError::PerfRegression(format!(
            "live-smoke gate failed: speedup {speedup:.2}× (need ≥ {min_speedup}), \
             max |Δβ| {max_coef_delta:.2e} (need ≤ {parity_tol:.0e}), \
             publish_ok={publish_ok}, serve_ok={serve_ok}"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_smoke_end_to_end() {
        // Scaled way down, and with the speedup gate disabled: at toy
        // sizes both fits finish in microseconds and the ratio is noise.
        // Parity, publish, and serve gates still run at full strength.
        let out = std::env::temp_dir()
            .join(format!("BENCH_live_test_{}.json", std::process::id()));
        let args = Args::parse(
            [
                "live-smoke".to_string(),
                "--n".into(),
                "600".into(),
                "--p".into(),
                "8".into(),
                "--chunk-rows".into(),
                "128".into(),
                "--min-speedup".into(),
                "0.0".into(),
                "--out".into(),
                out.to_str().unwrap().to_string(),
            ]
            .into_iter(),
        );
        run(&args).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = json::parse(&text).unwrap();
        let gate = doc.require("gate").unwrap();
        assert!(gate.require("parity_ok").unwrap().as_bool().unwrap());
        assert!(gate.require("publish_ok").unwrap().as_bool().unwrap());
        assert!(gate.require("serve_ok").unwrap().as_bool().unwrap());
        let results = doc.require("results").unwrap();
        assert!(results.require("speedup").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(&out);
    }
}
