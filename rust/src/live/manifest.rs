//! The segment manifest: which append segments belong to a base store.
//!
//! An append never touches the base `.fsds`. It writes a small,
//! fully-formed segment store next to it (`{store}.seg{NNNNNN}.fsds`,
//! complete with header, checksum, and canonical descending-time sort —
//! the ordinary writer produces it, atomic `.partial.tmp` publish and
//! all), then atomically rewrites `{store}.manifest` to list the new
//! segment. The manifest is the *only* commit point:
//!
//! - a segment file with no manifest entry is an orphan from a crash
//!   between the two steps — readers ignore it, the next append or
//!   compaction deletes it;
//! - a manifest whose recorded base signature (n + header checksum) no
//!   longer matches the base file is stale — a compaction renamed a new
//!   base into place and crashed before deleting the manifest. Readers
//!   fall back to the base alone; the next append starts a fresh
//!   manifest and cleans the leftovers.
//!
//! Either way, every crash point leaves a store that opens cleanly.

use crate::api::json::{self, Json};
use crate::error::{FastSurvivalError, Result};
use crate::store::format::{self, StoreHeader, HEADER_LEN};
use std::io::Read;
use std::path::{Path, PathBuf};

/// Manifest schema version.
pub const MANIFEST_VERSION: usize = 1;

/// The base store a manifest binds to: enough to detect that the base
/// file was replaced (compaction, reconversion) out from under it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BaseSignature {
    pub n: usize,
    /// The base header's stored FNV-1a self-check — covers n, p,
    /// chunk_rows, and payload_offset, so any rewrite that changes the
    /// geometry changes the signature.
    pub checksum: u64,
}

/// One committed append segment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentEntry {
    /// Monotonic sequence number (also embedded in the file name).
    pub seq: u64,
    pub n: usize,
    pub n_events: usize,
}

/// The parsed `{store}.manifest`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub base: BaseSignature,
    pub segments: Vec<SegmentEntry>,
}

/// `{store}.manifest`.
pub fn manifest_path(store: &Path) -> PathBuf {
    PathBuf::from(format!("{}.manifest", store.display()))
}

/// `{store}.seg{seq:06}.fsds`.
pub fn segment_path(store: &Path, seq: u64) -> PathBuf {
    PathBuf::from(format!("{}.seg{seq:06}.fsds", store.display()))
}

/// Read just the fixed header of a store (48 bytes — no payload I/O).
pub fn read_header(store: &Path) -> Result<StoreHeader> {
    let mut file = std::fs::File::open(store)
        .map_err(|e| FastSurvivalError::io(format!("opening {}", store.display()), e))?;
    let mut head = [0u8; HEADER_LEN];
    format::read_exact(&mut file, &mut head, "header")?;
    StoreHeader::decode(&head)
}

/// The base signature the manifest must match: row count plus the
/// header's own FNV self-check.
pub fn base_signature(store: &Path) -> Result<BaseSignature> {
    let header = read_header(store)?;
    let checksum = format::fnv1a(&header.encode()[0..40]);
    Ok(BaseSignature { n: header.n, checksum })
}

/// Read a store's name and feature names without the O(n·p) stats pass
/// a full open makes — appends use this to reject rows whose schema
/// does not match the base.
pub fn read_name_and_features(store: &Path) -> Result<(String, Vec<String>)> {
    let mut file = std::fs::File::open(store)
        .map_err(|e| FastSurvivalError::io(format!("opening {}", store.display()), e))?;
    let mut head = [0u8; HEADER_LEN];
    format::read_exact(&mut file, &mut head, "header")?;
    let header = StoreHeader::decode(&head)?;
    let mut r = std::io::BufReader::new(&mut file);
    let name = format::read_string(&mut r, "dataset name")?;
    let n_names = format::read_u32(&mut r, "feature-name count")? as usize;
    if n_names != header.p {
        return Err(FastSurvivalError::Store(format!(
            "meta block names {n_names} features, header says {}",
            header.p
        )));
    }
    let mut feature_names = Vec::with_capacity(header.p);
    for _ in 0..header.p {
        feature_names.push(format::read_string(&mut r, "feature name")?);
    }
    Ok((name, feature_names))
}

impl Manifest {
    /// A fresh, empty manifest bound to the base store as it is now.
    pub fn fresh(store: &Path) -> Result<Manifest> {
        Ok(Manifest { base: base_signature(store)?, segments: Vec::new() })
    }

    /// The next segment sequence number.
    pub fn next_seq(&self) -> u64 {
        self.segments.iter().map(|s| s.seq).max().unwrap_or(0) + 1
    }

    /// Total appended rows across all committed segments.
    pub fn appended_rows(&self) -> usize {
        self.segments.iter().map(|s| s.n).sum()
    }

    /// Total appended events across all committed segments.
    pub fn appended_events(&self) -> usize {
        self.segments.iter().map(|s| s.n_events).sum()
    }

    /// Load `{store}.manifest` if present. `Ok(None)` when no manifest
    /// file exists; a malformed manifest is a typed Store error (it is
    /// our own atomic write, so corruption means something is wrong).
    pub fn load(store: &Path) -> Result<Option<Manifest>> {
        let path = manifest_path(store);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(FastSurvivalError::io(format!("reading {}", path.display()), e))
            }
        };
        let doc = json::parse(&text).map_err(|e| {
            FastSurvivalError::Store(format!("malformed manifest {}: {e}", path.display()))
        })?;
        let version = doc.require("manifest_version")?.as_usize()?;
        if version != MANIFEST_VERSION {
            return Err(FastSurvivalError::Store(format!(
                "unsupported manifest version {version} (this build reads {MANIFEST_VERSION})"
            )));
        }
        let base = doc.require("base")?;
        let n = base.require("n")?.as_usize()?;
        let checksum_hex = base.require("checksum")?.as_str()?;
        let checksum = u64::from_str_radix(
            checksum_hex.trim_start_matches("0x"),
            16,
        )
        .map_err(|_| {
            FastSurvivalError::Store(format!("bad base checksum {checksum_hex:?} in manifest"))
        })?;
        let mut segments = Vec::new();
        for seg in doc.require("segments")?.as_array()? {
            segments.push(SegmentEntry {
                seq: seg.require("seq")?.as_usize()? as u64,
                n: seg.require("n")?.as_usize()?,
                n_events: seg.require("n_events")?.as_usize()?,
            });
        }
        Ok(Some(Manifest { base: BaseSignature { n, checksum }, segments }))
    }

    /// Load the manifest *if* it is bound to the base store as it
    /// currently is. A missing or stale manifest (base replaced by a
    /// compaction that crashed before cleanup) returns `Ok(None)` — the
    /// base alone is authoritative then.
    pub fn load_valid(store: &Path) -> Result<Option<Manifest>> {
        let Some(m) = Manifest::load(store)? else { return Ok(None) };
        if m.base == base_signature(store)? {
            Ok(Some(m))
        } else {
            Ok(None)
        }
    }

    /// Atomically write `{store}.manifest` (temp file + rename) — the
    /// commit point of every append.
    pub fn save(&self, store: &Path) -> Result<()> {
        let segments: Vec<Json> = self
            .segments
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("seq".into(), Json::Num(s.seq as f64)),
                    ("n".into(), Json::Num(s.n as f64)),
                    ("n_events".into(), Json::Num(s.n_events as f64)),
                ])
            })
            .collect();
        let doc = Json::Obj(vec![
            ("manifest_version".into(), Json::Num(MANIFEST_VERSION as f64)),
            (
                "base".into(),
                Json::Obj(vec![
                    ("n".into(), Json::Num(self.base.n as f64)),
                    ("checksum".into(), Json::Str(format!("{:#018x}", self.base.checksum))),
                ]),
            ),
            ("segments".into(), Json::Arr(segments)),
        ]);
        let path = manifest_path(store);
        let tmp = PathBuf::from(format!("{}.partial.tmp", path.display()));
        std::fs::write(&tmp, doc.to_json_string())
            .map_err(|e| FastSurvivalError::io(format!("writing {}", tmp.display()), e))?;
        std::fs::rename(&tmp, &path).map_err(|e| {
            FastSurvivalError::io(
                format!("publishing {} -> {}", tmp.display(), path.display()),
                e,
            )
        })
    }
}

/// Delete files the crash protocol leaves behind: segment files not
/// listed in `keep` (orphans from a crash between segment write and
/// manifest commit, or from a stale manifest), and any `.partial.tmp`/
/// `.rows.tmp` writer workspace next to the store. Returns the paths it
/// removed. Only files prefixed with the store's own file name are ever
/// touched.
pub fn clean_stray_files(store: &Path, keep: Option<&Manifest>) -> Result<Vec<PathBuf>> {
    let dir = store.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
    let stem = match store.file_name().and_then(|s| s.to_str()) {
        Some(s) => s.to_string(),
        None => return Ok(Vec::new()),
    };
    let kept: Vec<PathBuf> = keep
        .map(|m| m.segments.iter().map(|s| segment_path(store, s.seq)).collect())
        .unwrap_or_default();
    let mut removed = Vec::new();
    let entries = std::fs::read_dir(&dir)
        .map_err(|e| FastSurvivalError::io(format!("listing {}", dir.display()), e))?;
    for entry in entries {
        let entry = entry.map_err(|e| FastSurvivalError::io("listing store directory", e))?;
        let name = match entry.file_name().into_string() {
            Ok(n) => n,
            Err(_) => continue,
        };
        if name == stem || !name.starts_with(&stem) {
            continue;
        }
        let suffix = &name[stem.len()..];
        let is_tmp = suffix.ends_with(".partial.tmp")
            || suffix.ends_with(".rows.tmp")
            || suffix.ends_with(".compact.tmp");
        let is_segment = suffix.starts_with(".seg") && suffix.ends_with(".fsds");
        if !is_tmp && !is_segment {
            continue;
        }
        let path = entry.path();
        if is_segment && kept.contains(&path) {
            continue;
        }
        std::fs::remove_file(&path)
            .map_err(|e| FastSurvivalError::io(format!("removing {}", path.display()), e))?;
        removed.push(path);
    }
    Ok(removed)
}

/// Verify a header's stored checksum against the raw bytes on disk
/// (used by `inspect`; [`StoreHeader::decode`] enforces it too, this
/// surfaces the stored vs computed pair for display).
pub fn header_checksum(store: &Path) -> Result<(u64, u64)> {
    let mut file = std::fs::File::open(store)
        .map_err(|e| FastSurvivalError::io(format!("opening {}", store.display()), e))?;
    let mut head = [0u8; HEADER_LEN];
    file.read_exact(&mut head)
        .map_err(|e| FastSurvivalError::io(format!("reading {} header", store.display()), e))?;
    let stored = u64::from_le_bytes(head[40..48].try_into().unwrap());
    let computed = format::fnv1a(&head[0..40]);
    Ok((stored, computed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::store::writer::{write_store, DatasetRows};

    fn temp_store(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fs_live_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join(format!("{tag}.fsds"));
        let ds = generate(&SyntheticConfig { n: 40, p: 3, rho: 0.2, k: 2, s: 0.1, seed: 5 });
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &out, 16, tag).unwrap();
        out
    }

    #[test]
    fn manifest_round_trips_and_binds_to_base() {
        let store = temp_store("roundtrip");
        let mut m = Manifest::fresh(&store).unwrap();
        assert_eq!(m.next_seq(), 1);
        m.segments.push(SegmentEntry { seq: 1, n: 7, n_events: 3 });
        m.segments.push(SegmentEntry { seq: 2, n: 5, n_events: 2 });
        m.save(&store).unwrap();
        let back = Manifest::load(&store).unwrap().unwrap();
        assert_eq!(back, m);
        assert_eq!(back.next_seq(), 3);
        assert_eq!(back.appended_rows(), 12);
        assert_eq!(back.appended_events(), 5);
        // Bound to the current base: load_valid sees it.
        assert!(Manifest::load_valid(&store).unwrap().is_some());
        // Rewrite the base (different n) → the manifest is stale.
        let ds = generate(&SyntheticConfig { n: 31, p: 3, rho: 0.2, k: 2, s: 0.1, seed: 6 });
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &store, 16, "rewritten").unwrap();
        assert!(Manifest::load_valid(&store).unwrap().is_none());
        assert!(Manifest::load(&store).unwrap().is_some(), "file still exists");
    }

    #[test]
    fn missing_manifest_is_none_and_garbage_is_typed() {
        let store = temp_store("missing");
        assert!(Manifest::load(&store).unwrap().is_none());
        std::fs::write(manifest_path(&store), "not json").unwrap();
        assert!(matches!(
            Manifest::load(&store),
            Err(FastSurvivalError::Store(_))
        ));
    }

    #[test]
    fn clean_stray_files_spares_committed_segments() {
        let store = temp_store("clean");
        // Committed segment (listed), orphan segment (not listed),
        // leftover writer workspace, and an unrelated neighbor file.
        let mut m = Manifest::fresh(&store).unwrap();
        m.segments.push(SegmentEntry { seq: 1, n: 1, n_events: 1 });
        m.save(&store).unwrap();
        std::fs::write(segment_path(&store, 1), b"committed").unwrap();
        std::fs::write(segment_path(&store, 2), b"orphan").unwrap();
        let tmp = PathBuf::from(format!("{}.seg000003.fsds.partial.tmp", store.display()));
        std::fs::write(&tmp, b"partial").unwrap();
        let neighbor = store.with_file_name("unrelated.fsds");
        std::fs::write(&neighbor, b"keep me").unwrap();

        let removed = clean_stray_files(&store, Some(&m)).unwrap();
        assert_eq!(removed.len(), 2, "orphan + partial: {removed:?}");
        assert!(segment_path(&store, 1).exists());
        assert!(!segment_path(&store, 2).exists());
        assert!(!tmp.exists());
        assert!(neighbor.exists());
        std::fs::remove_file(&neighbor).unwrap();
    }

    #[test]
    fn signature_tracks_header_and_checksums_agree() {
        let store = temp_store("sig");
        let sig = base_signature(&store).unwrap();
        assert_eq!(sig.n, 40);
        let (stored, computed) = header_checksum(&store).unwrap();
        assert_eq!(stored, computed);
        assert_eq!(stored, sig.checksum);
        let (name, features) = read_name_and_features(&store).unwrap();
        assert_eq!(name, "sig");
        assert_eq!(features.len(), 3);
    }
}
