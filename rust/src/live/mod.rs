//! The online learning loop: append survival rows to an on-disk store,
//! warm-refit the Cox model incrementally, and auto-publish into the
//! serving registry only when a held-out validation tail improves.
//!
//! Three pieces, each usable on its own:
//!
//! * [`append`] / [`manifest`] — rows land as merge-sorted **segment**
//!   stores next to the base `.fsds` (each a complete store: header,
//!   checksum, canonical descending-time sort, atomic temp-file
//!   publish), committed by an atomic manifest rewrite. Every crash
//!   point leaves a store that opens cleanly; [`append::compact`]
//!   folds segments back into one base.
//! * [`dataset`] / [`refit`] — [`dataset::LiveDataset`] serves base +
//!   committed segments as one merged view in global descending-time
//!   order, with metadata that matches a compacted store **bit for
//!   bit**; [`refit::IncrementalRefit`] warm-starts from the served β,
//!   warms up on only the appended blocks, then polishes with the exact
//!   chunked CD engine until the KKT residual certifies ≤1e-8 parity
//!   with a cold fit.
//! * [`watch`] — the control plane: fingerprint the store, refit on
//!   growth, score candidate vs incumbent on a deterministic holdout
//!   tail ([`crate::data::split::holdout_tail`], shared with CV), and
//!   publish into the [`crate::serve::ModelRegistry`] artifact dir only
//!   on strict improvement, drift-reference sidecar included.
//!
//! [`smoke`] runs the whole loop for CI and emits `BENCH_live.json`
//! with the ≥3× warm-vs-cold speedup and ≤1e-8 parity gates.

pub mod append;
pub mod dataset;
pub mod manifest;
pub mod refit;
pub mod smoke;
pub mod watch;

pub use append::{append_rows, compact, AppendSummary};
pub use dataset::LiveDataset;
pub use manifest::Manifest;
pub use refit::{IncrementalRefit, RefitResult};
pub use watch::{
    evaluate_holdout, fingerprint, improves, CycleReport, HoldoutMetrics, StoreFingerprint,
    Watcher,
};
