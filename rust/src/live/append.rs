//! Append and compaction over a base `.fsds` store.
//!
//! [`append_rows`] drains a [`RowSource`] into a fresh merge-sorted
//! segment store next to the base (the ordinary writer builds it:
//! header + checksum + canonical descending-time sort + atomic
//! `.partial.tmp` publish), then commits it by atomically rewriting the
//! manifest. [`compact`] streams base + segments back through the
//! writer into a single store and retires the manifest — after which
//! the merged view and a cold-written store are the same file.
//!
//! Crash protocol (every step leaves an openable store):
//! 1. segment written, manifest not yet updated → orphan segment,
//!    ignored by readers, deleted by the next append/compact;
//! 2. compacted store renamed over the base, manifest not yet deleted →
//!    the manifest's base signature no longer matches, so readers treat
//!    the (new) base as authoritative and the next append cleans up;
//! 3. manifest deleted, segment files not yet deleted → orphans, as (1).

use super::manifest::{
    self, base_signature, clean_stray_files, read_name_and_features, segment_path, Manifest,
    SegmentEntry,
};
use crate::error::{FastSurvivalError, Result};
use crate::store::{write_store_with, ChunkedDataset, CoxData, RowSource, StoreSummary};
use std::path::{Path, PathBuf};

/// What a committed append looked like.
#[derive(Clone, Debug)]
pub struct AppendSummary {
    /// Sequence number of the new segment.
    pub seq: u64,
    /// Path of the committed segment file.
    pub segment: PathBuf,
    /// Rows / events in the new segment.
    pub n: usize,
    pub n_events: usize,
    /// Total rows in the merged view (base + all committed segments).
    pub total_rows: usize,
    /// Committed segments after this append.
    pub segments: usize,
}

/// Append `source`'s rows to the store at `base` as a new sorted
/// segment. `chunk_rows` of 0 reuses the base store's chunk size. The
/// source's feature schema must match the base store's.
pub fn append_rows(
    base: &Path,
    source: &mut dyn RowSource,
    chunk_rows: usize,
) -> Result<AppendSummary> {
    let header = manifest::read_header(base)?;
    let (base_name, base_features) = read_name_and_features(base)?;
    if source.n_features() != header.p {
        return Err(FastSurvivalError::InvalidData(format!(
            "appended rows have {} features, store has {}",
            source.n_features(),
            header.p
        )));
    }
    let names = source.feature_names();
    if names != base_features {
        return Err(FastSurvivalError::InvalidData(format!(
            "appended feature names {names:?} do not match the store's {base_features:?}"
        )));
    }
    // Resume from a valid manifest or start fresh; either way, sweep
    // the crash leftovers (orphan segments, stale-manifest segments,
    // writer temp files) before writing anything new.
    let mut m = match Manifest::load_valid(base)? {
        Some(m) => m,
        None => Manifest::fresh(base)?,
    };
    clean_stray_files(base, Some(&m))?;

    let seq = m.next_seq();
    let seg_path = segment_path(base, seq);
    let chunk_rows = if chunk_rows == 0 { header.chunk_rows } else { chunk_rows };
    let seg_name = format!("{base_name}.seg{seq:06}");
    // Segments inherit the base store's cell precision so the merged
    // view reads one uniform format and compaction round-trips it.
    let summary = write_store_with(source, &seg_path, chunk_rows, &seg_name, header.precision)?;

    // Commit: the manifest rewrite is the only mutation readers see.
    m.segments.push(SegmentEntry { seq, n: summary.n, n_events: summary.n_events });
    if let Err(e) = m.save(base) {
        // Failed commit: the segment is an orphan; remove it eagerly so
        // the failed append leaves no trace at all.
        let _ = std::fs::remove_file(&seg_path);
        return Err(e);
    }
    Ok(AppendSummary {
        seq,
        segment: seg_path,
        n: summary.n,
        n_events: summary.n_events,
        total_rows: m.base.n + m.appended_rows(),
        segments: m.segments.len(),
    })
}

/// A validated `.fsds` store replayed as a forward [`RowSource`] (rows
/// come out in the store's sorted order, one buffered chunk at a time).
pub struct StoreRows {
    store: ChunkedDataset,
    chunk: Vec<f64>,
    chunk_idx: usize,
    rows_in_chunk: usize,
    row: usize,
}

impl StoreRows {
    pub fn new(store: ChunkedDataset) -> Self {
        StoreRows { store, chunk: Vec::new(), chunk_idx: 0, rows_in_chunk: 0, row: 0 }
    }
}

impl RowSource for StoreRows {
    fn n_features(&self) -> usize {
        self.store.meta().p
    }

    fn feature_names(&self) -> Vec<String> {
        self.store.meta().feature_names.clone()
    }

    fn next_row(&mut self, feats: &mut Vec<f64>) -> Result<Option<(f64, bool)>> {
        let meta = self.store.meta_arc();
        if self.row >= self.rows_in_chunk {
            if self.chunk_idx >= meta.n_chunks {
                return Ok(None);
            }
            self.rows_in_chunk = self.store.load_chunk(self.chunk_idx, &mut self.chunk)?;
            self.chunk_idx += 1;
            self.row = 0;
        }
        let (k, rows) = (self.row, self.rows_in_chunk);
        feats.clear();
        for j in 0..meta.p {
            feats.push(self.chunk[j * rows + k]);
        }
        let global = (self.chunk_idx - 1) * meta.chunk_rows + k;
        self.row += 1;
        Ok(Some((meta.time[global], meta.event[global])))
    }
}

/// Several row sources replayed back to back — the compaction arrival
/// order (base rows in base order, then each segment's rows in segment
/// order). The live merged reader computes its statistics in this same
/// order, which is why its metadata matches a compacted store bit for
/// bit.
pub struct ChainRows {
    sources: Vec<StoreRows>,
    current: usize,
}

impl ChainRows {
    pub fn new(sources: Vec<StoreRows>) -> Self {
        ChainRows { sources, current: 0 }
    }
}

impl RowSource for ChainRows {
    fn n_features(&self) -> usize {
        self.sources[0].n_features()
    }

    fn feature_names(&self) -> Vec<String> {
        self.sources[0].feature_names()
    }

    fn next_row(&mut self, feats: &mut Vec<f64>) -> Result<Option<(f64, bool)>> {
        while self.current < self.sources.len() {
            if let Some(out) = self.sources[self.current].next_row(feats)? {
                return Ok(Some(out));
            }
            self.current += 1;
        }
        Ok(None)
    }
}

/// Merge all committed segments back into the base store. Streams base
/// + segments through the ordinary writer to `{base}.compact.tmp`, then
/// (the commit point) renames it over the base, retires the manifest,
/// and deletes the segment files. A store with no committed segments is
/// returned unchanged. `chunk_rows` of 0 keeps the base chunk size.
pub fn compact(base: &Path, chunk_rows: usize) -> Result<StoreSummary> {
    let header = manifest::read_header(base)?;
    let (base_name, _) = read_name_and_features(base)?;
    let chunk_rows = if chunk_rows == 0 { header.chunk_rows } else { chunk_rows };
    let m_opt = Manifest::load_valid(base)?;
    if m_opt.as_ref().is_none_or(|m| m.segments.is_empty()) {
        // Nothing to merge; still sweep crash leftovers.
        clean_stray_files(base, m_opt.as_ref())?;
        let store = ChunkedDataset::open(base)?;
        let meta = store.meta();
        return Ok(StoreSummary {
            n: meta.n,
            p: meta.p,
            chunk_rows: meta.chunk_rows,
            n_chunks: meta.n_chunks,
            n_events: meta.n_events,
            bytes: header.expected_file_len(),
        });
    }
    let m = m_opt.unwrap();
    clean_stray_files(base, Some(&m))?;

    let mut sources = vec![StoreRows::new(ChunkedDataset::open(base)?)];
    for seg in &m.segments {
        sources.push(StoreRows::new(ChunkedDataset::open(&segment_path(base, seg.seq))?));
    }
    let mut chain = ChainRows::new(sources);
    let merged_tmp = PathBuf::from(format!("{}.compact.tmp", base.display()));
    let summary =
        write_store_with(&mut chain, &merged_tmp, chunk_rows, &base_name, header.precision)?;
    drop(chain); // release the base store's read handle before replacing it

    // Commit: the new base lands atomically; from here the old manifest
    // is stale by signature, so any crash below only leaves cleanable
    // leftovers.
    std::fs::rename(&merged_tmp, base).map_err(|e| {
        FastSurvivalError::io(
            format!("publishing {} -> {}", merged_tmp.display(), base.display()),
            e,
        )
    })?;
    let _ = std::fs::remove_file(manifest::manifest_path(base));
    for seg in &m.segments {
        let _ = std::fs::remove_file(segment_path(base, seg.seq));
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::data::SurvivalDataset;
    use crate::store::writer::DatasetRows;
    use crate::store::write_store;

    fn temp_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fs_live_append_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn gen(n: usize, seed: u64) -> SurvivalDataset {
        generate(&SyntheticConfig { n, p: 4, rho: 0.3, k: 2, s: 0.1, seed })
    }

    fn base_store(tag: &str, n: usize, seed: u64) -> PathBuf {
        let out = temp_dir().join(format!("{tag}.fsds"));
        let ds = gen(n, seed);
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &out, 16, tag).unwrap();
        out
    }

    #[test]
    fn append_commits_a_segment_and_compact_retires_it() {
        let base = base_store("appends", 60, 1);
        let extra = gen(13, 2);
        let mut rows = DatasetRows::new(&extra);
        let s1 = append_rows(&base, &mut rows, 0).unwrap();
        assert_eq!((s1.seq, s1.n, s1.total_rows, s1.segments), (1, 13, 73, 1));
        assert!(s1.segment.exists());
        // Segments are complete stores in their own right.
        let seg = ChunkedDataset::open(&s1.segment).unwrap();
        assert_eq!(seg.meta().n, 13);
        assert_eq!(seg.meta().n_events, extra.n_events());

        let extra2 = gen(7, 3);
        let mut rows = DatasetRows::new(&extra2);
        let s2 = append_rows(&base, &mut rows, 0).unwrap();
        assert_eq!((s2.seq, s2.total_rows, s2.segments), (2, 80, 2));

        let merged = compact(&base, 0).unwrap();
        assert_eq!(merged.n, 80);
        assert_eq!(merged.n_events, gen(60, 1).n_events() + extra.n_events() + extra2.n_events());
        assert!(Manifest::load(&base).unwrap().is_none(), "manifest retired");
        assert!(!s1.segment.exists() && !segment_path(&base, 2).exists());
        // The compacted store opens and validates (sorted, checksummed).
        let store = ChunkedDataset::open(&base).unwrap();
        assert_eq!(store.meta().n, 80);
        // Compacting again is a no-op.
        let again = compact(&base, 0).unwrap();
        assert_eq!(again.n, 80);
    }

    #[test]
    fn f32_base_appends_and_compacts_as_f32() {
        use crate::util::compute::Precision;
        let base = temp_dir().join("prec32.fsds");
        let ds = gen(40, 41);
        let mut rows = DatasetRows::new(&ds);
        write_store_with(&mut rows, &base, 16, "p32", Precision::F32Storage).unwrap();
        let extra = gen(9, 42);
        let mut rows = DatasetRows::new(&extra);
        let s = append_rows(&base, &mut rows, 0).unwrap();
        // The committed segment inherits the base's v2 cell format.
        let seg = ChunkedDataset::open(&s.segment).unwrap();
        assert_eq!(seg.header().precision, Precision::F32Storage);
        let merged = compact(&base, 0).unwrap();
        assert_eq!(merged.n, 49);
        let flat = ChunkedDataset::open(&base).unwrap();
        assert_eq!(flat.header().precision, Precision::F32Storage);
        assert_eq!(flat.meta().n, 49);
    }

    #[test]
    fn schema_mismatches_are_typed_errors() {
        let base = base_store("schema", 40, 5);
        // Wrong width.
        let wrong = generate(&SyntheticConfig { n: 5, p: 3, rho: 0.3, k: 2, s: 0.1, seed: 7 });
        let mut rows = DatasetRows::new(&wrong);
        assert!(matches!(
            append_rows(&base, &mut rows, 0),
            Err(FastSurvivalError::InvalidData(_))
        ));
        // Right width, wrong names.
        let mut renamed = gen(5, 7);
        renamed.feature_names[2] = "sneaky".into();
        let mut rows = DatasetRows::new(&renamed);
        let err = append_rows(&base, &mut rows, 0).unwrap_err();
        assert!(matches!(err, FastSurvivalError::InvalidData(_)));
        assert!(err.to_string().contains("sneaky"));
    }

    #[test]
    fn store_rows_replays_the_sorted_order() {
        let base = base_store("replay", 45, 9);
        let mut store = ChunkedDataset::open(&base).unwrap();
        let ds = store.to_dataset().unwrap();
        let mut src = StoreRows::new(ChunkedDataset::open(&base).unwrap());
        let mut feats = Vec::new();
        for i in 0..45 {
            let (t, e) = src.next_row(&mut feats).unwrap().unwrap();
            assert_eq!(t, ds.time[i], "row {i}");
            assert_eq!(e, ds.event[i]);
            for j in 0..4 {
                assert_eq!(feats[j], ds.x.get(i, j), "row {i} col {j}");
            }
        }
        assert!(src.next_row(&mut feats).unwrap().is_none());
    }

    #[test]
    fn orphan_segments_are_swept_by_the_next_append() {
        let base = base_store("orphans", 30, 11);
        // Simulate a crash between segment write and manifest commit:
        // a fully written segment with no manifest entry.
        let extra = gen(6, 12);
        let mut rows = DatasetRows::new(&extra);
        let orphan = segment_path(&base, 1);
        write_store(&mut rows, &orphan, 8, "orphan").unwrap();
        assert!(orphan.exists());
        assert!(Manifest::load(&base).unwrap().is_none());
        // Next append sweeps the orphan and commits seq 1 itself.
        let extra2 = gen(4, 13);
        let mut rows = DatasetRows::new(&extra2);
        let s = append_rows(&base, &mut rows, 0).unwrap();
        assert_eq!(s.seq, 1);
        assert_eq!(s.n, 4, "the orphan's rows must not leak into the commit");
        let m = Manifest::load_valid(&base).unwrap().unwrap();
        assert_eq!(m.segments.len(), 1);
        assert_eq!(m.segments[0].n, 4);
    }
}
