//! Incremental warm refit: pick up a previously-fit β, warm it up
//! against only the *newly appended* rows, then polish with the exact
//! chunked CD engine until the KKT residual certifies optimality.
//!
//! The computational story mirrors the cold two-phase
//! [`StreamingFit`](crate::store::StreamingFit), with one inversion:
//! a cold fit's sampled-block warmup must survey the whole store to
//! climb from β = 0, while a warm refit already sits within a small
//! append's perturbation of the new optimum — so its warmup samples
//! only the segment blocks (the rows the old β has never seen) and the
//! exact phase needs a handful of sweeps instead of dozens. Both runs
//! finish inside [`exact_chunked_cd`] with the same residual threshold
//! ε, and a μ-strongly-convex objective pins each within √p·ε/μ of the
//! unique optimum — the ≤1e-8 parity certificate costs nothing beyond
//! the derivative pass every sweep makes anyway.

use super::dataset::LiveDataset;
use crate::cox::derivatives::Workspace;
use crate::cox::lipschitz::all_lipschitz;
use crate::cox::{CoxProblem, CoxState};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::linalg::Matrix;
use crate::optim::cd::SurrogateKind;
use crate::optim::{Objective, Trace};
use crate::store::streaming::exact_chunked_cd;
use crate::store::CoxData;
use crate::util::compute::Compute;
use crate::util::rng::Rng;

/// Same annealing constant as the cold warmup: block t blends with
/// weight `BLEND / (BLEND + t)`.
const BLEND: f64 = 4.0;

/// Warm refit configuration. `stop_kkt` is mandatory (> 0): the KKT
/// certificate is the whole point — without it a warm start could stop
/// on a flat loss while still far from the cold fit's answer.
#[derive(Clone, Debug)]
pub struct IncrementalRefit {
    pub objective: Objective,
    pub surrogate: SurrogateKind,
    /// Maximum exact-phase sweeps.
    pub max_sweeps: usize,
    /// KKT residual threshold certifying the exact phase (must be > 0).
    pub stop_kkt: f64,
    /// Warmup passes over the segment blocks (0 = skip straight to the
    /// exact phase; 1 samples each appended block once in expectation).
    pub warmup_passes: usize,
    /// Block-sampler seed (fixed seed = fixed refit).
    pub seed: u64,
    /// Kernel backend / thread request, resolved once at refit start.
    pub compute: Compute,
}

impl Default for IncrementalRefit {
    fn default() -> Self {
        IncrementalRefit {
            objective: Objective::default(),
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 10_000,
            stop_kkt: 1e-9,
            warmup_passes: 1,
            seed: 0,
            compute: Compute::default(),
        }
    }
}

/// What a warm refit produced; field-compatible with the cold
/// [`StreamingFitResult`](crate::store::StreamingFitResult) consumers.
#[derive(Clone, Debug)]
pub struct RefitResult {
    pub beta: Vec<f64>,
    /// Linear predictor per merged sorted sample at the final β.
    pub eta: Vec<f64>,
    pub objective_value: f64,
    /// Exact-phase sweeps run — the number a warm start keeps small.
    pub sweeps: usize,
    /// Segment warmup blocks consumed.
    pub warmup_blocks: usize,
    pub trace: Trace,
}

impl IncrementalRefit {
    /// Refit over the merged live view, starting from `warm_beta` (the
    /// currently-served model's coefficients).
    pub fn refit(&self, live: &mut LiveDataset, warm_beta: &[f64]) -> Result<RefitResult> {
        let meta = live.meta_arc();
        let p = meta.p;
        if warm_beta.len() != p {
            return Err(FastSurvivalError::InvalidData(format!(
                "warm start has {} coefficients but the store has {} features",
                warm_beta.len(),
                p
            )));
        }
        if meta.n_events == 0 {
            return Err(FastSurvivalError::InvalidData(
                "all samples are censored: the Cox partial likelihood has no events to fit"
                    .into(),
            ));
        }
        if self.stop_kkt <= 0.0 || !self.stop_kkt.is_finite() {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "incremental refit requires a positive KKT threshold (got {}): \
                 the residual certificate is what guarantees parity with a cold fit",
                self.stop_kkt
            )));
        }
        if !self.objective.l1.is_finite()
            || self.objective.l1 < 0.0
            || !self.objective.l2.is_finite()
            || self.objective.l2 < 0.0
        {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "penalties must be finite and non-negative (got l1={}, l2={})",
                self.objective.l1, self.objective.l2
            )));
        }
        if self.max_sweeps == 0 {
            return Err(FastSurvivalError::InvalidConfig(
                "max_sweeps must be at least 1".into(),
            ));
        }
        let obj = self.objective;
        // Resolved once; no env reads inside the sweep loops below.
        let rc = self.compute.resolve()?;
        let mut beta = warm_beta.to_vec();

        // ---------------- Phase A: segment-block warmup. Only the
        // appended rows — the data the warm β has never conditioned on.
        // Each block is a time-contiguous run of one segment's sorted
        // order, so its partial likelihood is well-formed as-is.
        let blocks = live.segment_blocks();
        let mut warmup_blocks = 0usize;
        if self.warmup_passes > 0 && !blocks.is_empty() {
            let _span = crate::obs::SpanTimer::start(crate::obs::Phase::RefitWarmup);
            let mut rng = Rng::new(self.seed);
            let mut chunkbuf: Vec<f64> = Vec::new();
            let total = self.warmup_passes * blocks.len();
            for t in 0..total {
                let (s, c) = blocks[rng.below(blocks.len())];
                let (rows, r0) = live.load_source_chunk(s, c, &mut chunkbuf)?;
                let smeta = live.source_meta(s);
                let block_events =
                    smeta.event[r0..r0 + rows].iter().filter(|&&e| e).count();
                if block_events == 0 {
                    continue;
                }
                let x = Matrix { rows, cols: p, data: chunkbuf[..rows * p].to_vec() };
                let block = SurvivalDataset::new(
                    x,
                    smeta.time[r0..r0 + rows].to_vec(),
                    smeta.event[r0..r0 + rows].to_vec(),
                    "segment-block",
                );
                let bpr = CoxProblem::try_new(&block)?;
                // Penalties scaled by the block's share of the *merged*
                // event count, as the cold warmup scales by its share of
                // the full store.
                let frac = block_events as f64 / meta.n_events as f64;
                let bobj = Objective { l1: obj.l1 * frac, l2: obj.l2 * frac };
                let blip = all_lipschitz(&bpr);
                let mut bst = CoxState::from_beta(&bpr, &beta);
                let mut ws = Workspace::new();
                for l in 0..p {
                    self.surrogate.step_b(&bpr, &mut bst, &mut ws, l, blip[l], bobj, rc.backend);
                }
                let alpha = BLEND / (BLEND + t as f64);
                for (bj, sj) in beta.iter_mut().zip(bst.beta.iter()) {
                    *bj += alpha * (sj - *bj);
                }
                warmup_blocks += 1;
            }
        }

        // ---------------- Phase B: exact chunked CD over the merged
        // view, loss stopping disabled (tol = 0) — only the KKT
        // residual may declare convergence.
        let exact_span = crate::obs::SpanTimer::start(crate::obs::Phase::RefitExact);
        let outcome = exact_chunked_cd(
            live,
            &meta,
            beta,
            self.surrogate,
            obj,
            self.max_sweeps,
            0.0,
            self.stop_kkt,
            0.0,
            rc,
        )?;
        drop(exact_span);
        let mut state = outcome.state;
        let beta = std::mem::take(&mut state.beta);
        let eta = std::mem::take(&mut state.eta);
        Ok(RefitResult {
            beta,
            eta,
            objective_value: outcome.objective_value,
            sweeps: outcome.sweeps,
            warmup_blocks,
            trace: outcome.trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::live::append::append_rows;
    use crate::store::writer::{write_store, DatasetRows};
    use crate::store::StreamingFit;
    use std::path::PathBuf;

    fn temp_store(tag: &str, n: usize, appended: usize) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fs_live_refit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join(format!("{tag}.fsds"));
        let ds = generate(&SyntheticConfig { n, p: 6, rho: 0.3, k: 3, s: 0.1, seed: 7 });
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &base, 64, tag).unwrap();
        if appended > 0 {
            let extra =
                generate(&SyntheticConfig { n: appended, p: 6, rho: 0.3, k: 3, s: 0.1, seed: 8 });
            let mut rows = DatasetRows::new(&extra);
            append_rows(&base, &mut rows, 64).unwrap();
        }
        base
    }

    #[test]
    fn warm_refit_matches_cold_fit_to_1e8() {
        let base = temp_store("parity", 400, 24);
        let obj = Objective { l1: 0.0, l2: 1.0 };

        // The "previously served" β: a cold fit of the base alone.
        let mut base_only = crate::store::ChunkedDataset::open(&base).unwrap();
        let served = StreamingFit {
            objective: obj,
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 10_000,
            tol: 0.0,
            stop_kkt: 1e-9,
            ..Default::default()
        }
        .fit(&mut base_only)
        .unwrap();

        let mut live = LiveDataset::open(&base).unwrap();
        let warm = IncrementalRefit {
            objective: obj,
            stop_kkt: 1e-9,
            ..Default::default()
        }
        .refit(&mut live, &served.beta)
        .unwrap();
        assert!(warm.trace.converged, "warm refit must KKT-converge");
        assert!(warm.warmup_blocks > 0, "appended segments must warm up");

        let mut live2 = LiveDataset::open(&base).unwrap();
        let cold = StreamingFit {
            objective: obj,
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 10_000,
            tol: 0.0,
            stop_kkt: 1e-9,
            ..Default::default()
        }
        .fit(&mut live2)
        .unwrap();
        for (a, b) in warm.beta.iter().zip(cold.beta.iter()) {
            assert!((a - b).abs() <= 1e-8, "warm {a} vs cold {b}");
        }
        assert!(
            warm.sweeps <= cold.sweeps,
            "a warm start must not polish longer than a cold one ({} vs {})",
            warm.sweeps,
            cold.sweeps
        );
    }

    #[test]
    fn bad_configs_are_typed_errors() {
        let base = temp_store("cfg", 120, 10);
        let mut live = LiveDataset::open(&base).unwrap();
        let p = live.meta().p;
        let r = IncrementalRefit { stop_kkt: 0.0, ..Default::default() }
            .refit(&mut live, &vec![0.0; p]);
        assert!(matches!(r, Err(FastSurvivalError::InvalidConfig(_))));
        let r = IncrementalRefit::default().refit(&mut live, &vec![0.0; p + 1]);
        assert!(matches!(r, Err(FastSurvivalError::InvalidData(_))));
        let r = IncrementalRefit { max_sweeps: 0, ..Default::default() }
            .refit(&mut live, &vec![0.0; p]);
        assert!(matches!(r, Err(FastSurvivalError::InvalidConfig(_))));
        let r = IncrementalRefit {
            objective: Objective { l1: -1.0, l2: 0.0 },
            ..Default::default()
        }
        .refit(&mut live, &vec![0.0; p]);
        assert!(matches!(r, Err(FastSurvivalError::InvalidConfig(_))));
    }
}
