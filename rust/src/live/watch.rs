//! The online learning loop's control plane: detect appends, warm-refit,
//! validate on a deterministic holdout tail, and publish into the model
//! registry **only on improvement**.
//!
//! The publish gate is deliberately conservative — a candidate must be
//! at least as good as the incumbent on *both* holdout metrics
//! (C-index up, partial-likelihood deviance down) and better by more
//! than a noise margin on at least one. Ties do not publish: refitting
//! on identical data lands within the KKT certificate's radius of the
//! incumbent, so its metrics agree to far below [`GATE_MARGIN`], and
//! republishing an equivalent model would churn versions for nothing.
//! A rejected candidate leaves the artifact directory byte-for-byte
//! untouched.
//!
//! The holdout is [`crate::data::split::holdout_tail`] over the merged
//! sorted rows — the same seeded, thread-count-independent permutation
//! the CV drivers use, so "validation tail" means the same thing in
//! `fastsurvival watch` and in `cv_l1_path`. Every published version
//! gets a `<name>@<version>.drift` sidecar holding the training-score
//! histogram the server's drift tracker compares live traffic against.

use super::dataset::LiveDataset;
use super::manifest::{base_signature, BaseSignature, Manifest};
use super::refit::{IncrementalRefit, RefitResult};
use crate::api::model::{CoxModel, FitDiagnostics};
use crate::cox::loss::loss_for_eta;
use crate::cox::CoxProblem;
use crate::data::split::holdout_tail;
use crate::error::{FastSurvivalError, Result};
use crate::metrics::{concordance_index, BreslowBaseline};
use crate::optim::cd::SurrogateKind;
use crate::optim::Objective;
use crate::serve::drift::{DriftReference, DriftRegistry};
use crate::serve::ModelRegistry;
use crate::store::CoxData;
use crate::util::compute::Compute;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// What the watcher compares across cycles to decide "the store grew".
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreFingerprint {
    pub base: BaseSignature,
    /// Committed segment sequence numbers, in manifest order.
    pub segments: Vec<u64>,
}

/// Read the current fingerprint of a store (base header signature +
/// committed segments).
pub fn fingerprint(store: &Path) -> Result<StoreFingerprint> {
    let base = base_signature(store)?;
    let segments = match Manifest::load_valid(store)? {
        Some(m) => m.segments.iter().map(|s| s.seq).collect(),
        None => Vec::new(),
    };
    Ok(StoreFingerprint { base, segments })
}

/// Holdout-tail validation metrics for one coefficient vector.
#[derive(Clone, Copy, Debug)]
pub struct HoldoutMetrics {
    pub cindex: f64,
    /// Partial-likelihood deviance vs the null model on the holdout,
    /// `2·(ℓ(β) − ℓ(0))` in negated-log-likelihood form — lower is
    /// better, 0 means "no better than no model".
    pub deviance: f64,
    pub n: usize,
    pub n_events: usize,
}

/// Relative noise margin for the publish gate. A refit on identical
/// data lands within the KKT certificate's radius of the incumbent, so
/// its holdout metrics differ from the incumbent's by optimizer noise
/// far below this margin — sub-margin "improvements" must not churn
/// versions.
pub const GATE_MARGIN: f64 = 1e-6;

/// The strict-improvement publish gate: no worse on either holdout
/// metric (within [`GATE_MARGIN`]) and better than the margin on at
/// least one.
pub fn improves(candidate: &HoldoutMetrics, incumbent: &HoldoutMetrics) -> bool {
    let ci_margin = GATE_MARGIN;
    let dev_margin = GATE_MARGIN * incumbent.deviance.abs().max(1.0);
    let ci_no_worse = candidate.cindex >= incumbent.cindex - ci_margin;
    let dev_no_worse = candidate.deviance <= incumbent.deviance + dev_margin;
    let ci_better = candidate.cindex > incumbent.cindex + ci_margin;
    let dev_better = candidate.deviance < incumbent.deviance - dev_margin;
    ci_no_worse && dev_no_worse && (ci_better || dev_better)
}

/// What one watch cycle did.
#[derive(Clone, Debug)]
pub struct CycleReport {
    pub refit_secs: f64,
    /// Exact-phase sweeps the warm refit ran.
    pub sweeps: usize,
    pub candidate: HoldoutMetrics,
    /// `None` when no incumbent version exists yet.
    pub incumbent: Option<HoldoutMetrics>,
    /// The version published this cycle (`None` = gate rejected).
    pub published: Option<u64>,
    /// Human-readable gate decision.
    pub reason: String,
}

/// Configuration for the watch/refit/publish loop.
#[derive(Clone, Debug)]
pub struct Watcher {
    /// The `.fsds` store being appended to.
    pub store: PathBuf,
    /// The registry artifact directory published into.
    pub artifacts: PathBuf,
    /// Model name; versions are `<name>@1`, `<name>@2`, …
    pub name: String,
    pub objective: Objective,
    pub surrogate: SurrogateKind,
    pub max_sweeps: usize,
    pub stop_kkt: f64,
    pub warmup_passes: usize,
    pub seed: u64,
    /// Kernel backend / thread request forwarded to each cycle's refit,
    /// resolved once per refit.
    pub compute: Compute,
    /// Fraction of merged rows held out for validation.
    pub holdout_frac: f64,
    /// Seed for the holdout permutation — fixed per deployment so the
    /// incumbent and every future candidate are judged on the same tail.
    pub holdout_seed: u64,
}

impl Watcher {
    pub fn new(store: impl Into<PathBuf>, artifacts: impl Into<PathBuf>, name: &str) -> Watcher {
        Watcher {
            store: store.into(),
            artifacts: artifacts.into(),
            name: name.to_string(),
            objective: Objective { l1: 0.0, l2: 1.0 },
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 10_000,
            stop_kkt: 1e-9,
            warmup_passes: 1,
            seed: 0,
            compute: Compute::default(),
            holdout_frac: 0.1,
            holdout_seed: 17,
        }
    }

    /// Run one full cycle: open the live view, warm-refit from the
    /// incumbent (zeros when none), validate both on the holdout tail,
    /// and publish the candidate iff the gate passes.
    pub fn run_cycle(&self) -> Result<CycleReport> {
        std::fs::create_dir_all(&self.artifacts).map_err(|e| {
            FastSurvivalError::io(format!("creating artifact dir {:?}", self.artifacts), e)
        })?;
        let mut live = LiveDataset::open(&self.store)?;
        let meta = live.meta_arc();

        let registry = ModelRegistry::open(&self.artifacts)?;
        let latest = registry.snapshot().latest_version(&self.name);
        let incumbent_model = match latest {
            Some(v) => Some(load_artifact_model(&self.artifacts, &self.name, v)?),
            None => None,
        };
        let warm_beta = match &incumbent_model {
            Some(m) if m.feature_names() == meta.feature_names => m.beta().to_vec(),
            // Schema drifted (or first cycle): cold-start the refit.
            _ => vec![0.0; meta.p],
        };

        let t0 = Instant::now();
        let refit = IncrementalRefit {
            objective: self.objective,
            surrogate: self.surrogate,
            max_sweeps: self.max_sweeps,
            stop_kkt: self.stop_kkt,
            warmup_passes: self.warmup_passes,
            seed: self.seed,
            compute: self.compute,
        }
        .refit(&mut live, &warm_beta)?;
        let refit_secs = t0.elapsed().as_secs_f64();
        if refit.trace.diverged {
            return Err(FastSurvivalError::Diverged {
                optimizer: format!("incremental-{}", self.surrogate.name()),
                iterations: refit.sweeps,
            });
        }

        let candidate =
            evaluate_holdout(&mut live, &refit.beta, self.holdout_frac, self.holdout_seed)?;
        let incumbent = match &incumbent_model {
            Some(m) if m.feature_names() == meta.feature_names => Some(evaluate_holdout(
                &mut live,
                m.beta(),
                self.holdout_frac,
                self.holdout_seed,
            )?),
            _ => None,
        };

        let publish = match &incumbent {
            None => true,
            Some(inc) => improves(&candidate, inc),
        };
        let (published, reason) = if publish {
            let version = latest.map_or(1, |v| v + 1);
            self.publish(&meta.feature_names, &meta.time, &meta.event, &refit, version, refit_secs)?;
            let reason = match &incumbent {
                None => format!("no incumbent {} — published v{version}", self.name),
                Some(inc) => format!(
                    "improved holdout (C-index {:.6} ≥ {:.6}, deviance {:.6} ≤ {:.6}) — \
                     published v{version}",
                    candidate.cindex, inc.cindex, candidate.deviance, inc.deviance
                ),
            };
            (Some(version), reason)
        } else {
            let inc = incumbent.as_ref().unwrap();
            (
                None,
                format!(
                    "rejected: candidate (C-index {:.6}, deviance {:.6}) does not strictly \
                     improve on incumbent v{} (C-index {:.6}, deviance {:.6})",
                    candidate.cindex,
                    candidate.deviance,
                    latest.unwrap(),
                    inc.cindex,
                    inc.deviance
                ),
            )
        };
        crate::obs::record_watch_cycle(refit_secs, refit.sweeps, published.is_some());
        Ok(CycleReport {
            refit_secs,
            sweeps: refit.sweeps,
            candidate,
            incumbent,
            published,
            reason,
        })
    }

    /// Atomically publish a refit as `<name>@<version>.json` plus its
    /// drift sidecar. The temp file carries a non-`.json` extension so
    /// a crash mid-publish leaves nothing the registry would load.
    fn publish(
        &self,
        feature_names: &[String],
        time: &[f64],
        event: &[bool],
        refit: &RefitResult,
        version: u64,
        wall_secs: f64,
    ) -> Result<()> {
        let baseline = BreslowBaseline::fit(time, event, &refit.eta);
        let n_events = event.iter().filter(|&&e| e).count();
        let diagnostics = FitDiagnostics {
            optimizer: format!("incremental-{}", self.surrogate.name()),
            engine: "live-store".to_string(),
            iterations: refit.sweeps,
            converged: refit.trace.converged,
            budget_exhausted: refit.trace.budget_exhausted,
            objective_value: refit.objective_value,
            l1: self.objective.l1,
            l2: self.objective.l2,
            n_train: time.len(),
            n_events,
            wall_secs,
            trace: refit.trace.clone(),
            report: None,
        };
        let model = CoxModel::from_parts(
            feature_names.to_vec(),
            refit.beta.clone(),
            baseline,
            diagnostics,
        );
        let spec = format!("{}@{version}", self.name);
        let final_path = self.artifacts.join(format!("{spec}.json"));
        let tmp = self.artifacts.join(format!("{spec}.json.partial.tmp"));
        model.save(&tmp)?;
        std::fs::rename(&tmp, &final_path)
            .map_err(|e| FastSurvivalError::io(format!("publishing artifact {final_path:?}"), e))?;
        // The drift reference: the training-score (η) histogram live
        // traffic will be compared against.
        DriftReference::from_scores(&refit.eta)
            .save(&DriftRegistry::sidecar_path(&self.artifacts, &spec))
    }
}

/// Score one β on the deterministic holdout tail of the merged view.
pub fn evaluate_holdout(
    live: &mut LiveDataset,
    beta: &[f64],
    frac: f64,
    seed: u64,
) -> Result<HoldoutMetrics> {
    let n = live.meta().n;
    let (_train, hold) = holdout_tail(n, seed, frac);
    let ds = live.subset_rows(&hold)?;
    let n_events = ds.event.iter().filter(|&&e| e).count();
    if n_events == 0 {
        return Err(FastSurvivalError::InvalidData(format!(
            "holdout tail ({} rows) has no events; raise holdout_frac",
            hold.len()
        )));
    }
    let eta = ds.x.matvec(beta);
    let cindex = concordance_index(&ds.time, &ds.event, &eta);
    let pr = CoxProblem::try_new(&ds)?;
    let eta_sorted: Vec<f64> = pr.order.iter().map(|&i| eta[i]).collect();
    let null_loss = loss_for_eta(&pr, &vec![0.0; ds.n()]);
    let deviance = 2.0 * (loss_for_eta(&pr, &eta_sorted) - null_loss);
    Ok(HoldoutMetrics { cindex, deviance, n: hold.len(), n_events })
}

/// Load the raw `CoxModel` behind a registry artifact, trying the flat
/// layout first, then the nested one.
fn load_artifact_model(artifacts: &Path, name: &str, version: u64) -> Result<CoxModel> {
    let flat = artifacts.join(format!("{name}@{version}.json"));
    if flat.is_file() {
        return CoxModel::load(&flat);
    }
    CoxModel::load(&artifacts.join(name).join(format!("{version}.json")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::live::append::append_rows;
    use crate::store::writer::{write_store, DatasetRows};
    use std::path::PathBuf;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fs_watch_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn seed_store(dir: &Path, n: usize) -> PathBuf {
        let base = dir.join("events.fsds");
        let ds = generate(&SyntheticConfig { n, p: 6, rho: 0.3, k: 3, s: 0.1, seed: 11 });
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &base, 64, "events").unwrap();
        base
    }

    #[test]
    fn fingerprint_tracks_appends() {
        let dir = temp_dir("fp");
        let base = seed_store(&dir, 150);
        let f0 = fingerprint(&base).unwrap();
        assert!(f0.segments.is_empty());
        let extra = generate(&SyntheticConfig { n: 12, p: 6, rho: 0.3, k: 3, s: 0.1, seed: 12 });
        let mut rows = DatasetRows::new(&extra);
        append_rows(&base, &mut rows, 64).unwrap();
        let f1 = fingerprint(&base).unwrap();
        assert_ne!(f0, f1);
        assert_eq!(f1.segments, vec![1]);
        assert_eq!(f0.base, f1.base, "appends leave the base untouched");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gate_requires_strict_improvement() {
        let a = HoldoutMetrics { cindex: 0.70, deviance: -10.0, n: 50, n_events: 20 };
        let same = a;
        assert!(!improves(&same, &a), "ties must not publish");
        let better_ci = HoldoutMetrics { cindex: 0.71, ..a };
        assert!(improves(&better_ci, &a));
        let better_dev = HoldoutMetrics { deviance: -11.0, ..a };
        assert!(improves(&better_dev, &a));
        let mixed = HoldoutMetrics { cindex: 0.72, deviance: -9.0, ..a };
        assert!(!improves(&mixed, &a), "a regression on either metric rejects");
        let noise = HoldoutMetrics { cindex: 0.70, deviance: -10.0 - 1e-9, ..a };
        assert!(!improves(&noise, &a), "sub-margin optimizer noise must not publish");
    }

    #[test]
    fn first_cycle_publishes_and_identical_refit_is_rejected() {
        let dir = temp_dir("cycle");
        let base = seed_store(&dir, 260);
        let artifacts = dir.join("models");
        let watcher = Watcher::new(&base, &artifacts, "events");

        let first = watcher.run_cycle().unwrap();
        assert_eq!(first.published, Some(1), "{}", first.reason);
        assert!(first.incumbent.is_none());
        assert!(artifacts.join("events@1.json").is_file());
        assert!(artifacts.join("events@1.drift").is_file(), "sidecar published");

        // No new data: the deterministic refit reproduces the incumbent,
        // ties on both metrics, and must NOT publish.
        let before = std::fs::read(artifacts.join("events@1.json")).unwrap();
        let second = watcher.run_cycle().unwrap();
        assert_eq!(second.published, None, "{}", second.reason);
        assert!(!artifacts.join("events@2.json").exists());
        assert_eq!(
            std::fs::read(artifacts.join("events@1.json")).unwrap(),
            before,
            "rejected cycle must leave the incumbent byte-identical"
        );

        // Append fresh rows; the refit now sees more data and the gate
        // decides on real metrics (publish or not, the report is sound).
        let extra =
            generate(&SyntheticConfig { n: 40, p: 6, rho: 0.3, k: 3, s: 0.1, seed: 13 });
        let mut rows = DatasetRows::new(&extra);
        append_rows(&base, &mut rows, 64).unwrap();
        let third = watcher.run_cycle().unwrap();
        assert!(third.incumbent.is_some());
        if let Some(v) = third.published {
            assert_eq!(v, 2);
            assert!(artifacts.join("events@2.json").is_file());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn holdout_metrics_are_thread_count_independent_inputs() {
        // holdout_tail is a pure function of (n, seed, frac); two
        // evaluations of the same β must agree bitwise.
        let dir = temp_dir("holdout");
        let base = seed_store(&dir, 200);
        let mut live = LiveDataset::open(&base).unwrap();
        let beta = vec![0.1, -0.2, 0.0, 0.3, 0.0, 0.05];
        let a = evaluate_holdout(&mut live, &beta, 0.15, 9).unwrap();
        let b = evaluate_holdout(&mut live, &beta, 0.15, 9).unwrap();
        assert_eq!(a.cindex.to_bits(), b.cindex.to_bits());
        assert_eq!(a.deviance.to_bits(), b.deviance.to_bits());
        assert!(a.n >= 2 && a.n_events > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
