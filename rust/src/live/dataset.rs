//! [`LiveDataset`]: base store + committed append segments, read as one
//! merged [`CoxData`] in global descending-time order — without
//! rewriting a byte.
//!
//! The merge is defined by the engine's own canonical comparator:
//! concatenate the sources' rows in *arrival order* (base rows in base
//! order, then each segment's rows in segment order — exactly the
//! stream a compaction feeds the writer) and stable-sort by
//! [`descending_time_order`]. Because each source is already sorted,
//! the result is a k-way merge; because it is the *same* stable sort
//! the writer runs at compaction, the merged view's row order, Welford
//! statistics, and per-column constants are all bitwise identical to
//! what [`super::append::compact`] will produce — reading live and
//! reading after compaction are indistinguishable to the trainer.
//!
//! Reads stay chunk-granular: within any global row range, each
//! source's contribution is a run of consecutive within-source rows
//! (merging preserves per-source order), so a merged chunk costs one
//! contiguous range read per source per column.

use super::manifest::{segment_path, Manifest, SegmentEntry};
use crate::cox::lipschitz::LipschitzPair;
use crate::cox::problem::{build_tie_groups, descending_time_order};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::linalg::Matrix;
use crate::store::dataset::read_cells_append;
use crate::store::format::StoreHeader;
use crate::store::source::RunningStats;
use crate::store::{ChunkedDataset, CoxData, StoreMeta};
use std::fs::File;
use std::path::Path;
use std::sync::Arc;

/// One underlying validated store (base or segment).
struct Source {
    file: File,
    header: StoreHeader,
    meta: Arc<StoreMeta>,
}

/// The merged live view over base + segments.
pub struct LiveDataset {
    sources: Vec<Source>,
    /// Global sorted row g → index into the arrival-order concatenation
    /// of all sources' rows. The identity when there are no segments.
    order: Vec<usize>,
    /// Row-count prefix sums over sources (len = sources + 1).
    offsets: Vec<usize>,
    meta: Arc<StoreMeta>,
    /// Reusable buffers: raw bytes, per-source gather, concatenation.
    bytebuf: Vec<u8>,
    srcbufs: Vec<Vec<f64>>,
    concatbuf: Vec<f64>,
}

impl LiveDataset {
    /// Open the base store plus every segment its (valid) manifest
    /// lists. A missing or stale manifest means the base alone is
    /// served — orphan segment files on disk are ignored, exactly as
    /// the crash protocol requires.
    pub fn open(path: &Path) -> Result<LiveDataset> {
        let manifest = Manifest::load_valid(path)?;
        let mut stores = vec![ChunkedDataset::open(path)?];
        if let Some(m) = &manifest {
            for seg in &m.segments {
                let sp = segment_path(path, seg.seq);
                let store = ChunkedDataset::open(&sp)?;
                check_entry(seg, store.meta(), &sp)?;
                stores.push(store);
            }
        }
        LiveDataset::from_stores(stores)
    }

    /// Build the merged view over already-validated stores (index 0 is
    /// the base).
    pub fn from_stores(stores: Vec<ChunkedDataset>) -> Result<LiveDataset> {
        assert!(!stores.is_empty());
        let base_meta = stores[0].meta_arc();
        let (p, chunk_rows) = (base_meta.p, base_meta.chunk_rows);
        for s in &stores[1..] {
            if s.meta().p != p || s.meta().feature_names != base_meta.feature_names {
                return Err(FastSurvivalError::Store(format!(
                    "segment {} does not share the base store's feature schema",
                    s.path().display()
                )));
            }
        }
        let sources: Vec<Source> = stores
            .into_iter()
            .map(|s| {
                let (file, header, meta) = s.into_parts();
                Source { file, header, meta }
            })
            .collect();

        let mut offsets = vec![0usize];
        for s in &sources {
            offsets.push(offsets.last().unwrap() + s.meta.n);
        }
        let n = *offsets.last().unwrap();

        if sources.len() == 1 {
            // No segments: the base is the merged view verbatim.
            let meta = Arc::clone(&sources[0].meta);
            return Ok(LiveDataset {
                sources,
                order: (0..n).collect(),
                offsets,
                meta,
                bytebuf: Vec::new(),
                srcbufs: vec![Vec::new()],
                concatbuf: Vec::new(),
            });
        }

        // Arrival-order concatenation of the O(n) columns, then the
        // writer's own stable sort — the merge.
        let mut concat_time = Vec::with_capacity(n);
        let mut concat_event = Vec::with_capacity(n);
        for s in &sources {
            concat_time.extend_from_slice(&s.meta.time);
            concat_event.extend_from_slice(&s.meta.event);
        }
        let order = descending_time_order(&concat_time);
        let time: Vec<f64> = order.iter().map(|&i| concat_time[i]).collect();
        let event: Vec<bool> = order.iter().map(|&i| concat_event[i]).collect();
        let delta: Vec<f64> = event.iter().map(|&e| if e { 1.0 } else { 0.0 }).collect();
        let (groups, _group_of) = build_tie_groups(&time, &delta);
        let n_events = event.iter().filter(|&&e| e).count();

        // One streaming pass per column: Welford stats in arrival order
        // (the writer's convention — per-column accumulators are
        // independent, so per-column replay is bit-identical to the
        // writer's per-row push), then Xᵀδ / Lipschitz / binary flags in
        // merged ascending row order (the reader's convention).
        let mut group_end_ne = vec![0.0_f64; n];
        for g in &groups {
            if g.n_events > 0 {
                group_end_ne[g.end - 1] = g.n_events as f64;
            }
        }
        let mut sources = sources;
        let mut bytebuf = Vec::new();
        let mut concat_col: Vec<f64> = Vec::with_capacity(n);
        let mut means = Vec::with_capacity(p);
        let mut stds = Vec::with_capacity(p);
        let mut xt_delta = Vec::with_capacity(p);
        let mut lipschitz = Vec::with_capacity(p);
        let mut col_binary = Vec::with_capacity(p);
        for j in 0..p {
            concat_col.clear();
            for s in sources.iter_mut() {
                let rows = s.meta.n;
                read_col_range(&mut s.file, &s.header, &mut bytebuf, j, 0, rows, &mut concat_col)?;
            }
            let mut st = RunningStats::new(1);
            for v in &concat_col {
                st.push_row(std::slice::from_ref(v));
            }
            let (m, s) = st.finish();
            means.push(m[0]);
            stds.push(s[0]);

            let (mut xtd, mut h, mut l) = (0.0_f64, f64::NEG_INFINITY, f64::INFINITY);
            let mut lip = LipschitzPair::default();
            let mut binary = true;
            for g in 0..n {
                let x = concat_col[order[g]];
                xtd += x * delta[g];
                if x > h {
                    h = x;
                }
                if x < l {
                    l = x;
                }
                if x != 0.0 && x != 1.0 {
                    binary = false;
                }
                let ne = group_end_ne[g];
                if ne > 0.0 {
                    lip.add_group(ne, h - l);
                }
            }
            xt_delta.push(xtd);
            lipschitz.push(lip);
            col_binary.push(binary);
        }

        let meta = StoreMeta {
            n,
            p,
            chunk_rows,
            n_chunks: n.div_ceil(chunk_rows),
            name: base_meta.name.clone(),
            feature_names: base_meta.feature_names.clone(),
            means,
            stds,
            time,
            delta,
            event,
            groups,
            n_events,
            xt_delta,
            lipschitz,
            col_binary,
        };
        let srcbufs = sources.iter().map(|_| Vec::new()).collect();
        Ok(LiveDataset {
            sources,
            order,
            offsets,
            meta: Arc::new(meta),
            bytebuf,
            srcbufs,
            concatbuf: Vec::new(),
        })
    }

    /// Number of committed append segments in this view.
    pub fn n_segments(&self) -> usize {
        self.sources.len() - 1
    }

    /// Rows contributed by segments (the "new" rows a warm refit's
    /// warmup should concentrate on).
    pub fn appended_rows(&self) -> usize {
        self.meta.n - self.sources[0].meta.n
    }

    /// Every time-contiguous block the segments contribute, as
    /// `(source index ≥ 1, chunk index within that source)` — the
    /// sampling pool for the incremental warmup.
    pub fn segment_blocks(&self) -> Vec<(usize, usize)> {
        let mut blocks = Vec::new();
        for (s, src) in self.sources.iter().enumerate().skip(1) {
            for c in 0..src.meta.n_chunks {
                blocks.push((s, c));
            }
        }
        blocks
    }

    /// A segment source's own metadata (sorted times/events for block
    /// subproblems).
    pub fn source_meta(&self, s: usize) -> &StoreMeta {
        &self.sources[s].meta
    }

    /// Load one column-major chunk of a single source (`rows`, plus the
    /// chunk's starting row within that source).
    pub fn load_source_chunk(
        &mut self,
        s: usize,
        c: usize,
        buf: &mut Vec<f64>,
    ) -> Result<(usize, usize)> {
        let src = &mut self.sources[s];
        let rows = src.header.rows_in_chunk(c);
        buf.clear();
        read_cells_append(
            &mut src.file,
            &mut self.bytebuf,
            src.header.col_segment_offset(c, 0),
            rows * src.header.p,
            src.header.precision,
            buf,
        )
        .map(|()| (rows, c * src.header.chunk_rows))
    }

    /// Which source the arrival-concatenation index `ci` falls in.
    fn source_of(&self, ci: usize) -> usize {
        let mut s = 0;
        while self.offsets[s + 1] <= ci {
            s += 1;
        }
        s
    }

    /// Materialize a subset of merged rows (by global sorted index) as
    /// an in-memory dataset — the watcher's holdout extraction. Costs
    /// one full-column scan per feature; intended for holdout-sized
    /// subsets, not the whole store.
    pub fn subset_rows(&mut self, idx: &[usize]) -> Result<SurvivalDataset> {
        let mut cols: Vec<Vec<f64>> = Vec::with_capacity(self.meta.p);
        let mut col = Vec::new();
        for j in 0..self.meta.p {
            self.load_col(j, &mut col)?;
            cols.push(idx.iter().map(|&i| col[i]).collect());
        }
        let x = Matrix::from_columns(&cols);
        let time: Vec<f64> = idx.iter().map(|&i| self.meta.time[i]).collect();
        let event: Vec<bool> = idx.iter().map(|&i| self.meta.event[i]).collect();
        let mut ds = SurvivalDataset::new(x, time, event, "holdout");
        ds.feature_names = self.meta.feature_names.clone();
        Ok(ds)
    }
}

/// A committed manifest entry must describe the segment file it points
/// to — a mismatch means the store directory was tampered with.
fn check_entry(entry: &SegmentEntry, meta: &StoreMeta, path: &Path) -> Result<()> {
    if entry.n != meta.n || entry.n_events != meta.n_events {
        return Err(FastSurvivalError::Store(format!(
            "manifest lists segment {} as n={} events={} but the file holds n={} events={}",
            path.display(),
            entry.n,
            entry.n_events,
            meta.n,
            meta.n_events
        )));
    }
    Ok(())
}

impl CoxData for LiveDataset {
    fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    fn meta_arc(&self) -> Arc<StoreMeta> {
        Arc::clone(&self.meta)
    }

    fn load_chunk(&mut self, c: usize, buf: &mut Vec<f64>) -> Result<usize> {
        let r0 = c * self.meta.chunk_rows;
        let rows = self.meta.chunk_rows.min(self.meta.n - r0);
        let n_src = self.sources.len();
        // Per-source run of within-source rows this global range needs.
        let mut lo = vec![usize::MAX; n_src];
        let mut hi = vec![0usize; n_src];
        for g in r0..r0 + rows {
            let ci = self.order[g];
            let s = self.source_of(ci);
            let pos = ci - self.offsets[s];
            lo[s] = lo[s].min(pos);
            hi[s] = hi[s].max(pos + 1);
        }
        buf.clear();
        buf.reserve(rows * self.meta.p);
        for j in 0..self.meta.p {
            for s in 0..n_src {
                if lo[s] < hi[s] {
                    let src = &mut self.sources[s];
                    self.srcbufs[s].clear();
                    read_col_range(
                        &mut src.file,
                        &src.header,
                        &mut self.bytebuf,
                        j,
                        lo[s],
                        hi[s] - lo[s],
                        &mut self.srcbufs[s],
                    )?;
                }
            }
            for k in 0..rows {
                let ci = self.order[r0 + k];
                let s = self.source_of(ci);
                let pos = ci - self.offsets[s];
                buf.push(self.srcbufs[s][pos - lo[s]]);
            }
        }
        Ok(rows)
    }

    fn load_col(&mut self, l: usize, buf: &mut Vec<f64>) -> Result<()> {
        // Arrival-order concatenation (one contiguous full-column read
        // per source — n·8 bytes total, same I/O as a single store),
        // then the merge permutation.
        let mut concat = std::mem::take(&mut self.concatbuf);
        concat.clear();
        for s in self.sources.iter_mut() {
            read_col_range(&mut s.file, &s.header, &mut self.bytebuf, l, 0, s.meta.n, &mut concat)?;
        }
        buf.clear();
        buf.reserve(self.meta.n);
        for &ci in &self.order {
            buf.push(concat[ci]);
        }
        self.concatbuf = concat;
        Ok(())
    }
}

/// Read rows `[start, start+len)` of column `l` from one store,
/// spanning its chunk boundaries with one contiguous read per chunk
/// touched.
fn read_col_range(
    file: &mut File,
    header: &StoreHeader,
    bytebuf: &mut Vec<u8>,
    l: usize,
    start: usize,
    len: usize,
    out: &mut Vec<f64>,
) -> Result<()> {
    let mut row = start;
    let end = start + len;
    while row < end {
        let c = row / header.chunk_rows;
        let within = row - c * header.chunk_rows;
        let crows = header.rows_in_chunk(c);
        let take = (crows - within).min(end - row);
        read_cells_append(
            file,
            bytebuf,
            header.col_segment_offset(c, l) + header.cell_bytes() * within as u64,
            take,
            header.precision,
            out,
        )?;
        row += take;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::live::append::append_rows;
    use crate::store::writer::{write_store, DatasetRows};
    use std::path::PathBuf;

    fn temp_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fs_live_dataset_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn gen(n: usize, seed: u64) -> SurvivalDataset {
        generate(&SyntheticConfig { n, p: 5, rho: 0.3, k: 2, s: 0.1, seed })
    }

    fn store_with_segments(tag: &str) -> (PathBuf, usize) {
        let base = temp_dir().join(format!("{tag}.fsds"));
        let ds = gen(90, 21);
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &base, 16, tag).unwrap();
        let mut total = 90;
        for (n, seed) in [(17, 22), (11, 23)] {
            let extra = gen(n, seed);
            let mut rows = DatasetRows::new(&extra);
            append_rows(&base, &mut rows, 8).unwrap();
            total += n;
        }
        (base, total)
    }

    #[test]
    fn merged_view_matches_compacted_store_bitwise() {
        let (base, total) = store_with_segments("parity");
        let mut live = LiveDataset::open(&base).unwrap();
        assert_eq!(live.n_segments(), 2);
        assert_eq!(live.appended_rows(), 28);
        assert_eq!(live.meta().n, total);
        let live_meta = live.meta_arc();
        let mut live_cols: Vec<Vec<f64>> = Vec::new();
        let mut col = Vec::new();
        for j in 0..5 {
            live.load_col(j, &mut col).unwrap();
            live_cols.push(col.clone());
        }

        // Compact into a single store; every derived quantity and every
        // byte of column data must agree bitwise.
        super::super::append::compact(&base, 0).unwrap();
        let mut flat = ChunkedDataset::open(&base).unwrap();
        let fm = flat.meta_arc();
        assert_eq!(fm.n, total);
        assert_eq!(fm.time, live_meta.time);
        assert_eq!(fm.event, live_meta.event);
        assert_eq!(fm.groups, live_meta.groups);
        assert_eq!(fm.means, live_meta.means, "Welford order must match the writer");
        assert_eq!(fm.stds, live_meta.stds);
        assert_eq!(fm.xt_delta, live_meta.xt_delta);
        assert_eq!(fm.lipschitz, live_meta.lipschitz);
        assert_eq!(fm.col_binary, live_meta.col_binary);
        for j in 0..5 {
            flat.load_col(j, &mut col).unwrap();
            assert_eq!(col, live_cols[j], "column {j}");
        }
    }

    #[test]
    fn chunk_reads_match_column_reads() {
        let (base, total) = store_with_segments("chunks");
        let mut live = LiveDataset::open(&base).unwrap();
        let meta = live.meta_arc();
        let mut cols: Vec<Vec<f64>> = Vec::new();
        let mut col = Vec::new();
        for j in 0..meta.p {
            live.load_col(j, &mut col).unwrap();
            assert_eq!(col.len(), total);
            cols.push(col.clone());
        }
        let mut chunk = Vec::new();
        for c in 0..meta.n_chunks {
            let rows = live.load_chunk(c, &mut chunk).unwrap();
            let r0 = c * meta.chunk_rows;
            for j in 0..meta.p {
                assert_eq!(
                    &chunk[j * rows..(j + 1) * rows],
                    &cols[j][r0..r0 + rows],
                    "chunk {c} column {j}"
                );
            }
        }
    }

    #[test]
    fn no_manifest_serves_the_base_alone() {
        let dir = temp_dir();
        let base = dir.join("plain.fsds");
        let ds = gen(40, 31);
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &base, 16, "plain").unwrap();
        // An orphan segment on disk (crash before manifest commit) is
        // invisible to the reader.
        let orphan = segment_path(&base, 1);
        let extra = gen(9, 32);
        let mut rows = DatasetRows::new(&extra);
        write_store(&mut rows, &orphan, 8, "orphan").unwrap();

        let mut live = LiveDataset::open(&base).unwrap();
        assert_eq!(live.n_segments(), 0);
        assert_eq!(live.meta().n, 40);
        // And it is bitwise the plain store.
        let mut flat = ChunkedDataset::open(&base).unwrap();
        let mut a = Vec::new();
        let mut b = Vec::new();
        for j in 0..5 {
            live.load_col(j, &mut a).unwrap();
            flat.load_col(j, &mut b).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn segment_blocks_cover_all_appended_rows() {
        let (base, _) = store_with_segments("blocks");
        let mut live = LiveDataset::open(&base).unwrap();
        let blocks = live.segment_blocks();
        assert!(!blocks.is_empty());
        let mut seen = 0;
        let mut buf = Vec::new();
        for (s, c) in blocks {
            let (rows, r0) = live.load_source_chunk(s, c, &mut buf).unwrap();
            assert_eq!(buf.len(), rows * 5);
            assert!(r0 + rows <= live.source_meta(s).n);
            seen += rows;
        }
        assert_eq!(seen, live.appended_rows());
    }

    #[test]
    fn subset_rows_extracts_the_requested_rows() {
        let (base, total) = store_with_segments("subset");
        let mut live = LiveDataset::open(&base).unwrap();
        let idx = [0usize, 5, total - 1];
        let sub = live.subset_rows(&idx).unwrap();
        assert_eq!(sub.n(), 3);
        let meta = live.meta_arc();
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(sub.time[k], meta.time[i]);
            assert_eq!(sub.event[k], meta.event[i]);
        }
        let mut col = Vec::new();
        live.load_col(2, &mut col).unwrap();
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(sub.x.get(k, 2), col[i]);
        }
    }
}
