//! ABESS baseline \[71\]: adaptive best-subset selection by splicing.
//!
//! For a target support size k: initialize with the k highest screening
//! scores, fit on the active set, then repeatedly try to *splice* —
//! exchange the s lowest-"sacrifice" active features with the s
//! highest-sacrifice inactive features — accepting an exchange when the
//! refitted loss improves. The sacrifice scores follow the abess paper:
//! backward (active) ζ_j = ½ d2_j β_j², forward (inactive)
//! ξ_j = ½ d1_j² / d2_j.
//!
//! Path-native since the warm-start refactor: [`Abess::run_k_from`]
//! accepts the k−1 solution's state, the Lipschitz table and risk-set
//! workspace are caller-owned (computed once per problem, not once per
//! k), and every refit resumes from the current state through the shared
//! support-restricted CD routine instead of restarting at zeros.

use super::{solution_from_beta, SparseSolution, VariableSelector};
use crate::cox::derivatives::{all_coord_d1_d2, Workspace};
use crate::cox::lipschitz::{all_lipschitz, LipschitzPair};
use crate::cox::loss::loss;
use crate::cox::{CoxProblem, CoxState};
use crate::optim::cd::{fit_support_warm, SurrogateKind};
use crate::optim::{FitConfig, Objective};

/// ABESS splicing configuration (mirrors the defaults the paper used:
/// `primary_model_fit_max_iter = 20`, exact Newton refits replaced by our
/// CD engine which plays the role of `primary_model_fit`).
#[derive(Clone, Debug)]
pub struct Abess {
    /// Maximum splicing exchange size s_max.
    pub max_exchange: usize,
    /// CD sweeps per refit.
    pub fit_sweeps: usize,
    /// Maximum splicing rounds.
    pub max_rounds: usize,
    /// Stabilizing ridge.
    pub l2: f64,
}

impl Default for Abess {
    fn default() -> Self {
        Abess { max_exchange: 2, fit_sweeps: 20, max_rounds: 10, l2: 0.0 }
    }
}

impl Abess {
    /// Fit coefficients restricted to `support` (sorted ascending),
    /// warm-started from `init` when given (coefficients outside the
    /// target support are zeroed first so the restricted fit starts
    /// feasible). Returns (state, unpenalized loss).
    fn refit_from(
        &self,
        problem: &CoxProblem,
        init: Option<&CoxState>,
        support: &[usize],
        lip: &[LipschitzPair],
        ws: &mut Workspace,
    ) -> (CoxState, f64) {
        let mut st = match init {
            Some(s) => {
                let mut st = s.clone();
                for l in 0..problem.p() {
                    if st.beta[l] != 0.0 && support.binary_search(&l).is_err() {
                        let d = -st.beta[l];
                        st.update_coord(problem, l, d);
                    }
                }
                st
            }
            None => CoxState::zeros(problem),
        };
        let cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: self.l2 },
            max_iters: self.fit_sweeps,
            tol: 1e-8,
            budget_secs: 0.0,
            record_trace: false,
            ..Default::default()
        };
        fit_support_warm(problem, &mut st, support, &cfg, lip, SurrogateKind::Cubic, ws);
        let final_loss = loss(problem, &st);
        (st, final_loss)
    }

    /// Solve for one target size k (cold: screens at β = 0 and computes
    /// its own Lipschitz table — use [`Abess::run_k_from`] to amortize
    /// both across a path).
    pub fn run_k(&self, problem: &CoxProblem, k: usize) -> SparseSolution {
        let lip = all_lipschitz(problem);
        let mut ws = Workspace::default();
        self.run_k_from(problem, k, None, &lip, &mut ws).0
    }

    /// Solve for one target size k from an optional warm state (typically
    /// the k−1 solution on a cardinality path). Returns the solution plus
    /// the fitted state so callers can chain warm starts. `lip` and `ws`
    /// are caller-owned: the Lipschitz pairs depend only on the data, so
    /// one table serves every k, and the version-tagged risk-set cache
    /// carries across refits.
    pub fn run_k_from(
        &self,
        problem: &CoxProblem,
        k: usize,
        warm: Option<&CoxState>,
        lip: &[LipschitzPair],
        ws: &mut Workspace,
    ) -> (SparseSolution, CoxState) {
        let p = problem.p();
        let k = k.min(p);

        // Initial active set. Warm: keep the warm support's strongest
        // coordinates (backward sacrifice) and top up to k with the best
        // inactive screening scores at the warm state. Cold: screen at
        // β = 0 exactly as before.
        let screen_state = match warm {
            Some(s) => s.clone(),
            None => CoxState::zeros(problem),
        };
        let (d1s, d2s) = all_coord_d1_d2(problem, &screen_state, ws);
        let mut active: Vec<usize> = match warm {
            Some(s) => {
                let mut sup: Vec<(f64, usize)> = (0..p)
                    .filter(|&l| s.beta[l] != 0.0)
                    .map(|l| (0.5 * d2s[l].max(0.0) * s.beta[l] * s.beta[l], l))
                    .collect();
                sup.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                sup.truncate(k);
                let mut act: Vec<usize> = sup.into_iter().map(|(_, l)| l).collect();
                if act.len() < k {
                    let mut fwd: Vec<(f64, usize)> = (0..p)
                        .filter(|l| !act.contains(l))
                        .map(|l| {
                            let d2 = d2s[l].max(1e-12);
                            (0.5 * d1s[l] * d1s[l] / d2, l)
                        })
                        .collect();
                    fwd.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                    let need = k - act.len();
                    for &(_, l) in fwd.iter().take(need) {
                        act.push(l);
                    }
                }
                act
            }
            None => {
                let mut scored: Vec<(f64, usize)> = (0..p)
                    .map(|l| {
                        let d2 = d2s[l].max(1e-12);
                        (0.5 * d1s[l] * d1s[l] / d2, l)
                    })
                    .collect();
                scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                scored.into_iter().take(k).map(|(_, l)| l).collect()
            }
        };
        active.sort_unstable();

        let (mut state, mut best_loss) = self.refit_from(problem, warm, &active, lip, ws);

        for _round in 0..self.max_rounds {
            let (d1s, d2s) = all_coord_d1_d2(problem, &state, ws);
            // Backward sacrifice for active, forward for inactive.
            let mut backward: Vec<(f64, usize)> = active
                .iter()
                .map(|&l| (0.5 * d2s[l].max(0.0) * state.beta[l] * state.beta[l], l))
                .collect();
            backward.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let mut forward: Vec<(f64, usize)> = (0..p)
                .filter(|l| !active.contains(l))
                .map(|l| {
                    let d2 = d2s[l].max(1e-12);
                    (0.5 * d1s[l] * d1s[l] / d2, l)
                })
                .collect();
            forward.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

            let mut improved = false;
            for s in 1..=self.max_exchange.min(k).min(forward.len()) {
                let mut cand: Vec<usize> = active
                    .iter()
                    .filter(|l| !backward[..s].iter().any(|&(_, b)| b == **l))
                    .copied()
                    .collect();
                cand.extend(forward[..s].iter().map(|&(_, f)| f));
                cand.sort_unstable();
                let (new_state, new_loss) =
                    self.refit_from(problem, Some(&state), &cand, lip, ws);
                if new_loss < best_loss - 1e-10 {
                    active = cand;
                    state = new_state;
                    best_loss = new_loss;
                    improved = true;
                    break;
                }
            }
            if !improved {
                break;
            }
        }
        (solution_from_beta(problem, state.beta.clone()), state)
    }
}

impl VariableSelector for Abess {
    fn name(&self) -> &'static str {
        "abess"
    }

    /// One warm-started sweep over the requested sizes: the Lipschitz
    /// table and workspace are built once, and each k resumes from the
    /// previous solution's state.
    fn select(&self, problem: &CoxProblem, ks: &[usize]) -> Vec<SparseSolution> {
        let lip = all_lipschitz(problem);
        let mut ws = Workspace::default();
        let mut warm: Option<CoxState> = None;
        let mut out = Vec::with_capacity(ks.len());
        for &k in ks {
            let (sol, state) = self.run_k_from(problem, k, warm.as_ref(), &lip, &mut ws);
            out.push(sol);
            warm = Some(state);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    #[test]
    fn recovers_easy_signal() {
        let ds = generate(&SyntheticConfig { n: 300, p: 20, rho: 0.2, k: 3, s: 0.1, seed: 7 });
        let pr = CoxProblem::new(&ds);
        let sol = Abess::default().run_k(&pr, 3);
        let truth: Vec<usize> = ds
            .true_beta
            .as_ref()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, b)| **b != 0.0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(sol.support, truth);
    }

    #[test]
    fn returns_exact_k() {
        let ds = generate(&SyntheticConfig { n: 200, p: 15, rho: 0.5, k: 4, s: 0.1, seed: 8 });
        let pr = CoxProblem::new(&ds);
        for k in [1, 2, 5] {
            let sol = Abess::default().run_k(&pr, k);
            assert_eq!(sol.k, k, "requested {k}, got {}", sol.k);
        }
    }

    #[test]
    fn splicing_never_hurts_loss() {
        let ds = generate(&SyntheticConfig { n: 200, p: 20, rho: 0.8, k: 4, s: 0.1, seed: 9 });
        let pr = CoxProblem::new(&ds);
        // Initial screen-only fit (no splicing rounds).
        let no_splice = Abess { max_rounds: 0, ..Default::default() }.run_k(&pr, 4);
        let spliced = Abess::default().run_k(&pr, 4);
        assert!(spliced.train_loss <= no_splice.train_loss + 1e-9);
    }

    #[test]
    fn warm_start_matches_requested_sizes_and_does_not_hurt() {
        let ds = generate(&SyntheticConfig { n: 250, p: 18, rho: 0.4, k: 3, s: 0.1, seed: 10 });
        let pr = CoxProblem::new(&ds);
        let ab = Abess::default();
        let ks: Vec<usize> = (1..=6).collect();
        let warm_sols = ab.select(&pr, &ks);
        assert_eq!(warm_sols.len(), ks.len());
        for (sol, &k) in warm_sols.iter().zip(ks.iter()) {
            assert_eq!(sol.k, k);
        }
        // Warm chaining grows the active set from the k−1 state, and both
        // the restricted CD and splicing are monotone from that warm
        // init, so the loss can only improve along the k-path.
        for w in warm_sols.windows(2) {
            assert!(
                w[1].train_loss <= w[0].train_loss + 1e-6,
                "k-path loss increased: {} -> {}",
                w[0].train_loss,
                w[1].train_loss
            );
        }
    }
}
