//! ABESS baseline \[71\]: adaptive best-subset selection by splicing.
//!
//! For a target support size k: initialize with the k highest screening
//! scores, fit on the active set, then repeatedly try to *splice* —
//! exchange the s lowest-"sacrifice" active features with the s
//! highest-sacrifice inactive features — accepting an exchange when the
//! refitted loss improves. The sacrifice scores follow the abess paper:
//! backward (active) ζ_j = ½ d2_j β_j², forward (inactive)
//! ξ_j = ½ d1_j² / d2_j.

use super::{solution_from_beta, SparseSolution, VariableSelector};
use crate::cox::derivatives::{all_coord_d1_d2, Workspace};
use crate::cox::lipschitz::{all_lipschitz, LipschitzPair};
use crate::cox::loss::loss;
use crate::cox::{CoxProblem, CoxState};
use crate::optim::cubic::cubic_coord_step;
use crate::optim::Objective;

/// ABESS splicing configuration (mirrors the defaults the paper used:
/// `primary_model_fit_max_iter = 20`, exact Newton refits replaced by our
/// CD engine which plays the role of `primary_model_fit`).
#[derive(Clone, Debug)]
pub struct Abess {
    /// Maximum splicing exchange size s_max.
    pub max_exchange: usize,
    /// CD sweeps per refit.
    pub fit_sweeps: usize,
    /// Maximum splicing rounds.
    pub max_rounds: usize,
    /// Stabilizing ridge.
    pub l2: f64,
}

impl Default for Abess {
    fn default() -> Self {
        Abess { max_exchange: 2, fit_sweeps: 20, max_rounds: 10, l2: 0.0 }
    }
}

impl Abess {
    /// Fit coefficients restricted to `support`; returns (state, loss).
    fn refit(
        &self,
        problem: &CoxProblem,
        support: &[usize],
        lip: &[LipschitzPair],
    ) -> (CoxState, f64) {
        let mut st = CoxState::zeros(problem);
        let obj = Objective { l1: 0.0, l2: self.l2 };
        let mut prev = f64::INFINITY;
        for _ in 0..self.fit_sweeps {
            for &l in support {
                cubic_coord_step(problem, &mut st, l, lip[l], obj);
            }
            let cur = loss(problem, &st);
            if (prev - cur).abs() < 1e-8 * (prev.abs() + 1.0) {
                prev = cur;
                break;
            }
            prev = cur;
        }
        let final_loss = prev.min(loss(problem, &st));
        (st, final_loss)
    }

    /// Solve for one target size k.
    pub fn run_k(&self, problem: &CoxProblem, k: usize) -> SparseSolution {
        let p = problem.p();
        let k = k.min(p);
        let lip = all_lipschitz(problem);
        let mut ws = Workspace::default();

        // Initial screening at β = 0.
        let st0 = CoxState::zeros(problem);
        let (d1s, d2s) = all_coord_d1_d2(problem, &st0, &mut ws);
        let mut scored: Vec<(f64, usize)> = (0..p)
            .map(|l| {
                let d2 = d2s[l].max(1e-12);
                (0.5 * d1s[l] * d1s[l] / d2, l)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut active: Vec<usize> = scored.iter().take(k).map(|&(_, l)| l).collect();
        active.sort_unstable();

        let (mut state, mut best_loss) = self.refit(problem, &active, &lip);

        for _round in 0..self.max_rounds {
            let (d1s, d2s) = all_coord_d1_d2(problem, &state, &mut ws);
            // Backward sacrifice for active, forward for inactive.
            let mut backward: Vec<(f64, usize)> = active
                .iter()
                .map(|&l| (0.5 * d2s[l].max(0.0) * state.beta[l] * state.beta[l], l))
                .collect();
            backward.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let mut forward: Vec<(f64, usize)> = (0..p)
                .filter(|l| !active.contains(l))
                .map(|l| {
                    let d2 = d2s[l].max(1e-12);
                    (0.5 * d1s[l] * d1s[l] / d2, l)
                })
                .collect();
            forward.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

            let mut improved = false;
            for s in 1..=self.max_exchange.min(k).min(forward.len()) {
                let mut cand: Vec<usize> = active
                    .iter()
                    .filter(|l| !backward[..s].iter().any(|&(_, b)| b == **l))
                    .copied()
                    .collect();
                cand.extend(forward[..s].iter().map(|&(_, f)| f));
                cand.sort_unstable();
                let (new_state, new_loss) = self.refit(problem, &cand, &lip);
                if new_loss < best_loss - 1e-10 {
                    active = cand;
                    state = new_state;
                    best_loss = new_loss;
                    improved = true;
                    break;
                }
            }
            if !improved {
                break;
            }
        }
        solution_from_beta(problem, state.beta)
    }
}

impl VariableSelector for Abess {
    fn name(&self) -> &'static str {
        "abess"
    }

    fn select(&self, problem: &CoxProblem, ks: &[usize]) -> Vec<SparseSolution> {
        ks.iter().map(|&k| self.run_k(problem, k)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    #[test]
    fn recovers_easy_signal() {
        let ds = generate(&SyntheticConfig { n: 300, p: 20, rho: 0.2, k: 3, s: 0.1, seed: 7 });
        let pr = CoxProblem::new(&ds);
        let sol = Abess::default().run_k(&pr, 3);
        let truth: Vec<usize> = ds
            .true_beta
            .as_ref()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, b)| **b != 0.0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(sol.support, truth);
    }

    #[test]
    fn returns_exact_k() {
        let ds = generate(&SyntheticConfig { n: 200, p: 15, rho: 0.5, k: 4, s: 0.1, seed: 8 });
        let pr = CoxProblem::new(&ds);
        for k in [1, 2, 5] {
            let sol = Abess::default().run_k(&pr, k);
            assert_eq!(sol.k, k, "requested {k}, got {}", sol.k);
        }
    }

    #[test]
    fn splicing_never_hurts_loss() {
        let ds = generate(&SyntheticConfig { n: 200, p: 20, rho: 0.8, k: 4, s: 0.1, seed: 9 });
        let pr = CoxProblem::new(&ds);
        // Initial screen-only fit (no splicing rounds).
        let no_splice = Abess { max_rounds: 0, ..Default::default() }.run_k(&pr, 4);
        let spliced = Abess::default().run_k(&pr, 4);
        assert!(spliced.train_loss <= no_splice.train_loss + 1e-9);
    }
}
