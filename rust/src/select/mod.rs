//! Variable selection for the CPH model.
//!
//! The paper's method: the cardinality-constrained (ℓ0) problem solved by
//! **beam search** over supports, with the surrogate coordinate descent
//! engine doing both feature screening and coefficient fine-tuning
//! (Section 3.5). Baselines: ABESS splicing \[71\], the Coxnet ℓ1 path
//! \[62\], and the Adaptive Lasso \[69\].

pub mod abess;
pub mod adaptive_lasso;
pub mod beam;
pub mod path;

pub use abess::Abess;
pub use adaptive_lasso::AdaptiveLasso;
pub use beam::BeamSearch;
pub use path::CoxnetPath;

/// One sparse solution on the support-size path.
#[derive(Clone, Debug)]
pub struct SparseSolution {
    /// Support size (number of nonzero coefficients).
    pub k: usize,
    /// Indices of nonzero coefficients, ascending.
    pub support: Vec<usize>,
    /// Dense coefficient vector.
    pub beta: Vec<f64>,
    /// Unpenalized CPH training loss at `beta`.
    pub train_loss: f64,
}

/// Common interface: produce one solution per requested support size.
/// `Sync` so cross-validation can fan folds out across threads.
pub trait VariableSelector: Sync {
    fn name(&self) -> &'static str;

    /// Solutions for each target support size in `ks` (ascending). The
    /// returned vector is sorted by `k`; selectors that cannot hit a size
    /// exactly return their closest solution (as the paper's baselines do).
    fn select(&self, problem: &crate::cox::CoxProblem, ks: &[usize]) -> Vec<SparseSolution>;
}

pub(crate) fn solution_from_beta(problem: &crate::cox::CoxProblem, beta: Vec<f64>) -> SparseSolution {
    use crate::cox::{loss::loss, CoxState};
    let support: Vec<usize> = beta
        .iter()
        .enumerate()
        .filter(|(_, b)| b.abs() > 1e-10)
        .map(|(i, _)| i)
        .collect();
    let st = CoxState::from_beta(problem, &beta);
    SparseSolution { k: support.len(), support, beta, train_loss: loss(problem, &st) }
}
