//! Coxnet baseline \[62\]: the ℓ1(+ℓ2) regularization path with warm
//! starts, and exact-support-size extraction (the paper ran Coxnet
//! "forcing the number of non-zero coefficients to be exactly k").

use super::{solution_from_beta, SparseSolution, VariableSelector};
use crate::cox::derivatives::beta_gradient;
use crate::cox::{CoxProblem, CoxState};
use crate::optim::{FitConfig, Objective, Optimizer, QuasiNewton};
use crate::runtime::engine::NativeEngine;

/// Coxnet path configuration.
#[derive(Clone, Debug)]
pub struct CoxnetPath {
    /// Number of path points.
    pub n_lambdas: usize,
    /// λ_min / λ_max ratio (paper: alpha_min_ratio = 0.01).
    pub min_ratio: f64,
    /// ElasticNet mixing: penalty = λ·(l1_ratio‖β‖₁ + (1−l1_ratio)‖β‖₂²).
    pub l1_ratio: f64,
    /// Outer quasi-Newton iterations per path point.
    pub max_outer: usize,
}

impl Default for CoxnetPath {
    fn default() -> Self {
        CoxnetPath { n_lambdas: 50, min_ratio: 0.01, l1_ratio: 1.0, max_outer: 25 }
    }
}

/// One path point.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub lambda: f64,
    pub solution: SparseSolution,
}

impl CoxnetPath {
    /// λ_max: the smallest λ for which β = 0 is optimal (max |∇ℓ(0)|).
    pub fn lambda_max(&self, problem: &CoxProblem) -> f64 {
        let st = CoxState::zeros(problem);
        let g = beta_gradient(problem, &st);
        let gmax = g.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
        gmax / self.l1_ratio.max(1e-12)
    }

    /// Fit the whole warm-started path (λ descending).
    pub fn run(&self, problem: &CoxProblem) -> Vec<PathPoint> {
        let lmax = self.lambda_max(problem);
        let lmin = lmax * self.min_ratio;
        let mut points = Vec::with_capacity(self.n_lambdas);
        let mut warm = CoxState::zeros(problem);
        for i in 0..self.n_lambdas {
            let frac = i as f64 / (self.n_lambdas - 1).max(1) as f64;
            let lambda = lmax * (lmin / lmax).powf(frac);
            let cfg = FitConfig {
                objective: Objective {
                    l1: lambda * self.l1_ratio,
                    l2: lambda * (1.0 - self.l1_ratio),
                },
                max_iters: self.max_outer,
                tol: 1e-9,
                record_trace: false,
                ..Default::default()
            };
            let res = QuasiNewton::default()
                .fit_from(problem, warm.clone(), &cfg, &NativeEngine)
                .expect("native quasi-newton fit is infallible");
            warm = CoxState::from_beta(problem, &res.beta);
            points.push(PathPoint { lambda, solution: solution_from_beta(problem, res.beta) });
        }
        points
    }
}

impl VariableSelector for CoxnetPath {
    fn name(&self) -> &'static str {
        "coxnet"
    }

    /// For each k, the path point whose support size is closest to k
    /// (preferring exact matches with the lowest loss).
    fn select(&self, problem: &CoxProblem, ks: &[usize]) -> Vec<SparseSolution> {
        let path = self.run(problem);
        ks.iter()
            .filter_map(|&k| {
                let exact: Vec<&PathPoint> =
                    path.iter().filter(|p| p.solution.k == k).collect();
                if !exact.is_empty() {
                    return exact
                        .into_iter()
                        .min_by(|a, b| {
                            a.solution.train_loss.partial_cmp(&b.solution.train_loss).unwrap()
                        })
                        .map(|p| p.solution.clone());
                }
                path.iter()
                    .min_by_key(|p| (p.solution.k as i64 - k as i64).unsigned_abs())
                    .map(|p| p.solution.clone())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    #[test]
    fn lambda_max_zeroes_everything() {
        let ds = generate(&SyntheticConfig { n: 150, p: 10, rho: 0.3, k: 2, s: 0.1, seed: 21 });
        let pr = CoxProblem::new(&ds);
        let cp = CoxnetPath { n_lambdas: 3, ..Default::default() };
        let path = cp.run(&pr);
        assert_eq!(path[0].solution.k, 0, "at λ_max the model must be empty");
    }

    #[test]
    fn support_grows_as_lambda_shrinks() {
        let ds = generate(&SyntheticConfig { n: 200, p: 15, rho: 0.3, k: 4, s: 0.1, seed: 22 });
        let pr = CoxProblem::new(&ds);
        let cp = CoxnetPath { n_lambdas: 20, ..Default::default() };
        let path = cp.run(&pr);
        let first = path.first().unwrap().solution.k;
        let last = path.last().unwrap().solution.k;
        assert!(last > first, "support must grow along the path: {first} -> {last}");
    }

    #[test]
    fn select_prefers_exact_sizes() {
        let ds = generate(&SyntheticConfig { n: 200, p: 12, rho: 0.2, k: 3, s: 0.1, seed: 23 });
        let pr = CoxProblem::new(&ds);
        let cp = CoxnetPath { n_lambdas: 30, ..Default::default() };
        let path = cp.run(&pr);
        let achieved: std::collections::BTreeSet<usize> =
            path.iter().map(|p| p.solution.k).collect();
        let sols = cp.select(&pr, &[2]);
        if achieved.contains(&2) {
            assert_eq!(sols[0].k, 2);
        } else {
            assert!(!sols.is_empty());
        }
    }
}
