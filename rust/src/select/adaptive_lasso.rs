//! Adaptive Lasso baseline \[69\] (the paper's SkglmALassoCox).
//!
//! Stage 1: ridge fit to obtain pilot coefficients. Stage 2: weighted ℓ1
//! problem with per-coordinate penalties λ·w_j, w_j = 1/(|β̂_j| + ε)^γ,
//! solved by our quadratic-surrogate CD (the surrogate machinery accepts
//! per-coordinate λ1 trivially since the subproblem is separable).

use super::{solution_from_beta, SparseSolution, VariableSelector};
use crate::cox::derivatives::coord_d1;
use crate::cox::lipschitz::all_lipschitz;
use crate::cox::loss::loss;
use crate::cox::{CoxProblem, CoxState};
use crate::optim::prox::quad_l1_step;
use crate::optim::{FitConfig, Objective, Optimizer, QuadraticSurrogate};

/// Adaptive Lasso over a grid of penalty strengths (paper: 9 alphas).
#[derive(Clone, Debug)]
pub struct AdaptiveLasso {
    /// Penalty grid; the paper used {0.01, 0.05, 0.1, 0.5, 1, 5, 10, 50, 100}.
    pub alphas: Vec<f64>,
    /// Pilot ridge strength.
    pub pilot_l2: f64,
    /// Weight exponent γ.
    pub gamma: f64,
    /// Weight regularizer ε.
    pub eps: f64,
    /// Sweeps for the weighted-ℓ1 stage.
    pub max_sweeps: usize,
}

impl Default for AdaptiveLasso {
    fn default() -> Self {
        AdaptiveLasso {
            alphas: vec![0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 50.0, 100.0],
            pilot_l2: 1.0,
            gamma: 1.0,
            eps: 1e-4,
            max_sweeps: 100,
        }
    }
}

impl AdaptiveLasso {
    /// Weighted-ℓ1 CD fit with per-coordinate penalties `lam[l]`.
    fn weighted_l1_fit(&self, problem: &CoxProblem, lam: &[f64]) -> Vec<f64> {
        let lip = all_lipschitz(problem);
        let mut st = CoxState::zeros(problem);
        let mut prev = f64::INFINITY;
        for _ in 0..self.max_sweeps {
            for l in 0..problem.p() {
                let b = lip[l].l2;
                if b <= 0.0 {
                    continue;
                }
                let a = coord_d1(problem, &st, l);
                let delta = quad_l1_step(a, b, st.beta[l], lam[l]);
                st.update_coord(problem, l, delta);
            }
            let cur = loss(problem, &st)
                + st
                    .beta
                    .iter()
                    .zip(lam)
                    .map(|(b, l)| b.abs() * l)
                    .sum::<f64>();
            if (prev - cur).abs() < 1e-9 * (prev.abs() + 1.0) {
                break;
            }
            prev = cur;
        }
        st.beta
    }

    /// Full two-stage fit at one α; returns the solution.
    pub fn run_alpha(&self, problem: &CoxProblem, alpha: f64) -> SparseSolution {
        // Stage 1: ridge pilot.
        let pilot_cfg = FitConfig {
            objective: Objective { l1: 0.0, l2: self.pilot_l2 },
            max_iters: 100,
            tol: 1e-10,
            record_trace: false,
            ..Default::default()
        };
        let pilot = QuadraticSurrogate
            .fit(problem, &pilot_cfg)
            .expect("native pilot fit is infallible");
        // Stage 2: weighted ℓ1.
        let lam: Vec<f64> = pilot
            .beta
            .iter()
            .map(|b| alpha / (b.abs() + self.eps).powf(self.gamma))
            .collect();
        let beta = self.weighted_l1_fit(problem, &lam);
        solution_from_beta(problem, beta)
    }
}

impl VariableSelector for AdaptiveLasso {
    fn name(&self) -> &'static str {
        "adaptive-lasso"
    }

    /// The α grid yields a set of support sizes; for each requested k we
    /// return the closest achieved solution (like the skglm baseline,
    /// which cannot target k exactly).
    fn select(&self, problem: &CoxProblem, ks: &[usize]) -> Vec<SparseSolution> {
        let sols: Vec<SparseSolution> =
            self.alphas.iter().map(|&a| self.run_alpha(problem, a)).collect();
        ks.iter()
            .filter_map(|&k| {
                sols.iter()
                    .min_by_key(|s| (s.k as i64 - k as i64).unsigned_abs())
                    .cloned()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    #[test]
    fn larger_alpha_is_sparser() {
        let ds = generate(&SyntheticConfig { n: 200, p: 15, rho: 0.3, k: 3, s: 0.1, seed: 11 });
        let pr = CoxProblem::new(&ds);
        let al = AdaptiveLasso::default();
        let s_small = al.run_alpha(&pr, 0.05);
        let s_big = al.run_alpha(&pr, 20.0);
        assert!(s_big.k <= s_small.k, "{} vs {}", s_big.k, s_small.k);
    }

    #[test]
    fn recovers_signal_at_moderate_alpha() {
        let ds = generate(&SyntheticConfig { n: 300, p: 12, rho: 0.2, k: 2, s: 0.1, seed: 12 });
        let pr = CoxProblem::new(&ds);
        let truth: Vec<usize> = ds
            .true_beta
            .as_ref()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, b)| **b != 0.0)
            .map(|(i, _)| i)
            .collect();
        // Some alpha on the default grid should recover the support.
        let al = AdaptiveLasso::default();
        let hit = al
            .alphas
            .iter()
            .map(|&a| al.run_alpha(&pr, a))
            .any(|s| s.support == truth);
        assert!(hit, "no grid point recovered the planted support");
    }

    #[test]
    fn select_returns_one_per_k() {
        let ds = generate(&SyntheticConfig { n: 150, p: 10, rho: 0.3, k: 2, s: 0.1, seed: 13 });
        let pr = CoxProblem::new(&ds);
        let al = AdaptiveLasso {
            alphas: vec![0.1, 1.0, 10.0],
            ..Default::default()
        };
        let sols = al.select(&pr, &[1, 2, 3]);
        assert_eq!(sols.len(), 3);
    }
}
