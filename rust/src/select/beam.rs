//! Beam search for the cardinality-constrained CPH problem (Section 3.5).
//!
//! Starting from the empty support, each expansion step:
//! 1. screens all inactive features with the batched O(np) derivative
//!    pass, estimating each feature's achievable loss decrease from its
//!    own cubic surrogate (a lower bound on the true decrease);
//! 2. evaluates the top screened candidates *exactly* by optimizing that
//!    single coefficient with a few cubic-surrogate steps and measuring
//!    the real loss decrease — "select features based on which
//!    coefficient, if optimized, can result in the largest decrease";
//! 3. keeps the best `width` children (beam), and fine-tunes **all**
//!    nonzero coefficients of each child by coordinate descent.
//!
//! Without the monotone surrogate CD both steps are unreliable — Newton
//! steps can increase the loss mid-expansion, which is exactly why the
//! paper says the beam-search framework "cannot be applied directly to
//! the CPH model" with prior optimizers.

use super::{solution_from_beta, SparseSolution, VariableSelector};
use crate::cox::derivatives::{all_coord_d1_d2, Workspace};
use crate::cox::lipschitz::{all_lipschitz, LipschitzPair};
use crate::cox::loss::loss;
use crate::cox::{CoxProblem, CoxState};
use crate::optim::cubic::cubic_coord_step;
use crate::optim::prox::cubic_step;
use crate::optim::Objective;
use std::collections::BTreeSet;

/// Beam-search ℓ0 solver configuration.
#[derive(Clone, Debug)]
pub struct BeamSearch {
    /// Beam width B (number of parent states kept per level).
    pub width: usize,
    /// Number of screened candidates evaluated exactly per parent.
    pub screen: usize,
    /// Cubic steps used for the exact single-coordinate evaluation.
    pub eval_steps: usize,
    /// CD sweeps for fine-tuning a child's support.
    pub finetune_sweeps: usize,
    /// Relative tolerance for fine-tuning.
    pub finetune_tol: f64,
    /// Small ridge added during fitting for stability (0 = none).
    pub l2: f64,
    /// Swap-polish rounds applied to the best states at each level
    /// (repairs "correlated neighbor" picks; 0 disables).
    pub polish_rounds: usize,
    /// Replacement candidates evaluated per support feature during polish.
    pub polish_candidates: usize,
}

impl Default for BeamSearch {
    fn default() -> Self {
        BeamSearch {
            width: 10,
            screen: 20,
            eval_steps: 4,
            finetune_sweeps: 40,
            finetune_tol: 1e-8,
            l2: 0.0,
            polish_rounds: 2,
            polish_candidates: 5,
        }
    }
}

/// One beam state: a support with fine-tuned coefficients.
#[derive(Clone, Debug)]
struct BeamState {
    state: CoxState,
    support: BTreeSet<usize>,
    loss: f64,
}

impl BeamSearch {
    /// Estimated loss decrease from the cubic surrogate at coordinate l
    /// (surrogate is an upper bound on the loss, so its decrease is a
    /// guaranteed-achievable decrease).
    #[inline]
    fn surrogate_gain(d1: f64, d2: f64, l3: f64) -> f64 {
        let delta = cubic_step(d1, d2.max(0.0), l3);
        -(d1 * delta + 0.5 * d2.max(0.0) * delta * delta + l3 / 6.0 * delta.abs().powi(3))
    }

    /// Exact gain: apply `eval_steps` cubic steps on coordinate l and
    /// measure the true loss decrease. Returns (gain, moved state).
    fn exact_gain(
        &self,
        problem: &CoxProblem,
        parent: &BeamState,
        l: usize,
        lip: &LipschitzPair,
    ) -> (f64, CoxState) {
        let mut st = parent.state.clone();
        let obj = Objective { l1: 0.0, l2: self.l2 };
        for _ in 0..self.eval_steps {
            let d = cubic_coord_step(problem, &mut st, l, *lip, obj);
            if d.abs() < 1e-12 {
                break;
            }
        }
        let new_loss = loss(problem, &st);
        (parent.loss - new_loss, st)
    }

    /// Fine-tune all support coordinates of a child state by cubic CD.
    fn finetune(
        &self,
        problem: &CoxProblem,
        st: &mut CoxState,
        support: &BTreeSet<usize>,
        lip: &[LipschitzPair],
    ) -> f64 {
        let coords: Vec<usize> = support.iter().copied().collect();
        let obj = Objective { l1: 0.0, l2: self.l2 };
        let mut prev = f64::INFINITY;
        for _ in 0..self.finetune_sweeps {
            for &l in &coords {
                cubic_coord_step(problem, st, l, lip[l], obj);
            }
            let cur = loss(problem, st);
            if (prev - cur).abs() < self.finetune_tol * (prev.abs() + 1.0) {
                return cur;
            }
            prev = cur;
        }
        prev
    }

    /// Swap-polish one beam state in place: for every support feature,
    /// try replacing it with each of the top screened inactive
    /// candidates (evaluated after zeroing the feature), keep the best
    /// improving exchange, and repeat for `polish_rounds` rounds. This
    /// repairs the classic failure under ρ→1 correlation where a
    /// *neighbor* of a true feature is greedily picked and never
    /// revisited by pure forward selection.
    fn polish(
        &self,
        problem: &CoxProblem,
        bs: &mut BeamState,
        lip: &[LipschitzPair],
        ws: &mut Workspace,
    ) {
        for _ in 0..self.polish_rounds {
            let mut improved = false;
            let support: Vec<usize> = bs.support.iter().copied().collect();
            for &j in &support {
                // Remove j from the model.
                let mut removed = bs.state.clone();
                let bj = removed.beta[j];
                if bj != 0.0 {
                    removed.update_coord(problem, j, -bj);
                }
                // Screen replacements on the reduced model.
                let (d1s, d2s) = all_coord_d1_d2(problem, &removed, ws);
                let mut scored: Vec<(f64, usize)> = (0..problem.p())
                    .filter(|l| !bs.support.contains(l) || *l == j)
                    .filter(|l| lip[*l].l2 > 0.0)
                    .map(|l| (Self::surrogate_gain(d1s[l], d2s[l], lip[l].l3), l))
                    .collect();
                scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                scored.truncate(self.polish_candidates);
                // Evaluate each replacement exactly.
                for (_, c) in scored {
                    if c == j {
                        continue;
                    }
                    let mut candidate_state = removed.clone();
                    let obj = Objective { l1: 0.0, l2: self.l2 };
                    for _ in 0..self.eval_steps {
                        let d = cubic_coord_step(problem, &mut candidate_state, c, lip[c], obj);
                        if d.abs() < 1e-12 {
                            break;
                        }
                    }
                    let mut new_support = bs.support.clone();
                    new_support.remove(&j);
                    new_support.insert(c);
                    let new_loss =
                        self.finetune(problem, &mut candidate_state, &new_support, lip);
                    if new_loss < bs.loss - 1e-10 {
                        bs.state = candidate_state;
                        bs.support = new_support;
                        bs.loss = new_loss;
                        improved = true;
                        break; // j replaced; move to next feature
                    }
                }
            }
            if !improved {
                break;
            }
        }
    }

    /// Run beam search up to support size `max_k`; returns the best
    /// solution found at every size 1..=max_k.
    pub fn run(&self, problem: &CoxProblem, max_k: usize) -> Vec<SparseSolution> {
        self.run_from(problem, max_k, None)
    }

    /// [`BeamSearch::run`] from an optional warm state: its nonzero
    /// coefficients seed the root support, so expansion continues from a
    /// previous path solve instead of rebuilding every level from the
    /// empty model. Sizes at or below the warm support are not revisited.
    pub fn run_from(
        &self,
        problem: &CoxProblem,
        max_k: usize,
        warm: Option<CoxState>,
    ) -> Vec<SparseSolution> {
        let p = problem.p();
        let max_k = max_k.min(p);
        let lip = all_lipschitz(problem);
        let mut ws = Workspace::default();

        let root = match warm {
            Some(state) => {
                let support: BTreeSet<usize> = state
                    .beta
                    .iter()
                    .enumerate()
                    .filter(|(_, b)| **b != 0.0)
                    .map(|(l, _)| l)
                    .collect();
                let l0 = loss(problem, &state);
                BeamState { state, support, loss: l0 }
            }
            None => {
                let state = CoxState::zeros(problem);
                let l0 = loss(problem, &state);
                BeamState { state, support: BTreeSet::new(), loss: l0 }
            }
        };
        let mut beam = vec![root];
        let mut best_per_k: Vec<Option<SparseSolution>> = vec![None; max_k + 1];
        // A warm root is itself a solution at its own size.
        let warm_k = beam[0].support.len();
        if warm_k >= 1 && warm_k <= max_k {
            best_per_k[warm_k] =
                Some(solution_from_beta(problem, beam[0].state.beta.clone()));
        }

        for _k in (warm_k + 1)..=max_k {
            let mut children: Vec<BeamState> = Vec::new();
            let mut seen: BTreeSet<Vec<usize>> = BTreeSet::new();
            for parent in &beam {
                // 1. screen all inactive coordinates by surrogate gain.
                let (d1s, d2s) = all_coord_d1_d2(problem, &parent.state, &mut ws);
                let mut scored: Vec<(f64, usize)> = (0..p)
                    .filter(|l| !parent.support.contains(l) && lip[*l].l2 > 0.0)
                    .map(|l| (Self::surrogate_gain(d1s[l], d2s[l], lip[l].l3), l))
                    .collect();
                scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                scored.truncate(self.screen);

                // 2. evaluate the screened candidates exactly.
                let mut evaluated: Vec<(f64, usize, CoxState)> = scored
                    .into_iter()
                    .map(|(_, l)| {
                        let (gain, st) = self.exact_gain(problem, parent, l, &lip[l]);
                        (gain, l, st)
                    })
                    .collect();
                evaluated.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                evaluated.truncate(self.width);

                // 3. spawn children (dedup by support), fine-tune later.
                for (_, l, st) in evaluated {
                    let mut support = parent.support.clone();
                    support.insert(l);
                    let key: Vec<usize> = support.iter().copied().collect();
                    if seen.insert(key) {
                        let child_loss = loss(problem, &st);
                        children.push(BeamState { state: st, support, loss: child_loss });
                    }
                }
            }
            if children.is_empty() {
                break;
            }
            // Fine-tune each child fully, then keep the best `width`.
            for child in &mut children {
                child.loss = self.finetune(problem, &mut child.state, &child.support, &lip);
            }
            children.sort_by(|a, b| a.loss.partial_cmp(&b.loss).unwrap());
            children.truncate(self.width);

            // Swap-polish the leading states so neighbor-pick errors do
            // not compound through later expansion levels.
            if self.polish_rounds > 0 {
                let top = children.len().min(2);
                for child in children.iter_mut().take(top) {
                    self.polish(problem, child, &lip, &mut ws);
                }
                children.sort_by(|a, b| a.loss.partial_cmp(&b.loss).unwrap());
            }

            let best = &children[0];
            let k = best.support.len();
            if k <= max_k {
                let sol = solution_from_beta(problem, best.state.beta.clone());
                let replace = match &best_per_k[k] {
                    None => true,
                    Some(old) => sol.train_loss < old.train_loss,
                };
                if replace {
                    best_per_k[k] = Some(sol);
                }
            }
            beam = children;
        }

        best_per_k.into_iter().flatten().collect()
    }
}

impl VariableSelector for BeamSearch {
    fn name(&self) -> &'static str {
        "fastsurvival-beam"
    }

    fn select(&self, problem: &CoxProblem, ks: &[usize]) -> Vec<SparseSolution> {
        let max_k = ks.iter().copied().max().unwrap_or(0);
        let path = self.run(problem, max_k);
        // Return the solution at each requested k (path has one per size).
        ks.iter()
            .filter_map(|&k| path.iter().find(|s| s.k == k).cloned())
            .collect()
    }
}

/// Cheap screening used by tests and by ABESS: surrogate gain for every
/// coordinate at the current state.
pub fn screen_gains(problem: &CoxProblem, state: &CoxState) -> Vec<f64> {
    let lip = all_lipschitz(problem);
    let mut ws = Workspace::default();
    let (d1s, d2s) = all_coord_d1_d2(problem, state, &mut ws);
    (0..problem.p())
        .map(|l| BeamSearch::surrogate_gain(d1s[l], d2s[l], lip[l].l3))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    fn small_synthetic(n: usize, p: usize, k: usize, rho: f64, seed: u64) -> CoxProblem {
        let cfg = SyntheticConfig { n, p, rho, k, s: 0.1, seed };
        CoxProblem::new(&generate(&cfg))
    }

    #[test]
    fn recovers_strong_signal_low_correlation() {
        let ds = generate(&SyntheticConfig { n: 300, p: 20, rho: 0.2, k: 3, s: 0.1, seed: 1 });
        let pr = CoxProblem::new(&ds);
        let bs = BeamSearch { width: 5, screen: 10, ..Default::default() };
        let path = bs.run(&pr, 3);
        let sol = path.iter().find(|s| s.k == 3).expect("k=3 solution");
        let truth: Vec<usize> = ds
            .true_beta
            .as_ref()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, b)| **b != 0.0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(sol.support, truth, "support must match planted signal");
    }

    #[test]
    fn loss_decreases_along_path() {
        let pr = small_synthetic(200, 15, 4, 0.5, 2);
        let bs = BeamSearch { width: 3, screen: 8, ..Default::default() };
        let path = bs.run(&pr, 5);
        assert!(path.len() >= 4);
        for w in path.windows(2) {
            assert!(w[1].train_loss <= w[0].train_loss + 1e-9, "path must improve");
            assert!(w[1].k > w[0].k);
        }
    }

    #[test]
    fn exact_gain_is_at_least_surrogate_gain() {
        // The surrogate upper-bounds the loss, so the true decrease from
        // the cubic step must be >= the surrogate-predicted decrease.
        let pr = small_synthetic(150, 10, 3, 0.3, 3);
        let st = CoxState::zeros(&pr);
        let lip = all_lipschitz(&pr);
        let gains = screen_gains(&pr, &st);
        let bs = BeamSearch { eval_steps: 1, ..Default::default() };
        let root = BeamState {
            state: st.clone(),
            support: BTreeSet::new(),
            loss: loss(&pr, &st),
        };
        for l in 0..pr.p() {
            let (exact, _) = bs.exact_gain(&pr, &root, l, &lip[l]);
            assert!(
                exact >= gains[l] - 1e-8,
                "coord {l}: exact {exact} < surrogate {}",
                gains[l]
            );
        }
    }

    #[test]
    fn warm_root_continues_a_previous_run() {
        let pr = small_synthetic(200, 15, 3, 0.3, 6);
        let bs = BeamSearch { width: 3, screen: 8, ..Default::default() };
        // Cold path up to k=2, then continue from its best k=2 state.
        let head = bs.run(&pr, 2);
        let k2 = head.iter().find(|s| s.k == 2).expect("k=2 solution");
        let warm = CoxState::from_beta(&pr, &k2.beta);
        let tail = bs.run_from(&pr, 4, Some(warm));
        // The warm root is reported at its own size, and expansion only
        // covers the remaining sizes.
        let sizes: Vec<usize> = tail.iter().map(|s| s.k).collect();
        assert!(sizes.contains(&2) && sizes.contains(&3) && sizes.contains(&4), "{sizes:?}");
        assert!(sizes.iter().all(|&k| k >= 2));
        for w in tail.windows(2) {
            assert!(w[1].train_loss <= w[0].train_loss + 1e-9, "warm path must improve");
        }
        // Continuing cannot be worse at k=2 than the state it started from.
        let warm_k2 = tail.iter().find(|s| s.k == 2).unwrap();
        assert!((warm_k2.train_loss - k2.train_loss).abs() < 1e-9);
    }

    #[test]
    fn respects_max_k() {
        let pr = small_synthetic(100, 8, 2, 0.3, 4);
        let bs = BeamSearch { width: 2, screen: 4, ..Default::default() };
        let path = bs.run(&pr, 4);
        assert!(path.iter().all(|s| s.k <= 4));
        let sel = bs.select(&pr, &[1, 3]);
        assert!(sel.iter().all(|s| s.k == 1 || s.k == 3));
    }

    #[test]
    fn handles_correlated_features() {
        // ρ=0.9: greedy screening alone often picks a correlated proxy;
        // beam search with exact evaluation should still recover a
        // support achieving at least as good a loss as the truth.
        let ds = generate(&SyntheticConfig { n: 400, p: 30, rho: 0.9, k: 3, s: 0.1, seed: 5 });
        let pr = CoxProblem::new(&ds);
        let bs = BeamSearch { width: 8, screen: 15, ..Default::default() };
        let path = bs.run(&pr, 3);
        let sol = path.iter().find(|s| s.k == 3).unwrap();
        // Fit the true support for comparison.
        let truth: Vec<usize> = ds
            .true_beta
            .as_ref()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, b)| **b != 0.0)
            .map(|(i, _)| i)
            .collect();
        let lip = all_lipschitz(&pr);
        let mut st = CoxState::zeros(&pr);
        let support: BTreeSet<usize> = truth.iter().copied().collect();
        let truth_loss = bs.finetune(&pr, &mut st, &support, &lip);
        assert!(
            sol.train_loss <= truth_loss + 1e-3,
            "beam loss {} vs truth-support loss {}",
            sol.train_loss,
            truth_loss
        );
    }
}
