//! The compute-engine abstraction: the same Cox quantities served either
//! by the native Rust kernels (sequential CD hot path) or by the AOT-
//! compiled XLA artifacts (batched screening / parity proof that the
//! three layers compose). Integration tests assert parity.
//!
//! Every [`crate::optim::Optimizer`] takes a `&dyn CoxEngine`, so engine
//! selection threads through one fit path — there is no separate
//! engine-specific driver.

use super::client::{lit_f32, lit_f32_matrix, lit_i32, XlaRuntime};
use crate::cox::derivatives::{self, CoordDerivs, Workspace};
use crate::cox::lipschitz::{self, LipschitzPair};
use crate::cox::{loss, CoxProblem, CoxState};
use crate::error::{FastSurvivalError, Result};
use std::path::Path;

/// Cox quantities every optimizer needs, engine-agnostic.
pub trait CoxEngine {
    fn name(&self) -> &'static str;

    /// True when quantities are computed by the in-process native
    /// kernels. Baselines that need full-gradient/Hessian kernels
    /// (Newton family, GD) require a native engine.
    fn is_native(&self) -> bool {
        false
    }

    /// Unpenalized loss ℓ(β).
    fn loss(&self, problem: &CoxProblem, state: &CoxState) -> Result<f64>;

    /// (d1, d2, d3) at one coordinate.
    fn coord_derivs(&self, problem: &CoxProblem, state: &CoxState, l: usize)
        -> Result<CoordDerivs>;

    /// First derivative at one coordinate (quadratic-surrogate hot path).
    fn coord_d1(&self, problem: &CoxProblem, state: &CoxState, l: usize) -> Result<f64> {
        Ok(self.coord_derivs(problem, state, l)?.d1)
    }

    /// (d1, d2) at one coordinate (cubic-surrogate hot path).
    fn coord_d1_d2(&self, problem: &CoxProblem, state: &CoxState, l: usize) -> Result<(f64, f64)> {
        let d = self.coord_derivs(problem, state, l)?;
        Ok((d.d1, d.d2))
    }

    /// Batched (d1\[p\], d2\[p\]) over all coordinates.
    fn all_d1_d2(&self, problem: &CoxProblem, state: &CoxState) -> Result<(Vec<f64>, Vec<f64>)>;

    /// Batched (d1\[p\], d2\[p\]) reusing a caller-held [`Workspace`] so
    /// repeated screening passes share the per-η risk-set weight cache.
    /// Engines without native workspaces ignore `ws` — this keeps one
    /// kernel contract across the native blocked-parallel path and the
    /// AOT-XLA path.
    fn all_d1_d2_ws(
        &self,
        problem: &CoxProblem,
        state: &CoxState,
        ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let _ = ws;
        self.all_d1_d2(problem, state)
    }

    /// Lipschitz constants for one coordinate (Theorem 3.4).
    fn lipschitz(&self, problem: &CoxProblem, l: usize) -> Result<LipschitzPair>;
}

/// In-process Rust kernels (the default request path).
#[derive(Default)]
pub struct NativeEngine;

impl CoxEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn is_native(&self) -> bool {
        true
    }

    fn loss(&self, problem: &CoxProblem, state: &CoxState) -> Result<f64> {
        Ok(loss::loss(problem, state))
    }

    fn coord_derivs(
        &self,
        problem: &CoxProblem,
        state: &CoxState,
        l: usize,
    ) -> Result<CoordDerivs> {
        Ok(derivatives::coord_derivs(problem, state, l))
    }

    fn coord_d1(&self, problem: &CoxProblem, state: &CoxState, l: usize) -> Result<f64> {
        Ok(derivatives::coord_d1(problem, state, l))
    }

    fn coord_d1_d2(&self, problem: &CoxProblem, state: &CoxState, l: usize) -> Result<(f64, f64)> {
        Ok(derivatives::coord_d1_d2(problem, state, l))
    }

    fn all_d1_d2(&self, problem: &CoxProblem, state: &CoxState) -> Result<(Vec<f64>, Vec<f64>)> {
        let mut ws = Workspace::default();
        self.all_d1_d2_ws(problem, state, &mut ws)
    }

    fn all_d1_d2_ws(
        &self,
        problem: &CoxProblem,
        state: &CoxState,
        ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        // The blocked cache-aware kernel, parallel over feature blocks.
        Ok(derivatives::all_coord_d1_d2(problem, state, ws))
    }

    fn lipschitz(&self, problem: &CoxProblem, l: usize) -> Result<LipschitzPair> {
        Ok(lipschitz::coord_lipschitz(problem, l))
    }
}

/// AOT-compiled XLA artifacts on the PJRT CPU client.
pub struct XlaEngine {
    rt: XlaRuntime,
}

impl XlaEngine {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        Ok(XlaEngine { rt: XlaRuntime::new(artifact_dir)? })
    }

    pub fn runtime(&self) -> &XlaRuntime {
        &self.rt
    }

    /// Padded per-sample tensors for an n-bucket: (w, v, delta, tie_end).
    fn padded_base(
        &self,
        problem: &CoxProblem,
        state: &CoxState,
        bucket_n: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>) {
        let n = problem.n();
        assert!(bucket_n >= n);
        let mut w = vec![0.0_f32; bucket_n];
        let mut v = vec![0.0_f32; bucket_n];
        let mut delta = vec![0.0_f32; bucket_n];
        let mut tie_end = vec![(bucket_n - 1) as i32; bucket_n];
        for k in 0..n {
            w[k] = state.w[k] as f32;
            v[k] = (state.eta[k] - state.shift) as f32;
            delta[k] = problem.delta[k] as f32;
            tie_end[k] = (problem.risk_end(k) - 1) as i32;
        }
        (w, v, delta, tie_end)
    }
}

fn no_bucket(entry: &str, n: usize) -> FastSurvivalError {
    FastSurvivalError::Engine(format!("no {entry} bucket for n={n}"))
}

impl CoxEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn loss(&self, problem: &CoxProblem, state: &CoxState) -> Result<f64> {
        let spec = self
            .rt
            .manifest
            .bucket_for_n("cox_loss", problem.n())
            .ok_or_else(|| no_bucket("cox_loss", problem.n()))?;
        let (w, v, delta, tie_end) = self.padded_base(problem, state, spec.n);
        let name = spec.name.clone();
        let out = self.rt.execute(
            &name,
            &[lit_f32(&w), lit_f32(&v), lit_f32(&delta), lit_i32(&tie_end)],
        )?;
        Ok(out[0].to_vec::<f32>()?[0] as f64)
    }

    fn coord_derivs(
        &self,
        problem: &CoxProblem,
        state: &CoxState,
        l: usize,
    ) -> Result<CoordDerivs> {
        let spec = self
            .rt
            .manifest
            .bucket_for_n("coord_derivs", problem.n())
            .ok_or_else(|| no_bucket("coord_derivs", problem.n()))?;
        let bucket_n = spec.n;
        let name = spec.name.clone();
        let (w, _v, delta, tie_end) = self.padded_base(problem, state, bucket_n);
        let mut x = vec![0.0_f32; bucket_n];
        let col = problem.x.col(l);
        for k in 0..problem.n() {
            x[k] = col[k] as f32;
        }
        let out = self.rt.execute(
            &name,
            &[lit_f32(&w), lit_f32(&x), lit_f32(&delta), lit_i32(&tie_end)],
        )?;
        let d = out[0].to_vec::<f32>()?;
        Ok(CoordDerivs { d1: d[0] as f64, d2: d[1] as f64, d3: d[2] as f64 })
    }

    fn all_d1_d2(&self, problem: &CoxProblem, state: &CoxState) -> Result<(Vec<f64>, Vec<f64>)> {
        let n = problem.n();
        let p = problem.p();
        let spec = self
            .rt
            .manifest
            .bucket_for_np("all_derivs", n, p)
            .ok_or_else(|| {
                FastSurvivalError::Engine(format!("no all_derivs bucket for n={n}, p={p}"))
            })?;
        let (bn, bp) = (spec.n, spec.p);
        let name = spec.name.clone();
        let (w, _v, delta, tie_end) = self.padded_base(problem, state, bn);
        // Padded (bn, bp) matrix in column-major f64 for the helper.
        let mut col_major = vec![0.0_f64; bn * bp];
        for c in 0..p {
            let col = problem.x.col(c);
            col_major[c * bn..c * bn + n].copy_from_slice(col);
        }
        let x_lit = lit_f32_matrix(bn, bp, &col_major)?;
        let out = self.rt.execute(
            &name,
            &[lit_f32(&w), x_lit, lit_f32(&delta), lit_i32(&tie_end)],
        )?;
        let d1_full = out[0].to_vec::<f32>()?;
        let d2_full = out[1].to_vec::<f32>()?;
        Ok((
            d1_full[..p].iter().map(|&v| v as f64).collect(),
            d2_full[..p].iter().map(|&v| v as f64).collect(),
        ))
    }

    fn lipschitz(&self, problem: &CoxProblem, l: usize) -> Result<LipschitzPair> {
        let spec = self
            .rt
            .manifest
            .bucket_for_n("lipschitz", problem.n())
            .ok_or_else(|| no_bucket("lipschitz", problem.n()))?;
        let bn = spec.n;
        let name = spec.name.clone();
        let n = problem.n();
        let mut x = vec![0.0_f32; bn];
        let mut delta = vec![0.0_f32; bn];
        let mut tie_end = vec![(bn - 1) as i32; bn];
        let mut valid = vec![0.0_f32; bn];
        let col = problem.x.col(l);
        for k in 0..n {
            x[k] = col[k] as f32;
            delta[k] = problem.delta[k] as f32;
            tie_end[k] = (problem.risk_end(k) - 1) as i32;
            valid[k] = 1.0;
        }
        let out = self.rt.execute(
            &name,
            &[lit_f32(&x), lit_f32(&delta), lit_i32(&tie_end), lit_f32(&valid)],
        )?;
        let v = out[0].to_vec::<f32>()?;
        Ok(LipschitzPair { l2: v[0] as f64, l3: v[1] as f64 })
    }
}

/// Which compute engine serves the Cox quantities — the one registry
/// behind both [`engine_by_name`] (CLI strings) and the `CoxFit` builder.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// In-process Rust kernels (default).
    #[default]
    Native,
    /// AOT-compiled XLA artifacts on the PJRT CPU client (`make
    /// artifacts`; needs the `xla` cargo feature).
    Xla,
}

impl EngineKind {
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Xla => "xla",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "native" => Ok(EngineKind::Native),
            "xla" => Ok(EngineKind::Xla),
            other => Err(FastSurvivalError::Unknown {
                kind: "engine",
                name: other.to_string(),
                expected: "native|xla",
            }),
        }
    }

    /// Instantiate the engine (`artifact_dir` is only read for
    /// [`EngineKind::Xla`]).
    pub fn build(self, artifact_dir: &Path) -> Result<Box<dyn CoxEngine>> {
        match self {
            EngineKind::Native => Ok(Box::new(NativeEngine)),
            EngineKind::Xla => Ok(Box::new(XlaEngine::new(artifact_dir)?)),
        }
    }
}

/// Engine factory for the CLI — a thin wrapper over [`EngineKind`].
pub fn engine_by_name(name: &str, artifact_dir: &Path) -> Result<Box<dyn CoxEngine>> {
    EngineKind::from_name(name)?.build(artifact_dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    fn random_problem(n: usize, p: usize, seed: u64, ties: bool) -> CoxProblem {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> =
            (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n)
            .map(|_| {
                let t = rng.uniform_range(0.5, 9.5);
                if ties {
                    (t * 2.0).round() / 2.0
                } else {
                    t
                }
            })
            .collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        CoxProblem::new(&SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "r"))
    }

    fn xla() -> Option<XlaEngine> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            // Errors (e.g. a build without the `xla` feature) downgrade
            // to a skip rather than a panic.
            XlaEngine::new(dir).ok()
        } else {
            None
        }
    }

    #[test]
    fn native_default_coord_helpers_match_fused_kernels() {
        let ne = NativeEngine;
        let pr = random_problem(120, 3, 40, true);
        let st = CoxState::from_beta(&pr, &[0.2, -0.4, 0.1]);
        for l in 0..3 {
            let d = ne.coord_derivs(&pr, &st, l).unwrap();
            let d1 = ne.coord_d1(&pr, &st, l).unwrap();
            let (e1, e2) = ne.coord_d1_d2(&pr, &st, l).unwrap();
            assert!((d.d1 - d1).abs() < 1e-12);
            assert!((d.d1 - e1).abs() < 1e-12);
            assert!((d.d2 - e2).abs() < 1e-12);
        }
        assert!(ne.is_native());
    }

    #[test]
    fn native_all_d1_d2_ws_matches_plain_and_reuses_cache() {
        let ne = NativeEngine;
        let pr = random_problem(90, 20, 41, true);
        let st = CoxState::from_beta(&pr, &[0.05; 20]);
        let (a1, a2) = ne.all_d1_d2(&pr, &st).unwrap();
        let mut ws = Workspace::default();
        // Twice through the same workspace: second call hits the cache.
        for _ in 0..2 {
            let (b1, b2) = ne.all_d1_d2_ws(&pr, &st, &mut ws).unwrap();
            for l in 0..20 {
                assert!((a1[l] - b1[l]).abs() < 1e-12);
                assert!((a2[l] - b2[l]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn parity_loss() {
        let Some(xe) = xla() else { return };
        let ne = NativeEngine;
        for &ties in &[false, true] {
            let pr = random_problem(200, 4, 42, ties);
            let st = CoxState::from_beta(&pr, &[0.3, -0.2, 0.1, 0.4]);
            let a = ne.loss(&pr, &st).unwrap();
            let b = xe.loss(&pr, &st).unwrap();
            assert!((a - b).abs() / (a.abs() + 1.0) < 1e-4, "native {a} vs xla {b}");
        }
    }

    #[test]
    fn parity_coord_derivs() {
        let Some(xe) = xla() else { return };
        let ne = NativeEngine;
        let pr = random_problem(300, 3, 43, true);
        let st = CoxState::from_beta(&pr, &[0.2, -0.5, 0.0]);
        for l in 0..3 {
            let a = ne.coord_derivs(&pr, &st, l).unwrap();
            let b = xe.coord_derivs(&pr, &st, l).unwrap();
            assert!((a.d1 - b.d1).abs() < 1e-2 * (a.d1.abs() + 1.0), "d1 {} vs {}", a.d1, b.d1);
            assert!((a.d2 - b.d2).abs() < 1e-2 * (a.d2.abs() + 1.0), "d2 {} vs {}", a.d2, b.d2);
            assert!((a.d3 - b.d3).abs() < 2e-2 * (a.d3.abs() + 1.0), "d3 {} vs {}", a.d3, b.d3);
        }
    }

    #[test]
    fn parity_all_derivs() {
        let Some(xe) = xla() else { return };
        let ne = NativeEngine;
        let pr = random_problem(150, 6, 44, false);
        let st = CoxState::from_beta(&pr, &[0.1, 0.2, -0.1, 0.0, 0.3, -0.2]);
        let (a1, a2) = ne.all_d1_d2(&pr, &st).unwrap();
        let (b1, b2) = xe.all_d1_d2(&pr, &st).unwrap();
        for l in 0..6 {
            assert!((a1[l] - b1[l]).abs() < 1e-2 * (a1[l].abs() + 1.0), "{} vs {}", a1[l], b1[l]);
            assert!((a2[l] - b2[l]).abs() < 1e-2 * (a2[l].abs() + 1.0), "{} vs {}", a2[l], b2[l]);
        }
    }

    #[test]
    fn parity_lipschitz() {
        let Some(xe) = xla() else { return };
        let ne = NativeEngine;
        let pr = random_problem(250, 3, 45, true);
        for l in 0..3 {
            let a = ne.lipschitz(&pr, l).unwrap();
            let b = xe.lipschitz(&pr, l).unwrap();
            assert!((a.l2 - b.l2).abs() < 1e-3 * (a.l2 + 1.0), "{} vs {}", a.l2, b.l2);
            assert!((a.l3 - b.l3).abs() < 1e-3 * (a.l3 + 1.0), "{} vs {}", a.l3, b.l3);
        }
    }
}
