//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust request path.
//!
//! Python runs once at build time (`make artifacts`); afterwards the
//! binary is self-contained: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! The PJRT client itself is gated behind the `xla` cargo feature (the
//! bindings crate only exists in the accelerator image); the default
//! build ships a stub whose constructor returns a typed error, so the
//! [`engine::CoxEngine`] abstraction — and everything above it — is
//! engine-complete in every build.

pub mod artifacts;
pub mod client;
pub mod engine;

pub use artifacts::{ArtifactSpec, Manifest};
pub use client::XlaRuntime;
pub use engine::{CoxEngine, NativeEngine, XlaEngine};
