//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust request path.
//!
//! Python runs once at build time (`make artifacts`); afterwards the
//! binary is self-contained: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod artifacts;
pub mod client;
pub mod engine;

pub use artifacts::{ArtifactSpec, Manifest};
pub use client::XlaRuntime;
pub use engine::{CoxEngine, NativeEngine, XlaEngine};
