//! Artifact manifest parsing (TSV — no serde offline) and shape-bucket
//! selection.
//!
//! `manifest.tsv` columns: name, file, n, p, comma-joined `dtype:shape`
//! input signatures, one row per lowered entry point.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered entry point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub n: usize,
    pub p: usize,
    pub input_sig: Vec<String>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `dir/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {path:?}: {e} (run `make artifacts`)"))?;
        let mut entries = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 5 {
                return Err(format!("manifest line {} malformed: {line:?}", lineno + 1));
            }
            let spec = ArtifactSpec {
                name: cols[0].to_string(),
                file: dir.join(cols[1]),
                n: cols[2].parse().map_err(|e| format!("bad n: {e}"))?,
                p: cols[3].parse().map_err(|e| format!("bad p: {e}"))?,
                input_sig: cols[4].split(',').map(|s| s.to_string()).collect(),
            };
            entries.insert(spec.name.clone(), spec);
        }
        if entries.is_empty() {
            return Err("manifest is empty".into());
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Smallest per-coordinate bucket with capacity >= n, by entry prefix
    /// (e.g. "coord_derivs").
    pub fn bucket_for_n(&self, prefix: &str, n: usize) -> Option<&ArtifactSpec> {
        self.entries
            .values()
            .filter(|s| s.name.starts_with(prefix) && !s.name.contains("_p") && s.n >= n)
            .min_by_key(|s| s.n)
    }

    /// Smallest (n, p) bucket covering the problem, for batched entries.
    pub fn bucket_for_np(&self, prefix: &str, n: usize, p: usize) -> Option<&ArtifactSpec> {
        self.entries
            .values()
            .filter(|s| s.name.starts_with(prefix) && s.n >= n && s.p >= p)
            .min_by_key(|s| (s.n, s.p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(lines: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fs_manifest_{}", lines.len()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), lines).unwrap();
        dir
    }

    #[test]
    fn parses_rows() {
        let dir = write_manifest(
            "coord_derivs_n1024\tcoord_derivs_n1024.hlo.txt\t1024\t1\tfloat32:1024,int32:1024\n",
        );
        let m = Manifest::load(&dir).unwrap();
        let s = &m.entries["coord_derivs_n1024"];
        assert_eq!(s.n, 1024);
        assert_eq!(s.input_sig.len(), 2);
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        let dir = write_manifest(
            "coord_derivs_n1024\ta\t1024\t1\tx:1\n\
             coord_derivs_n4096\tb\t4096\t1\tx:1\n\
             all_derivs_n1024_p128\tc\t1024\t128\tx:1\n\
             all_derivs_n4096_p512\td\t4096\t512\tx:1\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for_n("coord_derivs", 500).unwrap().n, 1024);
        assert_eq!(m.bucket_for_n("coord_derivs", 1025).unwrap().n, 4096);
        assert!(m.bucket_for_n("coord_derivs", 999999).is_none());
        let np = m.bucket_for_np("all_derivs", 1000, 200).unwrap();
        assert_eq!((np.n, np.p), (4096, 512));
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn malformed_line_rejected() {
        let dir = write_manifest("too\tfew\tcols\n");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // Integration check against the actual build output when it exists.
        let dir = Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.bucket_for_n("coord_derivs", 1).is_some());
            assert!(m.bucket_for_n("cox_loss", 1).is_some());
            assert!(m.bucket_for_n("lipschitz", 1).is_some());
            assert!(m.bucket_for_np("all_derivs", 1, 1).is_some());
        }
    }
}
