//! PJRT CPU client wrapper: lazy compilation and typed execution of the
//! AOT artifacts. Adapted from /opt/xla-example/load_hlo (the smoke-
//! verified reference wiring for this image).

use super::artifacts::{ArtifactSpec, Manifest};
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;

/// A PJRT CPU client plus a cache of compiled executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
}

impl XlaRuntime {
    /// Create the CPU client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client, manifest, executables: RefCell::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.executables.borrow().contains_key(name) {
            return Ok(());
        }
        let spec: &ArtifactSpec = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.executables.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on literal inputs; returns the flattened tuple
    /// elements of the (return_tuple=True) result.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let exes = self.executables.borrow();
        let exe = exes.get(name).expect("just compiled");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        Ok(lit.to_tuple()?)
    }

    /// Number of compiled (cached) executables — used by perf telemetry.
    pub fn compiled_count(&self) -> usize {
        self.executables.borrow().len()
    }
}

/// f32 vector literal.
pub fn lit_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// i32 vector literal.
pub fn lit_i32(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 matrix literal with shape (rows, cols), from column-major f64 data.
pub fn lit_f32_matrix(rows: usize, cols: usize, col_major: &[f64]) -> Result<xla::Literal> {
    // XLA expects row-major contiguous data for the default layout.
    let mut row_major = vec![0.0_f32; rows * cols];
    for c in 0..cols {
        for r in 0..rows {
            row_major[r * cols + c] = col_major[c * rows + r] as f32;
        }
    }
    Ok(xla::Literal::vec1(&row_major).reshape(&[rows as i64, cols as i64])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<XlaRuntime> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            Some(XlaRuntime::new(dir).expect("runtime"))
        } else {
            None
        }
    }

    #[test]
    fn cpu_client_boots_and_compiles_loss() {
        let Some(rt) = runtime() else { return };
        assert!(!rt.platform().is_empty());
        // cox_loss on trivial data: n=1024 bucket, one event at index 0.
        let n = 1024;
        let mut w = vec![0.0_f32; n];
        let mut v = vec![0.0_f32; n];
        let mut delta = vec![0.0_f32; n];
        let tie_end: Vec<i32> = (0..n as i32).collect();
        // two samples: w=1 each; event at first → loss = ln(1) = 0
        w[0] = 1.0;
        w[1] = 1.0;
        v[0] = 0.0;
        v[1] = 0.0;
        delta[0] = 1.0;
        let out = rt
            .execute(
                "cox_loss_n1024",
                &[lit_f32(&w), lit_f32(&v), lit_f32(&delta), lit_i32(&tie_end)],
            )
            .unwrap();
        let loss: f32 = out[0].to_vec::<f32>().unwrap()[0];
        // Risk set of sample 0 is {0} → log(1) − 0 = 0.
        assert!(loss.abs() < 1e-6, "loss={loss}");
        assert_eq!(rt.compiled_count(), 1);
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("nope", &[]).is_err());
    }

    #[test]
    fn matrix_literal_round_trip() {
        let lit = lit_f32_matrix(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        // column-major input [c0=(1,2), c1=(3,4), c2=(5,6)] → row major
        let v = lit.to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
    }
}
