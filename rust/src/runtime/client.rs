//! PJRT CPU client wrapper: lazy compilation and typed execution of the
//! AOT artifacts.
//!
//! The real client needs the `xla` bindings crate, which only exists in
//! the accelerator build image; it is gated behind the `xla-bindings`
//! cargo feature (which implies `xla`). Every other build — default,
//! `--no-default-features`, and the CI `--features xla` stub build —
//! substitutes a stub with the same surface whose constructor returns a
//! typed [`FastSurvivalError::Unsupported`], so engine selection stays a
//! runtime decision and downstream code compiles unchanged, entirely
//! offline. Inside the image: uncomment the `xla` dependency in
//! `rust/Cargo.toml` and build with `--features xla-bindings`.

#[cfg(feature = "xla-bindings")]
pub use pjrt::{lit_f32, lit_f32_matrix, lit_i32, Literal, XlaRuntime};

#[cfg(not(feature = "xla-bindings"))]
pub use stub::{lit_f32, lit_f32_matrix, lit_i32, Literal, XlaRuntime};

/// Real PJRT-backed runtime (accelerator image only).
#[cfg(feature = "xla-bindings")]
mod pjrt {
    use crate::error::{FastSurvivalError, Result};
    use crate::runtime::artifacts::{ArtifactSpec, Manifest};
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::path::Path;

    pub use xla::Literal;

    impl From<xla::Error> for FastSurvivalError {
        fn from(e: xla::Error) -> Self {
            FastSurvivalError::Engine(format!("xla: {e}"))
        }
    }

    /// A PJRT CPU client plus a cache of compiled executables.
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        executables: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl XlaRuntime {
        /// Create the CPU client and load the manifest from `dir`.
        pub fn new(dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(dir).map_err(FastSurvivalError::Engine)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| FastSurvivalError::Engine(format!("creating PJRT CPU client: {e}")))?;
            Ok(XlaRuntime { client, manifest, executables: RefCell::new(BTreeMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch cached) an artifact by name.
        fn ensure_compiled(&self, name: &str) -> Result<()> {
            if self.executables.borrow().contains_key(name) {
                return Ok(());
            }
            let spec: &ArtifactSpec = self.manifest.entries.get(name).ok_or_else(|| {
                FastSurvivalError::Engine(format!("unknown artifact {name:?}"))
            })?;
            let path = spec
                .file
                .to_str()
                .ok_or_else(|| FastSurvivalError::Engine("non-utf8 artifact path".into()))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| FastSurvivalError::Engine(format!("parsing {:?}: {e}", spec.file)))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| FastSurvivalError::Engine(format!("compiling {name}: {e}")))?;
            self.executables.borrow_mut().insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute an artifact on literal inputs; returns the flattened
        /// tuple elements of the (return_tuple=True) result.
        pub fn execute(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
            self.ensure_compiled(name)?;
            let exes = self.executables.borrow();
            let exe = exes.get(name).expect("just compiled");
            let result = exe
                .execute::<Literal>(inputs)
                .map_err(|e| FastSurvivalError::Engine(format!("executing {name}: {e}")))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| FastSurvivalError::Engine(format!("fetching result of {name}: {e}")))?;
            Ok(lit.to_tuple()?)
        }

        /// Number of compiled (cached) executables — used by perf telemetry.
        pub fn compiled_count(&self) -> usize {
            self.executables.borrow().len()
        }
    }

    /// f32 vector literal.
    pub fn lit_f32(v: &[f32]) -> Literal {
        Literal::vec1(v)
    }

    /// i32 vector literal.
    pub fn lit_i32(v: &[i32]) -> Literal {
        Literal::vec1(v)
    }

    /// f32 matrix literal with shape (rows, cols), from column-major f64
    /// data. XLA expects row-major contiguous data for the default layout.
    pub fn lit_f32_matrix(rows: usize, cols: usize, col_major: &[f64]) -> Result<Literal> {
        let mut row_major = vec![0.0_f32; rows * cols];
        for c in 0..cols {
            for r in 0..rows {
                row_major[r * cols + c] = col_major[c * rows + r] as f32;
            }
        }
        Ok(Literal::vec1(&row_major).reshape(&[rows as i64, cols as i64])?)
    }
}

/// Offline stand-in: the same surface, every entry point reports that the
/// `xla` feature is off. Keeps engine-selection code paths compiling and
/// lets tests degrade to a skip instead of a crash.
#[cfg(not(feature = "xla-bindings"))]
mod stub {
    use crate::error::{FastSurvivalError, Result};
    use crate::runtime::artifacts::Manifest;
    use std::path::Path;

    fn unavailable() -> FastSurvivalError {
        FastSurvivalError::Unsupported(
            "XLA runtime not compiled in; uncomment the `xla` dependency and rebuild \
             with `--features xla-bindings` inside the accelerator image (the bindings \
             crate is not available offline)"
                .into(),
        )
    }

    /// Stand-in for `xla::Literal`.
    pub struct Literal;

    impl Literal {
        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            Err(unavailable())
        }
    }

    /// Stand-in runtime; construction always fails with a typed error.
    pub struct XlaRuntime {
        pub manifest: Manifest,
    }

    impl XlaRuntime {
        pub fn new(dir: &Path) -> Result<Self> {
            // Still validate the manifest so callers get the more specific
            // error when the artifact directory itself is broken.
            Manifest::load(dir).map_err(FastSurvivalError::Engine)?;
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn execute(&self, _name: &str, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            Err(unavailable())
        }

        pub fn compiled_count(&self) -> usize {
            0
        }
    }

    pub fn lit_f32(_v: &[f32]) -> Literal {
        Literal
    }

    pub fn lit_i32(_v: &[i32]) -> Literal {
        Literal
    }

    pub fn lit_f32_matrix(_rows: usize, _cols: usize, _col_major: &[f64]) -> Result<Literal> {
        Ok(Literal)
    }
}

#[cfg(all(test, feature = "xla-bindings"))]
mod tests {
    use super::*;
    use std::path::Path;

    fn runtime() -> Option<XlaRuntime> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            Some(XlaRuntime::new(dir).expect("runtime"))
        } else {
            None
        }
    }

    #[test]
    fn cpu_client_boots_and_compiles_loss() {
        let Some(rt) = runtime() else { return };
        assert!(!rt.platform().is_empty());
        // cox_loss on trivial data: n=1024 bucket, one event at index 0.
        let n = 1024;
        let mut w = vec![0.0_f32; n];
        let mut v = vec![0.0_f32; n];
        let mut delta = vec![0.0_f32; n];
        let tie_end: Vec<i32> = (0..n as i32).collect();
        // two samples: w=1 each; event at first → loss = ln(1) = 0
        w[0] = 1.0;
        w[1] = 1.0;
        v[0] = 0.0;
        v[1] = 0.0;
        delta[0] = 1.0;
        let out = rt
            .execute(
                "cox_loss_n1024",
                &[lit_f32(&w), lit_f32(&v), lit_f32(&delta), lit_i32(&tie_end)],
            )
            .unwrap();
        let loss: f32 = out[0].to_vec::<f32>().unwrap()[0];
        // Risk set of sample 0 is {0} → log(1) − 0 = 0.
        assert!(loss.abs() < 1e-6, "loss={loss}");
        assert_eq!(rt.compiled_count(), 1);
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("nope", &[]).is_err());
    }

    #[test]
    fn matrix_literal_round_trip() {
        let lit = lit_f32_matrix(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        // column-major input [c0=(1,2), c1=(3,4), c2=(5,6)] → row major
        let v = lit.to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
    }
}

#[cfg(all(test, not(feature = "xla-bindings")))]
mod stub_tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn stub_runtime_reports_feature_off() {
        // A syntactically valid artifact dir still yields the typed
        // "feature off" error rather than a panic.
        let dir = std::env::temp_dir().join("fs_stub_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "cox_loss_n64\tloss.hlo.txt\t64\t1\tfloat32:64\n",
        )
        .unwrap();
        let err = XlaRuntime::new(&dir).unwrap_err();
        assert!(err.to_string().contains("xla"), "got: {err}");
        // A broken dir yields the more specific engine error.
        let err = XlaRuntime::new(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("manifest"), "got: {err}");
    }
}
