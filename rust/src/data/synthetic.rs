//! The paper's synthetic data generator (Appendix C.2).
//!
//! Features are drawn from N(0, Σ) with AR(1) correlation Σ_jl = ρ^|j-l|;
//! the true coefficient vector is k-sparse with β*_j = 1 at every
//! (p/k)-th index; death times follow t_i = (-log V_i / exp(x_i^T β*))^s
//! with V_i ~ U(0,1); censoring times C_i ~ U(0,1); δ_i = 1{t_i > C_i}
//! and t_i ← min(t_i, C_i) — exactly the process in Eq. (28)–(31).

use super::survival::SurvivalDataset;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Parameters of the Appendix C.2 generator.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    pub n: usize,
    pub p: usize,
    /// AR(1) correlation level ρ (paper uses 0.9 in Fig 2).
    pub rho: f64,
    /// True support size k (paper uses 15).
    pub k: usize,
    /// Time-shape hyperparameter s (paper uses 0.1).
    pub s: f64,
    pub seed: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig { n: 1200, p: 1200, rho: 0.9, k: 15, s: 0.1, seed: 0 }
    }
}

/// Draw one row of N(0, Σ) with Σ_jl = ρ^|j-l| using the AR(1) recursion
/// x_j = ρ x_{j-1} + sqrt(1-ρ²) ε_j, which has exactly that covariance.
fn ar1_row(p: usize, rho: f64, rng: &mut Rng) -> Vec<f64> {
    let mut row = Vec::with_capacity(p);
    let mut prev = rng.normal();
    row.push(prev);
    let w = (1.0 - rho * rho).sqrt();
    for _ in 1..p {
        let x = rho * prev + w * rng.normal();
        row.push(x);
        prev = x;
    }
    row
}

/// The k-sparse ground truth: β*_j = 1 iff (j+1) mod (p/k) == 0.
/// (The paper states "if j mod (p/k) = 0 then β*_j = 1"; with 1-based
/// indices that plants exactly k coefficients, which we mirror 0-based.)
pub fn true_beta(p: usize, k: usize) -> Vec<f64> {
    let stride = (p / k).max(1);
    let mut beta = vec![0.0; p];
    let mut planted = 0;
    for j in 0..p {
        if (j + 1) % stride == 0 && planted < k {
            beta[j] = 1.0;
            planted += 1;
        }
    }
    beta
}

/// One observation from the Eq. (28)–(31) process given the linear
/// predictor η and two uniforms: `v ∈ (0, 1]` drives the death time,
/// `censor ∈ [0, 1)` the censoring time. Shared by the materializing
/// [`generate`] and the streaming [`SyntheticStream`] so both apply the
/// identical observation model (see the event-convention note below).
#[inline]
fn observe(eta: f64, s: f64, v: f64, censor: f64) -> (f64, bool) {
    let death = (-(v.ln()) / eta.exp()).powf(s);
    // Event convention: the paper's Eq. (30) literally reads
    // δ = 1{t_i > C_i}, but taken literally the observed "events"
    // happen at censoring times C ~ U(0,1) independent of x, which
    // destroys support recovery entirely (we verified: F1 = 0).
    // We therefore use the conventional δ = 1{death <= censor}
    // (failure observed), matching the abess generator [71] the
    // paper builds on. See DESIGN.md "Substitutions".
    (death.min(censor), death <= censor)
}

/// Generate a dataset per Appendix C.2.
pub fn generate(cfg: &SyntheticConfig) -> SurvivalDataset {
    let mut rng = Rng::new(cfg.seed);
    let beta = true_beta(cfg.p, cfg.k);

    let mut x = Matrix::zeros(cfg.n, cfg.p);
    let mut eta = vec![0.0; cfg.n];
    for i in 0..cfg.n {
        let row = ar1_row(cfg.p, cfg.rho, &mut rng);
        let mut e = 0.0;
        for (j, &v) in row.iter().enumerate() {
            x.set(i, j, v);
            if beta[j] != 0.0 {
                e += beta[j] * v;
            }
        }
        eta[i] = e;
    }

    let mut time = Vec::with_capacity(cfg.n);
    let mut event = Vec::with_capacity(cfg.n);
    for &e in &eta {
        // Death time: (-log V / exp(η))^s, V ~ U(0,1).
        let v = 1.0 - rng.uniform(); // (0, 1]
        let censor = rng.uniform();
        let (t, observed_event) = observe(e, cfg.s, v, censor);
        time.push(t);
        event.push(observed_event);
    }

    let mut ds = SurvivalDataset::new(x, time, event, "synthetic");
    ds.name = format!("synthetic_n{}_p{}_rho{}", cfg.n, cfg.p, cfg.rho);
    ds.true_beta = Some(beta);
    ds
}

/// Chunk-at-a-time Appendix-C.2 generator: yields rows in fixed order
/// with O(chunk · p) working memory, so a benchmark dataset of any n can
/// be streamed straight into a `.fsds` store without the O(n·p)
/// allocation [`generate`] makes.
///
/// Determinism: row i's draws depend only on the seed and on i (features
/// and survival times come from two independent sequential streams), so
/// the produced data is identical for every chunking of the same n —
/// asking for chunks of 7 or of 4096 yields the same dataset. The
/// sequence intentionally differs from [`generate`]'s (which draws all
/// features before any survival time and cannot be streamed).
#[derive(Clone, Debug)]
pub struct SyntheticStream {
    cfg: SyntheticConfig,
    beta: Vec<f64>,
    feat_rng: Rng,
    time_rng: Rng,
    produced: usize,
}

impl SyntheticStream {
    pub fn new(cfg: &SyntheticConfig) -> Self {
        SyntheticStream {
            cfg: cfg.clone(),
            beta: true_beta(cfg.p, cfg.k),
            feat_rng: Rng::new(cfg.seed),
            // An independent stream for the survival times: xoshiro
            // seeded through SplitMix64, so any two seeds give
            // uncorrelated sequences.
            time_rng: Rng::new(cfg.seed ^ 0x9E37_79B9_7F4A_7C15),
            produced: 0,
        }
    }

    /// The planted k-sparse ground truth.
    pub fn true_beta(&self) -> &[f64] {
        &self.beta
    }

    /// Rows not yet produced.
    pub fn remaining(&self) -> usize {
        self.cfg.n - self.produced
    }

    /// Produce the next `min(max_rows, remaining)` rows, appending
    /// row-major features to `x` and per-row observations to
    /// `time`/`event`. Returns the number of rows appended (0 at end).
    pub fn next_chunk(
        &mut self,
        max_rows: usize,
        x: &mut Vec<f64>,
        time: &mut Vec<f64>,
        event: &mut Vec<bool>,
    ) -> usize {
        let rows = max_rows.min(self.remaining());
        for _ in 0..rows {
            let row = ar1_row(self.cfg.p, self.cfg.rho, &mut self.feat_rng);
            let mut eta = 0.0;
            for (j, &v) in row.iter().enumerate() {
                if self.beta[j] != 0.0 {
                    eta += self.beta[j] * v;
                }
            }
            x.extend_from_slice(&row);
            let v = 1.0 - self.time_rng.uniform(); // (0, 1]
            let censor = self.time_rng.uniform();
            let (t, e) = observe(eta, self.cfg.s, v, censor);
            time.push(t);
            event.push(e);
        }
        self.produced += rows;
        rows
    }

    /// Materialize the whole stream (tests and small conversions).
    pub fn materialize(mut self) -> SurvivalDataset {
        let cfg = self.cfg.clone();
        let mut x = Vec::with_capacity(cfg.n * cfg.p);
        let mut time = Vec::with_capacity(cfg.n);
        let mut event = Vec::with_capacity(cfg.n);
        while self.next_chunk(4096, &mut x, &mut time, &mut event) > 0 {}
        let mut m = Matrix::zeros(cfg.n, cfg.p);
        for i in 0..cfg.n {
            for j in 0..cfg.p {
                m.set(i, j, x[i * cfg.p + j]);
            }
        }
        let mut ds = SurvivalDataset::new(m, time, event, "synthetic");
        ds.name = format!("synthetic_stream_n{}_p{}_rho{}", cfg.n, cfg.p, cfg.rho);
        ds.true_beta = Some(self.beta);
        ds
    }
}

/// The three Fig-2 / Table-1 configurations (SyntheticHighCorrHighDim1–3).
pub fn fig2_config(idx: usize, seed: u64) -> SyntheticConfig {
    let (n, p) = match idx {
        1 => (1200, 1200),
        2 => (900, 900),
        3 => (600, 600),
        _ => panic!("fig2 synthetic index must be 1..=3"),
    };
    SyntheticConfig { n, p, rho: 0.9, k: 15, s: 0.1, seed }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn true_beta_has_k_ones() {
        let b = true_beta(1200, 15);
        assert_eq!(b.iter().filter(|&&v| v == 1.0).count(), 15);
        let b = true_beta(10, 3);
        assert_eq!(b.iter().filter(|&&v| v == 1.0).count(), 3);
    }

    #[test]
    fn shapes_and_determinism() {
        let cfg = SyntheticConfig { n: 50, p: 20, k: 4, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.n(), 50);
        assert_eq!(a.p(), 20);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.time, b.time);
    }

    #[test]
    fn ar1_correlation_close_to_rho() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let rho = 0.9;
        let (mut s01, mut s00, mut s11) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let r = ar1_row(2, rho, &mut rng);
            s01 += r[0] * r[1];
            s00 += r[0] * r[0];
            s11 += r[1] * r[1];
        }
        let corr = s01 / (s00.sqrt() * s11.sqrt());
        assert!((corr - rho).abs() < 0.02, "corr={corr}");
    }

    #[test]
    fn times_positive_events_mixed() {
        let cfg = SyntheticConfig { n: 400, p: 30, k: 5, ..Default::default() };
        let d = generate(&cfg);
        assert!(d.time.iter().all(|&t| t > 0.0 && t.is_finite()));
        let ev = d.n_events();
        assert!(ev > 0 && ev < d.n(), "events={ev}");
    }

    #[test]
    fn stream_is_chunk_size_invariant_and_deterministic() {
        let cfg = SyntheticConfig { n: 137, p: 11, rho: 0.6, k: 3, s: 0.1, seed: 5 };
        // Two different chunkings must produce identical data.
        let mut a = SyntheticStream::new(&cfg);
        let (mut xa, mut ta, mut ea) = (Vec::new(), Vec::new(), Vec::new());
        while a.next_chunk(7, &mut xa, &mut ta, &mut ea) > 0 {}
        let mut b = SyntheticStream::new(&cfg);
        let (mut xb, mut tb, mut eb) = (Vec::new(), Vec::new(), Vec::new());
        while b.next_chunk(64, &mut xb, &mut tb, &mut eb) > 0 {}
        assert_eq!(xa.len(), 137 * 11);
        assert_eq!(xa, xb);
        assert_eq!(ta, tb);
        assert_eq!(ea, eb);
        // Materialize agrees with the raw chunks.
        let ds = SyntheticStream::new(&cfg).materialize();
        assert_eq!(ds.n(), 137);
        assert_eq!(ds.p(), 11);
        assert_eq!(ds.time, ta);
        for i in 0..5 {
            for j in 0..11 {
                assert_eq!(ds.x.get(i, j), xa[i * 11 + j]);
            }
        }
        assert!(ds.time.iter().all(|&t| t > 0.0 && t.is_finite()));
        let ev = ds.n_events();
        assert!(ev > 0 && ev < ds.n(), "events={ev}");
    }

    #[test]
    fn fig2_configs_match_table1() {
        assert_eq!(fig2_config(1, 0).n, 1200);
        assert_eq!(fig2_config(2, 0).n, 900);
        assert_eq!(fig2_config(3, 0).n, 600);
    }
}
