//! Streaming CSV reader for survival data (no external crates offline).
//!
//! Expected layout: a header row, a `time` column, an `event` column
//! (0/1 or true/false), and numeric feature columns. The reader goes
//! through any `BufRead` one line at a time, so the out-of-core store
//! converter can turn a CSV of any size into a `.fsds` store without
//! ever holding the file — let alone the parsed matrix — in memory.
//! [`load_survival_csv`] is the materializing convenience on top.
//!
//! Every parse error carries the 1-based physical line number of the
//! offending row.

use super::survival::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::linalg::Matrix;
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

/// Split one CSV line honoring double quotes. Public because the
/// serving subsystem's streaming CSV scorer reuses the exact same
/// cell-splitting rules as this loader.
pub fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    out.push(cur);
    out
}

fn parse_event(s: &str) -> std::result::Result<bool, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "yes" | "dead" | "event" => Ok(true),
        "0" | "false" | "no" | "censored" => Ok(false),
        other => other
            .parse::<f64>()
            .map(|v| v != 0.0)
            .map_err(|_| format!("unparseable event value {other:?}")),
    }
}

/// Which columns of the header play which role.
#[derive(Clone, Debug)]
pub struct CsvColumns {
    /// Header cells as written.
    pub header: Vec<String>,
    /// Index of the observation-time column.
    pub time_col: usize,
    /// Index of the event-indicator column.
    pub event_col: usize,
    /// Indices of the feature columns, in header order.
    pub feat_cols: Vec<usize>,
}

impl CsvColumns {
    /// Resolve roles from a header: column named `time`/`t` (or the
    /// first) is the observation time; `event`/`status`/`delta`/`censor`
    /// (or the second) is the indicator; everything else is a feature.
    fn resolve(header: Vec<String>) -> Result<CsvColumns> {
        let lower: Vec<String> = header.iter().map(|h| h.to_ascii_lowercase()).collect();
        let time_col = lower.iter().position(|h| h == "time" || h == "t").unwrap_or(0);
        let event_col = lower
            .iter()
            .position(|h| h == "event" || h == "status" || h == "delta" || h == "censor")
            .unwrap_or(1);
        if header.len() < 2 || time_col == event_col {
            return Err(FastSurvivalError::InvalidData(
                "CSV needs distinct time and event columns".into(),
            ));
        }
        let feat_cols: Vec<usize> =
            (0..header.len()).filter(|&i| i != time_col && i != event_col).collect();
        Ok(CsvColumns { header, time_col, event_col, feat_cols })
    }

    /// Feature names in feature order.
    pub fn feature_names(&self) -> Vec<String> {
        self.feat_cols.iter().map(|&c| self.header[c].clone()).collect()
    }
}

/// A streaming survival-CSV reader: header parsed up front, then one
/// data row per [`SurvivalCsvReader::next_row`] call, reusing the
/// caller's feature buffer. Blank lines are skipped; line numbers in
/// errors are 1-based physical lines of the underlying reader.
pub struct SurvivalCsvReader<R: BufRead> {
    reader: R,
    /// Resolved column roles (public: converters report schemas).
    pub columns: CsvColumns,
    line: String,
    lineno: usize,
}

/// Open `path` and parse the CSV header, with typed I/O errors naming
/// the path (a missing file is an error message, not a panic).
pub fn open_survival_csv(path: &Path) -> Result<SurvivalCsvReader<BufReader<File>>> {
    let file = File::open(path)
        .map_err(|e| FastSurvivalError::io(format!("opening {}", path.display()), e))?;
    SurvivalCsvReader::new(BufReader::new(file))
}

impl<R: BufRead> SurvivalCsvReader<R> {
    /// Parse the header (first non-blank line) and resolve column roles.
    pub fn new(reader: R) -> Result<Self> {
        let mut r = SurvivalCsvReader {
            reader,
            columns: CsvColumns {
                header: Vec::new(),
                time_col: 0,
                event_col: 1,
                feat_cols: Vec::new(),
            },
            line: String::new(),
            lineno: 0,
        };
        let header = match r.next_nonblank_line()? {
            Some(line) => split_csv_line(line).into_iter().map(|h| h.trim().to_string()).collect(),
            None => return Err(FastSurvivalError::InvalidData("empty CSV file".into())),
        };
        r.columns = CsvColumns::resolve(header)?;
        Ok(r)
    }

    /// Number of feature columns.
    pub fn p(&self) -> usize {
        self.columns.feat_cols.len()
    }

    /// Advance to the next non-blank line; `Ok(None)` at EOF. The
    /// returned slice borrows the internal line buffer.
    fn next_nonblank_line(&mut self) -> Result<Option<&str>> {
        loop {
            self.line.clear();
            let read = self
                .reader
                .read_line(&mut self.line)
                .map_err(|e| FastSurvivalError::io("reading CSV", e))?;
            if read == 0 {
                return Ok(None);
            }
            self.lineno += 1;
            if !self.line.trim().is_empty() {
                // Borrow through self.line (NLL: reborrow after the loop).
                break;
            }
        }
        Ok(Some(self.line.trim_end_matches(&['\n', '\r'][..])))
    }

    /// Parse the next data row: clears and fills `feats` (feature order)
    /// and returns `(time, event)`; `Ok(None)` at end of file. Every
    /// error message names the 1-based line number.
    pub fn next_row(&mut self, feats: &mut Vec<f64>) -> Result<Option<(f64, bool)>> {
        let lineno;
        let cells = {
            let line = match self.next_nonblank_line()? {
                Some(l) => l,
                None => return Ok(None),
            };
            let cells = split_csv_line(line);
            lineno = self.lineno;
            cells
        };
        let cols = &self.columns;
        if cells.len() != cols.header.len() {
            return Err(FastSurvivalError::InvalidData(format!(
                "line {lineno}: {} cells, expected {}",
                cells.len(),
                cols.header.len()
            )));
        }
        let time = cells[cols.time_col].trim().parse::<f64>().map_err(|_| {
            FastSurvivalError::InvalidData(format!(
                "line {lineno}: bad time value {:?}",
                cells[cols.time_col]
            ))
        })?;
        let event = parse_event(&cells[cols.event_col])
            .map_err(|m| FastSurvivalError::InvalidData(format!("line {lineno}: {m}")))?;
        feats.clear();
        for &c in &cols.feat_cols {
            feats.push(cells[c].trim().parse::<f64>().map_err(|_| {
                FastSurvivalError::InvalidData(format!(
                    "line {lineno}: bad feature {:?} value {:?}",
                    cols.header[c], cells[c]
                ))
            })?);
        }
        Ok(Some((time, event)))
    }
}

/// Load a survival CSV into memory by streaming it row by row (the file
/// itself is never held whole). Column roles as in [`CsvColumns`].
pub fn load_survival_csv(path: &Path, name: &str) -> Result<SurvivalDataset> {
    let mut reader = open_survival_csv(path)?;
    let feature_names = reader.columns.feature_names();
    let mut feats: Vec<Vec<f64>> = vec![Vec::new(); reader.p()];
    let mut time = Vec::new();
    let mut event = Vec::new();
    let mut row = Vec::with_capacity(reader.p());
    while let Some((t, e)) = reader.next_row(&mut row)? {
        time.push(t);
        event.push(e);
        for (col, &v) in feats.iter_mut().zip(row.iter()) {
            col.push(v);
        }
    }
    let x = Matrix::from_columns(&feats);
    let mut ds = SurvivalDataset::new(x, time, event, name);
    ds.feature_names = feature_names;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_temp(content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fs_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("t{}.csv", content.len()));
        std::fs::write(&path, content).unwrap();
        path
    }

    #[test]
    fn loads_basic_csv() {
        let p = write_temp("time,event,age,bp\n5.0,1,60,120\n3.0,0,50,110\n");
        let ds = load_survival_csv(&p, "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.p(), 2);
        assert_eq!(ds.time, vec![5.0, 3.0]);
        assert_eq!(ds.event, vec![true, false]);
        assert_eq!(ds.feature_names, vec!["age", "bp"]);
    }

    #[test]
    fn handles_quoted_cells() {
        let cells = split_csv_line("a,\"b,c\",\"d\"\"e\"");
        assert_eq!(cells, vec!["a", "b,c", "d\"e"]);
    }

    #[test]
    fn reorders_named_columns() {
        let p = write_temp("age,status,time\n60,1,5.0\n50,0,3.0\n");
        let ds = load_survival_csv(&p, "t").unwrap();
        assert_eq!(ds.time, vec![5.0, 3.0]);
        assert_eq!(ds.feature_names, vec!["age"]);
    }

    #[test]
    fn errors_carry_line_numbers() {
        // Ragged row on physical line 3 (line 1 header, line 2 fine).
        let p = write_temp("time,event,a\n1.0,1,2\n1.0,1\n");
        let err = load_survival_csv(&p, "t").unwrap_err();
        assert!(err.to_string().contains("line 3"), "got: {err}");
        // Bad event value on line 2.
        let p = write_temp("time,event,a\n1.0,maybe,2\n");
        let err = load_survival_csv(&p, "t").unwrap_err();
        assert!(err.to_string().contains("line 2"), "got: {err}");
        assert!(err.to_string().contains("maybe"), "got: {err}");
        // Bad time on line 4 with a blank line in between: physical
        // line numbers count blanks.
        let p = write_temp("time,event,a\n1.0,1,2\n\nbadtime,0,3\n");
        let err = load_survival_csv(&p, "t").unwrap_err();
        assert!(err.to_string().contains("line 4"), "got: {err}");
        // Bad feature value names the column.
        let p = write_temp("time,event,age\n1.0,1,young\n");
        let err = load_survival_csv(&p, "t").unwrap_err();
        assert!(err.to_string().contains("age") && err.to_string().contains("line 2"));
    }

    #[test]
    fn missing_file_is_a_typed_io_error() {
        let err = load_survival_csv(Path::new("/nonexistent/nope.csv"), "t").unwrap_err();
        assert!(matches!(err, FastSurvivalError::Io { .. }), "got: {err}");
        assert!(err.to_string().contains("nope.csv"));
    }

    #[test]
    fn streaming_reader_yields_rows_in_order() {
        let p = write_temp("time,event,a,b\n5.0,1,1,2\n\n3.0,0,3,4\n");
        let mut r = open_survival_csv(&p).unwrap();
        assert_eq!(r.p(), 2);
        assert_eq!(r.columns.feature_names(), vec!["a", "b"]);
        let mut row = Vec::new();
        assert_eq!(r.next_row(&mut row).unwrap(), Some((5.0, true)));
        assert_eq!(row, vec![1.0, 2.0]);
        assert_eq!(r.next_row(&mut row).unwrap(), Some((3.0, false)));
        assert_eq!(row, vec![3.0, 4.0]);
        assert_eq!(r.next_row(&mut row).unwrap(), None);
    }

    #[test]
    fn crlf_line_endings_parse() {
        let p = write_temp("time,event,a\r\n2.0,1,7\r\n");
        let ds = load_survival_csv(&p, "t").unwrap();
        assert_eq!(ds.time, vec![2.0]);
        assert_eq!(ds.x.get(0, 0), 7.0);
    }
}
