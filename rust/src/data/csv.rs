//! Minimal CSV reader for survival data (no external crates offline).
//!
//! Expected layout: a header row, a `time` column, an `event` column
//! (0/1 or true/false), and numeric feature columns. Used when a real
//! dataset CSV is dropped into `data/` to replace a stand-in.

use super::survival::SurvivalDataset;
use crate::linalg::Matrix;
use std::path::Path;

/// Split one CSV line honoring double quotes. Public because the
/// serving subsystem's streaming CSV scorer reuses the exact same
/// cell-splitting rules as this loader.
pub fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    out.push(cur);
    out
}

fn parse_event(s: &str) -> Result<bool, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "yes" | "dead" | "event" => Ok(true),
        "0" | "false" | "no" | "censored" => Ok(false),
        other => other
            .parse::<f64>()
            .map(|v| v != 0.0)
            .map_err(|_| format!("unparseable event value {other:?}")),
    }
}

/// Load a survival CSV. Column named `time` (or first column) is the
/// observation time; column named `event`/`status`/`delta` (or second)
/// is the indicator; everything else is a numeric feature.
pub fn load_survival_csv(path: &Path, name: &str) -> Result<SurvivalDataset, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header: Vec<String> = split_csv_line(lines.next().ok_or("empty file")?)
        .into_iter()
        .map(|h| h.trim().to_string())
        .collect();

    let lower: Vec<String> = header.iter().map(|h| h.to_ascii_lowercase()).collect();
    let time_col = lower.iter().position(|h| h == "time" || h == "t").unwrap_or(0);
    let event_col = lower
        .iter()
        .position(|h| h == "event" || h == "status" || h == "delta" || h == "censor")
        .unwrap_or(1);
    if time_col == event_col {
        return Err("time and event columns coincide".into());
    }

    let feat_cols: Vec<usize> =
        (0..header.len()).filter(|&i| i != time_col && i != event_col).collect();

    let mut time = Vec::new();
    let mut event = Vec::new();
    let mut feats: Vec<Vec<f64>> = vec![Vec::new(); feat_cols.len()];
    for (lineno, line) in lines.enumerate() {
        let cells = split_csv_line(line);
        if cells.len() != header.len() {
            return Err(format!(
                "row {} has {} cells, expected {}",
                lineno + 2,
                cells.len(),
                header.len()
            ));
        }
        time.push(
            cells[time_col]
                .trim()
                .parse::<f64>()
                .map_err(|_| format!("bad time at row {}", lineno + 2))?,
        );
        event.push(parse_event(&cells[event_col])?);
        for (k, &c) in feat_cols.iter().enumerate() {
            feats[k].push(
                cells[c]
                    .trim()
                    .parse::<f64>()
                    .map_err(|_| format!("bad feature {:?} at row {}", header[c], lineno + 2))?,
            );
        }
    }

    let x = Matrix::from_columns(&feats);
    let mut ds = SurvivalDataset::new(x, time, event, name);
    ds.feature_names = feat_cols.iter().map(|&c| header[c].clone()).collect();
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_temp(content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fs_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("t{}.csv", content.len()));
        std::fs::write(&path, content).unwrap();
        path
    }

    #[test]
    fn loads_basic_csv() {
        let p = write_temp("time,event,age,bp\n5.0,1,60,120\n3.0,0,50,110\n");
        let ds = load_survival_csv(&p, "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.p(), 2);
        assert_eq!(ds.time, vec![5.0, 3.0]);
        assert_eq!(ds.event, vec![true, false]);
        assert_eq!(ds.feature_names, vec!["age", "bp"]);
    }

    #[test]
    fn handles_quoted_cells() {
        let cells = split_csv_line("a,\"b,c\",\"d\"\"e\"");
        assert_eq!(cells, vec!["a", "b,c", "d\"e"]);
    }

    #[test]
    fn reorders_named_columns() {
        let p = write_temp("age,status,time\n60,1,5.0\n50,0,3.0\n");
        let ds = load_survival_csv(&p, "t").unwrap();
        assert_eq!(ds.time, vec![5.0, 3.0]);
        assert_eq!(ds.feature_names, vec!["age"]);
    }

    #[test]
    fn errors_on_ragged_rows() {
        let p = write_temp("time,event,a\n1.0,1\n");
        assert!(load_survival_csv(&p, "t").is_err());
    }

    #[test]
    fn errors_on_bad_event() {
        let p = write_temp("time,event,a\n1.0,maybe,2\n");
        assert!(load_survival_csv(&p, "t").is_err());
    }
}
