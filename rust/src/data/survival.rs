//! The time-to-event dataset container `{x_i, t_i, δ_i}` and split helpers.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// A survival dataset: features `x` (n×p), observation times `t`, and
/// event indicators `δ` (true = failure observed, false = censored).
#[derive(Clone, Debug)]
pub struct SurvivalDataset {
    pub x: Matrix,
    pub time: Vec<f64>,
    pub event: Vec<bool>,
    /// Human-readable feature names (len p).
    pub feature_names: Vec<String>,
    /// Ground-truth coefficients when known (synthetic data), for F1.
    pub true_beta: Option<Vec<f64>>,
    pub name: String,
}

impl SurvivalDataset {
    pub fn new(x: Matrix, time: Vec<f64>, event: Vec<bool>, name: &str) -> Self {
        assert_eq!(x.rows, time.len());
        assert_eq!(x.rows, event.len());
        let feature_names = (0..x.cols).map(|j| format!("f{j}")).collect();
        SurvivalDataset {
            x,
            time,
            event,
            feature_names,
            true_beta: None,
            name: name.to_string(),
        }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn p(&self) -> usize {
        self.x.cols
    }

    pub fn n_events(&self) -> usize {
        self.event.iter().filter(|&&e| e).count()
    }

    /// Fraction of censored samples.
    pub fn censoring_rate(&self) -> f64 {
        1.0 - self.n_events() as f64 / self.n() as f64
    }

    /// Subset by sample indices.
    pub fn subset(&self, idx: &[usize]) -> SurvivalDataset {
        SurvivalDataset {
            x: self.x.select_rows(idx),
            time: idx.iter().map(|&i| self.time[i]).collect(),
            event: idx.iter().map(|&i| self.event[i]).collect(),
            feature_names: self.feature_names.clone(),
            true_beta: self.true_beta.clone(),
            name: self.name.clone(),
        }
    }

    /// Keep only the given feature columns.
    pub fn select_features(&self, cols: &[usize]) -> SurvivalDataset {
        SurvivalDataset {
            x: self.x.select_columns(cols),
            time: self.time.clone(),
            event: self.event.clone(),
            feature_names: cols.iter().map(|&c| self.feature_names[c].clone()).collect(),
            true_beta: self
                .true_beta
                .as_ref()
                .map(|b| cols.iter().map(|&c| b[c]).collect()),
            name: self.name.clone(),
        }
    }

    /// Deterministic shuffled k-fold split: the same `(k, seed)` always
    /// yields the same assignment, independent of thread count, call
    /// order, or any other process state — the split is derived entirely
    /// from a fresh seeded [`Rng`] on the calling thread. Every CV driver
    /// routes through this.
    pub fn kfold_seeded(&self, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
        let mut rng = Rng::new(seed);
        self.kfold_indices(k, &mut rng)
    }

    /// Shuffled k-fold split: returns (train, test) index pairs.
    /// Delegates to the shared [`crate::data::split`] helper so CV and
    /// the online-learning validator agree on one split convention;
    /// the assignment is bitwise identical to what this method always
    /// produced.
    pub fn kfold_indices(&self, k: usize, rng: &mut Rng) -> Vec<(Vec<usize>, Vec<usize>)> {
        crate::data::split::kfold_indices(self.n(), k, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SurvivalDataset {
        let x = Matrix::from_rows(&[
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
            vec![0.0, 0.0],
        ]);
        SurvivalDataset::new(x, vec![4.0, 3.0, 2.0, 1.0], vec![true, false, true, true], "tiny")
    }

    #[test]
    fn basic_stats() {
        let d = tiny();
        assert_eq!(d.n(), 4);
        assert_eq!(d.p(), 2);
        assert_eq!(d.n_events(), 3);
        assert!((d.censoring_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn subset_consistent() {
        let d = tiny();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.time, vec![2.0, 4.0]);
        assert_eq!(s.event, vec![true, true]);
        assert_eq!(s.x.row(0), vec![1.0, 1.0]);
    }

    #[test]
    fn select_features_tracks_names() {
        let d = tiny();
        let s = d.select_features(&[1]);
        assert_eq!(s.p(), 1);
        assert_eq!(s.feature_names, vec!["f1"]);
    }

    #[test]
    fn kfold_partitions() {
        let d = tiny();
        let mut rng = Rng::new(1);
        let folds = d.kfold_indices(2, &mut rng);
        assert_eq!(folds.len(), 2);
        for (train, test) in &folds {
            let mut all: Vec<usize> = train.iter().chain(test.iter()).copied().collect();
            all.sort_unstable();
            assert_eq!(all, vec![0, 1, 2, 3]);
        }
    }
}
