//! Shared deterministic index splits.
//!
//! Every consumer of a random split in the crate — the CV drivers'
//! seeded k-folds and the online-learning watcher's holdout tail —
//! routes through this module. The determinism contract is the one CV
//! has always promised: the split is a pure function of `(n, k/frac,
//! seed)`, derived entirely from a fresh seeded [`Rng`] on the calling
//! thread, so it is independent of thread count, call order, and any
//! other process state. The watcher relies on this to validate a
//! candidate refit against the *same* holdout rows the previous publish
//! was validated on, even across process restarts.

use crate::util::rng::Rng;

/// A seeded permutation of `0..n` — the primitive every split here is
/// built from.
pub fn seeded_permutation(n: usize, seed: u64) -> Vec<usize> {
    Rng::new(seed).permutation(n)
}

/// Shuffled k-fold split over `0..n`: returns `(train, test)` index
/// pairs. Fold membership is round-robin over the permutation
/// (`folds[i % k]`), which keeps fold sizes within one of each other.
///
/// This is the exact assignment `SurvivalDataset::kfold_indices` has
/// always produced; that method now delegates here, so existing seeded
/// CV folds are bitwise unchanged.
pub fn kfold_indices(n: usize, k: usize, rng: &mut Rng) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && k <= n);
    let perm = rng.permutation(n);
    let mut folds: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &s) in perm.iter().enumerate() {
        folds[i % k].push(s);
    }
    (0..k)
        .map(|f| {
            let test = folds[f].clone();
            let train: Vec<usize> = (0..k)
                .filter(|&g| g != f)
                .flat_map(|g| folds[g].iter().copied())
                .collect();
            (train, test)
        })
        .collect()
}

/// Seeded k-fold split (fresh [`Rng`] from `seed`).
pub fn kfold_seeded(n: usize, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    let mut rng = Rng::new(seed);
    kfold_indices(n, k, &mut rng)
}

/// Deterministic holdout split: `(train, holdout)` index pairs where the
/// holdout set is the *tail* of the seeded permutation — `⌈frac·n⌉`
/// rows, at least 1 and at most n−1 so both sides stay non-empty.
///
/// Callers that need a stable holdout as the dataset grows should keep
/// `seed` fixed; rows keep their identity (indices into the caller's
/// ordering), so two datasets that share a prefix share most of the
/// holdout by construction of the Fisher–Yates permutation only when n
/// is unchanged — the watcher therefore always splits the *merged*
/// store and compares candidate vs incumbent on the identical index
/// set, never holdouts from two different n.
pub fn holdout_tail(n: usize, seed: u64, frac: f64) -> (Vec<usize>, Vec<usize>) {
    assert!(n >= 2, "holdout_tail needs at least 2 rows, got {n}");
    assert!(
        frac > 0.0 && frac < 1.0,
        "holdout fraction must be in (0, 1), got {frac}"
    );
    let h = ((frac * n as f64).ceil() as usize).clamp(1, n - 1);
    let perm = seeded_permutation(n, seed);
    let cut = n - h;
    (perm[..cut].to_vec(), perm[cut..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutation_deterministic_and_complete() {
        let a = seeded_permutation(100, 7);
        let b = seeded_permutation(100, 7);
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(a, seeded_permutation(100, 8));
    }

    #[test]
    fn kfold_partitions_and_is_seed_deterministic() {
        let folds = kfold_seeded(23, 4, 11);
        assert_eq!(folds.len(), 4);
        for (train, test) in &folds {
            let mut all: Vec<usize> = train.iter().chain(test.iter()).copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..23).collect::<Vec<_>>());
        }
        assert_eq!(folds, kfold_seeded(23, 4, 11));
        // Fold sizes within one of each other.
        for (_, test) in &folds {
            assert!(test.len() == 5 || test.len() == 6);
        }
    }

    #[test]
    fn holdout_tail_partitions_deterministically() {
        let (train, hold) = holdout_tail(200, 5, 0.1);
        assert_eq!(hold.len(), 20);
        assert_eq!(train.len(), 180);
        let mut all: Vec<usize> = train.iter().chain(hold.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<_>>());
        assert_eq!(holdout_tail(200, 5, 0.1), (train, hold));
        assert_ne!(holdout_tail(200, 6, 0.1).1, holdout_tail(200, 5, 0.1).1);
    }

    #[test]
    fn holdout_tail_clamps_to_nonempty_sides() {
        let (train, hold) = holdout_tail(2, 1, 0.01);
        assert_eq!(hold.len(), 1);
        assert_eq!(train.len(), 1);
        let (train, hold) = holdout_tail(5, 1, 0.99);
        assert_eq!(hold.len(), 4);
        assert_eq!(train.len(), 1);
    }

    #[test]
    #[should_panic(expected = "holdout fraction")]
    fn holdout_tail_rejects_bad_frac() {
        holdout_tail(10, 1, 1.5);
    }
}
