//! Quantile threshold binarization (Sec. 4.2 preprocessing).
//!
//! Each continuous feature is expanded into one-hot threshold indicators
//! `1{x <= q}` over up to `max_quantiles` distinct quantile cutpoints
//! (the paper uses 1000 quantiles). Adjacent thresholds of one source
//! column are nested and therefore *highly correlated* — exactly the
//! regime where the paper claims existing variable selectors fail.

use super::survival::SurvivalDataset;
use crate::linalg::Matrix;

/// Binarization settings.
#[derive(Clone, Debug)]
pub struct BinarizeConfig {
    /// Number of quantile cutpoints per continuous column (paper: 1000).
    pub max_quantiles: usize,
    /// Columns with at most this many distinct values are treated as
    /// categorical and one-hot encoded per value instead.
    pub categorical_max_distinct: usize,
}

impl Default for BinarizeConfig {
    fn default() -> Self {
        BinarizeConfig { max_quantiles: 1000, categorical_max_distinct: 8 }
    }
}

fn distinct_sorted(col: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = col.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.dedup();
    v
}

/// Threshold cutpoints: up to `q` distinct quantiles of the column
/// (excluding the maximum so no indicator is identically 1).
pub fn quantile_cutpoints(col: &[f64], q: usize) -> Vec<f64> {
    let distinct = distinct_sorted(col);
    if distinct.len() <= 1 {
        return Vec::new();
    }
    let candidates = &distinct[..distinct.len() - 1]; // drop max
    if candidates.len() <= q {
        return candidates.to_vec();
    }
    // Evenly spaced quantile picks over the distinct values.
    let mut cuts = Vec::with_capacity(q);
    for i in 0..q {
        let idx = (i as f64 + 0.5) / q as f64 * candidates.len() as f64;
        let idx = (idx as usize).min(candidates.len() - 1);
        cuts.push(candidates[idx]);
    }
    cuts.dedup();
    cuts
}

/// Expand every column into binary threshold features.
pub fn binarize(ds: &SurvivalDataset, cfg: &BinarizeConfig) -> SurvivalDataset {
    let n = ds.n();
    let mut columns: Vec<Vec<f64>> = Vec::new();
    let mut names: Vec<String> = Vec::new();

    for j in 0..ds.p() {
        let col = ds.x.col(j);
        let distinct = distinct_sorted(col);
        if distinct.len() <= 2 {
            // Already binary (or constant): keep as-is.
            columns.push(col.to_vec());
            names.push(ds.feature_names[j].clone());
            continue;
        }
        if distinct.len() <= cfg.categorical_max_distinct {
            // Categorical: one-hot per value, dropping one reference level.
            for v in distinct.iter().skip(1) {
                columns.push(col.iter().map(|&x| if x == *v { 1.0 } else { 0.0 }).collect());
                names.push(format!("{}=={}", ds.feature_names[j], v));
            }
            continue;
        }
        for cut in quantile_cutpoints(col, cfg.max_quantiles) {
            columns.push(col.iter().map(|&x| if x <= cut { 1.0 } else { 0.0 }).collect());
            names.push(format!("{}<={:.6}", ds.feature_names[j], cut));
        }
    }

    let x = if columns.is_empty() {
        Matrix::zeros(n, 0)
    } else {
        Matrix::from_columns(&columns)
    };
    let mut out = SurvivalDataset::new(x, ds.time.clone(), ds.event.clone(), &ds.name);
    out.feature_names = names;
    out.name = format!("{}_bin", ds.name);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn continuous_ds(n: usize, p: usize, seed: u64) -> SurvivalDataset {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f64>> = (0..p).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let time: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 5.0)).collect();
        let event: Vec<bool> = (0..n).map(|i| i % 3 != 0).collect();
        SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "cont")
    }

    #[test]
    fn binary_columns_pass_through() {
        let x = Matrix::from_columns(&[vec![0.0, 1.0, 0.0, 1.0]]);
        let ds = SurvivalDataset::new(x, vec![1.0, 2.0, 3.0, 4.0], vec![true; 4], "b");
        let out = binarize(&ds, &BinarizeConfig::default());
        assert_eq!(out.p(), 1);
        assert_eq!(out.x.col(0), ds.x.col(0));
    }

    #[test]
    fn continuous_expands_and_is_nested() {
        let ds = continuous_ds(200, 1, 3);
        let cfg = BinarizeConfig { max_quantiles: 10, ..Default::default() };
        let out = binarize(&ds, &cfg);
        assert!(out.p() >= 8 && out.p() <= 10, "p={}", out.p());
        // Nested: indicator columns for increasing cutpoints are ordered.
        for i in 0..out.n() {
            let mut prev = 0.0;
            for j in 0..out.p() {
                let v = out.x.get(i, j);
                assert!(v >= prev - 1e-12, "thresholds must be nested");
                prev = v;
            }
        }
    }

    #[test]
    fn all_columns_binary_after() {
        let ds = continuous_ds(100, 3, 9);
        let out = binarize(&ds, &BinarizeConfig { max_quantiles: 7, ..Default::default() });
        for j in 0..out.p() {
            assert!(out.x.col(j).iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn categorical_one_hot() {
        let x = Matrix::from_columns(&[vec![0.0, 1.0, 2.0, 1.0, 0.0, 2.0]]);
        let ds = SurvivalDataset::new(x, vec![1., 2., 3., 4., 5., 6.], vec![true; 6], "cat");
        let out = binarize(&ds, &BinarizeConfig::default());
        assert_eq!(out.p(), 2); // 3 levels, drop reference
    }

    #[test]
    fn constant_column_kept_single() {
        let x = Matrix::from_columns(&[vec![5.0; 4]]);
        let ds = SurvivalDataset::new(x, vec![1., 2., 3., 4.], vec![true; 4], "c");
        let out = binarize(&ds, &BinarizeConfig::default());
        assert_eq!(out.p(), 1);
    }
}
