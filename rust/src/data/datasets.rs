//! Stand-ins for the paper's four real datasets.
//!
//! The real CSVs (Flchain, Kickstarter1, Dialysis, EmployeeAttrition) are
//! not available in this offline image, so each loader first looks for
//! `data/<name>.csv` (columns: time, event, then features) and otherwise
//! generates a synthetic stand-in matching the published sample size,
//! raw feature count, and approximate censoring rate (Table 1), with a
//! sparse ground-truth log-hazard over a few latent columns so that the
//! sparsity/accuracy experiments exercise the same code paths.
//! See DESIGN.md "Substitutions".

use super::binarize::{binarize, BinarizeConfig};
use super::csv;
use super::survival::SurvivalDataset;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Spec of a real-dataset stand-in (Table 1 row).
#[derive(Clone, Debug)]
pub struct StandInSpec {
    pub name: &'static str,
    pub n: usize,
    /// Raw (pre-binarization) feature count from Table 1.
    pub p_raw: usize,
    /// How many raw features carry signal.
    pub k_signal: usize,
    /// Target censoring rate.
    pub censoring: f64,
    /// Fraction of raw columns that are categorical-ish.
    pub frac_categorical: f64,
}

/// Table 1 rows.
pub fn spec(name: &str) -> StandInSpec {
    match name {
        "flchain" => StandInSpec {
            name: "flchain",
            n: 7874,
            p_raw: 39,
            k_signal: 6,
            censoring: 0.72,
            frac_categorical: 0.5,
        },
        "kickstarter1" => StandInSpec {
            name: "kickstarter1",
            n: 4175,
            p_raw: 54,
            k_signal: 8,
            censoring: 0.32,
            frac_categorical: 0.4,
        },
        "dialysis" => StandInSpec {
            name: "dialysis",
            n: 6805,
            p_raw: 7,
            k_signal: 3,
            censoring: 0.76,
            frac_categorical: 0.4,
        },
        "employee_attrition" => StandInSpec {
            name: "employee_attrition",
            n: 14999,
            p_raw: 17,
            k_signal: 5,
            censoring: 0.76,
            frac_categorical: 0.5,
        },
        other => panic!("unknown dataset {other:?}"),
    }
}

/// All stand-in names (Table 1 real datasets).
pub const REAL_DATASETS: [&str; 4] =
    ["flchain", "kickstarter1", "dialysis", "employee_attrition"];

/// Generate (or load) the raw continuous/categorical dataset.
pub fn load_raw(name: &str, seed: u64) -> SurvivalDataset {
    let path = std::path::Path::new("data").join(format!("{name}.csv"));
    if path.exists() {
        return csv::load_survival_csv(&path, name)
            .unwrap_or_else(|e| panic!("failed to read {path:?}: {e}"));
    }
    generate_stand_in(&spec(name), seed)
}

/// Load raw then apply the Sec. 4.2 quantile binarization.
pub fn load_binarized(name: &str, seed: u64, max_quantiles: usize) -> SurvivalDataset {
    let raw = load_raw(name, seed);
    binarize(&raw, &BinarizeConfig { max_quantiles, ..Default::default() })
}

/// Build a stand-in: latent risk over a handful of columns, Weibull-ish
/// times, uniform censoring tuned to the target rate.
pub fn generate_stand_in(s: &StandInSpec, seed: u64) -> SurvivalDataset {
    let mut rng = Rng::new(seed ^ 0x5EED_u64.wrapping_mul(s.n as u64));
    let n = s.n;
    let p = s.p_raw;

    // Raw columns: mix of continuous (possibly skewed) and small-integer
    // categorical columns, with mild cross-correlation via a shared factor.
    let n_cat = ((p as f64) * s.frac_categorical) as usize;
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(p);
    let shared: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    for j in 0..p {
        if j < n_cat {
            let levels = 2 + rng.below(4); // 2..=5 levels
            cols.push(
                (0..n)
                    .map(|i| {
                        let z = 0.5 * shared[i] + rng.normal();
                        // Quantize a latent normal into levels.
                        let u = 0.5 * (1.0 + erf_approx(z / std::f64::consts::SQRT_2));
                        (u * levels as f64).floor().min(levels as f64 - 1.0)
                    })
                    .collect(),
            );
        } else {
            let skew = rng.bernoulli(0.3);
            cols.push(
                (0..n)
                    .map(|i| {
                        let z = 0.4 * shared[i] + rng.normal();
                        if skew {
                            z.exp() // log-normal-ish lab value
                        } else {
                            z
                        }
                    })
                    .collect(),
            );
        }
    }

    // Sparse signal over k columns with alternating signs.
    let mut beta = vec![0.0; p];
    let stride = (p / s.k_signal).max(1);
    let mut planted = 0;
    for j in 0..p {
        if (j + 1) % stride == 0 && planted < s.k_signal {
            beta[j] = if planted % 2 == 0 { 0.8 } else { -0.8 };
            planted += 1;
        }
    }

    // Standardize columns for η so scale-free; keep raw columns in X.
    let mut eta = vec![0.0; n];
    for (j, col) in cols.iter().enumerate() {
        if beta[j] == 0.0 {
            continue;
        }
        let mean = col.iter().sum::<f64>() / n as f64;
        let var = col.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let std = var.sqrt().max(1e-9);
        for i in 0..n {
            eta[i] += beta[j] * (col[i] - mean) / std;
        }
    }

    // Event times ~ exponential with rate exp(η); tune uniform censoring
    // horizon to hit the target censoring rate approximately.
    let death: Vec<f64> = eta.iter().map(|&e| rng.exponential() / e.exp()).collect();
    let mut sorted = death.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Censor horizon so that roughly `censoring` of samples get censored:
    // C ~ U(0, c_max) with c_max chosen via the empirical death quantile.
    let q_idx = (((1.0 - s.censoring) * n as f64) as usize).min(n - 1);
    let c_max = (2.0 * sorted[q_idx]).max(1e-9);
    let mut time = Vec::with_capacity(n);
    let mut event = Vec::with_capacity(n);
    for &d in &death {
        let c = rng.uniform_range(0.0, c_max);
        event.push(d <= c);
        time.push(d.min(c));
    }

    let mut ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, s.name);
    ds.true_beta = Some(beta);
    ds.feature_names = (0..p)
        .map(|j| if j < n_cat { format!("cat{j}") } else { format!("num{j}") })
        .collect();
    ds
}

/// Abramowitz–Stegun erf approximation (|err| < 1.5e-7).
fn erf_approx(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_table1_sizes() {
        assert_eq!(spec("flchain").n, 7874);
        assert_eq!(spec("kickstarter1").n, 4175);
        assert_eq!(spec("dialysis").n, 6805);
        assert_eq!(spec("employee_attrition").n, 14999);
    }

    #[test]
    fn stand_in_shapes_and_censoring() {
        let mut s = spec("dialysis");
        s.n = 2000; // keep test fast
        let d = generate_stand_in(&s, 1);
        assert_eq!(d.n(), 2000);
        assert_eq!(d.p(), 7);
        let cr = d.censoring_rate();
        assert!((cr - s.censoring).abs() < 0.15, "censoring={cr}");
    }

    #[test]
    fn stand_in_deterministic() {
        let mut s = spec("dialysis");
        s.n = 300;
        let a = generate_stand_in(&s, 5);
        let b = generate_stand_in(&s, 5);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.time, b.time);
    }

    #[test]
    fn signal_exists() {
        let mut s = spec("flchain");
        s.n = 500;
        let d = generate_stand_in(&s, 2);
        let beta = d.true_beta.as_ref().unwrap();
        assert_eq!(beta.iter().filter(|&&b| b != 0.0).count(), s.k_signal);
    }

    #[test]
    fn erf_sane() {
        assert!((erf_approx(0.0)).abs() < 1e-7);
        assert!((erf_approx(10.0) - 1.0).abs() < 1e-6);
        assert!((erf_approx(-10.0) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn binarized_stand_in_is_binary_and_wide() {
        let mut s = spec("dialysis");
        s.n = 400;
        let raw = generate_stand_in(&s, 3);
        let b = binarize(&raw, &BinarizeConfig { max_quantiles: 30, ..Default::default() });
        assert!(b.p() > raw.p());
        for j in 0..b.p() {
            assert!(b.x.col(j).iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }
}
