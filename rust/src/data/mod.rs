//! Datasets: the survival-data container, the paper's synthetic generator
//! (Appendix C.2), stand-ins for the four real datasets, the quantile
//! binarization preprocessor (Sec. 4.2), and a CSV loader.

pub mod binarize;
pub mod csv;
pub mod datasets;
pub mod split;
pub mod survival;
pub mod synthetic;

pub use survival::SurvivalDataset;
