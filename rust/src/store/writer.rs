//! Streaming `.fsds` writer: rows in, sorted columnar chunks out, never
//! more than O(n + chunk·p) in memory.
//!
//! Two passes:
//! 1. Drain the [`RowSource`] once, spilling raw rows to a temporary
//!    row-major file next to the output while collecting the O(n)
//!    columns (time, event) and one-pass standardization stats.
//! 2. Sort the collected times with the engine's canonical
//!    [`descending_time_order`], then gather rows from the spill file in
//!    sorted order, assembling one column-major chunk at a time.
//!
//! The spill file is the external-sort workspace: disk holds the n×p
//! payload twice transiently, RAM never holds it at all.

use super::format::{self, StoreHeader, DEFAULT_CHUNK_ROWS, HEADER_LEN};
use super::source::RunningStats;
use crate::cox::problem::descending_time_order;
use crate::util::compute::Precision;
use crate::data::csv::SurvivalCsvReader;
use crate::data::synthetic::{SyntheticConfig, SyntheticStream};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use std::fs::File;
use std::io::{BufRead, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// A forward-only stream of survival rows — the writer's input contract.
pub trait RowSource {
    /// Number of feature columns every row carries.
    fn n_features(&self) -> usize;
    /// Feature names, in row order.
    fn feature_names(&self) -> Vec<String>;
    /// Fill `feats` with the next row's features and return its
    /// `(time, event)`; `Ok(None)` at end of stream.
    fn next_row(&mut self, feats: &mut Vec<f64>) -> Result<Option<(f64, bool)>>;
}

/// Any streaming survival CSV is a row source.
impl<R: BufRead> RowSource for SurvivalCsvReader<R> {
    fn n_features(&self) -> usize {
        self.p()
    }

    fn feature_names(&self) -> Vec<String> {
        self.columns.feature_names()
    }

    fn next_row(&mut self, feats: &mut Vec<f64>) -> Result<Option<(f64, bool)>> {
        SurvivalCsvReader::next_row(self, feats)
    }
}

/// The Appendix-C.2 generator as a row source: datasets of any n stream
/// straight to disk without an O(n·p) allocation.
pub struct SyntheticRows {
    stream: SyntheticStream,
    p: usize,
    x: Vec<f64>,
    time: Vec<f64>,
    event: Vec<bool>,
    pos: usize,
}

/// Rows the synthetic source buffers per refill.
const SYNTH_BUF_ROWS: usize = 1024;

impl SyntheticRows {
    pub fn new(cfg: &SyntheticConfig) -> Self {
        SyntheticRows {
            stream: SyntheticStream::new(cfg),
            p: cfg.p,
            x: Vec::new(),
            time: Vec::new(),
            event: Vec::new(),
            pos: 0,
        }
    }
}

impl RowSource for SyntheticRows {
    fn n_features(&self) -> usize {
        self.p
    }

    fn feature_names(&self) -> Vec<String> {
        (0..self.p).map(|j| format!("f{j}")).collect()
    }

    fn next_row(&mut self, feats: &mut Vec<f64>) -> Result<Option<(f64, bool)>> {
        if self.pos == self.time.len() {
            self.x.clear();
            self.time.clear();
            self.event.clear();
            self.pos = 0;
            if self.stream.next_chunk(SYNTH_BUF_ROWS, &mut self.x, &mut self.time, &mut self.event)
                == 0
            {
                return Ok(None);
            }
        }
        let i = self.pos;
        feats.clear();
        feats.extend_from_slice(&self.x[i * self.p..(i + 1) * self.p]);
        self.pos += 1;
        Ok(Some((self.time[i], self.event[i])))
    }
}

/// An in-memory dataset as a row source (tests; small conversions).
pub struct DatasetRows<'a> {
    ds: &'a SurvivalDataset,
    i: usize,
}

impl<'a> DatasetRows<'a> {
    pub fn new(ds: &'a SurvivalDataset) -> Self {
        DatasetRows { ds, i: 0 }
    }
}

impl RowSource for DatasetRows<'_> {
    fn n_features(&self) -> usize {
        self.ds.p()
    }

    fn feature_names(&self) -> Vec<String> {
        self.ds.feature_names.clone()
    }

    fn next_row(&mut self, feats: &mut Vec<f64>) -> Result<Option<(f64, bool)>> {
        if self.i >= self.ds.n() {
            return Ok(None);
        }
        feats.clear();
        for j in 0..self.ds.p() {
            feats.push(self.ds.x.get(self.i, j));
        }
        let out = (self.ds.time[self.i], self.ds.event[self.i]);
        self.i += 1;
        Ok(Some(out))
    }
}

/// What a completed write looked like.
#[derive(Clone, Debug)]
pub struct StoreSummary {
    pub n: usize,
    pub p: usize,
    pub chunk_rows: usize,
    pub n_chunks: usize,
    pub n_events: usize,
    /// Final store size in bytes.
    pub bytes: u64,
}

/// Stream `source` into a sorted columnar store at `out`. `chunk_rows`
/// of 0 selects [`DEFAULT_CHUNK_ROWS`]. Writes format v1 (f64 cells) —
/// byte-identical to every prior release; use [`write_store_with`] for
/// mixed-precision (f32-cell) stores.
///
/// The store is assembled at `{out}.partial.tmp` and renamed into place
/// only on success, so an interrupted or failed conversion never leaves
/// a truncated file at the destination path — `out` either holds the
/// previous content or a complete store.
pub fn write_store(
    source: &mut dyn RowSource,
    out: &Path,
    chunk_rows: usize,
    name: &str,
) -> Result<StoreSummary> {
    write_store_with(source, out, chunk_rows, name, Precision::F64)
}

/// [`write_store`] with an explicit feature-cell precision:
/// [`Precision::F64`] writes format v1, [`Precision::F32Storage`]
/// writes format v2 (f32 cells, half the feature payload and half the
/// column-scan I/O; times, events, and meta stats stay f64).
pub fn write_store_with(
    source: &mut dyn RowSource,
    out: &Path,
    chunk_rows: usize,
    name: &str,
    precision: Precision,
) -> Result<StoreSummary> {
    let chunk_rows = if chunk_rows == 0 { DEFAULT_CHUNK_ROWS } else { chunk_rows };
    let spill_path = PathBuf::from(format!("{}.rows.tmp", out.display()));
    let partial_path = PathBuf::from(format!("{}.partial.tmp", out.display()));
    let result = write_store_inner(source, &partial_path, &spill_path, chunk_rows, name, precision);
    // The spill file is workspace either way; best-effort cleanup.
    let _ = std::fs::remove_file(&spill_path);
    match result {
        Ok(summary) => {
            std::fs::rename(&partial_path, out).map_err(|e| {
                FastSurvivalError::io(
                    format!("publishing {} -> {}", partial_path.display(), out.display()),
                    e,
                )
            })?;
            Ok(summary)
        }
        Err(e) => {
            let _ = std::fs::remove_file(&partial_path);
            Err(e)
        }
    }
}

/// Pass-1 output: the O(n) columns and one-pass stats collected while
/// the n×p payload was spilled row-major to disk. The sharded writer
/// reuses this so every shard shares one spill pass and one set of
/// global standardization stats.
pub(crate) struct SpilledRows {
    pub p: usize,
    pub feature_names: Vec<String>,
    pub time: Vec<f64>,
    pub event: Vec<bool>,
    pub means: Vec<f64>,
    pub stds: Vec<f64>,
}

/// Drain `source` once: spill raw rows (f64 LE, row-major) to
/// `spill_path`, validating every value, and collect the time/event
/// columns plus Welford standardization stats.
pub(crate) fn spill_rows(source: &mut dyn RowSource, spill_path: &Path) -> Result<SpilledRows> {
    let p = source.n_features();
    if p == 0 {
        return Err(FastSurvivalError::InvalidData(
            "row source has no feature columns".into(),
        ));
    }
    let feature_names = source.feature_names();
    let spill = File::create(spill_path)
        .map_err(|e| FastSurvivalError::io(format!("creating {}", spill_path.display()), e))?;
    let mut spill_w = BufWriter::new(spill);
    let mut time: Vec<f64> = Vec::new();
    let mut event: Vec<bool> = Vec::new();
    let mut stats = RunningStats::new(p);
    let mut feats: Vec<f64> = Vec::with_capacity(p);
    while let Some((t, e)) = source.next_row(&mut feats)? {
        let row_idx = time.len();
        if !t.is_finite() {
            return Err(FastSurvivalError::InvalidData(format!(
                "non-finite observation time {t} at data row {row_idx}"
            )));
        }
        if feats.len() != p {
            return Err(FastSurvivalError::InvalidData(format!(
                "data row {row_idx} has {} features, expected {p}",
                feats.len()
            )));
        }
        for (j, &v) in feats.iter().enumerate() {
            if !v.is_finite() {
                return Err(FastSurvivalError::InvalidData(format!(
                    "non-finite feature value (column {j}, data row {row_idx})"
                )));
            }
            spill_w
                .write_all(&v.to_le_bytes())
                .map_err(|e| FastSurvivalError::io("writing row spill", e))?;
        }
        stats.push_row(&feats);
        time.push(t);
        event.push(e);
    }
    spill_w.flush().map_err(|e| FastSurvivalError::io("flushing row spill", e))?;
    if time.is_empty() {
        return Err(FastSurvivalError::InvalidData("row source produced no rows".into()));
    }
    // One-pass standardization stats (shared Welford convention: see
    // `source::RunningStats`).
    let (means, stds) = stats.finish();
    Ok(SpilledRows { p, feature_names, time, event, means, stds })
}

/// Pass-2: assemble one complete store at `out` holding the spilled
/// rows `order[..]` in that order (a window of a full
/// `descending_time_order` for shard writes; the whole order for a
/// single store). Gathers rows from the spill file one column-major
/// chunk at a time and returns the header so callers can size and
/// checksum the result.
#[allow(clippy::too_many_arguments)]
pub(crate) fn write_sorted_store(
    spilled: &SpilledRows,
    spill_path: &Path,
    order: &[usize],
    out: &Path,
    chunk_rows: usize,
    name: &str,
    precision: Precision,
) -> Result<StoreHeader> {
    let (n, p) = (order.len(), spilled.p);
    let meta = format::encode_meta(name, &spilled.feature_names, &spilled.means, &spilled.stds);
    let header = StoreHeader {
        n,
        p,
        chunk_rows,
        payload_offset: (HEADER_LEN + meta.len()) as u64,
        precision,
    };
    let out_file = File::create(out)
        .map_err(|e| FastSurvivalError::io(format!("creating {}", out.display()), e))?;
    let mut w = BufWriter::new(out_file);
    let werr = |e| FastSurvivalError::io(format!("writing {}", out.display()), e);
    w.write_all(&header.encode()).map_err(werr)?;
    w.write_all(&meta).map_err(werr)?;
    for &i in order {
        w.write_all(&spilled.time[i].to_le_bytes()).map_err(werr)?;
    }
    for &i in order {
        w.write_all(&[spilled.event[i] as u8]).map_err(werr)?;
    }

    // Gather rows from the spill in sorted order, one chunk at a time.
    let mut spill_r = File::open(spill_path)
        .map_err(|e| FastSurvivalError::io(format!("reopening {}", spill_path.display()), e))?;
    let row_bytes = p * 8;
    let mut rowbuf = vec![0u8; row_bytes];
    let mut chunk: Vec<f64> = Vec::with_capacity(chunk_rows * p);
    for c in 0..header.n_chunks() {
        let r0 = c * chunk_rows;
        let rows = header.rows_in_chunk(c);
        chunk.clear();
        chunk.resize(rows * p, 0.0);
        // Visit source rows in ascending spill offset (the sorted order
        // is arbitrary relative to arrival order, so iterating by k
        // would seek randomly): a forward scan the OS can read ahead
        // of, with the scatter index k keeping the output byte-for-byte
        // identical to the naive gather.
        let mut gather: Vec<(usize, usize)> = (0..rows).map(|k| (order[r0 + k], k)).collect();
        gather.sort_unstable();
        for (src_row, k) in gather {
            spill_r
                .seek(SeekFrom::Start((src_row * row_bytes) as u64))
                .map_err(|e| FastSurvivalError::io("seeking row spill", e))?;
            spill_r
                .read_exact(&mut rowbuf)
                .map_err(|e| FastSurvivalError::io("reading row spill", e))?;
            for j in 0..p {
                let v = f64::from_le_bytes(rowbuf[j * 8..j * 8 + 8].try_into().unwrap());
                chunk[j * rows + k] = v;
            }
        }
        match precision {
            Precision::F64 => {
                for &v in &chunk {
                    w.write_all(&v.to_le_bytes()).map_err(werr)?;
                }
            }
            Precision::F32Storage => {
                for &v in &chunk {
                    w.write_all(&(v as f32).to_le_bytes()).map_err(werr)?;
                }
            }
        }
    }
    w.flush().map_err(werr)?;
    Ok(header)
}

fn write_store_inner(
    source: &mut dyn RowSource,
    out: &Path,
    spill_path: &Path,
    chunk_rows: usize,
    name: &str,
    precision: Precision,
) -> Result<StoreSummary> {
    // ---- Pass 1: spill raw rows, collect O(n) columns + stats.
    let spilled = spill_rows(source, spill_path)?;

    // ---- Sort: the engine's canonical descending-time order.
    let order = descending_time_order(&spilled.time);
    let n_events = spilled.event.iter().filter(|&&e| e).count();

    // ---- Pass 2: header + meta + sorted O(n) columns + gathered chunks.
    let header = write_sorted_store(&spilled, spill_path, &order, out, chunk_rows, name, precision)?;

    Ok(StoreSummary {
        n: spilled.time.len(),
        p: spilled.p,
        chunk_rows,
        n_chunks: header.n_chunks(),
        n_events,
        bytes: header.expected_file_len(),
    })
}

/// Convenience: stream a CSV file into a store (v1/f64 cells).
pub fn convert_csv(input: &Path, out: &Path, chunk_rows: usize, name: &str) -> Result<StoreSummary> {
    convert_csv_with(input, out, chunk_rows, name, Precision::F64)
}

/// [`convert_csv`] with an explicit feature-cell precision.
pub fn convert_csv_with(
    input: &Path,
    out: &Path,
    chunk_rows: usize,
    name: &str,
    precision: Precision,
) -> Result<StoreSummary> {
    let mut reader = crate::data::csv::open_survival_csv(input)?;
    write_store_with(&mut reader, out, chunk_rows, name, precision)
}

/// Convenience: stream the Appendix-C.2 generator into a store
/// (v1/f64 cells).
pub fn convert_synthetic(
    cfg: &SyntheticConfig,
    out: &Path,
    chunk_rows: usize,
) -> Result<StoreSummary> {
    convert_synthetic_with(cfg, out, chunk_rows, Precision::F64)
}

/// [`convert_synthetic`] with an explicit feature-cell precision.
pub fn convert_synthetic_with(
    cfg: &SyntheticConfig,
    out: &Path,
    chunk_rows: usize,
    precision: Precision,
) -> Result<StoreSummary> {
    let mut rows = SyntheticRows::new(cfg);
    let name = format!("synthetic_stream_n{}_p{}_rho{}", cfg.n, cfg.p, cfg.rho);
    write_store_with(&mut rows, out, chunk_rows, &name, precision)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    fn temp_store(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fs_store_writer_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{tag}.fsds"))
    }

    #[test]
    fn writes_and_sizes_a_small_store() {
        let ds = generate(&SyntheticConfig { n: 41, p: 3, rho: 0.2, k: 2, s: 0.1, seed: 9 });
        let out = temp_store("small");
        let mut rows = DatasetRows::new(&ds);
        let s = write_store(&mut rows, &out, 16, "small").unwrap();
        assert_eq!((s.n, s.p, s.chunk_rows, s.n_chunks), (41, 3, 16, 3));
        assert_eq!(s.n_events, ds.n_events());
        assert_eq!(std::fs::metadata(&out).unwrap().len(), s.bytes);
        // Spill workspace is gone.
        assert!(!PathBuf::from(format!("{}.rows.tmp", out.display())).exists());
    }

    #[test]
    fn f32_store_is_half_the_feature_payload_and_decodes_quantized() {
        use crate::store::dataset::ChunkedDataset;
        let ds = generate(&SyntheticConfig { n: 37, p: 4, rho: 0.3, k: 2, s: 0.1, seed: 13 });
        let out64 = temp_store("prec64");
        let out32 = temp_store("prec32");
        let mut rows = DatasetRows::new(&ds);
        let s64 = write_store_with(&mut rows, &out64, 16, "p", Precision::F64).unwrap();
        let mut rows = DatasetRows::new(&ds);
        let s32 = write_store_with(&mut rows, &out32, 16, "p", Precision::F32Storage).unwrap();
        // Identical geometry, feature payload shrunk by exactly 4·n·p.
        assert_eq!((s32.n, s32.p, s32.n_chunks), (s64.n, s64.p, s64.n_chunks));
        assert_eq!(s64.bytes - s32.bytes, 4 * 37 * 4);
        assert_eq!(std::fs::metadata(&out32).unwrap().len(), s32.bytes);
        // The v2 store opens and serves columns equal to the f32
        // round-trip of the v1 store's columns; times stay exact f64.
        let mut st64 = ChunkedDataset::open(&out64).unwrap();
        let mut st32 = ChunkedDataset::open(&out32).unwrap();
        assert_eq!(st64.meta().time, st32.meta().time);
        assert_eq!(st64.meta().event, st32.meta().event);
        let (mut c64, mut c32) = (Vec::new(), Vec::new());
        for l in 0..4 {
            st64.load_col(l, &mut c64).unwrap();
            st32.load_col(l, &mut c32).unwrap();
            let quant: Vec<f64> = c64.iter().map(|&v| v as f32 as f64).collect();
            assert_eq!(c32, quant, "column {l} must decode as the f32 round-trip");
        }
    }

    #[test]
    fn synthetic_source_streams_every_row() {
        let cfg = SyntheticConfig { n: 130, p: 5, rho: 0.4, k: 2, s: 0.1, seed: 4 };
        let mut src = SyntheticRows::new(&cfg);
        let mut feats = Vec::new();
        let mut count = 0;
        while src.next_row(&mut feats).unwrap().is_some() {
            assert_eq!(feats.len(), 5);
            count += 1;
        }
        assert_eq!(count, 130);
        // And the rows match the stream's own chunked output.
        let ds = crate::data::synthetic::SyntheticStream::new(&cfg).materialize();
        let mut src = SyntheticRows::new(&cfg);
        src.next_row(&mut feats).unwrap().unwrap();
        assert_eq!(feats, (0..5).map(|j| ds.x.get(0, j)).collect::<Vec<_>>());
    }

    #[test]
    fn empty_source_is_a_typed_error() {
        let ds = generate(&SyntheticConfig { n: 10, p: 2, rho: 0.2, k: 1, s: 0.1, seed: 1 });
        let mut rows = DatasetRows::new(&ds);
        // Drain it first.
        let mut feats = Vec::new();
        while rows.next_row(&mut feats).unwrap().is_some() {}
        let out = temp_store("empty");
        assert!(matches!(
            write_store(&mut rows, &out, 8, "empty"),
            Err(FastSurvivalError::InvalidData(_))
        ));
    }
}
