//! Out-of-core data subsystem: larger-than-RAM Cox training.
//!
//! The paper's surrogate methods make each training pass O(n·p); this
//! module removes the remaining constraint that the n×p design matrix
//! be resident in RAM. Three pieces:
//!
//! - [`format`]/[`writer`] — the `.fsds` binary columnar store: rows
//!   pre-sorted by descending observation time (the engine's canonical
//!   order, so risk sets are prefixes of the on-disk layout), features
//!   in fixed-width column-major chunks, O(n) time/event columns, and
//!   one-pass standardization stats. Writers stream from any
//!   [`writer::RowSource`] — a CSV of any size, the Appendix-C.2
//!   synthetic generator, or an in-memory dataset — through an
//!   external-sort spill file, never holding the matrix.
//! - [`dataset`] — [`ChunkedDataset`], the bounded-memory reader: O(n)
//!   risk-set metadata plus one streaming pass deriving the per-column
//!   constants (Xᵀδ, Lipschitz pairs) bit-identically to the in-memory
//!   kernels; after that, chunk and single-column reads on demand.
//! - [`streaming`] — [`StreamingFit`], the two-phase trainer:
//!   BigSurvSGD-style sampled-block surrogate warmup for fast early
//!   progress, then exact chunked quadratic/cubic-surrogate coordinate
//!   descent (monotone, globally convergent per the paper) streaming
//!   one column per step. Runs over [`CoxData`] — implemented by both
//!   the on-disk store and the in-memory [`MemoryCoxData`] reference,
//!   which share every floating-point operation, so chunked and
//!   in-memory fits agree bit for bit.
//!
//! - [`shard`]/[`shard_fit`] — sharded big-n training: a dataset split
//!   into time-contiguous shard stores under a versioned manifest
//!   ([`ShardManifest`], atomic publish like the live-model manifest),
//!   an assembled [`ShardedDataset`] view serving the exact global
//!   chunk geometry, and [`StreamingFit::fit_sharded`] — per-shard
//!   derivative passes merged through exclusive prefix carries into
//!   exact global risk-set quantities, bitwise identical to the
//!   single-store fit at any shard/worker count.
//!
//! Entry points: `CoxFit::fit_store` in the public API, `convert` /
//! `fit --store` / `bigfit` in the CLI.

pub mod dataset;
pub mod format;
pub mod shard;
pub mod shard_fit;
pub mod source;
pub mod streaming;
pub mod writer;

pub use dataset::ChunkedDataset;
pub use format::DEFAULT_CHUNK_ROWS;
pub use shard::{
    convert_csv_sharded, convert_synthetic_sharded, shard_manifest_path, write_sharded_store,
    ShardEntry, ShardManifest, ShardedDataset, ShardedSummary, SHARD_MANIFEST_VERSION,
};
pub use source::{CoxData, MemoryCoxData, StoreMeta};
pub use streaming::{reference_fit_kkt, StreamingFit, StreamingFitResult};
pub use writer::{
    convert_csv, convert_csv_with, convert_synthetic, convert_synthetic_with, write_store,
    write_store_with, DatasetRows, RowSource, StoreSummary, SyntheticRows,
};
