//! Out-of-core Cox training: BigSurvSGD-style sampled-block warmup, then
//! exact chunked surrogate coordinate descent over the full data.
//!
//! Phase 1 (*fast early progress*): sample time-contiguous row blocks
//! (the store's chunks — strata of comparable individuals, exactly the
//! blocks BigSurvSGD optimizes over), fit one surrogate CD sweep on each
//! block's partial likelihood from the current β, and blend the block
//! solution in with an annealed weight. Each step costs O(chunk·p) and
//! needs one chunk in memory.
//!
//! Phase 2 (*exact polish*): the paper's quadratic/cubic surrogate CD on
//! the full-data partial likelihood, one streamed column per coordinate
//! step. Every floating-point operation is shared with the in-memory
//! path — [`coord_d1_col`]/[`coord_d1_d2_col`] for derivatives,
//! [`CoxState::update_coord_col`] for the incremental η/w update,
//! [`loss_for_parts`] for the per-sweep stop check — so the fit is
//! monotone and globally convergent per the paper, and chunked vs
//! in-memory runs agree coefficient-for-coefficient, bit for bit.
//! Per-sweep I/O is exactly n·p·8 bytes of column reads; resident memory
//! stays O(n + chunk·p).

use super::source::{CoxData, StoreMeta};
use crate::cox::derivatives::{merge_tiles, MergeScratch, Workspace};
use crate::cox::lipschitz::all_lipschitz;
use crate::cox::loss::loss_for_parts_b;
use crate::cox::{CoxProblem, CoxState};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::linalg::Matrix;
use crate::optim::cd::SurrogateKind;
use crate::optim::objective::Stopper;
use crate::optim::{FitConfig, Objective, Trace};
use crate::util::compute::{Compute, ResolvedCompute};
use crate::util::rng::Rng;
use std::time::Instant;

/// Annealing constant for the warmup blend: block t moves β toward the
/// block solution with weight `BLEND / (BLEND + t)` — full trust in the
/// first block (one CD sweep from wherever β stands), then averaging
/// noise away as coverage accumulates.
const BLEND: f64 = 4.0;

/// Out-of-core fit configuration. Works over any [`CoxData`] source;
/// defaults mirror the in-memory `CoxFit` defaults where they overlap.
#[derive(Clone, Debug)]
pub struct StreamingFit {
    pub objective: Objective,
    /// Which surrogate supplies the exact-phase coordinate step.
    pub surrogate: SurrogateKind,
    /// Maximum exact-phase sweeps (each = one full pass over columns).
    pub max_sweeps: usize,
    /// Relative loss-decrease tolerance for the exact phase.
    pub tol: f64,
    /// Optional KKT-residual stopping for the exact phase (0 = off):
    /// stop once every coordinate's pre-step KKT residual is ≤ this.
    /// Residual stopping bounds the distance to the optimum directly
    /// (‖β−β*‖ ≤ √p·ε/μ for a μ-strongly-convex objective), which is
    /// what certifies ≤1e-8 parity against an independently-run
    /// in-memory fit — loss-change stopping cannot (the same lesson the
    /// warm-started path solver learned). The residual falls out of the
    /// derivative pass each step already makes, so tracking it is free.
    pub stop_kkt: f64,
    /// Wall-clock budget in seconds for the exact phase (0 = unlimited).
    pub budget_secs: f64,
    /// Warmup blocks to sample; `None` = one pass worth (`n_chunks`).
    /// Warmup is skipped entirely for single-chunk data (the exact phase
    /// already touches everything once per sweep).
    pub sgd_blocks: Option<usize>,
    /// Seed for the block sampler (fixed seed = fixed fit).
    pub seed: u64,
    /// Kernel backend / thread request, resolved once at fit start (the
    /// store's own header decides cell precision — the `precision`
    /// field here only affects in-memory sources built from it).
    pub compute: Compute,
}

impl Default for StreamingFit {
    fn default() -> Self {
        StreamingFit {
            objective: Objective::default(),
            surrogate: SurrogateKind::Cubic,
            max_sweeps: 200,
            tol: 1e-9,
            stop_kkt: 0.0,
            budget_secs: 0.0,
            sgd_blocks: None,
            seed: 0,
            compute: Compute::default(),
        }
    }
}

/// What a streamed fit produced.
#[derive(Clone, Debug)]
pub struct StreamingFitResult {
    pub beta: Vec<f64>,
    /// Linear predictor per sorted sample at the final β (what the
    /// Breslow baseline fit needs — computed anyway, never re-read from
    /// disk).
    pub eta: Vec<f64>,
    /// Final penalized objective.
    pub objective_value: f64,
    /// Exact-phase sweeps run.
    pub sweeps: usize,
    /// Warmup blocks consumed.
    pub sgd_steps: usize,
    /// Exact-phase loss trace (convergence/divergence/budget flags).
    pub trace: Trace,
}

impl StreamingFit {
    /// Run the two-phase fit over `data`.
    pub fn fit<S: CoxData>(&self, data: &mut S) -> Result<StreamingFitResult> {
        // An owned metadata handle (pointer clone, not a copy of the
        // O(n) vectors — the bigfit peak-RSS budget pays for every
        // resident byte): `data` stays mutably borrowable for the
        // chunk/column reads below.
        let meta = data.meta_arc();
        self.validate(&meta)?;
        let obj = self.objective;
        // Resolve the compute request exactly once — no optimizer loop
        // below ever re-reads the environment.
        let rc = self.compute.resolve()?;
        // One wall clock over both phases: `budget_secs` must bound the
        // whole fit, not just the exact polish (the warmup alone is
        // n_chunks CD sweeps — minutes at the tracked scale).
        let fit_start = Instant::now();

        // ---------------- Phase 1: sampled-block surrogate warmup.
        let (beta, sgd_steps) = self.sampled_block_warmup(data, &meta, rc, &fit_start)?;

        // ---------------- Phase 2: exact chunked surrogate CD.
        // The exact phase gets whatever the warmup left of the budget; a
        // fully-spent budget still runs one sweep before the stopper
        // fires and reports budget_exhausted — the same post-iteration
        // check the in-memory fit makes.
        let remaining = if self.budget_secs > 0.0 {
            (self.budget_secs - fit_start.elapsed().as_secs_f64()).max(1e-9)
        } else {
            0.0
        };
        let outcome = exact_chunked_cd(
            data,
            &meta,
            beta,
            self.surrogate,
            obj,
            self.max_sweeps,
            self.tol,
            self.stop_kkt,
            remaining,
            rc,
        )?;
        let mut state = outcome.state;
        let beta = std::mem::take(&mut state.beta);
        let eta = std::mem::take(&mut state.eta);
        Ok(StreamingFitResult {
            beta,
            eta,
            objective_value: outcome.objective_value,
            sweeps: outcome.sweeps,
            sgd_steps,
            trace: outcome.trace,
        })
    }

    /// Input/config validation shared by [`StreamingFit::fit`] and the
    /// sharded fit entry: bad data and bad configuration must surface as
    /// the same typed errors on every path.
    pub(crate) fn validate(&self, meta: &StoreMeta) -> Result<()> {
        if meta.p == 0 {
            return Err(FastSurvivalError::InvalidData(
                "store has no feature columns".into(),
            ));
        }
        if meta.n_events == 0 {
            return Err(FastSurvivalError::InvalidData(
                "all samples are censored: the Cox partial likelihood has no events to fit"
                    .into(),
            ));
        }
        if !self.objective.l1.is_finite()
            || self.objective.l1 < 0.0
            || !self.objective.l2.is_finite()
            || self.objective.l2 < 0.0
        {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "penalties must be finite and non-negative (got l1={}, l2={})",
                self.objective.l1, self.objective.l2
            )));
        }
        if self.max_sweeps == 0 {
            return Err(FastSurvivalError::InvalidConfig(
                "max_sweeps must be at least 1".into(),
            ));
        }
        Ok(())
    }

    /// Phase 1: BigSurvSGD-style sampled-block surrogate warmup, shared
    /// by the single-store and sharded fits. Because the sharded dataset
    /// serves the *global* chunk geometry, both paths sample identical
    /// blocks from an identical seed and return the identical β.
    pub(crate) fn sampled_block_warmup<S: CoxData>(
        &self,
        data: &mut S,
        meta: &StoreMeta,
        rc: ResolvedCompute,
        fit_start: &Instant,
    ) -> Result<(Vec<f64>, usize)> {
        let obj = self.objective;
        let p = meta.p;
        let over_budget = |start: &Instant| {
            self.budget_secs > 0.0 && start.elapsed().as_secs_f64() > self.budget_secs
        };
        let mut beta = vec![0.0_f64; p];
        let mut sgd_steps = 0usize;
        let blocks = self.sgd_blocks.unwrap_or(meta.n_chunks);
        if blocks > 0 && meta.n_chunks > 1 {
            let _span = crate::obs::SpanTimer::start(crate::obs::Phase::StreamWarmup);
            let mut rng = Rng::new(self.seed);
            let mut chunkbuf: Vec<f64> = Vec::new();
            for t in 0..blocks {
                if over_budget(fit_start) {
                    break;
                }
                let c = rng.below(meta.n_chunks);
                let rows = data.load_chunk(c, &mut chunkbuf)?;
                let r0 = c * meta.chunk_rows;
                let block_events =
                    meta.event[r0..r0 + rows].iter().filter(|&&e| e).count();
                if block_events == 0 {
                    continue;
                }
                // The chunk is a contiguous run of the globally sorted
                // order, so its rows are already descending in time and
                // the block problem's stable re-sort is the identity.
                let x = Matrix { rows, cols: p, data: chunkbuf[..rows * p].to_vec() };
                let block = SurvivalDataset::new(
                    x,
                    meta.time[r0..r0 + rows].to_vec(),
                    meta.event[r0..r0 + rows].to_vec(),
                    "block",
                );
                let bpr = CoxProblem::try_new(&block)?;
                // Scale penalties by the block's share of events so the
                // block objective estimates the full one.
                let frac = block_events as f64 / meta.n_events as f64;
                let bobj = Objective { l1: obj.l1 * frac, l2: obj.l2 * frac };
                let blip = all_lipschitz(&bpr);
                let mut bst = CoxState::from_beta(&bpr, &beta);
                let mut ws = Workspace::new();
                for l in 0..p {
                    self.surrogate.step_b(&bpr, &mut bst, &mut ws, l, blip[l], bobj, rc.backend);
                }
                let alpha = BLEND / (BLEND + t as f64);
                for (bj, sj) in beta.iter_mut().zip(bst.beta.iter()) {
                    *bj += alpha * (sj - *bj);
                }
                sgd_steps += 1;
            }
        }
        Ok((beta, sgd_steps))
    }
}

/// η = Xβ accumulated chunk by chunk, skipping zero coefficients —
/// shared by the single-store exact phase and the sharded engine (whose
/// dataset serves the same global chunk geometry, so both rebuild the
/// identical η bit for bit).
pub(crate) fn rebuild_eta<S: CoxData>(
    data: &mut S,
    meta: &StoreMeta,
    beta: &[f64],
) -> Result<Vec<f64>> {
    let mut eta = vec![0.0_f64; meta.n];
    let mut chunkbuf: Vec<f64> = Vec::new();
    for c in 0..meta.n_chunks {
        let rows = data.load_chunk(c, &mut chunkbuf)?;
        let r0 = c * meta.chunk_rows;
        for (j, &bj) in beta.iter().enumerate() {
            if bj == 0.0 {
                continue;
            }
            let col = &chunkbuf[j * rows..(j + 1) * rows];
            for (k, &x) in col.iter().enumerate() {
                eta[r0 + k] += x * bj;
            }
        }
    }
    Ok(eta)
}

/// What the exact chunked-CD phase left behind.
pub(crate) struct ExactPhaseOutcome {
    pub state: CoxState,
    pub objective_value: f64,
    pub sweeps: usize,
    pub trace: Trace,
}

/// The exact chunked surrogate-CD phase, shared between
/// [`StreamingFit::fit`] (entered from the warmup's β) and the online
/// incremental refit driver (entered from a previously-published
/// model's β): rebuild η = Xβ chunk by chunk, then sweep columns with
/// the engine's parts-level residual step until loss tolerance, KKT
/// residual, or the wall-clock budget stops it. Keeping one body means
/// a warm refit and a cold streamed fit run the identical
/// floating-point sequence per sweep — the ≤1e-8 parity certificate
/// compares two runs of *this* code differing only in their starting β.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exact_chunked_cd<S: CoxData>(
    data: &mut S,
    meta: &StoreMeta,
    beta: Vec<f64>,
    surrogate: SurrogateKind,
    obj: Objective,
    max_sweeps: usize,
    tol: f64,
    stop_kkt: f64,
    budget_secs: f64,
    compute: ResolvedCompute,
) -> Result<ExactPhaseOutcome> {
    let p = meta.p;
    let eta = rebuild_eta(data, meta, &beta)?;
    let mut state = CoxState::from_eta(beta, eta);
    // The canonical merge-tile decomposition: data-derived only, shared
    // with the sharded engine so single-store and sharded fits replay
    // the identical per-tile floating-point sequence.
    let tile_cuts = merge_tiles(&meta.groups);
    let mut scratch = MergeScratch::default();
    let config = FitConfig {
        objective: obj,
        max_iters: max_sweeps,
        tol,
        budget_secs,
        record_trace: true,
        compute,
    };
    let mut stopper = Stopper::new();
    let mut sweeps = 0usize;
    let mut colbuf: Vec<f64> = Vec::new();
    for it in 0..max_sweeps {
        let _span = crate::obs::SpanTimer::start(crate::obs::Phase::StreamExactSweep);
        // Largest pre-step KKT residual seen this sweep, reported by
        // the engine's merged parts-level step
        // ([`SurrogateKind::step_residual_col_merged_b`] — one source
        // of truth with the sharded engine's distributed step, STEP_SNAP
        // no-op snapping included).
        let mut max_res = 0.0_f64;
        for l in 0..p {
            data.load_col(l, &mut colbuf)?;
            let (_delta, residual) = surrogate.step_residual_col_merged_b(
                &meta.groups,
                &tile_cuts,
                &mut scratch,
                meta.xt_delta[l],
                &mut state,
                &colbuf,
                meta.col_binary[l],
                l,
                meta.lipschitz[l],
                obj,
                0.0,
                compute.backend,
            );
            if residual > max_res {
                max_res = residual;
            }
        }
        sweeps = it + 1;
        let loss = loss_for_parts_b(
            compute.backend,
            &meta.groups,
            &meta.delta,
            &state.eta,
            &state.w,
            state.shift,
        ) + obj.penalty(&state.beta);
        let stop_loss = stopper.step_with(it, loss, Some(max_res), &config);
        let stopped_kkt = stop_kkt > 0.0 && max_res <= stop_kkt;
        if stopped_kkt {
            stopper.trace.converged = true;
        }
        if stop_loss || stopped_kkt {
            break;
        }
    }
    let objective_value = loss_for_parts_b(
        compute.backend,
        &meta.groups,
        &meta.delta,
        &state.eta,
        &state.w,
        state.shift,
    ) + obj.penalty(&state.beta);
    Ok(ExactPhaseOutcome { state, objective_value, sweeps, trace: stopper.trace })
}

/// Classic in-memory surrogate CD driven to a KKT residual — the
/// reference the parity gates compare streamed fits against. Runs the
/// engine's own [`SurrogateKind::step_residual`] hot path (workspace
/// caching and all) from β = 0 until every coordinate's residual is
/// ≤ `stop_kkt` or `max_sweeps` run out; returns β. With a μ-strongly-
/// convex objective (μ ≥ 2λ₂), both this reference and a residual-
/// stopped [`StreamingFit`] land within √p·ε/μ of the unique optimum,
/// which is what certifies their ≤1e-8 agreement.
pub fn reference_fit_kkt(
    problem: &CoxProblem,
    obj: Objective,
    surrogate: SurrogateKind,
    stop_kkt: f64,
    max_sweeps: usize,
) -> Vec<f64> {
    let lip = all_lipschitz(problem);
    let mut st = CoxState::zeros(problem);
    let mut ws = Workspace::new();
    for _ in 0..max_sweeps {
        let mut max_res = 0.0_f64;
        for l in 0..problem.p() {
            let (_, r) = surrogate.step_residual(problem, &mut st, &mut ws, l, lip[l], obj, 0.0);
            if r > max_res {
                max_res = r;
            }
        }
        if max_res <= stop_kkt {
            break;
        }
    }
    st.beta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::store::source::MemoryCoxData;

    fn ds(n: usize, p: usize, seed: u64) -> SurvivalDataset {
        generate(&SyntheticConfig { n, p, rho: 0.4, k: 3, s: 0.1, seed })
    }

    #[test]
    fn chunked_fit_matches_classic_in_memory_fit() {
        let ds = ds(300, 8, 21);
        let obj = Objective { l1: 0.0, l2: 1.0 };
        let mut mem = MemoryCoxData::from_dataset(&ds, 64).unwrap();
        let fit = StreamingFit {
            objective: obj,
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 10_000,
            tol: 0.0,
            stop_kkt: 1e-9,
            ..Default::default()
        };
        let res = fit.fit(&mut mem).unwrap();
        assert!(res.sgd_steps > 0, "multi-chunk data must warm up");
        assert!(res.trace.converged, "KKT-stopped fit should converge");
        assert!(res.trace.monotone(1e-10), "exact phase must be monotone");

        // The engine's own in-memory CD, driven to the same KKT
        // residual, lands on the same strictly convex optimum: both are
        // within √p·ε/μ ≈ 1.4e-9 of it, so they agree to ≤1e-8.
        let pr = CoxProblem::new(&ds);
        let classic = reference_fit_kkt(&pr, obj, SurrogateKind::Quadratic, 1e-9, 10_000);
        for (a, b) in res.beta.iter().zip(classic.iter()) {
            assert!((a - b).abs() <= 1e-8, "chunked {a} vs classic {b}");
        }
        // η is the sorted-order linear predictor of the final β.
        let expect_eta = pr.x.matvec(&res.beta);
        for (a, b) in res.eta.iter().zip(expect_eta.iter()) {
            assert!((a - b).abs() <= 1e-9);
        }
    }

    #[test]
    fn cubic_surrogate_reaches_the_same_optimum() {
        let ds = ds(200, 6, 31);
        let mut mem = MemoryCoxData::from_dataset(&ds, 50).unwrap();
        let quad = StreamingFit {
            objective: Objective { l1: 0.0, l2: 1.0 },
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 3000,
            tol: 1e-13,
            ..Default::default()
        }
        .fit(&mut mem)
        .unwrap();
        let cubic = StreamingFit {
            objective: Objective { l1: 0.0, l2: 1.0 },
            surrogate: SurrogateKind::Cubic,
            max_sweeps: 3000,
            tol: 1e-13,
            ..Default::default()
        }
        .fit(&mut mem)
        .unwrap();
        assert!((quad.objective_value - cubic.objective_value).abs() < 1e-6);
    }

    #[test]
    fn l1_streamed_fit_is_sparse() {
        let ds = ds(250, 10, 41);
        let mut mem = MemoryCoxData::from_dataset(&ds, 64).unwrap();
        let strong = StreamingFit {
            objective: Objective { l1: 40.0, l2: 0.0 },
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 300,
            ..Default::default()
        }
        .fit(&mut mem)
        .unwrap();
        let weak = StreamingFit {
            objective: Objective { l1: 0.01, l2: 0.0 },
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 300,
            ..Default::default()
        }
        .fit(&mut mem)
        .unwrap();
        let nnz = |b: &[f64]| b.iter().filter(|v| v.abs() > 1e-10).count();
        assert!(
            nnz(&strong.beta) < nnz(&weak.beta),
            "strong λ1 must be sparser: {} vs {}",
            nnz(&strong.beta),
            nnz(&weak.beta)
        );
    }

    #[test]
    fn all_censored_and_zero_sweeps_are_typed_errors() {
        use crate::linalg::Matrix;
        let x = Matrix::from_columns(&[vec![1.0, -1.0, 0.5]]);
        let d = SurvivalDataset::new(x, vec![3.0, 2.0, 1.0], vec![false; 3], "censored");
        let mut mem = MemoryCoxData::from_dataset(&d, 2).unwrap();
        assert!(matches!(
            StreamingFit::default().fit(&mut mem),
            Err(FastSurvivalError::InvalidData(_))
        ));
        let ds = ds(50, 3, 1);
        let mut mem = MemoryCoxData::from_dataset(&ds, 16).unwrap();
        let bad = StreamingFit { max_sweeps: 0, ..Default::default() };
        assert!(matches!(
            bad.fit(&mut mem),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        let bad = StreamingFit {
            objective: Objective { l1: -1.0, l2: 0.0 },
            ..Default::default()
        };
        assert!(matches!(
            bad.fit(&mut mem),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
    }
}
