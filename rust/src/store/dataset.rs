//! Bounded-memory reader over a `.fsds` store.
//!
//! [`ChunkedDataset::open`] reads the header and the O(n) payload
//! columns (time, event), rebuilds the risk-set structure with the same
//! [`build_tie_groups`] the in-memory [`crate::cox::CoxProblem`] uses,
//! then makes a
//! single streaming pass over the feature chunks to derive the O(p)
//! per-column constants (Xᵀδ, Theorem-3.4 Lipschitz pairs, binary
//! flags) — accumulating per column in ascending row order, i.e. the
//! exact floating-point sequence the in-memory kernels produce. After
//! `open`, memory holds O(n + p) bookkeeping plus one reusable I/O
//! buffer; the n×p matrix stays on disk.

use super::format::{self, StoreHeader, HEADER_LEN};
use super::source::{CoxData, StoreMeta};
use crate::cox::lipschitz::LipschitzPair;
use crate::cox::problem::{build_tie_groups, TieGroup};
use crate::data::SurvivalDataset;
use crate::error::{FastSurvivalError, Result};
use crate::linalg::Matrix;
use crate::util::compute::Precision;
use std::fs::File;
use std::io::{BufReader, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Guard for [`ChunkedDataset::to_dataset`]: materializing is meant for
/// tests and spot checks, not for the workloads the store exists for.
const MATERIALIZE_CAP: u64 = 1 << 28; // 256M doubles = 2 GiB

/// An open `.fsds` store: O(n) metadata in memory, features on disk.
/// Metadata is held behind an [`Arc`] so the fit driver can keep a
/// handle across its mutable chunk/column reads without copying the
/// O(n) vectors.
pub struct ChunkedDataset {
    file: File,
    path: PathBuf,
    header: StoreHeader,
    meta: Arc<StoreMeta>,
    /// Reusable byte buffer for chunk/column reads.
    bytebuf: Vec<u8>,
}

impl ChunkedDataset {
    /// Open and validate a store. Header corruption, truncation, and
    /// unsorted payloads all surface as typed
    /// [`FastSurvivalError::Store`] errors; a missing file is a typed
    /// I/O error naming the path.
    pub fn open(path: &Path) -> Result<Self> {
        let mut file = File::open(path)
            .map_err(|e| FastSurvivalError::io(format!("opening {}", path.display()), e))?;
        let file_len = file
            .metadata()
            .map_err(|e| FastSurvivalError::io(format!("stat {}", path.display()), e))?
            .len();
        let mut head = [0u8; HEADER_LEN];
        format::read_exact(&mut file, &mut head, "header")?;
        let header = StoreHeader::decode(&head)?;
        if file_len != header.expected_file_len() {
            return Err(FastSurvivalError::Store(format!(
                "{} is {} bytes but the header implies {} — truncated or corrupt",
                path.display(),
                file_len,
                header.expected_file_len()
            )));
        }
        let (n, p) = (header.n, header.p);

        // Meta block, then the O(n) payload columns, read buffered.
        let mut r = BufReader::new(&mut file);
        let name = format::read_string(&mut r, "dataset name")?;
        let n_names = format::read_u32(&mut r, "feature-name count")? as usize;
        if n_names != p {
            return Err(FastSurvivalError::Store(format!(
                "meta block names {n_names} features, header says {p}"
            )));
        }
        let mut feature_names = Vec::with_capacity(p);
        for _ in 0..p {
            feature_names.push(format::read_string(&mut r, "feature name")?);
        }
        let means = format::read_f64_vec(&mut r, p, "standardization means")?;
        let stds = format::read_f64_vec(&mut r, p, "standardization stds")?;
        // The payload is read sequentially from here, so the meta block
        // must end exactly where the header says the payload starts — a
        // corrupt length field would silently misalign every read below.
        let consumed = HEADER_LEN as u64
            + 8
            + name.len() as u64
            + feature_names.iter().map(|f| 4 + f.len() as u64).sum::<u64>()
            + 16 * p as u64;
        if consumed != header.payload_offset {
            return Err(FastSurvivalError::Store(format!(
                "meta block ends at {consumed} but payload starts at {} — corrupt meta",
                header.payload_offset
            )));
        }

        let time = format::read_f64_vec(&mut r, n, "time column")?;
        for (k, &t) in time.iter().enumerate() {
            if !t.is_finite() {
                return Err(FastSurvivalError::Store(format!(
                    "non-finite time {t} at sorted row {k}"
                )));
            }
            if k > 0 && t > time[k - 1] {
                return Err(FastSurvivalError::Store(format!(
                    "times not sorted descending at row {k} ({} then {t})",
                    time[k - 1]
                )));
            }
        }
        let mut event_bytes = vec![0u8; n];
        format::read_exact(&mut r, &mut event_bytes, "event column")?;
        drop(r);
        let mut event = Vec::with_capacity(n);
        for (k, &b) in event_bytes.iter().enumerate() {
            match b {
                0 => event.push(false),
                1 => event.push(true),
                other => {
                    return Err(FastSurvivalError::Store(format!(
                        "invalid event byte {other} at sorted row {k}"
                    )))
                }
            }
        }
        let delta: Vec<f64> = event.iter().map(|&e| if e { 1.0 } else { 0.0 }).collect();
        // The per-row group_of map is discarded: the chunked kernels
        // only walk `groups`, and O(n) indices would sit against the
        // peak-RSS budget unused.
        let (groups, _group_of) = build_tie_groups(&time, &delta);
        let n_events = event.iter().filter(|&&e| e).count();

        // Streaming stats pass over the feature chunks, before the meta
        // is frozen behind its Arc.
        let mut bytebuf = Vec::new();
        let (xt_delta, lipschitz, col_binary) =
            derive_column_stats(&mut file, &mut bytebuf, &header, &delta, &groups)?;

        let meta = StoreMeta {
            n,
            p,
            chunk_rows: header.chunk_rows,
            n_chunks: header.n_chunks(),
            name,
            feature_names,
            means,
            stds,
            time,
            delta,
            event,
            groups,
            n_events,
            xt_delta,
            lipschitz,
            col_binary,
        };
        Ok(ChunkedDataset {
            file,
            path: path.to_path_buf(),
            header,
            meta: Arc::new(meta),
            bytebuf,
        })
    }

    /// The path this store was opened from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The decoded fixed header (geometry + payload location).
    pub fn header(&self) -> &StoreHeader {
        &self.header
    }

    /// Decompose into the raw read handle + geometry + metadata. The
    /// live merged reader wraps several validated stores and drives its
    /// own per-source range reads over their chunk geometry.
    pub(crate) fn into_parts(self) -> (File, StoreHeader, Arc<StoreMeta>) {
        (self.file, self.header, self.meta)
    }

    /// Materialize the whole store as an in-memory [`SurvivalDataset`]
    /// in sorted (descending-time) order — tests and spot checks only;
    /// refuses stores past a size cap.
    pub fn to_dataset(&mut self) -> Result<SurvivalDataset> {
        if self.meta.n as u64 * self.meta.p as u64 > MATERIALIZE_CAP {
            return Err(FastSurvivalError::InvalidConfig(format!(
                "refusing to materialize {}×{} store into RAM (use the chunked fit path)",
                self.meta.n, self.meta.p
            )));
        }
        let mut cols: Vec<Vec<f64>> = Vec::with_capacity(self.meta.p);
        let mut col = Vec::new();
        for l in 0..self.meta.p {
            self.load_col(l, &mut col)?;
            cols.push(col.clone());
        }
        let x = Matrix::from_columns(&cols);
        let mut ds =
            SurvivalDataset::new(x, self.meta.time.clone(), self.meta.event.clone(), "store");
        ds.name = self.meta.name.clone();
        ds.feature_names = self.meta.feature_names.clone();
        Ok(ds)
    }
}

impl CoxData for ChunkedDataset {
    fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    fn meta_arc(&self) -> Arc<StoreMeta> {
        Arc::clone(&self.meta)
    }

    fn load_chunk(&mut self, c: usize, buf: &mut Vec<f64>) -> Result<usize> {
        let rows = self.header.rows_in_chunk(c);
        let cells = rows * self.header.p;
        buf.clear();
        read_cells_append(
            &mut self.file,
            &mut self.bytebuf,
            self.header.col_segment_offset(c, 0),
            cells,
            self.header.precision,
            buf,
        )?;
        Ok(rows)
    }

    fn load_col(&mut self, l: usize, buf: &mut Vec<f64>) -> Result<()> {
        // The per-coordinate hot path of the streamed fit: decode each
        // chunk's column segment straight into the caller's buffer — no
        // intermediate vector, no second copy.
        buf.clear();
        buf.reserve(self.header.n);
        for c in 0..self.header.n_chunks() {
            let rows = self.header.rows_in_chunk(c);
            read_cells_append(
                &mut self.file,
                &mut self.bytebuf,
                self.header.col_segment_offset(c, l),
                rows,
                self.header.precision,
                buf,
            )?;
        }
        Ok(())
    }
}

/// Seek + read `count` feature cells at `offset`, decoding them onto
/// the end of `out` (the byte buffer is caller-owned and reused across
/// reads). v1 cells are f64; v2 cells are f32, widened to f64 here so
/// every downstream kernel accumulates in full precision. Shared with
/// the live merged reader, which does per-source range reads over the
/// same chunk geometry.
pub(crate) fn read_cells_append(
    file: &mut File,
    bytebuf: &mut Vec<u8>,
    offset: u64,
    count: usize,
    precision: Precision,
    out: &mut Vec<f64>,
) -> Result<()> {
    let cell = match precision {
        Precision::F64 => 8,
        Precision::F32Storage => 4,
    };
    bytebuf.clear();
    bytebuf.resize(count * cell, 0);
    file.seek(SeekFrom::Start(offset))
        .map_err(|e| FastSurvivalError::io("seeking store", e))?;
    file.read_exact(bytebuf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FastSurvivalError::Store("truncated store while reading feature data".into())
        } else {
            FastSurvivalError::io("reading store feature data", e)
        }
    })?;
    out.reserve(count);
    match precision {
        Precision::F64 => {
            for chunk in bytebuf.chunks_exact(8) {
                out.push(f64::from_le_bytes(chunk.try_into().unwrap()));
            }
        }
        Precision::F32Storage => {
            for chunk in bytebuf.chunks_exact(4) {
                out.push(f32::from_le_bytes(chunk.try_into().unwrap()) as f64);
            }
        }
    }
    Ok(())
}

/// The streaming per-column constants pass with externalized carry
/// state: Xᵀδ, Theorem-3.4 Lipschitz pairs, and binary flags
/// accumulate per column in ascending **global** row order, across any
/// sequence of column-major chunk buffers. [`derive_column_stats`]
/// drives it over one store's chunks; the sharded dataset drives the
/// identical pass over every shard's chunks in shard order — the
/// per-row floating-point sequence is the same either way, so the
/// derived constants are bit-identical to the in-memory
/// `tr_matvec` / `coord_lipschitz` passes regardless of how the rows
/// are split into files.
pub(crate) struct ColumnStatsPass {
    /// ne of the group ending at each global row (0.0 = not a group
    /// end, or an event-free group — both add nothing, matching the
    /// in-memory `if g.n_events > 0` skip).
    group_end_ne: Vec<f64>,
    xt_delta: Vec<f64>,
    lipschitz: Vec<LipschitzPair>,
    col_binary: Vec<bool>,
    hi: Vec<f64>,
    lo: Vec<f64>,
    p: usize,
}

impl ColumnStatsPass {
    pub(crate) fn new(n: usize, p: usize, groups: &[TieGroup]) -> Self {
        let mut group_end_ne = vec![0.0_f64; n];
        for g in groups {
            if g.n_events > 0 {
                group_end_ne[g.end - 1] = g.n_events as f64;
            }
        }
        ColumnStatsPass {
            group_end_ne,
            xt_delta: vec![0.0_f64; p],
            lipschitz: vec![LipschitzPair::default(); p],
            col_binary: vec![true; p],
            hi: vec![f64::NEG_INFINITY; p],
            lo: vec![f64::INFINITY; p],
            p,
        }
    }

    /// Fold one column-major chunk buffer (`rows` rows starting at
    /// global row `r0`) into the carry state. Chunks must arrive in
    /// ascending global row order; `delta` is the full sorted event
    /// indicator column.
    pub(crate) fn process_chunk(&mut self, chunk: &[f64], rows: usize, r0: usize, delta: &[f64]) {
        for j in 0..self.p {
            let col = &chunk[j * rows..(j + 1) * rows];
            let (mut xtd, mut h, mut l) = (self.xt_delta[j], self.hi[j], self.lo[j]);
            let mut lip = self.lipschitz[j];
            let mut binary = self.col_binary[j];
            for (k, &x) in col.iter().enumerate() {
                let global = r0 + k;
                xtd += x * delta[global];
                if x > h {
                    h = x;
                }
                if x < l {
                    l = x;
                }
                if x != 0.0 && x != 1.0 {
                    binary = false;
                }
                let ne = self.group_end_ne[global];
                if ne > 0.0 {
                    lip.add_group(ne, h - l);
                }
            }
            self.xt_delta[j] = xtd;
            self.hi[j] = h;
            self.lo[j] = l;
            self.lipschitz[j] = lip;
            self.col_binary[j] = binary;
        }
    }

    pub(crate) fn finish(self) -> (Vec<f64>, Vec<LipschitzPair>, Vec<bool>) {
        (self.xt_delta, self.lipschitz, self.col_binary)
    }
}

/// One streaming pass over every chunk of a single store deriving the
/// per-column constants via [`ColumnStatsPass`]. Runs before the
/// metadata is frozen behind its Arc.
fn derive_column_stats(
    file: &mut File,
    bytebuf: &mut Vec<u8>,
    header: &StoreHeader,
    delta: &[f64],
    groups: &[TieGroup],
) -> Result<(Vec<f64>, Vec<LipschitzPair>, Vec<bool>)> {
    let (n, p) = (header.n, header.p);
    let mut pass = ColumnStatsPass::new(n, p, groups);
    let mut chunk: Vec<f64> = Vec::new();
    for c in 0..header.n_chunks() {
        let rows = header.rows_in_chunk(c);
        chunk.clear();
        read_cells_append(
            file,
            bytebuf,
            header.col_segment_offset(c, 0),
            rows * p,
            header.precision,
            &mut chunk,
        )?;
        pass.process_chunk(&chunk, rows, c * header.chunk_rows, delta);
    }
    Ok(pass.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cox::CoxProblem;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::store::writer::{write_store, DatasetRows};
    use std::path::PathBuf;

    fn temp_path(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fs_store_dataset_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{tag}.fsds"))
    }

    fn small_store(tag: &str, n: usize, p: usize, seed: u64) -> (SurvivalDataset, PathBuf) {
        let ds = generate(&SyntheticConfig { n, p, rho: 0.3, k: 2.min(p), s: 0.1, seed });
        let out = temp_path(tag);
        let mut rows = DatasetRows::new(&ds);
        write_store(&mut rows, &out, 16, "t").unwrap();
        (ds, out)
    }

    #[test]
    fn derived_stats_match_in_memory_problem_bitwise() {
        let (ds, path) = small_store("stats", 77, 5, 11);
        let pr = CoxProblem::new(&ds);
        let store = ChunkedDataset::open(&path).unwrap();
        let m = store.meta();
        assert_eq!(m.n, 77);
        assert_eq!(m.p, 5);
        assert_eq!(m.time, pr.time);
        assert_eq!(m.delta, pr.delta);
        assert_eq!(m.groups, pr.groups);
        assert_eq!(m.n_events, pr.n_events);
        assert_eq!(m.xt_delta, pr.xt_delta, "Xᵀδ must be bitwise identical");
        assert_eq!(m.col_binary, pr.col_binary);
        let lip = crate::cox::lipschitz::all_lipschitz(&pr);
        assert_eq!(m.lipschitz, lip, "Lipschitz constants must be bitwise identical");
    }

    #[test]
    fn chunk_and_column_reads_match_materialized_matrix() {
        let (ds, path) = small_store("reads", 53, 4, 7);
        let pr = CoxProblem::new(&ds);
        let mut store = ChunkedDataset::open(&path).unwrap();
        let mut col = Vec::new();
        for l in 0..4 {
            store.load_col(l, &mut col).unwrap();
            assert_eq!(col, pr.x.col(l), "column {l}");
        }
        let mut chunk = Vec::new();
        let rows = store.load_chunk(3, &mut chunk).unwrap();
        assert_eq!(rows, 53 - 48);
        for j in 0..4 {
            assert_eq!(&chunk[j * rows..(j + 1) * rows], &pr.x.col(j)[48..53]);
        }
        // Materialization equals the sorted problem bitwise.
        let back = store.to_dataset().unwrap();
        assert_eq!(back.x.data, pr.x.data);
        assert_eq!(back.time, pr.time);
    }

    #[test]
    fn truncated_and_corrupt_stores_are_typed_errors() {
        let (_, path) = small_store("corrupt", 30, 3, 3);
        let bytes = std::fs::read(&path).unwrap();

        // Truncated payload.
        let cut = temp_path("cut");
        std::fs::write(&cut, &bytes[..bytes.len() - 7]).unwrap();
        assert!(matches!(
            ChunkedDataset::open(&cut),
            Err(FastSurvivalError::Store(_))
        ));

        // Flipped header bit (checksum).
        let mut corrupt = bytes.clone();
        corrupt[10] ^= 0x10;
        let cpath = temp_path("flip");
        std::fs::write(&cpath, &corrupt).unwrap();
        let err = ChunkedDataset::open(&cpath).unwrap_err();
        assert!(matches!(err, FastSurvivalError::Store(_)));

        // Not a store at all.
        let junk = temp_path("junk");
        std::fs::write(&junk, b"time,event\n1,0\n").unwrap();
        assert!(matches!(
            ChunkedDataset::open(&junk),
            Err(FastSurvivalError::Store(_))
        ));

        // Missing file: typed Io error naming the path.
        let missing = temp_path("missing-never-written");
        let err = ChunkedDataset::open(&missing).unwrap_err();
        assert!(matches!(err, FastSurvivalError::Io { .. }));
        assert!(err.to_string().contains("missing-never-written"));
    }
}
