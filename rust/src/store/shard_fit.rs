//! The sharded parallel big-n fit engine: per-shard derivative passes
//! with exact risk-set merging.
//!
//! Each worker owns a contiguous range of canonical merge tiles
//! ([`merge_tiles`] — the same decomposition the single-store chunked
//! fit replays) and, with it, the contiguous global-row span those tiles
//! cover: its slice of η/w, and a private [`ShardColReader`] that reads
//! only its rows of each column from the shard files. A coordinate step
//! is a two-phase distributed scan:
//!
//! 1. **Scan** — every worker computes per-group risk-set subtotals for
//!    its tiles *from zero* ([`tile_scan_b`]) and reports per-tile
//!    totals.
//! 2. **Merge + Emit** — the coordinator folds the per-tile totals into
//!    exclusive prefix carries ([`fold_carries`]) in canonical tile
//!    order, hands each worker its carry window, and the workers emit
//!    per-tile derivative contributions ([`tile_emit`]) that the
//!    coordinator folds — again in tile order — into the exact global
//!    (d1, d2).
//!
//! Because every sum is associated identically to the single-store
//! merged pass, the fold is *exact*, not approximate: the sharded fit
//! and the single-store fit execute the same floating-point sequence
//! per coordinate step, so their results are bitwise identical for any
//! shard count and any worker count. The Δ-application, η-rebase
//! schedule ([`REFRESH_EVERY`] / [`REBASE_SPAN`]), no-op snapping, and
//! stopping logic all reuse the exact code or constants of the
//! single-store path for the same reason.
//!
//! The protocol is plain `mpsc` over `std::thread::scope` — workers
//! borrow their η/w slices (`split_at_mut`), so there is no copying of
//! the O(n) state and no unsafe code.

use super::shard::{ShardColReader, ShardedDataset};
use super::source::{CoxData, StoreMeta};
use super::streaming::{rebuild_eta, StreamingFit, StreamingFitResult};
use crate::cox::derivatives::{fold_carries, merge_tiles, tile_emit, tile_scan_b, RiskPartials};
use crate::cox::loss::loss_for_parts_b;
use crate::cox::problem::TieGroup;
use crate::cox::state::{apply_coord_slice_b, REBASE_SPAN, REFRESH_EVERY};
use crate::error::{FastSurvivalError, Result};
use crate::optim::cd::SurrogateKind;
use crate::optim::objective::Stopper;
use crate::optim::{FitConfig, Objective, Trace};
use crate::util::compute::{KernelBackend, ResolvedCompute};
use crate::util::parallel::contiguous_ranges;
use std::sync::mpsc;
use std::time::Instant;

/// One worker's ownership: a contiguous tile range, the tie-group range
/// those tiles cover, and the contiguous global-row span of those
/// groups. Consecutive workers cover consecutive spans, so the η/w
/// vectors split cleanly into disjoint `&mut` slices.
#[derive(Clone, Copy, Debug)]
struct WorkerSpan {
    t_lo: usize,
    t_hi: usize,
    g_lo: usize,
    g_hi: usize,
    row_a: usize,
    row_b: usize,
}

/// Coordinator → worker commands, one round-trip per command.
enum Cmd {
    /// Read the worker's row range of column `l` and scan its tiles
    /// from zero; reply with per-tile totals.
    Scan { l: usize, need_d2: bool },
    /// Emit per-tile (e1, e2) from the scanned subtotals, seeded with
    /// the coordinator's exclusive prefix carries (one per owned tile).
    Emit { carries: Vec<RiskPartials> },
    /// Apply Δ to the worker's η/w slice using the column already in
    /// its buffer from the preceding `Scan`; reply with the slice max η.
    Apply { delta: f64, binary: bool },
    /// Report the slice max η for a rebase decision (refresh-fold
    /// semantics: `f64::max` from −∞, matching `CoxState::refresh_w`).
    EtaMax,
    /// Recompute `w = exp(η − m)` over the slice for the new shift.
    Rebase { m: f64 },
}

/// Worker → coordinator replies, in 1:1 correspondence with [`Cmd`].
enum Reply {
    Tiles(Vec<RiskPartials>),
    Emitted(Vec<(f64, f64)>),
    Applied(f64),
    EtaMax(f64),
    Rebased,
    Failed(FastSurvivalError),
}

fn worker_died() -> FastSurvivalError {
    FastSurvivalError::Engine("a shard worker terminated unexpectedly".into())
}

fn protocol_violation() -> FastSurvivalError {
    FastSurvivalError::Engine("shard worker replied out of protocol".into())
}

/// Send `cmd`, surfacing the worker's parting [`Reply::Failed`] if it
/// already hung up.
fn send_cmd(tx: &mpsc::Sender<Cmd>, rx: &mpsc::Receiver<Reply>, cmd: Cmd) -> Result<()> {
    crate::obs::counters::shard_cmd(match cmd {
        Cmd::Scan { .. } => crate::obs::ShardCmdKind::Scan,
        Cmd::Emit { .. } => crate::obs::ShardCmdKind::Emit,
        Cmd::Apply { .. } => crate::obs::ShardCmdKind::Apply,
        Cmd::EtaMax | Cmd::Rebase { .. } => crate::obs::ShardCmdKind::Ctl,
    });
    if tx.send(cmd).is_err() {
        return Err(match rx.try_recv() {
            Ok(Reply::Failed(e)) => e,
            _ => worker_died(),
        });
    }
    Ok(())
}

/// Receive one reply, converting worker faults into typed errors.
fn recv_reply(rx: &mpsc::Receiver<Reply>) -> Result<Reply> {
    match rx.recv() {
        Ok(Reply::Failed(e)) => Err(e),
        Ok(reply) => Ok(reply),
        Err(_) => Err(worker_died()),
    }
}

/// The worker loop: serve commands until the coordinator drops its
/// sender (end of sweep) or a read fails. `eta`/`w` are this worker's
/// exclusive slices of the global vectors, indexed from `span.row_a`.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rx: mpsc::Receiver<Cmd>,
    tx: mpsc::Sender<Reply>,
    span: WorkerSpan,
    groups: &[TieGroup],
    tile_cuts: &[usize],
    backend: KernelBackend,
    reader: &mut ShardColReader,
    colbuf: &mut Vec<f64>,
    gs: &mut Vec<RiskPartials>,
    eta: &mut [f64],
    w: &mut [f64],
) {
    gs.resize(span.g_hi - span.g_lo, RiskPartials::default());
    // Whether the last Scan requested s2 — Emit must mirror it.
    let mut cur_need_s2 = false;
    while let Ok(cmd) = rx.recv() {
        let reply = match cmd {
            Cmd::Scan { l, need_d2 } => {
                let _span = crate::obs::SpanTimer::start(crate::obs::Phase::ShardScan);
                cur_need_s2 = need_d2;
                match reader.read_col_range(l, span.row_a, span.row_b, colbuf) {
                    Ok(()) => {
                        let mut totals = Vec::with_capacity(span.t_hi - span.t_lo);
                        for t in span.t_lo..span.t_hi {
                            let (g_lo, g_hi) = (tile_cuts[t], tile_cuts[t + 1]);
                            totals.push(tile_scan_b(
                                backend,
                                groups,
                                g_lo,
                                g_hi,
                                w,
                                colbuf,
                                span.row_a,
                                need_d2,
                                &mut gs[g_lo - span.g_lo..g_hi - span.g_lo],
                            ));
                        }
                        Reply::Tiles(totals)
                    }
                    Err(e) => Reply::Failed(e),
                }
            }
            Cmd::Emit { carries } => {
                let _span = crate::obs::SpanTimer::start(crate::obs::Phase::ShardEmit);
                let mut emitted = Vec::with_capacity(span.t_hi - span.t_lo);
                for (i, t) in (span.t_lo..span.t_hi).enumerate() {
                    let (g_lo, g_hi) = (tile_cuts[t], tile_cuts[t + 1]);
                    emitted.push(tile_emit(
                        groups,
                        g_lo,
                        g_hi,
                        carries[i],
                        &gs[g_lo - span.g_lo..g_hi - span.g_lo],
                        cur_need_s2,
                    ));
                }
                Reply::Emitted(emitted)
            }
            Cmd::Apply { delta, binary } => {
                let _span = crate::obs::SpanTimer::start(crate::obs::Phase::ShardApply);
                Reply::Applied(apply_coord_slice_b(backend, colbuf, binary, delta, eta, w))
            }
            Cmd::EtaMax => {
                Reply::EtaMax(eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max))
            }
            Cmd::Rebase { m } => {
                for (e, wk) in eta.iter().zip(w.iter_mut()) {
                    *wk = (*e - m).exp();
                }
                Reply::Rebased
            }
        };
        let failed = matches!(reply, Reply::Failed(_));
        if tx.send(reply).is_err() || failed {
            return;
        }
    }
}

/// What the sharded exact phase left behind (the distributed analogue
/// of [`super::streaming::ExactPhaseOutcome`], with the state vectors
/// owned directly — the engine never builds a `CoxState`, because the
/// η/w vectors live sliced across workers during a sweep).
pub(crate) struct ShardFitOutcome {
    pub beta: Vec<f64>,
    pub eta: Vec<f64>,
    pub objective_value: f64,
    pub sweeps: usize,
    pub trace: Trace,
}

/// Exact surrogate CD over a sharded dataset with `shard_workers`
/// parallel scan workers. Bitwise identical to
/// [`super::streaming::exact_chunked_cd`] on the equivalent single
/// store: same merge-tile decomposition, same per-coordinate
/// (d1, d2) association, same Δ/residual formula
/// ([`SurrogateKind::delta_residual_from`]), same η/w update kernels
/// and rebase schedule, same stopper.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exact_sharded_cd(
    data: &mut ShardedDataset,
    meta: &StoreMeta,
    beta: Vec<f64>,
    surrogate: SurrogateKind,
    obj: Objective,
    max_sweeps: usize,
    tol: f64,
    stop_kkt: f64,
    budget_secs: f64,
    compute: ResolvedCompute,
    shard_workers: usize,
) -> Result<ShardFitOutcome> {
    let p = meta.p;
    let backend = compute.backend;
    let groups: &[TieGroup] = &meta.groups;
    let mut beta = beta;
    let mut eta = rebuild_eta(data, meta, &beta)?;

    // Replicate `CoxState::from_eta` → `refresh_w` exactly: shift to the
    // max η (0 when non-finite), w = exp(η − shift), counter reset.
    let m = eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut shift = if m.is_finite() { m } else { 0.0 };
    let mut w: Vec<f64> = eta.iter().map(|&e| (e - shift).exp()).collect();
    let mut updates_since_refresh = 0usize;

    // Canonical tile decomposition, shared with the single-store path.
    let tile_cuts = merge_tiles(groups);
    let ntiles = tile_cuts.len().saturating_sub(1);
    let workers = shard_workers.max(1).min(ntiles.max(1));
    let spans: Vec<WorkerSpan> = contiguous_ranges(ntiles, workers)
        .into_iter()
        .map(|(t_lo, t_hi)| {
            let (g_lo, g_hi) = (tile_cuts[t_lo], tile_cuts[t_hi]);
            let (row_a, row_b) = if g_hi > g_lo {
                (groups[g_lo].start, groups[g_hi - 1].end)
            } else {
                (0, 0)
            };
            WorkerSpan { t_lo, t_hi, g_lo, g_hi, row_a, row_b }
        })
        .collect();

    // Per-worker resources persist across sweeps: independent column
    // readers (own file handles and seek positions), column buffers,
    // and per-group scratch.
    let mut readers: Vec<ShardColReader> = Vec::with_capacity(spans.len());
    for _ in &spans {
        readers.push(data.col_reader()?);
    }
    let mut colbufs: Vec<Vec<f64>> = spans.iter().map(|_| Vec::new()).collect();
    let mut gsbufs: Vec<Vec<RiskPartials>> = spans.iter().map(|_| Vec::new()).collect();

    let config = FitConfig {
        objective: obj,
        max_iters: max_sweeps,
        tol,
        budget_secs,
        record_trace: true,
        compute,
    };
    let mut stopper = Stopper::new();
    let mut sweeps = 0usize;
    let need_d2 = surrogate == SurrogateKind::Cubic;

    for it in 0..max_sweeps {
        // One sweep: spawn the worker fleet over disjoint η/w slices,
        // run every coordinate through the two-phase distributed step,
        // then join (scope end) so the loss pass below sees the whole
        // vectors again.
        let max_res = std::thread::scope(|scope| -> Result<f64> {
            let mut txs: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(spans.len());
            let mut rxs: Vec<mpsc::Receiver<Reply>> = Vec::with_capacity(spans.len());
            let mut eta_rest: &mut [f64] = &mut eta;
            let mut w_rest: &mut [f64] = &mut w;
            for ((span, reader), (colbuf, gs)) in spans
                .iter()
                .zip(readers.iter_mut())
                .zip(colbufs.iter_mut().zip(gsbufs.iter_mut()))
            {
                let len = span.row_b - span.row_a;
                let (eta_s, eta_tail) = std::mem::take(&mut eta_rest).split_at_mut(len);
                let (w_s, w_tail) = std::mem::take(&mut w_rest).split_at_mut(len);
                eta_rest = eta_tail;
                w_rest = w_tail;
                let (cmd_tx, cmd_rx) = mpsc::channel();
                let (rep_tx, rep_rx) = mpsc::channel();
                let span = *span;
                let tc: &[usize] = &tile_cuts;
                scope.spawn(move || {
                    worker_loop(
                        cmd_rx, rep_tx, span, groups, tc, backend, reader, colbuf, gs,
                        eta_s, w_s,
                    )
                });
                txs.push(cmd_tx);
                rxs.push(rep_rx);
            }

            let mut max_res = 0.0_f64;
            for l in 0..p {
                let beta_l = beta[l];
                let lip = meta.lipschitz[l];
                if surrogate == SurrogateKind::Quadratic && lip.l2 + 2.0 * obj.l2 <= 0.0 {
                    // Flat (constant) coordinate: no information, no
                    // move — mirrors the merged step's early return
                    // (residual 0, state untouched).
                    continue;
                }
                // Phase A: distributed per-tile scan.
                for (tx, rx) in txs.iter().zip(rxs.iter()) {
                    send_cmd(tx, rx, Cmd::Scan { l, need_d2 })?;
                }
                let mut tile_totals: Vec<RiskPartials> = Vec::with_capacity(ntiles);
                for rx in &rxs {
                    match recv_reply(rx)? {
                        Reply::Tiles(t) => tile_totals.extend(t),
                        _ => return Err(protocol_violation()),
                    }
                }
                // Merge: exclusive prefix carries in canonical tile
                // order (workers are in tile order, so the extend above
                // reassembled the canonical sequence).
                let carries = fold_carries(&tile_totals, need_d2);
                // Phase B: distributed emission, folded in tile order.
                for ((tx, rx), span) in txs.iter().zip(rxs.iter()).zip(spans.iter()) {
                    send_cmd(
                        tx,
                        rx,
                        Cmd::Emit { carries: carries[span.t_lo..span.t_hi].to_vec() },
                    )?;
                }
                let (mut d1, mut d2) = (0.0_f64, 0.0_f64);
                for rx in &rxs {
                    match recv_reply(rx)? {
                        Reply::Emitted(es) => {
                            for (e1, e2) in es {
                                d1 += e1;
                                d2 += e2;
                            }
                        }
                        _ => return Err(protocol_violation()),
                    }
                }
                let d1 = d1 - meta.xt_delta[l];
                let (delta, residual) =
                    surrogate.delta_residual_from(d1, d2, beta_l, lip, obj, 0.0);
                if residual > max_res {
                    max_res = residual;
                }
                if delta == 0.0 {
                    // No state change, no refresh-counter bump —
                    // mirrors `CoxState::update_coord_col_b`.
                    continue;
                }
                beta[l] += delta;
                for (tx, rx) in txs.iter().zip(rxs.iter()) {
                    send_cmd(tx, rx, Cmd::Apply { delta, binary: meta.col_binary[l] })?;
                }
                let mut max_eta = f64::NEG_INFINITY;
                for rx in &rxs {
                    match recv_reply(rx)? {
                        Reply::Applied(m) => {
                            if m > max_eta {
                                max_eta = m;
                            }
                        }
                        _ => return Err(protocol_violation()),
                    }
                }
                updates_since_refresh += 1;
                if max_eta - shift > REBASE_SPAN
                    || max_eta - shift < -REBASE_SPAN
                    || updates_since_refresh >= REFRESH_EVERY
                {
                    // Distributed `refresh_w`: max-fold η across slices,
                    // then rebase every w to the new shift.
                    for (tx, rx) in txs.iter().zip(rxs.iter()) {
                        send_cmd(tx, rx, Cmd::EtaMax)?;
                    }
                    let mut m = f64::NEG_INFINITY;
                    for rx in &rxs {
                        match recv_reply(rx)? {
                            Reply::EtaMax(em) => m = m.max(em),
                            _ => return Err(protocol_violation()),
                        }
                    }
                    let m = if m.is_finite() { m } else { 0.0 };
                    for (tx, rx) in txs.iter().zip(rxs.iter()) {
                        send_cmd(tx, rx, Cmd::Rebase { m })?;
                    }
                    for rx in &rxs {
                        match recv_reply(rx)? {
                            Reply::Rebased => {}
                            _ => return Err(protocol_violation()),
                        }
                    }
                    shift = m;
                    updates_since_refresh = 0;
                }
            }
            Ok(max_res)
            // txs drop here → workers drain and exit → scope joins.
        })?;

        sweeps = it + 1;
        let loss = loss_for_parts_b(backend, groups, &meta.delta, &eta, &w, shift)
            + obj.penalty(&beta);
        let stop_loss = stopper.step_with(it, loss, Some(max_res), &config);
        let stopped_kkt = stop_kkt > 0.0 && max_res <= stop_kkt;
        if stopped_kkt {
            stopper.trace.converged = true;
        }
        if stop_loss || stopped_kkt {
            break;
        }
    }
    let objective_value =
        loss_for_parts_b(backend, groups, &meta.delta, &eta, &w, shift) + obj.penalty(&beta);
    Ok(ShardFitOutcome { beta, eta, objective_value, sweeps, trace: stopper.trace })
}

impl StreamingFit {
    /// Run the two-phase fit over a sharded dataset with `shard_workers`
    /// parallel exact-phase workers. Phase 1 (sampled-block warmup) is
    /// the exact single-store code over the global chunk geometry the
    /// sharded dataset serves; phase 2 is the distributed exact CD
    /// ([`exact_sharded_cd`]). The result is bitwise identical to
    /// [`StreamingFit::fit`] on the equivalent single store, for every
    /// shard count and worker count.
    pub fn fit_sharded(
        &self,
        data: &mut ShardedDataset,
        shard_workers: usize,
    ) -> Result<StreamingFitResult> {
        let meta = data.meta_arc();
        self.validate(&meta)?;
        let rc = self.compute.resolve()?;
        let fit_start = Instant::now();
        let (beta, sgd_steps) = self.sampled_block_warmup(data, &meta, rc, &fit_start)?;
        let remaining = if self.budget_secs > 0.0 {
            (self.budget_secs - fit_start.elapsed().as_secs_f64()).max(1e-9)
        } else {
            0.0
        };
        let outcome = exact_sharded_cd(
            data,
            &meta,
            beta,
            self.surrogate,
            self.objective,
            self.max_sweeps,
            self.tol,
            self.stop_kkt,
            remaining,
            rc,
            shard_workers,
        )?;
        Ok(StreamingFitResult {
            beta: outcome.beta,
            eta: outcome.eta,
            objective_value: outcome.objective_value,
            sweeps: outcome.sweeps,
            sgd_steps,
            trace: outcome.trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::store::dataset::ChunkedDataset;
    use crate::store::shard::write_sharded_store;
    use crate::store::writer::{write_store_with, DatasetRows};
    use crate::util::compute::Precision;
    use std::path::PathBuf;

    fn temp_dir() -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fs_store_shard_fit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Write `ds` as both a single store and an `n_shards`-way sharded
    /// store, fit both with `fit`, and require bitwise identity at
    /// every requested worker count.
    fn assert_sharded_parity(
        ds: &SurvivalDataset,
        chunk_rows: usize,
        n_shards: usize,
        fit: &StreamingFit,
        worker_counts: &[usize],
        tag: &str,
    ) {
        let dir = temp_dir();
        let single = dir.join(format!("{tag}_single.fsds"));
        let sharded = dir.join(format!("{tag}_sharded.fsds"));
        let mut rows = DatasetRows::new(ds);
        write_store_with(&mut rows, &single, chunk_rows, tag, Precision::F64).unwrap();
        let mut rows = DatasetRows::new(ds);
        write_sharded_store(&mut rows, &sharded, chunk_rows, tag, Precision::F64, n_shards)
            .unwrap();

        let mut one = ChunkedDataset::open(&single).unwrap();
        let reference = fit.fit(&mut one).unwrap();
        for &workers in worker_counts {
            let mut many = ShardedDataset::open(&sharded).unwrap();
            let res = fit.fit_sharded(&mut many, workers).unwrap();
            assert_eq!(
                bits(&res.beta),
                bits(&reference.beta),
                "{tag}: β must be bitwise identical at {workers} workers"
            );
            assert_eq!(
                bits(&res.eta),
                bits(&reference.eta),
                "{tag}: η must be bitwise identical at {workers} workers"
            );
            assert_eq!(
                res.objective_value.to_bits(),
                reference.objective_value.to_bits(),
                "{tag}: objective must be bitwise identical at {workers} workers"
            );
            assert_eq!(res.sweeps, reference.sweeps, "{tag}: same stopping point");
            assert_eq!(res.sgd_steps, reference.sgd_steps, "{tag}: same warmup");
        }
    }

    #[test]
    fn sharded_fit_is_bitwise_identical_small() {
        // n is far below one merge tile, so the engine clamps to one
        // worker — the degenerate case must still be exact.
        let ds = generate(&SyntheticConfig { n: 240, p: 5, rho: 0.3, k: 2, s: 0.1, seed: 11 });
        let fit = StreamingFit {
            objective: Objective { l1: 0.0, l2: 1.0 },
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 40,
            tol: 1e-12,
            ..Default::default()
        };
        assert_sharded_parity(&ds, 32, 3, &fit, &[1, 4], "small");
    }

    #[test]
    fn sharded_fit_is_bitwise_identical_multi_tile() {
        // n spans several merge tiles, so 2 and 3 workers genuinely
        // exercise the distributed scan/merge/emit protocol.
        let ds =
            generate(&SyntheticConfig { n: 9500, p: 4, rho: 0.2, k: 2, s: 0.1, seed: 23 });
        let fit = StreamingFit {
            objective: Objective { l1: 0.0, l2: 1.0 },
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 6,
            tol: 1e-12,
            ..Default::default()
        };
        assert_sharded_parity(&ds, 1024, 3, &fit, &[1, 2, 3], "multitile");
    }

    #[test]
    fn cubic_and_l1_sharded_fits_stay_bitwise() {
        let ds = generate(&SyntheticConfig { n: 300, p: 6, rho: 0.4, k: 3, s: 0.1, seed: 7 });
        let cubic = StreamingFit {
            objective: Objective { l1: 0.0, l2: 0.5 },
            surrogate: SurrogateKind::Cubic,
            max_sweeps: 30,
            tol: 1e-12,
            ..Default::default()
        };
        assert_sharded_parity(&ds, 64, 2, &cubic, &[2], "cubic");
        let lasso = StreamingFit {
            objective: Objective { l1: 2.0, l2: 0.1 },
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 30,
            tol: 1e-12,
            stop_kkt: 1e-8,
            ..Default::default()
        };
        assert_sharded_parity(&ds, 64, 4, &lasso, &[2], "lasso");
    }

    #[test]
    fn heavy_ties_at_shard_boundaries_stay_bitwise() {
        // Times tied in runs of 9: shard cuts must snap to group ends
        // and the distributed emission must still match exactly.
        let p = 4;
        let n = 360;
        let cols: Vec<Vec<f64>> = (0..p)
            .map(|j| (0..n).map(|i| ((i * 13 + j * 5) % 7) as f64 - 3.0).collect())
            .collect();
        let time: Vec<f64> = (0..n).map(|i| (i / 9) as f64).collect();
        let event: Vec<bool> = (0..n).map(|i| i % 4 != 0).collect();
        let ds = SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "ties");
        let fit = StreamingFit {
            objective: Objective { l1: 0.0, l2: 1.0 },
            surrogate: SurrogateKind::Quadratic,
            max_sweeps: 25,
            tol: 1e-12,
            ..Default::default()
        };
        assert_sharded_parity(&ds, 48, 4, &fit, &[1, 2], "ties");
    }

    #[test]
    fn fit_sharded_validates_like_fit() {
        let dir = temp_dir();
        let ds = generate(&SyntheticConfig { n: 80, p: 3, rho: 0.2, k: 2, s: 0.1, seed: 5 });
        let out = dir.join("validate.fsds");
        let mut rows = DatasetRows::new(&ds);
        write_sharded_store(&mut rows, &out, 16, "v", Precision::F64, 2).unwrap();
        let mut many = ShardedDataset::open(&out).unwrap();
        let bad = StreamingFit { max_sweeps: 0, ..Default::default() };
        assert!(matches!(
            bad.fit_sharded(&mut many, 2),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
        let bad = StreamingFit {
            objective: Objective { l1: -1.0, l2: 0.0 },
            ..Default::default()
        };
        assert!(matches!(
            bad.fit_sharded(&mut many, 2),
            Err(FastSurvivalError::InvalidConfig(_))
        ));
    }
}
