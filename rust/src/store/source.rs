//! The data-access surface the chunked trainer runs on.
//!
//! [`CoxData`] is the minimal contract the out-of-core driver needs:
//! O(n) risk-set metadata held in memory ([`StoreMeta`]) plus two bulk
//! reads — a column-major row chunk and a full feature column. Two
//! implementations exist: the on-disk [`super::ChunkedDataset`] and the
//! in-memory [`MemoryCoxData`] reference. Both feed the *same* driver
//! code and the same parts-level Cox kernels
//! ([`crate::cox::derivatives::coord_d1_col`] and friends), so a chunked
//! fit and an in-memory fit perform identical floating-point operations
//! in identical order — the parity tests assert their coefficients match
//! bit for bit.

use crate::cox::lipschitz::{all_lipschitz, LipschitzPair};
use crate::cox::problem::TieGroup;
use crate::cox::CoxProblem;
use crate::data::SurvivalDataset;
use crate::error::Result;
use crate::linalg::Matrix;
use crate::util::compute::Precision;
use std::sync::Arc;

/// Everything the trainer holds in memory about a dataset: O(n) risk-set
/// structure and O(p) per-column constants — but never the n×p matrix.
#[derive(Clone, Debug)]
pub struct StoreMeta {
    pub n: usize,
    pub p: usize,
    pub chunk_rows: usize,
    pub n_chunks: usize,
    pub name: String,
    pub feature_names: Vec<String>,
    /// One-pass standardization stats recorded by the writer (metadata;
    /// features are stored raw).
    pub means: Vec<f64>,
    pub stds: Vec<f64>,
    /// Observation times, sorted descending (CoxProblem order).
    pub time: Vec<f64>,
    /// Event indicators in sorted order, 1.0/0.0.
    pub delta: Vec<f64>,
    /// Event indicators in sorted order, as booleans.
    pub event: Vec<bool>,
    /// Tie groups over the sorted times; risk sets are prefixes. (No
    /// per-row `group_of` map here: the chunked kernels only walk
    /// groups, and an O(n) vector of indices would count against the
    /// peak-RSS budget for nothing — derive it from `groups` if ever
    /// needed.)
    pub groups: Vec<TieGroup>,
    pub n_events: usize,
    /// `(Xᵀδ)_l` per column — the β-independent gradient term.
    pub xt_delta: Vec<f64>,
    /// Theorem-3.4 surrogate constants per column.
    pub lipschitz: Vec<LipschitzPair>,
    /// Per-column all-values-in-{0,1} flag (binary fast path).
    pub col_binary: Vec<bool>,
}

impl StoreMeta {
    /// The dataset's in-memory footprint if it were materialized
    /// (n·p doubles) — the yardstick the peak-RSS gate measures against.
    pub fn matrix_bytes(&self) -> u64 {
        self.n as u64 * self.p as u64 * 8
    }
}

/// Streaming per-column mean/std accumulator — Welford's one-pass
/// algorithm, which stays accurate where the raw-moment
/// `Σx²/n − mean²` formula catastrophically cancels (e.g. a
/// timestamp-scale column with mean ~1e9 and spread ~1 would record
/// σ = 1.0 under raw moments because both terms round to the same
/// ~1e18). The one place the store's stats convention lives: the
/// writer's row-streaming pass and the in-memory reference source both
/// go through it, so they cannot drift apart. σ floor as in
/// `Matrix::standardize_columns`: (near-)constant columns keep σ = 1
/// instead of going to 0/NaN; variance is population (÷n), matching it
/// too.
pub(crate) struct RunningStats {
    count: f64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl RunningStats {
    pub(crate) fn new(p: usize) -> Self {
        RunningStats { count: 0.0, mean: vec![0.0; p], m2: vec![0.0; p] }
    }

    pub(crate) fn push_row(&mut self, row: &[f64]) {
        debug_assert_eq!(row.len(), self.mean.len());
        self.count += 1.0;
        for (j, &x) in row.iter().enumerate() {
            let d = x - self.mean[j];
            self.mean[j] += d / self.count;
            self.m2[j] += d * (x - self.mean[j]);
        }
    }

    /// `(means, stds)` with the σ floor applied.
    pub(crate) fn finish(self) -> (Vec<f64>, Vec<f64>) {
        let n = self.count.max(1.0);
        let stds = self
            .m2
            .iter()
            .map(|&m2| {
                let var = (m2 / n).max(0.0);
                if var > 1e-24 {
                    var.sqrt()
                } else {
                    1.0
                }
            })
            .collect();
        (self.mean, stds)
    }
}

/// Chunk/column access over a Cox dataset in canonical sorted order.
///
/// `load_chunk` fills `buf` column-major for the chunk's rows (column
/// `j` of chunk `c` is `buf[j·rows .. (j+1)·rows]`) and returns `rows`;
/// `load_col` fills `buf` with one full column over all n sorted rows.
/// Methods take `&mut self` because the on-disk implementation seeks.
pub trait CoxData {
    fn meta(&self) -> &StoreMeta;
    /// The same metadata as an owned handle. The fit driver holds this
    /// across its mutable `load_chunk`/`load_col` calls — a pointer
    /// clone, not a copy of the O(n) vectors (the out-of-core peak-RSS
    /// budget pays for every resident byte).
    fn meta_arc(&self) -> Arc<StoreMeta>;
    fn load_chunk(&mut self, c: usize, buf: &mut Vec<f64>) -> Result<usize>;
    fn load_col(&mut self, l: usize, buf: &mut Vec<f64>) -> Result<()>;
}

/// In-memory [`CoxData`]: the whole sorted matrix resident, served
/// through the same chunk/column surface as the on-disk store. This is
/// the parity reference for the chunked trainer and the zero-I/O path
/// for datasets that comfortably fit in RAM.
pub struct MemoryCoxData {
    x: Matrix,
    meta: Arc<StoreMeta>,
}

impl MemoryCoxData {
    /// Build from a dataset (validates + sorts through
    /// [`CoxProblem::try_new`], so the row order, tie groups, Xᵀδ, and
    /// Lipschitz constants are the engine's own).
    pub fn from_dataset(ds: &SurvivalDataset, chunk_rows: usize) -> Result<Self> {
        Self::from_dataset_with(ds, chunk_rows, Precision::F64)
    }

    /// [`MemoryCoxData::from_dataset`] with an explicit cell precision:
    /// under [`Precision::F32Storage`] every feature cell is rounded
    /// through f32 before any derived constant is computed, so this
    /// source serves exactly what a v2 `.fsds` store of the same data
    /// decodes — the in-memory parity reference for mixed-precision
    /// chunked fits.
    pub fn from_dataset_with(
        ds: &SurvivalDataset,
        chunk_rows: usize,
        precision: Precision,
    ) -> Result<Self> {
        let ds_quantized;
        let ds = match precision {
            Precision::F64 => ds,
            Precision::F32Storage => {
                let mut q = ds.clone();
                q.x.quantize_f32();
                ds_quantized = q;
                &ds_quantized
            }
        };
        let pr = CoxProblem::try_new(ds)?;
        let lipschitz = all_lipschitz(&pr);
        let chunk_rows = chunk_rows.max(1);
        let n = pr.n();
        let p = pr.p();
        let n_chunks = n.div_ceil(chunk_rows);
        // Standardization stats over the sorted columns (metadata only),
        // through the shared streaming accumulator.
        let mut means = Vec::with_capacity(p);
        let mut stds = Vec::with_capacity(p);
        for j in 0..p {
            let mut st = RunningStats::new(1);
            for v in pr.x.col(j) {
                st.push_row(std::slice::from_ref(v));
            }
            let (m, s) = st.finish();
            means.push(m[0]);
            stds.push(s[0]);
        }
        let event: Vec<bool> = pr.delta.iter().map(|&d| d == 1.0).collect();
        let meta = StoreMeta {
            n,
            p,
            chunk_rows,
            n_chunks,
            name: ds.name.clone(),
            feature_names: ds.feature_names.clone(),
            means,
            stds,
            time: pr.time.clone(),
            delta: pr.delta.clone(),
            event,
            groups: pr.groups.clone(),
            n_events: pr.n_events,
            xt_delta: pr.xt_delta.clone(),
            lipschitz,
            col_binary: pr.col_binary.clone(),
        };
        Ok(MemoryCoxData { x: pr.x, meta: Arc::new(meta) })
    }
}

impl CoxData for MemoryCoxData {
    fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    fn meta_arc(&self) -> Arc<StoreMeta> {
        Arc::clone(&self.meta)
    }

    fn load_chunk(&mut self, c: usize, buf: &mut Vec<f64>) -> Result<usize> {
        let r0 = c * self.meta.chunk_rows;
        let rows = self.meta.chunk_rows.min(self.meta.n - r0);
        buf.clear();
        buf.reserve(rows * self.meta.p);
        for j in 0..self.meta.p {
            buf.extend_from_slice(&self.x.col(j)[r0..r0 + rows]);
        }
        Ok(rows)
    }

    fn load_col(&mut self, l: usize, buf: &mut Vec<f64>) -> Result<()> {
        buf.clear();
        buf.extend_from_slice(self.x.col(l));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    #[test]
    fn memory_source_serves_sorted_chunks_and_columns() {
        let ds = generate(&SyntheticConfig { n: 53, p: 4, rho: 0.3, k: 2, s: 0.1, seed: 3 });
        let pr = CoxProblem::new(&ds);
        let mut src = MemoryCoxData::from_dataset(&ds, 16).unwrap();
        let meta = src.meta().clone();
        assert_eq!(meta.n, 53);
        assert_eq!(meta.n_chunks, 4);
        assert_eq!(meta.time, pr.time);
        assert_eq!(meta.xt_delta, pr.xt_delta);
        assert_eq!(meta.matrix_bytes(), 53 * 4 * 8);
        // Column read matches the problem's column.
        let mut col = Vec::new();
        src.load_col(2, &mut col).unwrap();
        assert_eq!(col, pr.x.col(2));
        // Chunk read is column-major over the chunk's rows.
        let mut chunk = Vec::new();
        let rows = src.load_chunk(3, &mut chunk).unwrap();
        assert_eq!(rows, 53 - 3 * 16);
        for j in 0..4 {
            assert_eq!(&chunk[j * rows..(j + 1) * rows], &pr.x.col(j)[48..53]);
        }
    }
}
