//! Sharded `.fsds` layout: one logical big-n store split into
//! time-contiguous row-range shards for parallel fitting.
//!
//! A sharded store is a set of complete, individually-valid `.fsds`
//! files (`{out}.g{GGG}.shard{SSS}.fsds`) plus a versioned JSON
//! manifest (`{out}.shards.json`). Shard `s` holds sorted global rows
//! `[row0, row0 + rows)` of the canonical descending-time order, so the
//! concatenation of the shard payloads in sequence order *is* the
//! single-store payload: risk sets stay prefixes of the global order
//! and every per-shard scan composes into the exact global quantities.
//!
//! Crash safety follows the PR-6 manifest discipline, with a
//! generation twist: every rewrite bumps `generation`, which is
//! embedded in the shard file names. New-generation shards are
//! assembled under fresh names (`.partial.tmp`, then renamed), never
//! touching the files the current manifest points at; the manifest
//! rename is the single commit point that atomically flips readers to
//! the new generation. Any crash before that leaves the previous view
//! fully openable.
//!
//! Tie groups never straddle shards: the writer cuts only at tie-group
//! ends, so each shard boundary is a strict time decrease. A manifest
//! describing equal or overlapping time ranges across shards is a
//! typed [`FastSurvivalError::Store`] error — such a split would break
//! the prefix structure of risk sets.

use super::dataset::{read_cells_append, ColumnStatsPass};
use super::format::{self, fnv1a, StoreHeader, DEFAULT_CHUNK_ROWS, HEADER_LEN};
use super::source::{CoxData, StoreMeta};
use super::writer::{spill_rows, write_sorted_store, RowSource, SyntheticRows};
use crate::api::json::{self, Json};
use crate::cox::problem::{build_tie_groups, descending_time_order, TieGroup};
use crate::data::synthetic::SyntheticConfig;
use crate::error::{FastSurvivalError, Result};
use crate::util::compute::Precision;
use std::fs::File;
use std::io::BufReader;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Shard-manifest schema version.
pub const SHARD_MANIFEST_VERSION: usize = 1;

/// `{out}.shards.json`.
pub fn shard_manifest_path(out: &Path) -> PathBuf {
    PathBuf::from(format!("{}.shards.json", out.display()))
}

/// `{out}.g{generation:03}.shard{seq:03}.fsds` — generation-numbered so
/// a rewrite never overwrites the files a live manifest points at.
pub fn shard_file_path(out: &Path, generation: u64, seq: usize) -> PathBuf {
    PathBuf::from(format!("{}.g{generation:03}.shard{seq:03}.fsds", out.display()))
}

/// One shard in the manifest: where it lives, which sorted global rows
/// it holds, its time range, and its header's FNV self-check.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardEntry {
    /// Position in the global row order (also embedded in the name).
    pub seq: usize,
    /// File *name* (no directory) — resolved against the manifest's
    /// parent directory, so a sharded store can be moved as a unit.
    pub file: String,
    /// Rows this shard holds.
    pub rows: usize,
    /// First sorted global row index.
    pub row0: usize,
    /// Time of the shard's first (largest-time) row.
    pub t_first: f64,
    /// Time of the shard's last (smallest-time) row.
    pub t_last: f64,
    /// The shard header's stored FNV-1a self-check.
    pub checksum: u64,
}

/// The parsed `{out}.shards.json`: global geometry plus the shard list.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    pub generation: u64,
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub chunk_rows: usize,
    pub precision: Precision,
    pub shards: Vec<ShardEntry>,
}

impl ShardManifest {
    /// Structural validation: sequential shards, cumulative row ranges
    /// summing to `n`, descending time within and strictly *decreasing*
    /// across shards. Equal boundary times mean a tie group straddles
    /// two shards; reversed ranges mean the shards overlap — both are
    /// typed Store errors because either breaks the risk-set prefix
    /// structure the sharded fit depends on.
    pub fn validate(&self) -> Result<()> {
        let err = |msg: String| Err(FastSurvivalError::Store(msg));
        if self.n == 0 || self.p == 0 || self.chunk_rows == 0 {
            return err(format!(
                "degenerate shard-manifest geometry (n={}, p={}, chunk_rows={})",
                self.n, self.p, self.chunk_rows
            ));
        }
        if self.shards.is_empty() {
            return err("shard manifest lists no shards".into());
        }
        let mut row0 = 0usize;
        for (i, s) in self.shards.iter().enumerate() {
            if s.seq != i {
                return err(format!("shard {i} carries sequence number {}", s.seq));
            }
            if s.rows == 0 {
                return err(format!("shard {i} is empty"));
            }
            if s.row0 != row0 {
                return err(format!(
                    "shard {i} starts at row {} but the previous shards cover {row0} rows",
                    s.row0
                ));
            }
            if !s.t_first.is_finite() || !s.t_last.is_finite() || s.t_first < s.t_last {
                return err(format!(
                    "shard {i} time range is not descending ({} .. {})",
                    s.t_first, s.t_last
                ));
            }
            if i > 0 {
                let prev = &self.shards[i - 1];
                if prev.t_last == s.t_first {
                    return err(format!(
                        "tie group at time {} straddles shards {} and {i} — each tie group \
                         must be owned by exactly one shard",
                        s.t_first,
                        i - 1
                    ));
                }
                if prev.t_last < s.t_first {
                    return err(format!(
                        "shards {} and {i} have overlapping time ranges ({} .. {} then \
                         {} .. {})",
                        i - 1,
                        prev.t_first,
                        prev.t_last,
                        s.t_first,
                        s.t_last
                    ));
                }
            }
            row0 += s.rows;
        }
        if row0 != self.n {
            return err(format!(
                "shard rows sum to {row0} but the manifest says n={}",
                self.n
            ));
        }
        Ok(())
    }

    /// Load a shard manifest if present. `Ok(None)` when no manifest
    /// file exists; a malformed or structurally invalid manifest is a
    /// typed Store error (it is our own atomic write).
    pub fn load(path: &Path) -> Result<Option<ShardManifest>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(FastSurvivalError::io(format!("reading {}", path.display()), e))
            }
        };
        let doc = json::parse(&text).map_err(|e| {
            FastSurvivalError::Store(format!("malformed shard manifest {}: {e}", path.display()))
        })?;
        let version = doc.require("shard_manifest_version")?.as_usize()?;
        if version != SHARD_MANIFEST_VERSION {
            return Err(FastSurvivalError::Store(format!(
                "unsupported shard manifest version {version} (this build reads \
                 {SHARD_MANIFEST_VERSION})"
            )));
        }
        let precision = Precision::from_name(doc.require("precision")?.as_str()?)?;
        let mut shards = Vec::new();
        for s in doc.require("shards")?.as_array()? {
            let checksum_hex = s.require("checksum")?.as_str()?;
            let checksum = u64::from_str_radix(checksum_hex.trim_start_matches("0x"), 16)
                .map_err(|_| {
                    FastSurvivalError::Store(format!(
                        "bad shard checksum {checksum_hex:?} in manifest"
                    ))
                })?;
            shards.push(ShardEntry {
                seq: s.require("seq")?.as_usize()?,
                file: s.require("file")?.as_str()?.to_string(),
                rows: s.require("rows")?.as_usize()?,
                row0: s.require("row0")?.as_usize()?,
                t_first: s.require("t_first")?.as_f64()?,
                t_last: s.require("t_last")?.as_f64()?,
                checksum,
            });
        }
        let manifest = ShardManifest {
            generation: doc.require("generation")?.as_usize()? as u64,
            name: doc.require("name")?.as_str()?.to_string(),
            n: doc.require("n")?.as_usize()?,
            p: doc.require("p")?.as_usize()?,
            chunk_rows: doc.require("chunk_rows")?.as_usize()?,
            precision,
            shards,
        };
        manifest.validate()?;
        Ok(Some(manifest))
    }

    /// Atomically write the manifest (temp file + rename) — the single
    /// commit point that flips readers to this generation.
    pub fn save(&self, path: &Path) -> Result<()> {
        let shards: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("seq".into(), Json::Num(s.seq as f64)),
                    ("file".into(), Json::Str(s.file.clone())),
                    ("rows".into(), Json::Num(s.rows as f64)),
                    ("row0".into(), Json::Num(s.row0 as f64)),
                    ("t_first".into(), Json::Num(s.t_first)),
                    ("t_last".into(), Json::Num(s.t_last)),
                    ("checksum".into(), Json::Str(format!("{:#018x}", s.checksum))),
                ])
            })
            .collect();
        let doc = Json::Obj(vec![
            ("shard_manifest_version".into(), Json::Num(SHARD_MANIFEST_VERSION as f64)),
            ("generation".into(), Json::Num(self.generation as f64)),
            ("name".into(), Json::Str(self.name.clone())),
            ("n".into(), Json::Num(self.n as f64)),
            ("p".into(), Json::Num(self.p as f64)),
            ("chunk_rows".into(), Json::Num(self.chunk_rows as f64)),
            ("precision".into(), Json::Str(self.precision.name().to_string())),
            ("shards".into(), Json::Arr(shards)),
        ]);
        let tmp = PathBuf::from(format!("{}.partial.tmp", path.display()));
        std::fs::write(&tmp, doc.to_json_string())
            .map_err(|e| FastSurvivalError::io(format!("writing {}", tmp.display()), e))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            FastSurvivalError::io(format!("publishing {} -> {}", tmp.display(), path.display()), e)
        })
    }
}

/// What a completed sharded write looked like.
#[derive(Clone, Debug)]
pub struct ShardedSummary {
    pub n: usize,
    pub p: usize,
    pub chunk_rows: usize,
    pub n_events: usize,
    /// Shards actually written (≤ the requested count when tie groups
    /// or a small n leave fewer usable boundaries).
    pub n_shards: usize,
    pub generation: u64,
    /// Total bytes across all shard files.
    pub bytes: u64,
    pub manifest_path: PathBuf,
}

/// Cut the sorted rows `0..n` into at most `shards` contiguous windows,
/// cutting only at tie-group ends so no group straddles a boundary.
/// Each requested boundary `s·n/shards` is snapped to the last group
/// end at or before it (a straddling group is owned by the later
/// shard); snaps that would produce an empty shard are dropped, so the
/// actual shard count can be smaller than requested. Returns the full
/// boundary list `[0, c1, .., n]`.
fn shard_cuts(groups: &[TieGroup], n: usize, shards: usize) -> Vec<usize> {
    let mut bounds = vec![0usize];
    let mut gi = 0usize;
    for s in 1..shards {
        let target = s * n / shards;
        let mut cut = *bounds.last().unwrap();
        while gi < groups.len() && groups[gi].end <= target {
            cut = groups[gi].end;
            gi += 1;
        }
        if cut > *bounds.last().unwrap() && cut < n {
            bounds.push(cut);
        }
    }
    bounds.push(n);
    bounds
}

/// Stream `source` into a sharded store: one spill + sort pass, then
/// one complete `.fsds` file per shard window, then the manifest as the
/// atomic commit. The concatenated shard payloads are exactly the rows
/// a single-store write of the same source would hold, in the same
/// canonical descending-time order with the same global
/// standardization stats.
pub fn write_sharded_store(
    source: &mut dyn RowSource,
    out: &Path,
    chunk_rows: usize,
    name: &str,
    precision: Precision,
    shards: usize,
) -> Result<ShardedSummary> {
    if shards == 0 {
        return Err(FastSurvivalError::InvalidConfig(
            "shard count must be at least 1".into(),
        ));
    }
    let chunk_rows = if chunk_rows == 0 { DEFAULT_CHUNK_ROWS } else { chunk_rows };
    let spill_path = PathBuf::from(format!("{}.rows.tmp", out.display()));
    let result = write_sharded_inner(source, out, &spill_path, chunk_rows, name, precision, shards);
    // The spill file is workspace either way; best-effort cleanup.
    let _ = std::fs::remove_file(&spill_path);
    result
}

#[allow(clippy::too_many_arguments)]
fn write_sharded_inner(
    source: &mut dyn RowSource,
    out: &Path,
    spill_path: &Path,
    chunk_rows: usize,
    name: &str,
    precision: Precision,
    shards: usize,
) -> Result<ShardedSummary> {
    let spilled = spill_rows(source, spill_path)?;
    let n = spilled.time.len();
    let n_events = spilled.event.iter().filter(|&&e| e).count();
    let order = descending_time_order(&spilled.time);
    let stime: Vec<f64> = order.iter().map(|&i| spilled.time[i]).collect();
    let sdelta: Vec<f64> =
        order.iter().map(|&i| if spilled.event[i] { 1.0 } else { 0.0 }).collect();
    let (groups, _group_of) = build_tie_groups(&stime, &sdelta);
    let bounds = shard_cuts(&groups, n, shards);

    // New generation: fresh file names, so the current manifest's view
    // stays intact until the final rename below.
    let manifest_path = shard_manifest_path(out);
    let generation = match ShardManifest::load(&manifest_path)? {
        Some(prev) => prev.generation + 1,
        None => 0,
    };

    let mut entries = Vec::with_capacity(bounds.len() - 1);
    let mut bytes = 0u64;
    for (seq, win) in bounds.windows(2).enumerate() {
        let (a, b) = (win[0], win[1]);
        let shard_path = shard_file_path(out, generation, seq);
        let partial = PathBuf::from(format!("{}.partial.tmp", shard_path.display()));
        let header = match write_sorted_store(
            &spilled,
            spill_path,
            &order[a..b],
            &partial,
            chunk_rows,
            name,
            precision,
        ) {
            Ok(h) => h,
            Err(e) => {
                let _ = std::fs::remove_file(&partial);
                return Err(e);
            }
        };
        std::fs::rename(&partial, &shard_path).map_err(|e| {
            FastSurvivalError::io(
                format!("publishing {} -> {}", partial.display(), shard_path.display()),
                e,
            )
        })?;
        bytes += header.expected_file_len();
        let file = shard_path
            .file_name()
            .map(|f| f.to_string_lossy().into_owned())
            .expect("shard path always has a file name");
        entries.push(ShardEntry {
            seq,
            file,
            rows: b - a,
            row0: a,
            t_first: stime[a],
            t_last: stime[b - 1],
            checksum: fnv1a(&header.encode()[0..40]),
        });
    }

    let manifest = ShardManifest {
        generation,
        name: name.to_string(),
        n,
        p: spilled.p,
        chunk_rows,
        precision,
        shards: entries,
    };
    manifest.validate()?;
    manifest.save(&manifest_path)?;
    Ok(ShardedSummary {
        n,
        p: spilled.p,
        chunk_rows,
        n_events,
        n_shards: manifest.shards.len(),
        generation,
        bytes,
        manifest_path,
    })
}

/// Convenience: stream the Appendix-C.2 generator into a sharded store.
pub fn convert_synthetic_sharded(
    cfg: &SyntheticConfig,
    out: &Path,
    chunk_rows: usize,
    precision: Precision,
    shards: usize,
) -> Result<ShardedSummary> {
    let mut rows = SyntheticRows::new(cfg);
    let name = format!("synthetic_stream_n{}_p{}_rho{}", cfg.n, cfg.p, cfg.rho);
    write_sharded_store(&mut rows, out, chunk_rows, &name, precision, shards)
}

/// Convenience: stream a CSV file into a sharded store.
pub fn convert_csv_sharded(
    input: &Path,
    out: &Path,
    chunk_rows: usize,
    name: &str,
    precision: Precision,
    shards: usize,
) -> Result<ShardedSummary> {
    let mut reader = crate::data::csv::open_survival_csv(input)?;
    write_sharded_store(&mut reader, out, chunk_rows, name, precision, shards)
}

/// Read the *local* row range `[la, lb)` of column `j` from one shard
/// file, walking its chunk geometry and appending decoded cells to
/// `out`.
pub(crate) fn read_local_col_range(
    file: &mut File,
    header: &StoreHeader,
    j: usize,
    la: usize,
    lb: usize,
    bytebuf: &mut Vec<u8>,
    out: &mut Vec<f64>,
) -> Result<()> {
    let mut a = la;
    while a < lb {
        let c = a / header.chunk_rows;
        let cstart = c * header.chunk_rows;
        let cend = cstart + header.rows_in_chunk(c);
        let b = lb.min(cend);
        let offset =
            header.col_segment_offset(c, j) + header.cell_bytes() * (a - cstart) as u64;
        read_cells_append(file, bytebuf, offset, b - a, header.precision, out)?;
        a = b;
    }
    Ok(())
}

/// One open shard file.
struct ShardReader {
    file: File,
    header: StoreHeader,
    path: PathBuf,
    row0: usize,
}

/// An open sharded store: the manifest's shard set presented as one
/// logical [`CoxData`] source with **global** chunk geometry — chunk
/// `c` covers sorted global rows `[c·chunk_rows, ..)` even when that
/// window straddles shard files, so warm-up sampling and η rebuilds
/// are bitwise identical to the single-store path.
pub struct ShardedDataset {
    manifest: ShardManifest,
    readers: Vec<ShardReader>,
    meta: Arc<StoreMeta>,
    /// Reusable byte buffer for cell reads.
    iobuf: Vec<u8>,
}

impl ShardedDataset {
    /// Open a sharded store. `path` is either the logical store path
    /// (the manifest is looked up at `{path}.shards.json`) or the
    /// manifest path itself. Every shard is fully validated: header
    /// checksum against the manifest, row count, geometry, schema and
    /// stats agreement, payload time ranges — any mismatch is a typed
    /// [`FastSurvivalError::Store`] error.
    pub fn open(path: &Path) -> Result<Self> {
        let manifest_path = if path.to_string_lossy().ends_with(".shards.json") {
            path.to_path_buf()
        } else {
            shard_manifest_path(path)
        };
        let manifest = ShardManifest::load(&manifest_path)?.ok_or_else(|| {
            FastSurvivalError::Store(format!(
                "no shard manifest at {}",
                manifest_path.display()
            ))
        })?;
        let dir = manifest_path
            .parent()
            .map(Path::to_path_buf)
            .unwrap_or_else(|| PathBuf::from("."));

        let (n, p) = (manifest.n, manifest.p);
        let mut readers = Vec::with_capacity(manifest.shards.len());
        let mut time: Vec<f64> = Vec::with_capacity(n);
        let mut event: Vec<bool> = Vec::with_capacity(n);
        let mut schema: Option<(String, Vec<String>, Vec<f64>, Vec<f64>)> = None;
        for entry in &manifest.shards {
            let fpath = dir.join(&entry.file);
            let serr = |msg: String| {
                FastSurvivalError::Store(format!("shard {} ({}): {msg}", entry.seq, fpath.display()))
            };
            let mut file = File::open(&fpath)
                .map_err(|e| FastSurvivalError::io(format!("opening {}", fpath.display()), e))?;
            let file_len = file
                .metadata()
                .map_err(|e| FastSurvivalError::io(format!("stat {}", fpath.display()), e))?
                .len();
            let mut head = [0u8; HEADER_LEN];
            format::read_exact(&mut file, &mut head, "shard header")?;
            let header = StoreHeader::decode(&head)?;
            let checksum = fnv1a(&header.encode()[0..40]);
            if checksum != entry.checksum {
                return Err(serr(format!(
                    "header checksum {checksum:#018x} does not match the manifest's {:#018x}",
                    entry.checksum
                )));
            }
            if header.n != entry.rows {
                return Err(serr(format!(
                    "holds {} rows but the manifest records {}",
                    header.n, entry.rows
                )));
            }
            if header.p != p
                || header.chunk_rows != manifest.chunk_rows
                || header.precision != manifest.precision
            {
                return Err(serr(format!(
                    "geometry (p={}, chunk_rows={}, precision={}) disagrees with the \
                     manifest (p={p}, chunk_rows={}, precision={})",
                    header.p,
                    header.chunk_rows,
                    header.precision.name(),
                    manifest.chunk_rows,
                    manifest.precision.name()
                )));
            }
            if file_len != header.expected_file_len() {
                return Err(serr(format!(
                    "is {file_len} bytes but the header implies {} — truncated or corrupt",
                    header.expected_file_len()
                )));
            }

            // Meta block: every shard carries the same name, feature
            // names, and global standardization stats.
            let mut r = BufReader::new(&mut file);
            let name = format::read_string(&mut r, "dataset name")?;
            let n_names = format::read_u32(&mut r, "feature-name count")? as usize;
            if n_names != p {
                return Err(serr(format!(
                    "meta block names {n_names} features, manifest says {p}"
                )));
            }
            let mut feature_names = Vec::with_capacity(p);
            for _ in 0..p {
                feature_names.push(format::read_string(&mut r, "feature name")?);
            }
            let means = format::read_f64_vec(&mut r, p, "standardization means")?;
            let stds = format::read_f64_vec(&mut r, p, "standardization stds")?;
            let consumed = HEADER_LEN as u64
                + 8
                + name.len() as u64
                + feature_names.iter().map(|f| 4 + f.len() as u64).sum::<u64>()
                + 16 * p as u64;
            if consumed != header.payload_offset {
                return Err(serr(format!(
                    "meta block ends at {consumed} but payload starts at {} — corrupt meta",
                    header.payload_offset
                )));
            }
            if name != manifest.name {
                return Err(serr(format!(
                    "dataset name {name:?} disagrees with the manifest's {:?}",
                    manifest.name
                )));
            }
            match &schema {
                None => schema = Some((name, feature_names, means, stds)),
                Some((_, f0, m0, s0)) => {
                    if &feature_names != f0 || &means != m0 || &stds != s0 {
                        return Err(serr(
                            "feature schema or standardization stats disagree with shard 0"
                                .into(),
                        ));
                    }
                }
            }

            // Payload O(n) columns: validate and splice into the global
            // time/event vectors.
            if entry.row0 != time.len() {
                return Err(serr(format!(
                    "manifest places this shard at row {} but previous shards cover {} rows",
                    entry.row0,
                    time.len()
                )));
            }
            let stime = format::read_f64_vec(&mut r, header.n, "time column")?;
            for (k, &t) in stime.iter().enumerate() {
                if !t.is_finite() {
                    return Err(serr(format!("non-finite time {t} at shard row {k}")));
                }
                if k > 0 && t > stime[k - 1] {
                    return Err(serr(format!(
                        "times not sorted descending at shard row {k} ({} then {t})",
                        stime[k - 1]
                    )));
                }
            }
            if stime[0] != entry.t_first || stime[header.n - 1] != entry.t_last {
                return Err(serr(format!(
                    "payload time range {} .. {} disagrees with the manifest's {} .. {}",
                    stime[0],
                    stime[header.n - 1],
                    entry.t_first,
                    entry.t_last
                )));
            }
            let mut event_bytes = vec![0u8; header.n];
            format::read_exact(&mut r, &mut event_bytes, "event column")?;
            drop(r);
            for (k, &b) in event_bytes.iter().enumerate() {
                match b {
                    0 => event.push(false),
                    1 => event.push(true),
                    other => {
                        return Err(serr(format!("invalid event byte {other} at shard row {k}")))
                    }
                }
            }
            time.extend_from_slice(&stime);
            readers.push(ShardReader { file, header, path: fpath, row0: entry.row0 });
        }
        // validate() guaranteed strictly decreasing ranges across
        // shards and the per-shard payloads are descending, so the
        // concatenation is globally descending.
        let delta: Vec<f64> = event.iter().map(|&e| if e { 1.0 } else { 0.0 }).collect();
        let (groups, _group_of) = build_tie_groups(&time, &delta);
        let n_events = event.iter().filter(|&&e| e).count();

        // The per-column constants pass runs over the shards in order —
        // the same ascending-global-row floating-point sequence the
        // single-store open produces, so the results are bitwise equal.
        let mut pass = ColumnStatsPass::new(n, p, &groups);
        let mut iobuf: Vec<u8> = Vec::new();
        let mut chunk: Vec<f64> = Vec::new();
        for reader in &mut readers {
            for c in 0..reader.header.n_chunks() {
                let rows = reader.header.rows_in_chunk(c);
                chunk.clear();
                read_cells_append(
                    &mut reader.file,
                    &mut iobuf,
                    reader.header.col_segment_offset(c, 0),
                    rows * p,
                    reader.header.precision,
                    &mut chunk,
                )?;
                pass.process_chunk(&chunk, rows, reader.row0 + c * reader.header.chunk_rows, &delta);
            }
        }
        let (xt_delta, lipschitz, col_binary) = pass.finish();

        let (name, feature_names, means, stds) = schema.expect("manifest has at least one shard");
        let meta = StoreMeta {
            n,
            p,
            chunk_rows: manifest.chunk_rows,
            n_chunks: n.div_ceil(manifest.chunk_rows),
            name,
            feature_names,
            means,
            stds,
            time,
            delta,
            event,
            groups,
            n_events,
            xt_delta,
            lipschitz,
            col_binary,
        };
        Ok(ShardedDataset { manifest, readers, meta: Arc::new(meta), iobuf })
    }

    /// The validated manifest this dataset was opened from.
    pub fn manifest(&self) -> &ShardManifest {
        &self.manifest
    }

    /// An independent column reader over the same shard files (fresh
    /// file handles, so each fit worker gets its own seek position).
    pub(crate) fn col_reader(&self) -> Result<ShardColReader> {
        let mut shards = Vec::with_capacity(self.readers.len());
        for r in &self.readers {
            let file = File::open(&r.path)
                .map_err(|e| FastSurvivalError::io(format!("opening {}", r.path.display()), e))?;
            shards.push((file, r.header, r.row0));
        }
        Ok(ShardColReader { shards, bytebuf: Vec::new() })
    }
}

impl CoxData for ShardedDataset {
    fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    fn meta_arc(&self) -> Arc<StoreMeta> {
        Arc::clone(&self.meta)
    }

    fn load_chunk(&mut self, c: usize, buf: &mut Vec<f64>) -> Result<usize> {
        // Global chunk geometry: the window may straddle shard files,
        // in which case each column is assembled from the shards'
        // overlapping local ranges in order.
        let g0 = c * self.meta.chunk_rows;
        let g1 = self.meta.n.min(g0 + self.meta.chunk_rows);
        let rows = g1 - g0;
        buf.clear();
        buf.reserve(rows * self.meta.p);
        let iobuf = &mut self.iobuf;
        for j in 0..self.meta.p {
            for r in self.readers.iter_mut() {
                let s_end = r.row0 + r.header.n;
                if g1 <= r.row0 || g0 >= s_end {
                    continue;
                }
                let la = g0.max(r.row0) - r.row0;
                let lb = g1.min(s_end) - r.row0;
                read_local_col_range(&mut r.file, &r.header, j, la, lb, iobuf, buf)?;
            }
        }
        Ok(rows)
    }

    fn load_col(&mut self, l: usize, buf: &mut Vec<f64>) -> Result<()> {
        buf.clear();
        buf.reserve(self.meta.n);
        let iobuf = &mut self.iobuf;
        for r in self.readers.iter_mut() {
            read_local_col_range(&mut r.file, &r.header, l, 0, r.header.n, iobuf, buf)?;
        }
        Ok(())
    }
}

/// A standalone shard-set column reader: global-row range reads over
/// fresh file handles. Each sharded-fit worker owns one, scanning only
/// its tile range's rows.
pub(crate) struct ShardColReader {
    /// `(file, header, row0)` per shard, in sequence order.
    shards: Vec<(File, StoreHeader, usize)>,
    bytebuf: Vec<u8>,
}

impl ShardColReader {
    /// Read global sorted rows `[a, b)` of column `l` into `out`
    /// (cleared first).
    pub(crate) fn read_col_range(
        &mut self,
        l: usize,
        a: usize,
        b: usize,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        out.clear();
        out.reserve(b.saturating_sub(a));
        for (file, header, row0) in self.shards.iter_mut() {
            let s_end = *row0 + header.n;
            if b <= *row0 || a >= s_end {
                continue;
            }
            let la = a.max(*row0) - *row0;
            let lb = b.min(s_end) - *row0;
            read_local_col_range(file, header, l, la, lb, &mut self.bytebuf, out)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::data::SurvivalDataset;
    use crate::linalg::Matrix;
    use crate::store::dataset::ChunkedDataset;
    use crate::store::writer::{write_store_with, DatasetRows};

    fn temp_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fs_store_shard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tied_dataset(n: usize, p: usize, group: usize) -> SurvivalDataset {
        // Deterministic features, times tied in runs of `group` rows.
        let cols: Vec<Vec<f64>> = (0..p)
            .map(|j| (0..n).map(|i| ((i * 31 + j * 7) % 11) as f64 - 5.0).collect())
            .collect();
        let time: Vec<f64> = (0..n).map(|i| (i / group) as f64).collect();
        let event: Vec<bool> = (0..n).map(|i| i % 3 != 0).collect();
        SurvivalDataset::new(Matrix::from_columns(&cols), time, event, "ties")
    }

    #[test]
    fn sharded_store_matches_single_store_bitwise() {
        let dir = temp_dir();
        let ds = generate(&SyntheticConfig { n: 203, p: 4, rho: 0.3, k: 2, s: 0.1, seed: 17 });
        let single = dir.join("single.fsds");
        let sharded = dir.join("sharded.fsds");
        let mut rows = DatasetRows::new(&ds);
        write_store_with(&mut rows, &single, 16, "t", Precision::F64).unwrap();
        let mut rows = DatasetRows::new(&ds);
        let summary =
            write_sharded_store(&mut rows, &sharded, 16, "t", Precision::F64, 3).unwrap();
        assert_eq!(summary.n, 203);
        assert!(summary.n_shards >= 2 && summary.n_shards <= 3);
        assert_eq!(summary.generation, 0);

        let mut one = ChunkedDataset::open(&single).unwrap();
        let mut many = ShardedDataset::open(&sharded).unwrap();
        // Derived metadata is bitwise identical.
        assert_eq!(many.meta().time, one.meta().time);
        assert_eq!(many.meta().event, one.meta().event);
        assert_eq!(many.meta().groups, one.meta().groups);
        assert_eq!(many.meta().xt_delta, one.meta().xt_delta);
        assert_eq!(many.meta().lipschitz, one.meta().lipschitz);
        assert_eq!(many.meta().col_binary, one.meta().col_binary);
        assert_eq!(many.meta().means, one.meta().means);
        assert_eq!((many.meta().n_chunks, many.meta().chunk_rows), (13, 16));
        // Column and global-chunk reads agree even where a global chunk
        // straddles shard files.
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for l in 0..4 {
            one.load_col(l, &mut a).unwrap();
            many.load_col(l, &mut b).unwrap();
            assert_eq!(a, b, "column {l}");
        }
        for c in 0..13 {
            let ra = one.load_chunk(c, &mut a).unwrap();
            let rb = many.load_chunk(c, &mut b).unwrap();
            assert_eq!((ra, &a), (rb, &b), "global chunk {c}");
        }
        // Range reads compose the same columns.
        let mut reader = many.col_reader().unwrap();
        let mut piece = Vec::new();
        one.load_col(2, &mut a).unwrap();
        reader.read_col_range(2, 50, 160, &mut piece).unwrap();
        assert_eq!(piece, a[50..160]);
    }

    #[test]
    fn tie_groups_never_straddle_shards() {
        let dir = temp_dir();
        let ds = tied_dataset(90, 3, 7);
        let out = dir.join("tied.fsds");
        let mut rows = DatasetRows::new(&ds);
        let summary = write_sharded_store(&mut rows, &out, 8, "ties", Precision::F64, 4).unwrap();
        let manifest = ShardManifest::load(&summary.manifest_path).unwrap().unwrap();
        assert!(manifest.shards.len() >= 2);
        for w in manifest.shards.windows(2) {
            assert!(
                w[0].t_last > w[1].t_first,
                "boundary must be a strict time decrease: {} then {}",
                w[0].t_last,
                w[1].t_first
            );
        }
        // And the assembled dataset still matches a single store.
        let single = dir.join("tied_single.fsds");
        let mut rows = DatasetRows::new(&ds);
        write_store_with(&mut rows, &single, 8, "ties", Precision::F64).unwrap();
        let one = ChunkedDataset::open(&single).unwrap();
        let many = ShardedDataset::open(&out).unwrap();
        assert_eq!(many.meta().groups, one.meta().groups);
        assert_eq!(many.meta().xt_delta, one.meta().xt_delta);
    }

    #[test]
    fn rewrite_bumps_generation_and_crash_leftovers_are_harmless() {
        let dir = temp_dir();
        let ds = generate(&SyntheticConfig { n: 60, p: 3, rho: 0.2, k: 2, s: 0.1, seed: 3 });
        let out = dir.join("regen.fsds");
        let mut rows = DatasetRows::new(&ds);
        write_sharded_store(&mut rows, &out, 16, "g", Precision::F64, 2).unwrap();
        let before = ShardedDataset::open(&out).unwrap().meta_arc();

        // Simulate a crash mid-rewrite: a next-generation partial and a
        // stray completed next-generation shard, manifest untouched.
        let stray = shard_file_path(&out, 1, 0);
        std::fs::write(&stray, b"incomplete next generation shard").unwrap();
        let partial = PathBuf::from(format!(
            "{}.partial.tmp",
            shard_file_path(&out, 1, 1).display()
        ));
        std::fs::write(&partial, b"torn write").unwrap();
        let after = ShardedDataset::open(&out).unwrap();
        assert_eq!(after.manifest().generation, 0);
        assert_eq!(after.meta().time, before.time);
        std::fs::remove_file(&stray).unwrap();
        std::fs::remove_file(&partial).unwrap();

        // A completed rewrite flips to generation 1 atomically.
        let mut rows = DatasetRows::new(&ds);
        let summary = write_sharded_store(&mut rows, &out, 16, "g", Precision::F64, 2).unwrap();
        assert_eq!(summary.generation, 1);
        let after = ShardedDataset::open(&out).unwrap();
        assert_eq!(after.manifest().generation, 1);
        assert_eq!(after.meta().time, before.time);
    }

    #[test]
    fn invalid_manifests_are_typed_errors() {
        let dir = temp_dir();
        let ds = generate(&SyntheticConfig { n: 50, p: 2, rho: 0.2, k: 1, s: 0.1, seed: 9 });
        let out = dir.join("invalid.fsds");
        let mut rows = DatasetRows::new(&ds);
        let summary = write_sharded_store(&mut rows, &out, 16, "v", Precision::F64, 2).unwrap();
        let good = ShardManifest::load(&summary.manifest_path).unwrap().unwrap();

        // Overlapping time ranges (reversed boundary) are rejected.
        let mut bad = good.clone();
        let hi = bad.shards[0].t_first;
        bad.shards[1].t_first = hi + 1.0;
        bad.save(&summary.manifest_path).unwrap();
        let err = ShardManifest::load(&summary.manifest_path).unwrap_err();
        assert!(matches!(err, FastSurvivalError::Store(_)));
        assert!(err.to_string().contains("overlapping"), "got: {err}");

        // An exactly-shared boundary time means a straddling tie group.
        let mut bad = good.clone();
        bad.shards[1].t_first = bad.shards[0].t_last;
        bad.save(&summary.manifest_path).unwrap();
        let err = ShardManifest::load(&summary.manifest_path).unwrap_err();
        assert!(err.to_string().contains("tie group"), "got: {err}");

        // Row-count drift is rejected.
        let mut bad = good.clone();
        bad.shards[1].rows += 1;
        bad.n += 1;
        bad.save(&summary.manifest_path).unwrap();
        assert!(ShardedDataset::open(&out).is_err());

        // Restore and confirm the happy path still opens.
        good.save(&summary.manifest_path).unwrap();
        ShardedDataset::open(&out).unwrap();

        // Missing manifest: load says none, open is a typed error.
        let missing = dir.join("never_written.fsds");
        assert!(ShardManifest::load(&shard_manifest_path(&missing)).unwrap().is_none());
        assert!(matches!(
            ShardedDataset::open(&missing),
            Err(FastSurvivalError::Store(_))
        ));
    }

    #[test]
    fn shard_cuts_snap_to_group_ends() {
        // Groups of 7 over 60 rows; targets 15/30/45 snap to 14/28/42.
        let time: Vec<f64> = (0..60).map(|i| -((i / 7) as f64)).collect();
        let delta = vec![1.0; 60];
        let (groups, _) = build_tie_groups(&time, &delta);
        assert_eq!(shard_cuts(&groups, 60, 4), vec![0, 14, 28, 42, 60]);
        // One giant tie group cannot be cut at all.
        let (one, _) = build_tie_groups(&[5.0; 40], &[1.0; 40]);
        assert_eq!(shard_cuts(&one, 40, 4), vec![0, 40]);
        // shards=1 is the identity split.
        assert_eq!(shard_cuts(&groups, 60, 1), vec![0, 60]);
    }
}
