//! The `.fsds` on-disk columnar dataset format.
//!
//! Layout (all integers and floats little-endian):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"FSDS"
//! 4       4     format version (u32): 1 = f64 feature cells,
//!               2 = f32 feature cells (mixed-precision storage)
//! 8       8     n   — number of samples (u64)
//! 16      8     p   — number of feature columns (u64)
//! 24      8     chunk_rows — rows per feature chunk (u64)
//! 32      8     payload_offset — absolute offset of time[] (u64)
//! 40      8     FNV-1a checksum of bytes 0..40 (u64)
//! 48      ..    meta block: dataset name (u32 len + utf8),
//!               feature names (u32 count, then u32 len + utf8 each),
//!               one-pass standardization stats: means[p], stds[p]
//! payload_offset:
//!               time[n]  f64, sorted descending (CoxProblem order)
//!               event[n] u8 (1 = failure observed, 0 = censored)
//!               feature chunks: for chunk c covering sorted rows
//!               [c·chunk_rows, min(n, (c+1)·chunk_rows)), each column's
//!               segment stored contiguously (column-major within the
//!               chunk) — so one column of one chunk is a single
//!               contiguous read, and a full-column scan over all chunks
//!               costs exactly n·cell_bytes of I/O (8 for version 1,
//!               4 for version 2).
//! ```
//!
//! Version 2 stores feature cells as f32 (times stay f64, events u8, and
//! every meta field stays f64): half the payload bytes and half the
//! column-scan bandwidth. Readers widen each cell to f64 on decode, so
//! all accumulation stays f64 — a v2 fit agrees with its v1 twin to the
//! storage quantization (≤1e-6 per coefficient). Version 1 files are
//! byte-identical to every prior release and remain the default.
//!
//! Rows are pre-sorted by the writer with the engine's canonical
//! [`crate::cox::problem::descending_time_order`], so risk sets are
//! prefixes of the on-disk order and the chunked reader can run the
//! exact risk-set recurrences without ever materializing the matrix.
//!
//! Every malformed-file condition (bad magic, unsupported version,
//! checksum mismatch, truncation, unsorted times) is a typed
//! [`FastSurvivalError::Store`].

use crate::error::{FastSurvivalError, Result};
use crate::util::compute::Precision;
use std::io::Read;

/// File magic.
pub const MAGIC: [u8; 4] = *b"FSDS";
/// Format version for f64 feature cells (the default; byte-identical to
/// every prior release).
pub const FORMAT_VERSION: u32 = 1;
/// Format version for f32 feature cells (mixed-precision storage).
pub const FORMAT_VERSION_F32: u32 = 2;
/// Fixed header length in bytes (before the meta block).
pub const HEADER_LEN: usize = 48;
/// Default rows per feature chunk: 8192 × p doubles per chunk keeps the
/// working buffer in the low megabytes for p in the hundreds while
/// amortizing per-chunk seek overhead.
pub const DEFAULT_CHUNK_ROWS: usize = 8192;
/// Cap on any length field read from a header (names, counts) so a
/// corrupt file cannot request a multi-gigabyte allocation.
const MAX_META_LEN: u64 = 1 << 24;

/// FNV-1a 64-bit hash — the header self-check.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The decoded fixed header: store geometry plus payload location.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StoreHeader {
    pub n: usize,
    pub p: usize,
    pub chunk_rows: usize,
    /// Absolute offset where `time[]` starts (end of the meta block).
    pub payload_offset: u64,
    /// Feature-cell storage precision, carried by the format version:
    /// version 1 ⇔ [`Precision::F64`], version 2 ⇔
    /// [`Precision::F32Storage`].
    pub precision: Precision,
}

impl StoreHeader {
    /// Bytes per feature cell (8 for v1/f64, 4 for v2/f32).
    pub fn cell_bytes(&self) -> u64 {
        match self.precision {
            Precision::F64 => 8,
            Precision::F32Storage => 4,
        }
    }
    /// Number of feature chunks.
    pub fn n_chunks(&self) -> usize {
        if self.n == 0 {
            0
        } else {
            self.n.div_ceil(self.chunk_rows)
        }
    }

    /// Rows in chunk `c` (only the last chunk may be short).
    pub fn rows_in_chunk(&self, c: usize) -> usize {
        let start = c * self.chunk_rows;
        self.chunk_rows.min(self.n.saturating_sub(start))
    }

    /// Absolute offset where the feature chunks start.
    pub fn chunk_base(&self) -> u64 {
        // time[n] f64 + event[n] u8.
        self.payload_offset + self.n as u64 * 8 + self.n as u64
    }

    /// Absolute offset of column `j`'s segment within chunk `c`. All
    /// chunks before `c` are full (`chunk_rows` rows), so the prefix is
    /// exactly `c · chunk_rows · p` doubles.
    pub fn col_segment_offset(&self, c: usize, j: usize) -> u64 {
        debug_assert!(c < self.n_chunks() && j < self.p);
        let prefix = (c as u64) * (self.chunk_rows as u64) * (self.p as u64);
        let within = (j as u64) * (self.rows_in_chunk(c) as u64);
        self.chunk_base() + self.cell_bytes() * (prefix + within)
    }

    /// Total file length this header implies.
    pub fn expected_file_len(&self) -> u64 {
        self.chunk_base() + self.cell_bytes() * (self.n as u64) * (self.p as u64)
    }

    /// Encode the fixed header (checksum included).
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let version = match self.precision {
            Precision::F64 => FORMAT_VERSION,
            Precision::F32Storage => FORMAT_VERSION_F32,
        };
        let mut buf = [0u8; HEADER_LEN];
        buf[0..4].copy_from_slice(&MAGIC);
        buf[4..8].copy_from_slice(&version.to_le_bytes());
        buf[8..16].copy_from_slice(&(self.n as u64).to_le_bytes());
        buf[16..24].copy_from_slice(&(self.p as u64).to_le_bytes());
        buf[24..32].copy_from_slice(&(self.chunk_rows as u64).to_le_bytes());
        buf[32..40].copy_from_slice(&self.payload_offset.to_le_bytes());
        let crc = fnv1a(&buf[0..40]);
        buf[40..48].copy_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Decode and validate a fixed header.
    pub fn decode(buf: &[u8]) -> Result<StoreHeader> {
        if buf.len() < HEADER_LEN {
            return Err(FastSurvivalError::Store(format!(
                "truncated header: {} bytes, need {HEADER_LEN}",
                buf.len()
            )));
        }
        if buf[0..4] != MAGIC {
            return Err(FastSurvivalError::Store(format!(
                "bad magic {:?} (not an .fsds store)",
                &buf[0..4]
            )));
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        let precision = match version {
            FORMAT_VERSION => Precision::F64,
            FORMAT_VERSION_F32 => Precision::F32Storage,
            _ => {
                return Err(FastSurvivalError::Store(format!(
                    "unsupported store format version {version} (this build reads \
                     {FORMAT_VERSION} and {FORMAT_VERSION_F32})"
                )))
            }
        };
        let crc_stored = u64::from_le_bytes(buf[40..48].try_into().unwrap());
        let crc = fnv1a(&buf[0..40]);
        if crc != crc_stored {
            return Err(FastSurvivalError::Store(format!(
                "header checksum mismatch (stored {crc_stored:#018x}, computed {crc:#018x}) — \
                 corrupt file"
            )));
        }
        let n = u64::from_le_bytes(buf[8..16].try_into().unwrap());
        let p = u64::from_le_bytes(buf[16..24].try_into().unwrap());
        let chunk_rows = u64::from_le_bytes(buf[24..32].try_into().unwrap());
        let payload_offset = u64::from_le_bytes(buf[32..40].try_into().unwrap());
        if n == 0 || p == 0 || chunk_rows == 0 {
            return Err(FastSurvivalError::Store(format!(
                "degenerate store geometry (n={n}, p={p}, chunk_rows={chunk_rows})"
            )));
        }
        if payload_offset < HEADER_LEN as u64 {
            return Err(FastSurvivalError::Store(format!(
                "payload offset {payload_offset} overlaps the header"
            )));
        }
        // Hostile-geometry guard: the FNV self-check is trivially
        // recomputable, so a crafted header can carry any n/p/chunk_rows.
        // Cap each dimension and the cell count so every downstream
        // offset/length computation (chunk_base, col_segment_offset,
        // expected_file_len, `vec![0u8; n*8]` reads) is provably far from
        // u64/usize overflow — a bad header must stay a typed Store
        // error, never a wrapped multiplication or an absurd allocation.
        const MAX_DIM: u64 = 1 << 48;
        const MAX_CELLS: u64 = 1 << 53;
        if n > MAX_DIM || p > MAX_DIM || chunk_rows > MAX_DIM || payload_offset > MAX_DIM {
            return Err(FastSurvivalError::Store(format!(
                "implausible store geometry (n={n}, p={p}, chunk_rows={chunk_rows}, \
                 payload_offset={payload_offset}) — corrupt header"
            )));
        }
        match n.checked_mul(p) {
            Some(cells) if cells <= MAX_CELLS => {}
            _ => {
                return Err(FastSurvivalError::Store(format!(
                    "implausible store size n×p = {n}×{p} — corrupt header"
                )))
            }
        }
        Ok(StoreHeader {
            n: n as usize,
            p: p as usize,
            chunk_rows: chunk_rows as usize,
            payload_offset,
            precision,
        })
    }
}

// ------------------------------------------------------- read helpers

pub(crate) fn read_exact(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FastSurvivalError::Store(format!("truncated store while reading {what}"))
        } else {
            FastSurvivalError::io(format!("reading store {what}"), e)
        }
    })
}

pub(crate) fn read_u32(r: &mut impl Read, what: &str) -> Result<u32> {
    let mut b = [0u8; 4];
    read_exact(r, &mut b, what)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn read_f64_vec(r: &mut impl Read, len: usize, what: &str) -> Result<Vec<f64>> {
    let mut bytes = vec![0u8; len * 8];
    read_exact(r, &mut bytes, what)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub(crate) fn read_string(r: &mut impl Read, what: &str) -> Result<String> {
    let len = read_u32(r, what)? as u64;
    if len > MAX_META_LEN {
        return Err(FastSurvivalError::Store(format!(
            "implausible {what} length {len} — corrupt meta block"
        )));
    }
    let mut bytes = vec![0u8; len as usize];
    read_exact(r, &mut bytes, what)?;
    String::from_utf8(bytes)
        .map_err(|_| FastSurvivalError::Store(format!("{what} is not valid UTF-8")))
}

// ------------------------------------------------------ write helpers

pub(crate) fn push_string(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

pub(crate) fn push_f64_slice(out: &mut Vec<u8>, vs: &[f64]) {
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode the meta block: dataset name, feature names, streaming
/// standardization stats. Its length fixes `payload_offset`.
pub(crate) fn encode_meta(
    name: &str,
    feature_names: &[String],
    means: &[f64],
    stds: &[f64],
) -> Vec<u8> {
    let mut out = Vec::new();
    push_string(&mut out, name);
    out.extend_from_slice(&(feature_names.len() as u32).to_le_bytes());
    for fname in feature_names {
        push_string(&mut out, fname);
    }
    push_f64_slice(&mut out, means);
    push_f64_slice(&mut out, stds);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header(n: usize, p: usize, chunk_rows: usize, payload_offset: u64) -> StoreHeader {
        StoreHeader { n, p, chunk_rows, payload_offset, precision: Precision::F64 }
    }

    #[test]
    fn header_round_trips() {
        let h = header(1_000_003, 117, 8192, 321);
        let enc = h.encode();
        assert_eq!(StoreHeader::decode(&enc).unwrap(), h);
        // v2 (f32 cells) round-trips and is distinguished by version.
        let h32 = StoreHeader { precision: Precision::F32Storage, ..h };
        let enc32 = h32.encode();
        assert_eq!(enc32[4], 2, "f32 stores carry format version 2");
        assert_eq!(StoreHeader::decode(&enc32).unwrap(), h32);
        assert_ne!(enc[4..8], enc32[4..8]);
    }

    #[test]
    fn geometry_arithmetic() {
        let h = header(20, 3, 8, 100);
        assert_eq!(h.n_chunks(), 3);
        assert_eq!(h.rows_in_chunk(0), 8);
        assert_eq!(h.rows_in_chunk(2), 4);
        assert_eq!(h.chunk_base(), 100 + 20 * 8 + 20);
        // Chunk 1, column 2: one full chunk before (8·3 doubles), then
        // two 8-row columns within.
        assert_eq!(h.col_segment_offset(1, 2), h.chunk_base() + 8 * (8 * 3 + 2 * 8));
        // Last chunk's columns are 4 rows wide.
        assert_eq!(h.col_segment_offset(2, 1), h.chunk_base() + 8 * (16 * 3 + 4));
        assert_eq!(h.expected_file_len(), h.chunk_base() + 8 * 60);
    }

    #[test]
    fn f32_geometry_uses_four_byte_cells() {
        let h = StoreHeader {
            n: 20,
            p: 3,
            chunk_rows: 8,
            payload_offset: 100,
            precision: Precision::F32Storage,
        };
        assert_eq!(h.cell_bytes(), 4);
        // The O(n) payload (time f64 + event u8) is unchanged; only the
        // feature cells shrink.
        assert_eq!(h.chunk_base(), 100 + 20 * 8 + 20);
        assert_eq!(h.col_segment_offset(1, 2), h.chunk_base() + 4 * (8 * 3 + 2 * 8));
        assert_eq!(h.expected_file_len(), h.chunk_base() + 4 * 60);
    }

    #[test]
    fn corrupt_headers_are_typed_errors() {
        use crate::error::FastSurvivalError;
        let h = header(5, 2, 4, 64);
        let good = h.encode();
        // Wrong magic.
        let mut bad = good;
        bad[0] = b'X';
        assert!(matches!(StoreHeader::decode(&bad), Err(FastSurvivalError::Store(_))));
        // Future version.
        let mut bad = good;
        bad[4] = 99;
        assert!(matches!(StoreHeader::decode(&bad), Err(FastSurvivalError::Store(_))));
        // Flipped bit in n: checksum catches it.
        let mut bad = good;
        bad[9] ^= 0x40;
        let err = StoreHeader::decode(&bad).unwrap_err();
        assert!(err.to_string().contains("checksum"), "got: {err}");
        // Truncated.
        assert!(matches!(
            StoreHeader::decode(&good[..20]),
            Err(FastSurvivalError::Store(_))
        ));
    }

    #[test]
    fn hostile_geometry_is_a_typed_error_not_an_overflow() {
        use crate::error::FastSurvivalError;
        // A crafted header can always carry a valid FNV self-check; the
        // geometry caps must still reject it before any offset math.
        for h in [
            header(1 << 60, 2, 8, 64),
            header(1 << 30, 1 << 30, 8, 64),
            header(8, 2, 1 << 60, 64),
        ] {
            let enc = h.encode();
            assert!(
                matches!(StoreHeader::decode(&enc), Err(FastSurvivalError::Store(_))),
                "geometry {h:?} must be rejected"
            );
        }
    }

    #[test]
    fn meta_block_encoding_is_length_stable() {
        let m = encode_meta("ds", &["a".into(), "bb".into()], &[0.0, 1.0], &[1.0, 2.0]);
        // name(4+2) + count(4) + names(4+1 + 4+2) + 2·2·8 doubles.
        assert_eq!(m.len(), 6 + 4 + 5 + 6 + 32);
    }
}
