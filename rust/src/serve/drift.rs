//! Score-distribution drift counters: each served model's online risk
//! scores are bucketed into a signed-log₂ histogram and compared
//! against a stored *training reference* (the score distribution the
//! model saw at fit time, published as a `<name>@<version>.drift`
//! sidecar next to the artifact — a non-`.json` extension, so the
//! registry scan never mistakes it for a model).
//!
//! Two summary numbers are exported through `GET /metrics`:
//!
//! * **total-variation distance** `½·Σ|p̂ᵢ − q̂ᵢ|` between the online
//!   and reference bucket frequencies — 0 for identical distributions,
//!   1 for disjoint support; and
//! * **online concordance** `P(online > ref) + ½·P(same bucket)` — a
//!   bucket-level Mann–Whitney statistic; 0.5 means no shift, above
//!   0.5 the live population scores *higher* than training, below it
//!   lower. Direction is what TVD can't tell you.
//!
//! The hot path is one `fetch_add` per scored row; summaries are
//! derived at `/metrics` render time from the bucket counts.

use crate::api::json;
use crate::error::{FastSurvivalError, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Histogram width: 32 magnitude buckets per sign, log₂|v| clamped to
/// [−16, 16). Bucket index is monotone in the score value.
pub const N_DRIFT_BUCKETS: usize = 64;

/// Sidecar schema version.
const DRIFT_VERSION: u64 = 1;

/// Map a score to its bucket. Risk scores are positive finite in
/// practice; zeros, negatives, and non-finite values still land in
/// well-defined buckets so hostile inputs can't panic the tracker.
pub fn bucket_of_score(v: f64) -> usize {
    if v.is_nan() || v == 0.0 {
        return N_DRIFT_BUCKETS / 2;
    }
    if v == f64::INFINITY {
        return N_DRIFT_BUCKETS - 1;
    }
    if v == f64::NEG_INFINITY {
        return 0;
    }
    let mag = (v.abs().log2().floor() as i64 + 16).clamp(0, 31) as usize;
    if v > 0.0 {
        32 + mag
    } else {
        31 - mag
    }
}

/// A stored training-score histogram — the drift comparison baseline.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftReference {
    pub counts: Vec<u64>,
}

impl DriftReference {
    /// Histogram a batch of training scores.
    pub fn from_scores(scores: &[f64]) -> DriftReference {
        let mut counts = vec![0u64; N_DRIFT_BUCKETS];
        for &s in scores {
            counts[bucket_of_score(s)] += 1;
        }
        DriftReference { counts }
    }

    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256);
        out.push_str("{\"drift_version\": ");
        out.push_str(&DRIFT_VERSION.to_string());
        out.push_str(", \"buckets\": ");
        out.push_str(&N_DRIFT_BUCKETS.to_string());
        out.push_str(", \"counts\": [");
        for (i, c) in self.counts.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&c.to_string());
        }
        out.push_str("]}");
        out
    }

    /// Atomic write (temp file + rename) of the sidecar.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("drift.partial.tmp");
        std::fs::write(&tmp, self.to_json())
            .map_err(|e| FastSurvivalError::io(format!("writing drift sidecar {tmp:?}"), e))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| FastSurvivalError::io(format!("publishing drift sidecar {path:?}"), e))
    }

    pub fn load(path: &Path) -> Result<DriftReference> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| FastSurvivalError::io(format!("reading drift sidecar {path:?}"), e))?;
        let doc = json::parse(&text)?;
        let version = doc.require("drift_version")?.as_usize()?;
        if version as u64 != DRIFT_VERSION {
            return Err(FastSurvivalError::Serve(format!(
                "drift sidecar {path:?}: unsupported drift_version {version}"
            )));
        }
        let buckets = doc.require("buckets")?.as_usize()?;
        if buckets != N_DRIFT_BUCKETS {
            return Err(FastSurvivalError::Serve(format!(
                "drift sidecar {path:?}: {buckets} buckets, expected {N_DRIFT_BUCKETS}"
            )));
        }
        let raw = doc.require("counts")?.as_array()?;
        if raw.len() != N_DRIFT_BUCKETS {
            return Err(FastSurvivalError::Serve(format!(
                "drift sidecar {path:?}: counts has {} entries, expected {N_DRIFT_BUCKETS}",
                raw.len()
            )));
        }
        let mut counts = Vec::with_capacity(N_DRIFT_BUCKETS);
        for v in raw {
            counts.push(v.as_usize()? as u64);
        }
        Ok(DriftReference { counts })
    }
}

/// Per-model online histogram plus its (optional) training reference.
pub struct DriftTracker {
    online: Vec<AtomicU64>,
    total: AtomicU64,
    reference: Option<DriftReference>,
}

impl DriftTracker {
    pub fn new(reference: Option<DriftReference>) -> DriftTracker {
        DriftTracker {
            online: (0..N_DRIFT_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            reference,
        }
    }

    pub fn has_reference(&self) -> bool {
        self.reference.is_some()
    }

    pub fn record_all(&self, scores: &[f64]) {
        for &s in scores {
            self.online[bucket_of_score(s)].fetch_add(1, Ordering::Relaxed);
        }
        self.total.fetch_add(scores.len() as u64, Ordering::Relaxed);
    }

    pub fn samples(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    fn online_counts(&self) -> Vec<u64> {
        self.online.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Total-variation distance between online and reference bucket
    /// frequencies (`None` without a reference or without samples).
    pub fn tvd(&self) -> Option<f64> {
        let reference = self.reference.as_ref()?;
        let online = self.online_counts();
        let (on, rn) = (online.iter().sum::<u64>(), reference.counts.iter().sum::<u64>());
        if on == 0 || rn == 0 {
            return None;
        }
        let mut tvd = 0.0;
        for (o, r) in online.iter().zip(reference.counts.iter()) {
            tvd += (*o as f64 / on as f64 - *r as f64 / rn as f64).abs();
        }
        Some(0.5 * tvd)
    }

    /// Bucket-level online concordance `P(online > ref) + ½·P(tie)` —
    /// 0.5 means the live score distribution sits where training did.
    pub fn concordance(&self) -> Option<f64> {
        let reference = self.reference.as_ref()?;
        let online = self.online_counts();
        let (on, rn) = (online.iter().sum::<u64>(), reference.counts.iter().sum::<u64>());
        if on == 0 || rn == 0 {
            return None;
        }
        // Prefix sums over the reference: buckets are monotone in value,
        // so "online sample beats reference sample" is "lower ref bucket".
        let mut below = 0.0_f64; // ref mass strictly below bucket i
        let mut conc = 0.0_f64;
        for (i, &o) in online.iter().enumerate() {
            let tie = reference.counts[i] as f64;
            conc += o as f64 * (below + 0.5 * tie);
            below += tie;
        }
        Some(conc / (on as f64 * rn as f64))
    }

    /// One model's drift block in the `/metrics` document.
    fn write_json(&self, out: &mut String) {
        out.push_str("{\"samples\": ");
        out.push_str(&self.samples().to_string());
        out.push_str(", \"reference\": ");
        out.push_str(if self.has_reference() { "true" } else { "false" });
        out.push_str(", \"tvd\": ");
        match self.tvd() {
            Some(v) => json::write_f64(out, v),
            None => out.push_str("null"),
        }
        out.push_str(", \"concordance\": ");
        match self.concordance() {
            Some(v) => json::write_f64(out, v),
            None => out.push_str("null"),
        }
        out.push('}');
    }
}

/// All drift trackers for one server, keyed by `name@version`. Lives on
/// the server handle — *not* inside the hot-swapped registry state —
/// so counters survive `/v1/reload`.
pub struct DriftRegistry {
    root: PathBuf,
    trackers: Mutex<BTreeMap<String, Arc<DriftTracker>>>,
}

impl DriftRegistry {
    /// `root` is the artifact directory; sidecars are looked up as
    /// `<root>/<name>@<version>.drift`.
    pub fn new(root: impl AsRef<Path>) -> DriftRegistry {
        DriftRegistry {
            root: root.as_ref().to_path_buf(),
            trackers: Mutex::new(BTreeMap::new()),
        }
    }

    /// Sidecar path for a model spec.
    pub fn sidecar_path(root: &Path, spec: &str) -> PathBuf {
        root.join(format!("{spec}.drift"))
    }

    /// The tracker for `spec`, created on first use (loading the
    /// sidecar if one exists; a corrupt sidecar just means no
    /// reference — scoring must never fail on metrics plumbing).
    pub fn tracker(&self, spec: &str) -> Arc<DriftTracker> {
        let mut map = self.trackers.lock().unwrap();
        if let Some(t) = map.get(spec) {
            return Arc::clone(t);
        }
        let side = DriftRegistry::sidecar_path(&self.root, spec);
        let reference = if side.is_file() { DriftReference::load(&side).ok() } else { None };
        let t = Arc::new(DriftTracker::new(reference));
        map.insert(spec.to_string(), Arc::clone(&t));
        t
    }

    /// The `"drift"` object for the `/metrics` document.
    pub fn write_json(&self, out: &mut String) {
        let map = self.trackers.lock().unwrap();
        out.push('{');
        for (i, (spec, t)) in map.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            json::write_str(out, spec);
            out.push_str(": ");
            t.write_json(out);
        }
        out.push('}');
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_in_value() {
        let values = [
            f64::NEG_INFINITY,
            -1e9,
            -2.0,
            -0.004,
            0.0,
            3e-4,
            0.5,
            1.0,
            7.0,
            1e8,
            f64::INFINITY,
        ];
        let buckets: Vec<usize> = values.iter().map(|&v| bucket_of_score(v)).collect();
        for w in buckets.windows(2) {
            assert!(w[0] <= w[1], "buckets must be monotone: {buckets:?}");
        }
        assert!(bucket_of_score(f64::NAN) < N_DRIFT_BUCKETS);
    }

    #[test]
    fn reference_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join(format!("fs_drift_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m@1.drift");
        let r = DriftReference::from_scores(&[0.1, 0.5, 1.0, 2.0, 2.0, 8.0]);
        r.save(&path).unwrap();
        assert_eq!(DriftReference::load(&path).unwrap(), r);
        // Corruption is a typed error, not a panic.
        std::fs::write(&path, "{\"drift_version\": 99}").unwrap();
        assert!(DriftReference::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn identical_distributions_read_as_no_drift() {
        let scores: Vec<f64> = (1..200).map(|i| 0.05 * i as f64).collect();
        let t = DriftTracker::new(Some(DriftReference::from_scores(&scores)));
        assert_eq!(t.tvd(), None, "no online samples yet");
        t.record_all(&scores);
        assert_eq!(t.samples(), scores.len() as u64);
        assert!(t.tvd().unwrap() < 1e-12);
        assert!((t.concordance().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shifted_scores_move_both_statistics() {
        let train: Vec<f64> = (1..500).map(|i| 0.01 * i as f64).collect();
        let t = DriftTracker::new(Some(DriftReference::from_scores(&train)));
        // Live scores 32× larger: 5 buckets to the right.
        let live: Vec<f64> = train.iter().map(|v| v * 32.0).collect();
        t.record_all(&live);
        assert!(t.tvd().unwrap() > 0.5, "tvd {:?}", t.tvd());
        assert!(t.concordance().unwrap() > 0.9, "conc {:?}", t.concordance());
    }

    #[test]
    fn registry_is_lazy_and_survives_missing_sidecars() {
        let dir = std::env::temp_dir().join(format!("fs_driftreg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        DriftReference::from_scores(&[1.0, 2.0])
            .save(&DriftRegistry::sidecar_path(&dir, "m@1"))
            .unwrap();
        let reg = DriftRegistry::new(&dir);
        assert!(reg.tracker("m@1").has_reference());
        assert!(!reg.tracker("m@2").has_reference(), "no sidecar → no reference");
        // Same Arc on repeat lookups.
        let a = reg.tracker("m@1");
        a.record_all(&[1.0]);
        assert_eq!(reg.tracker("m@1").samples(), 1);
        let mut out = String::new();
        reg.write_json(&mut out);
        let doc = json::parse(&out).unwrap();
        assert!(doc.require("m@1").is_ok() && doc.require("m@2").is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
