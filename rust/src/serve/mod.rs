//! The model-serving subsystem: prediction-time infrastructure for
//! fitted Cox models, zero external dependencies (std only, workers
//! from [`crate::util::parallel`]).
//!
//! Three layers, composable on their own or through the CLI:
//!
//! * [`registry`] — a hot-swappable [`registry::ModelRegistry`] that
//!   loads versioned `CoxModel` JSON artifacts from a directory and
//!   serves them by `name@version` behind an atomic-swap `Arc` handle;
//!   a reload never disturbs in-flight scoring.
//! * [`scorer`] — [`scorer::CompiledModel`] (β pruned to its nonzero
//!   support, Breslow baseline as a binary-searchable step table, LRU
//!   cache of H₀ at registered horizon grids) plus the
//!   [`scorer::MicroBatcher`] that merges many small concurrent
//!   requests into one parallel sweep, and a streaming CSV scorer for
//!   offline `n ≫ RAM` batches.
//! * [`http`] — a hand-rolled multi-threaded HTTP/1.1 server
//!   (keep-alive, pipelining, content-length framing, graceful
//!   shutdown) exposing `/v1/score`, `/v1/models`, `/v1/reload`,
//!   `/healthz`, `/metrics` (per-endpoint latency/throughput counters
//!   from [`stats`], batcher gauges, sliced SLO series), and
//!   `/debug/trace` (the flight recorder's last-K request records).
//!
//! Request-level observability rides the HTTP layer: every request gets
//! an ID (`x-request-id` in, echoed out) and a six-stage lifecycle
//! breakdown (`read`/`parse`/`queue_wait`/`batch_score`/`serialize`/
//! `write`, see [`crate::obs::recorder`]) recorded — behind the
//! process-wide obs flag — into the flight recorder, sliced metrics,
//! and an optional JSONL access log.
//!
//! [`smoke`] drives all of it end to end for CI: concurrent burst,
//! mid-burst hot reload, bitwise parity with the in-process API,
//! `BENCH_serve.json` throughput/latency numbers, plus the request-obs
//! gates (off/on overhead ≤ the baseline's `serve_obs_gate`, server-vs-
//! client latency reconciliation, access-log schema validation).
//!
//! The training-side counterpart is [`crate::api`]; serving reuses its
//! JSON parser and the exact same arithmetic (scores are bit-for-bit
//! equal to `CoxModel::predict_risk` / `predict_survival_curve`).

pub mod drift;
pub mod http;
pub mod registry;
pub mod scorer;
pub mod smoke;
pub mod stats;

pub use drift::{DriftReference, DriftRegistry, DriftTracker};
pub use http::{serve, ClientResponse, HttpClient, ServeConfig, ServerHandle};
pub use registry::{ModelRegistry, RegistryState, ReloadReport};
pub use scorer::{
    score_csv, BatchConfig, BatchGaugesSnapshot, CompiledModel, MicroBatcher, ScoreOutput,
};
pub use stats::ServeMetrics;
