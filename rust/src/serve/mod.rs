//! The model-serving subsystem: prediction-time infrastructure for
//! fitted Cox models, zero external dependencies (std only, workers
//! from [`crate::util::parallel`]).
//!
//! Three layers, composable on their own or through the CLI:
//!
//! * [`registry`] — a hot-swappable [`registry::ModelRegistry`] that
//!   loads versioned `CoxModel` JSON artifacts from a directory and
//!   serves them by `name@version` behind an atomic-swap `Arc` handle;
//!   a reload never disturbs in-flight scoring.
//! * [`scorer`] — [`scorer::CompiledModel`] (β pruned to its nonzero
//!   support, Breslow baseline as a binary-searchable step table, LRU
//!   cache of H₀ at registered horizon grids) plus the
//!   [`scorer::MicroBatcher`] that merges many small concurrent
//!   requests into one parallel sweep, and a streaming CSV scorer for
//!   offline `n ≫ RAM` batches.
//! * [`http`] — a hand-rolled multi-threaded HTTP/1.1 server
//!   (keep-alive, pipelining, content-length framing, graceful
//!   shutdown) exposing `/v1/score`, `/v1/models`, `/v1/reload`,
//!   `/healthz`, and `/metrics` (per-endpoint latency/throughput
//!   counters from [`stats`]).
//!
//! [`smoke`] drives all of it end to end for CI: concurrent burst,
//! mid-burst hot reload, bitwise parity with the in-process API, and
//! `BENCH_serve.json` throughput/latency numbers.
//!
//! The training-side counterpart is [`crate::api`]; serving reuses its
//! JSON parser and the exact same arithmetic (scores are bit-for-bit
//! equal to `CoxModel::predict_risk` / `predict_survival_curve`).

pub mod drift;
pub mod http;
pub mod registry;
pub mod scorer;
pub mod smoke;
pub mod stats;

pub use drift::{DriftReference, DriftRegistry, DriftTracker};
pub use http::{serve, HttpClient, ServeConfig, ServerHandle};
pub use registry::{ModelRegistry, RegistryState, ReloadReport};
pub use scorer::{score_csv, BatchConfig, CompiledModel, MicroBatcher, ScoreOutput};
pub use stats::ServeMetrics;
