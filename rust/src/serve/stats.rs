//! Lock-free serving metrics: per-endpoint request/error/row counters
//! and log₂-bucketed latency histograms, surfaced as the JSON document
//! behind `GET /metrics` and as Prometheus text exposition behind
//! `GET /metrics?format=prometheus`.
//!
//! Everything is atomic — recording a request is a handful of relaxed
//! fetch-adds on the hot path, and readers (the `/metrics` handler)
//! observe a consistent-enough snapshot without ever blocking scorers.
//! The histogram type lives in [`crate::obs::hist`] — one
//! implementation shared between serving latency and training span
//! timing, with midpoint-interpolated quantiles (within 1.5× of the
//! true sample). The document also carries the training-side gauges
//! ([`crate::obs::training_gauges`]): last refit duration/sweeps and
//! publish/reject counts, live when a watch loop runs in this process.

use crate::api::json;
use crate::obs::hist::{write_prom_cumulative, LatencyHistogram};
use crate::obs::{training_gauges, TrainingGauges};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counters for one endpoint.
pub struct EndpointStats {
    pub name: &'static str,
    requests: AtomicU64,
    errors: AtomicU64,
    rows: AtomicU64,
    hist: LatencyHistogram,
}

impl EndpointStats {
    fn new(name: &'static str) -> Self {
        EndpointStats {
            name,
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            hist: LatencyHistogram::new(),
        }
    }

    /// Record one handled request: success flag, rows scored (0 for
    /// non-scoring endpoints), wall latency in microseconds.
    pub fn record(&self, ok: bool, rows: u64, us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        if rows > 0 {
            self.rows.fetch_add(rows, Ordering::Relaxed);
        }
        self.hist.record(us);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    fn write_json(&self, out: &mut String) {
        out.push_str("{\"requests\": ");
        out.push_str(&self.requests().to_string());
        out.push_str(", \"errors\": ");
        out.push_str(&self.errors().to_string());
        out.push_str(", \"rows\": ");
        out.push_str(&self.rows().to_string());
        out.push_str(", \"mean_ms\": ");
        json::write_f64(out, self.hist.mean_us() / 1e3);
        out.push_str(", \"p50_ms\": ");
        json::write_f64(out, self.hist.quantile_us(0.50) / 1e3);
        out.push_str(", \"p99_ms\": ");
        json::write_f64(out, self.hist.quantile_us(0.99) / 1e3);
        out.push('}');
    }
}

/// All serving metrics, one instance per server.
pub struct ServeMetrics {
    started: Instant,
    pub score: EndpointStats,
    pub models: EndpointStats,
    pub reload: EndpointStats,
    pub healthz: EndpointStats,
    pub metrics_ep: EndpointStats,
    pub trace: EndpointStats,
    pub other: EndpointStats,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            started: Instant::now(),
            score: EndpointStats::new("score"),
            models: EndpointStats::new("models"),
            reload: EndpointStats::new("reload"),
            healthz: EndpointStats::new("healthz"),
            metrics_ep: EndpointStats::new("metrics"),
            trace: EndpointStats::new("trace"),
            other: EndpointStats::new("other"),
        }
    }
}

impl ServeMetrics {
    /// Stats slot for a routing key (unknown keys land in `other`).
    pub fn endpoint(&self, key: &str) -> &EndpointStats {
        match key {
            "score" => &self.score,
            "models" => &self.models,
            "reload" => &self.reload,
            "healthz" => &self.healthz,
            "metrics" => &self.metrics_ep,
            "trace" => &self.trace,
            _ => &self.other,
        }
    }

    fn endpoints(&self) -> [&EndpointStats; 7] {
        [
            &self.score,
            &self.models,
            &self.reload,
            &self.healthz,
            &self.metrics_ep,
            &self.trace,
            &self.other,
        ]
    }

    /// The `GET /metrics` response document.
    pub fn to_json(&self) -> String {
        let uptime = self.started.elapsed().as_secs_f64();
        let rows: u64 = self.score.rows();
        let g = training_gauges();
        let mut out = String::with_capacity(1024);
        out.push_str("{\"uptime_secs\": ");
        json::write_f64(&mut out, uptime);
        out.push_str(", \"rows_scored\": ");
        out.push_str(&rows.to_string());
        out.push_str(", \"rows_per_sec\": ");
        json::write_f64(&mut out, if uptime > 0.0 { rows as f64 / uptime } else { 0.0 });
        out.push_str(", \"training\": ");
        write_training_json(&mut out, &g);
        out.push_str(", \"endpoints\": {");
        for (i, ep) in self.endpoints().iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            json::write_str(&mut out, ep.name);
            out.push_str(": ");
            ep.write_json(&mut out);
        }
        out.push_str("}}");
        out
    }

    /// The `GET /metrics?format=prometheus` response: the same snapshot
    /// as [`ServeMetrics::to_json`] in Prometheus text exposition —
    /// per-endpoint counters, cumulative latency histograms (`le` in
    /// microseconds), and the training gauges.
    pub fn to_prometheus(&self) -> String {
        let uptime = self.started.elapsed().as_secs_f64();
        let g = training_gauges();
        let mut out = String::with_capacity(4096);
        out.push_str("# TYPE fastsurvival_uptime_seconds gauge\n");
        out.push_str(&format!("fastsurvival_uptime_seconds {uptime}\n"));
        out.push_str("# TYPE fastsurvival_rows_scored_total counter\n");
        out.push_str(&format!("fastsurvival_rows_scored_total {}\n", self.score.rows()));
        out.push_str("# TYPE fastsurvival_requests_total counter\n");
        for ep in self.endpoints() {
            out.push_str(&format!(
                "fastsurvival_requests_total{{endpoint=\"{}\"}} {}\n",
                ep.name,
                ep.requests()
            ));
        }
        out.push_str("# TYPE fastsurvival_errors_total counter\n");
        for ep in self.endpoints() {
            out.push_str(&format!(
                "fastsurvival_errors_total{{endpoint=\"{}\"}} {}\n",
                ep.name,
                ep.errors()
            ));
        }
        out.push_str("# TYPE fastsurvival_rows_total counter\n");
        for ep in self.endpoints() {
            out.push_str(&format!(
                "fastsurvival_rows_total{{endpoint=\"{}\"}} {}\n",
                ep.name,
                ep.rows()
            ));
        }
        out.push_str("# TYPE fastsurvival_request_latency_us histogram\n");
        for ep in self.endpoints() {
            // Conformant cumulative exposition with a fixed `le`
            // boundary set: every finite bucket appears on every scrape
            // (empty ones included), so scrapers see stable series.
            write_prom_cumulative(
                &mut out,
                "fastsurvival_request_latency_us",
                &format!("endpoint=\"{}\"", ep.name),
                &ep.hist.bucket_counts(),
                ep.hist.count(),
                ep.hist.sum_us(),
            );
        }
        out.push_str("# TYPE fastsurvival_last_refit_seconds gauge\n");
        out.push_str(&format!("fastsurvival_last_refit_seconds {}\n", g.last_refit_secs));
        out.push_str("# TYPE fastsurvival_last_refit_sweeps gauge\n");
        out.push_str(&format!("fastsurvival_last_refit_sweeps {}\n", g.last_sweeps));
        out.push_str("# TYPE fastsurvival_publishes_total counter\n");
        out.push_str(&format!("fastsurvival_publishes_total {}\n", g.publishes));
        out.push_str("# TYPE fastsurvival_rejects_total counter\n");
        out.push_str(&format!("fastsurvival_rejects_total {}\n", g.rejects));
        out
    }
}

fn write_training_json(out: &mut String, g: &TrainingGauges) {
    out.push_str("{\"last_refit_secs\": ");
    json::write_f64(out, g.last_refit_secs);
    out.push_str(", \"last_refit_sweeps\": ");
    out.push_str(&g.last_sweeps.to_string());
    out.push_str(", \"publishes\": ");
    out.push_str(&g.publishes.to_string());
    out.push_str(", \"rejects\": ");
    out.push_str(&g.rejects.to_string());
    out.push('}');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_document_is_valid_json() {
        let m = ServeMetrics::default();
        m.score.record(true, 64, 1200);
        m.score.record(false, 0, 300);
        m.healthz.record(true, 0, 15);
        let doc = json::parse(&m.to_json()).unwrap();
        let eps = doc.require("endpoints").unwrap();
        let score = eps.require("score").unwrap();
        assert_eq!(score.require("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(score.require("errors").unwrap().as_usize().unwrap(), 1);
        assert_eq!(score.require("rows").unwrap().as_usize().unwrap(), 64);
        assert!(doc.require("rows_per_sec").unwrap().as_f64().unwrap() >= 0.0);
        // The training block is always present (zeros before any watch
        // cycle runs in this process).
        let training = doc.require("training").unwrap();
        assert!(training.require("publishes").unwrap().as_usize().is_ok());
        assert!(training.require("last_refit_secs").unwrap().as_f64().is_ok());
        // Unknown routing keys fall back to "other".
        assert_eq!(m.endpoint("nope").name, "other");
    }

    #[test]
    fn prometheus_exposition_matches_the_json_snapshot() {
        let m = ServeMetrics::default();
        m.score.record(true, 64, 1200);
        m.score.record(false, 0, 300);
        m.reload.record(true, 0, 50);
        let doc = json::parse(&m.to_json()).unwrap();
        let text = m.to_prometheus();
        // Counters agree with the JSON document, endpoint by endpoint.
        for ep in ["score", "models", "reload", "healthz", "metrics", "trace", "other"] {
            let js = doc.require("endpoints").unwrap().require(ep).unwrap();
            for (series, field) in [
                ("fastsurvival_requests_total", "requests"),
                ("fastsurvival_errors_total", "errors"),
                ("fastsurvival_rows_total", "rows"),
            ] {
                let want = js.require(field).unwrap().as_usize().unwrap();
                let line = format!("{series}{{endpoint=\"{ep}\"}} {want}");
                assert!(text.contains(&line), "missing {line:?} in:\n{text}");
            }
        }
        // Histogram series: +Inf equals _count equals request count.
        let hist_lines = [
            "fastsurvival_request_latency_us_bucket{endpoint=\"score\",le=\"+Inf\"} 2",
            "fastsurvival_request_latency_us_count{endpoint=\"score\"} 2",
            "fastsurvival_request_latency_us_sum{endpoint=\"score\"} 1500",
            // Non-empty buckets appear with integer-µs inclusive
            // bounds: 1200 µs → bucket [1024, 2048) → le="2047";
            // 300 µs → bucket [256, 512) → le="511".
            "fastsurvival_request_latency_us_bucket{endpoint=\"score\",le=\"511\"} 1",
            "fastsurvival_request_latency_us_bucket{endpoint=\"score\",le=\"2047\"} 2",
        ];
        for line in hist_lines {
            assert!(text.contains(line), "missing {line:?} in:\n{text}");
        }
        // Fixed boundary set: empty buckets are emitted too, so every
        // scrape exposes the same `le` series (here: nothing was ever
        // recorded for "other", yet its zero bucket is present).
        assert!(text
            .contains("fastsurvival_request_latency_us_bucket{endpoint=\"other\",le=\"0\"} 0"));
        // Training gauges are present in both formats.
        assert!(text.contains("fastsurvival_publishes_total "));
        assert!(doc.require("training").is_ok());
    }
}
