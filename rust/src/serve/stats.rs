//! Lock-free serving metrics: per-endpoint request/error/row counters
//! and log₂-bucketed latency histograms, surfaced as the JSON document
//! behind `GET /metrics`.
//!
//! Everything is atomic — recording a request is a handful of relaxed
//! fetch-adds on the hot path, and readers (the `/metrics` handler)
//! observe a consistent-enough snapshot without ever blocking scorers.
//! Quantiles come from the histogram buckets, so p50/p99 are upper
//! bounds within a factor of 2 (the bucket width) of the true value.

use crate::api::json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Number of log₂ latency buckets: bucket `i` covers `[2^(i−1), 2^i)`
/// microseconds; the open-ended top bucket absorbs everything from
/// 2³⁸ µs (~3.2 days) up.
const N_BUCKETS: usize = 40;

/// Log₂-bucketed latency histogram over microseconds.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

fn bucket_of(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        (64 - us.leading_zeros() as usize).min(N_BUCKETS - 1)
    }
}

impl LatencyHistogram {
    pub fn record(&self, us: u64) {
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Quantile estimate in microseconds: the upper bound of the bucket
    /// containing the q-th sample (0 when empty). `q` in [0, 1].
    pub fn quantile_us(&self, q: f64) -> f64 {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return (1u64 << i) as f64;
            }
        }
        (1u64 << (N_BUCKETS - 1)) as f64
    }
}

/// Counters for one endpoint.
pub struct EndpointStats {
    pub name: &'static str,
    requests: AtomicU64,
    errors: AtomicU64,
    rows: AtomicU64,
    hist: LatencyHistogram,
}

impl EndpointStats {
    fn new(name: &'static str) -> Self {
        EndpointStats {
            name,
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            hist: LatencyHistogram::default(),
        }
    }

    /// Record one handled request: success flag, rows scored (0 for
    /// non-scoring endpoints), wall latency in microseconds.
    pub fn record(&self, ok: bool, rows: u64, us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        if rows > 0 {
            self.rows.fetch_add(rows, Ordering::Relaxed);
        }
        self.hist.record(us);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    fn write_json(&self, out: &mut String) {
        out.push_str("{\"requests\": ");
        out.push_str(&self.requests().to_string());
        out.push_str(", \"errors\": ");
        out.push_str(&self.errors().to_string());
        out.push_str(", \"rows\": ");
        out.push_str(&self.rows().to_string());
        out.push_str(", \"mean_ms\": ");
        json::write_f64(out, self.hist.mean_us() / 1e3);
        out.push_str(", \"p50_ms\": ");
        json::write_f64(out, self.hist.quantile_us(0.50) / 1e3);
        out.push_str(", \"p99_ms\": ");
        json::write_f64(out, self.hist.quantile_us(0.99) / 1e3);
        out.push('}');
    }
}

/// All serving metrics, one instance per server.
pub struct ServeMetrics {
    started: Instant,
    pub score: EndpointStats,
    pub models: EndpointStats,
    pub reload: EndpointStats,
    pub healthz: EndpointStats,
    pub metrics_ep: EndpointStats,
    pub other: EndpointStats,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            started: Instant::now(),
            score: EndpointStats::new("score"),
            models: EndpointStats::new("models"),
            reload: EndpointStats::new("reload"),
            healthz: EndpointStats::new("healthz"),
            metrics_ep: EndpointStats::new("metrics"),
            other: EndpointStats::new("other"),
        }
    }
}

impl ServeMetrics {
    /// Stats slot for a routing key (unknown keys land in `other`).
    pub fn endpoint(&self, key: &str) -> &EndpointStats {
        match key {
            "score" => &self.score,
            "models" => &self.models,
            "reload" => &self.reload,
            "healthz" => &self.healthz,
            "metrics" => &self.metrics_ep,
            _ => &self.other,
        }
    }

    fn endpoints(&self) -> [&EndpointStats; 6] {
        [
            &self.score,
            &self.models,
            &self.reload,
            &self.healthz,
            &self.metrics_ep,
            &self.other,
        ]
    }

    /// The `GET /metrics` response document.
    pub fn to_json(&self) -> String {
        let uptime = self.started.elapsed().as_secs_f64();
        let rows: u64 = self.score.rows();
        let mut out = String::with_capacity(1024);
        out.push_str("{\"uptime_secs\": ");
        json::write_f64(&mut out, uptime);
        out.push_str(", \"rows_scored\": ");
        out.push_str(&rows.to_string());
        out.push_str(", \"rows_per_sec\": ");
        json::write_f64(&mut out, if uptime > 0.0 { rows as f64 / uptime } else { 0.0 });
        out.push_str(", \"endpoints\": {");
        for (i, ep) in self.endpoints().iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            json::write_str(&mut out, ep.name);
            out.push_str(": ");
            ep.write_json(&mut out);
        }
        out.push_str("}}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_cover_the_range() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn quantiles_are_monotone_upper_bounds() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.5), 0.0, "empty histogram");
        for us in [10u64, 20, 40, 80, 160, 1000, 5000] {
            h.record(us);
        }
        let p50 = h.quantile_us(0.50);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 40.0, "p50 bucket must cover the median sample");
        assert!(p99 >= 5000.0, "p99 bucket must cover the max sample");
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.count(), 7);
    }

    #[test]
    fn metrics_document_is_valid_json() {
        let m = ServeMetrics::default();
        m.score.record(true, 64, 1200);
        m.score.record(false, 0, 300);
        m.healthz.record(true, 0, 15);
        let doc = json::parse(&m.to_json()).unwrap();
        let eps = doc.require("endpoints").unwrap();
        let score = eps.require("score").unwrap();
        assert_eq!(score.require("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(score.require("errors").unwrap().as_usize().unwrap(), 1);
        assert_eq!(score.require("rows").unwrap().as_usize().unwrap(), 64);
        assert!(doc.require("rows_per_sec").unwrap().as_f64().unwrap() >= 0.0);
        // Unknown routing keys fall back to "other".
        assert_eq!(m.endpoint("nope").name, "other");
    }
}
