//! The `serve-smoke` CLI subcommand: an end-to-end serving benchmark
//! and correctness gate, CI's proof that the scoring server holds up
//! under concurrent load.
//!
//! One run: fit a p-feature model on synthetic data, publish it to a
//! temp artifact directory, start the HTTP server on an OS-assigned
//! port, fire a concurrent multi-client scoring burst (keep-alive
//! connections, fixed-size row batches), POST `/v1/reload` several
//! times mid-burst, and assert that every response is a 200 whose risk
//! vector is **bitwise** equal to in-process `CoxModel::predict_risk`
//! on the same rows. Throughput (rows/sec) and exact client-side
//! p50/p99 latencies land in `BENCH_serve.json`; any HTTP error,
//! parity mismatch, or failed reload makes the run exit nonzero, so CI
//! can gate on it directly.

use super::http::{serve, HttpClient, ServeConfig};
use super::registry::ModelRegistry;
use super::scorer::BatchConfig;
use crate::api::json;
use crate::api::CoxFit;
use crate::data::synthetic::{generate, SyntheticConfig};
use crate::error::{FastSurvivalError, Result};
use crate::util::args::Args;
use crate::util::parallel::num_threads;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-client burst outcome.
struct ClientOutcome {
    latencies_ms: Vec<f64>,
    non_200: usize,
    parity_failures: usize,
    io_errors: usize,
}

pub fn run(args: &Args) -> Result<()> {
    let p = args.get_or("p", 500usize);
    let batch_rows = args.get_or("batch-rows", 64usize);
    let clients = args.get_or("clients", 6usize).max(1);
    let requests = args.get_or("requests", 25usize).max(1);
    let reloads = args.get_or("reloads", 4usize);
    let seed = args.get_or("seed", 7u64);
    let out_path = args.str_or("out", "BENCH_serve.json");

    // 1. Train a model at the tracked workload shape. Accuracy is
    // irrelevant here — the burst measures the serving path — so a few
    // ridge sweeps suffice and keep the smoke fast.
    let n_train = (2 * batch_rows.max(32)).max(400);
    let ds = generate(&SyntheticConfig { n: n_train, p, rho: 0.5, k: 10, s: 0.1, seed });
    let model = CoxFit::new().l2(1.0).max_iters(6).tol(1e-4).fit(&ds)?;
    println!(
        "serve-smoke: model p={p} nonzero={} · {clients} clients × {requests} requests \
         × {batch_rows} rows · {reloads} mid-burst reloads",
        model.nonzero_coefficients(0.0).len()
    );

    // 2. Publish to a temp artifact directory and start the server.
    let dir = std::env::temp_dir().join(format!("fs_serve_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)
        .map_err(|e| FastSurvivalError::io(format!("creating {dir:?}"), e))?;
    model.save(&dir.join("risk@1.json"))?;
    let registry = Arc::new(ModelRegistry::open(&dir)?);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        // One worker per client connection plus slack for the reloader,
        // so burst latency measures scoring, not connection queueing.
        workers: args.get_or("workers", clients + 2).max(num_threads()),
        max_body_bytes: 32 << 20,
        batch: BatchConfig::default(),
    };
    let handle = serve(Arc::clone(&registry), &cfg)?;
    let addr = handle.local_addr();
    println!("serve-smoke: listening on http://{addr}");

    // 3. Distinct row batch + expected (bitwise) risks per client.
    let mut bodies: Vec<String> = Vec::with_capacity(clients);
    let mut expected: Vec<Vec<f64>> = Vec::with_capacity(clients);
    for c in 0..clients {
        let offset = (c * batch_rows) % (ds.n().saturating_sub(batch_rows).max(1));
        let idx: Vec<usize> = (offset..offset + batch_rows).map(|i| i % ds.n()).collect();
        let sub = ds.x.select_rows(&idx);
        expected.push(model.predict_risk(&sub)?);
        let mut body = String::from("{\"model\": \"risk@1\", \"rows\": [");
        for (i, &r) in idx.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            let row: Vec<f64> = (0..p).map(|j| ds.x.get(r, j)).collect();
            json::write_f64_array(&mut body, &row);
        }
        body.push_str("]}");
        bodies.push(body);
    }

    // 4. The burst: every client hammers its batch over one keep-alive
    // connection while the reloader hot-swaps the registry mid-flight.
    let wall_start = Instant::now();
    let mut outcomes: Vec<ClientOutcome> = Vec::with_capacity(clients);
    let mut reload_failures = 0usize;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            let body = &bodies[c];
            let expect = &expected[c];
            handles.push(scope.spawn(move || client_burst(addr, body, expect, requests)));
        }
        let reloader = scope.spawn(move || {
            let mut failures = 0usize;
            for _ in 0..reloads {
                std::thread::sleep(Duration::from_millis(20));
                let ok = HttpClient::connect(addr)
                    .and_then(|mut cl| cl.post("/v1/reload", "{}"))
                    .map(|resp| resp.status == 200)
                    .unwrap_or(false);
                if !ok {
                    failures += 1;
                }
            }
            failures
        });
        for h in handles {
            outcomes.push(h.join().expect("client thread panicked"));
        }
        reload_failures = reloader.join().expect("reloader thread panicked");
    });
    let wall_secs = wall_start.elapsed().as_secs_f64();

    // 5. Aggregate.
    let mut latencies: Vec<f64> = Vec::new();
    let mut non_200 = 0usize;
    let mut parity_failures = 0usize;
    let mut io_errors = 0usize;
    for o in &outcomes {
        latencies.extend_from_slice(&o.latencies_ms);
        non_200 += o.non_200;
        parity_failures += o.parity_failures;
        io_errors += o.io_errors;
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let quantile = |q: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let i = ((q * latencies.len() as f64).ceil() as usize).clamp(1, latencies.len());
        latencies[i - 1]
    };
    let ok_requests = latencies.len() - non_200.min(latencies.len());
    let rows_per_sec = if wall_secs > 0.0 {
        (ok_requests * batch_rows) as f64 / wall_secs
    } else {
        0.0
    };
    let all_200 = non_200 == 0 && io_errors == 0;
    let parity_ok = parity_failures == 0;
    let reloads_ok = reload_failures == 0;

    println!(
        "serve-smoke: {} requests in {wall_secs:.2}s · {rows_per_sec:.0} rows/s · \
         p50 {:.2} ms · p99 {:.2} ms · non-200 {non_200} · io errors {io_errors} · \
         parity failures {parity_failures} · reload failures {reload_failures}",
        latencies.len(),
        quantile(0.50),
        quantile(0.99),
    );

    // 6. Server-side metrics snapshot rides along for diagnosis.
    let server_metrics = HttpClient::connect(addr)
        .and_then(|mut cl| cl.get("/metrics"))
        .map(|r| r.body)
        .unwrap_or_else(|_| "null".into());
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    // 7. Emit BENCH_serve.json.
    let mut out = String::with_capacity(1024);
    out.push_str("{\n  \"schema_version\": 1,\n  \"bench\": \"serve\",\n  \"workload\": {");
    out.push_str(&format!(
        "\"p\": {p}, \"batch_rows\": {batch_rows}, \"clients\": {clients}, \
         \"requests_per_client\": {requests}, \"reloads\": {reloads}, \"seed\": {seed}, \
         \"threads\": {}",
        num_threads()
    ));
    out.push_str("},\n  \"results\": {\"rows_per_sec\": ");
    json::write_f64(&mut out, rows_per_sec);
    out.push_str(", \"p50_ms\": ");
    json::write_f64(&mut out, quantile(0.50));
    out.push_str(", \"p99_ms\": ");
    json::write_f64(&mut out, quantile(0.99));
    out.push_str(", \"wall_secs\": ");
    json::write_f64(&mut out, wall_secs);
    out.push_str(&format!(
        ", \"requests\": {}, \"non_200\": {non_200}, \"io_errors\": {io_errors}, \
         \"parity_failures\": {parity_failures}, \"reload_failures\": {reload_failures}",
        latencies.len()
    ));
    out.push_str("},\n  \"gate\": {");
    out.push_str(&format!(
        "\"all_200\": {all_200}, \"bitwise_parity\": {parity_ok}, \
         \"reloads_ok\": {reloads_ok}"
    ));
    out.push_str("},\n  \"server_metrics\": ");
    out.push_str(&server_metrics);
    out.push_str("\n}\n");
    std::fs::write(Path::new(&out_path), &out)
        .map_err(|e| FastSurvivalError::io(format!("writing {out_path}"), e))?;
    println!("serve-smoke: wrote {out_path}");

    if !(all_200 && parity_ok && reloads_ok) {
        return Err(FastSurvivalError::Serve(format!(
            "smoke gate failed: non_200={non_200} io_errors={io_errors} \
             parity_failures={parity_failures} reload_failures={reload_failures}"
        )));
    }
    Ok(())
}

/// One client's share of the burst: sequential keep-alive requests,
/// bitwise parity check per response.
fn client_burst(
    addr: std::net::SocketAddr,
    body: &str,
    expect: &[f64],
    requests: usize,
) -> ClientOutcome {
    let mut outcome = ClientOutcome {
        latencies_ms: Vec::with_capacity(requests),
        non_200: 0,
        parity_failures: 0,
        io_errors: 0,
    };
    let mut client = match HttpClient::connect(addr) {
        Ok(c) => c,
        Err(_) => {
            outcome.io_errors = requests;
            return outcome;
        }
    };
    for _ in 0..requests {
        let started = Instant::now();
        let response = match client.post("/v1/score", body) {
            Ok(r) => r,
            Err(_) => {
                outcome.io_errors += 1;
                // The server may have closed the connection; reconnect
                // once rather than failing the whole client.
                match HttpClient::connect(addr) {
                    Ok(c) => {
                        client = c;
                        continue;
                    }
                    Err(_) => break,
                }
            }
        };
        outcome.latencies_ms.push(started.elapsed().as_secs_f64() * 1e3);
        if response.status != 200 {
            outcome.non_200 += 1;
        } else {
            let risk = json::parse(&response.body)
                .ok()
                .and_then(|doc| doc.get("risk").cloned())
                .and_then(|r| r.as_f64_vec().ok());
            match risk {
                Some(risk) if risk.len() == expect.len() => {
                    let bitwise = risk
                        .iter()
                        .zip(expect)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    if !bitwise {
                        outcome.parity_failures += 1;
                    }
                }
                _ => outcome.parity_failures += 1,
            }
        }
        // An announced close (per-connection request cap, error paths)
        // is normal keep-alive lifecycle, not a failure: reconnect
        // before the next request instead of writing into a dead socket.
        if response.close {
            match HttpClient::connect(addr) {
                Ok(c) => client = c,
                Err(_) => {
                    outcome.io_errors += 1;
                    break;
                }
            }
        }
    }
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_smoke_end_to_end() {
        // A scaled-down run of the real harness: tiny model, few
        // clients, but the full server + burst + reload + gate path.
        let out = std::env::temp_dir()
            .join(format!("BENCH_serve_test_{}.json", std::process::id()));
        let args = Args::parse(
            [
                "serve-smoke".to_string(),
                "--p".into(),
                "12".into(),
                "--batch-rows".into(),
                "8".into(),
                "--clients".into(),
                "2".into(),
                "--requests".into(),
                "4".into(),
                "--reloads".into(),
                "1".into(),
                "--out".into(),
                out.to_str().unwrap().to_string(),
            ]
            .into_iter(),
        );
        run(&args).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = json::parse(&text).unwrap();
        let gate = doc.require("gate").unwrap();
        assert!(gate.require("all_200").unwrap().as_bool().unwrap());
        assert!(gate.require("bitwise_parity").unwrap().as_bool().unwrap());
        assert!(
            doc.require("results")
                .unwrap()
                .require("rows_per_sec")
                .unwrap()
                .as_f64()
                .unwrap()
                > 0.0
        );
        let _ = std::fs::remove_file(&out);
    }
}
