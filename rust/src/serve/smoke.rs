//! The `serve-smoke` CLI subcommand: an end-to-end serving benchmark
//! and correctness gate, CI's proof that the scoring server holds up
//! under concurrent load — and that watching it costs (almost) nothing.
//!
//! One run: fit a p-feature model on synthetic data, publish it to a
//! temp artifact directory, start the HTTP server on an OS-assigned
//! port, and fire the same concurrent multi-client scoring burst
//! (keep-alive connections, fixed-size row batches, `/v1/reload`
//! hot-swaps riding the first burst of each phase) twice over:
//!
//! 1. **obs off** — request-level observability disabled, `--obs-reps`
//!    repetitions, best-of throughput is the baseline;
//! 2. **obs on** — flight recorder + sliced metrics + access log all
//!    recording, same repetitions, best-of throughput is the treatment.
//!
//! Every response must be a 200 whose risk vector is **bitwise** equal
//! to in-process `CoxModel::predict_risk` on the same rows. On top of
//! the classic burst gates, three request-obs gates ride the run:
//!
//! * **overhead** — `(off − on) / off` throughput loss, checked against
//!   the committed `serve_obs_gate` when `--check ci/bench_baseline.json`
//!   is passed (same-run off/on, so machine speed cancels);
//! * **reconciliation** — server-side p50/p99 from the flight
//!   recorder's exact per-request totals (`/debug/trace`) must agree
//!   with the client-side quantiles within `--recon-tol-pct`;
//! * **access log** — exactly one well-formed JSONL line per scoring
//!   request, unique request IDs, and per-line stage micros that sum to
//!   the recorded total within 5% (or 25 µs on tiny requests).
//!
//! Throughput, latency quantiles, and the whole request-obs block land
//! in `BENCH_serve.json`; any failed gate makes the run exit nonzero,
//! so CI can gate on it directly.

use super::http::{serve, HttpClient, ServeConfig};
use super::registry::ModelRegistry;
use super::scorer::BatchConfig;
use crate::api::json;
use crate::api::CoxFit;
use crate::data::synthetic::{generate, SyntheticConfig};
use crate::error::{FastSurvivalError, Result};
use crate::obs::recorder::parse_request_records;
use crate::util::args::Args;
use crate::util::parallel::num_threads;
use std::collections::BTreeSet;
use std::net::SocketAddr;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-client burst outcome.
struct ClientOutcome {
    latencies_ms: Vec<f64>,
    non_200: usize,
    parity_failures: usize,
    io_errors: usize,
}

/// One full multi-client burst, aggregated.
struct BurstResult {
    latencies_ms: Vec<f64>,
    non_200: usize,
    parity_failures: usize,
    io_errors: usize,
    reload_failures: usize,
    wall_secs: f64,
}

/// Exact ceil-rank quantile of an ascending-sorted sample.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[i - 1]
}

/// The committed `serve_obs_gate` block of a `--check` baseline file.
struct ServeObsGate {
    enforce: bool,
    max_overhead_pct: f64,
}

/// Parse `serve_obs_gate` out of `ci/bench_baseline.json`; `Ok(None)`
/// when the file has no such block (older baselines stay compatible).
fn load_serve_obs_gate(path: &str) -> Result<Option<ServeObsGate>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| FastSurvivalError::io(format!("reading baseline {path}"), e))?;
    let doc = json::parse(&text)?;
    let gate = match doc.get("serve_obs_gate") {
        None => return Ok(None),
        Some(g) => g,
    };
    Ok(Some(ServeObsGate {
        enforce: gate.get("enforce").map(|b| b.as_bool().unwrap_or(false)).unwrap_or(false),
        max_overhead_pct: gate
            .get("max_overhead_pct")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(1.0),
    }))
}

pub fn run(args: &Args) -> Result<()> {
    let p = args.get_or("p", 500usize);
    let batch_rows = args.get_or("batch-rows", 64usize);
    let clients = args.get_or("clients", 6usize).max(1);
    let requests = args.get_or("requests", 25usize).max(1);
    let reloads = args.get_or("reloads", 4usize);
    let seed = args.get_or("seed", 7u64);
    let obs_reps = args.get_or("obs-reps", 2usize).max(1);
    let slow_ms = args.get_or("slow-ms", 250u64);
    let recon_tol_pct = args.get_or("recon-tol-pct", 10.0f64);
    let out_path = args.str_or("out", "BENCH_serve.json");
    let trace_dump = args.get("trace-dump").map(|s| s.to_string());
    let check = args.get("check").map(|s| s.to_string());

    // 1. Train a model at the tracked workload shape. Accuracy is
    // irrelevant here — the burst measures the serving path — so a few
    // ridge sweeps suffice and keep the smoke fast.
    let n_train = (2 * batch_rows.max(32)).max(400);
    let ds = generate(&SyntheticConfig { n: n_train, p, rho: 0.5, k: 10, s: 0.1, seed });
    let model = CoxFit::new().l2(1.0).max_iters(6).tol(1e-4).fit(&ds)?;
    println!(
        "serve-smoke: model p={p} nonzero={} · {clients} clients × {requests} requests \
         × {batch_rows} rows · {reloads} mid-burst reloads · {obs_reps} reps per obs phase",
        model.nonzero_coefficients(0.0).len()
    );

    // 2. Publish to a temp artifact directory and start the server with
    // the full request-obs stack wired up: access log, slow capture,
    // and a flight recorder big enough to hold every obs-on request.
    let dir = std::env::temp_dir().join(format!("fs_serve_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)
        .map_err(|e| FastSurvivalError::io(format!("creating {dir:?}"), e))?;
    model.save(&dir.join("risk@1.json"))?;
    let registry = Arc::new(ModelRegistry::open(&dir)?);
    let access_log_path = args
        .get("access-log")
        .map(|s| s.to_string())
        .unwrap_or_else(|| dir.join("access_log.jsonl").to_string_lossy().into_owned());
    // The server appends; start from a clean file so line counts are
    // exact across reruns.
    let _ = std::fs::remove_file(&access_log_path);
    let burst_requests = clients * requests;
    let recorder_capacity = obs_reps * burst_requests + reloads + 64;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        // One worker per client connection plus slack for the reloader,
        // so burst latency measures scoring, not connection queueing.
        workers: args.get_or("workers", clients + 2).max(num_threads()),
        max_body_bytes: 32 << 20,
        batch: BatchConfig::default(),
        access_log: Some(access_log_path.clone()),
        slow_ms,
        recorder_capacity,
    };
    let handle = serve(Arc::clone(&registry), &cfg)?;
    let addr = handle.local_addr();
    println!("serve-smoke: listening on http://{addr} · access log {access_log_path}");

    // 3. Distinct row batch + expected (bitwise) risks per client.
    let mut bodies: Vec<String> = Vec::with_capacity(clients);
    let mut expected: Vec<Vec<f64>> = Vec::with_capacity(clients);
    for c in 0..clients {
        let offset = (c * batch_rows) % (ds.n().saturating_sub(batch_rows).max(1));
        let idx: Vec<usize> = (offset..offset + batch_rows).map(|i| i % ds.n()).collect();
        let sub = ds.x.select_rows(&idx);
        expected.push(model.predict_risk(&sub)?);
        let mut body = String::from("{\"model\": \"risk@1\", \"rows\": [");
        for (i, &r) in idx.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            let row: Vec<f64> = (0..p).map(|j| ds.x.get(r, j)).collect();
            json::write_f64_array(&mut body, &row);
        }
        body.push_str("]}");
        bodies.push(body);
    }

    // 4. The A/B phases: identical burst workloads with request-level
    // observability off, then on. The reloader rides the first burst of
    // each phase so both phases pay the same hot-swap traffic.
    let per_burst_rps = |b: &BurstResult, tag: &str, rep: usize| -> f64 {
        let ok = b.latencies_ms.len().saturating_sub(b.non_200);
        let rps =
            if b.wall_secs > 0.0 { (ok * batch_rows) as f64 / b.wall_secs } else { 0.0 };
        println!(
            "serve-smoke: [{tag}] burst {}/{obs_reps}: {} responses in {:.2}s · {rps:.0} rows/s",
            rep + 1,
            b.latencies_ms.len(),
            b.wall_secs
        );
        rps
    };
    crate::obs::set_enabled(false);
    let mut off_bursts: Vec<BurstResult> = Vec::with_capacity(obs_reps);
    let mut off_best = 0.0f64;
    for rep in 0..obs_reps {
        let b = one_burst(addr, &bodies, &expected, requests, if rep == 0 { reloads } else { 0 });
        off_best = off_best.max(per_burst_rps(&b, "obs off", rep));
        off_bursts.push(b);
    }
    crate::obs::set_enabled(true);
    let mut on_bursts: Vec<BurstResult> = Vec::with_capacity(obs_reps);
    let mut on_best = 0.0f64;
    for rep in 0..obs_reps {
        let b = one_burst(addr, &bodies, &expected, requests, if rep == 0 { reloads } else { 0 });
        on_best = on_best.max(per_burst_rps(&b, "obs on", rep));
        on_bursts.push(b);
    }
    let overhead_pct =
        if off_best > 0.0 { (off_best - on_best) / off_best * 100.0 } else { f64::NAN };

    // 5. Aggregate. Error counters span both phases; the reported
    // latency quantiles come from the obs-on phase (what production
    // runs), which is also what the server-side records cover.
    let mut on_latencies: Vec<f64> = Vec::new();
    let mut total_responses = 0usize;
    let mut non_200 = 0usize;
    let mut parity_failures = 0usize;
    let mut io_errors = 0usize;
    let mut reload_failures = 0usize;
    let mut wall_secs = 0.0f64;
    for b in off_bursts.iter().chain(on_bursts.iter()) {
        total_responses += b.latencies_ms.len();
        non_200 += b.non_200;
        parity_failures += b.parity_failures;
        io_errors += b.io_errors;
        reload_failures += b.reload_failures;
        wall_secs += b.wall_secs;
    }
    for b in &on_bursts {
        on_latencies.extend_from_slice(&b.latencies_ms);
    }
    on_latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let client_p50 = quantile(&on_latencies, 0.50);
    let client_p99 = quantile(&on_latencies, 0.99);
    let rows_per_sec = on_best;

    // 6. Server-side truth: the flight recorder holds exact per-request
    // lifecycle totals for every obs-on request, so its score-request
    // quantiles must reconcile with what the clients measured.
    let trace_body = HttpClient::connect(addr)
        .and_then(|mut cl| cl.get(&format!("/debug/trace?n={recorder_capacity}")))
        .map(|r| r.body)
        .unwrap_or_default();
    if let Some(path) = &trace_dump {
        std::fs::write(Path::new(path), &trace_body)
            .map_err(|e| FastSurvivalError::io(format!("writing {path}"), e))?;
        println!("serve-smoke: wrote flight-recorder dump to {path}");
    }
    let slow_records = match json::parse(&trace_body) {
        Ok(doc) => doc
            .require("slow")
            .ok()
            .and_then(|s| s.as_array().ok().map(|a| a.len()))
            .unwrap_or(0),
        Err(_) => 0,
    };
    let server_records = parse_request_records(&trace_body).unwrap_or_default();
    let mut server_score_ms: Vec<f64> = server_records
        .iter()
        .filter(|r| r.endpoint == "score" && r.status == 200)
        .map(|r| r.total_us as f64 / 1e3)
        .collect();
    server_score_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let server_p50 = quantile(&server_score_ms, 0.50);
    let server_p99 = quantile(&server_score_ms, 0.99);
    let delta_pct = |server: f64, client: f64| -> f64 {
        if client > 0.0 { ((server - client) / client * 100.0).abs() } else { f64::NAN }
    };
    let recon_d50 = delta_pct(server_p50, client_p50);
    let recon_d99 = delta_pct(server_p99, client_p99);
    let recon_ok = !server_score_ms.is_empty()
        && recon_d50.is_finite()
        && recon_d50 <= recon_tol_pct
        && recon_d99.is_finite()
        && recon_d99 <= recon_tol_pct;

    // 7. Server metrics snapshot rides along for diagnosis, then shut
    // down — which joins every worker, so the access log is complete.
    let server_metrics = HttpClient::connect(addr)
        .and_then(|mut cl| cl.get("/metrics"))
        .map(|r| r.body)
        .unwrap_or_else(|_| "null".into());
    handle.shutdown();

    // 8. Access-log validation: exactly one line per obs-on scoring
    // response, unique IDs, stage micros that sum to each total.
    let expected_score_lines: usize = on_bursts.iter().map(|b| b.latencies_ms.len()).sum();
    let log_text = std::fs::read_to_string(&access_log_path).unwrap_or_default();
    let log_records = parse_request_records(&log_text).unwrap_or_default();
    let score_lines = log_records.iter().filter(|r| r.endpoint == "score").count();
    let unique_ids: BTreeSet<&str> = log_records.iter().map(|r| r.id.as_str()).collect();
    let mut stage_sum_bad = 0usize;
    for r in &log_records {
        let sum = r.stage_sum_us() as i64;
        let total = r.total_us as i64;
        let tol = ((total as f64 * 0.05) as i64).max(25);
        if (sum - total).abs() > tol {
            stage_sum_bad += 1;
        }
    }
    let access_log_ok = !log_records.is_empty()
        && score_lines == expected_score_lines
        && unique_ids.len() == log_records.len()
        && stage_sum_bad == 0;
    let _ = std::fs::remove_dir_all(&dir);

    // 9. Gates.
    let all_200 = non_200 == 0 && io_errors == 0;
    let parity_ok = parity_failures == 0;
    let reloads_ok = reload_failures == 0;
    let gate_cfg = match &check {
        None => None,
        Some(path) => load_serve_obs_gate(path)?,
    };
    let max_overhead = gate_cfg.as_ref().map(|g| g.max_overhead_pct).unwrap_or(1.0);
    let obs_overhead_ok = overhead_pct.is_finite() && overhead_pct <= max_overhead;

    println!(
        "serve-smoke: {total_responses} responses in {wall_secs:.2}s · obs-on best \
         {rows_per_sec:.0} rows/s · p50 {client_p50:.2} ms · p99 {client_p99:.2} ms · \
         non-200 {non_200} · io errors {io_errors} · parity failures {parity_failures} · \
         reload failures {reload_failures}"
    );
    println!(
        "serve-smoke: request-obs overhead {overhead_pct:.2}% (off {off_best:.0} vs on \
         {on_best:.0} rows/s) · server p50/p99 {server_p50:.2}/{server_p99:.2} ms vs \
         client {client_p50:.2}/{client_p99:.2} ms (Δ {recon_d50:.1}%/{recon_d99:.1}%, \
         tol {recon_tol_pct}%) · access log {} lines ({score_lines} score, \
         {stage_sum_bad} bad stage sums) · {slow_records} slow records",
        log_records.len()
    );

    // 10. Emit BENCH_serve.json.
    let mut out = String::with_capacity(2048);
    out.push_str("{\n  \"schema_version\": 2,\n  \"bench\": \"serve\",\n  \"workload\": {");
    out.push_str(&format!(
        "\"p\": {p}, \"batch_rows\": {batch_rows}, \"clients\": {clients}, \
         \"requests_per_client\": {requests}, \"reloads\": {reloads}, \"seed\": {seed}, \
         \"obs_reps\": {obs_reps}, \"slow_ms\": {slow_ms}, \"threads\": {}",
        num_threads()
    ));
    out.push_str("},\n  \"results\": {\"rows_per_sec\": ");
    json::write_f64(&mut out, rows_per_sec);
    out.push_str(", \"p50_ms\": ");
    json::write_f64(&mut out, client_p50);
    out.push_str(", \"p99_ms\": ");
    json::write_f64(&mut out, client_p99);
    out.push_str(", \"wall_secs\": ");
    json::write_f64(&mut out, wall_secs);
    out.push_str(&format!(
        ", \"requests\": {total_responses}, \"non_200\": {non_200}, \
         \"io_errors\": {io_errors}, \"parity_failures\": {parity_failures}, \
         \"reload_failures\": {reload_failures}"
    ));
    out.push_str("},\n  \"request_obs\": {\"off_rows_per_sec_best\": ");
    json::write_f64(&mut out, off_best);
    out.push_str(", \"on_rows_per_sec_best\": ");
    json::write_f64(&mut out, on_best);
    out.push_str(", \"overhead_pct\": ");
    json::write_f64(&mut out, overhead_pct);
    out.push_str(", \"server_p50_ms\": ");
    json::write_f64(&mut out, server_p50);
    out.push_str(", \"server_p99_ms\": ");
    json::write_f64(&mut out, server_p99);
    out.push_str(", \"client_p50_ms\": ");
    json::write_f64(&mut out, client_p50);
    out.push_str(", \"client_p99_ms\": ");
    json::write_f64(&mut out, client_p99);
    out.push_str(", \"recon_delta_p50_pct\": ");
    json::write_f64(&mut out, recon_d50);
    out.push_str(", \"recon_delta_p99_pct\": ");
    json::write_f64(&mut out, recon_d99);
    out.push_str(", \"recon_tol_pct\": ");
    json::write_f64(&mut out, recon_tol_pct);
    out.push_str(&format!(
        ", \"server_score_records\": {}, \"access_log_lines\": {}, \
         \"access_log_score_lines\": {score_lines}, \"slow_records\": {slow_records}",
        server_score_ms.len(),
        log_records.len()
    ));
    out.push_str("},\n  \"gate\": {");
    out.push_str(&format!(
        "\"all_200\": {all_200}, \"bitwise_parity\": {parity_ok}, \
         \"reloads_ok\": {reloads_ok}, \"recon_ok\": {recon_ok}, \
         \"access_log_ok\": {access_log_ok}, \"obs_overhead_ok\": {obs_overhead_ok}"
    ));
    out.push_str("},\n  \"server_metrics\": ");
    out.push_str(&server_metrics);
    out.push_str("\n}\n");
    std::fs::write(Path::new(&out_path), &out)
        .map_err(|e| FastSurvivalError::io(format!("writing {out_path}"), e))?;
    println!("serve-smoke: wrote {out_path}");

    // Leave the process-wide flag the way a fresh process starts.
    crate::obs::set_enabled(false);

    if !(all_200 && parity_ok && reloads_ok && recon_ok && access_log_ok) {
        return Err(FastSurvivalError::Serve(format!(
            "smoke gate failed: non_200={non_200} io_errors={io_errors} \
             parity_failures={parity_failures} reload_failures={reload_failures} \
             recon_ok={recon_ok} (Δp50 {recon_d50:.1}% Δp99 {recon_d99:.1}% vs tol \
             {recon_tol_pct}%) access_log_ok={access_log_ok} ({score_lines} score lines, \
             expected {expected_score_lines}, {stage_sum_bad} bad stage sums)"
        )));
    }
    if let Some(g) = &gate_cfg {
        if !obs_overhead_ok {
            let msg = format!(
                "serve_obs_gate: request-obs overhead {overhead_pct:.2}% exceeds \
                 {max_overhead:.2}% (off {off_best:.0} rows/s vs on {on_best:.0} rows/s)"
            );
            if g.enforce {
                return Err(FastSurvivalError::PerfRegression(msg));
            }
            println!("serve-smoke: advisory (enforce=false): {msg}");
        } else {
            println!(
                "serve-smoke: serve_obs_gate ok ({overhead_pct:.2}% ≤ {max_overhead:.2}%)"
            );
        }
    }
    Ok(())
}

/// One full burst: every client hammers its batch over one keep-alive
/// connection; when `reloads > 0` a reloader thread hot-swaps the
/// registry mid-flight.
fn one_burst(
    addr: SocketAddr,
    bodies: &[String],
    expected: &[Vec<f64>],
    requests: usize,
    reloads: usize,
) -> BurstResult {
    let wall_start = Instant::now();
    let mut outcomes: Vec<ClientOutcome> = Vec::with_capacity(bodies.len());
    let mut reload_failures = 0usize;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(bodies.len());
        for (body, expect) in bodies.iter().zip(expected) {
            handles.push(scope.spawn(move || client_burst(addr, body, expect, requests)));
        }
        let reloader = scope.spawn(move || {
            let mut failures = 0usize;
            for _ in 0..reloads {
                std::thread::sleep(Duration::from_millis(20));
                let ok = HttpClient::connect(addr)
                    .and_then(|mut cl| cl.post("/v1/reload", "{}"))
                    .map(|resp| resp.status == 200)
                    .unwrap_or(false);
                if !ok {
                    failures += 1;
                }
            }
            failures
        });
        for h in handles {
            outcomes.push(h.join().expect("client thread panicked"));
        }
        reload_failures = reloader.join().expect("reloader thread panicked");
    });
    let wall_secs = wall_start.elapsed().as_secs_f64();
    let mut out = BurstResult {
        latencies_ms: Vec::new(),
        non_200: 0,
        parity_failures: 0,
        io_errors: 0,
        reload_failures,
        wall_secs,
    };
    for o in outcomes {
        out.latencies_ms.extend_from_slice(&o.latencies_ms);
        out.non_200 += o.non_200;
        out.parity_failures += o.parity_failures;
        out.io_errors += o.io_errors;
    }
    out
}

/// One client's share of the burst: sequential keep-alive requests,
/// bitwise parity check per response.
fn client_burst(
    addr: std::net::SocketAddr,
    body: &str,
    expect: &[f64],
    requests: usize,
) -> ClientOutcome {
    let mut outcome = ClientOutcome {
        latencies_ms: Vec::with_capacity(requests),
        non_200: 0,
        parity_failures: 0,
        io_errors: 0,
    };
    let mut client = match HttpClient::connect(addr) {
        Ok(c) => c,
        Err(_) => {
            outcome.io_errors = requests;
            return outcome;
        }
    };
    for _ in 0..requests {
        let started = Instant::now();
        let response = match client.post("/v1/score", body) {
            Ok(r) => r,
            Err(_) => {
                outcome.io_errors += 1;
                // The server may have closed the connection; reconnect
                // once rather than failing the whole client.
                match HttpClient::connect(addr) {
                    Ok(c) => {
                        client = c;
                        continue;
                    }
                    Err(_) => break,
                }
            }
        };
        outcome.latencies_ms.push(started.elapsed().as_secs_f64() * 1e3);
        if response.status != 200 {
            outcome.non_200 += 1;
        } else {
            let risk = json::parse(&response.body)
                .ok()
                .and_then(|doc| doc.get("risk").cloned())
                .and_then(|r| r.as_f64_vec().ok());
            match risk {
                Some(risk) if risk.len() == expect.len() => {
                    let bitwise = risk
                        .iter()
                        .zip(expect)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    if !bitwise {
                        outcome.parity_failures += 1;
                    }
                }
                _ => outcome.parity_failures += 1,
            }
        }
        // An announced close (per-connection request cap, error paths)
        // is normal keep-alive lifecycle, not a failure: reconnect
        // before the next request instead of writing into a dead socket.
        if response.close {
            match HttpClient::connect(addr) {
                Ok(c) => client = c,
                Err(_) => {
                    outcome.io_errors += 1;
                    break;
                }
            }
        }
    }
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_smoke_end_to_end() {
        // A scaled-down run of the real harness: tiny model, few
        // clients, but the full server + off/on burst + reload + gate
        // path. The guard serializes the process-wide obs flag with the
        // other obs-global tests; the reconciliation tolerance is wide
        // because sub-millisecond requests are fixed-overhead-dominated.
        let _guard = crate::obs::span::test_support::obs_test_guard();
        let out = std::env::temp_dir()
            .join(format!("BENCH_serve_test_{}.json", std::process::id()));
        let args = Args::parse(
            [
                "serve-smoke".to_string(),
                "--p".into(),
                "12".into(),
                "--batch-rows".into(),
                "8".into(),
                "--clients".into(),
                "2".into(),
                "--requests".into(),
                "4".into(),
                "--reloads".into(),
                "1".into(),
                "--obs-reps".into(),
                "1".into(),
                "--slow-ms".into(),
                "1".into(),
                "--recon-tol-pct".into(),
                "500".into(),
                "--out".into(),
                out.to_str().unwrap().to_string(),
            ]
            .into_iter(),
        );
        run(&args).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = json::parse(&text).unwrap();
        let gate = doc.require("gate").unwrap();
        assert!(gate.require("all_200").unwrap().as_bool().unwrap());
        assert!(gate.require("bitwise_parity").unwrap().as_bool().unwrap());
        assert!(gate.require("recon_ok").unwrap().as_bool().unwrap());
        assert!(gate.require("access_log_ok").unwrap().as_bool().unwrap());
        let obs = doc.require("request_obs").unwrap();
        assert!(obs.require("server_p50_ms").unwrap().as_f64().unwrap() > 0.0);
        // 1 obs-on rep × 2 clients × 4 requests, all landing in the log.
        assert_eq!(
            obs.require("access_log_score_lines").unwrap().as_usize().unwrap(),
            8
        );
        assert!(
            doc.require("results")
                .unwrap()
                .require("rows_per_sec")
                .unwrap()
                .as_f64()
                .unwrap()
                > 0.0
        );
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn serve_obs_gate_parses_and_enforces() {
        let path = std::env::temp_dir()
            .join(format!("fs_serve_obs_gate_{}.json", std::process::id()));
        let path_str = path.to_str().unwrap();
        std::fs::write(
            &path,
            "{\"serve_obs_gate\": {\"enforce\": true, \"max_overhead_pct\": 1.5}}",
        )
        .unwrap();
        let g = load_serve_obs_gate(path_str).unwrap().unwrap();
        assert!(g.enforce);
        assert_eq!(g.max_overhead_pct, 1.5);
        // No block → None (older baselines are compatible).
        std::fs::write(&path, "{\"tolerance_pct\": 25}").unwrap();
        assert!(load_serve_obs_gate(path_str).unwrap().is_none());
        // enforce defaults to false, threshold to 1.0.
        std::fs::write(&path, "{\"serve_obs_gate\": {}}").unwrap();
        let g = load_serve_obs_gate(path_str).unwrap().unwrap();
        assert!(!g.enforce);
        assert_eq!(g.max_overhead_pct, 1.0);
        // A missing file is an error, not a silent pass.
        let _ = std::fs::remove_file(&path);
        assert!(load_serve_obs_gate(path_str).is_err());
    }
}
