//! A minimal hand-rolled HTTP/1.1 scoring server over
//! `std::net::TcpListener` — no external dependencies, request bodies
//! parsed with the in-repo JSON parser ([`crate::api::json`]).
//!
//! Protocol support is deliberately small but correct: content-length
//! framing (chunked bodies are rejected with 400), keep-alive with
//! pipelining (leftover bytes after one request's body start the next),
//! `Expect: 100-continue`, an oversized-body guard (413 before the body
//! is read), and graceful shutdown — the accept loop is woken by a
//! self-connect (the TCP flavor of the classic self-pipe trick), worker
//! threads finish their in-flight request, and queued connections drain
//! before the pool joins.
//!
//! Endpoints:
//!
//! | route            | body                                     | reply |
//! |------------------|------------------------------------------|-------|
//! | `POST /v1/score` | `{"model": "name@ver"?, "rows": [[f64…]…], "horizons": [f64…]?}` | `{"model", "n", "risk": […], "survival": [[…]…]?}` |
//! | `GET /v1/models` | —                                        | `{"models": [{name, version, features, nonzero, latest}…]}` |
//! | `POST /v1/reload`| —                                        | `{"reloaded", "artifacts", "names"}` |
//! | `GET /healthz`   | —                                        | `{"status": "ok", "artifacts", "generation", "models": […]}` |
//! | `GET /metrics`   | —                                        | per-endpoint counters + latency quantiles + training gauges + per-model drift + batcher gauges + sliced SLO series |
//! | `GET /debug/trace?n=K` | —                                  | last K completed request records + pinned slow requests from the flight recorder |
//!
//! `GET /metrics?format=prometheus` returns the same snapshot as
//! Prometheus text exposition (`text/plain`) instead of JSON.
//!
//! Request-level observability: every request carries an ID (the
//! client's `x-request-id`, echoed back, or a generated `fs-<n>`) and a
//! six-stage lifecycle breakdown — `read`, `parse`, `queue_wait`,
//! `batch_score`, `serialize`, `write` (see [`crate::obs::Stage`]).
//! Clock reads and ID plumbing are always-on; the recording sinks (the
//! flight recorder, sliced metrics, and the optional JSONL access log)
//! sit behind the process-wide obs flag — one relaxed atomic load per
//! request when disabled.

use super::drift::DriftRegistry;
use super::registry::{parse_spec, ModelRegistry};
use super::scorer::{BatchConfig, MicroBatcher};
use super::stats::ServeMetrics;
use crate::api::json::{self, Json};
use crate::error::{FastSurvivalError, Result};
use crate::obs::hist::write_prom_cumulative;
use crate::obs::recorder::{
    render_debug_trace, render_sliced_prometheus, write_record_json, write_sliced_json,
    FlightRecorder, RequestRecord, SlicedMetrics, Stage, N_STAGES,
};
use crate::util::parallel::{num_threads, WorkerPool};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Cap on request-head size (request line + headers).
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Keep-alive idle window before a connection is closed.
const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// Requests served on one keep-alive connection before the server
/// answers `Connection: close`. A connection parks a worker for its
/// whole lifetime, so this cap (together with [`IDLE_TIMEOUT`] and the
/// over-provisioned default worker count) bounds how long persistent
/// clients can monopolize the pool while new connections queue.
const MAX_REQUESTS_PER_CONN: usize = 256;

/// Slots in the flight recorder's pinned slow-request ring. Kept small
/// and separate from the main ring so a burst of fast requests can
/// never evict the outliers worth debugging.
const SLOW_RING_CAP: usize = 64;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; use port 0 for an OS-assigned port.
    pub addr: String,
    /// Connection-handling worker threads. These spend their lives in
    /// blocking socket I/O (each parks on one connection at a time;
    /// scoring parallelism comes from the micro-batcher's own data-
    /// parallel sweep), so the default deliberately over-provisions
    /// relative to cores — see [`ServeConfig::default_workers`].
    pub workers: usize,
    /// Request bodies above this size are refused with 413.
    pub max_body_bytes: usize,
    /// Micro-batching knobs for the scoring queue.
    pub batch: BatchConfig,
    /// Structured JSONL access log path; `None` disables the log.
    /// Lines are only written while the obs flag is on.
    pub access_log: Option<String>,
    /// Requests slower than this (total lifecycle) are pinned into the
    /// flight recorder's slow ring; 0 disables slow capture.
    pub slow_ms: u64,
    /// Main flight-recorder ring capacity (completed request records
    /// retrievable via `/debug/trace`).
    pub recorder_capacity: usize,
}

impl ServeConfig {
    /// Default connection-worker count: 4× the compute threads, at
    /// least 16 — I/O-bound workers are cheap, and a pool much larger
    /// than the expected persistent-connection count is what keeps
    /// fresh connections (health checks included) from queueing behind
    /// keep-alive clients.
    pub fn default_workers() -> usize {
        (num_threads() * 4).max(16)
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            workers: ServeConfig::default_workers(),
            max_body_bytes: 8 << 20,
            batch: BatchConfig::default(),
            access_log: None,
            slow_ms: 0,
            recorder_capacity: 512,
        }
    }
}

/// Everything a connection handler needs, all cheaply cloneable.
#[derive(Clone)]
struct Ctx {
    registry: Arc<ModelRegistry>,
    batcher: Arc<MicroBatcher>,
    metrics: Arc<ServeMetrics>,
    /// Drift counters live here, beside the registry handle rather than
    /// inside the hot-swapped state, so a `/v1/reload` never resets them.
    drift: Arc<DriftRegistry>,
    recorder: Arc<FlightRecorder>,
    sliced: Arc<SlicedMetrics>,
    /// One line per completed request while obs is on; the mutex
    /// serializes whole lines so concurrent workers never interleave.
    access_log: Option<Arc<Mutex<std::fs::File>>>,
    /// Source of generated `fs-<n>` request IDs.
    next_request_id: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    max_body: usize,
}

/// A running server. Dropping the handle shuts it down gracefully.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    registry: Arc<ModelRegistry>,
    drift: Arc<DriftRegistry>,
    recorder: Arc<FlightRecorder>,
    sliced: Arc<SlicedMetrics>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the OS-assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    pub fn drift(&self) -> &Arc<DriftRegistry> {
        &self.drift
    }

    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    pub fn sliced(&self) -> &Arc<SlicedMetrics> {
        &self.sliced
    }

    /// Graceful shutdown: stop accepting, let in-flight requests
    /// finish, drain queued connections, join every thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(accept) = self.accept.take() {
            self.shutdown.store(true, Ordering::Release);
            // Self-connect to wake the blocking accept() — the TCP
            // analogue of writing to a self-pipe. An unspecified bind
            // address (0.0.0.0 / ::) is not connectable everywhere, so
            // aim the wake at the same family's loopback on the bound
            // port (a v6-only listener never accepts 127.0.0.1).
            let mut wake = self.addr;
            if wake.ip().is_unspecified() {
                let loopback: std::net::IpAddr = match wake.ip() {
                    std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                    std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
                };
                wake.set_ip(loopback);
            }
            let _ = TcpStream::connect(wake);
            let _ = accept.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Bind and start the scoring server.
pub fn serve(registry: Arc<ModelRegistry>, cfg: &ServeConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| FastSurvivalError::io(format!("binding {}", cfg.addr), e))?;
    let addr = listener
        .local_addr()
        .map_err(|e| FastSurvivalError::io("resolving bound address".to_string(), e))?;
    let metrics = Arc::new(ServeMetrics::default());
    let drift = Arc::new(DriftRegistry::new(registry.root()));
    let recorder = Arc::new(FlightRecorder::new(
        cfg.recorder_capacity,
        SLOW_RING_CAP,
        cfg.slow_ms.saturating_mul(1_000),
    ));
    let sliced = Arc::new(SlicedMetrics::new());
    let access_log = match &cfg.access_log {
        None => None,
        Some(path) => {
            let file = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .map_err(|e| FastSurvivalError::io(format!("opening access log {path}"), e))?;
            Some(Arc::new(Mutex::new(file)))
        }
    };
    let shutdown = Arc::new(AtomicBool::new(false));
    let ctx = Ctx {
        registry: Arc::clone(&registry),
        batcher: Arc::new(MicroBatcher::new(cfg.batch.clone())),
        metrics: Arc::clone(&metrics),
        drift: Arc::clone(&drift),
        recorder: Arc::clone(&recorder),
        sliced: Arc::clone(&sliced),
        access_log,
        next_request_id: Arc::new(AtomicU64::new(1)),
        shutdown: Arc::clone(&shutdown),
        max_body: cfg.max_body_bytes,
    };
    let workers = cfg.workers.max(1);
    let accept = std::thread::Builder::new()
        .name("fs-accept".into())
        .spawn(move || {
            // The pool lives (and joins) inside the accept thread, so a
            // single join on this thread tears the whole server down.
            let pool = WorkerPool::new(workers, "fs-http");
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if ctx.shutdown.load(Ordering::Acquire) {
                            break; // the self-connect wake, or late client
                        }
                        let ctx = ctx.clone();
                        pool.execute(move || handle_connection(stream, &ctx));
                    }
                    Err(_) => {
                        if ctx.shutdown.load(Ordering::Acquire) {
                            break;
                        }
                        // Transient accept error (EMFILE, aborted
                        // handshake); keep serving.
                    }
                }
            }
            // pool drops here: queued connections drain, workers join.
        })
        .map_err(|e| FastSurvivalError::io("spawning accept thread".to_string(), e))?;
    Ok(ServerHandle {
        addr,
        shutdown,
        accept: Some(accept),
        metrics,
        registry,
        drift,
        recorder,
        sliced,
    })
}

// -------------------------------------------------------- wire plumbing

/// Growable read buffer that preserves bytes beyond the current request
/// (pipelining support).
struct ByteBuf {
    data: Vec<u8>,
}

impl ByteBuf {
    fn new() -> Self {
        ByteBuf { data: Vec::with_capacity(8 * 1024) }
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn fill(&mut self, stream: &mut TcpStream) -> std::io::Result<usize> {
        let mut tmp = [0u8; 8 * 1024];
        let n = stream.read(&mut tmp)?;
        self.data.extend_from_slice(&tmp[..n]);
        Ok(n)
    }

    fn find_double_crlf(&self) -> Option<usize> {
        self.data.windows(4).position(|w| w == b"\r\n\r\n")
    }

    /// Remove and return the first `n` bytes.
    fn take(&mut self, n: usize) -> Vec<u8> {
        let rest = self.data.split_off(n);
        std::mem::replace(&mut self.data, rest)
    }
}

struct Request {
    method: String,
    path: String,
    /// Raw query string (no leading `?`; empty when absent).
    query: String,
    body: Vec<u8>,
    keep_alive: bool,
    /// Client-supplied `x-request-id`, if any.
    request_id: Option<String>,
    /// When this request's first bytes were available — the lifecycle
    /// clock's zero.
    started: Instant,
    /// Microseconds of the `read` stage (first bytes → framed body).
    read_us: u64,
}

enum ReadErr {
    /// Declared body exceeds the configured cap → 413.
    TooLarge,
    /// Unparseable request → 400, then close.
    Malformed(String),
    /// Socket error / timeout / peer mid-request hangup → just close.
    Io,
}

impl From<std::io::Error> for ReadErr {
    fn from(_: std::io::Error) -> Self {
        ReadErr::Io
    }
}

/// Read one framed request. `Ok(None)` means the peer closed cleanly
/// between requests.
fn read_request(
    stream: &mut TcpStream,
    buf: &mut ByteBuf,
    max_body: usize,
) -> std::result::Result<Option<Request>, ReadErr> {
    // The lifecycle clock starts when this request's first bytes exist:
    // immediately for pipelined leftovers, otherwise at the first
    // successful socket read (idle keep-alive wait is not request time).
    let mut started = if buf.is_empty() { None } else { Some(Instant::now()) };
    let head_end = loop {
        if let Some(pos) = buf.find_double_crlf() {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(ReadErr::Malformed("request head too large".into()));
        }
        let n = buf.fill(stream)?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(ReadErr::Malformed("connection closed mid-request".into()));
        }
        started.get_or_insert_with(Instant::now);
    };
    let started = started.unwrap_or_else(Instant::now);
    let head = buf.take(head_end + 4);
    let head = std::str::from_utf8(&head)
        .map_err(|_| ReadErr::Malformed("request head is not UTF-8".into()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines
        .next()
        .ok_or_else(|| ReadErr::Malformed("empty request".into()))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ReadErr::Malformed("missing method".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| ReadErr::Malformed("missing request target".into()))?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut content_length = 0usize;
    let mut keep_alive = version != "HTTP/1.0";
    let mut expect_continue = false;
    let mut request_id: Option<String> = None;
    for line in lines {
        if line.is_empty() {
            continue; // the terminator splits into trailing empties
        }
        let (key, value) = line
            .split_once(':')
            .ok_or_else(|| ReadErr::Malformed(format!("malformed header line {line:?}")))?;
        let key = key.trim().to_ascii_lowercase();
        let value = value.trim();
        match key.as_str() {
            "content-length" => {
                content_length = value.parse::<usize>().map_err(|_| {
                    ReadErr::Malformed(format!("bad content-length {value:?}"))
                })?;
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "transfer-encoding" => {
                return Err(ReadErr::Malformed(
                    "chunked transfer encoding is not supported; send content-length"
                        .into(),
                ));
            }
            "expect" => {
                expect_continue = value.eq_ignore_ascii_case("100-continue");
            }
            "x-request-id" => {
                if !value.is_empty() {
                    request_id = Some(value.to_string());
                }
            }
            _ => {}
        }
    }
    if content_length > max_body {
        return Err(ReadErr::TooLarge);
    }
    if expect_continue && content_length > 0 {
        stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
    }
    while buf.len() < content_length {
        if buf.fill(stream)? == 0 {
            return Err(ReadErr::Malformed("connection closed mid-body".into()));
        }
    }
    let body = buf.take(content_length);
    let read_us = started.elapsed().as_micros() as u64;
    Ok(Some(Request {
        method,
        path,
        query,
        body,
        keep_alive,
        request_id,
        started,
        read_us,
    }))
}

/// Value of `key` in a raw query string (`a=1&b=2`), if present.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
        (k == key).then_some(v)
    })
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Content-Type of almost every response body.
const CT_JSON: &str = "application/json";

/// Content-Type of the Prometheus text exposition.
const CT_PROM: &str = "text/plain; version=0.0.4";

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    content_type: &str,
    keep_alive: bool,
    request_id: Option<&str>,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        reason_phrase(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    if let Some(id) = request_id {
        // Echo (or assign) the request ID so clients can correlate
        // responses with access-log lines and /debug/trace records.
        head.push_str("x-request-id: ");
        head.push_str(id);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn error_body(message: &str) -> String {
    let mut out = String::from("{\"error\": ");
    json::write_str(&mut out, message);
    out.push('}');
    out
}

fn handle_connection(mut stream: TcpStream, ctx: &Ctx) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let mut buf = ByteBuf::new();
    let mut served = 0usize;
    loop {
        if ctx.shutdown.load(Ordering::Acquire) {
            break;
        }
        let request = match read_request(&mut stream, &mut buf, ctx.max_body) {
            Ok(Some(r)) => r,
            Ok(None) => break,
            Err(ReadErr::TooLarge) => {
                let body = error_body("request body exceeds the configured limit");
                let _ = write_response(&mut stream, 413, &body, CT_JSON, false, None);
                break;
            }
            Err(ReadErr::Malformed(msg)) => {
                let _ =
                    write_response(&mut stream, 400, &error_body(&msg), CT_JSON, false, None);
                break;
            }
            Err(ReadErr::Io) => break, // includes keep-alive idle timeout
        };
        served += 1;
        let keep_alive = request.keep_alive
            && served < MAX_REQUESTS_PER_CONN
            && !ctx.shutdown.load(Ordering::Acquire);
        let request_id = request.request_id.clone().unwrap_or_else(|| {
            format!("fs-{}", ctx.next_request_id.fetch_add(1, Ordering::Relaxed))
        });
        let routed = route(ctx, &request);
        let write_started = Instant::now();
        let write_ok = write_response(
            &mut stream,
            routed.status,
            &routed.body,
            routed.content_type,
            keep_alive,
            Some(&request_id),
        )
        .is_ok();
        let write_us = write_started.elapsed().as_micros() as u64;
        let total_us = request.started.elapsed().as_micros() as u64;
        // Endpoint latency covers the full lifecycle (first byte read →
        // response flushed), matching the flight recorder's totals.
        ctx.metrics
            .endpoint(routed.endpoint)
            .record(routed.status < 400, routed.rows, total_us);
        if crate::obs::enabled() {
            let mut stage_us = [0u64; N_STAGES];
            stage_us[Stage::Read.index()] = request.read_us;
            stage_us[Stage::Parse.index()] = routed.parse_us;
            stage_us[Stage::QueueWait.index()] = routed.queue_us;
            stage_us[Stage::BatchScore.index()] = routed.score_us;
            stage_us[Stage::Serialize.index()] = routed.serialize_us;
            stage_us[Stage::Write.index()] = write_us;
            let record = RequestRecord {
                seq: ctx.recorder.begin(),
                id: request_id,
                endpoint: routed.endpoint,
                model: routed.model,
                rows: routed.rows,
                status: routed.status,
                stage_us,
                total_us,
            };
            ctx.sliced.record(&record);
            if let Some(log) = &ctx.access_log {
                let mut line = String::with_capacity(256);
                write_record_json(&record, &mut line);
                line.push('\n');
                // One write_all per line under the mutex: a single
                // syscall, and concurrent workers never interleave.
                let mut file = log.lock().unwrap();
                let _ = file.write_all(line.as_bytes());
            }
            ctx.recorder.commit(record);
        }
        if !(write_ok && keep_alive) {
            break;
        }
    }
}

/// One dispatched request: the response plus everything the
/// observability layer records about it.
struct Routed {
    status: u16,
    body: String,
    content_type: &'static str,
    /// Metrics key (`score`, `healthz`, …).
    endpoint: &'static str,
    /// Rows scored (0 off the scoring path).
    rows: u64,
    /// `name@version` that served the request; empty off the scoring
    /// path or before model resolution.
    model: String,
    parse_us: u64,
    queue_us: u64,
    score_us: u64,
    serialize_us: u64,
}

impl Routed {
    /// A non-scoring response whose whole handler duration counts as
    /// the `serialize` stage (there is nothing to parse, queue, or
    /// score).
    fn plain(
        status: u16,
        body: String,
        content_type: &'static str,
        endpoint: &'static str,
        serialize_us: u64,
    ) -> Routed {
        Routed {
            status,
            body,
            content_type,
            endpoint,
            rows: 0,
            model: String::new(),
            parse_us: 0,
            queue_us: 0,
            score_us: 0,
            serialize_us,
        }
    }
}

/// Dispatch one request.
fn route(ctx: &Ctx, request: &Request) -> Routed {
    let t0 = Instant::now();
    let method = request.method.as_str();
    // Non-scoring arms produce `(status, body, content type, endpoint)`
    // and count their whole handler duration as the serialize stage.
    let (status, body, content_type, endpoint) = match request.path.as_str() {
        "/healthz" => match method {
            "GET" => (200, healthz_body(ctx), CT_JSON, "healthz"),
            _ => (405, error_body("healthz is GET-only"), CT_JSON, "healthz"),
        },
        "/v1/models" => match method {
            "GET" => (200, models_body(ctx), CT_JSON, "models"),
            _ => (405, error_body("models is GET-only"), CT_JSON, "models"),
        },
        "/v1/reload" => match method {
            "POST" => match ctx.registry.reload() {
                Ok(report) => {
                    let names: Vec<Json> =
                        report.names.iter().map(|n| Json::Str(n.clone())).collect();
                    let doc = Json::Obj(vec![
                        ("reloaded".into(), Json::Bool(true)),
                        ("artifacts".into(), Json::Num(report.artifacts as f64)),
                        ("names".into(), Json::Arr(names)),
                    ]);
                    (200, doc.to_json_string(), CT_JSON, "reload")
                }
                // The previous state is still serving (atomic swap), so
                // a failed reload is an error reply, not an outage.
                Err(e) => (500, error_body(&e.to_string()), CT_JSON, "reload"),
            },
            _ => (405, error_body("reload is POST-only"), CT_JSON, "reload"),
        },
        "/v1/score" => match method {
            "POST" => return handle_score(ctx, &request.body, t0),
            _ => (405, error_body("score is POST-only"), CT_JSON, "score"),
        },
        "/metrics" => match method {
            "GET" => match query_param(&request.query, "format") {
                Some("prometheus") => (200, prometheus_body(ctx), CT_PROM, "metrics"),
                Some(other) => (
                    400,
                    error_body(&format!(
                        "unknown metrics format {other:?} (try \"prometheus\")"
                    )),
                    CT_JSON,
                    "metrics",
                ),
                None => (200, metrics_body(ctx), CT_JSON, "metrics"),
            },
            _ => (405, error_body("metrics is GET-only"), CT_JSON, "metrics"),
        },
        "/debug/trace" => match method {
            "GET" => {
                let n = match query_param(&request.query, "n") {
                    None => Ok(50usize),
                    Some(v) => v.parse::<usize>().map_err(|_| v.to_string()),
                };
                match n {
                    Ok(n) => (200, render_debug_trace(&ctx.recorder, n), CT_JSON, "trace"),
                    Err(bad) => (
                        400,
                        error_body(&format!("bad trace count n={bad:?}")),
                        CT_JSON,
                        "trace",
                    ),
                }
            }
            _ => (405, error_body("debug/trace is GET-only"), CT_JSON, "trace"),
        },
        other => (
            404,
            error_body(&format!("no such endpoint {other:?}")),
            CT_JSON,
            "other",
        ),
    };
    let serialize_us = t0.elapsed().as_micros() as u64;
    Routed::plain(status, body, content_type, endpoint, serialize_us)
}

/// `/healthz`: liveness plus what is actually being served — every
/// loaded `name@version` and the monotonic registry generation, so a
/// publisher can confirm its reload landed without scoring anything.
fn healthz_body(ctx: &Ctx) -> String {
    let state = ctx.registry.snapshot();
    let items: Vec<Json> = state
        .list()
        .iter()
        .map(|m| {
            Json::Obj(vec![
                ("name".into(), Json::Str(m.name().to_string())),
                ("version".into(), Json::Num(m.version() as f64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("status".into(), Json::Str("ok".into())),
        ("artifacts".into(), Json::Num(state.n_artifacts() as f64)),
        ("generation".into(), Json::Num(ctx.registry.generation() as f64)),
        ("models".into(), Json::Arr(items)),
    ])
    .to_json_string()
}

/// `/metrics`: the endpoint counters document with the per-model drift
/// block, batcher gauges, and sliced SLO series appended.
fn metrics_body(ctx: &Ctx) -> String {
    use std::fmt::Write as _;
    let mut body = ctx.metrics.to_json();
    debug_assert!(body.ends_with('}'));
    body.pop();
    body.push_str(", \"drift\": ");
    ctx.drift.write_json(&mut body);
    let g = ctx.batcher.gauges();
    let _ = write!(
        body,
        ", \"batcher\": {{\"queue_depth_hwm\": {}, \"flushes\": {}, \"flushed_requests\": {}",
        g.queue_depth_hwm, g.flushes, g.flushed_requests
    );
    body.push_str(", \"mean_requests_per_flush\": ");
    json::write_f64(&mut body, g.mean_requests_per_flush());
    body.push_str(", \"flush_rows_p50\": ");
    json::write_f64(&mut body, g.flush_rows_p50());
    body.push_str(", \"flush_rows_p99\": ");
    json::write_f64(&mut body, g.flush_rows_p99());
    body.push('}');
    body.push_str(", \"slices\": ");
    write_sliced_json(&ctx.sliced.snapshot(), &mut body);
    body.push('}');
    body
}

/// `/metrics?format=prometheus`: endpoint counters and histograms, then
/// batcher gauges, then the sliced SLO series.
fn prometheus_body(ctx: &Ctx) -> String {
    use std::fmt::Write as _;
    let mut out = ctx.metrics.to_prometheus();
    let g = ctx.batcher.gauges();
    out.push_str("# TYPE fastsurvival_batch_queue_depth_hwm gauge\n");
    let _ = writeln!(out, "fastsurvival_batch_queue_depth_hwm {}", g.queue_depth_hwm);
    out.push_str("# TYPE fastsurvival_batch_flushes_total counter\n");
    let _ = writeln!(out, "fastsurvival_batch_flushes_total {}", g.flushes);
    out.push_str("# TYPE fastsurvival_batch_flushed_requests_total counter\n");
    let _ = writeln!(out, "fastsurvival_batch_flushed_requests_total {}", g.flushed_requests);
    out.push_str("# TYPE fastsurvival_batch_flush_rows histogram\n");
    write_prom_cumulative(
        &mut out,
        "fastsurvival_batch_flush_rows",
        "",
        &g.flush_rows_buckets,
        g.flush_rows_count,
        g.flush_rows_sum,
    );
    out.push_str(&render_sliced_prometheus(&ctx.sliced.snapshot()));
    out
}

fn models_body(ctx: &Ctx) -> String {
    let state = ctx.registry.snapshot();
    let items: Vec<Json> = state
        .list()
        .iter()
        .map(|m| {
            Json::Obj(vec![
                ("name".into(), Json::Str(m.name().to_string())),
                ("version".into(), Json::Num(m.version() as f64)),
                ("features".into(), Json::Num(m.p() as f64)),
                ("nonzero".into(), Json::Num(m.support_len() as f64)),
                (
                    "latest".into(),
                    Json::Bool(state.latest_version(m.name()) == Some(m.version())),
                ),
            ])
        })
        .collect();
    Json::Obj(vec![("models".into(), Json::Arr(items))]).to_json_string()
}

/// A failed scoring request: everything before the failure counts as
/// parse time (validation is the parse stage).
fn score_fail(status: u16, message: &str, model: String, t0: Instant) -> Routed {
    Routed {
        status,
        body: error_body(message),
        content_type: CT_JSON,
        endpoint: "score",
        rows: 0,
        model,
        parse_us: t0.elapsed().as_micros() as u64,
        queue_us: 0,
        score_us: 0,
        serialize_us: 0,
    }
}

fn handle_score(ctx: &Ctx, body: &[u8], t0: Instant) -> Routed {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return score_fail(400, "request body is not UTF-8", String::new(), t0),
    };
    let doc = match json::parse(text) {
        Ok(d) => d,
        Err(e) => {
            return score_fail(400, &format!("malformed JSON body: {e}"), String::new(), t0)
        }
    };
    let spec = match doc.get("model") {
        None => "",
        Some(v) => match v.as_str() {
            Ok(s) => s,
            Err(_) => return score_fail(400, "\"model\" must be a string", String::new(), t0),
        },
    };
    // A syntactically bad spec is the client's error (400); only a
    // well-formed spec that names nothing deserves 404.
    if let Err(e) = parse_spec(spec) {
        return score_fail(400, &e.to_string(), String::new(), t0);
    }
    let model = match ctx.registry.resolve(spec) {
        Ok(m) => m,
        Err(e) => return score_fail(404, &e.to_string(), String::new(), t0),
    };
    let model_spec = model.spec();
    let rows_json = match doc.get("rows") {
        Some(r) => r,
        None => return score_fail(400, "missing \"rows\"", model_spec, t0),
    };
    let row_values = match rows_json.as_array() {
        Ok(a) => a,
        Err(_) => {
            return score_fail(400, "\"rows\" must be an array of arrays", model_spec, t0)
        }
    };
    let p = model.p();
    let n_rows = row_values.len();
    // Capacity is a hint from *unvalidated* input: cap it by the body
    // length (every JSON number costs ≥ 1 byte) so a hostile row count
    // can't force a huge up-front allocation before the per-row width
    // checks below reject it.
    let mut flat: Vec<f64> = Vec::with_capacity(n_rows.saturating_mul(p).min(text.len()));
    for (i, row) in row_values.iter().enumerate() {
        let values = match row.as_f64_vec() {
            Ok(v) => v,
            Err(_) => {
                return score_fail(
                    400,
                    &format!("row {i} is not a numeric array"),
                    model_spec,
                    t0,
                )
            }
        };
        // Overflowing literals (1e999 → inf) and nulls (→ NaN) would
        // turn the response's risk array into nulls, breaking the
        // documented numeric schema — reject them like bad horizons.
        if values.iter().any(|v| !v.is_finite()) {
            return score_fail(
                400,
                &format!("row {i} contains a non-finite value"),
                model_spec,
                t0,
            );
        }
        if values.len() != p {
            return score_fail(
                400,
                &format!(
                    "row {i} has {} features, model {} expects {p}",
                    values.len(),
                    model_spec
                ),
                model_spec.clone(),
                t0,
            );
        }
        flat.extend_from_slice(&values);
    }
    let horizons = match doc.get("horizons") {
        None => None,
        Some(h) => match h.as_f64_vec() {
            Ok(v) => {
                if let Some(bad) = v.iter().find(|x| !x.is_finite()) {
                    return score_fail(
                        400,
                        &format!("horizons must be finite, got {bad}"),
                        model_spec,
                        t0,
                    );
                }
                Some(v)
            }
            Err(_) => {
                return score_fail(
                    400,
                    "\"horizons\" must be a numeric array",
                    model_spec,
                    t0,
                )
            }
        },
    };
    let echo_horizons = horizons.clone();
    // Parse stage ends here: the request is validated and handed to the
    // micro-batcher.
    let t_submit = Instant::now();
    let parse_us = t_submit.saturating_duration_since(t0).as_micros() as u64;
    let receiver = ctx.batcher.submit(Arc::clone(&model), flat, n_rows, horizons);
    let recv = receiver.recv();
    let t_scored = Instant::now();
    // submit → result covers queue_wait + batch_score. The batcher
    // reports exact queue time (enqueue → claim); the remainder —
    // sweep, result routing, channel wake — is scoring.
    let wait_us = t_scored.saturating_duration_since(t_submit).as_micros() as u64;
    let output = match recv {
        Ok(Ok(o)) => o,
        Ok(Err(e)) => {
            let mut r = score_fail(400, &e.to_string(), model_spec, t0);
            r.parse_us = parse_us;
            r.score_us = wait_us;
            return r;
        }
        Err(_) => {
            let mut r = score_fail(500, "scoring queue dropped the request", model_spec, t0);
            r.parse_us = parse_us;
            r.score_us = wait_us;
            return r;
        }
    };
    let queue_us = output.queue_us.min(wait_us);
    let score_us = wait_us - queue_us;
    ctx.drift.tracker(&model_spec).record_all(&output.risk);
    let mut body = String::with_capacity(64 + output.risk.len() * 20);
    body.push_str("{\"model\": ");
    json::write_str(&mut body, &model_spec);
    body.push_str(", \"n\": ");
    body.push_str(&n_rows.to_string());
    body.push_str(", \"risk\": ");
    json::write_f64_array(&mut body, &output.risk);
    if let (Some(h), Some(curves)) = (echo_horizons, &output.survival) {
        body.push_str(", \"horizons\": ");
        json::write_f64_array(&mut body, &h);
        body.push_str(", \"survival\": [");
        for (i, curve) in curves.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            json::write_f64_array(&mut body, curve);
        }
        body.push(']');
    }
    body.push('}');
    let serialize_us = t_scored.elapsed().as_micros() as u64;
    Routed {
        status: 200,
        body,
        content_type: CT_JSON,
        endpoint: "score",
        rows: n_rows as u64,
        model: model_spec,
        parse_us,
        queue_us,
        score_us,
        serialize_us,
    }
}

// ------------------------------------------------------------ tiny client

/// A minimal buffered HTTP/1.1 client over one keep-alive connection —
/// enough for the smoke harness, the integration tests, and scripted
/// health checks, with the same framing rules as the server.
pub struct HttpClient {
    stream: TcpStream,
    buf: ByteBuf,
}

/// A parsed client-side response.
#[derive(Clone, Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub body: String,
    /// The server answered `Connection: close` (e.g. after an error or
    /// the per-connection request cap) — reconnect before the next
    /// request instead of writing into a dying socket.
    pub close: bool,
    /// The server's `x-request-id` response header (echoed from the
    /// request, or server-generated).
    pub request_id: Option<String>,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(HttpClient { stream, buf: ByteBuf::new() })
    }

    /// Send one request and read its response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<ClientResponse> {
        self.request_with(method, path, body, &[])
    }

    /// Send one request with extra headers (e.g. `x-request-id`) and
    /// read its response.
    pub fn request_with(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra_headers: &[(&str, &str)],
    ) -> std::io::Result<ClientResponse> {
        let mut req = format!(
            "{method} {path} HTTP/1.1\r\nHost: fastsurvival\r\nConnection: keep-alive\r\n"
        );
        for (k, v) in extra_headers {
            req.push_str(&format!("{k}: {v}\r\n"));
        }
        if let Some(b) = body {
            req.push_str(&format!(
                "Content-Type: application/json\r\nContent-Length: {}\r\n",
                b.len()
            ));
        }
        req.push_str("\r\n");
        if let Some(b) = body {
            req.push_str(b);
        }
        self.send_raw(req.as_bytes())?;
        self.read_response()
    }

    pub fn get(&mut self, path: &str) -> std::io::Result<ClientResponse> {
        self.request("GET", path, None)
    }

    pub fn post(&mut self, path: &str, body: &str) -> std::io::Result<ClientResponse> {
        self.request("POST", path, Some(body))
    }

    /// Write raw bytes (e.g. several pipelined requests at once); pair
    /// with one [`HttpClient::read_response`] per request sent.
    pub fn send_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()
    }

    /// Read exactly one content-length-framed response.
    pub fn read_response(&mut self) -> std::io::Result<ClientResponse> {
        let malformed =
            |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
        let head_end = loop {
            if let Some(pos) = self.buf.find_double_crlf() {
                break pos;
            }
            if self.buf.fill(&mut self.stream)? == 0 {
                return Err(malformed("connection closed before response head"));
            }
        };
        let head = self.buf.take(head_end + 4);
        let head =
            std::str::from_utf8(&head).map_err(|_| malformed("non-UTF-8 response head"))?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().ok_or_else(|| malformed("empty response"))?;
        let status = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| malformed("bad status line"))?;
        let mut content_length = 0usize;
        let mut close = false;
        let mut request_id: Option<String> = None;
        for line in lines {
            if let Some((k, v)) = line.split_once(':') {
                let k = k.trim();
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| malformed("bad content-length"))?;
                } else if k.eq_ignore_ascii_case("connection") {
                    close = v.trim().to_ascii_lowercase().contains("close");
                } else if k.eq_ignore_ascii_case("x-request-id") {
                    request_id = Some(v.trim().to_string());
                }
            }
        }
        while self.buf.len() < content_length {
            if self.buf.fill(&mut self.stream)? == 0 {
                return Err(malformed("connection closed mid-body"));
            }
        }
        let body = self.buf.take(content_length);
        let body =
            String::from_utf8(body).map_err(|_| malformed("non-UTF-8 response body"))?;
        Ok(ClientResponse { status, body, close, request_id })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_buf_take_preserves_pipelined_remainder() {
        let mut buf = ByteBuf::new();
        buf.data.extend_from_slice(b"HEAD\r\n\r\nBODYNEXT");
        assert_eq!(buf.find_double_crlf(), Some(4));
        assert_eq!(buf.take(8), b"HEAD\r\n\r\n");
        assert_eq!(buf.take(4), b"BODY");
        assert_eq!(buf.data, b"NEXT");
        assert_eq!(buf.len(), 4);
        assert!(!buf.is_empty());
    }

    #[test]
    fn reason_phrases_cover_emitted_codes() {
        for status in [200u16, 400, 404, 405, 413, 500] {
            assert_ne!(reason_phrase(status), "Unknown");
        }
    }

    #[test]
    fn query_params_parse() {
        assert_eq!(query_param("format=prometheus", "format"), Some("prometheus"));
        assert_eq!(query_param("a=1&format=prometheus&b", "format"), Some("prometheus"));
        assert_eq!(query_param("flag", "flag"), Some(""));
        assert_eq!(query_param("", "format"), None);
        assert_eq!(query_param("formatx=1", "format"), None);
    }

    #[test]
    fn error_bodies_are_json() {
        let body = error_body("quote \" and \\ backslash");
        let doc = json::parse(&body).unwrap();
        assert_eq!(
            doc.require("error").unwrap().as_str().unwrap(),
            "quote \" and \\ backslash"
        );
    }
}
