//! A minimal hand-rolled HTTP/1.1 scoring server over
//! `std::net::TcpListener` — no external dependencies, request bodies
//! parsed with the in-repo JSON parser ([`crate::api::json`]).
//!
//! Protocol support is deliberately small but correct: content-length
//! framing (chunked bodies are rejected with 400), keep-alive with
//! pipelining (leftover bytes after one request's body start the next),
//! `Expect: 100-continue`, an oversized-body guard (413 before the body
//! is read), and graceful shutdown — the accept loop is woken by a
//! self-connect (the TCP flavor of the classic self-pipe trick), worker
//! threads finish their in-flight request, and queued connections drain
//! before the pool joins.
//!
//! Endpoints:
//!
//! | route            | body                                     | reply |
//! |------------------|------------------------------------------|-------|
//! | `POST /v1/score` | `{"model": "name@ver"?, "rows": [[f64…]…], "horizons": [f64…]?}` | `{"model", "n", "risk": […], "survival": [[…]…]?}` |
//! | `GET /v1/models` | —                                        | `{"models": [{name, version, features, nonzero, latest}…]}` |
//! | `POST /v1/reload`| —                                        | `{"reloaded", "artifacts", "names"}` |
//! | `GET /healthz`   | —                                        | `{"status": "ok", "artifacts", "generation", "models": […]}` |
//! | `GET /metrics`   | —                                        | per-endpoint counters + latency quantiles + training gauges + per-model drift |
//!
//! `GET /metrics?format=prometheus` returns the same snapshot as
//! Prometheus text exposition (`text/plain`) instead of JSON.

use super::drift::DriftRegistry;
use super::registry::{parse_spec, ModelRegistry};
use super::scorer::{BatchConfig, MicroBatcher};
use super::stats::ServeMetrics;
use crate::api::json::{self, Json};
use crate::error::{FastSurvivalError, Result};
use crate::util::parallel::{num_threads, WorkerPool};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Cap on request-head size (request line + headers).
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Keep-alive idle window before a connection is closed.
const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// Requests served on one keep-alive connection before the server
/// answers `Connection: close`. A connection parks a worker for its
/// whole lifetime, so this cap (together with [`IDLE_TIMEOUT`] and the
/// over-provisioned default worker count) bounds how long persistent
/// clients can monopolize the pool while new connections queue.
const MAX_REQUESTS_PER_CONN: usize = 256;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; use port 0 for an OS-assigned port.
    pub addr: String,
    /// Connection-handling worker threads. These spend their lives in
    /// blocking socket I/O (each parks on one connection at a time;
    /// scoring parallelism comes from the micro-batcher's own data-
    /// parallel sweep), so the default deliberately over-provisions
    /// relative to cores — see [`ServeConfig::default_workers`].
    pub workers: usize,
    /// Request bodies above this size are refused with 413.
    pub max_body_bytes: usize,
    /// Micro-batching knobs for the scoring queue.
    pub batch: BatchConfig,
}

impl ServeConfig {
    /// Default connection-worker count: 4× the compute threads, at
    /// least 16 — I/O-bound workers are cheap, and a pool much larger
    /// than the expected persistent-connection count is what keeps
    /// fresh connections (health checks included) from queueing behind
    /// keep-alive clients.
    pub fn default_workers() -> usize {
        (num_threads() * 4).max(16)
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            workers: ServeConfig::default_workers(),
            max_body_bytes: 8 << 20,
            batch: BatchConfig::default(),
        }
    }
}

/// Everything a connection handler needs, all cheaply cloneable.
#[derive(Clone)]
struct Ctx {
    registry: Arc<ModelRegistry>,
    batcher: Arc<MicroBatcher>,
    metrics: Arc<ServeMetrics>,
    /// Drift counters live here, beside the registry handle rather than
    /// inside the hot-swapped state, so a `/v1/reload` never resets them.
    drift: Arc<DriftRegistry>,
    shutdown: Arc<AtomicBool>,
    max_body: usize,
}

/// A running server. Dropping the handle shuts it down gracefully.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    registry: Arc<ModelRegistry>,
    drift: Arc<DriftRegistry>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the OS-assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    pub fn drift(&self) -> &Arc<DriftRegistry> {
        &self.drift
    }

    /// Graceful shutdown: stop accepting, let in-flight requests
    /// finish, drain queued connections, join every thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(accept) = self.accept.take() {
            self.shutdown.store(true, Ordering::Release);
            // Self-connect to wake the blocking accept() — the TCP
            // analogue of writing to a self-pipe. An unspecified bind
            // address (0.0.0.0 / ::) is not connectable everywhere, so
            // aim the wake at the same family's loopback on the bound
            // port (a v6-only listener never accepts 127.0.0.1).
            let mut wake = self.addr;
            if wake.ip().is_unspecified() {
                let loopback: std::net::IpAddr = match wake.ip() {
                    std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                    std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
                };
                wake.set_ip(loopback);
            }
            let _ = TcpStream::connect(wake);
            let _ = accept.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Bind and start the scoring server.
pub fn serve(registry: Arc<ModelRegistry>, cfg: &ServeConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| FastSurvivalError::io(format!("binding {}", cfg.addr), e))?;
    let addr = listener
        .local_addr()
        .map_err(|e| FastSurvivalError::io("resolving bound address".to_string(), e))?;
    let metrics = Arc::new(ServeMetrics::default());
    let drift = Arc::new(DriftRegistry::new(registry.root()));
    let shutdown = Arc::new(AtomicBool::new(false));
    let ctx = Ctx {
        registry: Arc::clone(&registry),
        batcher: Arc::new(MicroBatcher::new(cfg.batch.clone())),
        metrics: Arc::clone(&metrics),
        drift: Arc::clone(&drift),
        shutdown: Arc::clone(&shutdown),
        max_body: cfg.max_body_bytes,
    };
    let workers = cfg.workers.max(1);
    let accept = std::thread::Builder::new()
        .name("fs-accept".into())
        .spawn(move || {
            // The pool lives (and joins) inside the accept thread, so a
            // single join on this thread tears the whole server down.
            let pool = WorkerPool::new(workers, "fs-http");
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if ctx.shutdown.load(Ordering::Acquire) {
                            break; // the self-connect wake, or late client
                        }
                        let ctx = ctx.clone();
                        pool.execute(move || handle_connection(stream, &ctx));
                    }
                    Err(_) => {
                        if ctx.shutdown.load(Ordering::Acquire) {
                            break;
                        }
                        // Transient accept error (EMFILE, aborted
                        // handshake); keep serving.
                    }
                }
            }
            // pool drops here: queued connections drain, workers join.
        })
        .map_err(|e| FastSurvivalError::io("spawning accept thread".to_string(), e))?;
    Ok(ServerHandle { addr, shutdown, accept: Some(accept), metrics, registry, drift })
}

// -------------------------------------------------------- wire plumbing

/// Growable read buffer that preserves bytes beyond the current request
/// (pipelining support).
struct ByteBuf {
    data: Vec<u8>,
}

impl ByteBuf {
    fn new() -> Self {
        ByteBuf { data: Vec::with_capacity(8 * 1024) }
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn fill(&mut self, stream: &mut TcpStream) -> std::io::Result<usize> {
        let mut tmp = [0u8; 8 * 1024];
        let n = stream.read(&mut tmp)?;
        self.data.extend_from_slice(&tmp[..n]);
        Ok(n)
    }

    fn find_double_crlf(&self) -> Option<usize> {
        self.data.windows(4).position(|w| w == b"\r\n\r\n")
    }

    /// Remove and return the first `n` bytes.
    fn take(&mut self, n: usize) -> Vec<u8> {
        let rest = self.data.split_off(n);
        std::mem::replace(&mut self.data, rest)
    }
}

struct Request {
    method: String,
    path: String,
    /// Raw query string (no leading `?`; empty when absent).
    query: String,
    body: Vec<u8>,
    keep_alive: bool,
}

enum ReadErr {
    /// Declared body exceeds the configured cap → 413.
    TooLarge,
    /// Unparseable request → 400, then close.
    Malformed(String),
    /// Socket error / timeout / peer mid-request hangup → just close.
    Io,
}

impl From<std::io::Error> for ReadErr {
    fn from(_: std::io::Error) -> Self {
        ReadErr::Io
    }
}

/// Read one framed request. `Ok(None)` means the peer closed cleanly
/// between requests.
fn read_request(
    stream: &mut TcpStream,
    buf: &mut ByteBuf,
    max_body: usize,
) -> std::result::Result<Option<Request>, ReadErr> {
    let head_end = loop {
        if let Some(pos) = buf.find_double_crlf() {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(ReadErr::Malformed("request head too large".into()));
        }
        let n = buf.fill(stream)?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(ReadErr::Malformed("connection closed mid-request".into()));
        }
    };
    let head = buf.take(head_end + 4);
    let head = std::str::from_utf8(&head)
        .map_err(|_| ReadErr::Malformed("request head is not UTF-8".into()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines
        .next()
        .ok_or_else(|| ReadErr::Malformed("empty request".into()))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ReadErr::Malformed("missing method".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| ReadErr::Malformed("missing request target".into()))?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut content_length = 0usize;
    let mut keep_alive = version != "HTTP/1.0";
    let mut expect_continue = false;
    for line in lines {
        if line.is_empty() {
            continue; // the terminator splits into trailing empties
        }
        let (key, value) = line
            .split_once(':')
            .ok_or_else(|| ReadErr::Malformed(format!("malformed header line {line:?}")))?;
        let key = key.trim().to_ascii_lowercase();
        let value = value.trim();
        match key.as_str() {
            "content-length" => {
                content_length = value.parse::<usize>().map_err(|_| {
                    ReadErr::Malformed(format!("bad content-length {value:?}"))
                })?;
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "transfer-encoding" => {
                return Err(ReadErr::Malformed(
                    "chunked transfer encoding is not supported; send content-length"
                        .into(),
                ));
            }
            "expect" => {
                expect_continue = value.eq_ignore_ascii_case("100-continue");
            }
            _ => {}
        }
    }
    if content_length > max_body {
        return Err(ReadErr::TooLarge);
    }
    if expect_continue && content_length > 0 {
        stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
    }
    while buf.len() < content_length {
        if buf.fill(stream)? == 0 {
            return Err(ReadErr::Malformed("connection closed mid-body".into()));
        }
    }
    let body = buf.take(content_length);
    Ok(Some(Request { method, path, query, body, keep_alive }))
}

/// Value of `key` in a raw query string (`a=1&b=2`), if present.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
        (k == key).then_some(v)
    })
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Content-Type of almost every response body.
const CT_JSON: &str = "application/json";

/// Content-Type of the Prometheus text exposition.
const CT_PROM: &str = "text/plain; version=0.0.4";

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    content_type: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason_phrase(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn error_body(message: &str) -> String {
    let mut out = String::from("{\"error\": ");
    json::write_str(&mut out, message);
    out.push('}');
    out
}

fn handle_connection(mut stream: TcpStream, ctx: &Ctx) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let mut buf = ByteBuf::new();
    let mut served = 0usize;
    loop {
        if ctx.shutdown.load(Ordering::Acquire) {
            break;
        }
        let request = match read_request(&mut stream, &mut buf, ctx.max_body) {
            Ok(Some(r)) => r,
            Ok(None) => break,
            Err(ReadErr::TooLarge) => {
                let body = error_body("request body exceeds the configured limit");
                let _ = write_response(&mut stream, 413, &body, CT_JSON, false);
                break;
            }
            Err(ReadErr::Malformed(msg)) => {
                let _ = write_response(&mut stream, 400, &error_body(&msg), CT_JSON, false);
                break;
            }
            Err(ReadErr::Io) => break, // includes keep-alive idle timeout
        };
        served += 1;
        let keep_alive = request.keep_alive
            && served < MAX_REQUESTS_PER_CONN
            && !ctx.shutdown.load(Ordering::Acquire);
        let started = Instant::now();
        let (status, body, content_type, endpoint, rows) = route(ctx, &request);
        let us = started.elapsed().as_micros() as u64;
        ctx.metrics.endpoint(endpoint).record(status < 400, rows, us);
        if write_response(&mut stream, status, &body, content_type, keep_alive).is_err() {
            break;
        }
        if !keep_alive {
            break;
        }
    }
}

/// Dispatch one request → `(status, body, content type, metrics key,
/// rows scored)`.
fn route(ctx: &Ctx, request: &Request) -> (u16, String, &'static str, &'static str, u64) {
    let method = request.method.as_str();
    match request.path.as_str() {
        "/healthz" => match method {
            "GET" => (200, healthz_body(ctx), CT_JSON, "healthz", 0),
            _ => (405, error_body("healthz is GET-only"), CT_JSON, "healthz", 0),
        },
        "/v1/models" => match method {
            "GET" => (200, models_body(ctx), CT_JSON, "models", 0),
            _ => (405, error_body("models is GET-only"), CT_JSON, "models", 0),
        },
        "/v1/reload" => match method {
            "POST" => match ctx.registry.reload() {
                Ok(report) => {
                    let names: Vec<Json> =
                        report.names.iter().map(|n| Json::Str(n.clone())).collect();
                    let doc = Json::Obj(vec![
                        ("reloaded".into(), Json::Bool(true)),
                        ("artifacts".into(), Json::Num(report.artifacts as f64)),
                        ("names".into(), Json::Arr(names)),
                    ]);
                    (200, doc.to_json_string(), CT_JSON, "reload", 0)
                }
                // The previous state is still serving (atomic swap), so
                // a failed reload is an error reply, not an outage.
                Err(e) => (500, error_body(&e.to_string()), CT_JSON, "reload", 0),
            },
            _ => (405, error_body("reload is POST-only"), CT_JSON, "reload", 0),
        },
        "/v1/score" => match method {
            "POST" => {
                let (status, body, rows) = handle_score(ctx, &request.body);
                (status, body, CT_JSON, "score", rows)
            }
            _ => (405, error_body("score is POST-only"), CT_JSON, "score", 0),
        },
        "/metrics" => match method {
            "GET" => match query_param(&request.query, "format") {
                Some("prometheus") => {
                    (200, ctx.metrics.to_prometheus(), CT_PROM, "metrics", 0)
                }
                Some(other) => (
                    400,
                    error_body(&format!(
                        "unknown metrics format {other:?} (try \"prometheus\")"
                    )),
                    CT_JSON,
                    "metrics",
                    0,
                ),
                None => (200, metrics_body(ctx), CT_JSON, "metrics", 0),
            },
            _ => (405, error_body("metrics is GET-only"), CT_JSON, "metrics", 0),
        },
        other => (
            404,
            error_body(&format!("no such endpoint {other:?}")),
            CT_JSON,
            "other",
            0,
        ),
    }
}

/// `/healthz`: liveness plus what is actually being served — every
/// loaded `name@version` and the monotonic registry generation, so a
/// publisher can confirm its reload landed without scoring anything.
fn healthz_body(ctx: &Ctx) -> String {
    let state = ctx.registry.snapshot();
    let items: Vec<Json> = state
        .list()
        .iter()
        .map(|m| {
            Json::Obj(vec![
                ("name".into(), Json::Str(m.name().to_string())),
                ("version".into(), Json::Num(m.version() as f64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("status".into(), Json::Str("ok".into())),
        ("artifacts".into(), Json::Num(state.n_artifacts() as f64)),
        ("generation".into(), Json::Num(ctx.registry.generation() as f64)),
        ("models".into(), Json::Arr(items)),
    ])
    .to_json_string()
}

/// `/metrics`: the endpoint counters document with the per-model drift
/// block appended.
fn metrics_body(ctx: &Ctx) -> String {
    let mut body = ctx.metrics.to_json();
    debug_assert!(body.ends_with('}'));
    body.pop();
    body.push_str(", \"drift\": ");
    ctx.drift.write_json(&mut body);
    body.push('}');
    body
}

fn models_body(ctx: &Ctx) -> String {
    let state = ctx.registry.snapshot();
    let items: Vec<Json> = state
        .list()
        .iter()
        .map(|m| {
            Json::Obj(vec![
                ("name".into(), Json::Str(m.name().to_string())),
                ("version".into(), Json::Num(m.version() as f64)),
                ("features".into(), Json::Num(m.p() as f64)),
                ("nonzero".into(), Json::Num(m.support_len() as f64)),
                (
                    "latest".into(),
                    Json::Bool(state.latest_version(m.name()) == Some(m.version())),
                ),
            ])
        })
        .collect();
    Json::Obj(vec![("models".into(), Json::Arr(items))]).to_json_string()
}

fn handle_score(ctx: &Ctx, body: &[u8]) -> (u16, String, u64) {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return (400, error_body("request body is not UTF-8"), 0),
    };
    let doc = match json::parse(text) {
        Ok(d) => d,
        Err(e) => return (400, error_body(&format!("malformed JSON body: {e}")), 0),
    };
    let spec = match doc.get("model") {
        None => "",
        Some(v) => match v.as_str() {
            Ok(s) => s,
            Err(_) => return (400, error_body("\"model\" must be a string"), 0),
        },
    };
    // A syntactically bad spec is the client's error (400); only a
    // well-formed spec that names nothing deserves 404.
    if let Err(e) = parse_spec(spec) {
        return (400, error_body(&e.to_string()), 0);
    }
    let model = match ctx.registry.resolve(spec) {
        Ok(m) => m,
        Err(e) => return (404, error_body(&e.to_string()), 0),
    };
    let rows_json = match doc.get("rows") {
        Some(r) => r,
        None => return (400, error_body("missing \"rows\""), 0),
    };
    let row_values = match rows_json.as_array() {
        Ok(a) => a,
        Err(_) => return (400, error_body("\"rows\" must be an array of arrays"), 0),
    };
    let p = model.p();
    let n_rows = row_values.len();
    // Capacity is a hint from *unvalidated* input: cap it by the body
    // length (every JSON number costs ≥ 1 byte) so a hostile row count
    // can't force a huge up-front allocation before the per-row width
    // checks below reject it.
    let mut flat: Vec<f64> = Vec::with_capacity(n_rows.saturating_mul(p).min(text.len()));
    for (i, row) in row_values.iter().enumerate() {
        let values = match row.as_f64_vec() {
            Ok(v) => v,
            Err(_) => {
                return (400, error_body(&format!("row {i} is not a numeric array")), 0)
            }
        };
        // Overflowing literals (1e999 → inf) and nulls (→ NaN) would
        // turn the response's risk array into nulls, breaking the
        // documented numeric schema — reject them like bad horizons.
        if values.iter().any(|v| !v.is_finite()) {
            return (
                400,
                error_body(&format!("row {i} contains a non-finite value")),
                0,
            );
        }
        if values.len() != p {
            return (
                400,
                error_body(&format!(
                    "row {i} has {} features, model {} expects {p}",
                    values.len(),
                    model.spec()
                )),
                0,
            );
        }
        flat.extend_from_slice(&values);
    }
    let horizons = match doc.get("horizons") {
        None => None,
        Some(h) => match h.as_f64_vec() {
            Ok(v) => {
                if let Some(bad) = v.iter().find(|x| !x.is_finite()) {
                    return (
                        400,
                        error_body(&format!("horizons must be finite, got {bad}")),
                        0,
                    );
                }
                Some(v)
            }
            Err(_) => return (400, error_body("\"horizons\" must be a numeric array"), 0),
        },
    };
    let echo_horizons = horizons.clone();
    let receiver = ctx.batcher.submit(Arc::clone(&model), flat, n_rows, horizons);
    let output = match receiver.recv() {
        Ok(Ok(o)) => o,
        Ok(Err(e)) => return (400, error_body(&e.to_string()), 0),
        Err(_) => return (500, error_body("scoring queue dropped the request"), 0),
    };
    ctx.drift.tracker(&model.spec()).record_all(&output.risk);
    let mut body = String::with_capacity(64 + output.risk.len() * 20);
    body.push_str("{\"model\": ");
    json::write_str(&mut body, &model.spec());
    body.push_str(", \"n\": ");
    body.push_str(&n_rows.to_string());
    body.push_str(", \"risk\": ");
    json::write_f64_array(&mut body, &output.risk);
    if let (Some(h), Some(curves)) = (echo_horizons, &output.survival) {
        body.push_str(", \"horizons\": ");
        json::write_f64_array(&mut body, &h);
        body.push_str(", \"survival\": [");
        for (i, curve) in curves.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            json::write_f64_array(&mut body, curve);
        }
        body.push(']');
    }
    body.push('}');
    (200, body, n_rows as u64)
}

// ------------------------------------------------------------ tiny client

/// A minimal buffered HTTP/1.1 client over one keep-alive connection —
/// enough for the smoke harness, the integration tests, and scripted
/// health checks, with the same framing rules as the server.
pub struct HttpClient {
    stream: TcpStream,
    buf: ByteBuf,
}

/// A parsed client-side response.
#[derive(Clone, Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub body: String,
    /// The server answered `Connection: close` (e.g. after an error or
    /// the per-connection request cap) — reconnect before the next
    /// request instead of writing into a dying socket.
    pub close: bool,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(HttpClient { stream, buf: ByteBuf::new() })
    }

    /// Send one request and read its response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<ClientResponse> {
        let mut req = format!(
            "{method} {path} HTTP/1.1\r\nHost: fastsurvival\r\nConnection: keep-alive\r\n"
        );
        if let Some(b) = body {
            req.push_str(&format!(
                "Content-Type: application/json\r\nContent-Length: {}\r\n",
                b.len()
            ));
        }
        req.push_str("\r\n");
        if let Some(b) = body {
            req.push_str(b);
        }
        self.send_raw(req.as_bytes())?;
        self.read_response()
    }

    pub fn get(&mut self, path: &str) -> std::io::Result<ClientResponse> {
        self.request("GET", path, None)
    }

    pub fn post(&mut self, path: &str, body: &str) -> std::io::Result<ClientResponse> {
        self.request("POST", path, Some(body))
    }

    /// Write raw bytes (e.g. several pipelined requests at once); pair
    /// with one [`HttpClient::read_response`] per request sent.
    pub fn send_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()
    }

    /// Read exactly one content-length-framed response.
    pub fn read_response(&mut self) -> std::io::Result<ClientResponse> {
        let malformed =
            |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
        let head_end = loop {
            if let Some(pos) = self.buf.find_double_crlf() {
                break pos;
            }
            if self.buf.fill(&mut self.stream)? == 0 {
                return Err(malformed("connection closed before response head"));
            }
        };
        let head = self.buf.take(head_end + 4);
        let head =
            std::str::from_utf8(&head).map_err(|_| malformed("non-UTF-8 response head"))?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().ok_or_else(|| malformed("empty response"))?;
        let status = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| malformed("bad status line"))?;
        let mut content_length = 0usize;
        let mut close = false;
        for line in lines {
            if let Some((k, v)) = line.split_once(':') {
                let k = k.trim();
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| malformed("bad content-length"))?;
                } else if k.eq_ignore_ascii_case("connection") {
                    close = v.trim().to_ascii_lowercase().contains("close");
                }
            }
        }
        while self.buf.len() < content_length {
            if self.buf.fill(&mut self.stream)? == 0 {
                return Err(malformed("connection closed mid-body"));
            }
        }
        let body = self.buf.take(content_length);
        let body =
            String::from_utf8(body).map_err(|_| malformed("non-UTF-8 response body"))?;
        Ok(ClientResponse { status, body, close })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_buf_take_preserves_pipelined_remainder() {
        let mut buf = ByteBuf::new();
        buf.data.extend_from_slice(b"HEAD\r\n\r\nBODYNEXT");
        assert_eq!(buf.find_double_crlf(), Some(4));
        assert_eq!(buf.take(8), b"HEAD\r\n\r\n");
        assert_eq!(buf.take(4), b"BODY");
        assert_eq!(buf.data, b"NEXT");
        assert_eq!(buf.len(), 4);
        assert!(!buf.is_empty());
    }

    #[test]
    fn reason_phrases_cover_emitted_codes() {
        for status in [200u16, 400, 404, 405, 413, 500] {
            assert_ne!(reason_phrase(status), "Unknown");
        }
    }

    #[test]
    fn query_params_parse() {
        assert_eq!(query_param("format=prometheus", "format"), Some("prometheus"));
        assert_eq!(query_param("a=1&format=prometheus&b", "format"), Some("prometheus"));
        assert_eq!(query_param("flag", "flag"), Some(""));
        assert_eq!(query_param("", "format"), None);
        assert_eq!(query_param("formatx=1", "format"), None);
    }

    #[test]
    fn error_bodies_are_json() {
        let body = error_body("quote \" and \\ backslash");
        let doc = json::parse(&body).unwrap();
        assert_eq!(
            doc.require("error").unwrap().as_str().unwrap(),
            "quote \" and \\ backslash"
        );
    }
}
