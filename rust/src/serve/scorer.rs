//! The batched scoring engine: a [`CompiledModel`] is a `CoxModel`
//! recompiled into its scoring-optimized form, and a [`MicroBatcher`]
//! merges many small concurrent requests into one parallel sweep.
//!
//! Compilation does three things the training-side representation does
//! not:
//! * prunes the dense β to its nonzero support (a sparse `(index,
//!   value)` list plus the feature-name map), so a k-sparse model pays
//!   O(k) per row instead of O(p) — the paper's cardinality-constrained
//!   solutions make k ≪ p the common case;
//! * keeps the Breslow baseline as a sorted step table scored by binary
//!   search (single horizon) or one merged scan (horizon grids, via
//!   [`crate::metrics::BreslowBaseline::cumulative_hazard_many`]);
//! * memoizes H₀ at registered horizon grids in a small per-model LRU
//!   cache, so repeated requests against the same grid never re-walk
//!   the step table.
//!
//! Bitwise parity with the training-side API is a hard invariant: the
//! support dot product accumulates in ascending feature order, exactly
//! like `Matrix::matvec` (which also skips zero coefficients), and the
//! survival transform applies the identical `exp(−H₀·e^η)` expression —
//! so `CompiledModel` scores are bit-for-bit equal to
//! `CoxModel::predict_risk` / `predict_survival_curve`.

use crate::api::CoxModel;
use crate::data::csv::split_csv_line;
use crate::error::{FastSurvivalError, Result};
use crate::metrics::BreslowBaseline;
use crate::obs::hist::{quantile_from_counts, LatencyHistogram, N_BUCKETS};
use crate::util::parallel::par_map_indices;
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How many horizon grids each model memoizes H₀ for.
const HORIZON_CACHE_CAP: usize = 32;

/// LRU of `horizon-grid → H₀ values`, most recent first.
struct HorizonCache {
    entries: Vec<(Vec<u64>, Arc<Vec<f64>>)>,
}

/// A `CoxModel` compiled for scoring. Cheap to share (`Arc`), safe to
/// score from many threads concurrently.
pub struct CompiledModel {
    name: String,
    version: u64,
    p: usize,
    feature_names: Vec<String>,
    /// Nonzero coefficients as `(feature index, value)`, ascending index.
    support: Vec<(usize, f64)>,
    baseline: BreslowBaseline,
    horizon_cache: Mutex<HorizonCache>,
}

/// The result of scoring one row batch.
#[derive(Clone, Debug)]
pub struct ScoreOutput {
    /// Linear risk η per row.
    pub risk: Vec<f64>,
    /// Survival probabilities per row at each requested horizon (in the
    /// request's horizon order); `None` when no horizons were asked for.
    pub survival: Option<Vec<Vec<f64>>>,
    /// Microseconds this request spent queued in the micro-batcher
    /// (enqueue → batch claim, linger included). 0 on the direct
    /// [`CompiledModel::score_rows`] path — it never queues.
    pub queue_us: u64,
}

impl CompiledModel {
    /// Compile a fitted model under a registry identity.
    pub fn compile(model: &CoxModel, name: &str, version: u64) -> CompiledModel {
        let beta = model.beta();
        let support: Vec<(usize, f64)> = beta
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0.0)
            .map(|(j, &b)| (j, b))
            .collect();
        CompiledModel {
            name: name.to_string(),
            version,
            p: beta.len(),
            feature_names: model.feature_names().to_vec(),
            support,
            baseline: model.baseline().clone(),
            horizon_cache: Mutex::new(HorizonCache { entries: Vec::new() }),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// `name@version`, the spec string clients use to address this model.
    pub fn spec(&self) -> String {
        format!("{}@{}", self.name, self.version)
    }

    /// Feature count the model expects per row.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Number of nonzero coefficients.
    pub fn support_len(&self) -> usize {
        self.support.len()
    }

    pub fn support(&self) -> &[(usize, f64)] {
        &self.support
    }

    pub fn feature_names(&self) -> &[String] {
        &self.feature_names
    }

    /// η for one dense row. Accumulates over the nonzero support in
    /// ascending feature order — bitwise identical to
    /// `Matrix::matvec(β)`, which also skips zero coefficients.
    #[inline]
    pub fn eta_row(&self, row: &[f64]) -> f64 {
        debug_assert_eq!(row.len(), self.p);
        let mut s = 0.0;
        for &(j, b) in &self.support {
            s += row[j] * b;
        }
        s
    }

    /// H₀ at a horizon grid, LRU-cached per distinct grid (keyed on the
    /// exact f64 bit patterns). Horizons may arrive unsorted; the step
    /// table is walked once on a sorted copy and the permutation undone.
    pub fn hazards_at(&self, horizons: &[f64]) -> Result<Arc<Vec<f64>>> {
        if let Some(bad) = horizons.iter().find(|h| !h.is_finite()) {
            return Err(FastSurvivalError::InvalidData(format!(
                "survival horizon must be finite, got {bad}"
            )));
        }
        let key: Vec<u64> = horizons.iter().map(|h| h.to_bits()).collect();
        {
            let mut cache = self.horizon_cache.lock().unwrap();
            if let Some(pos) = cache.entries.iter().position(|(k, _)| *k == key) {
                let entry = cache.entries.remove(pos);
                let hit = entry.1.clone();
                cache.entries.insert(0, entry);
                return Ok(hit);
            }
        }
        // Miss: compute outside the lock (scans are cheap, but never
        // serialize concurrent scorers behind one). Same shared
        // implementation as `predict_survival_curve`, so the two paths
        // are bit-identical by construction.
        let computed = Arc::new(self.baseline.cumulative_hazard_unsorted(horizons));
        let mut cache = self.horizon_cache.lock().unwrap();
        cache.entries.insert(0, (key, computed.clone()));
        if cache.entries.len() > HORIZON_CACHE_CAP {
            cache.entries.pop();
        }
        Ok(computed)
    }

    /// Score `n_rows` dense row-major rows (`rows.len() == n_rows * p`)
    /// in one parallel sweep. This is the direct path used by the
    /// offline CSV scorer; the HTTP server routes through the
    /// [`MicroBatcher`], which produces bit-identical results.
    pub fn score_rows(
        &self,
        rows: &[f64],
        n_rows: usize,
        horizons: Option<&[f64]>,
    ) -> Result<ScoreOutput> {
        if rows.len() != n_rows * self.p {
            return Err(FastSurvivalError::InvalidData(format!(
                "row buffer has {} values, expected {} ({} rows × {} features)",
                rows.len(),
                n_rows * self.p,
                n_rows,
                self.p
            )));
        }
        let h0 = match horizons {
            None => None,
            Some(h) => Some(self.hazards_at(h)?),
        };
        let per_row: Vec<(f64, Option<Vec<f64>>)> = par_map_indices(n_rows, |i| {
            let row = &rows[i * self.p..(i + 1) * self.p];
            let eta = self.eta_row(row);
            let surv = h0.as_ref().map(|h| {
                let ez = eta.exp();
                h.iter().map(|&hh| (-hh * ez).exp()).collect()
            });
            (eta, surv)
        });
        let risk: Vec<f64> = per_row.iter().map(|r| r.0).collect();
        let survival = if h0.is_some() {
            Some(per_row.into_iter().map(|r| r.1.unwrap_or_default()).collect())
        } else {
            None
        };
        Ok(ScoreOutput { risk, survival, queue_us: 0 })
    }
}

// ------------------------------------------------------- micro-batching

/// Micro-batching knobs.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Row budget per merged sweep; requests beyond it wait for the next.
    pub max_batch_rows: usize,
    /// How long the batcher lingers after the first request arrives,
    /// letting concurrent small requests pile into the same sweep.
    pub max_wait_us: u64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch_rows: 4096, max_wait_us: 150 }
    }
}

/// One enqueued scoring request.
struct Pending {
    model: Arc<CompiledModel>,
    rows: Vec<f64>,
    n_rows: usize,
    horizons: Option<Vec<f64>>,
    tx: mpsc::Sender<Result<ScoreOutput>>,
    /// When `submit` enqueued the request — the start of its
    /// `queue_wait` stage.
    enqueued: Instant,
}

/// Always-on batcher gauges: cheap relaxed atomics, updated on every
/// enqueue and flush regardless of the obs flag (same discipline as the
/// per-endpoint stats).
struct BatchGauges {
    /// High-water mark of the queue depth (requests), observed at
    /// enqueue time.
    queue_depth_hwm: AtomicU64,
    /// Completed flush sweeps.
    flushes: AtomicU64,
    /// Requests drained across all flushes — `flushed_requests /
    /// flushes` is the mean linger occupancy.
    flushed_requests: AtomicU64,
    /// Distribution of rows per flush sweep.
    flush_rows: LatencyHistogram,
}

/// Point-in-time copy of the batcher gauges.
#[derive(Clone, Debug)]
pub struct BatchGaugesSnapshot {
    pub queue_depth_hwm: u64,
    pub flushes: u64,
    pub flushed_requests: u64,
    pub flush_rows_count: u64,
    pub flush_rows_sum: u64,
    pub flush_rows_buckets: [u64; N_BUCKETS],
}

impl BatchGaugesSnapshot {
    /// Mean requests merged per flush sweep — how well the linger
    /// window is amortizing concurrent arrivals.
    pub fn mean_requests_per_flush(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.flushed_requests as f64 / self.flushes as f64
        }
    }

    pub fn flush_rows_p50(&self) -> f64 {
        quantile_from_counts(&self.flush_rows_buckets, 0.50)
    }

    pub fn flush_rows_p99(&self) -> f64 {
        quantile_from_counts(&self.flush_rows_buckets, 0.99)
    }
}

struct BatchShared {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    shutdown: AtomicBool,
    gauges: BatchGauges,
}

/// The micro-batching queue: many small concurrent requests amortize
/// into one parallel sweep. A dedicated batcher thread drains the queue
/// (after a short linger window), flattens every pending request's rows
/// into one job list, scores them with one data-parallel map, and
/// routes each request's slice back through its response channel.
///
/// Dropping the batcher drains any queued requests before joining.
pub struct MicroBatcher {
    shared: Arc<BatchShared>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl MicroBatcher {
    pub fn new(cfg: BatchConfig) -> MicroBatcher {
        let shared = Arc::new(BatchShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            gauges: BatchGauges {
                queue_depth_hwm: AtomicU64::new(0),
                flushes: AtomicU64::new(0),
                flushed_requests: AtomicU64::new(0),
                flush_rows: LatencyHistogram::new(),
            },
        });
        let loop_shared = Arc::clone(&shared);
        let thread = std::thread::Builder::new()
            .name("fs-batcher".into())
            .spawn(move || batcher_loop(&loop_shared, &cfg))
            .expect("failed to spawn micro-batcher thread");
        MicroBatcher { shared, thread: Some(thread) }
    }

    /// Enqueue a scoring request; the returned channel yields exactly
    /// one result. `rows` is dense row-major with `n_rows * model.p()`
    /// values.
    pub fn submit(
        &self,
        model: Arc<CompiledModel>,
        rows: Vec<f64>,
        n_rows: usize,
        horizons: Option<Vec<f64>>,
    ) -> mpsc::Receiver<Result<ScoreOutput>> {
        let (tx, rx) = mpsc::channel();
        let depth = {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Pending {
                model,
                rows,
                n_rows,
                horizons,
                tx,
                enqueued: Instant::now(),
            });
            q.len() as u64
        };
        self.shared.gauges.queue_depth_hwm.fetch_max(depth, Ordering::Relaxed);
        self.shared.cv.notify_one();
        rx
    }

    /// Snapshot the always-on batcher gauges (feeds `/metrics`).
    pub fn gauges(&self) -> BatchGaugesSnapshot {
        let g = &self.shared.gauges;
        BatchGaugesSnapshot {
            queue_depth_hwm: g.queue_depth_hwm.load(Ordering::Relaxed),
            flushes: g.flushes.load(Ordering::Relaxed),
            flushed_requests: g.flushed_requests.load(Ordering::Relaxed),
            flush_rows_count: g.flush_rows.count(),
            flush_rows_sum: g.flush_rows.sum_us(),
            flush_rows_buckets: g.flush_rows.bucket_counts(),
        }
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn batcher_loop(shared: &BatchShared, cfg: &BatchConfig) {
    let max_rows = cfg.max_batch_rows.max(1);
    loop {
        // Wait for the first request (or shutdown with an empty queue).
        {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, Duration::from_millis(25))
                    .unwrap();
                q = guard;
            }
        }
        // Linger briefly so concurrent callers land in this sweep.
        if cfg.max_wait_us > 0 && !shared.shutdown.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_micros(cfg.max_wait_us));
        }
        // Claim up to max_rows worth of requests.
        let mut batch: Vec<Pending> = Vec::new();
        let mut batch_rows = 0u64;
        {
            let mut q = shared.queue.lock().unwrap();
            let mut rows = 0usize;
            loop {
                let take = match q.front() {
                    Some(p) => batch.is_empty() || rows + p.n_rows.max(1) <= max_rows,
                    None => false,
                };
                if !take {
                    break;
                }
                let p = q.pop_front().unwrap();
                rows += p.n_rows.max(1);
                batch_rows += p.n_rows as u64;
                batch.push(p);
            }
        }
        if !batch.is_empty() {
            shared.gauges.flushes.fetch_add(1, Ordering::Relaxed);
            shared
                .gauges
                .flushed_requests
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
            shared.gauges.flush_rows.record(batch_rows);
            process_batch(batch);
        }
    }
}

/// Everything a scoring job needs, separated from the response channel
/// (`mpsc::Sender` is not `Sync`, so it must stay out of the parallel
/// sweep's captures).
struct Work {
    model: Arc<CompiledModel>,
    rows: Vec<f64>,
    n_rows: usize,
    h0: Option<Arc<Vec<f64>>>,
}

fn process_batch(batch: Vec<Pending>) {
    // Every request in this sweep stops waiting now — its queue_wait
    // stage ends at the claim, before validation and scoring begin.
    let claimed = Instant::now();
    // Resolve hazard grids and validate shapes up front; failures are
    // answered immediately and excluded from the sweep.
    let mut works: Vec<Work> = Vec::with_capacity(batch.len());
    let mut txs: Vec<mpsc::Sender<Result<ScoreOutput>>> = Vec::with_capacity(batch.len());
    let mut queue_uss: Vec<u64> = Vec::with_capacity(batch.len());
    for pending in batch {
        let Pending { model, rows, n_rows, horizons, tx, enqueued } = pending;
        let queue_us = claimed.saturating_duration_since(enqueued).as_micros() as u64;
        if rows.len() != n_rows * model.p() {
            let _ = tx.send(Err(FastSurvivalError::InvalidData(format!(
                "row buffer has {} values, expected {} ({} rows × {} features)",
                rows.len(),
                n_rows * model.p(),
                n_rows,
                model.p()
            ))));
            continue;
        }
        let h0 = match &horizons {
            None => None,
            Some(h) => match model.hazards_at(h) {
                Ok(h0) => Some(h0),
                Err(e) => {
                    let _ = tx.send(Err(e));
                    continue;
                }
            },
        };
        works.push(Work { model, rows, n_rows, h0 });
        txs.push(tx);
        queue_uss.push(queue_us);
    }
    // One flattened parallel sweep over every row of every request.
    let mut jobs: Vec<(usize, usize)> = Vec::new();
    for (w, work) in works.iter().enumerate() {
        for r in 0..work.n_rows {
            jobs.push((w, r));
        }
    }
    let per_row: Vec<(f64, Option<Vec<f64>>)> = par_map_indices(jobs.len(), |j| {
        let (w, r) = jobs[j];
        let work = &works[w];
        let p = work.model.p();
        let row = &work.rows[r * p..(r + 1) * p];
        let eta = work.model.eta_row(row);
        let surv = work.h0.as_ref().map(|h| {
            let ez = eta.exp();
            h.iter().map(|&hh| (-hh * ez).exp()).collect()
        });
        (eta, surv)
    });
    // Hand results back per request, moving each survival curve out of
    // the sweep's output (no per-row clones on the hot path).
    let mut results = per_row.into_iter();
    for ((work, tx), queue_us) in works.iter().zip(&txs).zip(queue_uss) {
        let mut risk = Vec::with_capacity(work.n_rows);
        let mut curves = Vec::with_capacity(if work.h0.is_some() { work.n_rows } else { 0 });
        for _ in 0..work.n_rows {
            let (eta, surv) = results.next().expect("one sweep result per row");
            risk.push(eta);
            if work.h0.is_some() {
                curves.push(surv.unwrap_or_default());
            }
        }
        let survival = if work.h0.is_some() { Some(curves) } else { None };
        let _ = tx.send(Ok(ScoreOutput { risk, survival, queue_us }));
    }
}

// --------------------------------------------------- offline CSV scoring

/// Summary of one [`score_csv`] run.
#[derive(Clone, Debug, PartialEq)]
pub struct CsvScoreSummary {
    pub rows: usize,
    pub chunks: usize,
}

/// How CSV columns feed model features.
enum ColMap {
    /// `(csv column, feature index)` for every support feature — used
    /// when all support feature names appear in the header. Non-support
    /// features contribute nothing to η, so their columns are ignored.
    Named(Vec<(usize, usize)>),
    /// CSV column per feature `0..p` — used when names don't match but
    /// the non-time/event column count equals p exactly.
    Positional(Vec<usize>),
}

/// Stream a survival CSV through the scorer in bounded chunks, writing
/// one output line per input row (`risk[,surv@h…]`). Only `chunk_rows`
/// rows are resident at a time, so `n ≫ RAM` inputs work.
///
/// Column mapping: if every support feature name appears in the header,
/// columns are matched by name (extra columns, including `time`/`event`,
/// are ignored). Otherwise all columns except a recognized time/event
/// column are taken positionally and must number exactly `p`.
pub fn score_csv<R: BufRead, W: Write>(
    model: &CompiledModel,
    input: &mut R,
    output: &mut W,
    horizons: &[f64],
    chunk_rows: usize,
) -> Result<CsvScoreSummary> {
    let chunk_rows = chunk_rows.max(1);
    let p = model.p();
    let mut line = String::new();
    let read_err = |e| FastSurvivalError::io("reading CSV input".to_string(), e);
    let write_err = |e| FastSurvivalError::io("writing scored CSV".to_string(), e);

    if input.read_line(&mut line).map_err(read_err)? == 0 {
        return Err(FastSurvivalError::InvalidData("empty CSV: missing header".into()));
    }
    let header: Vec<String> = split_csv_line(line.trim_end())
        .iter()
        .map(|h| h.trim().to_string())
        .collect();
    let lower: Vec<String> = header.iter().map(|h| h.to_ascii_lowercase()).collect();
    let meta_cols: Vec<usize> = (0..header.len())
        .filter(|&c| {
            matches!(
                lower[c].as_str(),
                "time" | "t" | "event" | "status" | "delta" | "censor"
            )
        })
        .collect();

    let mut named: Vec<(usize, usize)> = Vec::new();
    let mut all_named = true;
    for &(j, _) in model.support() {
        match header.iter().position(|h| *h == model.feature_names()[j]) {
            Some(c) => named.push((c, j)),
            None => {
                all_named = false;
                break;
            }
        }
    }
    let map = if all_named {
        ColMap::Named(named)
    } else {
        let feat_cols: Vec<usize> =
            (0..header.len()).filter(|c| !meta_cols.contains(c)).collect();
        if feat_cols.len() != p {
            return Err(FastSurvivalError::InvalidData(format!(
                "CSV does not match the model: not every support feature name is in the \
                 header, and {} non-time/event columns != p={p} for positional mapping",
                feat_cols.len()
            )));
        }
        ColMap::Positional(feat_cols)
    };

    let mut out_header = String::from("risk");
    for h in horizons {
        out_header.push_str(&format!(",surv@{h}"));
    }
    writeln!(output, "{out_header}").map_err(write_err)?;

    let hz = if horizons.is_empty() { None } else { Some(horizons) };
    let mut rows_total = 0usize;
    let mut chunks = 0usize;
    let mut lineno = 1usize;
    let mut rec = String::new(); // reused output-line buffer
    loop {
        let mut flat: Vec<f64> = Vec::with_capacity(chunk_rows * p);
        let mut n = 0usize;
        while n < chunk_rows {
            line.clear();
            if input.read_line(&mut line).map_err(read_err)? == 0 {
                break;
            }
            lineno += 1;
            let trimmed = line.trim_end();
            if trimmed.is_empty() {
                continue;
            }
            let cells = split_csv_line(trimmed);
            if cells.len() != header.len() {
                return Err(FastSurvivalError::InvalidData(format!(
                    "row {lineno} has {} cells, expected {}",
                    cells.len(),
                    header.len()
                )));
            }
            let base = flat.len();
            flat.resize(base + p, 0.0);
            match &map {
                ColMap::Named(pairs) => {
                    for &(c, j) in pairs {
                        flat[base + j] = parse_cell(&cells[c], lineno, &header[c])?;
                    }
                }
                ColMap::Positional(cols) => {
                    for (j, &c) in cols.iter().enumerate() {
                        flat[base + j] = parse_cell(&cells[c], lineno, &header[c])?;
                    }
                }
            }
            n += 1;
        }
        if n == 0 {
            break;
        }
        let scored = model.score_rows(&flat, n, hz)?;
        for i in 0..n {
            // Format into the reused buffer — no per-cell allocations
            // in the streaming hot loop (String's fmt::Write is
            // infallible, hence the discarded results).
            rec.clear();
            let _ = write!(rec, "{}", scored.risk[i]);
            if let Some(surv) = &scored.survival {
                for &s in &surv[i] {
                    let _ = write!(rec, ",{s}");
                }
            }
            writeln!(output, "{rec}").map_err(write_err)?;
        }
        rows_total += n;
        chunks += 1;
        if n < chunk_rows {
            break; // the inner loop only stops short at EOF
        }
    }
    output.flush().map_err(write_err)?;
    Ok(CsvScoreSummary { rows: rows_total, chunks })
}

fn parse_cell(cell: &str, lineno: usize, col: &str) -> Result<f64> {
    cell.trim().parse::<f64>().map_err(|_| {
        FastSurvivalError::InvalidData(format!(
            "bad value {cell:?} in column {col:?} at row {lineno}"
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::CoxFit;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::linalg::Matrix;

    fn fitted() -> (crate::data::SurvivalDataset, CoxModel) {
        let ds = generate(&SyntheticConfig { n: 160, p: 10, rho: 0.5, k: 3, s: 0.1, seed: 11 });
        let model = CoxFit::new().l1(0.2).l2(0.1).max_iters(200).tol(1e-10).fit(&ds).unwrap();
        (ds, model)
    }

    fn row_major(x: &Matrix, rows: &[usize]) -> Vec<f64> {
        let mut out = Vec::with_capacity(rows.len() * x.cols);
        for &r in rows {
            for c in 0..x.cols {
                out.push(x.get(r, c));
            }
        }
        out
    }

    #[test]
    fn compiled_scores_match_model_bitwise() {
        let (ds, model) = fitted();
        let compiled = CompiledModel::compile(&model, "m", 1);
        assert_eq!(compiled.p(), 10);
        assert_eq!(
            compiled.support_len(),
            model.beta().iter().filter(|&&b| b != 0.0).count()
        );
        let idx: Vec<usize> = (0..ds.n()).collect();
        let rows = row_major(&ds.x, &idx);
        let horizons = [0.5, 2.0, 0.1];
        let out = compiled.score_rows(&rows, ds.n(), Some(&horizons)).unwrap();
        let expect_risk = model.predict_risk(&ds.x).unwrap();
        let expect_curves = model.predict_survival_curve(&ds.x, &horizons).unwrap();
        for i in 0..ds.n() {
            assert_eq!(out.risk[i].to_bits(), expect_risk[i].to_bits(), "row {i}");
            let surv = &out.survival.as_ref().unwrap()[i];
            for j in 0..horizons.len() {
                assert_eq!(surv[j].to_bits(), expect_curves[i][j].to_bits());
            }
        }
    }

    #[test]
    fn hazard_grids_are_cached_and_validated() {
        let (_, model) = fitted();
        let compiled = CompiledModel::compile(&model, "m", 1);
        let a = compiled.hazards_at(&[1.0, 0.25, 3.0]).unwrap();
        let b = compiled.hazards_at(&[1.0, 0.25, 3.0]).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "identical grids must hit the LRU cache");
        let c = compiled.hazards_at(&[0.25, 1.0, 3.0]).unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "different order is a different grid key");
        // Values agree with the single-lookup path regardless of order.
        for (grid, h0) in [(&[1.0, 0.25, 3.0], &a), (&[0.25, 1.0, 3.0], &c)] {
            for (j, &t) in grid.iter().enumerate() {
                assert_eq!(h0[j].to_bits(), model.baseline().cumulative_hazard(t).to_bits());
            }
        }
        assert!(compiled.hazards_at(&[1.0, f64::NAN]).is_err());
    }

    #[test]
    fn score_rows_rejects_bad_buffer_shapes() {
        let (_, model) = fitted();
        let compiled = CompiledModel::compile(&model, "m", 1);
        assert!(compiled.score_rows(&[1.0; 9], 1, None).is_err());
        let empty = compiled.score_rows(&[], 0, Some(&[1.0])).unwrap();
        assert!(empty.risk.is_empty());
        assert_eq!(empty.survival, Some(vec![]));
    }

    #[test]
    fn micro_batcher_matches_direct_scoring_under_concurrency() {
        let (ds, model) = fitted();
        let compiled = Arc::new(CompiledModel::compile(&model, "m", 1));
        let batcher = MicroBatcher::new(BatchConfig { max_batch_rows: 64, max_wait_us: 200 });
        let expect = model.predict_risk(&ds.x).unwrap();
        let curves = model.predict_survival_curve(&ds.x, &[0.5, 1.5]).unwrap();
        std::thread::scope(|scope| {
            for t in 0..6usize {
                let compiled = &compiled;
                let batcher = &batcher;
                let ds = &ds;
                let expect = &expect;
                let curves = &curves;
                scope.spawn(move || {
                    for iter in 0..20usize {
                        let r = (t * 17 + iter * 3) % ds.n();
                        let rows = row_major(&ds.x, &[r]);
                        let horizons =
                            if iter % 2 == 0 { Some(vec![0.5, 1.5]) } else { None };
                        let rx = batcher.submit(
                            Arc::clone(compiled),
                            rows,
                            1,
                            horizons.clone(),
                        );
                        let out = rx.recv().unwrap().unwrap();
                        assert_eq!(out.risk[0].to_bits(), expect[r].to_bits());
                        match (horizons, &out.survival) {
                            (Some(_), Some(s)) => {
                                assert_eq!(s[0][0].to_bits(), curves[r][0].to_bits());
                                assert_eq!(s[0][1].to_bits(), curves[r][1].to_bits());
                            }
                            (None, None) => {}
                            other => panic!("survival mismatch: {other:?}"),
                        }
                    }
                });
            }
        });
        // Bad shapes are answered per-request, not dropped.
        let rx = batcher.submit(Arc::clone(&compiled), vec![1.0; 3], 1, None);
        assert!(rx.recv().unwrap().is_err());
    }

    #[test]
    fn batcher_gauges_and_queue_wait_are_recorded() {
        let (ds, model) = fitted();
        let compiled = Arc::new(CompiledModel::compile(&model, "m", 1));
        let batcher = MicroBatcher::new(BatchConfig { max_batch_rows: 64, max_wait_us: 500 });
        let n_requests = 12usize;
        let outs: Vec<ScoreOutput> = (0..n_requests)
            .map(|i| {
                let rows = row_major(&ds.x, &[i % ds.n()]);
                batcher
                    .submit(Arc::clone(&compiled), rows, 1, None)
                    .recv()
                    .unwrap()
                    .unwrap()
            })
            .collect();
        // Queue wait spans enqueue → claim, so the 500µs linger is a
        // floor for every batched request; the direct path reports 0.
        for out in &outs {
            assert!(out.queue_us >= 400, "linger not reflected: {}", out.queue_us);
        }
        let direct = compiled.score_rows(&row_major(&ds.x, &[0]), 1, None).unwrap();
        assert_eq!(direct.queue_us, 0);
        let g = batcher.gauges();
        assert!(g.queue_depth_hwm >= 1);
        assert!(g.flushes >= 1 && g.flushes <= n_requests as u64);
        assert_eq!(g.flushed_requests, n_requests as u64);
        assert_eq!(g.flush_rows_count, g.flushes);
        assert_eq!(g.flush_rows_sum, n_requests as u64, "one row per request");
        assert!(g.mean_requests_per_flush() >= 1.0);
        assert!(g.flush_rows_p50() > 0.0);
        assert!(g.flush_rows_p50() <= g.flush_rows_p99());
    }

    #[test]
    fn csv_scoring_streams_in_chunks_with_parity() {
        let (ds, model) = fitted();
        let compiled = CompiledModel::compile(&model, "m", 1);
        // Build a CSV by name (time/event first, then features).
        let mut csv = String::from("time,event");
        for name in &ds.feature_names {
            csv.push_str(&format!(",{name}"));
        }
        csv.push('\n');
        for i in 0..ds.n() {
            csv.push_str(&format!("{},{}", ds.time[i], u8::from(ds.event[i])));
            for c in 0..ds.p() {
                csv.push_str(&format!(",{}", ds.x.get(i, c)));
            }
            csv.push('\n');
        }
        let horizons = [0.5, 2.0];
        let mut out: Vec<u8> = Vec::new();
        let summary = score_csv(
            &compiled,
            &mut csv.as_bytes(),
            &mut out,
            &horizons,
            7, // force many chunks
        )
        .unwrap();
        assert_eq!(summary.rows, ds.n());
        assert!(summary.chunks >= ds.n() / 7, "chunking must actually engage");
        let text = String::from_utf8(out).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), "risk,surv@0.5,surv@2");
        let expect_risk = model.predict_risk(&ds.x).unwrap();
        let expect_curves = model.predict_survival_curve(&ds.x, &horizons).unwrap();
        for i in 0..ds.n() {
            let cells: Vec<f64> = lines
                .next()
                .unwrap()
                .split(',')
                .map(|c| c.parse().unwrap())
                .collect();
            assert!((cells[0] - expect_risk[i]).abs() <= 1e-12, "row {i} risk");
            assert!((cells[1] - expect_curves[i][0]).abs() <= 1e-12);
            assert!((cells[2] - expect_curves[i][1]).abs() <= 1e-12);
        }
        assert!(lines.next().is_none());
    }

    #[test]
    fn csv_scoring_rejects_unmappable_headers() {
        let (_, model) = fitted();
        let compiled = CompiledModel::compile(&model, "m", 1);
        // Unknown names AND wrong positional width.
        let csv = "time,event,a,b\n1.0,1,0.5,0.5\n";
        let mut out: Vec<u8> = Vec::new();
        assert!(score_csv(&compiled, &mut csv.as_bytes(), &mut out, &[], 8).is_err());
        let mut empty: &[u8] = b"";
        assert!(score_csv(&compiled, &mut empty, &mut out, &[], 8).is_err());
    }
}
