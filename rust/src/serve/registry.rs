//! The hot-swappable model registry: versioned `CoxModel` JSON
//! artifacts loaded from a directory and served by `name@version`
//! behind an `Arc` read-mostly handle.
//!
//! Artifact directory layout (both forms may coexist):
//!
//! ```text
//! models/
//! ├── churn@1.json          # flat:   <name>@<version>.json
//! ├── churn@2.json
//! └── relapse/              # nested: <name>/<version>.json
//!     ├── 1.json
//!     └── 3.json
//! ```
//!
//! Lookups clone an `Arc<CompiledModel>` out of the current snapshot, so
//! scoring threads never hold a lock while working and a reload can
//! never corrupt an in-flight request: [`ModelRegistry::reload`] scans
//! the directory into a *fresh* state and atomically swaps the shared
//! handle only if the entire scan succeeded. A reload that hits a
//! schema-mismatched or malformed artifact returns a typed error
//! ([`crate::error::FastSurvivalError::Serve`]) and leaves the previous
//! state serving.

use super::scorer::CompiledModel;
use crate::api::CoxModel;
use crate::error::{FastSurvivalError, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

fn serve_err(msg: impl Into<String>) -> FastSurvivalError {
    FastSurvivalError::Serve(msg.into())
}

/// One immutable snapshot of every loaded model.
pub struct RegistryState {
    /// `name → version → compiled model`, both levels sorted.
    models: BTreeMap<String, BTreeMap<u64, Arc<CompiledModel>>>,
}

impl RegistryState {
    /// Total number of loaded artifacts (across all names/versions).
    pub fn n_artifacts(&self) -> usize {
        self.models.values().map(|v| v.len()).sum()
    }

    /// Distinct model names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Every loaded model, sorted by name then version.
    pub fn list(&self) -> Vec<&Arc<CompiledModel>> {
        self.models.values().flat_map(|v| v.values()).collect()
    }

    /// Highest loaded version of `name`.
    pub fn latest_version(&self, name: &str) -> Option<u64> {
        self.models.get(name)?.keys().next_back().copied()
    }

    /// Look up `name` at `version` (or its latest version).
    pub fn get(&self, name: &str, version: Option<u64>) -> Option<&Arc<CompiledModel>> {
        let versions = self.models.get(name)?;
        match version {
            Some(v) => versions.get(&v),
            None => versions.values().next_back(),
        }
    }
}

/// What a successful [`ModelRegistry::reload`] found.
#[derive(Clone, Debug)]
pub struct ReloadReport {
    pub artifacts: usize,
    pub names: Vec<String>,
}

/// Directory-backed registry of compiled models with atomic hot reload.
pub struct ModelRegistry {
    root: PathBuf,
    state: RwLock<Arc<RegistryState>>,
    /// Monotonic state-swap counter: 1 after [`ModelRegistry::open`],
    /// +1 on every *successful* [`ModelRegistry::reload`]. Lets a
    /// publisher (or `/healthz` poller) verify that a reload actually
    /// took — a failed reload leaves both the state and this counter
    /// untouched.
    generation: AtomicU64,
}

impl ModelRegistry {
    /// Scan `root` and load every artifact. Fails fast on the first
    /// malformed, schema-mismatched, or mis-named artifact — a server
    /// should refuse to start on a bad directory rather than silently
    /// serve a subset. An empty (or all-ignored) directory is fine: the
    /// server can start first and receive artifacts + `/v1/reload` later.
    pub fn open(root: impl AsRef<Path>) -> Result<ModelRegistry> {
        let root = root.as_ref().to_path_buf();
        let state = Arc::new(scan(&root)?);
        Ok(ModelRegistry {
            root,
            state: RwLock::new(state),
            generation: AtomicU64::new(1),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Current registry generation (see the field docs).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// The current immutable snapshot. Callers score against the
    /// snapshot (or models cloned out of it) without holding any lock.
    pub fn snapshot(&self) -> Arc<RegistryState> {
        self.state.read().unwrap().clone()
    }

    /// Re-scan the artifact directory and atomically swap in the fresh
    /// state. All-or-nothing: any scan error leaves the previous state
    /// untouched (and still serving), and in-flight requests holding
    /// `Arc<CompiledModel>` handles from the old state are unaffected
    /// either way.
    pub fn reload(&self) -> Result<ReloadReport> {
        let fresh = Arc::new(scan(&self.root)?);
        let report = ReloadReport {
            artifacts: fresh.n_artifacts(),
            names: fresh.names().iter().map(|s| s.to_string()).collect(),
        };
        *self.state.write().unwrap() = fresh;
        self.generation.fetch_add(1, Ordering::AcqRel);
        Ok(report)
    }

    /// Resolve a client spec: `"name@version"`, `"name"` (latest
    /// version), or `""` (the unique loaded model, if exactly one name
    /// is loaded).
    pub fn resolve(&self, spec: &str) -> Result<Arc<CompiledModel>> {
        let state = self.snapshot();
        let (name, version) = parse_spec(spec)?;
        let name = match name {
            Some(n) => n,
            None => match state.models.len() {
                0 => return Err(serve_err("no models loaded")),
                1 => state.models.keys().next().unwrap().clone(),
                _ => {
                    return Err(serve_err(format!(
                        "multiple models loaded ({}); address one as \"name\" or \
                         \"name@version\"",
                        state.names().join(", ")
                    )))
                }
            },
        };
        if let Some(model) = state.get(&name, version) {
            return Ok(model.clone());
        }
        match (version, state.latest_version(&name)) {
            (Some(v), Some(latest)) => Err(serve_err(format!(
                "model {name:?} has no version {v} (latest loaded: {latest})"
            ))),
            _ => Err(FastSurvivalError::Unknown {
                kind: "model",
                name,
                expected: "a loaded model name (see GET /v1/models)",
            }),
        }
    }
}

/// Parse `""` / `"name"` / `"name@version"`. Public so the HTTP layer
/// can distinguish a syntactically bad spec (client error, 400) from a
/// well-formed spec that names nothing (404).
pub fn parse_spec(spec: &str) -> Result<(Option<String>, Option<u64>)> {
    let s = spec.trim();
    if s.is_empty() {
        return Ok((None, None));
    }
    match s.rsplit_once('@') {
        None => Ok((Some(s.to_string()), None)),
        Some((name, v)) => {
            if name.is_empty() {
                return Err(serve_err(format!("bad model spec {s:?}: empty name")));
            }
            let version = v.parse::<u64>().map_err(|_| {
                serve_err(format!(
                    "bad model spec {s:?}: version must be an unsigned integer"
                ))
            })?;
            Ok((Some(name.to_string()), Some(version)))
        }
    }
}

fn is_json(path: &Path) -> bool {
    path.extension().and_then(|e| e.to_str()) == Some("json")
}

fn utf8_stem(path: &Path) -> Result<&str> {
    path.file_stem()
        .and_then(|s| s.to_str())
        .ok_or_else(|| serve_err(format!("artifact {path:?}: non-UTF-8 file name")))
}

fn sorted_entries(dir: &Path) -> Result<Vec<PathBuf>> {
    let rd = std::fs::read_dir(dir)
        .map_err(|e| FastSurvivalError::io(format!("scanning model directory {dir:?}"), e))?;
    let mut paths = Vec::new();
    for entry in rd {
        let entry = entry
            .map_err(|e| FastSurvivalError::io(format!("scanning model directory {dir:?}"), e))?;
        paths.push(entry.path());
    }
    paths.sort();
    Ok(paths)
}

fn load_artifact(path: &Path, name: &str, version: u64) -> Result<Arc<CompiledModel>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| FastSurvivalError::io(format!("reading artifact {path:?}"), e))?;
    // A schema-mismatched or corrupt artifact surfaces as a typed
    // rejection naming the offending file, not a panic or a skip.
    let model = CoxModel::from_json(&text)
        .map_err(|e| serve_err(format!("artifact {path:?} rejected: {e}")))?;
    Ok(Arc::new(CompiledModel::compile(&model, name, version)))
}

fn insert(
    models: &mut BTreeMap<String, BTreeMap<u64, Arc<CompiledModel>>>,
    path: &Path,
    name: &str,
    version: u64,
) -> Result<()> {
    let slot = models.entry(name.to_string()).or_default();
    if slot.contains_key(&version) {
        return Err(serve_err(format!(
            "duplicate artifact for {name}@{version} (second copy at {path:?}; flat and \
             nested layouts may not both define the same version)"
        )));
    }
    slot.insert(version, load_artifact(path, name, version)?);
    Ok(())
}

fn scan(root: &Path) -> Result<RegistryState> {
    let mut models: BTreeMap<String, BTreeMap<u64, Arc<CompiledModel>>> = BTreeMap::new();
    for path in sorted_entries(root)? {
        if path.is_dir() {
            let name = path
                .file_name()
                .and_then(|s| s.to_str())
                .ok_or_else(|| serve_err(format!("non-UTF-8 model directory {path:?}")))?
                .to_string();
            for file in sorted_entries(&path)? {
                if !is_json(&file) {
                    continue; // READMEs, temp files, hidden files
                }
                let stem = utf8_stem(&file)?;
                let version = stem.parse::<u64>().map_err(|_| {
                    serve_err(format!(
                        "artifact {file:?}: nested artifacts must be named \
                         <version>.json with an unsigned-integer version"
                    ))
                })?;
                insert(&mut models, &file, &name, version)?;
            }
        } else if is_json(&path) {
            let stem = utf8_stem(&path)?;
            let (name, vstr) = stem.rsplit_once('@').ok_or_else(|| {
                serve_err(format!(
                    "artifact {path:?}: flat artifacts must be named \
                     <name>@<version>.json (or use a <name>/<version>.json directory)"
                ))
            })?;
            if name.is_empty() {
                return Err(serve_err(format!("artifact {path:?}: empty model name")));
            }
            let version = vstr.parse::<u64>().map_err(|_| {
                serve_err(format!(
                    "artifact {path:?}: version {vstr:?} must be an unsigned integer"
                ))
            })?;
            insert(&mut models, &path, name, version)?;
        }
        // Anything else (non-json files) is ignored.
    }
    Ok(RegistryState { models })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::CoxFit;
    use crate::data::synthetic::{generate, SyntheticConfig};

    fn unique_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fs_registry_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn toy_model(l2: f64) -> CoxModel {
        let ds = generate(&SyntheticConfig { n: 120, p: 6, rho: 0.4, k: 2, s: 0.1, seed: 3 });
        CoxFit::new().l2(l2).max_iters(60).tol(1e-8).fit(&ds).unwrap()
    }

    #[test]
    fn open_loads_flat_and_nested_layouts() {
        let dir = unique_dir("layouts");
        let model = toy_model(1.0);
        model.save(&dir.join("churn@1.json")).unwrap();
        model.save(&dir.join("churn@2.json")).unwrap();
        model.save(&dir.join("relapse").join("7.json")).unwrap();
        std::fs::write(dir.join("README.txt"), "not an artifact").unwrap();
        let reg = ModelRegistry::open(&dir).unwrap();
        let st = reg.snapshot();
        assert_eq!(st.n_artifacts(), 3);
        assert_eq!(st.names(), vec!["churn", "relapse"]);
        assert_eq!(st.latest_version("churn"), Some(2));
        assert_eq!(reg.resolve("churn").unwrap().version(), 2);
        assert_eq!(reg.resolve("churn@1").unwrap().version(), 1);
        assert_eq!(reg.resolve("relapse").unwrap().spec(), "relapse@7");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resolve_specs_and_errors() {
        let dir = unique_dir("specs");
        toy_model(1.0).save(&dir.join("only@1.json")).unwrap();
        let reg = ModelRegistry::open(&dir).unwrap();
        // Empty spec works when exactly one name is loaded.
        assert_eq!(reg.resolve("").unwrap().name(), "only");
        assert_eq!(reg.resolve("  only@1 ").unwrap().version(), 1);
        assert!(reg.resolve("missing").is_err());
        assert!(reg.resolve("only@9").is_err());
        assert!(reg.resolve("only@x").is_err());
        assert!(reg.resolve("@3").is_err());
        // A second name makes the empty spec ambiguous.
        toy_model(2.0).save(&dir.join("other@1.json")).unwrap();
        reg.reload().unwrap();
        assert!(reg.resolve("").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_artifacts_are_rejected_with_typed_errors() {
        let dir = unique_dir("bad");
        std::fs::write(dir.join("broken@1.json"), "{ not json").unwrap();
        assert!(matches!(
            ModelRegistry::open(&dir),
            Err(FastSurvivalError::Serve(_))
        ));
        // Schema mismatch (wrong format_version) is also a typed reject.
        let good = toy_model(1.0).to_json();
        std::fs::write(
            dir.join("broken@1.json"),
            good.replace("\"format_version\": 1", "\"format_version\": 99"),
        )
        .unwrap();
        assert!(matches!(
            ModelRegistry::open(&dir),
            Err(FastSurvivalError::Serve(_))
        ));
        // Bad names are layout errors.
        std::fs::remove_file(dir.join("broken@1.json")).unwrap();
        std::fs::write(dir.join("noversion.json"), &good).unwrap();
        assert!(ModelRegistry::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_reload_keeps_previous_state() {
        let dir = unique_dir("atomic");
        toy_model(1.0).save(&dir.join("m@1.json")).unwrap();
        let reg = ModelRegistry::open(&dir).unwrap();
        assert_eq!(reg.generation(), 1);
        let before = reg.resolve("m@1").unwrap();
        // Drop a corrupt artifact; reload must fail and keep serving v1.
        std::fs::write(dir.join("m@2.json"), "garbage").unwrap();
        assert!(reg.reload().is_err());
        assert_eq!(reg.generation(), 1, "failed reload must not bump the generation");
        let after = reg.resolve("m").unwrap();
        assert!(Arc::ptr_eq(&before, &after), "old state must keep serving");
        // Fix it; reload now swaps in both versions and bumps the counter.
        toy_model(3.0).save(&dir.join("m@2.json")).unwrap();
        let report = reg.reload().unwrap();
        assert_eq!(report.artifacts, 2);
        assert_eq!(reg.generation(), 2);
        assert_eq!(reg.resolve("m").unwrap().version(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
