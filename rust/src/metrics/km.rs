//! Kaplan–Meier and Nelson–Aalen estimators.

/// A right-continuous step function S(t) = P(T > t) estimated by
/// Kaplan–Meier. Also used (with flipped indicators) for the censoring
/// distribution G(t) needed by IPCW Brier weights.
#[derive(Clone, Debug)]
pub struct KaplanMeier {
    /// Distinct event times, ascending.
    pub times: Vec<f64>,
    /// Survival value *at and after* the corresponding time (until next).
    pub surv: Vec<f64>,
}

impl KaplanMeier {
    /// Fit S(t) from observations. `event[i] = true` marks the terminal
    /// event; censored observations leave the risk set silently.
    pub fn fit(time: &[f64], event: &[bool]) -> Self {
        assert_eq!(time.len(), event.len());
        let n = time.len();
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| time[a].partial_cmp(&time[b]).unwrap());

        let mut times = Vec::new();
        let mut surv = Vec::new();
        let mut s = 1.0_f64;
        let mut at_risk = n as f64;
        let mut i = 0;
        while i < n {
            let t = time[idx[i]];
            let mut d = 0.0; // events at t
            let mut m = 0.0; // total leaving at t
            while i < n && time[idx[i]] == t {
                if event[idx[i]] {
                    d += 1.0;
                }
                m += 1.0;
                i += 1;
            }
            if d > 0.0 {
                s *= 1.0 - d / at_risk;
                times.push(t);
                surv.push(s);
            }
            at_risk -= m;
        }
        KaplanMeier { times, surv }
    }

    /// Censoring-distribution KM: flip the indicator (a "censoring event"
    /// is the event of interest) — used for IPCW weights G(t).
    pub fn fit_censoring(time: &[f64], event: &[bool]) -> Self {
        let flipped: Vec<bool> = event.iter().map(|&e| !e).collect();
        KaplanMeier::fit(time, &flipped)
    }

    /// S(t): right-continuous evaluation.
    pub fn at(&self, t: f64) -> f64 {
        // Last index with times[i] <= t.
        match self.times.partition_point(|&x| x <= t) {
            0 => 1.0,
            k => self.surv[k - 1],
        }
    }

    /// S(t−): left limit (used by IPCW at the observation's own time).
    pub fn at_left(&self, t: f64) -> f64 {
        match self.times.partition_point(|&x| x < t) {
            0 => 1.0,
            k => self.surv[k - 1],
        }
    }
}

/// Nelson–Aalen cumulative hazard Λ(t) = Σ_{t_i ≤ t} d_i / n_i.
#[derive(Clone, Debug)]
pub struct NelsonAalen {
    pub times: Vec<f64>,
    pub cumhaz: Vec<f64>,
}

impl NelsonAalen {
    pub fn fit(time: &[f64], event: &[bool]) -> Self {
        let n = time.len();
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| time[a].partial_cmp(&time[b]).unwrap());
        let mut times = Vec::new();
        let mut cumhaz = Vec::new();
        let mut h = 0.0_f64;
        let mut at_risk = n as f64;
        let mut i = 0;
        while i < n {
            let t = time[idx[i]];
            let mut d = 0.0;
            let mut m = 0.0;
            while i < n && time[idx[i]] == t {
                if event[idx[i]] {
                    d += 1.0;
                }
                m += 1.0;
                i += 1;
            }
            if d > 0.0 {
                h += d / at_risk;
                times.push(t);
                cumhaz.push(h);
            }
            at_risk -= m;
        }
        NelsonAalen { times, cumhaz }
    }

    pub fn at(&self, t: f64) -> f64 {
        match self.times.partition_point(|&x| x <= t) {
            0 => 0.0,
            k => self.cumhaz[k - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_censoring_matches_empirical() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true; 4];
        let km = KaplanMeier::fit(&time, &event);
        assert!((km.at(0.5) - 1.0).abs() < 1e-12);
        assert!((km.at(1.0) - 0.75).abs() < 1e-12);
        assert!((km.at(2.5) - 0.5).abs() < 1e-12);
        assert!((km.at(4.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn censoring_shrinks_risk_set_without_drop() {
        // Classic textbook check: censored at 2 leaves S unchanged at 2,
        // but the next event divides by a smaller risk set.
        let time = vec![1.0, 2.0, 3.0];
        let event = vec![true, false, true];
        let km = KaplanMeier::fit(&time, &event);
        assert!((km.at(1.0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((km.at(2.5) - 2.0 / 3.0).abs() < 1e-12);
        assert!((km.at(3.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn left_limit_differs_at_event_times() {
        let time = vec![1.0, 2.0];
        let event = vec![true, true];
        let km = KaplanMeier::fit(&time, &event);
        assert!((km.at_left(1.0) - 1.0).abs() < 1e-12);
        assert!((km.at(1.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ties_handled_together() {
        let time = vec![1.0, 1.0, 2.0, 2.0];
        let event = vec![true, true, true, false];
        let km = KaplanMeier::fit(&time, &event);
        assert!((km.at(1.0) - 0.5).abs() < 1e-12);
        assert!((km.at(2.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn nelson_aalen_monotone_and_consistent() {
        let time = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let event = vec![true, false, true, true, false];
        let na = NelsonAalen::fit(&time, &event);
        assert_eq!(na.at(0.0), 0.0);
        assert!((na.at(1.0) - 0.2).abs() < 1e-12);
        assert!((na.at(3.0) - (0.2 + 1.0 / 3.0)).abs() < 1e-12);
        let mut prev = 0.0;
        for t in [0.5, 1.5, 2.5, 3.5, 4.5, 5.5] {
            assert!(na.at(t) >= prev);
            prev = na.at(t);
        }
    }

    #[test]
    fn censoring_km_flips() {
        let time = vec![1.0, 2.0];
        let event = vec![true, false];
        let g = KaplanMeier::fit_censoring(&time, &event);
        // Censoring event at t=2 only.
        assert!((g.at(1.5) - 1.0).abs() < 1e-12);
        assert!((g.at(2.0) - 0.0).abs() < 1e-12);
    }
}
