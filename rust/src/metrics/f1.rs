//! Support-recovery precision / recall / F1 (Appendix C.2).
//!
//! `P = |supp(β*) ∩ supp(β̂)| / |supp(β̂)|`,
//! `R = |supp(β*) ∩ supp(β̂)| / |supp(β*)|`, `F1 = 2PR/(P+R)`.

/// Precision / recall / F1 for variable selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SupportScores {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

/// Compute support-recovery scores with tolerance `tol` for "nonzero".
pub fn support_f1(true_beta: &[f64], est_beta: &[f64], tol: f64) -> SupportScores {
    assert_eq!(true_beta.len(), est_beta.len());
    let mut tp = 0usize;
    let mut est_nnz = 0usize;
    let mut true_nnz = 0usize;
    for (t, e) in true_beta.iter().zip(est_beta) {
        let t_on = t.abs() > tol;
        let e_on = e.abs() > tol;
        if t_on {
            true_nnz += 1;
        }
        if e_on {
            est_nnz += 1;
        }
        if t_on && e_on {
            tp += 1;
        }
    }
    let precision = if est_nnz == 0 { 0.0 } else { tp as f64 / est_nnz as f64 };
    let recall = if true_nnz == 0 { 0.0 } else { tp as f64 / true_nnz as f64 };
    let f1 = if precision + recall == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    };
    SupportScores { precision, recall, f1 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_recovery_is_one() {
        let t = vec![1.0, 0.0, 1.0, 0.0];
        let s = support_f1(&t, &t, 1e-9);
        assert_eq!(s.f1, 1.0);
        assert_eq!(s.precision, 1.0);
        assert_eq!(s.recall, 1.0);
    }

    #[test]
    fn disjoint_supports_zero() {
        let t = vec![1.0, 0.0];
        let e = vec![0.0, 1.0];
        let s = support_f1(&t, &e, 1e-9);
        assert_eq!(s.f1, 0.0);
    }

    #[test]
    fn partial_overlap() {
        let t = vec![1.0, 1.0, 0.0, 0.0];
        let e = vec![0.5, 0.0, 0.3, 0.0];
        let s = support_f1(&t, &e, 1e-9);
        assert_eq!(s.precision, 0.5);
        assert_eq!(s.recall, 0.5);
        assert_eq!(s.f1, 0.5);
    }

    #[test]
    fn empty_estimate_handled() {
        let t = vec![1.0, 0.0];
        let e = vec![0.0, 0.0];
        let s = support_f1(&t, &e, 1e-9);
        assert_eq!(s.f1, 0.0);
    }

    #[test]
    fn tolerance_respected() {
        let t = vec![1.0];
        let e = vec![1e-12];
        assert_eq!(support_f1(&t, &e, 1e-9).f1, 0.0);
        assert_eq!(support_f1(&t, &e, 1e-15).f1, 1.0);
    }
}
