//! Evaluation metrics (Appendix C.2): Harrell's C-index, the integrated
//! Brier score with IPCW weights, Kaplan–Meier / Nelson–Aalen estimators,
//! the Breslow baseline hazard, and support-recovery precision/recall/F1.

pub mod breslow;
pub mod brier;
pub mod cindex;
pub mod f1;
pub mod km;

pub use breslow::BreslowBaseline;
pub use brier::{brier_score, integrated_brier_score};
pub use cindex::concordance_index;
pub use f1::{support_f1, SupportScores};
pub use km::KaplanMeier;
