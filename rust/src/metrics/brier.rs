//! (Integrated) Brier score with IPCW weights, Graf et al. \[24\].
//!
//! `BS(t) = n⁻¹ Σ_i [ Ŝ(t|x_i)²·1{t_i ≤ t, δ_i=1}/G(t_i⁻)
//!                   + (1−Ŝ(t|x_i))²·1{t_i > t}/G(t) ]`
//! where G is the Kaplan–Meier estimate of the censoring distribution on
//! the training data. IBS integrates BS over a time grid (trapezoid).

use super::km::KaplanMeier;

/// Brier score at a single horizon `t`. `surv(i, t)` is the model's
/// predicted survival probability for test sample `i` at time `t`.
pub fn brier_score(
    time: &[f64],
    event: &[bool],
    surv: &dyn Fn(usize, f64) -> f64,
    censor_km: &KaplanMeier,
    t: f64,
) -> f64 {
    let n = time.len();
    let mut total = 0.0;
    for i in 0..n {
        let s = surv(i, t).clamp(0.0, 1.0);
        if time[i] <= t && event[i] {
            let g = censor_km.at_left(time[i]).max(1e-10);
            total += s * s / g;
        } else if time[i] > t {
            let g = censor_km.at(t).max(1e-10);
            total += (1.0 - s) * (1.0 - s) / g;
        }
        // censored before t: weight 0
    }
    total / n as f64
}

/// Integrated Brier score over `grid` (must be ascending), trapezoid rule
/// normalized by the grid span.
pub fn integrated_brier_score(
    time: &[f64],
    event: &[bool],
    surv: &dyn Fn(usize, f64) -> f64,
    censor_km: &KaplanMeier,
    grid: &[f64],
) -> f64 {
    assert!(grid.len() >= 2, "need at least two grid points");
    let bs: Vec<f64> = grid.iter().map(|&t| brier_score(time, event, surv, censor_km, t)).collect();
    let mut integral = 0.0;
    for k in 1..grid.len() {
        let dt = grid[k] - grid[k - 1];
        assert!(dt >= 0.0, "grid must be ascending");
        integral += 0.5 * (bs[k] + bs[k - 1]) * dt;
    }
    integral / (grid[grid.len() - 1] - grid[0])
}

/// Default evaluation grid: `n_points` between the 5th and 95th
/// percentile of observed *event* times (sksurv convention).
pub fn default_grid(time: &[f64], event: &[bool], n_points: usize) -> Vec<f64> {
    let mut ev: Vec<f64> = time
        .iter()
        .zip(event)
        .filter(|(_, &e)| e)
        .map(|(&t, _)| t)
        .collect();
    if ev.len() < 2 {
        ev = time.to_vec();
    }
    ev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = ev[(0.05 * (ev.len() - 1) as f64) as usize];
    let hi = ev[(0.95 * (ev.len() - 1) as f64) as usize];
    let hi = if hi > lo { hi } else { lo + 1e-9 };
    (0..n_points)
        .map(|k| lo + (hi - lo) * k as f64 / (n_points - 1) as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_predictions_score_zero() {
        // No censoring; oracle survival: S(t|i) = 1{t < t_i}.
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true; 4];
        let g = KaplanMeier::fit_censoring(&time, &event); // G == 1
        let t_copy = time.clone();
        let surv = move |i: usize, t: f64| if t < t_copy[i] { 1.0 } else { 0.0 };
        for t in [0.5, 1.5, 2.5, 3.5] {
            let bs = brier_score(&time, &event, &surv, &g, t);
            assert!(bs.abs() < 1e-12, "t={t} bs={bs}");
        }
    }

    #[test]
    fn constant_half_scores_quarter() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true; 4];
        let g = KaplanMeier::fit_censoring(&time, &event);
        let surv = |_i: usize, _t: f64| 0.5;
        let bs = brier_score(&time, &event, &surv, &g, 2.5);
        assert!((bs - 0.25).abs() < 1e-12, "bs={bs}");
    }

    #[test]
    fn ibs_integrates_constant() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true; 4];
        let g = KaplanMeier::fit_censoring(&time, &event);
        let surv = |_i: usize, _t: f64| 0.5;
        let grid = vec![1.0, 2.0, 3.0];
        let ibs = integrated_brier_score(&time, &event, &surv, &g, &grid);
        assert!((ibs - 0.25).abs() < 1e-12);
    }

    #[test]
    fn informative_model_beats_constant() {
        use crate::metrics::breslow::BreslowBaseline;
        let mut rng = Rng::new(17);
        let n = 500;
        let eta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let time: Vec<f64> = eta.iter().map(|&e| rng.exponential() / e.exp()).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.8)).collect();
        let g = KaplanMeier::fit_censoring(&time, &event);
        let b = BreslowBaseline::fit(&time, &event, &eta);
        let grid = default_grid(&time, &event, 25);
        let eta_c = eta.clone();
        let model = move |i: usize, t: f64| b.survival(t, eta_c[i]);
        let ibs_model = integrated_brier_score(&time, &event, &model, &g, &grid);
        let km = crate::metrics::km::KaplanMeier::fit(&time, &event);
        let marginal = move |_i: usize, t: f64| km.at(t);
        let ibs_marginal = integrated_brier_score(&time, &event, &marginal, &g, &grid);
        assert!(
            ibs_model < ibs_marginal,
            "model {ibs_model} should beat marginal {ibs_marginal}"
        );
    }

    #[test]
    fn default_grid_ascending_within_range() {
        let time = vec![1.0, 5.0, 2.0, 8.0, 3.0];
        let event = vec![true, true, false, true, true];
        let grid = default_grid(&time, &event, 10);
        assert_eq!(grid.len(), 10);
        assert!(grid.windows(2).all(|w| w[1] >= w[0]));
        assert!(grid[0] >= 1.0 && grid[9] <= 8.0);
    }
}
