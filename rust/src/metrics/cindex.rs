//! Harrell's concordance index \[26\].
//!
//! Fraction of comparable pairs whose predicted risks are correctly
//! ordered. A pair (i, j) is comparable when t_i < t_j and δ_i = 1 (the
//! earlier time is an observed event). Ties in predicted risk count ½.

/// Concordance index of `risk` (higher = fails earlier) on (time, event).
/// Returns 0.5 when there are no comparable pairs.
///
/// Dispatches to an O(n log n) Fenwick-tree counting implementation for
/// large n; the O(n²) pair scan remains as the small-n path and as the
/// test oracle.
pub fn concordance_index(time: &[f64], event: &[bool], risk: &[f64]) -> f64 {
    if time.len() > 512 {
        concordance_index_fast(time, event, risk)
    } else {
        concordance_index_naive(time, event, risk)
    }
}

/// O(n²) reference implementation (exact Harrell definition).
pub fn concordance_index_naive(time: &[f64], event: &[bool], risk: &[f64]) -> f64 {
    let n = time.len();
    assert_eq!(n, event.len());
    assert_eq!(n, risk.len());
    // Sort by time ascending so comparable pairs are (earlier event, later).
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| time[a].partial_cmp(&time[b]).unwrap());

    let mut concordant = 0.0_f64;
    let mut comparable = 0.0_f64;
    for (a_pos, &i) in idx.iter().enumerate() {
        if !event[i] {
            continue;
        }
        for &j in &idx[a_pos + 1..] {
            if time[j] <= time[i] {
                continue; // tied times are not comparable under Harrell
            }
            comparable += 1.0;
            if risk[i] > risk[j] {
                concordant += 1.0;
            } else if risk[i] == risk[j] {
                concordant += 0.5;
            }
        }
    }
    if comparable == 0.0 {
        0.5
    } else {
        concordant / comparable
    }
}

/// Fenwick tree over rank-compressed risks (counts per rank).
struct Fenwick {
    tree: Vec<u64>,
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Fenwick { tree: vec![0; n + 1] }
    }

    fn add(&mut self, mut i: usize) {
        i += 1;
        while i < self.tree.len() {
            self.tree[i] += 1;
            i += i & i.wrapping_neg();
        }
    }

    /// Count of inserted ranks in [0, i].
    fn prefix(&self, mut i: usize) -> u64 {
        i += 1;
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// O(n log n) concordance: walk tie groups from latest to earliest time,
/// keeping a Fenwick tree of the risks of all strictly-later samples;
/// each event then counts later samples with smaller/equal/greater risk
/// in O(log n).
pub fn concordance_index_fast(time: &[f64], event: &[bool], risk: &[f64]) -> f64 {
    let n = time.len();
    assert_eq!(n, event.len());
    assert_eq!(n, risk.len());

    // Rank-compress risks.
    let mut sorted_risk: Vec<f64> = risk.to_vec();
    sorted_risk.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted_risk.dedup();
    let rank = |r: f64| sorted_risk.partition_point(|&x| x < r);

    // Time-descending order, grouped by equal time.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| time[b].partial_cmp(&time[a]).unwrap());

    let mut bit = Fenwick::new(sorted_risk.len());
    let mut inserted: u64 = 0;
    let (mut concordant, mut comparable) = (0.0_f64, 0.0_f64);
    let mut g = 0;
    while g < n {
        let mut h = g;
        while h < n && time[idx[h]] == time[idx[g]] {
            h += 1;
        }
        // Events in this group compare against everything inserted so
        // far (strictly later times).
        for &i in &idx[g..h] {
            if !event[i] || inserted == 0 {
                continue;
            }
            let r = rank(risk[i]);
            let le = bit.prefix(r); // later samples with risk <= risk_i
            let lt = if r == 0 { 0 } else { bit.prefix(r - 1) };
            let eq = le - lt;
            comparable += inserted as f64;
            concordant += lt as f64 + 0.5 * eq as f64;
        }
        for &i in &idx[g..h] {
            bit.add(rank(risk[i]));
            inserted += 1;
        }
        g = h;
    }
    if comparable == 0.0 {
        0.5
    } else {
        concordant / comparable
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_ordering_gives_one() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true; 4];
        let risk = vec![4.0, 3.0, 2.0, 1.0];
        assert_eq!(concordance_index(&time, &event, &risk), 1.0);
    }

    #[test]
    fn reversed_ordering_gives_zero() {
        let time = vec![1.0, 2.0, 3.0];
        let event = vec![true; 3];
        let risk = vec![1.0, 2.0, 3.0];
        assert_eq!(concordance_index(&time, &event, &risk), 0.0);
    }

    #[test]
    fn constant_risk_gives_half() {
        let time = vec![1.0, 2.0, 3.0];
        let event = vec![true; 3];
        let risk = vec![7.0; 3];
        assert_eq!(concordance_index(&time, &event, &risk), 0.5);
    }

    #[test]
    fn censored_earlier_times_are_not_comparable() {
        // i censored at t=1: pairs starting at i don't count.
        let time = vec![1.0, 2.0];
        let event = vec![false, true];
        let risk = vec![0.0, 1.0];
        assert_eq!(concordance_index(&time, &event, &risk), 0.5); // no pairs
    }

    #[test]
    fn random_risk_near_half() {
        let mut rng = Rng::new(5);
        let n = 400;
        let time: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        let risk: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let c = concordance_index(&time, &event, &risk);
        assert!((c - 0.5).abs() < 0.05, "c={c}");
    }

    #[test]
    fn fast_matches_naive_exactly() {
        use crate::util::proptest::check;
        check(
            "cindex-fast-vs-naive",
            211,
            40,
            |r| {
                let n = 5 + r.below(120);
                // Quantized times + risks force tie handling on both axes.
                let time: Vec<f64> = (0..n).map(|_| (r.uniform() * 8.0).round()).collect();
                let event: Vec<bool> = (0..n).map(|_| r.bernoulli(0.6)).collect();
                let risk: Vec<f64> = (0..n).map(|_| (r.normal() * 2.0).round()).collect();
                (time, event, risk)
            },
            |(time, event, risk)| {
                let a = concordance_index_naive(time, event, risk);
                let b = concordance_index_fast(time, event, risk);
                if (a - b).abs() < 1e-12 {
                    Ok(())
                } else {
                    Err(format!("naive {a} vs fast {b}"))
                }
            },
        );
    }

    #[test]
    fn informative_risk_above_half() {
        let mut rng = Rng::new(6);
        let n = 300;
        let risk: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let time: Vec<f64> = risk.iter().map(|&r| rng.exponential() / r.exp()).collect();
        let event: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.8)).collect();
        let c = concordance_index(&time, &event, &risk);
        assert!(c > 0.7, "c={c}");
    }
}
