//! Breslow baseline cumulative hazard for the fitted CPH model.
//!
//! `H₀(t) = Σ_{event times t_i ≤ t} d_i / Σ_{j ∈ R_i} exp(η_j)`, giving
//! individual survival predictions `S(t|x) = exp(−H₀(t)·e^{x^Tβ})` — the
//! link from a Cox risk score to the survival curves the Brier score needs.

/// Breslow estimator fit on training data.
#[derive(Clone, Debug)]
pub struct BreslowBaseline {
    /// Distinct event times, ascending.
    pub times: Vec<f64>,
    /// Cumulative baseline hazard at each time.
    pub cumhaz: Vec<f64>,
}

impl BreslowBaseline {
    /// Rebuild from persisted `(times, cumhaz)` pairs, validating the
    /// invariants a fitted estimator guarantees: equal lengths, strictly
    /// ascending finite times, and non-negative, non-decreasing hazard.
    /// Used by `CoxModel::load` so a corrupted model file fails loudly.
    pub fn from_parts(times: Vec<f64>, cumhaz: Vec<f64>) -> crate::error::Result<Self> {
        use crate::error::FastSurvivalError;
        if times.len() != cumhaz.len() {
            return Err(FastSurvivalError::InvalidData(format!(
                "baseline length mismatch: {} times vs {} hazard values",
                times.len(),
                cumhaz.len()
            )));
        }
        if times.iter().any(|t| !t.is_finite()) || cumhaz.iter().any(|h| !h.is_finite()) {
            return Err(FastSurvivalError::InvalidData(
                "baseline contains non-finite values".into(),
            ));
        }
        if times.windows(2).any(|w| w[0] >= w[1]) {
            return Err(FastSurvivalError::InvalidData(
                "baseline event times must be strictly ascending".into(),
            ));
        }
        if matches!(cumhaz.first(), Some(&h) if h < 0.0)
            || cumhaz.windows(2).any(|w| w[1] < w[0])
        {
            return Err(FastSurvivalError::InvalidData(
                "baseline cumulative hazard must be non-negative and non-decreasing".into(),
            ));
        }
        Ok(BreslowBaseline { times, cumhaz })
    }

    /// Fit from training observations and their linear predictors η.
    pub fn fit(time: &[f64], event: &[bool], eta: &[f64]) -> Self {
        let n = time.len();
        assert_eq!(n, event.len());
        assert_eq!(n, eta.len());
        // Stabilized exp.
        let m = eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let m = if m.is_finite() { m } else { 0.0 };
        let w: Vec<f64> = eta.iter().map(|&e| (e - m).exp()).collect();

        // Ascending time order; risk set = suffix.
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| time[a].partial_cmp(&time[b]).unwrap());
        // Suffix sums of w in ascending order.
        let mut suffix = vec![0.0_f64; n + 1];
        for k in (0..n).rev() {
            suffix[k] = suffix[k + 1] + w[idx[k]];
        }

        let mut times = Vec::new();
        let mut cumhaz = Vec::new();
        let mut h = 0.0_f64;
        let mut k = 0;
        while k < n {
            let t = time[idx[k]];
            let mut d = 0.0;
            let denom = suffix[k]; // all with time >= t (ties included)
            let mut kk = k;
            while kk < n && time[idx[kk]] == t {
                if event[idx[kk]] {
                    d += 1.0;
                }
                kk += 1;
            }
            if d > 0.0 && denom > 0.0 {
                // Un-shift: denom is Σ e^{η−m}, so divide by e^m implicitly
                // by scaling d (equivalently multiply hazard by e^{-m}).
                h += d / (denom * m.exp());
                times.push(t);
                cumhaz.push(h);
            }
            k = kk;
        }
        BreslowBaseline { times, cumhaz }
    }

    /// H₀(t), right-continuous.
    pub fn cumulative_hazard(&self, t: f64) -> f64 {
        match self.times.partition_point(|&x| x <= t) {
            0 => 0.0,
            k => self.cumhaz[k - 1],
        }
    }

    /// Predicted survival S(t | η) = exp(−H₀(t) e^η).
    pub fn survival(&self, t: f64, eta: f64) -> f64 {
        (-self.cumulative_hazard(t) * eta.exp()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_eta_matches_nelson_aalen() {
        use crate::metrics::km::NelsonAalen;
        let time = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let event = vec![true, false, true, true, false];
        let eta = vec![0.0; 5];
        let b = BreslowBaseline::fit(&time, &event, &eta);
        let na = NelsonAalen::fit(&time, &event);
        for t in [0.5, 1.0, 2.5, 3.0, 4.5, 6.0] {
            assert!(
                (b.cumulative_hazard(t) - na.at(t)).abs() < 1e-12,
                "t={t}: {} vs {}",
                b.cumulative_hazard(t),
                na.at(t)
            );
        }
    }

    #[test]
    fn survival_decreasing_in_time_and_risk() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true; 4];
        let eta = vec![0.5, -0.5, 0.2, -0.2];
        let b = BreslowBaseline::fit(&time, &event, &eta);
        let mut prev = 1.0;
        for t in [0.5, 1.0, 2.0, 3.0, 4.0] {
            let s = b.survival(t, 0.0);
            assert!(s <= prev + 1e-12);
            prev = s;
        }
        assert!(b.survival(2.0, 1.0) < b.survival(2.0, -1.0));
    }

    #[test]
    fn from_parts_round_trips_and_validates() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true, true, false, true];
        let eta = vec![0.3, -0.1, 0.7, 0.0];
        let b = BreslowBaseline::fit(&time, &event, &eta);
        let r = BreslowBaseline::from_parts(b.times.clone(), b.cumhaz.clone()).unwrap();
        for t in [0.5, 1.0, 2.5, 4.5] {
            assert_eq!(b.cumulative_hazard(t), r.cumulative_hazard(t));
        }
        // Corrupted inputs are rejected.
        assert!(BreslowBaseline::from_parts(vec![1.0], vec![]).is_err());
        assert!(BreslowBaseline::from_parts(vec![2.0, 1.0], vec![0.1, 0.2]).is_err());
        assert!(BreslowBaseline::from_parts(vec![1.0, 2.0], vec![0.2, 0.1]).is_err());
        assert!(BreslowBaseline::from_parts(vec![1.0], vec![f64::NAN]).is_err());
    }

    #[test]
    fn shift_invariant() {
        // Adding a constant to all η must rescale H0 so that predicted
        // survival for a training subject is unchanged.
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true, true, false, true];
        let eta = vec![0.3, -0.1, 0.7, 0.0];
        let eta_shift: Vec<f64> = eta.iter().map(|e| e + 5.0).collect();
        let b0 = BreslowBaseline::fit(&time, &event, &eta);
        let b1 = BreslowBaseline::fit(&time, &event, &eta_shift);
        for (i, t) in [(0usize, 1.5), (2, 3.5)] {
            let s0 = b0.survival(t, eta[i]);
            let s1 = b1.survival(t, eta_shift[i]);
            assert!((s0 - s1).abs() < 1e-10, "{s0} vs {s1}");
        }
    }
}
