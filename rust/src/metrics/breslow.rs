//! Breslow baseline cumulative hazard for the fitted CPH model.
//!
//! `H₀(t) = Σ_{event times t_i ≤ t} d_i / Σ_{j ∈ R_i} exp(η_j)`, giving
//! individual survival predictions `S(t|x) = exp(−H₀(t)·e^{x^Tβ})` — the
//! link from a Cox risk score to the survival curves the Brier score needs.

/// Breslow estimator fit on training data.
#[derive(Clone, Debug)]
pub struct BreslowBaseline {
    /// Distinct event times, ascending.
    pub times: Vec<f64>,
    /// Cumulative baseline hazard at each time.
    pub cumhaz: Vec<f64>,
}

impl BreslowBaseline {
    /// Rebuild from persisted `(times, cumhaz)` pairs, validating the
    /// invariants a fitted estimator guarantees: equal lengths, strictly
    /// ascending finite times, and non-negative, non-decreasing hazard.
    /// Used by `CoxModel::load` so a corrupted model file fails loudly.
    pub fn from_parts(times: Vec<f64>, cumhaz: Vec<f64>) -> crate::error::Result<Self> {
        use crate::error::FastSurvivalError;
        if times.len() != cumhaz.len() {
            return Err(FastSurvivalError::InvalidData(format!(
                "baseline length mismatch: {} times vs {} hazard values",
                times.len(),
                cumhaz.len()
            )));
        }
        if times.iter().any(|t| !t.is_finite()) || cumhaz.iter().any(|h| !h.is_finite()) {
            return Err(FastSurvivalError::InvalidData(
                "baseline contains non-finite values".into(),
            ));
        }
        if times.windows(2).any(|w| w[0] >= w[1]) {
            return Err(FastSurvivalError::InvalidData(
                "baseline event times must be strictly ascending".into(),
            ));
        }
        if matches!(cumhaz.first(), Some(&h) if h < 0.0)
            || cumhaz.windows(2).any(|w| w[1] < w[0])
        {
            return Err(FastSurvivalError::InvalidData(
                "baseline cumulative hazard must be non-negative and non-decreasing".into(),
            ));
        }
        Ok(BreslowBaseline { times, cumhaz })
    }

    /// Fit from training observations and their linear predictors η.
    pub fn fit(time: &[f64], event: &[bool], eta: &[f64]) -> Self {
        let n = time.len();
        assert_eq!(n, event.len());
        assert_eq!(n, eta.len());
        // Stabilized exp.
        let m = eta.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let m = if m.is_finite() { m } else { 0.0 };
        let w: Vec<f64> = eta.iter().map(|&e| (e - m).exp()).collect();

        // Ascending time order; risk set = suffix.
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| time[a].partial_cmp(&time[b]).unwrap());
        // Suffix sums of w in ascending order.
        let mut suffix = vec![0.0_f64; n + 1];
        for k in (0..n).rev() {
            suffix[k] = suffix[k + 1] + w[idx[k]];
        }

        let mut times = Vec::new();
        let mut cumhaz = Vec::new();
        let mut h = 0.0_f64;
        let mut k = 0;
        while k < n {
            let t = time[idx[k]];
            let mut d = 0.0;
            let denom = suffix[k]; // all with time >= t (ties included)
            let mut kk = k;
            while kk < n && time[idx[kk]] == t {
                if event[idx[kk]] {
                    d += 1.0;
                }
                kk += 1;
            }
            if d > 0.0 && denom > 0.0 {
                // Un-shift: denom is Σ e^{η−m}, so divide by e^m implicitly
                // by scaling d (equivalently multiply hazard by e^{-m}).
                h += d / (denom * m.exp());
                times.push(t);
                cumhaz.push(h);
            }
            k = kk;
        }
        BreslowBaseline { times, cumhaz }
    }

    /// H₀(t), right-continuous. A single binary search over the step
    /// table (`partition_point`), O(log m) per lookup.
    pub fn cumulative_hazard(&self, t: f64) -> f64 {
        match self.times.partition_point(|&x| x <= t) {
            0 => 0.0,
            k => self.cumhaz[k - 1],
        }
    }

    /// H₀ evaluated at many query times in one merged scan: O(m + k)
    /// for k queries against m event times, versus O(k log m) for
    /// repeated [`BreslowBaseline::cumulative_hazard`] calls. This is
    /// the serving hot path — survival curves at a horizon grid walk
    /// the step table exactly once.
    ///
    /// `ts_sorted` must be ascending (and therefore NaN-free); the
    /// precondition is asserted because a silent violation would return
    /// stale hazards for out-of-order entries.
    pub fn cumulative_hazard_many(&self, ts_sorted: &[f64]) -> Vec<f64> {
        assert!(
            ts_sorted.windows(2).all(|w| w[0] <= w[1]),
            "cumulative_hazard_many requires ascending query times"
        );
        let mut out = Vec::with_capacity(ts_sorted.len());
        let mut k = 0usize;
        let mut h = 0.0f64;
        for &t in ts_sorted {
            while k < self.times.len() && self.times[k] <= t {
                h = self.cumhaz[k];
                k += 1;
            }
            out.push(h);
        }
        out
    }

    /// H₀ at arbitrary (possibly unsorted, possibly duplicated) query
    /// times: sorts a copy, runs the merged scan, and undoes the
    /// permutation. This is the one implementation shared by
    /// `CoxModel::predict_survival_curve` and the serving scorer's
    /// horizon-grid cache, so the two paths stay bit-identical by
    /// construction. Query times must be NaN-free (callers validate
    /// finiteness; NaN panics in the sort comparator).
    pub fn cumulative_hazard_unsorted(&self, ts: &[f64]) -> Vec<f64> {
        let mut order: Vec<usize> = (0..ts.len()).collect();
        order.sort_by(|&a, &b| ts[a].partial_cmp(&ts[b]).unwrap());
        let sorted: Vec<f64> = order.iter().map(|&i| ts[i]).collect();
        let h_sorted = self.cumulative_hazard_many(&sorted);
        let mut out = vec![0.0; ts.len()];
        for (s, &original) in order.iter().enumerate() {
            out[original] = h_sorted[s];
        }
        out
    }

    /// Predicted survival S(t | η) = exp(−H₀(t) e^η).
    pub fn survival(&self, t: f64, eta: f64) -> f64 {
        (-self.cumulative_hazard(t) * eta.exp()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_eta_matches_nelson_aalen() {
        use crate::metrics::km::NelsonAalen;
        let time = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let event = vec![true, false, true, true, false];
        let eta = vec![0.0; 5];
        let b = BreslowBaseline::fit(&time, &event, &eta);
        let na = NelsonAalen::fit(&time, &event);
        for t in [0.5, 1.0, 2.5, 3.0, 4.5, 6.0] {
            assert!(
                (b.cumulative_hazard(t) - na.at(t)).abs() < 1e-12,
                "t={t}: {} vs {}",
                b.cumulative_hazard(t),
                na.at(t)
            );
        }
    }

    #[test]
    fn survival_decreasing_in_time_and_risk() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true; 4];
        let eta = vec![0.5, -0.5, 0.2, -0.2];
        let b = BreslowBaseline::fit(&time, &event, &eta);
        let mut prev = 1.0;
        for t in [0.5, 1.0, 2.0, 3.0, 4.0] {
            let s = b.survival(t, 0.0);
            assert!(s <= prev + 1e-12);
            prev = s;
        }
        assert!(b.survival(2.0, 1.0) < b.survival(2.0, -1.0));
    }

    #[test]
    fn from_parts_round_trips_and_validates() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true, true, false, true];
        let eta = vec![0.3, -0.1, 0.7, 0.0];
        let b = BreslowBaseline::fit(&time, &event, &eta);
        let r = BreslowBaseline::from_parts(b.times.clone(), b.cumhaz.clone()).unwrap();
        for t in [0.5, 1.0, 2.5, 4.5] {
            assert_eq!(b.cumulative_hazard(t), r.cumulative_hazard(t));
        }
        // Corrupted inputs are rejected.
        assert!(BreslowBaseline::from_parts(vec![1.0], vec![]).is_err());
        assert!(BreslowBaseline::from_parts(vec![2.0, 1.0], vec![0.1, 0.2]).is_err());
        assert!(BreslowBaseline::from_parts(vec![1.0, 2.0], vec![0.2, 0.1]).is_err());
        assert!(BreslowBaseline::from_parts(vec![1.0], vec![f64::NAN]).is_err());
    }

    #[test]
    fn many_scan_matches_single_lookups() {
        let time = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let event = vec![true, true, false, true, true, false];
        let eta = vec![0.3, -0.1, 0.7, 0.0, -0.4, 0.2];
        let b = BreslowBaseline::fit(&time, &event, &eta);
        // Queries straddling every step boundary, plus before-first and
        // after-last, with repeats and exact-tie hits.
        let ts = [0.0, 0.5, 1.0, 1.0, 1.5, 2.0, 3.5, 4.0, 4.0, 9.0];
        let many = b.cumulative_hazard_many(&ts);
        for (i, &t) in ts.iter().enumerate() {
            assert_eq!(
                many[i].to_bits(),
                b.cumulative_hazard(t).to_bits(),
                "t={t}"
            );
        }
        // Empty query list and empty baseline are both fine.
        assert!(b.cumulative_hazard_many(&[]).is_empty());
        let empty = BreslowBaseline { times: vec![], cumhaz: vec![] };
        assert_eq!(empty.cumulative_hazard_many(&[1.0, 2.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn unsorted_queries_match_single_lookups_in_caller_order() {
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true, true, false, true];
        let eta = vec![0.3, -0.1, 0.7, 0.0];
        let b = BreslowBaseline::fit(&time, &event, &eta);
        let ts = [2.5, 0.5, 4.0, 2.5, 100.0]; // unsorted, with a duplicate
        let h = b.cumulative_hazard_unsorted(&ts);
        for (i, &t) in ts.iter().enumerate() {
            assert_eq!(h[i].to_bits(), b.cumulative_hazard(t).to_bits(), "t={t}");
        }
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn many_scan_rejects_unsorted_queries() {
        let b = BreslowBaseline { times: vec![1.0], cumhaz: vec![0.5] };
        b.cumulative_hazard_many(&[2.0, 1.0]);
    }

    #[test]
    fn shift_invariant() {
        // Adding a constant to all η must rescale H0 so that predicted
        // survival for a training subject is unchanged.
        let time = vec![1.0, 2.0, 3.0, 4.0];
        let event = vec![true, true, false, true];
        let eta = vec![0.3, -0.1, 0.7, 0.0];
        let eta_shift: Vec<f64> = eta.iter().map(|e| e + 5.0).collect();
        let b0 = BreslowBaseline::fit(&time, &event, &eta);
        let b1 = BreslowBaseline::fit(&time, &event, &eta_shift);
        for (i, t) in [(0usize, 1.5), (2, 3.5)] {
            let s0 = b0.survival(t, eta[i]);
            let s1 = b1.survival(t, eta_shift[i]);
            assert!((s0 - s1).abs() < 1e-10, "{s0} vs {s1}");
        }
    }
}
