//! Crate-wide training telemetry: span timing, engine counters, and
//! emission into model diagnostics, JSONL trace files, and `/metrics`.
//!
//! Layout:
//!
//! - [`hist`] — the one log₂ duration histogram, shared with serving
//!   (`serve/stats.rs` re-exports it for its endpoint latency stats);
//! - [`span`] — the fixed phase taxonomy ([`Phase`]), the RAII
//!   [`SpanTimer`], and the process-global sink (static relaxed
//!   atomics; disabled fast path is one atomic load per span);
//! - [`counters`] — engine counters (Workspace cache hits, kernel
//!   invocations per backend, screening skips, KKT repair rounds,
//!   shard-protocol commands) plus the always-on training gauges the
//!   `/metrics` document serves;
//! - [`report`] — per-fit [`FitReport`] diffs attached to
//!   `CoxModel`/`CoxPath` diagnostics, and the `--trace-out` JSONL
//!   format with its parser (the `profile` subcommand's input);
//! - [`recorder`] — request-level serving telemetry: the six-stage
//!   request-lifecycle taxonomy ([`Stage`]), the [`FlightRecorder`]
//!   ring of completed request records (plus a pinned slow-request
//!   ring), and [`SlicedMetrics`] keyed by endpoint × model@version ×
//!   batch-size bucket. `serve/http.rs` records into it; the
//!   `/debug/trace` endpoint and the access log render out of it.
//!
//! Everything is std-only and compiled in unconditionally; recording is
//! switched on per-process with [`set_enabled`] (the CLI does this when
//! `--trace-out` is given). Tracing never touches the optimizer's
//! floating-point stream — a traced fit is bitwise identical to an
//! untraced one.

pub mod counters;
pub mod hist;
pub mod recorder;
pub mod report;
pub mod span;

pub use counters::{
    counter_snapshot, record_watch_cycle, training_gauges, CounterSnapshot, ShardCmdKind,
    TrainingGauges,
};
pub use recorder::{
    batch_bucket, parse_request_records, render_debug_trace, render_sliced_prometheus,
    write_record_json, write_sliced_json, FlightRecorder, ParsedRequest, RequestRecord,
    SliceSnapshot, SlicedMetrics, Stage, N_STAGES,
};
pub use report::{
    obs_snapshot, parse_trace_jsonl, render_trace_jsonl, write_trace_jsonl, FitReport,
    ObsSnapshot, TraceDoc,
};
pub use span::{enabled, reset, set_enabled, snapshot_phases, Phase, SpanTimer};
