//! Engine counters (relaxed atomics) and the training-side gauges the
//! serving `/metrics` document exposes.
//!
//! Counter recording is gated on [`super::span::enabled`] — the same
//! one-atomic-load fast path as spans — and every site increments at a
//! coarse chokepoint (once per pass, per λ point, or per shard
//! command), never per row. Training gauges are different: they are
//! always-on serving state updated once per watch cycle, so an
//! in-process scoring server (the live smoke harness, tests, embedded
//! deployments) can report refit/publish health without tracing being
//! enabled.

use std::sync::atomic::{AtomicU64, Ordering};

static WORKSPACE_HITS: AtomicU64 = AtomicU64::new(0);
static WORKSPACE_MISSES: AtomicU64 = AtomicU64::new(0);
static KERNEL_SCALAR: AtomicU64 = AtomicU64::new(0);
static KERNEL_SIMD: AtomicU64 = AtomicU64::new(0);
static SCREENED_SKIPS: AtomicU64 = AtomicU64::new(0);
static KKT_REPAIR_ROUNDS: AtomicU64 = AtomicU64::new(0);
static SHARD_SCAN_CMDS: AtomicU64 = AtomicU64::new(0);
static SHARD_EMIT_CMDS: AtomicU64 = AtomicU64::new(0);
static SHARD_APPLY_CMDS: AtomicU64 = AtomicU64::new(0);
static SHARD_CTL_CMDS: AtomicU64 = AtomicU64::new(0);

/// Workspace derivative-cache outcome, keyed on `CoxState::version()`:
/// a hit reuses the cached risk-set prefix sums, a miss rebuilds them.
#[inline]
pub fn workspace_cache(hit: bool) {
    if !super::span::enabled() {
        return;
    }
    let c = if hit { &WORKSPACE_HITS } else { &WORKSPACE_MISSES };
    c.fetch_add(1, Ordering::Relaxed);
}

/// `n` derivative-kernel invocations on the given backend (one per
/// column of a batched pass, or one per single-column step).
#[inline]
pub fn kernel_calls(simd: bool, n: u64) {
    if !super::span::enabled() {
        return;
    }
    let c = if simd { &KERNEL_SIMD } else { &KERNEL_SCALAR };
    c.fetch_add(n, Ordering::Relaxed);
}

/// `n` coordinates the strong rule screened out of one λ point's
/// candidate set (work the solver never had to do).
#[inline]
pub fn screened_skips(n: u64) {
    if !super::span::enabled() {
        return;
    }
    SCREENED_SKIPS.fetch_add(n, Ordering::Relaxed);
}

/// `n` KKT repair rounds (re-sweeps after a violation check found
/// screened-out coordinates that wanted in).
#[inline]
pub fn kkt_repair_rounds(n: u64) {
    if !super::span::enabled() {
        return;
    }
    KKT_REPAIR_ROUNDS.fetch_add(n, Ordering::Relaxed);
}

/// Shard-protocol command classes, counted at the coordinator's send.
#[derive(Clone, Copy, Debug)]
pub enum ShardCmdKind {
    Scan,
    Emit,
    Apply,
    /// Control-plane commands (EtaMax, Rebase).
    Ctl,
}

/// One shard-protocol command broadcast by the coordinator.
#[inline]
pub fn shard_cmd(kind: ShardCmdKind) {
    if !super::span::enabled() {
        return;
    }
    let c = match kind {
        ShardCmdKind::Scan => &SHARD_SCAN_CMDS,
        ShardCmdKind::Emit => &SHARD_EMIT_CMDS,
        ShardCmdKind::Apply => &SHARD_APPLY_CMDS,
        ShardCmdKind::Ctl => &SHARD_CTL_CMDS,
    };
    c.fetch_add(1, Ordering::Relaxed);
}

/// A read-only copy of every engine counter.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub workspace_hits: u64,
    pub workspace_misses: u64,
    pub kernel_scalar: u64,
    pub kernel_simd: u64,
    pub screened_skips: u64,
    pub kkt_repair_rounds: u64,
    pub shard_scan_cmds: u64,
    pub shard_emit_cmds: u64,
    pub shard_apply_cmds: u64,
    pub shard_ctl_cmds: u64,
}

impl CounterSnapshot {
    /// Field-wise difference (`self` − `before`), for diffing two
    /// snapshots around one fit.
    pub fn since(&self, before: &CounterSnapshot) -> CounterSnapshot {
        CounterSnapshot {
            workspace_hits: self.workspace_hits - before.workspace_hits,
            workspace_misses: self.workspace_misses - before.workspace_misses,
            kernel_scalar: self.kernel_scalar - before.kernel_scalar,
            kernel_simd: self.kernel_simd - before.kernel_simd,
            screened_skips: self.screened_skips - before.screened_skips,
            kkt_repair_rounds: self.kkt_repair_rounds - before.kkt_repair_rounds,
            shard_scan_cmds: self.shard_scan_cmds - before.shard_scan_cmds,
            shard_emit_cmds: self.shard_emit_cmds - before.shard_emit_cmds,
            shard_apply_cmds: self.shard_apply_cmds - before.shard_apply_cmds,
            shard_ctl_cmds: self.shard_ctl_cmds - before.shard_ctl_cmds,
        }
    }

    /// `(name, value)` pairs in a stable order — one loop serves JSON,
    /// JSONL, and the profile table.
    pub fn fields(&self) -> [(&'static str, u64); 10] {
        [
            ("workspace_hits", self.workspace_hits),
            ("workspace_misses", self.workspace_misses),
            ("kernel_scalar", self.kernel_scalar),
            ("kernel_simd", self.kernel_simd),
            ("screened_skips", self.screened_skips),
            ("kkt_repair_rounds", self.kkt_repair_rounds),
            ("shard_scan_cmds", self.shard_scan_cmds),
            ("shard_emit_cmds", self.shard_emit_cmds),
            ("shard_apply_cmds", self.shard_apply_cmds),
            ("shard_ctl_cmds", self.shard_ctl_cmds),
        ]
    }

    /// Build from `(name, value)` pairs (unknown names ignored) — the
    /// inverse of [`CounterSnapshot::fields`] for deserialization.
    pub fn from_fields<'a>(pairs: impl Iterator<Item = (&'a str, u64)>) -> CounterSnapshot {
        let mut c = CounterSnapshot::default();
        for (name, v) in pairs {
            match name {
                "workspace_hits" => c.workspace_hits = v,
                "workspace_misses" => c.workspace_misses = v,
                "kernel_scalar" => c.kernel_scalar = v,
                "kernel_simd" => c.kernel_simd = v,
                "screened_skips" => c.screened_skips = v,
                "kkt_repair_rounds" => c.kkt_repair_rounds = v,
                "shard_scan_cmds" => c.shard_scan_cmds = v,
                "shard_emit_cmds" => c.shard_emit_cmds = v,
                "shard_apply_cmds" => c.shard_apply_cmds = v,
                "shard_ctl_cmds" => c.shard_ctl_cmds = v,
                _ => {}
            }
        }
        c
    }
}

/// Snapshot every engine counter.
pub fn counter_snapshot() -> CounterSnapshot {
    CounterSnapshot {
        workspace_hits: WORKSPACE_HITS.load(Ordering::Relaxed),
        workspace_misses: WORKSPACE_MISSES.load(Ordering::Relaxed),
        kernel_scalar: KERNEL_SCALAR.load(Ordering::Relaxed),
        kernel_simd: KERNEL_SIMD.load(Ordering::Relaxed),
        screened_skips: SCREENED_SKIPS.load(Ordering::Relaxed),
        kkt_repair_rounds: KKT_REPAIR_ROUNDS.load(Ordering::Relaxed),
        shard_scan_cmds: SHARD_SCAN_CMDS.load(Ordering::Relaxed),
        shard_emit_cmds: SHARD_EMIT_CMDS.load(Ordering::Relaxed),
        shard_apply_cmds: SHARD_APPLY_CMDS.load(Ordering::Relaxed),
        shard_ctl_cmds: SHARD_CTL_CMDS.load(Ordering::Relaxed),
    }
}

/// Zero every engine counter (called by [`super::span::reset`]).
pub(crate) fn reset_counters() {
    for c in [
        &WORKSPACE_HITS,
        &WORKSPACE_MISSES,
        &KERNEL_SCALAR,
        &KERNEL_SIMD,
        &SCREENED_SKIPS,
        &KKT_REPAIR_ROUNDS,
        &SHARD_SCAN_CMDS,
        &SHARD_EMIT_CMDS,
        &SHARD_APPLY_CMDS,
        &SHARD_CTL_CMDS,
    ] {
        c.store(0, Ordering::Relaxed);
    }
}

// ------------------------------------------------- training gauges

static LAST_REFIT_US: AtomicU64 = AtomicU64::new(0);
static LAST_SWEEPS: AtomicU64 = AtomicU64::new(0);
static PUBLISHES: AtomicU64 = AtomicU64::new(0);
static REJECTS: AtomicU64 = AtomicU64::new(0);

/// Record one watch-mode cycle: refit wall time, exact-phase sweeps,
/// and the publish-gate outcome. Always on (not gated on tracing).
pub fn record_watch_cycle(refit_secs: f64, sweeps: usize, published: bool) {
    LAST_REFIT_US.store((refit_secs * 1e6) as u64, Ordering::Relaxed);
    LAST_SWEEPS.store(sweeps as u64, Ordering::Relaxed);
    let c = if published { &PUBLISHES } else { &REJECTS };
    c.fetch_add(1, Ordering::Relaxed);
}

/// Training-side gauges for the `/metrics` document.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainingGauges {
    /// Wall seconds of the most recent warm refit (0 before the first).
    pub last_refit_secs: f64,
    /// Exact-phase sweeps of the most recent refit.
    pub last_sweeps: u64,
    /// Watch cycles whose candidate was published.
    pub publishes: u64,
    /// Watch cycles whose candidate the gate rejected.
    pub rejects: u64,
}

/// Snapshot the training gauges.
pub fn training_gauges() -> TrainingGauges {
    TrainingGauges {
        last_refit_secs: LAST_REFIT_US.load(Ordering::Relaxed) as f64 / 1e6,
        last_sweeps: LAST_SWEEPS.load(Ordering::Relaxed),
        publishes: PUBLISHES.load(Ordering::Relaxed),
        rejects: REJECTS.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::super::span::test_support::obs_test_guard;
    use super::super::span::{reset, set_enabled};
    use super::*;

    #[test]
    fn counters_gate_on_enabled_and_diff_cleanly() {
        let _g = obs_test_guard();
        set_enabled(false);
        reset();
        workspace_cache(true);
        kernel_calls(true, 10);
        assert_eq!(counter_snapshot(), CounterSnapshot::default());

        set_enabled(true);
        let before = counter_snapshot();
        workspace_cache(true);
        workspace_cache(false);
        kernel_calls(true, 10);
        kernel_calls(false, 3);
        screened_skips(7);
        kkt_repair_rounds(2);
        shard_cmd(ShardCmdKind::Scan);
        shard_cmd(ShardCmdKind::Emit);
        shard_cmd(ShardCmdKind::Apply);
        shard_cmd(ShardCmdKind::Ctl);
        let diff = counter_snapshot().since(&before);
        set_enabled(false);
        assert_eq!(diff.workspace_hits, 1);
        assert_eq!(diff.workspace_misses, 1);
        assert_eq!(diff.kernel_simd, 10);
        assert_eq!(diff.kernel_scalar, 3);
        assert_eq!(diff.screened_skips, 7);
        assert_eq!(diff.kkt_repair_rounds, 2);
        assert_eq!(diff.shard_scan_cmds, 1);
        assert_eq!(diff.shard_emit_cmds, 1);
        assert_eq!(diff.shard_apply_cmds, 1);
        assert_eq!(diff.shard_ctl_cmds, 1);
        // fields() / from_fields() are inverse.
        let rebuilt = CounterSnapshot::from_fields(diff.fields().into_iter());
        assert_eq!(rebuilt, diff);
        reset();
        assert_eq!(counter_snapshot(), CounterSnapshot::default());
    }

    #[test]
    fn training_gauges_track_cycles_without_tracing() {
        let _g = obs_test_guard();
        set_enabled(false);
        let before = training_gauges();
        record_watch_cycle(0.25, 6, true);
        record_watch_cycle(0.125, 2, false);
        let g = training_gauges();
        assert!((g.last_refit_secs - 0.125).abs() < 1e-9);
        assert_eq!(g.last_sweeps, 2);
        assert_eq!(g.publishes, before.publishes + 1);
        assert_eq!(g.rejects, before.rejects + 1);
    }
}
