//! Request-level serving telemetry: the request-lifecycle stage
//! taxonomy, the flight recorder (a fixed-capacity ring of completed
//! request records plus a separately pinned slow-request ring), and
//! sliced SLO metrics keyed by endpoint × model@version × batch-size
//! bucket.
//!
//! The lifecycle taxonomy is the serving twin of the training-side
//! [`crate::obs::Phase`] set: every HTTP request decomposes into six
//! stages — `read` (socket → framed request), `parse` (JSON body →
//! validated rows), `queue_wait` (enqueue → micro-batch claim),
//! `batch_score` (claim → scores delivered), `serialize` (response
//! body build), `write` (response → socket). The stages partition the
//! request's wall clock: their sum reconciles with `total_us` up to
//! integer-microsecond truncation and a few nanoseconds of routing
//! glue.
//!
//! The flight recorder is written on the request hot path, so it must
//! never serialize concurrent connection handlers: a writer claims a
//! slot index with one `fetch_add` on the head counter (lock-free), and
//! is then the slot's only writer until the ring wraps all the way
//! around. The per-slot mutex exists solely for that wraparound case
//! and for readers (`/debug/trace`) — in steady state it is always
//! uncontended. A stale writer that loses a wraparound race is dropped
//! by sequence comparison rather than overwriting a newer record.
//!
//! One JSON schema covers both sinks: an access-log line and a
//! `/debug/trace` record are the same flat object, so the `profile`
//! subcommand parses either with [`parse_request_records`].

use crate::api::json::{self, Json};
use crate::error::{FastSurvivalError, Result};
use crate::obs::hist::{quantile_from_counts, write_prom_cumulative, LatencyHistogram, N_BUCKETS};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Number of request-lifecycle stages.
pub const N_STAGES: usize = 6;

/// One stage of the request lifecycle, in wall-clock order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Socket bytes → one framed request (head + body buffered).
    Read = 0,
    /// JSON body parse, spec/row validation, model resolution.
    Parse = 1,
    /// Enqueue into the micro-batcher → batch claim (includes linger).
    QueueWait = 2,
    /// Batch claim → scores delivered back to the handler.
    BatchScore = 3,
    /// Response body construction.
    Serialize = 4,
    /// Response bytes → socket (including flush).
    Write = 5,
}

impl Stage {
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Read,
        Stage::Parse,
        Stage::QueueWait,
        Stage::BatchScore,
        Stage::Serialize,
        Stage::Write,
    ];

    pub fn index(self) -> usize {
        self as usize
    }

    /// Human-readable stage name (the taxonomy in docs and tables).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Read => "read",
            Stage::Parse => "parse",
            Stage::QueueWait => "queue_wait",
            Stage::BatchScore => "batch_score",
            Stage::Serialize => "serialize",
            Stage::Write => "write",
        }
    }

    /// JSON field key carrying this stage's microseconds.
    pub fn key(self) -> &'static str {
        match self {
            Stage::Read => "read_us",
            Stage::Parse => "parse_us",
            Stage::QueueWait => "queue_wait_us",
            Stage::BatchScore => "batch_score_us",
            Stage::Serialize => "serialize_us",
            Stage::Write => "write_us",
        }
    }
}

/// One completed request, as the flight recorder stores it.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    /// Global completion sequence, assigned by
    /// [`FlightRecorder::record`] (0 until then).
    pub seq: u64,
    /// Request ID: the client's `x-request-id` header, or a generated
    /// `fs-<n>` from the server's atomic counter.
    pub id: String,
    /// Routing key (`score`, `healthz`, …) — same vocabulary as the
    /// per-endpoint stats.
    pub endpoint: &'static str,
    /// `name@version` of the model that served the request; empty for
    /// non-scoring endpoints.
    pub model: String,
    /// Rows scored (0 for non-scoring endpoints).
    pub rows: u64,
    /// HTTP status of the response.
    pub status: u16,
    /// Per-stage microseconds, indexed by [`Stage::index`].
    pub stage_us: [u64; N_STAGES],
    /// End-to-end wall microseconds (first byte read → response flushed).
    pub total_us: u64,
}

impl RequestRecord {
    /// Sum of the stage micros — reconciles with `total_us` up to
    /// truncation (each stage rounds down independently).
    pub fn stage_sum_us(&self) -> u64 {
        self.stage_us.iter().sum()
    }
}

/// Fixed-capacity ring of the last N completed requests, plus a
/// separate ring pinned to slow requests so a burst of fast traffic
/// can never evict the outliers worth debugging.
pub struct FlightRecorder {
    slots: Vec<Mutex<Option<RequestRecord>>>,
    head: AtomicU64,
    slow_slots: Vec<Mutex<Option<RequestRecord>>>,
    slow_head: AtomicU64,
    slow_threshold_us: u64,
}

impl FlightRecorder {
    /// `slow_threshold_us == 0` disables the slow ring (nothing is ever
    /// pinned); the main ring always records.
    pub fn new(capacity: usize, slow_capacity: usize, slow_threshold_us: u64) -> Self {
        let capacity = capacity.max(1);
        let slow_capacity = slow_capacity.max(1);
        FlightRecorder {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
            slow_slots: (0..slow_capacity).map(|_| Mutex::new(None)).collect(),
            slow_head: AtomicU64::new(0),
            slow_threshold_us,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records ever written (monotonic; exceeds `capacity()` once
    /// the ring has wrapped).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    pub fn slow_threshold_us(&self) -> u64 {
        self.slow_threshold_us
    }

    /// Claim the next completion sequence number (one lock-free
    /// `fetch_add`). Callers that need the sequence before committing —
    /// e.g. to stamp an access-log line — claim here, set
    /// `rec.seq`, and [`commit`](FlightRecorder::commit) afterwards.
    pub fn begin(&self) -> u64 {
        self.head.fetch_add(1, Ordering::Relaxed)
    }

    /// Store one completed request. The slot index comes from a single
    /// lock-free `fetch_add`; the claimed slot's mutex is uncontended
    /// unless the ring wraps a full revolution mid-write, in which case
    /// the sequence comparison keeps the newest record.
    pub fn record(&self, mut rec: RequestRecord) {
        rec.seq = self.begin();
        self.commit(rec);
    }

    /// Store a record whose `seq` was already claimed with
    /// [`begin`](FlightRecorder::begin).
    pub fn commit(&self, rec: RequestRecord) {
        let seq = rec.seq;
        if self.slow_threshold_us > 0 && rec.total_us >= self.slow_threshold_us {
            let s = self.slow_head.fetch_add(1, Ordering::Relaxed);
            let idx = (s % self.slow_slots.len() as u64) as usize;
            let mut slot = self.slow_slots[idx].lock().unwrap();
            if slot.as_ref().map_or(true, |old| old.seq <= seq) {
                *slot = Some(rec.clone());
            }
        }
        let idx = (seq % self.slots.len() as u64) as usize;
        let mut slot = self.slots[idx].lock().unwrap();
        if slot.as_ref().map_or(true, |old| old.seq <= seq) {
            *slot = Some(rec);
        }
    }

    /// The last `k` completed records, oldest first.
    pub fn last(&self, k: usize) -> Vec<RequestRecord> {
        let mut all: Vec<RequestRecord> =
            self.slots.iter().filter_map(|s| s.lock().unwrap().clone()).collect();
        all.sort_by_key(|r| r.seq);
        if all.len() > k {
            all.drain(..all.len() - k);
        }
        all
    }

    /// Every pinned slow request, oldest first.
    pub fn slow(&self) -> Vec<RequestRecord> {
        let mut all: Vec<RequestRecord> =
            self.slow_slots.iter().filter_map(|s| s.lock().unwrap().clone()).collect();
        all.sort_by_key(|r| r.seq);
        all
    }
}

/// Serialize one record as the flat JSON object shared by the access
/// log (one line per request) and the `/debug/trace` dump.
pub fn write_record_json(r: &RequestRecord, out: &mut String) {
    out.push_str("{\"seq\": ");
    out.push_str(&r.seq.to_string());
    out.push_str(", \"id\": ");
    json::write_str(out, &r.id);
    out.push_str(", \"endpoint\": ");
    json::write_str(out, r.endpoint);
    out.push_str(", \"model\": ");
    json::write_str(out, &r.model);
    let _ = write!(out, ", \"rows\": {}, \"status\": {}", r.rows, r.status);
    for st in Stage::ALL {
        let _ = write!(out, ", \"{}\": {}", st.key(), r.stage_us[st.index()]);
    }
    let _ = write!(out, ", \"total_us\": {}}}", r.total_us);
}

/// The `/debug/trace?n=K` response body: the last K completed records
/// plus everything pinned in the slow ring.
pub fn render_debug_trace(rec: &FlightRecorder, n: usize) -> String {
    let records = rec.last(n);
    let slow = rec.slow();
    let mut out = String::with_capacity(256 + 192 * (records.len() + slow.len()));
    let _ = write!(
        out,
        "{{\"capacity\": {}, \"recorded\": {}, \"slow_threshold_us\": {}, \"records\": [",
        rec.capacity(),
        rec.recorded(),
        rec.slow_threshold_us()
    );
    for (i, r) in records.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        write_record_json(r, &mut out);
    }
    out.push_str("], \"slow\": [");
    for (i, r) in slow.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        write_record_json(r, &mut out);
    }
    out.push_str("]}");
    out
}

/// A request record parsed back out of an access log or `/debug/trace`
/// dump (endpoint/model become owned strings off the wire).
#[derive(Clone, Debug)]
pub struct ParsedRequest {
    pub id: String,
    pub endpoint: String,
    pub model: String,
    pub rows: u64,
    pub status: u16,
    pub stage_us: [u64; N_STAGES],
    pub total_us: u64,
}

impl ParsedRequest {
    pub fn stage_sum_us(&self) -> u64 {
        self.stage_us.iter().sum()
    }
}

fn parse_one_record(doc: &Json) -> Result<ParsedRequest> {
    let u64_field = |key: &str| -> Result<u64> {
        let v = doc.require(key)?.as_f64()?;
        if !(v.is_finite() && v >= 0.0) {
            return Err(FastSurvivalError::InvalidData(format!(
                "request record field {key:?} must be a non-negative number, got {v}"
            )));
        }
        Ok(v as u64)
    };
    let mut stage_us = [0u64; N_STAGES];
    for st in Stage::ALL {
        stage_us[st.index()] = u64_field(st.key())?;
    }
    Ok(ParsedRequest {
        id: doc.require("id")?.as_str()?.to_string(),
        endpoint: doc.require("endpoint")?.as_str()?.to_string(),
        model: doc.require("model")?.as_str()?.to_string(),
        rows: u64_field("rows")?,
        status: u64_field("status")?.min(u16::MAX as u64) as u16,
        stage_us,
        total_us: u64_field("total_us")?,
    })
}

/// Parse request records from either serve telemetry format:
///
/// * an access-log file — JSONL, one flat record object per line;
/// * a `/debug/trace` dump — one JSON object whose `records` array
///   holds the same objects (the pinned `slow` ring is skipped: its
///   entries are copies of main-ring records and would double-count).
pub fn parse_request_records(text: &str) -> Result<Vec<ParsedRequest>> {
    let first = text.lines().find(|l| !l.trim().is_empty()).unwrap_or("").trim();
    if first.is_empty() {
        return Err(FastSurvivalError::InvalidData(
            "empty request-record input (expected access-log JSONL or a /debug/trace dump)"
                .into(),
        ));
    }
    // A dump is a single object spanning the whole text; an access log
    // has one complete object per line. Probe the first line: if it
    // parses on its own, treat the input as JSONL.
    if json::parse(first).is_err() {
        let doc = json::parse(text)?;
        let records = doc.require("records")?.as_array()?;
        return records.iter().map(parse_one_record).collect();
    }
    let probe = json::parse(first)?;
    if probe.get("records").is_some() {
        // Single-line dump.
        let records = probe.require("records")?.as_array()?;
        return records.iter().map(parse_one_record).collect();
    }
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| parse_one_record(&json::parse(l)?))
        .collect()
}

// ------------------------------------------------------- sliced metrics

/// Batch-size bucket label for a scored row count (log₂ ranges, capped
/// at `4096+` — the micro-batcher's default row budget).
pub fn batch_bucket(rows: u64) -> &'static str {
    match rows {
        0 => "0",
        1 => "1",
        2..=3 => "2-3",
        4..=7 => "4-7",
        8..=15 => "8-15",
        16..=31 => "16-31",
        32..=63 => "32-63",
        64..=127 => "64-127",
        128..=255 => "128-255",
        256..=511 => "256-511",
        512..=1023 => "512-1023",
        1024..=2047 => "1024-2047",
        2048..=4095 => "2048-4095",
        _ => "4096+",
    }
}

/// Atomic counters for one (endpoint, model@version, batch bucket)
/// slice — same lock-free recording discipline as the endpoint stats.
struct SliceStats {
    requests: AtomicU64,
    errors: AtomicU64,
    rows: AtomicU64,
    stage_us: [AtomicU64; N_STAGES],
    hist: LatencyHistogram,
}

impl SliceStats {
    fn new() -> Self {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        SliceStats {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            stage_us: [ZERO; N_STAGES],
            hist: LatencyHistogram::new(),
        }
    }
}

struct SliceKey {
    endpoint: &'static str,
    model: String,
    batch: &'static str,
}

/// Per-(endpoint × model@version × batch-size-bucket) SLO metrics.
///
/// The slice table is append-only and tiny (endpoints × loaded models ×
/// ~14 buckets), so the hot path is a read-lock scan plus relaxed
/// fetch-adds; the write lock is taken once per new slice, ever.
#[derive(Default)]
pub struct SlicedMetrics {
    slices: RwLock<Vec<(SliceKey, Arc<SliceStats>)>>,
}

impl SlicedMetrics {
    pub fn new() -> Self {
        SlicedMetrics::default()
    }

    fn slot(&self, endpoint: &'static str, model: &str, batch: &'static str) -> Arc<SliceStats> {
        {
            let slices = self.slices.read().unwrap();
            if let Some((_, stats)) = slices.iter().find(|(k, _)| {
                k.endpoint == endpoint && k.model == model && k.batch == batch
            }) {
                return Arc::clone(stats);
            }
        }
        let mut slices = self.slices.write().unwrap();
        if let Some((_, stats)) = slices
            .iter()
            .find(|(k, _)| k.endpoint == endpoint && k.model == model && k.batch == batch)
        {
            return Arc::clone(stats);
        }
        let stats = Arc::new(SliceStats::new());
        slices.push((
            SliceKey { endpoint, model: model.to_string(), batch },
            Arc::clone(&stats),
        ));
        stats
    }

    /// Fold one completed request into its slice.
    pub fn record(&self, rec: &RequestRecord) {
        let stats = self.slot(rec.endpoint, &rec.model, batch_bucket(rec.rows));
        stats.requests.fetch_add(1, Ordering::Relaxed);
        if rec.status >= 400 {
            stats.errors.fetch_add(1, Ordering::Relaxed);
        }
        if rec.rows > 0 {
            stats.rows.fetch_add(rec.rows, Ordering::Relaxed);
        }
        for (slot, &us) in stats.stage_us.iter().zip(rec.stage_us.iter()) {
            if us > 0 {
                slot.fetch_add(us, Ordering::Relaxed);
            }
        }
        stats.hist.record(rec.total_us);
    }

    pub fn snapshot(&self) -> Vec<SliceSnapshot> {
        let slices = self.slices.read().unwrap();
        slices
            .iter()
            .map(|(k, s)| {
                let mut stage_us = [0u64; N_STAGES];
                for (o, a) in stage_us.iter_mut().zip(s.stage_us.iter()) {
                    *o = a.load(Ordering::Relaxed);
                }
                SliceSnapshot {
                    endpoint: k.endpoint,
                    model: k.model.clone(),
                    batch: k.batch,
                    requests: s.requests.load(Ordering::Relaxed),
                    errors: s.errors.load(Ordering::Relaxed),
                    rows: s.rows.load(Ordering::Relaxed),
                    stage_us,
                    latency_buckets: s.hist.bucket_counts(),
                    latency_count: s.hist.count(),
                    latency_sum_us: s.hist.sum_us(),
                }
            })
            .collect()
    }
}

/// A point-in-time copy of one slice's counters.
#[derive(Clone, Debug)]
pub struct SliceSnapshot {
    pub endpoint: &'static str,
    pub model: String,
    pub batch: &'static str,
    pub requests: u64,
    pub errors: u64,
    pub rows: u64,
    pub stage_us: [u64; N_STAGES],
    pub latency_buckets: [u64; N_BUCKETS],
    pub latency_count: u64,
    pub latency_sum_us: u64,
}

impl SliceSnapshot {
    pub fn p50_us(&self) -> f64 {
        quantile_from_counts(&self.latency_buckets, 0.50)
    }

    pub fn p99_us(&self) -> f64 {
        quantile_from_counts(&self.latency_buckets, 0.99)
    }
}

/// Append the sliced-metrics array to a JSON document under
/// construction (the `/metrics` handler).
pub fn write_sliced_json(slices: &[SliceSnapshot], out: &mut String) {
    out.push('[');
    for (i, s) in slices.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str("{\"endpoint\": ");
        json::write_str(out, s.endpoint);
        out.push_str(", \"model\": ");
        json::write_str(out, &s.model);
        out.push_str(", \"batch\": ");
        json::write_str(out, s.batch);
        let _ = write!(
            out,
            ", \"requests\": {}, \"errors\": {}, \"rows\": {}",
            s.requests, s.errors, s.rows
        );
        out.push_str(", \"p50_ms\": ");
        json::write_f64(out, s.p50_us() / 1e3);
        out.push_str(", \"p99_ms\": ");
        json::write_f64(out, s.p99_us() / 1e3);
        for st in Stage::ALL {
            let _ = write!(out, ", \"{}\": {}", st.key(), s.stage_us[st.index()]);
        }
        out.push('}');
    }
    out.push(']');
}

/// Escape a label value for Prometheus text exposition.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Sliced series as Prometheus text exposition: request/error/row
/// counters, per-stage cumulative micros, and a conformant cumulative
/// latency histogram per slice.
pub fn render_sliced_prometheus(slices: &[SliceSnapshot]) -> String {
    let mut out = String::with_capacity(512 + slices.len() * 2048);
    if slices.is_empty() {
        return out;
    }
    let labels: Vec<String> = slices
        .iter()
        .map(|s| {
            format!(
                "endpoint=\"{}\",model=\"{}\",batch=\"{}\"",
                s.endpoint,
                escape_label(&s.model),
                s.batch
            )
        })
        .collect();
    out.push_str("# TYPE fastsurvival_sliced_requests_total counter\n");
    for (s, l) in slices.iter().zip(&labels) {
        let _ = writeln!(out, "fastsurvival_sliced_requests_total{{{l}}} {}", s.requests);
    }
    out.push_str("# TYPE fastsurvival_sliced_errors_total counter\n");
    for (s, l) in slices.iter().zip(&labels) {
        let _ = writeln!(out, "fastsurvival_sliced_errors_total{{{l}}} {}", s.errors);
    }
    out.push_str("# TYPE fastsurvival_sliced_rows_total counter\n");
    for (s, l) in slices.iter().zip(&labels) {
        let _ = writeln!(out, "fastsurvival_sliced_rows_total{{{l}}} {}", s.rows);
    }
    out.push_str("# TYPE fastsurvival_sliced_stage_us_total counter\n");
    for (s, l) in slices.iter().zip(&labels) {
        for st in Stage::ALL {
            let _ = writeln!(
                out,
                "fastsurvival_sliced_stage_us_total{{{l},stage=\"{}\"}} {}",
                st.name(),
                s.stage_us[st.index()]
            );
        }
    }
    out.push_str("# TYPE fastsurvival_sliced_latency_us histogram\n");
    for (s, l) in slices.iter().zip(&labels) {
        write_prom_cumulative(
            &mut out,
            "fastsurvival_sliced_latency_us",
            l,
            &s.latency_buckets,
            s.latency_count,
            s.latency_sum_us,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: &str, endpoint: &'static str, rows: u64, total_us: u64) -> RequestRecord {
        let mut stage_us = [0u64; N_STAGES];
        // A deterministic per-record stage pattern the torn-record test
        // can verify: stage k carries total + k.
        for (k, s) in stage_us.iter_mut().enumerate() {
            *s = total_us + k as u64;
        }
        RequestRecord {
            seq: 0,
            id: id.to_string(),
            endpoint,
            model: "risk@1".into(),
            rows,
            status: 200,
            stage_us,
            total_us,
        }
    }

    #[test]
    fn ring_wraps_and_keeps_the_newest_records() {
        let fr = FlightRecorder::new(8, 4, 0);
        for i in 0..20u64 {
            fr.record(rec(&format!("r{i}"), "score", i, i * 10));
        }
        assert_eq!(fr.recorded(), 20);
        assert_eq!(fr.capacity(), 8);
        let last = fr.last(8);
        assert_eq!(last.len(), 8);
        let seqs: Vec<u64> = last.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, (12..20).collect::<Vec<u64>>(), "oldest-first, post-wrap");
        assert_eq!(last[7].id, "r19");
        // Asking for fewer returns the newest k.
        let tail = fr.last(3);
        assert_eq!(tail.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![17, 18, 19]);
        // Slow ring disabled at threshold 0: nothing pinned.
        assert!(fr.slow().is_empty());
    }

    #[test]
    fn concurrent_writers_never_tear_records() {
        // 4 writer threads (the scoring-thread shape), a ring small
        // enough to wrap many times under the race. Every stored record
        // must be internally consistent: id, rows, total, and the
        // stage pattern all derive from the same value.
        let fr = Arc::new(FlightRecorder::new(16, 8, 1_000));
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let fr = Arc::clone(&fr);
                scope.spawn(move || {
                    for i in 0..500u64 {
                        let v = t * 10_000 + i;
                        fr.record(rec(&format!("v{v}"), "score", v, v));
                    }
                });
            }
        });
        assert_eq!(fr.recorded(), 2000);
        let check = |r: &RequestRecord| {
            let v = r.total_us;
            assert_eq!(r.id, format!("v{v}"), "torn id vs total");
            assert_eq!(r.rows, v, "torn rows vs total");
            for (k, &s) in r.stage_us.iter().enumerate() {
                assert_eq!(s, v + k as u64, "torn stage {k}");
            }
        };
        let last = fr.last(16);
        assert_eq!(last.len(), 16);
        for r in &last {
            check(r);
        }
        // Sequences strictly increase (no duplicate or regressed slot).
        for w in last.windows(2) {
            assert!(w[0].seq < w[1].seq);
        }
        for r in &fr.slow() {
            check(r);
            assert!(r.total_us >= 1_000);
        }
    }

    #[test]
    fn slow_ring_survives_a_fast_burst() {
        let fr = FlightRecorder::new(4, 8, 5_000);
        for i in 0..3u64 {
            fr.record(rec(&format!("slow{i}"), "score", 64, 9_000 + i));
        }
        // A burst of fast requests wraps the 4-slot main ring many
        // times over; the slow ring must still hold all three outliers.
        for i in 0..100u64 {
            fr.record(rec(&format!("fast{i}"), "score", 1, 50));
        }
        let main_ids: Vec<&str> = fr.last(4).iter().map(|r| r.id.as_str()).collect();
        assert!(main_ids.iter().all(|id| id.starts_with("fast")), "{main_ids:?}");
        let slow = fr.slow();
        assert_eq!(slow.len(), 3, "fast burst evicted pinned slow records");
        for (i, r) in slow.iter().enumerate() {
            assert_eq!(r.id, format!("slow{i}"));
            assert_eq!(r.total_us, 9_000 + i as u64);
        }
    }

    #[test]
    fn debug_trace_and_access_log_share_one_parseable_schema() {
        let fr = FlightRecorder::new(8, 4, 2_000);
        fr.record(rec("a", "score", 64, 500));
        fr.record(rec("b", "score", 64, 3_000)); // pinned slow
        fr.record(rec("c", "healthz", 0, 20));
        // Dump form.
        let dump = render_debug_trace(&fr, 2);
        let doc = json::parse(&dump).unwrap();
        assert_eq!(doc.require("capacity").unwrap().as_usize().unwrap(), 8);
        assert_eq!(doc.require("recorded").unwrap().as_usize().unwrap(), 3);
        assert_eq!(doc.require("slow_threshold_us").unwrap().as_usize().unwrap(), 2_000);
        assert_eq!(doc.require("records").unwrap().as_array().unwrap().len(), 2);
        assert_eq!(doc.require("slow").unwrap().as_array().unwrap().len(), 1);
        let parsed = parse_request_records(&dump).unwrap();
        assert_eq!(parsed.len(), 2, "slow ring must not double-count");
        assert_eq!(parsed[0].id, "b");
        assert_eq!(parsed[1].id, "c");
        assert_eq!(parsed[1].endpoint, "healthz");
        assert_eq!(parsed[0].stage_us[Stage::QueueWait.index()], 3_002);
        // JSONL form: one line per record, same schema.
        let mut jsonl = String::new();
        for r in fr.last(8) {
            write_record_json(&r, &mut jsonl);
            jsonl.push('\n');
        }
        let lines = parse_request_records(&jsonl).unwrap();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].id, "a");
        assert_eq!(lines[0].total_us, 500);
        assert_eq!(lines[0].stage_sum_us(), 500 * 6 + 15);
        // Garbage rejects instead of silently dropping.
        assert!(parse_request_records("{\"nope\": 1}\n").is_err());
        assert!(parse_request_records("").is_err());
    }

    #[test]
    fn batch_buckets_cover_and_order() {
        assert_eq!(batch_bucket(0), "0");
        assert_eq!(batch_bucket(1), "1");
        assert_eq!(batch_bucket(64), "64-127");
        assert_eq!(batch_bucket(4095), "2048-4095");
        assert_eq!(batch_bucket(4096), "4096+");
        assert_eq!(batch_bucket(u64::MAX), "4096+");
    }

    #[test]
    fn sliced_metrics_aggregate_and_expose() {
        let sliced = SlicedMetrics::new();
        let mut a = rec("a", "score", 64, 1_200);
        a.stage_us = [10, 100, 150, 800, 120, 20];
        sliced.record(&a);
        sliced.record(&a);
        let mut b = rec("b", "score", 64, 900);
        b.status = 400;
        sliced.record(&b);
        let mut c = rec("c", "healthz", 0, 30);
        c.model = String::new();
        sliced.record(&c);
        let snap = sliced.snapshot();
        assert_eq!(snap.len(), 2, "one slice per (endpoint, model, batch)");
        let score = snap.iter().find(|s| s.endpoint == "score").unwrap();
        assert_eq!(score.model, "risk@1");
        assert_eq!(score.batch, "64-127");
        assert_eq!(score.requests, 3);
        assert_eq!(score.errors, 1);
        assert_eq!(score.rows, 192);
        assert_eq!(score.stage_us[Stage::QueueWait.index()], 150 + 150 + 902);
        assert!(score.p50_us() > 0.0 && score.p50_us() <= score.p99_us());
        // JSON block parses.
        let mut js = String::new();
        write_sliced_json(&snap, &mut js);
        let doc = json::parse(&js).unwrap();
        assert_eq!(doc.as_array().unwrap().len(), 2);
        // Prometheus exposition carries the full label set and a
        // conformant histogram.
        let prom = render_sliced_prometheus(&snap);
        let l = "endpoint=\"score\",model=\"risk@1\",batch=\"64-127\"";
        assert!(prom.contains(&format!("fastsurvival_sliced_requests_total{{{l}}} 3")));
        assert!(prom.contains(&format!("fastsurvival_sliced_errors_total{{{l}}} 1")));
        assert!(prom
            .contains(&format!("fastsurvival_sliced_stage_us_total{{{l},stage=\"queue_wait\"}}")));
        assert!(prom.contains(&format!("fastsurvival_sliced_latency_us_bucket{{{l},le=\"+Inf\"}} 3")));
        assert!(prom.contains(&format!("fastsurvival_sliced_latency_us_count{{{l}}} 3")));
        // Empty snapshot renders nothing (no dangling TYPE headers).
        assert!(render_sliced_prometheus(&[]).is_empty());
    }
}
